"""AOT lowering: JAX/Pallas computations -> HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the pinned xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser on the Rust side reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts are named ``<op>__<in0>__<in1>....hlo.txt`` with dims joined by
``x`` (e.g. ``linear_gelu__64x256__256x256__256.hlo.txt``); a
``manifest.txt`` lists every artifact with input/output shapes so the Rust
registry can validate at load time.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


def shape_tag(s) -> str:
    return "x".join(str(d) for d in s.shape) if s.shape else "scalar"


# The artifact catalog. Shapes here must match what the Rust side requests
# (rust/src/runtime/registry.rs and the fig2/transformer benches).
def catalog():
    entries = []

    def add(name, fn, *specs):
        entries.append((name, fn, specs))

    # smoke artifact: the /opt/xla-example round-trip computation
    add("matmul_add", model.matmul_add, spec(2, 2), spec(2, 2))

    # plain matmul offload shapes (MLP layers of the fig2 demo + bench)
    for m, k, n in [(32, 256, 256), (32, 256, 64), (64, 256, 256), (8, 64, 64)]:
        add("matmul", model.matmul, spec(m, k), spec(k, n))

    # fused linear+gelu (Pallas) at the MLP shapes
    for m, k, n in [(32, 256, 256), (64, 256, 1024), (128, 256, 256)]:
        add("linear_gelu", model.fused_linear_gelu, spec(m, k), spec(k, n), spec(n))

    # fused attention (Pallas): [B*H, L, hd]
    for bh, l, hd in [(8, 32, 64), (16, 64, 32)]:
        add("attention", model.fused_attention, spec(bh, l, hd), spec(bh, l, hd), spec(bh, l, hd))

    # fused layernorm (Pallas)
    for m, d in [(256, 256), (2048, 256)]:
        add("layernorm", model.fused_layernorm, spec(m, d), spec(d), spec(d))

    # full transformer block (B, L, D, heads) = (4, 32, 256, 4)
    b, l, d, heads, mlp = 4, 32, 256, 4, 1024
    blk = functools.partial(model.transformer_block, heads=heads)
    add(
        "transformer_block",
        blk,
        spec(b, l, d),  # x
        spec(d, d), spec(d, d), spec(d, d), spec(d, d),  # wq wk wv wo
        spec(d, mlp), spec(mlp,), spec(mlp, d), spec(d,),  # w1 b1 w2 b2
        spec(d,), spec(d,), spec(d,), spec(d,),  # ln1_g ln1_b ln2_g ln2_b
    )
    return entries


def lower_entry(name, fn, specs, out_dir):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    tag = "__".join([name] + [shape_tag(s) for s in specs])
    fname = f"{tag}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # output shape for the manifest
    out = jax.eval_shape(fn, *specs)
    out_shape = out[0].shape if isinstance(out, tuple) else out.shape
    return fname, out_shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name, fn, specs in catalog():
        fname, out_shape = lower_entry(name, fn, specs, args.out_dir)
        ins = ";".join(shape_tag(s) for s in specs)
        outs = "x".join(str(d) for d in out_shape)
        manifest.append(f"{name}\t{fname}\t{ins}\t{outs}")
        print(f"lowered {fname}  out={outs}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
