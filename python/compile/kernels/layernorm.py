"""L1 Pallas kernel: fused row-wise layer norm.

One grid step normalizes a `[bm, D]` strip entirely in VMEM: mean,
variance, scale, shift in a single pass instead of five separate HLO ops.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import pick_block


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...][None, :] + b_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def layernorm(x, gamma, beta, eps=1e-5, interpret=True):
    """Row-wise layer norm over the last dim of x [M, D]."""
    m, d = x.shape
    bm = pick_block(m)
    kern = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)
