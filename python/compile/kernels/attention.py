"""L1 Pallas kernel: fused scaled-dot-product attention with online
softmax (flash-attention schedule).

TPU thinking: one grid step owns a `[bq, d]` query tile resident in VMEM;
keys/values stream through in `[bk, d]` tiles. The running max `m`, running
normalizer `l`, and the output accumulator stay in registers/VMEM across
the K loop, so the `[L, L]` score matrix never materializes in HBM — the
same insight as the CUDA flash-attention paper, re-expressed with
BlockSpec + fori_loop instead of threadblock shared-memory staging.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import pick_block


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bk):
    q = q_ref[...]  # [bq, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    l_total = k_ref.shape[0]
    steps = l_total // bk

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_tile = pl.load(k_ref, (pl.dslice(i * bk, bk), slice(None)))  # [bk, d]
        v_tile = pl.load(v_ref, (pl.dslice(i * bk, bk), slice(None)))
        s = jnp.dot(q.astype(jnp.float32), k_tile.astype(jnp.float32).T) * scale  # [bq, bk]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jnp.dot(p, v_tile.astype(jnp.float32))
        return m_new, l_new, acc

    bq = q.shape[0]
    init = (
        jnp.full((bq,), -jnp.inf, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
        jnp.zeros((bq, d), jnp.float32),
    )
    _, l_fin, acc = jax.lax.fori_loop(0, steps, body, init)
    o_ref[...] = (acc / l_fin[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention(q, k, v, interpret=True):
    """softmax(q kᵀ/√d) v for q,k,v [B, L, D] (heads pre-folded into B)."""
    bsz, l, d = q.shape
    bq = pick_block(l, 128)
    bk = pick_block(l, 128)
    grid = (bsz, l // bq)
    kern = functools.partial(_kernel, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, l, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, l, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(l, d, dtype_bytes=4):
    """Per-grid-step VMEM estimate: q tile + k/v tiles + accumulators."""
    bq, bk = pick_block(l, 128), pick_block(l, 128)
    return dtype_bytes * (bq * d + 2 * bk * d + bq * d + 2 * bq)
