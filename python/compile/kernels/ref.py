"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness
ground truth — pytest asserts kernels against these)."""

import jax
import jax.numpy as jnp


def linear_gelu_ref(x, w, b):
    """y = gelu(x @ w + b), exact (erf) gelu."""
    y = x @ w + b
    return y * 0.5 * (1.0 + jax.lax.erf(y / jnp.sqrt(2.0).astype(y.dtype)))


def matmul_ref(x, w):
    """Plain matmul."""
    return x @ w


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Row-wise layer norm over the last dim."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention_ref(q, k, v):
    """softmax(q kᵀ / sqrt(d)) v over [B, L, D] (heads pre-folded into B)."""
    d = q.shape[-1]
    scores = jnp.einsum("bld,bmd->blm", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("blm,bmd->bld", p, v)


def transformer_block_ref(x, params, heads):
    """Pre-norm transformer block matching python/compile/model.py."""
    h = layernorm_ref(x, params["ln1_g"], params["ln1_b"])
    b, l, d = h.shape
    hd = d // heads

    def split(t):
        return (
            t.reshape(b, l, heads, hd).transpose(0, 2, 1, 3).reshape(b * heads, l, hd)
        )

    q = split(h @ params["wq"])
    k = split(h @ params["wk"])
    v = split(h @ params["wv"])
    ctx = attention_ref(q, k, v)
    ctx = ctx.reshape(b, heads, l, hd).transpose(0, 2, 1, 3).reshape(b, l, d)
    x = x + ctx @ params["wo"]
    h2 = layernorm_ref(x, params["ln2_g"], params["ln2_b"])
    mlp = linear_gelu_ref(h2.reshape(b * l, d), params["w1"], params["b1"])
    mlp = mlp @ params["w2"] + params["b2"]
    return x + mlp.reshape(b, l, d)
