"""L1 Pallas kernel: fused tiled `gelu(x @ w + b)`.

TPU thinking (DESIGN.md §Hardware-Adaptation): the tile shape is chosen for
the 128x128 MXU systolic array; each grid step stages an `[bm, K]` strip of
`x` and a `[K, bn]` strip of `w` into VMEM via BlockSpec, performs the
matmul at f32 accumulation, and applies bias+GELU in-register before the
write-back — one HBM round-trip for the whole epilogue instead of three
(matmul, bias add, gelu) in the unfused graph.

Runs with ``interpret=True`` everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode lowers the kernel to
plain HLO while preserving the block structure (see /opt/xla-example
README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def erf_approx(x):
    """Abramowitz & Stegun 7.1.26 erf (|err| < 1.5e-7), composed from
    primitive ops only: the pinned XLA 0.5.1 HLO text parser predates the
    dedicated `erf` opcode, so the kernel cannot lower through
    ``jax.lax.erf``. Matches the Rust CPU backend's erf bit-for-bit in
    structure."""
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = ((((1.061405429 * t - 1.453152027) * t + 1.421413741) * t - 0.284496736) * t + 0.254829592) * t
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b[None, :]
    o_ref[...] = (
        acc * 0.5 * (1.0 + erf_approx(acc / jnp.sqrt(2.0).astype(acc.dtype)))
    ).astype(o_ref.dtype)


def pick_block(dim, target=128):
    """Largest divisor of ``dim`` that is <= target (MXU-shaped when
    possible)."""
    for cand in (target, 64, 32, 16, 8, 4, 2, 1):
        if dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def linear_gelu(x, w, b, interpret=True):
    """gelu(x @ w + b) with x [M,K], w [K,N], b [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = pick_block(m)
    bn = pick_block(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, b)


def vmem_bytes(m, k, n, dtype_bytes=4):
    """VMEM footprint estimate for one grid step (DESIGN.md §Perf):
    x strip + w strip + bias + accumulator."""
    bm, bn = pick_block(m), pick_block(n)
    return dtype_bytes * (bm * k + k * bn + bn + bm * bn)
