"""L2: JAX compute graphs calling the L1 Pallas kernels.

These are the "vendor library" entry points of the Rust framework: each
function here is AOT-lowered by ``aot.py`` to an HLO-text artifact that the
Rust PJRT runtime (`rust/src/runtime/`) loads and executes from the request
path. Python never runs at serve/train time.
"""

import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.fused_linear import linear_gelu
from .kernels.layernorm import layernorm


def matmul(x, w):
    """Plain matmul artifact (hot-op offload for the XLA tensor backend)."""
    return (jnp.matmul(x, w),)


def matmul_add(x, y):
    """The /opt/xla-example smoke computation: matmul(x, y) + 2."""
    return (jnp.matmul(x, y) + 2.0,)


def fused_linear_gelu(x, w, b):
    """gelu(x @ w + b) through the Pallas tile kernel."""
    return (linear_gelu(x, w, b),)


def fused_attention(q, k, v):
    """Flash-style fused attention through the Pallas kernel.

    q/k/v are [B*H, L, hd] (heads pre-folded, matching the Rust
    MultiheadAttention's split_heads layout).
    """
    return (attention(q, k, v),)


def fused_layernorm(x, g, b):
    """Row-fused layer norm through the Pallas kernel."""
    return (layernorm(x, g, b),)


def transformer_block(x, wq, wk, wv, wo, w1, b1, w2, b2, ln1_g, ln1_b, ln2_g, ln2_b, *, heads):
    """A full pre-norm transformer encoder block assembled from the Pallas
    kernels — the model-level artifact benchmarked against the Rust
    composed forward (Figure 2's "static/AOT" computation mode)."""
    b, l, d = x.shape
    hd = d // heads

    h = layernorm(x.reshape(b * l, d), ln1_g, ln1_b).reshape(b, l, d)

    def split(t):
        return (
            t.reshape(b, l, heads, hd).transpose(0, 2, 1, 3).reshape(b * heads, l, hd)
        )

    q = split(h @ wq)
    k = split(h @ wk)
    v = split(h @ wv)
    ctx = attention(q, k, v)
    ctx = ctx.reshape(b, heads, l, hd).transpose(0, 2, 1, 3).reshape(b, l, d)
    x = x + ctx @ wo
    h2 = layernorm(x.reshape(b * l, d), ln2_g, ln2_b)
    mlp = linear_gelu(h2, w1, b1)
    mlp = mlp @ w2 + b2
    return (x + mlp.reshape(b, l, d),)
