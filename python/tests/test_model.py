"""L2 model graphs vs reference + AOT lowering smoke tests."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def _block_params(key, d=64, mlp=128):
    ks = jax.random.split(key, 8)
    p = {
        "wq": jax.random.normal(ks[0], (d, d)) * 0.05,
        "wk": jax.random.normal(ks[1], (d, d)) * 0.05,
        "wv": jax.random.normal(ks[2], (d, d)) * 0.05,
        "wo": jax.random.normal(ks[3], (d, d)) * 0.05,
        "w1": jax.random.normal(ks[4], (d, mlp)) * 0.05,
        "b1": jnp.zeros((mlp,)),
        "w2": jax.random.normal(ks[5], (mlp, d)) * 0.05,
        "b2": jnp.zeros((d,)),
        "ln1_g": jnp.ones((d,)),
        "ln1_b": jnp.zeros((d,)),
        "ln2_g": jnp.ones((d,)),
        "ln2_b": jnp.zeros((d,)),
    }
    return p


def test_transformer_block_matches_ref():
    d, heads = 64, 4
    p = _block_params(jax.random.PRNGKey(0), d=d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    got = model.transformer_block(
        x, p["wq"], p["wk"], p["wv"], p["wo"], p["w1"], p["b1"], p["w2"], p["b2"],
        p["ln1_g"], p["ln1_b"], p["ln2_g"], p["ln2_b"], heads=heads,
    )[0]
    want = ref.transformer_block_ref(x, p, heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5)


def test_hlo_text_lowering_roundtrips():
    # every catalog entry lowers to parseable, non-empty HLO text
    lowered = jax.jit(model.matmul_add).lower(
        aot.spec(2, 2), aot.spec(2, 2)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_catalog_entries_all_lower(tmp_path):
    entries = aot.catalog()
    assert len(entries) >= 10
    # lower a representative subset (full catalog runs via `make artifacts`)
    for name, fn, specs in entries[:3]:
        fname, out_shape = aot.lower_entry(name, fn, specs, str(tmp_path))
        assert (tmp_path / fname).exists()
        assert len(out_shape) >= 1


def test_pallas_artifact_executes_on_cpu_pjrt():
    # interpret-mode pallas lowers to plain HLO executable by CPU PJRT:
    # run the lowered computation through jax itself as a sanity check
    fn = functools.partial(model.fused_linear_gelu)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    b = jnp.zeros((32,))
    out = fn(x, w, b)[0]
    want = ref.linear_gelu_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
