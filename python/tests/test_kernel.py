"""Pallas kernels (interpret=True) vs pure-jnp oracles — the core L1
correctness signal. Sweeps shapes/dtypes; uses hypothesis when available,
otherwise a parametrized grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.fused_linear import linear_gelu, pick_block
from compile.kernels.layernorm import layernorm

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=dtype)


LINEAR_SHAPES = [(8, 16, 32), (32, 256, 256), (64, 128, 64), (1, 8, 8), (128, 64, 256)]


@pytest.mark.parametrize("m,k,n", LINEAR_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_gelu_matches_ref(m, k, n, dtype):
    keys = jax.random.split(jax.random.PRNGKey(m * 31 + n), 3)
    x = rand(keys[0], m, k, dtype=dtype)
    w = rand(keys[1], k, n, dtype=dtype)
    b = rand(keys[2], n, dtype=dtype)
    got = linear_gelu(x, w, b)
    want = ref.linear_gelu_ref(x, w, b)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


ATTN_SHAPES = [(2, 16, 8), (8, 32, 64), (4, 64, 32), (1, 8, 16)]


@pytest.mark.parametrize("b,l,d", ATTN_SHAPES)
def test_attention_matches_ref(b, l, d):
    keys = jax.random.split(jax.random.PRNGKey(b * 7 + l), 3)
    q, k, v = (rand(kk, b, l, d) for kk in keys)
    got = attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,d", [(4, 8), (256, 256), (32, 128), (1, 16)])
def test_layernorm_matches_ref(m, d):
    keys = jax.random.split(jax.random.PRNGKey(m + d), 3)
    x = rand(keys[0], m, d) * 3 + 1
    g = rand(keys[1], d)
    b = rand(keys[2], d)
    got = layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_pick_block_divides():
    for dim in [1, 2, 7, 8, 32, 100, 128, 256, 384]:
        blk = pick_block(dim)
        assert dim % blk == 0
        assert blk <= 128


def test_attention_rows_normalized():
    # attention output of constant V must be that constant
    b, l, d = 2, 16, 8
    q = rand(jax.random.PRNGKey(0), b, l, d)
    k = rand(jax.random.PRNGKey(1), b, l, d)
    v = jnp.ones((b, l, d), jnp.float32) * 3.5
    out = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
        k=st.sampled_from([8, 16, 64, 128]),
        n=st.sampled_from([8, 32, 128, 256]),
    )
    def test_linear_gelu_hypothesis_sweep(m, k, n):
        keys = jax.random.split(jax.random.PRNGKey(m * 1000 + k * 10 + n), 3)
        x = rand(keys[0], m, k)
        w = rand(keys[1], k, n)
        b = rand(keys[2], n)
        np.testing.assert_allclose(
            np.asarray(linear_gelu(x, w, b)),
            np.asarray(ref.linear_gelu_ref(x, w, b)),
            rtol=1e-5,
            atol=1e-5,
        )

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 4),
        l=st.sampled_from([8, 16, 32, 64]),
        d=st.sampled_from([8, 16, 32]),
    )
    def test_attention_hypothesis_sweep(b, l, d):
        keys = jax.random.split(jax.random.PRNGKey(b * 100 + l + d), 3)
        q, k, v = (rand(kk, b, l, d) for kk in keys)
        np.testing.assert_allclose(
            np.asarray(attention(q, k, v)),
            np.asarray(ref.attention_ref(q, k, v)),
            rtol=2e-5,
            atol=2e-5,
        )
