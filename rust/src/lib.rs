//! # flashlight-rs
//!
//! A Rust reproduction of **Flashlight: Enabling Innovation in Tools for
//! Machine Learning** (Kahn et al., ICML 2022): a minimalist, modular ML
//! framework whose contribution is its *open internal APIs* — a small
//! [`tensor::TensorBackend`] interface, a pluggable
//! [`memory::MemoryManagerAdapter`], a pluggable
//! [`dist::DistributedInterface`], a lightweight tape [`autograd`], and
//! compact reference implementations of each — plus domain packages and a
//! model zoo that make it a turn-key test bench for systems research.
//!
//! Architecture (paper Figure 1):
//!
//! ```text
//!  applications (examples/, coordinator,       trainers, launchers, CLI,
//!                serve)                        inference serving engine
//!  packages     (pkg::{speech, vision, text})  domain building blocks
//!  core         (nn, optim, data, meter)       modules, losses, pipelines
//!  autograd     (autograd::Variable)           dynamic tape
//!  foundation   (tensor, memory, dist)         open foundational interfaces
//!  backends     (tensor::cpu, tensor::lazy,    eager / deferred / AOT-static
//!                tensor::xla_backend+runtime)  computation modes (Figure 2)
//! ```
//!
//! The hot compute path can be offloaded to AOT-compiled XLA executables
//! (authored in JAX + Pallas at build time, loaded via PJRT by
//! [`runtime`]) — the analog of the original library's cuDNN/MKL vendor
//! kernels, behind the same small backend API.

pub mod autograd;
pub mod baseline;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod memory;
pub mod meter;
pub mod models;
pub mod nn;
pub mod obs;
pub mod optim;
pub mod pkg;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testutil;
pub mod util;

pub use autograd::Variable;
pub use tensor::{DType, Shape, Tensor};

/// Library version, mirroring the paper's evaluated Flashlight v0.3.1.
pub const VERSION: &str = "0.3.1-rs";
