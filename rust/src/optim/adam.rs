//! Adam-family optimizers (Adam, AdamW, Adagrad).
//!
//! The arithmetic — including bias correction — lives in the pure
//! [`UpdateRule`] cores; `step()` is a thin stateful wrapper, so eager
//! training and [`crate::coordinator::compile_step`] share one formula.
//! The step count feeds the rule as a scalar *tensor* so the bias
//! correction is itself backend-dispatched (and therefore traceable).

use crate::autograd::Variable;
use crate::tensor::{DType, Tensor};

use super::update::UpdateRule;
use super::Optimizer;

/// Adam (Kingma & Ba) with bias correction; `decoupled=false` puts weight
/// decay into the gradient (classic), `true` makes it AdamW.
pub struct AdamOptimizer {
    params: Vec<Variable>,
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    weight_decay: f64,
    decoupled: bool,
    m: Vec<Option<Tensor>>,
    v: Vec<Option<Tensor>>,
    t: u64,
}

impl AdamOptimizer {
    /// Standard Adam(0.9, 0.999).
    pub fn new(params: Vec<Variable>, lr: f64) -> Self {
        Self::full(params, lr, 0.9, 0.999, 1e-8, 0.0, false)
    }

    /// All knobs.
    pub fn full(
        params: Vec<Variable>,
        lr: f64,
        beta1: f64,
        beta2: f64,
        eps: f64,
        weight_decay: f64,
        decoupled: bool,
    ) -> Self {
        let n = params.len();
        AdamOptimizer {
            params,
            lr,
            beta1,
            beta2,
            eps,
            weight_decay,
            decoupled,
            m: vec![None; n],
            v: vec![None; n],
            t: 0,
        }
    }
}

impl AdamOptimizer {
    /// The pure update core this optimizer wraps.
    pub fn rule(&self) -> UpdateRule {
        UpdateRule::Adam {
            lr: self.lr,
            beta1: self.beta1,
            beta2: self.beta2,
            eps: self.eps,
            weight_decay: self.weight_decay,
            decoupled: self.decoupled,
        }
    }
}

impl Optimizer for AdamOptimizer {
    fn step(&mut self) {
        self.t += 1;
        let t = Tensor::full([], self.t as f64, DType::F32);
        let rule = self.rule();
        for (i, p) in self.params.iter().enumerate() {
            let Some(g) = p.grad() else { continue };
            let pt = p.tensor();
            let state: Vec<Tensor> = match (&self.m[i], &self.v[i]) {
                (Some(m), Some(v)) => vec![m.clone(), v.clone()],
                _ => rule.init_state(&pt),
            };
            let (p2, s2) = rule.apply(&pt, &g, &state, Some(&t));
            self.m[i] = Some(s2[0].clone());
            self.v[i] = Some(s2[1].clone());
            p.set_tensor(p2);
        }
    }

    fn params(&self) -> &[Variable] {
        &self.params
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

/// AdamW = Adam with decoupled weight decay.
pub struct AdamWOptimizer(AdamOptimizer);

impl AdamWOptimizer {
    /// Standard AdamW.
    pub fn new(params: Vec<Variable>, lr: f64, weight_decay: f64) -> Self {
        AdamWOptimizer(AdamOptimizer::full(params, lr, 0.9, 0.999, 1e-8, weight_decay, true))
    }
}

impl Optimizer for AdamWOptimizer {
    fn step(&mut self) {
        self.0.step()
    }
    fn params(&self) -> &[Variable] {
        self.0.params()
    }
    fn lr(&self) -> f64 {
        self.0.lr()
    }
    fn set_lr(&mut self, lr: f64) {
        self.0.set_lr(lr)
    }
}

/// Adagrad: per-coordinate accumulated squared gradients.
pub struct AdagradOptimizer {
    params: Vec<Variable>,
    lr: f64,
    eps: f64,
    accum: Vec<Option<Tensor>>,
}

impl AdagradOptimizer {
    /// Standard Adagrad.
    pub fn new(params: Vec<Variable>, lr: f64) -> Self {
        let n = params.len();
        AdagradOptimizer { params, lr, eps: 1e-10, accum: vec![None; n] }
    }
}

impl AdagradOptimizer {
    /// The pure update core this optimizer wraps.
    pub fn rule(&self) -> UpdateRule {
        UpdateRule::Adagrad { lr: self.lr, eps: self.eps }
    }
}

impl Optimizer for AdagradOptimizer {
    fn step(&mut self) {
        let rule = self.rule();
        for (i, p) in self.params.iter().enumerate() {
            let Some(g) = p.grad() else { continue };
            let pt = p.tensor();
            let state: Vec<Tensor> = match &self.accum[i] {
                Some(a) => vec![a.clone()],
                None => rule.init_state(&pt),
            };
            let (p2, s2) = rule.apply(&pt, &g, &state, None);
            self.accum[i] = Some(s2[0].clone());
            p.set_tensor(p2);
        }
    }

    fn params(&self) -> &[Variable] {
        &self.params
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |first update| == lr for any gradient scale
        let p = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        p.set_grad(Tensor::from_slice(&[123.0f32], [1]));
        let mut opt = AdamOptimizer::new(vec![p.clone()], 0.01);
        opt.step();
        assert!((p.tensor().item().abs() - 0.01).abs() < 1e-4);
    }

    #[test]
    fn adamw_decay_is_decoupled() {
        let p = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        p.set_grad(Tensor::zeros([1]));
        let mut opt = AdamWOptimizer::new(vec![p.clone()], 0.1, 0.5);
        opt.step();
        // zero gradient: only the decoupled decay applies: 1 - 0.1*0.5
        assert!((p.tensor().item() - 0.95).abs() < 1e-5);
    }

    #[test]
    fn adagrad_effective_lr_decays() {
        let p = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        let mut opt = AdagradOptimizer::new(vec![p.clone()], 1.0);
        p.set_grad(Tensor::from_slice(&[1.0f32], [1]));
        opt.step();
        let first = -p.tensor().item();
        p.set_grad(Tensor::from_slice(&[1.0f32], [1]));
        let before = p.tensor().item();
        opt.step();
        let second = before - p.tensor().item();
        assert!(second < first, "second step {second} not smaller than first {first}");
    }
}
