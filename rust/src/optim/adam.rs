//! Adam-family optimizers (Adam, AdamW, Adagrad).

use crate::autograd::Variable;
use crate::tensor::Tensor;

use super::Optimizer;

/// Adam (Kingma & Ba) with bias correction; `decoupled=false` puts weight
/// decay into the gradient (classic), `true` makes it AdamW.
pub struct AdamOptimizer {
    params: Vec<Variable>,
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    weight_decay: f64,
    decoupled: bool,
    m: Vec<Option<Tensor>>,
    v: Vec<Option<Tensor>>,
    t: u64,
}

impl AdamOptimizer {
    /// Standard Adam(0.9, 0.999).
    pub fn new(params: Vec<Variable>, lr: f64) -> Self {
        Self::full(params, lr, 0.9, 0.999, 1e-8, 0.0, false)
    }

    /// All knobs.
    pub fn full(
        params: Vec<Variable>,
        lr: f64,
        beta1: f64,
        beta2: f64,
        eps: f64,
        weight_decay: f64,
        decoupled: bool,
    ) -> Self {
        let n = params.len();
        AdamOptimizer {
            params,
            lr,
            beta1,
            beta2,
            eps,
            weight_decay,
            decoupled,
            m: vec![None; n],
            v: vec![None; n],
            t: 0,
        }
    }
}

impl Optimizer for AdamOptimizer {
    fn step(&mut self) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for (i, p) in self.params.iter().enumerate() {
            let Some(mut g) = p.grad() else { continue };
            if self.weight_decay != 0.0 && !self.decoupled {
                g = g.add(&p.tensor().mul_scalar(self.weight_decay));
            }
            let m = match &self.m[i] {
                Some(m) => m.mul_scalar(self.beta1).add(&g.mul_scalar(1.0 - self.beta1)),
                None => g.mul_scalar(1.0 - self.beta1),
            };
            let v = match &self.v[i] {
                Some(v) => v.mul_scalar(self.beta2).add(&g.mul(&g).mul_scalar(1.0 - self.beta2)),
                None => g.mul(&g).mul_scalar(1.0 - self.beta2),
            };
            self.m[i] = Some(m.clone());
            self.v[i] = Some(v.clone());
            let mhat = m.mul_scalar(1.0 / bc1);
            let vhat = v.mul_scalar(1.0 / bc2);
            let mut update = mhat.div(&vhat.sqrt().add_scalar(self.eps)).mul_scalar(self.lr);
            if self.weight_decay != 0.0 && self.decoupled {
                update = update.add(&p.tensor().mul_scalar(self.weight_decay * self.lr));
            }
            p.set_tensor(p.tensor().sub(&update));
        }
    }

    fn params(&self) -> &[Variable] {
        &self.params
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

/// AdamW = Adam with decoupled weight decay.
pub struct AdamWOptimizer(AdamOptimizer);

impl AdamWOptimizer {
    /// Standard AdamW.
    pub fn new(params: Vec<Variable>, lr: f64, weight_decay: f64) -> Self {
        AdamWOptimizer(AdamOptimizer::full(params, lr, 0.9, 0.999, 1e-8, weight_decay, true))
    }
}

impl Optimizer for AdamWOptimizer {
    fn step(&mut self) {
        self.0.step()
    }
    fn params(&self) -> &[Variable] {
        self.0.params()
    }
    fn lr(&self) -> f64 {
        self.0.lr()
    }
    fn set_lr(&mut self, lr: f64) {
        self.0.set_lr(lr)
    }
}

/// Adagrad: per-coordinate accumulated squared gradients.
pub struct AdagradOptimizer {
    params: Vec<Variable>,
    lr: f64,
    eps: f64,
    accum: Vec<Option<Tensor>>,
}

impl AdagradOptimizer {
    /// Standard Adagrad.
    pub fn new(params: Vec<Variable>, lr: f64) -> Self {
        let n = params.len();
        AdagradOptimizer { params, lr, eps: 1e-10, accum: vec![None; n] }
    }
}

impl Optimizer for AdagradOptimizer {
    fn step(&mut self) {
        for (i, p) in self.params.iter().enumerate() {
            let Some(g) = p.grad() else { continue };
            let acc = match &self.accum[i] {
                Some(a) => a.add(&g.mul(&g)),
                None => g.mul(&g),
            };
            self.accum[i] = Some(acc.clone());
            let update = g.div(&acc.sqrt().add_scalar(self.eps)).mul_scalar(self.lr);
            p.set_tensor(p.tensor().sub(&update));
        }
    }

    fn params(&self) -> &[Variable] {
        &self.params
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |first update| == lr for any gradient scale
        let p = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        p.set_grad(Tensor::from_slice(&[123.0f32], [1]));
        let mut opt = AdamOptimizer::new(vec![p.clone()], 0.01);
        opt.step();
        assert!((p.tensor().item().abs() - 0.01).abs() < 1e-4);
    }

    #[test]
    fn adamw_decay_is_decoupled() {
        let p = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        p.set_grad(Tensor::zeros([1]));
        let mut opt = AdamWOptimizer::new(vec![p.clone()], 0.1, 0.5);
        opt.step();
        // zero gradient: only the decoupled decay applies: 1 - 0.1*0.5
        assert!((p.tensor().item() - 0.95).abs() < 1e-5);
    }

    #[test]
    fn adagrad_effective_lr_decays() {
        let p = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        let mut opt = AdagradOptimizer::new(vec![p.clone()], 1.0);
        p.set_grad(Tensor::from_slice(&[1.0f32], [1]));
        opt.step();
        let first = -p.tensor().item();
        p.set_grad(Tensor::from_slice(&[1.0f32], [1]));
        let before = p.tensor().item();
        opt.step();
        let second = before - p.tensor().item();
        assert!(second < first, "second step {second} not smaller than first {first}");
    }
}
