//! Pure, traceable optimizer update rules.
//!
//! Each first-order optimizer in this crate factors into a stateless
//! [`UpdateRule`] core: a *pure function* `(param, grad, state[, t]) ->
//! (param', state')` expressed entirely in tensor ops. The mutating
//! [`super::Optimizer::step`] implementations are thin wrappers that feed
//! their per-parameter state through the rule and write the results back.
//!
//! Why this split matters: because a rule touches nothing but tensor
//! primitives, every arithmetic step flows through the installed backend's
//! `dispatch` choke point — so a capturing backend
//! ([`crate::tensor::TraceBackend`]) sees the *entire* optimizer update as
//! ordinary IR, and [`crate::coordinator::compile_step`] can fuse it into
//! one compiled program with the forward and backward passes. The eager
//! wrappers and the compiled replay execute the *same* op sequence, which
//! is what makes compiled-vs-eager parameter trajectories bit-identical.
//!
//! State layout is positional: [`UpdateRule::state_slots`] tensors per
//! parameter (velocity for momentum-SGD; first/second moments for Adam;
//! the squared-gradient accumulator for Adagrad/RMSProp), all initialized
//! to zeros by [`UpdateRule::init_state`] — zero state is arithmetically
//! identical to the lazily-initialized `None` state the wrappers
//! historically used (`0 * β + g == g` bitwise for finite `g`). Adam
//! additionally consumes a scalar step-count tensor `t` (already
//! incremented for the current step) so bias correction is itself a
//! traced computation rather than host-side `f64` math.

use crate::tensor::{DType, Tensor};
use crate::util::error::{Error, Result};

/// Scalar f32 constant on the default backend (traced like any other op).
fn scalar(v: f64) -> Tensor {
    Tensor::full([], v, DType::F32)
}

/// A stateless optimizer update core. See the module docs.
#[derive(Debug, Clone)]
pub enum UpdateRule {
    /// SGD with optional momentum / Nesterov / L2 weight decay.
    Sgd {
        /// Learning rate.
        lr: f64,
        /// Momentum coefficient (0 disables the velocity slot).
        momentum: f64,
        /// Nesterov lookahead.
        nesterov: bool,
        /// L2 weight decay added to the gradient.
        weight_decay: f64,
    },
    /// Adam / AdamW (Kingma & Ba) with bias correction.
    Adam {
        /// Learning rate.
        lr: f64,
        /// First-moment decay.
        beta1: f64,
        /// Second-moment decay.
        beta2: f64,
        /// Denominator fuzz.
        eps: f64,
        /// Weight decay; coupled (into the gradient) unless `decoupled`.
        weight_decay: f64,
        /// `true` = AdamW (decay applied directly to the parameter).
        decoupled: bool,
    },
    /// Adagrad: accumulated squared gradients.
    Adagrad {
        /// Learning rate.
        lr: f64,
        /// Denominator fuzz.
        eps: f64,
    },
    /// RMSProp: exponential moving average of squared gradients.
    RmsProp {
        /// Learning rate.
        lr: f64,
        /// Squared-gradient EMA decay.
        alpha: f64,
        /// Denominator fuzz.
        eps: f64,
    },
}

impl UpdateRule {
    /// The rule behind a [`crate::coordinator::TrainConfig`] optimizer
    /// string, mirroring `coordinator::trainer::make_optimizer` exactly
    /// (so an eager run and a compiled run of the same config share one
    /// arithmetic). Unknown names are an error.
    pub fn from_config(optimizer: &str, lr: f64) -> Result<UpdateRule> {
        match optimizer {
            "sgd" => Ok(UpdateRule::Sgd { lr, momentum: 0.9, nesterov: false, weight_decay: 0.0 }),
            "adam" => Ok(UpdateRule::Adam {
                lr,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                weight_decay: 0.0,
                decoupled: false,
            }),
            "adamw" => Ok(UpdateRule::Adam {
                lr,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                weight_decay: 0.01,
                decoupled: true,
            }),
            other => Err(Error::Config(format!("unknown optimizer `{other}`"))),
        }
    }

    /// Number of per-parameter state tensors the rule carries.
    pub fn state_slots(&self) -> usize {
        match self {
            UpdateRule::Sgd { momentum, .. } => usize::from(*momentum != 0.0),
            UpdateRule::Adam { .. } => 2,
            UpdateRule::Adagrad { .. } | UpdateRule::RmsProp { .. } => 1,
        }
    }

    /// Whether [`UpdateRule::apply`] needs the scalar step-count tensor.
    pub fn uses_step_count(&self) -> bool {
        matches!(self, UpdateRule::Adam { .. })
    }

    /// Fresh (all-zeros) state for one parameter.
    pub fn init_state(&self, param: &Tensor) -> Vec<Tensor> {
        (0..self.state_slots())
            .map(|_| Tensor::full(param.dims().to_vec(), 0.0, param.dtype()))
            .collect()
    }

    /// One pure update: `(param, grad, state[, t]) -> (param', state')`.
    ///
    /// `state` must have exactly [`UpdateRule::state_slots`] entries and
    /// `t` (the step count *after* incrementing, as a scalar tensor) must
    /// be present iff [`UpdateRule::uses_step_count`]. Nothing is mutated;
    /// every operation goes through the installed backend.
    pub fn apply(
        &self,
        param: &Tensor,
        grad: &Tensor,
        state: &[Tensor],
        t: Option<&Tensor>,
    ) -> (Tensor, Vec<Tensor>) {
        assert_eq!(state.len(), self.state_slots(), "update rule state arity");
        match *self {
            UpdateRule::Sgd { lr, momentum, nesterov, weight_decay } => {
                let mut g = grad.clone();
                if weight_decay != 0.0 {
                    g = g.add(&param.mul_scalar(weight_decay));
                }
                if momentum != 0.0 {
                    let v = state[0].mul_scalar(momentum).add(&g);
                    let update =
                        if nesterov { g.add(&v.mul_scalar(momentum)) } else { v.clone() };
                    (param.sub(&update.mul_scalar(lr)), vec![v])
                } else {
                    (param.sub(&g.mul_scalar(lr)), vec![])
                }
            }
            UpdateRule::Adam { lr, beta1, beta2, eps, weight_decay, decoupled } => {
                let t = t.expect("Adam update needs the step-count tensor");
                let mut g = grad.clone();
                if weight_decay != 0.0 && !decoupled {
                    g = g.add(&param.mul_scalar(weight_decay));
                }
                let m = state[0].mul_scalar(beta1).add(&g.mul_scalar(1.0 - beta1));
                let v = state[1].mul_scalar(beta2).add(&g.mul(&g).mul_scalar(1.0 - beta2));
                // bias correction as traced tensor math: 1 - beta^t
                let bc1 = scalar(1.0).sub(&scalar(beta1).pow(t));
                let bc2 = scalar(1.0).sub(&scalar(beta2).pow(t));
                let mhat = m.div(&bc1);
                let vhat = v.div(&bc2);
                let mut update = mhat.div(&vhat.sqrt().add_scalar(eps)).mul_scalar(lr);
                if weight_decay != 0.0 && decoupled {
                    update = update.add(&param.mul_scalar(weight_decay * lr));
                }
                (param.sub(&update), vec![m, v])
            }
            UpdateRule::Adagrad { lr, eps } => {
                let acc = state[0].add(&grad.mul(grad));
                let update = grad.div(&acc.sqrt().add_scalar(eps)).mul_scalar(lr);
                (param.sub(&update), vec![acc])
            }
            UpdateRule::RmsProp { lr, alpha, eps } => {
                let sq =
                    state[0].mul_scalar(alpha).add(&grad.mul(grad).mul_scalar(1.0 - alpha));
                let update = grad.div(&sq.sqrt().add_scalar(eps)).mul_scalar(lr);
                (param.sub(&update), vec![sq])
            }
        }
    }
}

/// Branch-free global L2-norm gradient clipping, expressed in tensor ops
/// so it is traceable: `scale = max_norm / max(norm, max_norm)` is exactly
/// `1.0` when the norm is under the cap (multiplying by `1.0` is bitwise
/// identity for finite f32), and `max_norm / norm` otherwise — no
/// data-dependent host branch, so the same formula runs eagerly and inside
/// a compiled train step. Returns the clipped gradients and the pre-clip
/// global norm (a scalar tensor).
pub fn clip_grads(grads: &[Tensor], max_norm: f64) -> (Vec<Tensor>, Tensor) {
    let mut total = scalar(0.0);
    for g in grads {
        total = total.add(&g.norm_sq());
    }
    let norm = total.sqrt();
    let scale = scalar(max_norm).div(&norm.maximum(&scalar(max_norm)));
    (grads.iter().map(|g| g.mul(&scale)).collect(), norm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_rejects_unknown() {
        assert!(UpdateRule::from_config("sgd", 0.1).is_ok());
        assert!(UpdateRule::from_config("adam", 0.1).is_ok());
        assert!(UpdateRule::from_config("adamw", 0.1).is_ok());
        assert!(UpdateRule::from_config("lion", 0.1).is_err());
    }

    #[test]
    fn sgd_momentum_rule_matches_hand_math() {
        let rule = UpdateRule::Sgd { lr: 1.0, momentum: 0.5, nesterov: false, weight_decay: 0.0 };
        let p = Tensor::from_slice(&[0.0f32], [1]);
        let g = Tensor::from_slice(&[1.0f32], [1]);
        let s0 = rule.init_state(&p);
        let (p1, s1) = rule.apply(&p, &g, &s0, None); // v=1, p=-1
        let (p2, _) = rule.apply(&p1, &g, &s1, None); // v=1.5, p=-2.5
        assert!((p1.item() + 1.0).abs() < 1e-6);
        assert!((p2.item() + 2.5).abs() < 1e-6);
    }

    #[test]
    fn adam_rule_first_step_is_lr_sized() {
        let rule = UpdateRule::Adam {
            lr: 0.01,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            decoupled: false,
        };
        let p = Tensor::from_slice(&[0.0f32], [1]);
        let g = Tensor::from_slice(&[123.0f32], [1]);
        let t = Tensor::from_slice(&[1.0f32], []);
        let (p1, st) = rule.apply(&p, &g, &rule.init_state(&p), Some(&t));
        assert!((p1.item().abs() - 0.01).abs() < 1e-4);
        assert_eq!(st.len(), 2);
    }

    #[test]
    fn rule_is_pure() {
        let rule = UpdateRule::RmsProp { lr: 0.1, alpha: 0.99, eps: 1e-8 };
        let p = Tensor::from_slice(&[3.0f32], [1]);
        let g = Tensor::from_slice(&[1.0f32], [1]);
        let s = rule.init_state(&p);
        let _ = rule.apply(&p, &g, &s, None);
        // inputs untouched
        assert_eq!(p.item(), 3.0);
        assert_eq!(g.item(), 1.0);
        assert_eq!(s[0].item(), 0.0);
    }

    #[test]
    fn clip_is_identity_under_cap_and_scales_over() {
        let g = Tensor::from_slice(&[3.0f32, 4.0], [2]);
        let (clipped, norm) = clip_grads(&[g.clone()], 10.0);
        assert!((norm.item() - 5.0).abs() < 1e-5);
        for (a, b) in clipped[0].to_vec().iter().zip(g.to_vec()) {
            assert_eq!(a.to_bits(), b.to_bits(), "under-cap clip must be bitwise identity");
        }
        let (clipped, norm) = clip_grads(&[g], 1.0);
        assert!((norm.item() - 5.0).abs() < 1e-5);
        let v = clipped[0].to_vec();
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
    }
}
