//! Stochastic gradient descent with optional momentum / Nesterov /
//! weight decay (paper Listing 9's `SGDOptimizer`).
//!
//! The arithmetic lives in the pure [`UpdateRule::Sgd`] core; `step()` is
//! a thin stateful wrapper, so eager training and
//! [`crate::coordinator::compile_step`] share one formula.

use crate::autograd::Variable;
use crate::tensor::Tensor;

use super::update::UpdateRule;
use super::Optimizer;

/// See module docs.
pub struct SGDOptimizer {
    params: Vec<Variable>,
    lr: f64,
    momentum: f64,
    nesterov: bool,
    weight_decay: f64,
    velocity: Vec<Option<Tensor>>,
}

impl SGDOptimizer {
    /// Plain SGD.
    pub fn new(params: Vec<Variable>, lr: f64) -> Self {
        Self::full(params, lr, 0.0, false, 0.0)
    }

    /// SGD with momentum (optionally Nesterov).
    pub fn with_momentum(params: Vec<Variable>, lr: f64, momentum: f64, nesterov: bool) -> Self {
        Self::full(params, lr, momentum, nesterov, 0.0)
    }

    /// All knobs.
    pub fn full(
        params: Vec<Variable>,
        lr: f64,
        momentum: f64,
        nesterov: bool,
        weight_decay: f64,
    ) -> Self {
        let n = params.len();
        SGDOptimizer { params, lr, momentum, nesterov, weight_decay, velocity: vec![None; n] }
    }
}

impl SGDOptimizer {
    /// The pure update core this optimizer wraps.
    pub fn rule(&self) -> UpdateRule {
        UpdateRule::Sgd {
            lr: self.lr,
            momentum: self.momentum,
            nesterov: self.nesterov,
            weight_decay: self.weight_decay,
        }
    }
}

impl Optimizer for SGDOptimizer {
    fn step(&mut self) {
        let rule = self.rule();
        for (i, p) in self.params.iter().enumerate() {
            let Some(g) = p.grad() else { continue };
            let pt = p.tensor();
            let state: Vec<Tensor> = match &self.velocity[i] {
                _ if self.momentum == 0.0 => Vec::new(),
                Some(v) => vec![v.clone()],
                None => rule.init_state(&pt),
            };
            let (p2, mut s2) = rule.apply(&pt, &g, &state, None);
            if self.momentum != 0.0 {
                self.velocity[i] = Some(s2.remove(0));
            }
            p.set_tensor(p2);
        }
    }

    fn params(&self) -> &[Variable] {
        &self.params
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_is_exact() {
        let p = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        p.set_grad(Tensor::from_slice(&[0.5f32], [1]));
        let mut opt = SGDOptimizer::new(vec![p.clone()], 0.2);
        opt.step();
        assert!((p.tensor().item() - 0.9).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let p = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        let mut opt = SGDOptimizer::with_momentum(vec![p.clone()], 1.0, 0.5, false);
        p.set_grad(Tensor::from_slice(&[1.0f32], [1]));
        opt.step(); // v=1, p=-1
        p.set_grad(Tensor::from_slice(&[1.0f32], [1]));
        opt.step(); // v=1.5, p=-2.5
        assert!((p.tensor().item() + 2.5).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let p = Variable::param(Tensor::from_slice(&[10.0f32], [1]));
        let mut opt = SGDOptimizer::full(vec![p.clone()], 0.1, 0.0, false, 1.0);
        p.set_grad(Tensor::zeros([1]));
        opt.step();
        assert!((p.tensor().item() - 9.0).abs() < 1e-5);
    }

    #[test]
    fn missing_grad_skipped() {
        let p = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        let mut opt = SGDOptimizer::new(vec![p.clone()], 0.5);
        opt.step(); // no grad: no change
        assert_eq!(p.tensor().item(), 1.0);
    }
}
