//! First-order stochastic optimizers (paper §4.2 "Optimizers"), defined in
//! terms of `Variable` and `Tensor` operations only — open to
//! experimentation with distributed or in-place variants.

pub mod adam;
pub mod rmsprop;
pub mod scheduler;
pub mod sgd;
pub mod update;

pub use adam::{AdagradOptimizer, AdamOptimizer, AdamWOptimizer};
pub use rmsprop::RMSPropOptimizer;
pub use scheduler::{CosineSchedule, LrSchedule, StepSchedule, WarmupLinearSchedule};
pub use sgd::SGDOptimizer;
pub use update::{clip_grads, UpdateRule};

use crate::autograd::Variable;
use crate::tensor::Tensor;

/// The optimizer interface: owns its parameter list, consumes accumulated
/// gradients on `step`.
pub trait Optimizer: Send {
    /// Apply one update using the gradients currently on the parameters.
    /// Parameters with no gradient are skipped.
    fn step(&mut self);

    /// Clear all parameter gradients (paper Listing 9's `zeroGrad`).
    fn zero_grad(&self) {
        for p in self.params() {
            p.zero_grad();
        }
    }

    /// The parameters being optimized.
    fn params(&self) -> &[Variable];

    /// Current learning rate.
    fn lr(&self) -> f64;

    /// Override the learning rate (used by schedulers).
    fn set_lr(&mut self, lr: f64);
}

/// Global L2-norm gradient clipping; returns the pre-clip norm.
///
/// Uses the same tensor formula as the branch-free
/// [`update::clip_grads`] traced by [`crate::coordinator::compile_step`],
/// but skips rewriting the gradients when the norm is under the cap:
/// there `clip_grads` multiplies by exactly `1.0`, a bitwise no-op, so
/// the early return is bit-identical to the traced path while sparing
/// the eager hot path a full copy of every gradient.
pub fn clip_grad_norm(params: &[Variable], max_norm: f64) -> f64 {
    let entries: Vec<(usize, Tensor)> =
        params.iter().enumerate().filter_map(|(i, p)| p.grad().map(|g| (i, g))).collect();
    if entries.is_empty() {
        return 0.0;
    }
    let grads: Vec<Tensor> = entries.iter().map(|(_, g)| g.clone()).collect();
    // the exact accumulation clip_grads performs
    let mut total = Tensor::full([], 0.0, crate::tensor::DType::F32);
    for g in &grads {
        total = total.add(&g.norm_sq());
    }
    let norm = total.sqrt().item();
    if norm > max_norm {
        let (clipped, _) = clip_grads(&grads, max_norm);
        for ((i, _), c) in entries.iter().zip(clipped) {
            params[*i].set_grad(c);
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ops;
    use crate::tensor::Tensor;

    /// Every optimizer must descend a convex quadratic.
    fn check_descends(mut make: impl FnMut(Vec<Variable>) -> Box<dyn Optimizer>) {
        let x = Variable::param(Tensor::from_slice(&[5.0f32, -3.0], [2]));
        let mut opt = make(vec![x.clone()]);
        let mut last = f64::INFINITY;
        for _ in 0..200 {
            let loss = ops::sum(&ops::mul(&x, &x), &[], false);
            let lv = loss.tensor().item();
            loss.backward();
            opt.step();
            opt.zero_grad();
            last = lv;
        }
        assert!(last < 1e-2, "did not descend: {last}");
    }

    #[test]
    fn all_optimizers_descend_quadratic() {
        check_descends(|p| Box::new(SGDOptimizer::new(p, 0.1)));
        check_descends(|p| Box::new(SGDOptimizer::with_momentum(p, 0.05, 0.9, false)));
        check_descends(|p| Box::new(SGDOptimizer::with_momentum(p, 0.05, 0.9, true)));
        check_descends(|p| Box::new(AdamOptimizer::new(p, 0.3)));
        check_descends(|p| Box::new(AdamWOptimizer::new(p, 0.3, 0.0)));
        check_descends(|p| Box::new(AdagradOptimizer::new(p, 1.0)));
        check_descends(|p| Box::new(RMSPropOptimizer::new(p, 0.05)));
    }

    #[test]
    fn clip_grad_norm_scales() {
        let p = Variable::param(Tensor::from_slice(&[3.0f32, 4.0], [2]));
        p.set_grad(Tensor::from_slice(&[3.0f32, 4.0], [2]));
        let norm = clip_grad_norm(&[p.clone()], 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let g = p.grad().unwrap().to_vec();
        assert!((g[0] - 0.6).abs() < 1e-6 && (g[1] - 0.8).abs() < 1e-6);
        // under the cap: untouched
        let norm2 = clip_grad_norm(&[p.clone()], 10.0);
        assert!((norm2 - 1.0).abs() < 1e-5);
        assert!((p.grad().unwrap().to_vec()[0] - 0.6).abs() < 1e-6);
    }
}
