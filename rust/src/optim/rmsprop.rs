//! RMSProp.
//!
//! The arithmetic lives in the pure [`UpdateRule::RmsProp`] core; `step()`
//! is a thin stateful wrapper (see [`super::update`]).

use crate::autograd::Variable;
use crate::tensor::Tensor;

use super::update::UpdateRule;
use super::Optimizer;

/// RMSProp with exponential moving average of squared gradients.
pub struct RMSPropOptimizer {
    params: Vec<Variable>,
    lr: f64,
    alpha: f64,
    eps: f64,
    sq: Vec<Option<Tensor>>,
}

impl RMSPropOptimizer {
    /// Standard RMSProp (alpha 0.99).
    pub fn new(params: Vec<Variable>, lr: f64) -> Self {
        let n = params.len();
        RMSPropOptimizer { params, lr, alpha: 0.99, eps: 1e-8, sq: vec![None; n] }
    }
}

impl RMSPropOptimizer {
    /// The pure update core this optimizer wraps.
    pub fn rule(&self) -> UpdateRule {
        UpdateRule::RmsProp { lr: self.lr, alpha: self.alpha, eps: self.eps }
    }
}

impl Optimizer for RMSPropOptimizer {
    fn step(&mut self) {
        let rule = self.rule();
        for (i, p) in self.params.iter().enumerate() {
            let Some(g) = p.grad() else { continue };
            let pt = p.tensor();
            let state: Vec<Tensor> = match &self.sq[i] {
                Some(s) => vec![s.clone()],
                None => rule.init_state(&pt),
            };
            let (p2, s2) = rule.apply(&pt, &g, &state, None);
            self.sq[i] = Some(s2[0].clone());
            p.set_tensor(p2);
        }
    }

    fn params(&self) -> &[Variable] {
        &self.params
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes_gradient_scale() {
        // two params with wildly different gradient scales move comparably
        let a = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        let b = Variable::param(Tensor::from_slice(&[0.0f32], [1]));
        let mut opt = RMSPropOptimizer::new(vec![a.clone(), b.clone()], 0.01);
        a.set_grad(Tensor::from_slice(&[1000.0f32], [1]));
        b.set_grad(Tensor::from_slice(&[0.001f32], [1]));
        opt.step();
        let ra = a.tensor().item().abs();
        let rb = b.tensor().item().abs();
        assert!(ra / rb < 2.0, "updates differ wildly: {ra} vs {rb}");
    }
}
