//! Learning-rate schedules (applied by the trainer each step).

/// A schedule maps a step index to a learning rate.
pub trait LrSchedule: Send {
    /// LR at `step` (0-based).
    fn lr_at(&self, step: u64) -> f64;
}

/// Constant-then-decay step schedule.
pub struct StepSchedule {
    /// Base LR.
    pub base: f64,
    /// Multiply by `gamma` every `every` steps.
    pub every: u64,
    /// Decay factor.
    pub gamma: f64,
}

impl LrSchedule for StepSchedule {
    fn lr_at(&self, step: u64) -> f64 {
        self.base * self.gamma.powi((step / self.every) as i32)
    }
}

/// Cosine decay from `base` to `floor` over `total` steps.
pub struct CosineSchedule {
    /// Peak LR.
    pub base: f64,
    /// Final LR.
    pub floor: f64,
    /// Horizon.
    pub total: u64,
}

impl LrSchedule for CosineSchedule {
    fn lr_at(&self, step: u64) -> f64 {
        let t = (step.min(self.total)) as f64 / self.total.max(1) as f64;
        self.floor + 0.5 * (self.base - self.floor) * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

/// Linear warmup to `base`, then linear decay to zero at `total`.
pub struct WarmupLinearSchedule {
    /// Peak LR.
    pub base: f64,
    /// Warmup steps.
    pub warmup: u64,
    /// Horizon.
    pub total: u64,
}

impl LrSchedule for WarmupLinearSchedule {
    fn lr_at(&self, step: u64) -> f64 {
        if step < self.warmup {
            self.base * (step + 1) as f64 / self.warmup as f64
        } else {
            let rest = (self.total - self.warmup).max(1) as f64;
            let done = (step - self.warmup) as f64;
            self.base * (1.0 - (done / rest).min(1.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_schedule_decays() {
        let s = StepSchedule { base: 1.0, every: 10, gamma: 0.1 };
        assert_eq!(s.lr_at(0), 1.0);
        assert_eq!(s.lr_at(9), 1.0);
        assert!((s.lr_at(10) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(25) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn cosine_endpoints() {
        let s = CosineSchedule { base: 1.0, floor: 0.1, total: 100 };
        assert!((s.lr_at(0) - 1.0).abs() < 1e-9);
        assert!((s.lr_at(100) - 0.1).abs() < 1e-9);
        assert!(s.lr_at(50) < 1.0 && s.lr_at(50) > 0.1);
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = WarmupLinearSchedule { base: 2.0, warmup: 10, total: 110 };
        assert!(s.lr_at(0) < s.lr_at(5));
        assert!((s.lr_at(9) - 2.0).abs() < 1e-9);
        assert!(s.lr_at(60) < 2.0);
        assert!(s.lr_at(109) > 0.0);
        assert_eq!(s.lr_at(200), 0.0);
    }
}
