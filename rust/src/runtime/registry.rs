//! Artifact catalog parsed from `artifacts/manifest.txt`
//! (`name \t file \t in0;in1;... \t out`, dims joined by `x`).

use std::collections::HashMap;
use std::path::Path;

use crate::tensor::Shape;
use crate::util::error::{Error, Result};

/// Lookup key: op name + exact input shapes (AOT executables are
/// shape-specialized).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// Op name (e.g. `linear_gelu`).
    pub op: String,
    /// Input shapes.
    pub ins: Vec<Vec<usize>>,
}

/// One manifest row.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Artifact file name within the artifacts dir.
    pub file: String,
    /// Output shape.
    pub out_shape: Shape,
}

/// The parsed manifest.
#[derive(Debug, Default)]
pub struct Registry {
    entries: HashMap<ArtifactKey, Entry>,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| d.parse::<usize>().map_err(|_| Error::Runtime(format!("bad dim in `{s}`"))))
        .collect()
}

impl Registry {
    /// Parse `manifest.txt`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("manifest {path:?}: {e}")))?;
        let mut entries = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(Error::Runtime(format!(
                    "manifest line {}: expected 4 tab-separated columns",
                    lineno + 1
                )));
            }
            let ins: Vec<Vec<usize>> =
                cols[2].split(';').map(parse_dims).collect::<Result<_>>()?;
            let out = parse_dims(cols[3])?;
            entries.insert(
                ArtifactKey { op: cols[0].to_string(), ins },
                Entry { file: cols[1].to_string(), out_shape: Shape::new(out) },
            );
        }
        Ok(Registry { entries })
    }

    /// Number of registered artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Exact-shape lookup.
    pub fn find(&self, op: &str, in_shapes: &[&Shape]) -> Option<&Entry> {
        let key = ArtifactKey {
            op: op.to_string(),
            ins: in_shapes.iter().map(|s| s.dims().to_vec()).collect(),
        };
        self.entries.get(&key)
    }

    /// All ops present (sorted, deduplicated).
    pub fn ops(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().map(|k| k.op.clone()).collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_rows() {
        let dir = std::env::temp_dir().join("fl_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.txt");
        std::fs::write(
            &path,
            "matmul\tmatmul__2x3__3x4.hlo.txt\t2x3;3x4\t2x4\nbias\tb.hlo.txt\t8\t8\n",
        )
        .unwrap();
        let r = Registry::load(&path).unwrap();
        assert_eq!(r.len(), 2);
        let s1 = Shape::new(vec![2, 3]);
        let s2 = Shape::new(vec![3, 4]);
        let e = r.find("matmul", &[&s1, &s2]).unwrap();
        assert_eq!(e.out_shape.dims(), &[2, 4]);
        assert!(r.find("matmul", &[&s2, &s1]).is_none());
        assert_eq!(r.ops(), vec!["bias".to_string(), "matmul".to_string()]);
    }

    #[test]
    fn rejects_malformed_rows() {
        let dir = std::env::temp_dir().join("fl_registry_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.txt");
        std::fs::write(&path, "just two\tcolumns\n").unwrap();
        assert!(Registry::load(&path).is_err());
    }

    #[test]
    fn scalar_dims_parse() {
        assert_eq!(parse_dims("scalar").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_dims("4x5").unwrap(), vec![4, 5]);
        assert!(parse_dims("4xbad").is_err());
    }
}
