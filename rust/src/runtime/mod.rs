//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! This is the "vendor kernel library" of the framework (DESIGN.md): the
//! XLA tensor backend ([`crate::tensor::xla_backend`]) dispatches hot ops
//! here exactly like the original library offloads to cuDNN/MKL. Python
//! runs only at `make artifacts` time; the `fl` binary is self-contained.

pub mod registry;
pub mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use crate::tensor::{DType, Shape, Tensor};
use crate::util::error::{Error, Result};

pub use registry::{ArtifactKey, Registry};

/// A compiled, executable artifact bound to the process-wide PJRT client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Output shape recorded in the manifest.
    pub out_shape: Shape,
}

/// The PJRT CPU runtime: artifact registry + compile cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    registry: Registry,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    dir: PathBuf,
}

// xla::PjRtClient wraps a thread-safe C++ client.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Open the artifacts directory (reads `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let registry = Registry::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT cpu client: {e}")))?;
        Ok(PjrtRuntime { client, registry, cache: Mutex::new(HashMap::new()), dir })
    }

    /// The process-wide runtime, if `artifacts/` exists (probed once).
    pub fn global() -> Option<Arc<PjrtRuntime>> {
        static INST: OnceLock<Option<Arc<PjrtRuntime>>> = OnceLock::new();
        INST.get_or_init(|| {
            let dir = std::env::var("FL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            PjrtRuntime::open(&dir).ok().map(Arc::new)
        })
        .clone()
    }

    /// The artifact registry.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Look up + compile (cached) the artifact for `op` with the given
    /// input shapes. Returns None when no artifact matches.
    pub fn lookup(&self, op: &str, in_shapes: &[&Shape]) -> Option<Arc<Executable>> {
        let entry = self.registry.find(op, in_shapes)?;
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(&entry.file) {
            return Some(e.clone());
        }
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str()?).ok()?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).ok()?;
        let out = Arc::new(Executable { exe, out_shape: entry.out_shape.clone() });
        cache.insert(entry.file.clone(), out.clone());
        Some(out)
    }

    /// Execute a compiled artifact on f32 tensors, returning the single
    /// (tupled) f32 output.
    pub fn execute(&self, exe: &Executable, inputs: &[&Tensor]) -> Result<Tensor> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.to_vec())
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("literal reshape: {e}")))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let out = lit.to_tuple1().map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))?;
        let values: Vec<f32> =
            out.to_vec().map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        if values.len() != exe.out_shape.numel() {
            return Err(Error::Runtime(format!(
                "artifact output {} elements, manifest says shape {}",
                values.len(),
                exe.out_shape
            )));
        }
        Ok(Tensor::from_host(
            crate::tensor::HostBuffer::F32(values),
            exe.out_shape.clone(),
        ))
    }

    /// Convenience: lookup + execute in one call.
    pub fn run(&self, op: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        let shapes: Vec<&Shape> = inputs.iter().map(|t| t.shape()).collect();
        let exe = self.lookup(op, &shapes).ok_or_else(|| Error::Unsupported {
            backend: "pjrt".into(),
            op: format!("{op}{shapes:?}"),
        })?;
        // f32-only artifact path
        for t in inputs {
            if t.dtype() != DType::F32 {
                return Err(Error::DType(format!("artifact {op} wants f32, got {}", t.dtype())));
            }
        }
        self.execute(&exe, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Arc<PjrtRuntime>> {
        // tests run from the workspace root; artifacts may not be built yet
        let rt = PjrtRuntime::global();
        if rt.is_none() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        }
        rt
    }

    #[test]
    fn smoke_matmul_add_roundtrip() {
        let Some(rt) = runtime() else { return };
        let x = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [2, 2]);
        let y = Tensor::ones([2, 2]);
        let out = rt.run("matmul_add", &[&x, &y]).unwrap();
        assert_eq!(out.to_vec(), vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn pallas_linear_gelu_artifact_matches_cpu_composition() {
        let Some(rt) = runtime() else { return };
        crate::util::rng::seed(77);
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -0.1, 0.1);
        let b = Tensor::rand([256], -0.1, 0.1);
        let got = rt.run("linear_gelu", &[&x, &w, &b]).unwrap();
        let want = x.matmul(&w).add(&b).gelu();
        let diff = got.max_abs_diff(&want).unwrap();
        assert!(diff < 1e-4, "pallas artifact vs cpu composition: {diff}");
    }

    #[test]
    fn pallas_attention_artifact_matches_cpu_composition() {
        let Some(rt) = runtime() else { return };
        crate::util::rng::seed(78);
        let q = Tensor::rand([8, 32, 64], -1.0, 1.0);
        let k = Tensor::rand([8, 32, 64], -1.0, 1.0);
        let v = Tensor::rand([8, 32, 64], -1.0, 1.0);
        let got = rt.run("attention", &[&q, &k, &v]).unwrap();
        let scale = 1.0 / 64.0f64.sqrt();
        let want = q.matmul(&k.t()).mul_scalar(scale).softmax(-1).matmul(&v);
        let diff = got.max_abs_diff(&want).unwrap();
        assert!(diff < 1e-4, "pallas attention vs cpu: {diff}");
    }

    #[test]
    fn missing_artifact_reports_unsupported() {
        let Some(rt) = runtime() else { return };
        let x = Tensor::ones([3, 3]);
        let err = rt.run("matmul", &[&x, &x]).unwrap_err();
        assert!(err.to_string().contains("does not support"));
    }
}
