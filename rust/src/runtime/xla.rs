//! Offline stand-in for the `xla-rs` PJRT bindings.
//!
//! The real runtime links libxla/PJRT through the `xla` crate; neither the
//! crate nor the native library is available on this offline testbed, so
//! this module reproduces the minimal API surface the [`super`] runtime
//! consumes and fails gracefully at the earliest entry point
//! ([`PjRtClient::cpu`]). Every caller already treats PJRT as optional
//! ([`super::PjrtRuntime::global`] returns `None`), so with this stub the
//! whole AOT/XLA path degrades to "artifacts not built" and the composed
//! CPU implementation takes over.
//!
//! Swapping in the real bindings is a one-line change: replace this module
//! with `use xla;` once the dependency is available.

/// Error type mirroring `xla-rs`'s error (Display-able, opaque).
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> Self {
        XlaError("PJRT/XLA bindings are not available in this offline build".to_string())
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// A PJRT client handle. In the stub, construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client. Always errors offline; callers degrade to
    /// the composed CPU path.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::unavailable())
    }

    /// Compile an XLA computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Parsed HLO module protobuf.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given input literals, producing per-device output
    /// buffers.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// A device-resident result buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape the literal.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    /// Extract the first element of a tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_closed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
