//! DDP-style gradient synchronization over the open
//! [`DistributedInterface`].
//!
//! After a worker's backward pass, [`GradientSynchronizer::synchronize`]
//! averages every parameter gradient with the other replicas. Gradients
//! are packed into **buckets** (flat f32 segments up to a configurable
//! byte budget) and each bucket is all-reduced as a single collective —
//! the same batching strategy distributed-data-parallel frameworks use to
//! amortize per-collective latency. Parameters are walked in *reverse*
//! registration order, mirroring the order in which the autograd tape
//! materializes gradients during the backward sweep, so a bucket launches
//! as soon as its gradients exist and communication overlaps the tail of
//! the backward pass instead of waiting for the full gradient set.
//!
//! For `world_size == 1` synchronization is a no-op: gradients stay
//! **bit-identical** to unsynchronized single-worker training (asserted by
//! the tests below), so the same training loop runs unmodified at any
//! world size.

use std::sync::Arc;

use crate::autograd::Variable;
use crate::tensor::Tensor;

use super::DistributedInterface;

/// Default bucket budget: 1 MiB of f32 gradients per collective (the
/// CPU-testbed analog of DDP's 25 MB default).
pub const DEFAULT_BUCKET_BYTES: usize = 1 << 20;

/// Bucketed gradient averaging over a [`DistributedInterface`]; see the
/// module docs.
pub struct GradientSynchronizer {
    dist: Arc<dyn DistributedInterface + Sync>,
    bucket_bytes: usize,
}

impl GradientSynchronizer {
    /// Synchronizer with the default bucket budget.
    pub fn new(dist: Arc<dyn DistributedInterface + Sync>) -> Self {
        Self::with_bucket_bytes(dist, DEFAULT_BUCKET_BYTES)
    }

    /// Synchronizer with an explicit per-bucket byte budget (minimum one
    /// gradient per bucket regardless of size).
    pub fn with_bucket_bytes(
        dist: Arc<dyn DistributedInterface + Sync>,
        bucket_bytes: usize,
    ) -> Self {
        GradientSynchronizer { dist, bucket_bytes: bucket_bytes.max(4) }
    }

    /// The communicator this synchronizer reduces over.
    pub fn dist(&self) -> &Arc<dyn DistributedInterface + Sync> {
        &self.dist
    }

    /// Average the gradients of `params` across all workers in place
    /// (`grad <- sum over workers / world_size`). Parameters without a
    /// gradient are skipped — every replica must agree on which parameters
    /// carry gradients (the collective contract).
    ///
    /// At `world_size == 1` this is a no-op, leaving every gradient (any
    /// dtype) untouched. At larger world sizes gradients travel through
    /// the reduction's f32 materialization — the
    /// [`all_reduce`](super::DistributedInterface::all_reduce) contract —
    /// so non-f32 gradients are narrowed to f32; the framework's training
    /// path is f32 throughout.
    pub fn synchronize(&self, params: &[Variable]) {
        let world = self.dist.world_size();
        if world <= 1 {
            return;
        }
        let entries = params.iter().enumerate().rev().filter_map(|(i, p)| p.grad().map(|g| (i, g)));
        self.reduce_entries(entries, world, &mut |i, t| params[i].set_grad(t));
    }

    /// Average a flat list of gradient *tensors* across all workers,
    /// returning the averaged tensors in the same order. This is the
    /// compiled-train-step face of the synchronizer: a
    /// [`crate::coordinator::CompiledTrainStep`] produces its gradients as
    /// program outputs rather than `Variable` side effects, and this
    /// method slots between the traced backward and the traced optimizer
    /// update.
    ///
    /// Bucketing is *identical* to [`GradientSynchronizer::synchronize`]
    /// (reverse order, same byte budget, one shared code path), so an
    /// eager replica and a compiled replica reduce bitwise-identical
    /// buckets. At `world_size == 1` the input handles are returned
    /// unchanged — bit-identical to unsynchronized training.
    pub fn average_tensors(&self, grads: &[Tensor]) -> Vec<Tensor> {
        let world = self.dist.world_size();
        if world <= 1 {
            return grads.to_vec();
        }
        let mut out: Vec<Option<Tensor>> = vec![None; grads.len()];
        let entries = grads.iter().enumerate().rev().map(|(i, g)| (i, g.clone()));
        self.reduce_entries(entries, world, &mut |i, t| out[i] = Some(t));
        out.into_iter().map(|t| t.expect("bucket reduction missed a gradient")).collect()
    }

    /// The shared bucketing sweep: walk `(index, gradient)` entries (the
    /// callers supply them in reverse registration order), pack them into
    /// byte-budgeted buckets, all-reduce each bucket as one collective,
    /// and hand every averaged gradient back through `apply`.
    fn reduce_entries(
        &self,
        entries: impl Iterator<Item = (usize, Tensor)>,
        world: usize,
        apply: &mut dyn FnMut(usize, Tensor),
    ) {
        let scale = 1.0 / world as f64;
        // (entry index, flat grad, grad dims) accumulated into the open bucket
        let mut bucket: Vec<(usize, Vec<f32>, Vec<usize>)> = Vec::new();
        let mut bytes = 0usize;
        for (i, g) in entries {
            let dims = g.dims().to_vec();
            let flat = g.to_vec();
            bytes += flat.len() * std::mem::size_of::<f32>();
            bucket.push((i, flat, dims));
            if bytes >= self.bucket_bytes {
                self.flush(&mut bucket, scale, apply);
                bytes = 0;
            }
        }
        self.flush(&mut bucket, scale, apply);
    }

    /// Reduce one bucket: flatten, all-reduce, scatter the averaged
    /// segments back through `apply`.
    fn flush(
        &self,
        bucket: &mut Vec<(usize, Vec<f32>, Vec<usize>)>,
        scale: f64,
        apply: &mut dyn FnMut(usize, Tensor),
    ) {
        if bucket.is_empty() {
            return;
        }
        let total: usize = bucket.iter().map(|(_, g, _)| g.len()).sum();
        let mut flat = Vec::with_capacity(total);
        for (_, g, _) in bucket.iter() {
            flat.extend_from_slice(g);
        }
        let reduced = self.dist.all_reduce(&Tensor::from_slice(&flat, [total]), scale).to_vec();
        let mut off = 0usize;
        for (idx, g, dims) in bucket.drain(..) {
            let seg = &reduced[off..off + g.len()];
            apply(idx, Tensor::from_slice(seg, dims));
            off += g.len();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::init_ring;
    use crate::tensor::DType;

    fn params_with_grads(vals: &[(Vec<f32>, Vec<f32>)]) -> Vec<Variable> {
        vals.iter()
            .map(|(v, g)| {
                let p = Variable::param(Tensor::from_slice(v, [v.len()]));
                p.set_grad(Tensor::from_slice(g, [g.len()]));
                p
            })
            .collect()
    }

    #[test]
    fn world_one_leaves_gradients_bit_identical() {
        crate::util::rng::seed(17);
        let w = init_ring(1).pop().unwrap();
        let sync = GradientSynchronizer::new(Arc::new(w));
        // random f32 grads, including awkward values
        let mut grads: Vec<Vec<f32>> = (0..5)
            .map(|i| Tensor::rand([13 + i], -10.0, 10.0).to_vec())
            .collect();
        grads[0][0] = 0.0;
        grads[1][1] = f32::MIN_POSITIVE; // subnormal-adjacent
        grads[2][2] = -1.0e-30;
        let params: Vec<Variable> = grads
            .iter()
            .map(|g| {
                let p = Variable::param(Tensor::zeros([g.len()]));
                p.set_grad(Tensor::from_slice(g, [g.len()]));
                p
            })
            .collect();
        sync.synchronize(&params);
        for (p, g) in params.iter().zip(&grads) {
            let after = p.grad().unwrap().to_vec();
            assert_eq!(after.len(), g.len());
            for (a, b) in after.iter().zip(g) {
                assert_eq!(a.to_bits(), b.to_bits(), "gradient bits changed at world=1");
            }
        }
    }

    #[test]
    fn multi_worker_synchronize_averages() {
        let n = 3;
        let workers = init_ring(n);
        let results: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|w| {
                    s.spawn(move || {
                        let rank = w.world_rank();
                        // two params; grads depend on rank (integer-valued)
                        let params = params_with_grads(&[
                            (vec![0.0; 4], vec![(rank * 3) as f32; 4]),
                            (vec![0.0; 2], vec![(rank + 1) as f32, 0.0]),
                        ]);
                        let sync = GradientSynchronizer::new(Arc::new(w));
                        sync.synchronize(&params);
                        params.iter().map(|p| p.grad().unwrap().to_vec()).collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // mean of (0,3,6) = 3; mean of (1,2,3) = 2
        for (rank, got) in results.iter().enumerate() {
            assert_eq!(got[0], vec![3.0; 4], "rank {rank} param 0");
            assert_eq!(got[1], vec![2.0, 0.0], "rank {rank} param 1");
        }
    }

    #[test]
    fn small_buckets_split_and_still_average() {
        let n = 2;
        let workers = init_ring(n);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|w| {
                    s.spawn(move || {
                        let rank = w.world_rank();
                        let params = params_with_grads(&[
                            (vec![0.0; 8], vec![rank as f32 * 2.0; 8]),
                            (vec![0.0; 8], vec![rank as f32 * 4.0; 8]),
                            (vec![0.0; 8], vec![rank as f32 * 6.0; 8]),
                        ]);
                        // 16-byte budget forces one bucket per parameter
                        let sync =
                            GradientSynchronizer::with_bucket_bytes(Arc::new(w), 16);
                        sync.synchronize(&params);
                        params
                            .iter()
                            .flat_map(|p| p.grad().unwrap().to_vec())
                            .collect::<Vec<f32>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let expect: Vec<f32> = [1.0f32, 2.0, 3.0]
            .iter()
            .flat_map(|&v| std::iter::repeat(v).take(8))
            .collect();
        for got in &results {
            assert_eq!(got, &expect);
        }
    }

    #[test]
    fn average_tensors_matches_variable_path_bitwise() {
        let n = 2;
        let workers = init_ring(n);
        let oks: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|w| {
                    s.spawn(move || {
                        let rank = w.world_rank();
                        let params = params_with_grads(&[
                            (vec![0.0; 4], vec![(rank * 3) as f32 + 0.25; 4]),
                            (vec![0.0; 2], vec![(rank + 1) as f32 * 0.1, -0.7]),
                        ]);
                        let grads: Vec<Tensor> =
                            params.iter().map(|p| p.grad().unwrap()).collect();
                        let sync = GradientSynchronizer::new(Arc::new(w));
                        // tensor path first, then the Variable path — every
                        // worker runs the collectives in the same order
                        let avg = sync.average_tensors(&grads);
                        sync.synchronize(&params);
                        params.iter().zip(&avg).all(|(p, a)| {
                            p.grad()
                                .unwrap()
                                .to_vec()
                                .iter()
                                .zip(a.to_vec())
                                .all(|(x, y)| x.to_bits() == y.to_bits())
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(oks.iter().all(|&b| b), "tensor path diverged from variable path");
    }

    #[test]
    fn average_tensors_world_one_is_identity() {
        let w = init_ring(1).pop().unwrap();
        let sync = GradientSynchronizer::new(Arc::new(w));
        let g = Tensor::from_slice(&[1.5f32, -0.0, f32::MIN_POSITIVE], [3]);
        let avg = sync.average_tensors(&[g.clone()]);
        for (a, b) in avg[0].to_vec().iter().zip(g.to_vec()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn params_without_grads_are_skipped() {
        let w = init_ring(1).pop().unwrap();
        let sync = GradientSynchronizer::new(Arc::new(w));
        let with = Variable::param(Tensor::ones([3]));
        with.set_grad(Tensor::full([3], 2.0, DType::F32));
        let without = Variable::param(Tensor::ones([3]));
        sync.synchronize(&[with.clone(), without.clone()]);
        assert_eq!(with.grad().unwrap().to_vec(), vec![2.0; 3]);
        assert!(without.grad().is_none());
    }

    #[test]
    fn synchronized_training_matches_manual_averaging() {
        // one step of "training" on 2 workers == manual mean of gradients
        let n = 2;
        let workers = init_ring(n);
        let grads = [vec![1.0f32, 3.0], vec![5.0f32, 7.0]];
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|w| {
                    let g = grads[w.world_rank()].clone();
                    s.spawn(move || {
                        let p = Variable::param(Tensor::zeros([2]));
                        p.set_grad(Tensor::from_slice(&g, [2]));
                        GradientSynchronizer::new(Arc::new(w)).synchronize(&[p.clone()]);
                        // SGD step with lr 1.0
                        let g = p.grad().unwrap();
                        p.set_tensor(p.tensor().sub(&g));
                        p.tensor().to_vec()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // mean grad = [3, 5]; param = 0 - mean
        for got in &outs {
            assert_eq!(got, &vec![-3.0, -5.0]);
        }
    }
}
