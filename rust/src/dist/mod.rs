//! The open distributed-training interface (paper §4.1.3, Listing 5).
//!
//! Flashlight's third foundational API: a deliberately small collective-
//! communication surface — rank, world size, `all_reduce`, `broadcast`,
//! `barrier` — behind which any transport can sit. The paper's library
//! backs this with NCCL/Gloo rings; this reproduction ships an **in-process
//! ring** ([`RingWorker`], built by [`init_ring`]) that runs each simulated
//! worker on its own native thread and exchanges chunks over `mpsc`
//! channels, implementing the classic bandwidth-optimal ring all-reduce
//! (reduce-scatter followed by all-gather). Because every chunk's final
//! sum is produced at exactly one worker and then replicated verbatim,
//! results are **bitwise identical across workers** — the property the
//! data-parallel trainer's replica-divergence checks rely on.
//!
//! Layered on top, [`GradientSynchronizer`] (in [`sync`]) performs
//! DDP-style bucketed gradient averaging after the backward pass.
//!
//! # Contract
//!
//! Collectives are *collective*: every worker of a ring must invoke the
//! same operations in the same order with identically-shaped tensors, or
//! the ring deadlocks/misroutes (the standard MPI/NCCL contract). Channels
//! are unbounded, so individual sends never block and the ring cannot
//! deadlock under a correct call sequence.
//!
//! # Example
//!
//! ```
//! use flashlight::dist::{init_ring, DistributedInterface};
//! use flashlight::tensor::Tensor;
//!
//! let workers = init_ring(2);
//! let sums: Vec<Vec<f32>> = std::thread::scope(|s| {
//!     workers
//!         .into_iter()
//!         .map(|w| {
//!             s.spawn(move || {
//!                 let mine = Tensor::full([4], (w.world_rank() + 1) as f64,
//!                                         flashlight::tensor::DType::F32);
//!                 w.all_reduce(&mine, 1.0).to_vec()
//!             })
//!         })
//!         .collect::<Vec<_>>()
//!         .into_iter()
//!         .map(|h| h.join().unwrap())
//!         .collect()
//! });
//! assert_eq!(sums[0], vec![3.0; 4]); // 1 + 2
//! assert_eq!(sums[0], sums[1]);
//! ```

pub mod sync;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::tensor::{HostBuffer, Tensor};

pub use sync::GradientSynchronizer;

/// The open distributed interface (paper Listing 5): the complete surface
/// a transport must implement to plug distributed training into the
/// framework. Implementations must be thread-safe; each worker is used
/// from its own thread.
pub trait DistributedInterface: Send + Sync {
    /// This worker's rank in `0..world_size`.
    fn world_rank(&self) -> usize;

    /// Number of workers in the communicator.
    fn world_size(&self) -> usize;

    /// Element-wise sum of `t` across all workers, multiplied by `scale`
    /// (pass `1.0 / world_size` for an average). Operates on the f32
    /// materialization of `t`; the result is bitwise identical on every
    /// worker.
    fn all_reduce(&self, t: &Tensor, scale: f64) -> Tensor;

    /// Every worker receives `root`'s tensor. Non-root callers pass their
    /// own same-shaped tensor (its value is ignored, its shape is used).
    fn broadcast(&self, t: &Tensor, root: usize) -> Tensor;

    /// Block until every worker in the ring has reached the barrier.
    fn barrier(&self);
}

/// Ring message: an all-reduce chunk, a broadcast payload, or a barrier
/// token. One FIFO channel per ring edge carries all three (collective
/// ordering keeps them unambiguous).
enum Msg {
    Chunk(Vec<f32>),
    Host(HostBuffer),
    Token,
}

/// One worker of an in-process ring communicator. Owns a sender to its
/// successor and a receiver from its predecessor; see [`init_ring`].
pub struct RingWorker {
    rank: usize,
    world: usize,
    tx_next: Sender<Msg>,
    // Receiver is !Sync; the Mutex restores Sync for &self collectives.
    rx_prev: Mutex<Receiver<Msg>>,
}

/// Build an `n`-worker in-process ring (worker `i` sends to `(i+1) % n`).
/// Move each returned [`RingWorker`] onto its own thread and drive the
/// same collective sequence on all of them. `n == 0` is treated as 1.
pub fn init_ring(n: usize) -> Vec<RingWorker> {
    let n = n.max(1);
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(Some(tx));
        receivers.push(Some(rx));
    }
    // worker i keeps the sender of edge i (i -> i+1) and the receiver of
    // edge i-1 (i-1 -> i)
    (0..n)
        .map(|i| RingWorker {
            rank: i,
            world: n,
            tx_next: senders[i].take().unwrap(),
            rx_prev: Mutex::new(receivers[(i + n - 1) % n].take().unwrap()),
        })
        .collect()
}

/// `(start, end)` element bounds splitting `len` into `n` nearly equal
/// chunks (leading chunks absorb the remainder).
fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let per = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let size = per + usize::from(i < rem);
        out.push((start, start + size));
        start += size;
    }
    out
}

impl RingWorker {
    fn send(&self, m: Msg) {
        self.tx_next.send(m).expect("ring peer hung up");
    }

    fn recv_chunk(&self) -> Vec<f32> {
        match self.rx_prev.lock().unwrap().recv().expect("ring peer hung up") {
            Msg::Chunk(v) => v,
            _ => panic!("ring protocol violation: expected chunk"),
        }
    }

    fn recv_host(&self) -> HostBuffer {
        match self.rx_prev.lock().unwrap().recv().expect("ring peer hung up") {
            Msg::Host(h) => h,
            _ => panic!("ring protocol violation: expected broadcast payload"),
        }
    }

    fn recv_token(&self) {
        match self.rx_prev.lock().unwrap().recv().expect("ring peer hung up") {
            Msg::Token => {}
            _ => panic!("ring protocol violation: expected barrier token"),
        }
    }
}

impl DistributedInterface for RingWorker {
    fn world_rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    fn all_reduce(&self, t: &Tensor, scale: f64) -> Tensor {
        let n = self.world;
        if n == 1 {
            return if scale == 1.0 { t.clone() } else { t.mul_scalar(scale) };
        }
        let shape = t.shape().clone();
        let mut data = t.to_vec();
        let bounds = chunk_bounds(data.len(), n);
        let r = self.rank;

        // Phase 1 — reduce-scatter: at step s, send chunk (r - s) and fold
        // the incoming chunk (r - s - 1) into the local buffer. After n-1
        // steps worker r holds the fully reduced chunk (r + 1) % n.
        for step in 0..n - 1 {
            let send_idx = (r + n - step) % n;
            let recv_idx = (r + 2 * n - step - 1) % n;
            let (s, e) = bounds[send_idx];
            self.send(Msg::Chunk(data[s..e].to_vec()));
            let incoming = self.recv_chunk();
            let (s, e) = bounds[recv_idx];
            for (d, v) in data[s..e].iter_mut().zip(incoming) {
                *d += v;
            }
        }
        // Phase 2 — all-gather: circulate the finished chunks; incoming
        // data *replaces* local chunks, so every worker ends with the same
        // bits for every chunk.
        for step in 0..n - 1 {
            let send_idx = (r + 1 + n - step) % n;
            let recv_idx = (r + n - step) % n;
            let (s, e) = bounds[send_idx];
            self.send(Msg::Chunk(data[s..e].to_vec()));
            let incoming = self.recv_chunk();
            let (s, e) = bounds[recv_idx];
            data[s..e].copy_from_slice(&incoming);
        }

        let out = Tensor::from_slice(&data, shape);
        if scale == 1.0 {
            out
        } else {
            out.mul_scalar(scale)
        }
    }

    fn broadcast(&self, t: &Tensor, root: usize) -> Tensor {
        if self.world == 1 {
            return t.clone();
        }
        assert!(root < self.world, "broadcast root {root} out of range");
        if self.rank == root {
            self.send(Msg::Host(t.to_host()));
            t.clone()
        } else {
            let host = self.recv_host();
            // forward around the ring unless the next hop is the root
            if (self.rank + 1) % self.world != root {
                self.send(Msg::Host(host.clone()));
            }
            Tensor::from_host(host, t.shape().clone())
        }
    }

    fn barrier(&self) {
        // n-1 rounds of token passing: completing round k proves the k-th
        // predecessor has entered the barrier, so after n-1 rounds every
        // worker has.
        for _ in 0..self.world.saturating_sub(1) {
            self.send(Msg::Token);
            self.recv_token();
        }
    }
}

impl std::fmt::Debug for RingWorker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RingWorker(rank={}/{})", self.rank, self.world)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    /// Run one closure per ring worker on its own thread; collect results
    /// in rank order.
    fn on_ring<T: Send>(
        n: usize,
        f: impl Fn(&RingWorker) -> T + Sync,
    ) -> Vec<T> {
        let workers = init_ring(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .iter()
                .map(|w| {
                    let f = &f;
                    s.spawn(move || f(w))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn ring_all_reduce_matches_single_process_sum_and_average() {
        let n = 4;
        let len = 37; // not divisible by n: exercises uneven chunks
        // integer-valued floats make reference summation order-independent
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..len).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let expect_sum: Vec<f32> =
            (0..len).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let sums = on_ring(n, |w| {
            let t = Tensor::from_slice(&inputs[w.world_rank()], [len]);
            w.all_reduce(&t, 1.0).to_vec()
        });
        for (r, got) in sums.iter().enumerate() {
            assert_eq!(got, &expect_sum, "rank {r} sum mismatch");
        }
        let avgs = on_ring(n, |w| {
            let t = Tensor::from_slice(&inputs[w.world_rank()], [len]);
            w.all_reduce(&t, 1.0 / n as f64).to_vec()
        });
        let expect_avg: Vec<f32> = expect_sum.iter().map(|&x| x / n as f32).collect();
        for got in &avgs {
            assert_eq!(got, &expect_avg);
        }
    }

    #[test]
    fn all_reduce_is_bitwise_identical_across_workers() {
        crate::util::rng::seed(9);
        let n = 3;
        let inputs: Vec<Vec<f32>> =
            (0..n).map(|_| Tensor::rand([50], -1.0, 1.0).to_vec()).collect();
        let outs = on_ring(n, |w| {
            let t = Tensor::from_slice(&inputs[w.world_rank()], [50]);
            w.all_reduce(&t, 1.0 / 3.0).to_vec()
        });
        for r in 1..n {
            assert!(
                outs[0].iter().zip(&outs[r]).all(|(a, b)| a.to_bits() == b.to_bits()),
                "rank {r} not bitwise identical to rank 0"
            );
        }
    }

    #[test]
    fn all_reduce_world_one_is_identity() {
        let w = init_ring(1).pop().unwrap();
        let t = Tensor::from_slice(&[1.5f32, -2.25, 0.0], [3]);
        let out = w.all_reduce(&t, 1.0);
        assert_eq!(out.to_vec(), t.to_vec());
        assert_eq!(w.world_size(), 1);
        assert_eq!(w.world_rank(), 0);
    }

    #[test]
    fn broadcast_distributes_roots_value() {
        for root in 0..3usize {
            let outs = on_ring(3, |w| {
                let mine = Tensor::full([5], w.world_rank() as f64 + 10.0, DType::F32);
                w.broadcast(&mine, root).to_vec()
            });
            for (r, got) in outs.iter().enumerate() {
                assert_eq!(got, &vec![root as f32 + 10.0; 5], "rank {r}, root {root}");
            }
        }
    }

    #[test]
    fn broadcast_preserves_dtype() {
        let outs = on_ring(2, |w| {
            let mine = Tensor::from_slice(&[w.world_rank() as i64 + 7, 2], [2]);
            let out = w.broadcast(&mine, 0);
            (out.dtype(), out.to_vec_i64())
        });
        for (d, v) in outs {
            assert_eq!(d, DType::I64);
            assert_eq!(v, vec![7, 2]);
        }
    }

    #[test]
    fn barrier_synchronizes_phases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let entered = AtomicUsize::new(0);
        let n = 4;
        on_ring(n, |w| {
            entered.fetch_add(1, Ordering::SeqCst);
            w.barrier();
            // after the barrier, every worker must have entered
            assert_eq!(entered.load(Ordering::SeqCst), n);
        });
    }

    #[test]
    fn chunk_bounds_partition_exactly() {
        for (len, n) in [(10usize, 3usize), (3, 4), (0, 2), (16, 4)] {
            let b = chunk_bounds(len, n);
            assert_eq!(b.len(), n);
            assert_eq!(b[0].0, 0);
            assert_eq!(b[n - 1].1, len);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn collectives_compose_in_sequence() {
        // all_reduce then broadcast then barrier on the same ring
        let outs = on_ring(2, |w| {
            let t = Tensor::full([4], (w.world_rank() + 1) as f64, DType::F32);
            let summed = w.all_reduce(&t, 1.0);
            let from_one = w.broadcast(&summed.mul_scalar((w.world_rank() + 1) as f64), 1);
            w.barrier();
            from_one.to_vec()
        });
        // root 1 broadcasts sum * 2 = [6, 6, 6, 6]
        assert_eq!(outs[0], vec![6.0; 4]);
        assert_eq!(outs[1], vec![6.0; 4]);
    }
}
