//! Structured spans: RAII-scoped timed regions recorded into per-thread
//! rings, plus the per-request serve timeline ([`RequestTrace`]).
//!
//! Recording is lock-free-ish by construction: a finished span touches
//! only its own thread's ring (one uncontended mutex lock — the global
//! collector takes the same lock only while *draining*). The ring has a
//! fixed capacity; overflow overwrites the oldest event and bumps a
//! process-wide atomic drop counter ([`dropped_spans`]), so truncation is
//! observable rather than silent. Timestamps are nanoseconds since a
//! process-wide epoch ([`now_ns`]), which is what lets events from many
//! threads land on one coherent Chrome-trace timeline.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Finished spans kept per thread before the oldest is overwritten.
const RING_CAPACITY: usize = 4096;
/// Finished request timelines kept in the collector before new ones are
/// counted as dropped instead of published.
const TRACE_CAPACITY: usize = 4096;

/// Spans overwritten by ring overflow plus request timelines dropped at
/// the collector cap, process-wide, since the last [`reset`].
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-wide trace epoch (the first obs call).
pub fn now_ns() -> u64 {
    let e = epoch();
    Instant::now().saturating_duration_since(e).as_nanos() as u64
}

/// A span attribute value. `Str` is `&'static str` on purpose: recording
/// must not allocate per-attribute on the hot path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttrValue {
    I64(i64),
    F64(f64),
    Str(&'static str),
}

/// How a [`SpanEvent`] renders: a timed region or a point-in-time mark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Timed region (`ph: "X"` in Chrome trace-event terms).
    Complete,
    /// Zero-duration mark (`ph: "i"`).
    Instant,
}

/// One finished span or instant, as drained by [`take_spans`].
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: &'static str,
    pub kind: SpanKind,
    /// [`now_ns`] at span entry.
    pub start_ns: u64,
    /// Zero for [`SpanKind::Instant`].
    pub dur_ns: u64,
    /// Obs-assigned thread id (dense, in thread first-use order).
    pub tid: u64,
    pub attrs: Vec<(&'static str, AttrValue)>,
}

// ---- per-thread rings + process-wide collector -----------------------------

struct Ring {
    events: Vec<SpanEvent>,
    /// Index of the oldest event once the ring is full (0 before that).
    head: usize,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < RING_CAPACITY {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % RING_CAPACITY;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn drain(&mut self) -> Vec<SpanEvent> {
        let mut out = std::mem::take(&mut self.events);
        out.rotate_left(self.head);
        self.head = 0;
        out
    }
}

struct Collector {
    /// Every thread's ring, registered on that thread's first recorded
    /// event. Entries are kept for the process lifetime (bounded by
    /// thread count) so a thread's spans survive its exit until drained.
    rings: Mutex<Vec<(u64, Arc<Mutex<Ring>>)>>,
    traces: Mutex<Vec<RequestTrace>>,
}

fn collector() -> &'static Collector {
    static COLLECTOR: OnceLock<Collector> = OnceLock::new();
    COLLECTOR.get_or_init(|| Collector {
        rings: Mutex::new(Vec::new()),
        traces: Mutex::new(Vec::new()),
    })
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

struct Local {
    tid: u64,
    ring: Arc<Mutex<Ring>>,
}

thread_local! {
    static LOCAL: Local = {
        let ring = Arc::new(Mutex::new(Ring { events: Vec::new(), head: 0 }));
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        lock(&collector().rings).push((tid, Arc::clone(&ring)));
        Local { tid, ring }
    };
}

fn record(mut ev: SpanEvent) {
    LOCAL.with(|l| {
        ev.tid = l.tid;
        lock(&l.ring).push(ev);
    });
}

// ---- the RAII span guard ---------------------------------------------------

struct SpanInner {
    name: &'static str,
    start_ns: u64,
    attrs: Vec<(&'static str, AttrValue)>,
}

/// RAII guard returned by [`span`]: records one [`SpanKind::Complete`]
/// event on drop. When obs is disabled at entry the guard is inert (no
/// clock read, no allocation, nothing recorded on drop).
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

impl SpanGuard {
    pub fn attr_i64(&mut self, key: &'static str, v: i64) {
        if let Some(inner) = &mut self.inner {
            inner.attrs.push((key, AttrValue::I64(v)));
        }
    }

    pub fn attr_f64(&mut self, key: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            inner.attrs.push((key, AttrValue::F64(v)));
        }
    }

    pub fn attr_str(&mut self, key: &'static str, v: &'static str) {
        if let Some(inner) = &mut self.inner {
            inner.attrs.push((key, AttrValue::Str(v)));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            let end = now_ns();
            record(SpanEvent {
                name: inner.name,
                kind: SpanKind::Complete,
                start_ns: inner.start_ns,
                dur_ns: end.saturating_sub(inner.start_ns),
                tid: 0,
                attrs: inner.attrs,
            });
        }
    }
}

/// Open a timed span closing when the returned guard drops. Nest freely:
/// overlap on the same thread renders as nesting in the Chrome trace.
///
/// ```
/// let mut s = flashlight::obs::span("compile.pass.cse");
/// s.attr_i64("instrs", 42);
/// // … work …
/// // drop records the span (if obs was enabled at entry)
/// ```
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { inner: None };
    }
    SpanGuard { inner: Some(SpanInner { name, start_ns: now_ns(), attrs: Vec::new() }) }
}

/// Record a zero-duration mark (e.g. an allocator event). No-op while
/// disabled.
#[inline]
pub fn instant(name: &'static str, attrs: &[(&'static str, AttrValue)]) {
    if !super::enabled() {
        return;
    }
    record(SpanEvent {
        name,
        kind: SpanKind::Instant,
        start_ns: now_ns(),
        dur_ns: 0,
        tid: 0,
        attrs: attrs.to_vec(),
    });
}

/// Drain every thread's ring, returning all finished spans sorted by
/// start time. Draining resets the rings but not [`dropped_spans`].
pub fn take_spans() -> Vec<SpanEvent> {
    let rings = lock(&collector().rings);
    let mut out = Vec::new();
    for (_tid, ring) in rings.iter() {
        out.extend(lock(ring).drain());
    }
    out.sort_by_key(|e| e.start_ns);
    out
}

/// Spans overwritten by ring overflow (plus request timelines dropped at
/// the collector cap) since the last [`reset`]. Non-zero means the
/// capture window was too long for the ring — export more often.
pub fn dropped_spans() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Drain and discard all recorded spans and request timelines and zero
/// the drop counter. Useful between capture windows.
pub fn reset() {
    let _ = take_spans();
    lock(&collector().traces).clear();
    DROPPED.store(0, Ordering::Relaxed);
}

// ---- per-request serve timelines -------------------------------------------

/// One step of a request's life in the serving stack. `what` is the
/// event name (`"queued"`, `"backpressure_stall"`, `"prefill_chunk"`,
/// `"decode_iter"`, `"sample"`, `"retire"`); the remaining fields carry
/// whichever context that step has (zero otherwise).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TimelineEvent {
    pub what: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Live rows in the decode batch / rows in the prefill pass.
    pub batch: u32,
    /// Compiled bucket size the iteration routed to (0 = none/eager).
    pub bucket: u32,
    /// Whether the iteration ran a compiled bucket (vs eager fallback).
    pub compiled: bool,
    /// Tokens processed by this event (prefill-chunk width, or 1 per
    /// sampled token).
    pub tokens: u32,
}

/// The life of one serve request: admit → backpressure stall → prefill
/// chunks → per-token decode steps → retire. Carried through the
/// batchers while obs is enabled, surfaced on
/// [`crate::serve::GenerateReport::timeline`], and published to the
/// collector at [`RequestTrace::finish`] for Chrome-trace export as
/// nested async spans.
///
/// The telemetry-balance oracle (pinned by the serve fuzz harness): the
/// number of `"sample"` events equals the report's generated-token
/// count. The first sampled token comes from prefill logits (`batch ==
/// 0`); every later one carries its decode iteration's batch / bucket /
/// compiled flag.
#[derive(Debug, Clone, Default)]
pub struct RequestTrace {
    /// Process-unique request id (also the Chrome async-span id).
    pub id: u64,
    /// [`now_ns`] at submission.
    pub submitted_ns: u64,
    pub events: Vec<TimelineEvent>,
    stall_start_ns: Option<u64>,
}

impl RequestTrace {
    /// Begin a timeline for a request submitted now — `None` while obs
    /// is disabled, so the off path costs one atomic load and the
    /// batchers' trace fields stay `Option<Box<_>>`-thin.
    pub fn start() -> Option<Box<RequestTrace>> {
        if !super::enabled() {
            return None;
        }
        Some(Box::new(RequestTrace {
            id: NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed),
            submitted_ns: now_ns(),
            events: Vec::new(),
            stall_start_ns: None,
        }))
    }

    /// The request failed admission (no KV reservation / batch full) and
    /// is waiting. First call wins; [`RequestTrace::admitted`] closes it.
    pub fn mark_stalled(&mut self) {
        if self.stall_start_ns.is_none() {
            self.stall_start_ns = Some(now_ns());
        }
    }

    /// The request was admitted: closes the `"queued"` interval (and the
    /// `"backpressure_stall"` interval, if any stall was marked).
    pub fn admitted(&mut self) {
        let now = now_ns();
        let queued_end = self.stall_start_ns.unwrap_or(now);
        self.events.push(TimelineEvent {
            what: "queued",
            start_ns: self.submitted_ns,
            dur_ns: queued_end.saturating_sub(self.submitted_ns),
            ..Default::default()
        });
        if let Some(stall) = self.stall_start_ns.take() {
            self.events.push(TimelineEvent {
                what: "backpressure_stall",
                start_ns: stall,
                dur_ns: now.saturating_sub(stall),
                ..Default::default()
            });
        }
    }

    /// Record an event that started at `start_ns` and ends now.
    pub fn push(
        &mut self,
        what: &'static str,
        start_ns: u64,
        batch: u32,
        bucket: u32,
        compiled: bool,
        tokens: u32,
    ) {
        self.events.push(TimelineEvent {
            what,
            start_ns,
            dur_ns: now_ns().saturating_sub(start_ns),
            batch,
            bucket,
            compiled,
            tokens,
        });
    }

    /// Close the timeline (appends a `"retire"` mark), publish a copy to
    /// the process-wide collector for Chrome-trace export, and return it
    /// for the request's `GenerateReport`.
    pub fn finish(mut this: Box<RequestTrace>) -> RequestTrace {
        this.events.push(TimelineEvent { what: "retire", start_ns: now_ns(), ..Default::default() });
        let trace = *this;
        let mut traces = lock(&collector().traces);
        if traces.len() < TRACE_CAPACITY {
            traces.push(trace.clone());
        } else {
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
        trace
    }
}

/// Drain the finished request timelines published by
/// [`RequestTrace::finish`], oldest first.
pub fn take_request_traces() -> Vec<RequestTrace> {
    std::mem::take(&mut *lock(&collector().traces))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{set_enabled, test_guard};

    #[test]
    fn ring_overflow_keeps_newest_and_counts_drops() {
        let _serial = test_guard();
        let was = crate::obs::enabled();
        set_enabled(true);
        let _ = take_spans();
        let dropped_before = dropped_spans();
        for i in 0..(RING_CAPACITY + 8) {
            instant("obs.test.flood", &[("i", AttrValue::I64(i as i64))]);
        }
        let mine: Vec<SpanEvent> =
            take_spans().into_iter().filter(|e| e.name == "obs.test.flood").collect();
        assert_eq!(mine.len(), RING_CAPACITY, "ring keeps exactly its capacity");
        assert!(
            dropped_spans() - dropped_before >= 8,
            "overflow must be counted, never silent"
        );
        // the survivors are the *newest* events, still in record order
        let first = match mine[0].attrs[0].1 {
            AttrValue::I64(v) => v,
            _ => unreachable!(),
        };
        assert_eq!(first, 8, "oldest events are the ones overwritten");
        let last = match mine[RING_CAPACITY - 1].attrs[0].1 {
            AttrValue::I64(v) => v,
            _ => unreachable!(),
        };
        assert_eq!(last as usize, RING_CAPACITY + 7);
        set_enabled(was);
    }

    #[test]
    fn spans_nest_and_order_by_start() {
        let _serial = test_guard();
        let was = crate::obs::enabled();
        set_enabled(true);
        let _ = take_spans();
        {
            let _outer = span("obs.test.outer");
            let _inner = span("obs.test.inner");
        }
        let spans: Vec<SpanEvent> =
            take_spans().into_iter().filter(|e| e.name.starts_with("obs.test.")).collect();
        assert_eq!(spans.len(), 2);
        let outer = spans.iter().find(|e| e.name == "obs.test.outer").unwrap();
        let inner = spans.iter().find(|e| e.name == "obs.test.inner").unwrap();
        assert!(outer.start_ns <= inner.start_ns, "outer opened first");
        assert!(
            outer.start_ns + outer.dur_ns >= inner.start_ns + inner.dur_ns,
            "inner closed within outer"
        );
        assert_eq!(outer.tid, inner.tid, "same thread");
        set_enabled(was);
    }

    #[test]
    fn request_trace_lifecycle_and_sample_balance() {
        let _serial = test_guard();
        let was = crate::obs::enabled();
        set_enabled(false);
        assert!(RequestTrace::start().is_none(), "disabled: no timeline allocated");
        set_enabled(true);
        let _ = take_request_traces();
        let mut t = RequestTrace::start().expect("enabled: timeline starts");
        t.mark_stalled();
        t.mark_stalled(); // idempotent: first stall wins
        t.admitted();
        let t0 = now_ns();
        t.push("prefill_chunk", t0, 1, 0, false, 8);
        for i in 0..4u32 {
            t.push("sample", now_ns(), if i == 0 { 0 } else { 2 }, 2, i != 0, 1);
        }
        let done = RequestTrace::finish(t);
        assert_eq!(done.events.iter().filter(|e| e.what == "sample").count(), 4);
        assert_eq!(done.events.iter().filter(|e| e.what == "queued").count(), 1);
        assert_eq!(done.events.iter().filter(|e| e.what == "backpressure_stall").count(), 1);
        assert_eq!(done.events.last().unwrap().what, "retire");
        let published = take_request_traces();
        let mine = published.iter().find(|p| p.id == done.id).expect("finish publishes a copy");
        assert_eq!(mine.events.len(), done.events.len());
        set_enabled(was);
    }
}
