//! Chrome trace-event JSON export — open the output in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`.
//!
//! Mapping (hand-rolled JSON; the crate is dependency-free):
//!
//! - [`SpanKind::Complete`] spans → complete events (`ph: "X"`) with
//!   `ts`/`dur` in fractional microseconds, one track per obs thread id;
//! - [`SpanKind::Instant`] marks → thread-scoped instant events
//!   (`ph: "i"`, `s: "t"`) — allocator events land here;
//! - request timelines ([`RequestTrace`]) → nested *async* events
//!   (`ph: "b"`/`"e"`, `cat: "serve.request"`, `id` = request id): one
//!   enclosing `request` pair from submission to retire, with each
//!   timed timeline event as a nested pair and zero-duration events
//!   (`retire`) as async instants (`ph: "n"`). Async events get their
//!   own tracks in the viewer, so a request's life is readable even
//!   though its iterations ran interleaved on the scheduler thread;
//! - span attributes and timeline context (batch / bucket / compiled /
//!   tokens) → `args`, visible on click.
//!
//! Export **drains** the recorder (rings and finished timelines), so a
//! capture window is: enable → run → export. The drop counter is
//! reported as `args.dropped` on the metadata event when non-zero —
//! truncated captures identify themselves.

use std::fmt::Write as _;

use crate::util::error::Result;

use super::span::{
    dropped_spans, take_request_traces, take_spans, AttrValue, RequestTrace, SpanEvent, SpanKind,
    TimelineEvent,
};

const PID: u64 = 1;

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn us(ns: u64) -> f64 {
    ns as f64 / 1_000.0
}

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn attr_args(attrs: &[(&'static str, AttrValue)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in attrs.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{}\": ", escape(k));
        match v {
            AttrValue::I64(n) => {
                let _ = write!(out, "{n}");
            }
            AttrValue::F64(f) => out.push_str(&num(*f)),
            AttrValue::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
        }
    }
    out.push('}');
    out
}

fn push_span(out: &mut String, ev: &SpanEvent) {
    match ev.kind {
        SpanKind::Complete => {
            let _ = write!(
                out,
                "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \
                 \"pid\": {PID}, \"tid\": {}, \"args\": {}}}",
                escape(ev.name),
                num(us(ev.start_ns)),
                num(us(ev.dur_ns)),
                ev.tid,
                attr_args(&ev.attrs)
            );
        }
        SpanKind::Instant => {
            let _ = write!(
                out,
                "{{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"t\", \"ts\": {}, \
                 \"pid\": {PID}, \"tid\": {}, \"args\": {}}}",
                escape(ev.name),
                num(us(ev.start_ns)),
                ev.tid,
                attr_args(&ev.attrs)
            );
        }
    }
}

fn timeline_args(ev: &TimelineEvent) -> String {
    format!(
        "{{\"batch\": {}, \"bucket\": {}, \"compiled\": {}, \"tokens\": {}}}",
        ev.batch, ev.bucket, ev.compiled, ev.tokens
    )
}

fn push_async(out: &mut String, trace: &RequestTrace, sep: &str) {
    let id = trace.id;
    let end_ns = trace
        .events
        .iter()
        .map(|e| e.start_ns + e.dur_ns)
        .max()
        .unwrap_or(trace.submitted_ns);
    let _ = write!(
        out,
        "{sep}{{\"name\": \"request\", \"cat\": \"serve.request\", \"ph\": \"b\", \
         \"id\": {id}, \"ts\": {}, \"pid\": {PID}, \"tid\": 0}}",
        num(us(trace.submitted_ns))
    );
    for ev in &trace.events {
        if ev.dur_ns == 0 {
            let _ = write!(
                out,
                "{sep}{{\"name\": \"{}\", \"cat\": \"serve.request\", \"ph\": \"n\", \
                 \"id\": {id}, \"ts\": {}, \"pid\": {PID}, \"tid\": 0, \"args\": {}}}",
                escape(ev.what),
                num(us(ev.start_ns)),
                timeline_args(ev)
            );
        } else {
            let _ = write!(
                out,
                "{sep}{{\"name\": \"{}\", \"cat\": \"serve.request\", \"ph\": \"b\", \
                 \"id\": {id}, \"ts\": {}, \"pid\": {PID}, \"tid\": 0, \"args\": {}}}",
                escape(ev.what),
                num(us(ev.start_ns)),
                timeline_args(ev)
            );
            let _ = write!(
                out,
                "{sep}{{\"name\": \"{}\", \"cat\": \"serve.request\", \"ph\": \"e\", \
                 \"id\": {id}, \"ts\": {}, \"pid\": {PID}, \"tid\": 0}}",
                escape(ev.what),
                num(us(ev.start_ns + ev.dur_ns))
            );
        }
    }
    let _ = write!(
        out,
        "{sep}{{\"name\": \"request\", \"cat\": \"serve.request\", \"ph\": \"e\", \
         \"id\": {id}, \"ts\": {}, \"pid\": {PID}, \"tid\": 0}}",
        num(us(end_ns))
    );
}

/// Drain everything recorded so far (spans from every thread's ring plus
/// finished request timelines) and render it as Chrome trace-event JSON.
pub fn chrome_trace_json() -> String {
    let spans = take_spans();
    let traces = take_request_traces();
    let dropped = dropped_spans();
    let mut out = String::from("{\"traceEvents\": [\n");
    let _ = write!(
        out,
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {PID}, \"tid\": 0, \
         \"args\": {{\"name\": \"flashlight\", \"dropped\": {dropped}}}}}"
    );
    for ev in &spans {
        out.push_str(",\n");
        push_span(&mut out, ev);
    }
    for trace in &traces {
        push_async(&mut out, trace, ",\n");
    }
    out.push_str("\n]}\n");
    out
}

/// [`chrome_trace_json`] to a file. Load it via Perfetto's "Open trace
/// file" or `chrome://tracing`.
pub fn export_chrome_trace(path: impl AsRef<std::path::Path>) -> Result<()> {
    std::fs::write(path, chrome_trace_json())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{instant, set_enabled, span, test_guard};

    /// A structural JSON check with no serde in the tree: balanced
    /// braces/brackets outside strings, and no trailing comma before a
    /// closer.
    fn assert_valid_jsonish(s: &str) {
        let mut depth: i64 = 0;
        let mut in_str = false;
        let mut esc = false;
        let mut last_significant = ' ';
        for c in s.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' | '[' => depth += 1,
                '}' | ']' => {
                    assert_ne!(last_significant, ',', "trailing comma before closer");
                    depth -= 1;
                    assert!(depth >= 0, "unbalanced closers");
                }
                _ => {}
            }
            if !c.is_whitespace() {
                last_significant = c;
            }
        }
        assert!(!in_str, "unterminated string");
        assert_eq!(depth, 0, "unbalanced braces");
    }

    #[test]
    fn export_covers_spans_instants_and_async_timelines() {
        let _serial = test_guard();
        let was = crate::obs::enabled();
        set_enabled(true);
        crate::obs::reset();
        {
            let mut s = span("obs.test.chrome.span");
            s.attr_i64("n", 3);
            s.attr_str("mode", "a\"b"); // exercises escaping
        }
        instant("obs.test.chrome.mark", &[("bytes", AttrValue::I64(128))]);
        let mut t = crate::obs::RequestTrace::start().unwrap();
        t.admitted();
        let t0 = crate::obs::now_ns();
        t.push("decode_iter", t0, 2, 4, true, 0);
        t.push("sample", t0, 2, 4, true, 1);
        let _report_copy = crate::obs::RequestTrace::finish(t);

        let json = chrome_trace_json();
        assert_valid_jsonish(&json);
        assert!(json.contains("\"name\": \"obs.test.chrome.span\", \"ph\": \"X\""));
        assert!(json.contains("a\\\"b"));
        assert!(json.contains("\"name\": \"obs.test.chrome.mark\", \"ph\": \"i\""));
        assert!(json.contains("\"cat\": \"serve.request\", \"ph\": \"b\""));
        assert!(json.contains("\"name\": \"decode_iter\""));
        assert!(json.contains("\"compiled\": true"));
        // export drained the recorder
        assert!(!chrome_trace_json().contains("obs.test.chrome.span"));
        crate::obs::reset();
        set_enabled(was);
    }
}
