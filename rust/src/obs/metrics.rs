//! The process-wide metrics registry: typed, named instruments with
//! atomics on the hot path and one snapshot call for everything.
//!
//! Naming convention: dot-separated `<subsystem>.<object>.<measure>`
//! (`serve.decode.compiled_iterations`, `exec.instrs`,
//! `serve.pool.leased_pages`, `profile.op.matmul.calls`). Three kinds:
//!
//! - [`Counter`] — monotone `u64` (though `set` exists so the existing
//!   stats structs can publish absolute snapshots of their own
//!   per-instance counters);
//! - [`Gauge`] — last-written `f64` (bit-packed in an `AtomicU64`);
//! - [`Histogram`] — reservoir-sampled distribution backed by
//!   [`crate::meter::PercentileMeter`], read out as p50/p95/p99.
//!
//! Handles are `Arc`-cloneable and cheap to cache; lookup by name takes
//! the registry lock once, so hot paths should hold a handle (see
//! `exec_counters`). Unlike spans, *publication* into the registry is
//! not gated on [`crate::obs::enabled`] — the publishers (`stats()`
//! methods, bench readouts) are off the hot path, and an always-on
//! registry is what lets [`metrics_snapshot`] be the single source of
//! truth for CI guards and benches.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::meter::PercentileMeter;

/// Monotone counter (with an absolute-`set` escape hatch for republished
/// per-instance stats).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite with an absolute value — for stats structs that already
    /// count internally and publish snapshots here.
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-written value, `f64` bits packed into an `AtomicU64`.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Reservoir-sampled distribution; `observe` takes one short mutex.
#[derive(Clone)]
pub struct Histogram(Arc<Mutex<PercentileMeter>>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        lock(&self.0).add(v);
    }

    pub fn count(&self) -> u64 {
        lock(&self.0).count()
    }

    pub fn quantile(&self, q: f64) -> f64 {
        lock(&self.0).quantile(q)
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// One registry entry as read out by [`metrics_snapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricSample {
    pub name: &'static str,
    pub kind: MetricKind,
    /// Counter value, gauge value, or histogram observation count.
    pub value: f64,
    /// Histogram percentiles (zero for counters/gauges).
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn registry() -> &'static Mutex<HashMap<&'static str, Metric>> {
    static REGISTRY: OnceLock<Mutex<HashMap<&'static str, Metric>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Intern `name` only when it is first registered — dynamic names (the
/// profiler's per-op metrics) leak one short string per unique name,
/// bounded by the metric-name universe.
fn intern(name: &str) -> &'static str {
    Box::leak(name.to_string().into_boxed_str())
}

macro_rules! get_or_register {
    ($name:expr, $variant:ident, $make:expr) => {{
        let mut reg = lock(registry());
        match reg.get($name) {
            Some(Metric::$variant(m)) => m.clone(),
            Some(_) => panic!(
                "obs: metric `{}` already registered with a different kind",
                $name
            ),
            None => {
                let m = $make;
                reg.insert(intern($name), Metric::$variant(m.clone()));
                m
            }
        }
    }};
}

/// The counter named `name`, registering it on first use. Panics if the
/// name is already registered as a different kind (a naming bug worth
/// failing loudly on).
pub fn counter(name: &str) -> Counter {
    get_or_register!(name, Counter, Counter(Arc::new(AtomicU64::new(0))))
}

/// The gauge named `name`, registering it on first use.
pub fn gauge(name: &str) -> Gauge {
    get_or_register!(name, Gauge, Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits()))))
}

/// The histogram named `name`, registering it on first use.
pub fn histogram(name: &str) -> Histogram {
    get_or_register!(name, Histogram, Histogram(Arc::new(Mutex::new(PercentileMeter::new()))))
}

/// Read out every registered metric, sorted by name — the single source
/// of truth for counters previously scattered across stats structs.
pub fn metrics_snapshot() -> Vec<MetricSample> {
    let reg = lock(registry());
    let mut out: Vec<MetricSample> = reg
        .iter()
        .map(|(name, metric)| match metric {
            Metric::Counter(c) => MetricSample {
                name,
                kind: MetricKind::Counter,
                value: c.get() as f64,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            },
            Metric::Gauge(g) => MetricSample {
                name,
                kind: MetricKind::Gauge,
                value: g.get(),
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            },
            Metric::Histogram(h) => {
                let m = lock(&h.0);
                MetricSample {
                    name,
                    kind: MetricKind::Histogram,
                    value: m.count() as f64,
                    p50: m.p50(),
                    p95: m.p95(),
                    p99: m.p99(),
                }
            }
        })
        .collect();
    out.sort_by(|a, b| a.name.cmp(b.name));
    out
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// The snapshot as a JSON array (hand-rolled: the crate is
/// dependency-free), suitable for dashboards and CI guards.
pub fn metrics_json() -> String {
    let mut out = String::from("[");
    for (i, s) in metrics_snapshot().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let kind = match s.kind {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        };
        out.push_str(&format!(
            "\n  {{\"name\": \"{}\", \"kind\": \"{}\", \"value\": {}",
            s.name,
            kind,
            fmt_f64(s.value)
        ));
        if s.kind == MetricKind::Histogram {
            out.push_str(&format!(
                ", \"p50\": {}, \"p95\": {}, \"p99\": {}",
                fmt_f64(s.p50),
                fmt_f64(s.p95),
                fmt_f64(s.p99)
            ));
        }
        out.push('}');
    }
    out.push_str("\n]\n");
    out
}

/// The snapshot as aligned human-readable text, one metric per line.
pub fn metrics_text() -> String {
    let snapshot = metrics_snapshot();
    let width = snapshot.iter().map(|s| s.name.len()).max().unwrap_or(0);
    let mut out = String::new();
    for s in &snapshot {
        match s.kind {
            MetricKind::Counter => {
                out.push_str(&format!("{:width$}  counter    {}\n", s.name, s.value as u64));
            }
            MetricKind::Gauge => {
                out.push_str(&format!("{:width$}  gauge      {:.3}\n", s.name, s.value));
            }
            MetricKind::Histogram => {
                out.push_str(&format!(
                    "{:width$}  histogram  n={} p50={:.1} p95={:.1} p99={:.1}\n",
                    s.name, s.value as u64, s.p50, s.p95, s.p99
                ));
            }
        }
    }
    out
}

/// Drop every registered metric (handles already held keep working but
/// are orphaned). Test isolation only.
pub fn reset_metrics() {
    lock(registry()).clear();
}

// ---- cached executor counters ----------------------------------------------

/// Handles for the compiled-program executor, cached so the per-run
/// publication cost is four atomic adds, not four registry lookups.
pub(super) struct ExecCounters {
    runs: Counter,
    instrs: Counter,
    ops: Counter,
    donated_bytes: Counter,
}

impl ExecCounters {
    pub(super) fn record(&self, instrs: u64, ops: u64, donated_bytes: u64) {
        self.runs.inc();
        self.instrs.add(instrs);
        self.ops.add(ops);
        self.donated_bytes.add(donated_bytes);
    }
}

pub(super) fn exec_counters() -> &'static ExecCounters {
    static EXEC: OnceLock<ExecCounters> = OnceLock::new();
    EXEC.get_or_init(|| ExecCounters {
        runs: counter("exec.runs"),
        instrs: counter("exec.instrs"),
        ops: counter("exec.ops"),
        donated_bytes: counter("exec.donated_bytes"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // Metric names are process-global; tests use unique `obs.test.*`
    // names and assert only on their own entries.

    #[test]
    fn instruments_register_once_and_read_back() {
        let c = counter("obs.test.metrics.counter");
        c.inc();
        c.add(4);
        assert_eq!(counter("obs.test.metrics.counter").get(), 5, "same instrument by name");
        c.set(2);
        assert_eq!(c.get(), 2);

        let g = gauge("obs.test.metrics.gauge");
        g.set(1.5);
        assert_eq!(gauge("obs.test.metrics.gauge").get(), 1.5);

        let h = histogram("obs.test.metrics.hist");
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        assert!((40.0..=60.0).contains(&p50), "p50 of 1..=100 near the middle, got {p50}");
    }

    #[test]
    fn snapshot_is_sorted_and_typed() {
        counter("obs.test.snap.b").inc();
        gauge("obs.test.snap.a").set(3.0);
        histogram("obs.test.snap.c").observe(7.0);
        let snap = metrics_snapshot();
        let mine: Vec<&MetricSample> =
            snap.iter().filter(|s| s.name.starts_with("obs.test.snap.")).collect();
        assert_eq!(
            mine.iter().map(|s| s.name).collect::<Vec<_>>(),
            vec!["obs.test.snap.a", "obs.test.snap.b", "obs.test.snap.c"],
            "snapshot sorted by name"
        );
        assert_eq!(mine[0].kind, MetricKind::Gauge);
        assert_eq!(mine[0].value, 3.0);
        assert_eq!(mine[1].kind, MetricKind::Counter);
        assert_eq!(mine[2].kind, MetricKind::Histogram);
        assert_eq!(mine[2].value, 1.0, "histogram sample carries its count");
        assert_eq!(mine[2].p50, 7.0);

        let json = metrics_json();
        assert!(json.contains("\"name\": \"obs.test.snap.b\", \"kind\": \"counter\""));
        let text = metrics_text();
        assert!(text.contains("obs.test.snap.a"));
    }
}
