//! Unified observability: structured spans, a process-wide metrics
//! registry, Chrome-trace export, and per-request serve timelines.
//!
//! The paper's thesis is that systems research needs frameworks whose
//! internals are *inspectable*; this module is the cross-layer
//! instrumentation substrate that makes the repo's spine — graph
//! compiler, fused kernels, compiled train steps, continuous batching —
//! answerable to questions like "where did this request's 40 ms go?".
//! Three faces, one switch:
//!
//! - **Spans** ([`span`], [`SpanGuard`]): RAII-scoped, nestable timed
//!   regions with `key=value` attributes, recorded into a fixed-capacity
//!   *per-thread ring* (overflow increments an atomic drop counter —
//!   truncation is never silent, see [`dropped_spans`]). A process-wide
//!   collector drains every thread's ring for export.
//!   [`export_chrome_trace`] writes the whole capture as Chrome
//!   trace-event JSON, openable in Perfetto / `chrome://tracing`.
//!   Instrumented out of the box: compiler passes and verify steps,
//!   [`crate::tensor::graph::FusedPlan`] lowering, compiled-program
//!   execution with sampled per-instruction timing (every
//!   [`set_exec_sample_every`]-th run), `compile_step` program builds,
//!   serve prefill chunks / decode iterations / bucket padding / eager
//!   fallbacks, and allocator events bridged from
//!   [`crate::memory::TelemetryMemoryManager`].
//! - **Metrics** ([`counter`], [`gauge`], [`histogram`]): a global typed
//!   registry with atomics on the hot path, names like
//!   `serve.decode.compiled_iterations`. The existing stats structs
//!   (`ContinuousStats`, `BatcherStats`, `EngineStats`, executor
//!   aggregates, KV-pool occupancy, the op profiler) publish into it, so
//!   [`metrics_snapshot`] / [`metrics_json`] / [`metrics_text`] are one
//!   source of truth instead of five structs.
//! - **Request timelines** ([`RequestTrace`]): every serve request
//!   carries admit → backpressure stall → prefill chunks → per-token
//!   decode steps (batch size, bucket, compiled vs eager) → retire,
//!   surfaced on [`crate::serve::GenerateReport::timeline`] and exported
//!   into the same Chrome trace as nested async spans.
//!
//! Everything is **disabled by default**. Enable with [`set_enabled`] or
//! `FL_TRACE=1`; the disabled hot path is a single relaxed atomic load
//! (`rust/benches/obs_overhead.rs` proves the serve-decode overhead is
//! under 1%, enforced by CI). Metric registry *publication* (absolute
//! `set`s inside `stats()` calls) is unconditional — it is off the hot
//! path — while span/timeline *recording* is gated on the switch.

mod chrome;
mod metrics;
mod span;

pub use chrome::{chrome_trace_json, export_chrome_trace};
pub use metrics::{
    counter, gauge, histogram, metrics_json, metrics_snapshot, metrics_text, reset_metrics,
    Counter, Gauge, Histogram, MetricKind, MetricSample,
};
pub use span::{
    dropped_spans, instant, now_ns, reset, span, take_request_traces, take_spans, AttrValue,
    RequestTrace, SpanEvent, SpanGuard, SpanKind, TimelineEvent,
};

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

/// Tri-state so the first [`enabled`] call can consult `FL_TRACE` without
/// putting a `Once` (two atomic ops) on the steady-state path.
static STATE: AtomicU8 = AtomicU8::new(UNINIT);

/// Whether observability recording is on. The steady-state cost of this
/// call — i.e. the *entire* disabled-mode cost of every instrumentation
/// point — is one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => init_from_env(),
    }
}

/// First-call initialization from the environment: `FL_TRACE=1` (or
/// `true`) enables recording, mirroring `FL_VERIFY`'s convention.
#[cold]
fn init_from_env() -> bool {
    let on = matches!(std::env::var("FL_TRACE").ok().as_deref(), Some("1") | Some("true"));
    // never clobber a concurrent set_enabled(): only fill in UNINIT
    let _ = STATE.compare_exchange(
        UNINIT,
        if on { ON } else { OFF },
        Ordering::Relaxed,
        Ordering::Relaxed,
    );
    STATE.load(Ordering::Relaxed) == ON
}

/// Turn recording on or off at runtime (overrides `FL_TRACE`). Spans and
/// timelines already recorded are kept; see [`reset`] to clear them.
pub fn set_enabled(on: bool) {
    STATE.store(if on { ON } else { OFF }, Ordering::Relaxed);
}

// ---- sampled per-instruction execution timing ------------------------------

/// Default: time individual instructions on every 16th compiled-program
/// execution (see [`set_exec_sample_every`]).
pub const DEFAULT_EXEC_SAMPLE_EVERY: u64 = 16;

static EXEC_SAMPLE_EVERY: AtomicU64 = AtomicU64::new(DEFAULT_EXEC_SAMPLE_EVERY);
static EXEC_RUNS_SEEN: AtomicU64 = AtomicU64::new(0);

/// Record per-instruction spans on every `n`-th compiled-program run
/// (`n == 1` samples every run; `n == 0` is clamped to 1). Sampling
/// bounds the enabled-mode overhead of instruction-level timing.
pub fn set_exec_sample_every(n: u64) {
    EXEC_SAMPLE_EVERY.store(n.max(1), Ordering::Relaxed);
}

/// Should the compiled-program execution starting now time each
/// instruction? False whenever recording is disabled; otherwise true for
/// every Nth run process-wide.
pub fn exec_should_sample() -> bool {
    if !enabled() {
        return false;
    }
    let n = EXEC_SAMPLE_EVERY.load(Ordering::Relaxed).max(1);
    EXEC_RUNS_SEEN.fetch_add(1, Ordering::Relaxed) % n == 0
}

/// Publish one compiled-program execution's aggregates into the metrics
/// registry (`exec.runs`, `exec.instrs`, `exec.ops`,
/// `exec.donated_bytes`). Called by the executor only when [`enabled`].
pub fn record_exec(instrs: u64, ops: u64, donated_bytes: u64) {
    metrics::exec_counters().record(instrs, ops, donated_bytes);
}

/// Serialize tests that flip the process-global switch (`cargo test`
/// runs tests concurrently in one process). Poison-tolerant like every
/// other lock in the crate.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests share one process: each takes the switch lock, snapshots and
    // restores the switch, and asserts on its *own* spans by name.

    #[test]
    fn switch_round_trips_and_gates_spans() {
        let _serial = test_guard();
        let was = enabled();
        set_enabled(false);
        assert!(!enabled());
        {
            let _s = span("obs.test.disabled");
        }
        assert!(
            !take_spans().iter().any(|e| e.name == "obs.test.disabled"),
            "disabled span must not record"
        );
        set_enabled(true);
        assert!(enabled());
        {
            let mut s = span("obs.test.enabled");
            s.attr_i64("k", 7);
        }
        let spans = take_spans();
        let ev = spans
            .iter()
            .find(|e| e.name == "obs.test.enabled")
            .expect("enabled span must record");
        assert!(ev.attrs.iter().any(|(k, v)| *k == "k" && matches!(v, AttrValue::I64(7))));
        set_enabled(was);
    }

    #[test]
    fn exec_sampling_is_gated_and_clamped() {
        let _serial = test_guard();
        let was = enabled();
        set_enabled(true);
        // n == 1 (and the n == 0 clamp) fire on every run — deterministic
        // even though the run counter is process-global
        set_exec_sample_every(1);
        assert!((0..16).all(|_| exec_should_sample()));
        set_exec_sample_every(0);
        assert!(exec_should_sample(), "n == 0 clamps to sample-every-run");
        set_exec_sample_every(DEFAULT_EXEC_SAMPLE_EVERY);
        set_enabled(false);
        assert!(!exec_should_sample(), "sampling is off while disabled");
        set_enabled(was);
    }
}
