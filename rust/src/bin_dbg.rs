fn main() {
    let path = "artifacts/linear_gelu__32x256__256x256__256.hlo.txt";
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = match xla::HloModuleProto::from_text_file(path) {
        Ok(p) => p, Err(e) => { println!("parse err: {e}"); return }
    };
    let comp = xla::XlaComputation::from_proto(&proto);
    match client.compile(&comp) {
        Ok(_) => println!("compile OK"),
        Err(e) => println!("compile err: {e}"),
    }
}
