//! `fl_dbg` — tiny PJRT artifact-compilation probe. Parses one HLO-text
//! artifact and attempts to compile it, printing each failure step instead
//! of panicking (the offline build stubs PJRT, so the client step reports
//! unavailability).

use flashlight::runtime::xla;

fn main() {
    let path = "artifacts/linear_gelu__32x256__256x256__256.hlo.txt";
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            println!("pjrt client err: {e}");
            return;
        }
    };
    let proto = match xla::HloModuleProto::from_text_file(path) {
        Ok(p) => p,
        Err(e) => {
            println!("parse err: {e}");
            return;
        }
    };
    let comp = xla::XlaComputation::from_proto(&proto);
    match client.compile(&comp) {
        Ok(_) => println!("compile OK"),
        Err(e) => println!("compile err: {e}"),
    }
}
