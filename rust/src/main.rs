//! `fl` — the flashlight-rs command-line launcher.
//!
//! ```text
//! fl train --config configs/bert_tiny.toml [--set train.lr=0.01 ...]
//! fl info                      # version, backends, artifact registry
//! fl artifacts-check           # run the PJRT smoke artifact
//! ```

use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;

use flashlight::coordinator::{train_classifier, train_data_parallel, train_lm, TrainConfig};
use flashlight::data::TransformDataset;
use flashlight::models;
use flashlight::pkg::text::AutoregressiveLmDataset;
use flashlight::pkg::vision::synthetic_image_classification;
use flashlight::runtime::PjrtRuntime;
use flashlight::tensor::{lazy::LazyBackend, set_default_backend, xla_backend::XlaBackend, Tensor};
use flashlight::util::error::{Error, Result};

fn usage() -> ! {
    eprintln!(
        "usage:\n  fl train --config <file> [--set k=v ...]\n  fl info\n  fl artifacts-check"
    );
    std::process::exit(2);
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "train" => cmd_train(&args[1..]),
        "info" => cmd_info(),
        "artifacts-check" => cmd_artifacts_check(),
        _ => usage(),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_info() -> Result<()> {
    println!("flashlight-rs {}", flashlight::VERSION);
    println!("backends: cpu (eager), lazy (deferred+fused), xla-aot (static)");
    println!("threads: {}", flashlight::util::parallel::num_threads());
    match PjrtRuntime::global() {
        Some(rt) => {
            println!("artifacts: {} registered ops: {:?}", rt.registry().len(), rt.registry().ops());
        }
        None => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_artifacts_check() -> Result<()> {
    let rt = PjrtRuntime::global()
        .ok_or_else(|| Error::Runtime("artifacts/ missing — run `make artifacts`".into()))?;
    let x = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [2, 2]);
    let y = Tensor::ones([2, 2]);
    let out = rt.run("matmul_add", &[&x, &y])?;
    println!("matmul_add smoke: {:?} (want [5, 5, 9, 9])", out.to_vec());
    assert_eq!(out.to_vec(), vec![5.0, 5.0, 9.0, 9.0]);
    println!("artifacts OK");
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut config_path: Option<String> = None;
    let mut overrides = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--config" => config_path = it.next().cloned(),
            "--set" => {
                overrides.push(
                    it.next().cloned().ok_or_else(|| Error::Config("--set needs k=v".into()))?,
                );
            }
            other => return Err(Error::Config(format!("unknown flag `{other}`"))),
        }
    }
    let path = config_path.ok_or_else(|| Error::Config("--config is required".into()))?;
    let cfg = TrainConfig::load(Path::new(&path), &overrides)?;
    println!("config: {cfg:?}");

    // backend selection (paper §5.2.4: one switch retargets everything)
    match cfg.backend.as_str() {
        "lazy" => {
            set_default_backend(LazyBackend::shared());
        }
        "xla" => {
            let be = XlaBackend::from_global_runtime()
                .ok_or_else(|| Error::Runtime("xla backend needs artifacts/".into()))?;
            set_default_backend(be);
        }
        _ => {}
    }

    if cfg.model == "bert" {
        // language-model path on a synthetic corpus
        let corpus: Vec<usize> = {
            let mut rng = flashlight::util::rng::Rng::new(cfg.seed);
            // token stream with bigram structure so the LM has signal
            let mut toks = vec![3usize];
            for _ in 0..20_000 {
                let prev = *toks.last().unwrap();
                let next = if rng.uniform() < 0.7 { (prev * 7 + 3) % 997 + 3 } else { rng.below(997) + 3 };
                toks.push(next);
            }
            toks
        };
        let ds = Arc::new(AutoregressiveLmDataset::new(corpus, 32, 8));
        let model = models::BertLike::new(1000, 128, 4, 2, 64);
        println!("model: {} params", flashlight::nn::num_params(&model));
        let report = train_lm(&model, ds, &cfg, |step, loss| {
            println!("step {step:>5}  loss {loss:.4}");
        })?;
        println!(
            "done: final loss {:.4}, {:.1} seq/s",
            report.final_loss, report.throughput
        );
        return Ok(());
    }

    // classifier path
    let make_data = |seed: usize| -> Arc<dyn flashlight::data::Dataset> {
        let base = synthetic_image_classification(256, 3, 32, 10, cfg.seed + seed as u64);
        Arc::new(TransformDataset::new(base, |s| s))
    };
    if cfg.workers > 1 {
        let model_name = cfg.model.clone();
        let reports = train_data_parallel(
            move || models::by_name(&model_name).expect("unknown model").0,
            |rank| make_data(rank),
            &cfg,
        )?;
        for (rank, r) in reports.iter().enumerate() {
            println!(
                "worker {rank}: final loss {:.4}, {:.1} samples/s",
                r.final_loss, r.throughput
            );
        }
    } else {
        let (mut model, _spec) = models::by_name(&cfg.model)
            .ok_or_else(|| Error::Config(format!("unknown model `{}`", cfg.model)))?;
        println!("model: {} params", flashlight::nn::num_params(model.as_ref()));
        let report = train_classifier(model.as_mut(), make_data(0), &cfg, |step, loss| {
            println!("step {step:>5}  loss {loss:.4}");
        })?;
        println!(
            "done: final loss {:.4}, eval error {:.1}%, {:.1} samples/s",
            report.final_loss,
            report.eval_error.unwrap_or(f64::NAN),
            report.throughput
        );
    }
    Ok(())
}
