//! In-house testing utilities: numeric gradient checking and a small
//! property-testing harness (no external `proptest` is available offline).

pub mod gradcheck;
pub mod prop;
