//! In-house testing utilities: numeric gradient checking, a small
//! property-testing harness (no external `proptest` is available
//! offline), and the shared bench-snapshot JSON writer.

pub mod bench_json;
pub mod gradcheck;
pub mod prop;

pub use bench_json::{write_bench_json, BenchRecord};
