//! A miniature property-testing harness (offline stand-in for proptest):
//! seeded random case generation with first-failure reporting.

use crate::util::rng::Rng;

/// Run `cases` random test cases. `gen` builds an input from the RNG;
/// `check` returns `Err(msg)` on a violated property. Panics with the
/// failing case number, seed, and a `Debug` dump of the input.
pub fn run<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = 0x5EED ^ name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {msg}\ninput: {input:?}");
        }
    }
}

/// Generate a random shape with rank in `[1, max_rank]` and each dim in
/// `[1, max_dim]`.
pub fn random_shape(rng: &mut Rng, max_rank: usize, max_dim: usize) -> Vec<usize> {
    let rank = 1 + rng.below(max_rank);
    (0..rank).map(|_| 1 + rng.below(max_dim)).collect()
}

/// Random f32 vector of length `n` in `[-bound, bound]`.
pub fn random_vec(rng: &mut Rng, n: usize, bound: f64) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_range(-bound, bound) as f32).collect()
}

/// A broadcast-compatible variant of `shape`: random subset of dims set to
/// 1, random leading dims dropped.
pub fn broadcastable_shape(rng: &mut Rng, shape: &[usize]) -> Vec<usize> {
    let drop = rng.below(shape.len() + 1);
    let mut out: Vec<usize> = shape[drop..].to_vec();
    for d in out.iter_mut() {
        if rng.uniform() < 0.4 {
            *d = 1;
        }
    }
    if out.is_empty() {
        out.push(1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        run("count", 50, |r| r.below(10), |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property `alwaysfail` failed")]
    fn reports_failure() {
        run("alwaysfail", 10, |r| r.below(5), |x| Err(format!("x={x}")));
    }

    #[test]
    fn shapes_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = random_shape(&mut rng, 4, 6);
            assert!((1..=4).contains(&s.len()));
            assert!(s.iter().all(|&d| (1..=6).contains(&d)));
            let b = broadcastable_shape(&mut rng, &s);
            assert!(b.len() <= s.len() || (b.len() == 1 && s.is_empty()));
        }
    }
}
