//! Shared machine-readable bench output: every bench writes its snapshot
//! as `BENCH_PR<N>.json` at the repo root through this one writer, so the
//! row format (`[{"op", "ns_per_iter", "backend", ...extras}]`) cannot
//! drift between benches. Hand-rolled JSON — the crate is dependency-free.

/// One measurement row (plus free-form numeric extras, e.g. per-pass op
/// counts for graph-compiler rows).
pub struct BenchRecord {
    /// Measured operation name.
    pub op: String,
    /// Nanoseconds per iteration (0 for non-timing rows).
    pub ns_per_iter: f64,
    /// Backend label.
    pub backend: &'static str,
    /// Additional numeric columns.
    pub extras: Vec<(&'static str, f64)>,
}

impl BenchRecord {
    /// Row without extras.
    pub fn new(op: impl Into<String>, ns_per_iter: f64, backend: &'static str) -> BenchRecord {
        BenchRecord { op: op.into(), ns_per_iter, backend, extras: Vec::new() }
    }
}

/// Write `records` to `<repo root>/<file_name>`, replacing any previous
/// snapshot (the perf trajectory accumulates across PRs via version
/// control, one snapshot per PR).
pub fn write_bench_json(file_name: &str, records: &[BenchRecord]) {
    let path = format!("{}/{}", env!("CARGO_MANIFEST_DIR"), file_name);
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let mut row = format!(
            "  {{\"op\": \"{}\", \"ns_per_iter\": {:.1}, \"backend\": \"{}\"",
            r.op, r.ns_per_iter, r.backend
        );
        for (k, v) in &r.extras {
            row.push_str(&format!(", \"{k}\": {v}"));
        }
        row.push_str(&format!("}}{}\n", if i + 1 < records.len() { "," } else { "" }));
        s.push_str(&row);
    }
    s.push_str("]\n");
    match std::fs::write(&path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_render_as_json() {
        let mut r = BenchRecord::new("matmul", 1234.5, "cpu");
        r.extras.push(("gflops", 2.0));
        // render through the same formatting path (no file I/O)
        let row = format!(
            "{{\"op\": \"{}\", \"ns_per_iter\": {:.1}, \"backend\": \"{}\"}}",
            r.op, r.ns_per_iter, r.backend
        );
        assert!(row.contains("\"matmul\"") && row.contains("1234.5"));
    }
}
