//! Numeric gradient checking for autograd operators.

use crate::autograd::Variable;
use crate::tensor::{DType, Tensor};
use crate::util::rng::Rng;

/// Check analytic vs central-difference gradients of `f` (a scalar-valued
/// function of one variable) at a random f64 point of shape `shape`.
///
/// Panics with a diagnostic on mismatch. Uses f64 inputs for stable
/// differencing.
pub fn check_grad(name: &str, shape: &[usize], f: impl Fn(&Variable) -> Variable) {
    check_grad_tol(name, shape, 1e-4, 5e-3, f)
}

/// [`check_grad`] with explicit step and tolerance.
pub fn check_grad_tol(
    name: &str,
    shape: &[usize],
    eps: f64,
    tol: f64,
    f: impl Fn(&Variable) -> Variable,
) {
    let mut rng = Rng::new(0xC0FFEE ^ name.len() as u64);
    let n: usize = shape.iter().product();
    let base: Vec<f64> = (0..n).map(|_| rng.uniform_range(-0.9, 0.9)).collect();
    let xt = Tensor::from_slice(&base, shape.to_vec()).astype(DType::F64);

    let x = Variable::param(xt.clone());
    let y = f(&x);
    assert_eq!(y.numel(), 1, "{name}: gradcheck target must be scalar");
    y.backward();
    let analytic = x.grad().expect("no gradient").to_vec_f64();

    // probe a subset of coordinates for large inputs
    let probes: Vec<usize> = if n <= 24 {
        (0..n).collect()
    } else {
        let mut p: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut p);
        p.truncate(24);
        p
    };
    for &i in &probes {
        let mut plus = base.clone();
        plus[i] += eps;
        let mut minus = base.clone();
        minus[i] -= eps;
        let fp = f(&Variable::constant(
            Tensor::from_slice(&plus, shape.to_vec()).astype(DType::F64),
        ))
        .tensor()
        .item();
        let fm = f(&Variable::constant(
            Tensor::from_slice(&minus, shape.to_vec()).astype(DType::F64),
        ))
        .tensor()
        .item();
        let numeric = (fp - fm) / (2.0 * eps);
        let denom = numeric.abs().max(analytic[i].abs()).max(1.0);
        assert!(
            (numeric - analytic[i]).abs() / denom < tol,
            "{name}: grad mismatch at {i}: numeric {numeric} vs analytic {}",
            analytic[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ops;

    #[test]
    fn passes_on_correct_gradient() {
        check_grad("square", &[4], |x| ops::sum(&ops::mul(x, x), &[], false));
    }

    #[test]
    #[should_panic(expected = "grad mismatch")]
    fn fails_on_wrong_gradient() {
        // claim d(sum(x))/dx = 2 (wrong)
        check_grad("bogus", &[3], |x| {
            let out = x.tensor().sum(&[], false);
            Variable::from_op(out, vec![x.clone()], "bogus", |ins, _g| {
                vec![Some(Tensor::full(ins[0].dims(), 2.0, DType::F64))]
            })
        });
    }
}
