//! Vision package (paper §4.3 "Vision"): data augmentations /
//! transformations and synthetic benchmark datasets (the stand-in for
//! ImageNet/COCO loaders on this testbed — see DESIGN.md substitutions).

pub mod datasets;
pub mod transforms;

pub use datasets::synthetic_image_classification;
pub use transforms::{normalize, random_crop, random_flip_h};
