//! Image transforms over `[C, H, W]` (single image) or `[N, C, H, W]`
//! tensors, composable through [`crate::data::TransformDataset`].

use crate::tensor::Tensor;
use crate::util::rng::with_thread_rng;

/// Per-channel normalization: `(x - mean[c]) / std[c]`.
pub fn normalize(x: &Tensor, mean: &[f64], std: &[f64]) -> Tensor {
    let c = x.dim(-3);
    assert_eq!(mean.len(), c);
    assert_eq!(std.len(), c);
    let m: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
    let s: Vec<f32> = std.iter().map(|&v| v as f32).collect();
    let mt = Tensor::from_slice(&m, [c, 1, 1]);
    let st = Tensor::from_slice(&s, [c, 1, 1]);
    x.sub(&mt).div(&st)
}

/// Random horizontal flip with probability `p` (flips the last axis).
pub fn random_flip_h(x: &Tensor, p: f64) -> Tensor {
    let flip = with_thread_rng(|r| r.uniform() < p);
    if flip {
        x.flip(&[-1])
    } else {
        x.clone()
    }
}

/// Random crop of `size`×`size` after zero-padding by `pad` (standard
/// CIFAR-style augmentation). Works on `[C, H, W]`.
pub fn random_crop(x: &Tensor, size: usize, pad: usize) -> Tensor {
    assert_eq!(x.rank(), 3, "random_crop wants [C,H,W]");
    let padded = x.pad(&[(0, 0), (pad, pad), (pad, pad)], 0.0);
    let (h, w) = (padded.dim(1), padded.dim(2));
    let (dy, dx) = with_thread_rng(|r| (r.below(h - size + 1), r.below(w - size + 1)));
    padded.slice(&[0, dy, dx], &[padded.dim(0), dy + size, dx + size])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_standardizes_channels() {
        let x = Tensor::full([2, 4, 4], 10.0, crate::tensor::DType::F32);
        let y = normalize(&x, &[10.0, 10.0], &[2.0, 5.0]);
        assert!(y.to_vec().iter().all(|&v| v == 0.0));
        let y2 = normalize(&x, &[8.0, 0.0], &[1.0, 10.0]);
        let v = y2.to_vec();
        assert_eq!(v[0], 2.0);
        assert_eq!(v[16], 1.0);
    }

    #[test]
    fn crop_shape_and_content() {
        let x = Tensor::arange(16, crate::tensor::DType::F32).reshape(&[1, 4, 4]);
        let y = random_crop(&x, 4, 2);
        assert_eq!(y.dims(), &[1, 4, 4]);
        // all original values still present or zeros from padding
        for v in y.to_vec() {
            assert!((0.0..16.0).contains(&v) || v == 0.0);
        }
    }

    #[test]
    fn flip_preserves_multiset() {
        crate::util::rng::seed(123);
        let x = Tensor::arange(12, crate::tensor::DType::F32).reshape(&[1, 3, 4]);
        let y = random_flip_h(&x, 1.0); // always flip
        let mut a = x.to_vec();
        let mut b = y.to_vec();
        assert_eq!(b[0], 3.0); // first row reversed
        a.sort_by(|p, q| p.partial_cmp(q).unwrap());
        b.sort_by(|p, q| p.partial_cmp(q).unwrap());
        assert_eq!(a, b);
    }
}
