//! Synthetic image-classification datasets: class-conditional Gaussian
//! blobs with structured spatial patterns, learnable by real models but
//! requiring genuine training (the testbed substitution for ImageNet —
//! DESIGN.md §Hardware-Adaptation).

use std::sync::Arc;

use crate::data::{Dataset, TensorDataset};
use crate::tensor::{DType, Shape, Tensor};
use crate::util::rng::Rng;

/// Generate `n` labelled images `[n, c, size, size]` over `classes`
/// classes. Each class gets a random spatial frequency pattern plus noise.
pub fn synthetic_image_classification(
    n: usize,
    c: usize,
    size: usize,
    classes: usize,
    seed: u64,
) -> Arc<dyn Dataset> {
    let mut rng = Rng::new(seed);
    // class prototypes: distinct sinusoidal patterns
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|k| {
            let fx = 1.0 + (k % 4) as f32;
            let fy = 1.0 + (k / 4) as f32;
            let phase = rng.uniform_range(0.0, std::f64::consts::TAU) as f32;
            (0..c * size * size)
                .map(|i| {
                    let pix = i % (size * size);
                    let (y, x) = (pix / size, pix % size);
                    ((fx * x as f32 + fy * y as f32) * std::f32::consts::TAU
                        / size as f32
                        + phase)
                        .sin()
                })
                .collect()
        })
        .collect();
    let mut xs = Vec::with_capacity(n * c * size * size);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let k = rng.below(classes);
        ys.push(k as i64);
        for &p in &protos[k] {
            xs.push(p + 0.3 * rng.normal() as f32);
        }
    }
    Arc::new(TensorDataset::new(vec![
        Tensor::from_slice(&xs, Shape::new(vec![n, c, size, size])),
        Tensor::from_slice(&ys, [n]).astype(DType::I64),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let ds = synthetic_image_classification(20, 3, 8, 5, 1);
        assert_eq!(ds.len(), 20);
        let s = ds.get(3);
        assert_eq!(s[0].dims(), &[1, 3, 8, 8]);
        let label = s[1].to_vec_i64()[0];
        assert!((0..5).contains(&label));
    }

    #[test]
    fn classes_are_separable_by_prototype_correlation() {
        let ds = synthetic_image_classification(60, 1, 8, 2, 7);
        // nearest-prototype classification on the raw data should beat chance
        let mut per_class: Vec<Vec<Vec<f32>>> = vec![Vec::new(), Vec::new()];
        for i in 0..ds.len() {
            let s = ds.get(i);
            per_class[s[1].to_vec_i64()[0] as usize].push(s[0].to_vec());
        }
        assert!(per_class[0].len() > 5 && per_class[1].len() > 5);
        let mean = |v: &Vec<Vec<f32>>| -> Vec<f32> {
            let mut m = vec![0.0; v[0].len()];
            for row in v {
                for (a, b) in m.iter_mut().zip(row) {
                    *a += b / v.len() as f32;
                }
            }
            m
        };
        let (m0, m1) = (mean(&per_class[0]), mean(&per_class[1]));
        let mut correct = 0;
        let mut total = 0;
        for (k, rows) in per_class.iter().enumerate() {
            for r in rows {
                let d0: f32 = r.iter().zip(&m0).map(|(a, b)| (a - b) * (a - b)).sum();
                let d1: f32 = r.iter().zip(&m1).map(|(a, b)| (a - b) * (a - b)).sum();
                let pred = if d0 < d1 { 0 } else { 1 };
                correct += usize::from(pred == k);
                total += 1;
            }
        }
        assert!(correct as f64 / total as f64 > 0.8, "classes not separable");
    }
}
