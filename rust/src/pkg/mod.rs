//! Domain-specific packages (paper §4.3): building blocks for common ML
//! tasks layered over the core, exactly as the original library structures
//! speech / vision / text atop its foundation APIs.

pub mod speech;
pub mod text;
pub mod vision;
