//! Connectionist Temporal Classification criterion with a custom tape
//! gradient (the speech package's "speech-specific sequential criteria").
//!
//! Blank index is 0. The forward–backward recursions run in log domain;
//! the gradient w.r.t. the frame log-probabilities is the negative state
//! posterior, registered as a custom autograd node (paper Listing 4
//! pattern).

use crate::autograd::Variable;
use crate::tensor::Tensor;

/// Numerically-stable log(exp(a)+exp(b)).
fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Extended label sequence with interleaved blanks: `_ l1 _ l2 _ ... _`.
fn extend(targets: &[usize]) -> Vec<usize> {
    let mut ext = Vec::with_capacity(targets.len() * 2 + 1);
    ext.push(0);
    for &t in targets {
        ext.push(t);
        ext.push(0);
    }
    ext
}

/// CTC negative log-likelihood of `targets` under `log_probs [T, C]`
/// (frame log-probabilities, blank = class 0), plus gradient
/// `d(-logP)/d(log_probs)`.
pub fn ctc_forward(log_probs: &[f64], t_len: usize, classes: usize, targets: &[usize]) -> (f64, Vec<f64>) {
    let ext = extend(targets);
    let s = ext.len();
    assert!(t_len * classes == log_probs.len());
    assert!(
        s <= 2 * t_len + 1,
        "target length {} too long for {} frames",
        targets.len(),
        t_len
    );
    let lp = |t: usize, k: usize| log_probs[t * classes + k];
    let ninf = f64::NEG_INFINITY;

    // alpha
    let mut alpha = vec![ninf; t_len * s];
    alpha[0] = lp(0, ext[0]);
    if s > 1 {
        alpha[1] = lp(0, ext[1]);
    }
    for t in 1..t_len {
        for i in 0..s {
            let mut a = alpha[(t - 1) * s + i];
            if i >= 1 {
                a = logaddexp(a, alpha[(t - 1) * s + i - 1]);
            }
            if i >= 2 && ext[i] != 0 && ext[i] != ext[i - 2] {
                a = logaddexp(a, alpha[(t - 1) * s + i - 2]);
            }
            alpha[t * s + i] = a + lp(t, ext[i]);
        }
    }
    let log_z = if s > 1 {
        logaddexp(alpha[(t_len - 1) * s + s - 1], alpha[(t_len - 1) * s + s - 2])
    } else {
        alpha[(t_len - 1) * s]
    };

    // beta
    let mut beta = vec![ninf; t_len * s];
    beta[(t_len - 1) * s + s - 1] = lp(t_len - 1, ext[s - 1]);
    if s > 1 {
        beta[(t_len - 1) * s + s - 2] = lp(t_len - 1, ext[s - 2]);
    }
    for t in (0..t_len - 1).rev() {
        for i in 0..s {
            let mut b = beta[(t + 1) * s + i];
            if i + 1 < s {
                b = logaddexp(b, beta[(t + 1) * s + i + 1]);
            }
            if i + 2 < s && ext[i + 2] != 0 && ext[i] != ext[i + 2] {
                b = logaddexp(b, beta[(t + 1) * s + i + 2]);
            }
            beta[t * s + i] = b + lp(t, ext[i]);
        }
    }

    // gradient: -posterior aggregated per class
    let mut grad = vec![0.0f64; t_len * classes];
    for t in 0..t_len {
        for (i, &lab) in ext.iter().enumerate() {
            // alpha and beta both include lp(t, ext[i]) — divide once out
            let post = alpha[t * s + i] + beta[t * s + i] - lp(t, ext[i]) - log_z;
            grad[t * classes + lab] -= post.exp();
        }
    }
    (-log_z, grad)
}

/// Differentiable CTC loss over a `[T, C]` log-probability Variable.
pub fn ctc_loss(log_probs: &Variable, targets: &[usize]) -> Variable {
    let lp = log_probs.tensor();
    let dims = lp.dims().to_vec();
    assert_eq!(dims.len(), 2, "ctc_loss wants [T, C] log-probs");
    let (t_len, classes) = (dims[0], dims[1]);
    let (loss, grad) = ctc_forward(&lp.to_vec_f64(), t_len, classes, targets);
    let grad_t = Tensor::from_slice(
        &grad.iter().map(|&g| g as f32).collect::<Vec<f32>>(),
        [t_len, classes],
    );
    Variable::from_op(
        Tensor::from_slice(&[loss as f32], [1]),
        vec![log_probs.clone()],
        "ctc",
        move |_, g| {
            let scale = g.to_vec()[0] as f64;
            vec![Some(grad_t.mul_scalar(scale))]
        },
    )
}

/// Greedy CTC decoding: per-frame argmax, collapse repeats, drop blanks.
pub fn greedy_decode(log_probs: &Tensor) -> Vec<usize> {
    let ids = log_probs.argmax(-1, false).to_vec_i64();
    let mut out = Vec::new();
    let mut prev = -1i64;
    for &id in &ids {
        if id != prev && id != 0 {
            out.push(id as usize);
        }
        prev = id;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ops;

    fn uniform_logp(t: usize, c: usize) -> Vec<f64> {
        vec![-(c as f64).ln(); t * c]
    }

    #[test]
    fn single_frame_single_label() {
        // P(target) = p(label at t=0); loss = -log p
        let c = 4;
        let lp = uniform_logp(1, c);
        let (loss, _) = ctc_forward(&lp, 1, c, &[2]);
        assert!((loss - (4.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn loss_matches_brute_force_enumeration() {
        // 3 frames, 3 classes, target [1,2]: enumerate all 27 paths
        crate::util::rng::seed(2);
        let t = 3;
        let c = 3;
        let raw = Tensor::rand([t, c], -1.0, 1.0).log_softmax(-1);
        let lp = raw.to_vec_f64();
        let (loss, _) = ctc_forward(&lp, t, c, &[1, 2]);
        // brute force: sum over all paths that collapse to [1,2]
        let mut total = 0.0f64;
        for p0 in 0..c {
            for p1 in 0..c {
                for p2 in 0..c {
                    let path = [p0, p1, p2];
                    let mut collapsed = Vec::new();
                    let mut prev = usize::MAX;
                    for &s in &path {
                        if s != prev && s != 0 {
                            collapsed.push(s);
                        }
                        prev = s;
                    }
                    if collapsed == vec![1, 2] {
                        total +=
                            (lp[p0] + lp[c + p1] + lp[2 * c + p2]).exp();
                    }
                }
            }
        }
        assert!((loss - (-total.ln())).abs() < 1e-8, "{loss} vs {}", -total.ln());
    }

    #[test]
    fn gradient_matches_numeric() {
        crate::util::rng::seed(3);
        let t = 5;
        let c = 4;
        let base = Tensor::rand([t, c], -1.0, 1.0).to_vec_f64();
        let targets = [1usize, 3];
        // treat log_probs as free inputs (gradcheck of the raw recursion)
        let (_, grad) = ctc_forward(&base, t, c, &targets);
        let eps = 1e-5;
        for probe in [0usize, 3, 7, 13, 19] {
            let mut p = base.clone();
            p[probe] += eps;
            let (lp, _) = ctc_forward(&p, t, c, &targets);
            let mut m = base.clone();
            m[probe] -= eps;
            let (lm, _) = ctc_forward(&m, t, c, &targets);
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - grad[probe]).abs() < 1e-5, "probe {probe}: {num} vs {}", grad[probe]);
        }
    }

    #[test]
    fn trains_to_emit_target() {
        crate::util::rng::seed(4);
        let t = 8;
        let c = 5;
        let logits = Variable::param(Tensor::rand([t, c], -0.1, 0.1));
        let targets = [2usize, 4, 1];
        let mut last = f64::INFINITY;
        for _ in 0..80 {
            let logp = ops::log_softmax(&logits, -1);
            let loss = ctc_loss(&logp, &targets);
            last = loss.tensor().item();
            loss.backward();
            let g = logits.grad().unwrap();
            logits.set_tensor(logits.tensor().sub(&g.mul_scalar(1.0)));
            logits.zero_grad();
        }
        assert!(last < 0.5, "CTC did not converge: {last}");
        let decoded = greedy_decode(&logits.tensor().log_softmax(-1));
        assert_eq!(decoded, targets.to_vec());
    }

    #[test]
    fn greedy_collapses_and_drops_blanks() {
        // frames argmax: [0, 1, 1, 0, 2, 2, 0]
        let mut lp = vec![-10.0f32; 7 * 3];
        for (t, k) in [(0, 0), (1, 1), (2, 1), (3, 0), (4, 2), (5, 2), (6, 0)] {
            lp[t * 3 + k] = 0.0;
        }
        let out = greedy_decode(&Tensor::from_slice(&lp, [7, 3]));
        assert_eq!(out, vec![1, 2]);
    }
}
