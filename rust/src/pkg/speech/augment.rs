//! Waveform augmentation (paper: "additive noise and reverberation").

use crate::util::rng::Rng;

/// Mix gaussian noise into `wave` at the given signal-to-noise ratio (dB).
pub fn additive_noise(wave: &mut [f32], snr_db: f32, rng: &mut Rng) {
    if wave.is_empty() {
        return;
    }
    let sig_pow: f32 = wave.iter().map(|x| x * x).sum::<f32>() / wave.len() as f32;
    let noise_pow = sig_pow / 10f32.powf(snr_db / 10.0);
    let sigma = noise_pow.sqrt();
    for x in wave.iter_mut() {
        *x += sigma * rng.normal() as f32;
    }
}

/// Simple synthetic reverb: convolve with an exponentially-decaying
/// impulse response of `taps` echoes.
pub fn reverb(wave: &[f32], taps: usize, decay: f32, spacing: usize) -> Vec<f32> {
    let mut out = wave.to_vec();
    for t in 1..=taps {
        let gain = decay.powi(t as i32);
        let off = t * spacing;
        for i in off..out.len() {
            out[i] += gain * wave[i - off];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snr_controls_noise_power() {
        let mut rng = Rng::new(5);
        let clean: Vec<f32> = (0..8000).map(|i| (i as f32 * 0.05).sin()).collect();
        let mut noisy = clean.clone();
        additive_noise(&mut noisy, 10.0, &mut rng);
        let noise_pow: f32 =
            clean.iter().zip(&noisy).map(|(c, n)| (n - c) * (n - c)).sum::<f32>() / clean.len() as f32;
        let sig_pow: f32 = clean.iter().map(|x| x * x).sum::<f32>() / clean.len() as f32;
        let snr = 10.0 * (sig_pow / noise_pow).log10();
        assert!((snr - 10.0).abs() < 1.0, "achieved snr {snr}");
    }

    #[test]
    fn reverb_adds_delayed_energy() {
        let mut impulse = vec![0.0f32; 100];
        impulse[0] = 1.0;
        let out = reverb(&impulse, 2, 0.5, 10);
        assert_eq!(out[0], 1.0);
        assert!((out[10] - 0.5).abs() < 1e-6);
        assert!((out[20] - 0.25).abs() < 1e-6);
    }
}
