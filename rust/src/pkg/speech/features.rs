//! Classical speech featurization: framing, Hann window, DFT power
//! spectrum, mel filterbank, log compression (paper: "spectogram, log-mel
//! filterbanks ... can run on-the-fly with minimal overhead").
//!
//! The DFT is implemented directly (O(N·K) per frame with precomputed
//! twiddles) — frame sizes are small (≤512) and this keeps the package
//! dependency-free.

use crate::tensor::{Shape, Tensor};

/// Featurization hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct FeatureParams {
    /// Sample rate in Hz.
    pub sample_rate: usize,
    /// Frame length in samples.
    pub frame_len: usize,
    /// Hop between frames in samples.
    pub hop: usize,
    /// Number of mel bins.
    pub n_mels: usize,
}

impl Default for FeatureParams {
    fn default() -> Self {
        FeatureParams { sample_rate: 16_000, frame_len: 400, hop: 160, n_mels: 80 }
    }
}

fn hann(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let x = std::f32::consts::PI * i as f32 / n as f32;
            (x.sin() * x.sin()) as f32
        })
        .collect()
}

/// Power spectrum of one frame (first `n/2+1` bins).
fn power_spectrum(frame: &[f32], cos_t: &[f32], sin_t: &[f32], bins: usize) -> Vec<f32> {
    let n = frame.len();
    let mut out = vec![0.0f32; bins];
    for (k, o) in out.iter_mut().enumerate() {
        let mut re = 0.0f32;
        let mut im = 0.0f32;
        for (i, &x) in frame.iter().enumerate() {
            let idx = (k * i) % n;
            re += x * cos_t[idx];
            im -= x * sin_t[idx];
        }
        *o = re * re + im * im;
    }
    out
}

fn hz_to_mel(f: f32) -> f32 {
    2595.0 * (1.0 + f / 700.0).log10()
}

fn mel_to_hz(m: f32) -> f32 {
    700.0 * (10f32.powf(m / 2595.0) - 1.0)
}

/// Triangular mel filterbank matrix `[n_mels, bins]`.
pub fn mel_filterbank(p: &FeatureParams, bins: usize) -> Vec<Vec<f32>> {
    let f_max = p.sample_rate as f32 / 2.0;
    let m_max = hz_to_mel(f_max);
    let centers: Vec<f32> = (0..p.n_mels + 2)
        .map(|i| mel_to_hz(m_max * i as f32 / (p.n_mels + 1) as f32))
        .collect();
    let hz_per_bin = f_max / (bins - 1) as f32;
    let mut fb = vec![vec![0.0f32; bins]; p.n_mels];
    for m in 0..p.n_mels {
        let (lo, mid, hi) = (centers[m], centers[m + 1], centers[m + 2]);
        for (b, w) in fb[m].iter_mut().enumerate() {
            let f = b as f32 * hz_per_bin;
            if f > lo && f < mid {
                *w = (f - lo) / (mid - lo);
            } else if f >= mid && f < hi {
                *w = (hi - f) / (hi - mid);
            }
        }
    }
    fb
}

/// Compute `[frames, n_mels]` log-mel features from a mono waveform.
pub fn log_mel_spectrogram(wave: &[f32], p: &FeatureParams) -> Tensor {
    let n = p.frame_len;
    let bins = n / 2 + 1;
    let window = hann(n);
    let cos_t: Vec<f32> = (0..n).map(|i| (2.0 * std::f32::consts::PI * i as f32 / n as f32).cos()).collect();
    let sin_t: Vec<f32> = (0..n).map(|i| (2.0 * std::f32::consts::PI * i as f32 / n as f32).sin()).collect();
    let fb = mel_filterbank(p, bins);
    let frames = if wave.len() < n { 0 } else { (wave.len() - n) / p.hop + 1 };
    let mut out = Vec::with_capacity(frames * p.n_mels);
    let mut buf = vec![0.0f32; n];
    for t in 0..frames {
        let start = t * p.hop;
        for i in 0..n {
            buf[i] = wave[start + i] * window[i];
        }
        let spec = power_spectrum(&buf, &cos_t, &sin_t, bins);
        for filt in &fb {
            let e: f32 = filt.iter().zip(&spec).map(|(w, s)| w * s).sum();
            out.push((e + 1e-10).ln());
        }
    }
    Tensor::from_slice(&out, Shape::new(vec![frames, p.n_mels]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sine(freq: f32, secs: f32, rate: usize) -> Vec<f32> {
        (0..(secs * rate as f32) as usize)
            .map(|i| (2.0 * std::f32::consts::PI * freq * i as f32 / rate as f32).sin())
            .collect()
    }

    #[test]
    fn frame_count_matches_hop() {
        let p = FeatureParams { frame_len: 256, hop: 128, n_mels: 20, sample_rate: 8000 };
        let feats = log_mel_spectrogram(&vec![0.0; 256 + 5 * 128], &p);
        assert_eq!(feats.dims(), &[6, 20]);
    }

    #[test]
    fn pure_tone_peaks_at_matching_mel() {
        let p = FeatureParams { frame_len: 256, hop: 128, n_mels: 40, sample_rate: 8000 };
        let low = log_mel_spectrogram(&sine(200.0, 0.25, 8000), &p);
        let high = log_mel_spectrogram(&sine(3000.0, 0.25, 8000), &p);
        // energy argmax of the first frame moves up with frequency
        let lo_peak = low.narrow(0, 0, 1).argmax(1, false).to_vec_i64()[0];
        let hi_peak = high.narrow(0, 0, 1).argmax(1, false).to_vec_i64()[0];
        assert!(hi_peak > lo_peak, "mel peaks: low {lo_peak} high {hi_peak}");
    }

    #[test]
    fn filterbank_rows_cover_spectrum() {
        let p = FeatureParams::default();
        let fb = mel_filterbank(&p, 201);
        assert_eq!(fb.len(), 80);
        for (i, row) in fb.iter().enumerate() {
            assert!(row.iter().any(|&w| w > 0.0), "empty mel filter {i}");
        }
    }
}
