//! CTC prefix beam-search decoder with shallow LM fusion (paper §4.3: "a
//! fast beam-search decoder (which can interface any language model)").

use std::collections::HashMap;

use crate::tensor::Tensor;

use super::lm::NGramLm;

/// Decoder hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct DecoderOpts {
    /// Beam width (prefixes kept per frame).
    pub beam: usize,
    /// LM weight for shallow fusion.
    pub lm_weight: f64,
    /// Per-token word-insertion bonus.
    pub word_bonus: f64,
}

impl Default for DecoderOpts {
    fn default() -> Self {
        DecoderOpts { beam: 16, lm_weight: 0.0, word_bonus: 0.0 }
    }
}

/// See module docs.
pub struct BeamSearchDecoder {
    opts: DecoderOpts,
    lm: Option<NGramLm>,
}

fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

impl BeamSearchDecoder {
    /// Lexicon-free decoder; pass an LM for shallow fusion.
    pub fn new(opts: DecoderOpts, lm: Option<NGramLm>) -> Self {
        BeamSearchDecoder { opts, lm }
    }

    /// Decode `[T, C]` frame log-probabilities (blank = class 0) into the
    /// best label sequence.
    pub fn decode(&self, log_probs: &Tensor) -> Vec<usize> {
        self.decode_n(log_probs, 1).pop().map(|(seq, _)| seq).unwrap_or_default()
    }

    /// Decode, returning the top-`n` hypotheses with scores (best last
    /// popped first — sorted best-first).
    pub fn decode_n(&self, log_probs: &Tensor, n: usize) -> Vec<(Vec<usize>, f64)> {
        let dims = log_probs.dims().to_vec();
        let (t_len, classes) = (dims[0], dims[1]);
        let lp = log_probs.to_vec_f64();
        let ninf = f64::NEG_INFINITY;

        // prefix -> (log P(ending in blank), log P(ending in non-blank))
        let mut beams: HashMap<Vec<usize>, (f64, f64)> = HashMap::new();
        beams.insert(Vec::new(), (0.0, ninf));

        for t in 0..t_len {
            let frame = &lp[t * classes..(t + 1) * classes];
            let mut next: HashMap<Vec<usize>, (f64, f64)> = HashMap::new();
            for (prefix, &(pb, pnb)) in &beams {
                let total = logaddexp(pb, pnb);
                // 1) blank extends both states into the blank state
                {
                    let e = next.entry(prefix.clone()).or_insert((ninf, ninf));
                    e.0 = logaddexp(e.0, total + frame[0]);
                }
                // 2) repeat of last non-blank label (stays same prefix)
                if let Some(&last) = prefix.last() {
                    let e = next.entry(prefix.clone()).or_insert((ninf, ninf));
                    e.1 = logaddexp(e.1, pnb + frame[last]);
                }
                // 3) extend with a new label
                for c in 1..classes {
                    let mut ext = prefix.clone();
                    ext.push(c);
                    let base = if Some(&c) == prefix.last() {
                        // after a repeat, a new same-label token needs a
                        // blank in between: only the blank state extends
                        pb
                    } else {
                        total
                    };
                    let mut score = base + frame[c];
                    if let Some(lm) = &self.lm {
                        score += self.opts.lm_weight * lm.score_next(prefix.last().copied(), c)
                            + self.opts.word_bonus;
                    }
                    let e = next.entry(ext).or_insert((ninf, ninf));
                    e.1 = logaddexp(e.1, score);
                }
            }
            // prune to beam width
            let mut entries: Vec<(Vec<usize>, (f64, f64))> = next.into_iter().collect();
            entries
                .sort_by(|a, b| {
                    let sa = logaddexp(a.1 .0, a.1 .1);
                    let sb = logaddexp(b.1 .0, b.1 .1);
                    sb.partial_cmp(&sa).unwrap()
                });
            entries.truncate(self.opts.beam);
            beams = entries.into_iter().collect();
        }

        let mut out: Vec<(Vec<usize>, f64)> = beams
            .into_iter()
            .map(|(seq, (pb, pnb))| (seq, logaddexp(pb, pnb)))
            .collect();
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        out.truncate(n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pkg::speech::ctc::greedy_decode;

    fn peaked(t_classes: &[(usize, usize)], classes: usize) -> Tensor {
        // high prob on the given class per frame
        let t = t_classes.len();
        let mut lp = vec![(0.05f32 / (classes - 1) as f32).ln(); t * classes];
        for (frame, &(ti, k)) in t_classes.iter().enumerate() {
            assert_eq!(frame, ti);
            lp[ti * classes + k] = 0.95f32.ln();
        }
        Tensor::from_slice(&lp, [t, classes]).log_softmax(-1)
    }

    #[test]
    fn beam_matches_greedy_on_peaked_input() {
        let lp = peaked(&[(0, 1), (1, 0), (2, 2), (3, 2), (4, 0)], 4);
        let dec = BeamSearchDecoder::new(DecoderOpts { beam: 8, ..Default::default() }, None);
        assert_eq!(dec.decode(&lp), greedy_decode(&lp));
        assert_eq!(dec.decode(&lp), vec![1, 2]);
    }

    #[test]
    fn beam_sums_over_alignments_where_greedy_cannot() {
        // classic case: two frames, blank is the single best path but the
        // label accumulates more total probability across alignments
        let classes = 3;
        // frame probs: blank 0.4, a 0.35, b 0.25 (twice)
        let p = [0.4f32, 0.35, 0.25];
        let mut lp = Vec::new();
        for _ in 0..2 {
            lp.extend(p.iter().map(|x| x.ln()));
        }
        let t = Tensor::from_slice(&lp, [2, classes]);
        // greedy: blank,blank -> []
        assert_eq!(greedy_decode(&t), Vec::<usize>::new());
        // beam: P([]) = .4*.4 = .16 ; P([a]) = .35*.35 + 2*.4*.35 = .4025
        let dec = BeamSearchDecoder::new(DecoderOpts { beam: 8, ..Default::default() }, None);
        assert_eq!(dec.decode(&t), vec![1]);
    }

    #[test]
    fn lm_fusion_changes_ranking() {
        // acoustically ambiguous between token 1 and 2 at the second slot;
        // LM strongly prefers (1 -> 2) over (1 -> 1)
        let classes = 3;
        let lp = vec![
            // frame 0: strongly token 1
            0.02f32.ln(), 0.96f32.ln(), 0.02f32.ln(),
            // frame 1: blank
            0.96f32.ln(), 0.02f32.ln(), 0.02f32.ln(),
            // frame 2: moderate edge to token 1 over token 2
            0.02f32.ln(), 0.60f32.ln(), 0.38f32.ln(),
        ];
        let t = Tensor::from_slice(&lp, [3, classes]);
        let no_lm = BeamSearchDecoder::new(DecoderOpts { beam: 8, ..Default::default() }, None);
        assert_eq!(no_lm.decode(&t), vec![1, 1]);
        let lm = NGramLm::train(3, &[vec![1, 2], vec![1, 2], vec![1, 2], vec![1, 1]], 0.05);
        let with_lm = BeamSearchDecoder::new(
            DecoderOpts { beam: 8, lm_weight: 1.0, ..Default::default() },
            Some(lm),
        );
        assert_eq!(with_lm.decode(&t), vec![1, 2], "LM should flip the ambiguous token");
    }

    #[test]
    fn top_n_is_sorted() {
        let lp = peaked(&[(0, 1), (1, 2)], 4);
        let dec = BeamSearchDecoder::new(DecoderOpts { beam: 8, ..Default::default() }, None);
        let hyps = dec.decode_n(&lp, 3);
        assert!(hyps.len() >= 2);
        for w in hyps.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
