//! Token n-gram language model (the decoder "can interface any language
//! model"; this is the reference implementation).

use std::collections::HashMap;

/// Bigram LM with add-k smoothing over integer token ids.
pub struct NGramLm {
    vocab: usize,
    k: f64,
    unigram: Vec<u64>,
    bigram: HashMap<(usize, usize), u64>,
    total: u64,
}

impl NGramLm {
    /// Train from token sequences.
    pub fn train(vocab: usize, sequences: &[Vec<usize>], k: f64) -> Self {
        let mut unigram = vec![0u64; vocab];
        let mut bigram = HashMap::new();
        let mut total = 0u64;
        for seq in sequences {
            for (i, &t) in seq.iter().enumerate() {
                assert!(t < vocab, "token {t} out of vocab {vocab}");
                unigram[t] += 1;
                total += 1;
                if i > 0 {
                    *bigram.entry((seq[i - 1], t)).or_insert(0) += 1;
                }
            }
        }
        NGramLm { vocab, k, unigram, bigram, total }
    }

    /// log P(token | prev); `prev = None` uses the unigram distribution.
    pub fn score_next(&self, prev: Option<usize>, token: usize) -> f64 {
        match prev {
            None => {
                ((self.unigram[token] as f64 + self.k)
                    / (self.total as f64 + self.k * self.vocab as f64))
                    .ln()
            }
            Some(p) => {
                let joint = *self.bigram.get(&(p, token)).unwrap_or(&0) as f64;
                let ctx = self.unigram[p] as f64;
                ((joint + self.k) / (ctx + self.k * self.vocab as f64)).ln()
            }
        }
    }

    /// Total log probability of a sequence.
    pub fn score(&self, seq: &[usize]) -> f64 {
        let mut s = 0.0;
        let mut prev = None;
        for &t in seq {
            s += self.score_next(prev, t);
            prev = Some(t);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequent_bigrams_score_higher() {
        let data = vec![vec![1, 2, 3], vec![1, 2, 4], vec![1, 2, 3]];
        let lm = NGramLm::train(5, &data, 0.1);
        assert!(lm.score_next(Some(1), 2) > lm.score_next(Some(1), 3));
        assert!(lm.score_next(Some(2), 3) > lm.score_next(Some(2), 4));
    }

    #[test]
    fn sequence_score_is_sum() {
        let lm = NGramLm::train(4, &[vec![0, 1, 2]], 0.5);
        let total = lm.score(&[0, 1]);
        let manual = lm.score_next(None, 0) + lm.score_next(Some(0), 1);
        assert!((total - manual).abs() < 1e-12);
    }

    #[test]
    fn smoothing_keeps_unseen_finite() {
        let lm = NGramLm::train(10, &[vec![1, 1]], 0.1);
        assert!(lm.score_next(Some(7), 8).is_finite());
        assert!(lm.score(&[9, 9, 9]).is_finite());
    }
}
