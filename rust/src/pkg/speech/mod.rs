//! Speech package (paper §4.3 "Speech"): on-the-fly featurization,
//! data augmentation, CTC criterion, and a beam-search decoder with
//! n-gram language-model rescoring.

pub mod augment;
pub mod ctc;
pub mod decoder;
pub mod features;
pub mod lm;

pub use augment::additive_noise;
pub use ctc::{ctc_loss, greedy_decode};
pub use decoder::{BeamSearchDecoder, DecoderOpts};
pub use features::{log_mel_spectrogram, FeatureParams};
pub use lm::NGramLm;
