//! Text package (paper §4.3 "Text"): tokenization and language-modeling
//! dataset pipelines (autoregressive and masked).

pub mod lm_data;
pub mod tokenizer;

pub use lm_data::{AutoregressiveLmDataset, MaskedLmBatch};
pub use tokenizer::Tokenizer;
