//! Word-level tokenizer with reserved specials and frequency-ranked vocab.

use std::collections::HashMap;

/// Reserved special tokens.
pub const PAD: usize = 0;
/// Unknown-token id.
pub const UNK: usize = 1;
/// Mask token (masked LM).
pub const MASK: usize = 2;
/// Number of reserved ids.
pub const NUM_SPECIALS: usize = 3;

/// Frequency-ranked word tokenizer.
pub struct Tokenizer {
    vocab: HashMap<String, usize>,
    inverse: Vec<String>,
}

impl Tokenizer {
    /// Build from a corpus keeping the `max_vocab` most frequent words.
    pub fn train(corpus: &str, max_vocab: usize) -> Self {
        let mut counts: HashMap<&str, u64> = HashMap::new();
        for w in corpus.split_whitespace() {
            *counts.entry(w).or_insert(0) += 1;
        }
        let mut ranked: Vec<(&str, u64)> = counts.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        ranked.truncate(max_vocab.saturating_sub(NUM_SPECIALS));
        let mut vocab = HashMap::new();
        let mut inverse = vec!["<pad>".to_string(), "<unk>".to_string(), "<mask>".to_string()];
        for (i, (w, _)) in ranked.iter().enumerate() {
            vocab.insert((*w).to_string(), NUM_SPECIALS + i);
            inverse.push((*w).to_string());
        }
        Tokenizer { vocab, inverse }
    }

    /// Vocabulary size (specials included).
    pub fn vocab_size(&self) -> usize {
        self.inverse.len()
    }

    /// Encode text to ids (unknowns map to `UNK`).
    pub fn encode(&self, text: &str) -> Vec<usize> {
        text.split_whitespace()
            .map(|w| self.vocab.get(w).copied().unwrap_or(UNK))
            .collect()
    }

    /// Decode ids back to text.
    pub fn decode(&self, ids: &[usize]) -> String {
        ids.iter()
            .map(|&i| self.inverse.get(i).map(|s| s.as_str()).unwrap_or("<oob>"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_known_words() {
        let t = Tokenizer::train("the cat sat on the mat the cat", 50);
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
        assert!(ids.iter().all(|&i| i >= NUM_SPECIALS));
    }

    #[test]
    fn unknowns_map_to_unk() {
        let t = Tokenizer::train("a b c", 10);
        assert_eq!(t.encode("zzz")[0], UNK);
        assert_eq!(t.decode(&[UNK]), "<unk>");
    }

    #[test]
    fn vocab_cap_keeps_most_frequent() {
        let t = Tokenizer::train("x x x y y z", NUM_SPECIALS + 2);
        assert_eq!(t.vocab_size(), NUM_SPECIALS + 2);
        assert_ne!(t.encode("x")[0], UNK);
        assert_ne!(t.encode("y")[0], UNK);
        assert_eq!(t.encode("z")[0], UNK); // dropped by cap
    }
}
