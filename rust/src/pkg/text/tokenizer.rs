//! Word-level tokenizer with reserved specials and frequency-ranked vocab.

use std::collections::HashMap;

/// Reserved special tokens.
pub const PAD: usize = 0;
/// Unknown-token id.
pub const UNK: usize = 1;
/// Mask token (masked LM).
pub const MASK: usize = 2;
/// Number of reserved ids.
pub const NUM_SPECIALS: usize = 3;

/// Frequency-ranked word tokenizer.
pub struct Tokenizer {
    vocab: HashMap<String, usize>,
    inverse: Vec<String>,
}

impl Tokenizer {
    /// Build from a corpus keeping the `max_vocab` most frequent words.
    pub fn train(corpus: &str, max_vocab: usize) -> Self {
        let mut counts: HashMap<&str, u64> = HashMap::new();
        for w in corpus.split_whitespace() {
            *counts.entry(w).or_insert(0) += 1;
        }
        let mut ranked: Vec<(&str, u64)> = counts.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        ranked.truncate(max_vocab.saturating_sub(NUM_SPECIALS));
        let mut vocab = HashMap::new();
        let mut inverse = vec!["<pad>".to_string(), "<unk>".to_string(), "<mask>".to_string()];
        for (i, (w, _)) in ranked.iter().enumerate() {
            vocab.insert((*w).to_string(), NUM_SPECIALS + i);
            inverse.push((*w).to_string());
        }
        Tokenizer { vocab, inverse }
    }

    /// Vocabulary size (specials included).
    pub fn vocab_size(&self) -> usize {
        self.inverse.len()
    }

    /// Encode text to ids (unknowns map to `UNK`).
    pub fn encode(&self, text: &str) -> Vec<usize> {
        text.split_whitespace()
            .map(|w| self.vocab.get(w).copied().unwrap_or(UNK))
            .collect()
    }

    /// Decode ids back to text.
    pub fn decode(&self, ids: &[usize]) -> String {
        ids.iter()
            .map(|&i| self.inverse.get(i).map(|s| s.as_str()).unwrap_or("<oob>"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_known_words() {
        let t = Tokenizer::train("the cat sat on the mat the cat", 50);
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
        assert!(ids.iter().all(|&i| i >= NUM_SPECIALS));
    }

    #[test]
    fn unknowns_map_to_unk() {
        let t = Tokenizer::train("a b c", 10);
        assert_eq!(t.encode("zzz")[0], UNK);
        assert_eq!(t.decode(&[UNK]), "<unk>");
    }

    #[test]
    fn encode_decode_roundtrip_is_stable() {
        let t = Tokenizer::train("to be or not to be that is the question", 64);
        let text = "to be or not to be";
        let ids = t.encode(text);
        assert_eq!(t.decode(&ids), text);
        // a second encode of the decoded text is idempotent
        assert_eq!(t.encode(&t.decode(&ids)), ids);
        // whitespace normalizes away: tabs and runs of spaces don't change ids
        assert_eq!(t.encode("to\tbe   or not\nto be"), ids);
    }

    #[test]
    fn oov_roundtrip_degrades_to_unk_in_place() {
        let t = Tokenizer::train("the cat sat", 50);
        let ids = t.encode("the dog sat");
        assert_eq!(ids.len(), 3);
        assert_eq!(ids[1], UNK, "unseen word must map to UNK");
        assert_ne!(ids[0], UNK);
        assert_ne!(ids[2], UNK);
        // decode keeps position: known words survive, the OOV shows as <unk>
        assert_eq!(t.decode(&ids), "the <unk> sat");
        // ids past the vocabulary decode to a visible marker, never panic
        assert_eq!(t.decode(&[t.vocab_size() + 7]), "<oob>");
        // specials decode to their reserved spellings
        assert_eq!(t.decode(&[PAD, UNK, MASK]), "<pad> <unk> <mask>");
    }

    #[test]
    fn vocab_size_is_stable_across_retrains() {
        let corpus = "a quick brown fox jumps over a lazy dog a quick fox";
        let t1 = Tokenizer::train(corpus, 100);
        let t2 = Tokenizer::train(corpus, 100);
        // same corpus -> same size and the same id assignment (ranking is
        // count-then-lexicographic, so HashMap iteration order cannot leak)
        assert_eq!(t1.vocab_size(), t2.vocab_size());
        assert_eq!(t1.encode(corpus), t2.encode(corpus));
        // size accounts for every distinct word plus the reserved specials
        let distinct = 8; // a quick brown fox jumps over lazy dog
        assert_eq!(t1.vocab_size(), distinct + NUM_SPECIALS);
        // and is capped exactly at max_vocab when the corpus overflows it
        let capped = Tokenizer::train(corpus, NUM_SPECIALS + 3);
        assert_eq!(capped.vocab_size(), NUM_SPECIALS + 3);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let t = Tokenizer::train("x y z", 10);
        assert!(t.encode("").is_empty());
        assert!(t.encode("   \n\t ").is_empty());
        assert_eq!(t.decode(&[]), "");
        // a cap smaller than the specials still yields a well-formed
        // specials-only vocabulary
        let tiny = Tokenizer::train("x y z", 2);
        assert_eq!(tiny.vocab_size(), NUM_SPECIALS);
        assert_eq!(tiny.encode("x")[0], UNK);
    }

    #[test]
    fn vocab_cap_keeps_most_frequent() {
        let t = Tokenizer::train("x x x y y z", NUM_SPECIALS + 2);
        assert_eq!(t.vocab_size(), NUM_SPECIALS + 2);
        assert_ne!(t.encode("x")[0], UNK);
        assert_ne!(t.encode("y")[0], UNK);
        assert_eq!(t.encode("z")[0], UNK); // dropped by cap
    }
}
