//! Language-modeling dataset pipelines: autoregressive windows and
//! BERT-style masked-LM batches (paper §4.3: "both autoregressive and
//! masked ... language modeling tasks are supported").

use std::sync::Arc;

use crate::data::{Dataset, Sample};
use crate::tensor::{DType, Tensor};
use crate::util::rng::Rng;

use super::tokenizer::MASK;

/// Sliding windows of `seq_len + 1` tokens over a flat id stream; each
/// sample is one `[1, seq_len+1]` window (input = `[..-1]`, target =
/// `[1..]` at loss time).
pub struct AutoregressiveLmDataset {
    ids: Arc<Vec<i64>>,
    seq_len: usize,
    stride: usize,
}

impl AutoregressiveLmDataset {
    /// Windows with the given stride.
    pub fn new(ids: Vec<usize>, seq_len: usize, stride: usize) -> Self {
        AutoregressiveLmDataset {
            ids: Arc::new(ids.into_iter().map(|i| i as i64).collect()),
            seq_len,
            stride: stride.max(1),
        }
    }
}

impl Dataset for AutoregressiveLmDataset {
    fn len(&self) -> usize {
        let window = self.seq_len + 1;
        if self.ids.len() < window {
            0
        } else {
            (self.ids.len() - window) / self.stride + 1
        }
    }

    fn get(&self, i: usize) -> Sample {
        let start = i * self.stride;
        let window = &self.ids[start..start + self.seq_len + 1];
        vec![Tensor::from_slice(window, [1, self.seq_len + 1])]
    }
}

/// One masked-LM batch: `input` with ~`mask_prob` positions replaced by
/// `<mask>`, plus `labels` (original ids at masked positions, -100
/// elsewhere, HF convention).
pub struct MaskedLmBatch {
    /// Corrupted inputs `[N, L]` (i64).
    pub input: Tensor,
    /// Labels `[N, L]` (i64; -100 = unmasked).
    pub labels: Tensor,
}

impl MaskedLmBatch {
    /// Corrupt a batch of token ids.
    pub fn make(ids: &Tensor, mask_prob: f64, rng: &mut Rng) -> MaskedLmBatch {
        let dims = ids.dims().to_vec();
        let flat = ids.to_vec_i64();
        let mut input = flat.clone();
        let mut labels = vec![-100i64; flat.len()];
        for i in 0..flat.len() {
            if rng.uniform() < mask_prob {
                labels[i] = flat[i];
                input[i] = MASK as i64;
            }
        }
        MaskedLmBatch {
            input: Tensor::from_slice(&input, dims.clone()).astype(DType::I64),
            labels: Tensor::from_slice(&labels, dims).astype(DType::I64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_cover_stream() {
        let ds = AutoregressiveLmDataset::new((0..20).collect(), 4, 5);
        assert_eq!(ds.len(), 4); // windows at 0,5,10,15 (len 5 each)
        let s = ds.get(1);
        assert_eq!(s[0].to_vec_i64(), vec![5, 6, 7, 8, 9]);
    }

    #[test]
    fn too_short_stream_is_empty() {
        let ds = AutoregressiveLmDataset::new(vec![1, 2], 4, 1);
        assert_eq!(ds.len(), 0);
    }

    #[test]
    fn masking_rate_and_labels() {
        let mut rng = Rng::new(3);
        let ids = Tensor::from_slice(&vec![7i64; 2000], [4, 500]);
        let b = MaskedLmBatch::make(&ids, 0.15, &mut rng);
        let inp = b.input.to_vec_i64();
        let lab = b.labels.to_vec_i64();
        let masked = inp.iter().filter(|&&t| t == MASK as i64).count();
        let rate = masked as f64 / inp.len() as f64;
        assert!((rate - 0.15).abs() < 0.03, "mask rate {rate}");
        for (i, l) in inp.iter().zip(&lab) {
            if *i == MASK as i64 {
                assert_eq!(*l, 7);
            } else {
                assert_eq!(*l, -100);
            }
        }
    }
}
