//! The AOT/XLA hybrid backend (paper Figure 2's "static" computation mode,
//! §4.1.1's hybrid vendor-offload strategy).
//!
//! Implements [`DelegateBackend`] over the reference CPU backend,
//! overriding the hot operations: `matmul` (and the `call_ext` fused ops
//! `linear_gelu` / `attention` / `layernorm` / `transformer_block`)
//! dispatch to AOT-compiled PJRT executables authored in JAX + Pallas at
//! build time. Shapes without a matching artifact silently fall back to
//! the composed CPU path, so the backend is always correct and
//! incrementally fast.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::cpu::CpuBackend;
use super::delegate::DelegateBackend;
use super::{DType, Tensor, TensorBackend};
use crate::runtime::PjrtRuntime;
use crate::util::error::Result;

/// See module docs.
pub struct XlaBackend {
    inner: Arc<dyn TensorBackend>,
    runtime: Arc<PjrtRuntime>,
    /// Ops served by PJRT executables.
    pub offloaded: AtomicU64,
    /// Ops that fell back to the CPU composition.
    pub fallbacks: AtomicU64,
}

impl XlaBackend {
    /// Build over the global PJRT runtime; `None` if `artifacts/` is
    /// absent (run `make artifacts`).
    pub fn from_global_runtime() -> Option<Arc<XlaBackend>> {
        let runtime = PjrtRuntime::global()?;
        Some(Arc::new(XlaBackend {
            inner: CpuBackend::shared(),
            runtime,
            offloaded: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
        }))
    }

    /// (offloaded, fallback) dispatch counts.
    pub fn counts(&self) -> (u64, u64) {
        (self.offloaded.load(Ordering::Relaxed), self.fallbacks.load(Ordering::Relaxed))
    }

    fn try_offload(&self, op: &str, inputs: &[&Tensor]) -> Option<Tensor> {
        // artifact path is f32-only
        if inputs.iter().any(|t| t.dtype() != DType::F32) {
            return None;
        }
        let shapes: Vec<&super::Shape> = inputs.iter().map(|t| t.shape()).collect();
        let exe = self.runtime.lookup(op, &shapes)?;
        match self.runtime.execute(&exe, inputs) {
            Ok(t) => {
                self.offloaded.fetch_add(1, Ordering::Relaxed);
                Some(t)
            }
            Err(_) => None,
        }
    }
}

impl DelegateBackend for XlaBackend {
    fn inner(&self) -> Arc<dyn TensorBackend> {
        self.inner.clone()
    }

    fn wrapper_name(&self) -> &str {
        "xla-aot"
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        if let Some(out) = self.try_offload("matmul", &[a, b]) {
            return out;
        }
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
        self.inner.matmul(a, b)
    }

    fn call_ext(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        if let Some(out) = self.try_offload(name, inputs) {
            return Ok(out);
        }
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
        self.inner.call_ext(name, inputs)
    }
}

crate::impl_delegate_backend!(XlaBackend);

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> Option<Arc<XlaBackend>> {
        let be = XlaBackend::from_global_runtime();
        if be.is_none() {
            eprintln!("skipping: artifacts/ not built");
        }
        be
    }

    #[test]
    fn matmul_offloads_on_artifact_shapes() {
        let Some(be) = backend() else { return };
        crate::util::rng::seed(9);
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -1.0, 1.0);
        let via_xla = TensorBackend::matmul(be.as_ref(), &x, &w);
        let via_cpu = x.matmul(&w);
        assert!(via_xla.allclose(&via_cpu, 1e-3, 1e-3));
        assert!(be.counts().0 >= 1, "expected offload");
    }

    #[test]
    fn unmatched_shapes_fall_back() {
        let Some(be) = backend() else { return };
        let x = Tensor::rand([3, 5], -1.0, 1.0);
        let w = Tensor::rand([5, 7], -1.0, 1.0);
        let out = TensorBackend::matmul(be.as_ref(), &x, &w);
        assert_eq!(out.dims(), &[3, 7]);
        assert!(be.counts().1 >= 1, "expected fallback");
    }

    #[test]
    fn fused_ext_linear_gelu() {
        let Some(be) = backend() else { return };
        crate::util::rng::seed(10);
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -0.1, 0.1);
        let b = Tensor::rand([256], -0.1, 0.1);
        let fused = TensorBackend::call_ext(be.as_ref(), "linear_gelu", &[&x, &w, &b]).unwrap();
        let composed = x.matmul(&w).add(&b).gelu();
        assert!(fused.allclose(&composed, 1e-4, 1e-4));
    }

    #[test]
    fn installs_as_default_backend() {
        let Some(be) = backend() else { return };
        let _guard = crate::tensor::BackendGuard::install(be.clone());
        // whole-framework dispatch picks it up (paper §5.2.4 swap)
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -1.0, 1.0);
        let before = be.counts().0;
        let _ = x.matmul(&w);
        assert!(be.counts().0 > before);
    }
}
