//! The AOT/XLA hybrid backend (paper Figure 2's "static" computation mode,
//! §4.1.1's hybrid vendor-offload strategy).
//!
//! A single [`Interposer`] over the reference CPU backend: the intercept
//! function matches the hot operations — [`Op::Matmul`] and the
//! [`Op::CallExt`] fused ops `linear_gelu` / `attention` / `layernorm` /
//! `transformer_block` — and dispatches them to AOT-compiled PJRT
//! executables authored in JAX + Pallas at build time. Shapes without a
//! matching artifact silently fall back to the composed CPU path, so the
//! backend is always correct and incrementally fast.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::cpu::CpuBackend;
use super::interpose::{InterposedBackend, Interposer};
use super::op::Op;
use super::{DType, Tensor, TensorBackend};
use crate::runtime::PjrtRuntime;
use crate::util::error::Result;

/// The offload policy (see module docs): tries PJRT for hot ops, counts
/// what it serves and what falls back.
pub struct XlaOffload {
    runtime: Arc<PjrtRuntime>,
    /// Ops served by PJRT executables.
    pub offloaded: AtomicU64,
    /// Hot ops that fell back to the CPU composition.
    pub fallbacks: AtomicU64,
}

impl XlaOffload {
    fn try_offload(&self, op: &str, inputs: &[&Tensor]) -> Option<Tensor> {
        // artifact path is f32-only
        if inputs.iter().any(|t| t.dtype() != DType::F32) {
            return None;
        }
        let shapes: Vec<&super::Shape> = inputs.iter().map(|t| t.shape()).collect();
        let exe = self.runtime.lookup(op, &shapes)?;
        match self.runtime.execute(&exe, inputs) {
            Ok(t) => {
                self.offloaded.fetch_add(1, Ordering::Relaxed);
                Some(t)
            }
            Err(_) => None,
        }
    }
}

impl Interposer for XlaOffload {
    fn name(&self) -> &str {
        "xla-aot"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        let hot = match op {
            Op::Matmul => Some("matmul"),
            Op::CallExt { name } => Some(name.as_str()),
            _ => None,
        };
        if let Some(kernel) = hot {
            if let Some(out) = self.try_offload(kernel, inputs) {
                return Ok(out);
            }
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
        }
        inner.dispatch(op, inputs)
    }
}

/// See module docs.
pub type XlaBackend = InterposedBackend<XlaOffload>;

impl XlaBackend {
    /// Build over the global PJRT runtime; `None` if `artifacts/` is
    /// absent (run `make artifacts`).
    pub fn from_global_runtime() -> Option<Arc<XlaBackend>> {
        let runtime = PjrtRuntime::global()?;
        Some(InterposedBackend::new(
            XlaOffload {
                runtime,
                offloaded: AtomicU64::new(0),
                fallbacks: AtomicU64::new(0),
            },
            CpuBackend::shared(),
        ))
    }

    /// (offloaded, fallback) dispatch counts.
    pub fn counts(&self) -> (u64, u64) {
        let x = self.interposer();
        (x.offloaded.load(Ordering::Relaxed), x.fallbacks.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> Option<Arc<XlaBackend>> {
        let be = XlaBackend::from_global_runtime();
        if be.is_none() {
            eprintln!("skipping: artifacts/ not built");
        }
        be
    }

    #[test]
    fn matmul_offloads_on_artifact_shapes() {
        let Some(be) = backend() else { return };
        crate::util::rng::seed(9);
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -1.0, 1.0);
        let via_xla = be.matmul(&x, &w);
        let via_cpu = x.matmul(&w);
        assert!(via_xla.allclose(&via_cpu, 1e-3, 1e-3));
        assert!(be.counts().0 >= 1, "expected offload");
    }

    #[test]
    fn unmatched_shapes_fall_back() {
        let Some(be) = backend() else { return };
        let x = Tensor::rand([3, 5], -1.0, 1.0);
        let w = Tensor::rand([5, 7], -1.0, 1.0);
        let out = be.matmul(&x, &w);
        assert_eq!(out.dims(), &[3, 7]);
        assert!(be.counts().1 >= 1, "expected fallback");
    }

    #[test]
    fn fused_ext_linear_gelu() {
        let Some(be) = backend() else { return };
        crate::util::rng::seed(10);
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -0.1, 0.1);
        let b = Tensor::rand([256], -0.1, 0.1);
        let fused = be.call_ext("linear_gelu", &[&x, &w, &b]).unwrap();
        let composed = x.matmul(&w).add(&b).gelu();
        assert!(fused.allclose(&composed, 1e-4, 1e-4));
    }

    #[test]
    fn installs_as_default_backend() {
        let Some(be) = backend() else { return };
        let _guard = crate::tensor::BackendGuard::install(be.clone());
        // whole-framework dispatch picks it up (paper §5.2.4 swap)
        let x = Tensor::rand([32, 256], -1.0, 1.0);
        let w = Tensor::rand([256, 256], -1.0, 1.0);
        let before = be.counts().0;
        let _ = x.matmul(&w);
        assert!(be.counts().0 > before);
    }
}
