//! The `TensorAdapter` interface (paper Listing 1): per-tensor state and
//! metadata attached by a backend implementation.

use std::any::Any;
use std::sync::Arc;

use super::backend::TensorBackend;
use super::dtype::DType;
use super::host::HostBuffer;
use super::shape::Shape;

/// Backend-private per-tensor state: shape, type, and whatever storage /
/// graph-node / device-buffer information the backend needs (paper
/// Listing 1). A [`super::Tensor`] is just a shared handle to one of these.
pub trait TensorAdapter: Send + Sync {
    /// Tensor shape metadata.
    fn shape(&self) -> &Shape;

    /// Element type metadata.
    fn dtype(&self) -> DType;

    /// The backend that owns this tensor (used for op dispatch: ops always
    /// run on the backend of their first operand).
    fn backend(&self) -> Arc<dyn TensorBackend>;

    /// Materialize the value to host memory. For eager backends this is a
    /// copy; for deferred backends this forces evaluation of the pending
    /// graph (paper §4.1.1: "tensor values need only be materialized upon
    /// user request").
    fn to_host(&self) -> HostBuffer;

    /// Downcast hook so a backend can recover its concrete adapter from a
    /// `Tensor` handed back through the public API.
    fn as_any(&self) -> &dyn Any;
}
