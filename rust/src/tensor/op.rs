//! The first-class operation IR: every [`TensorBackend`] primitive,
//! reified as data.
//!
//! [`Op`] encodes the complete primitive surface of the framework — one
//! variant per backend method, carrying the non-tensor payload (shapes,
//! axes, dtypes, conv/pool hyper-parameters) by value. Tensor operands
//! travel alongside as an `&[&Tensor]` slice. Together with
//! [`TensorBackend::dispatch`] this turns every cross-cutting concern
//! (tracing, profiling, fusion, graph capture, overhead modeling) from a
//! ~60-method override chore into a *single function*: wrappers observe
//! the `Op`, then either handle it or forward it.
//!
//! Design rules:
//!
//! - **Ops are pure data.** `Op` is `Clone + PartialEq + Debug`, carries
//!   no backend state, and can be stored, compared, serialized by hand,
//!   or replayed on any backend (see [`super::trace`]).
//! - **The typed methods stay the contract.** [`execute`] is the one
//!   place that maps each variant back to its typed method, so a backend
//!   that only implements the typed surface is automatically complete
//!   under `dispatch`, and a wrapper that only sees `dispatch` observes
//!   the full surface. Adding a variant without routing it is a compile
//!   error (the match below is exhaustive).
//! - **Creation ops take zero tensor inputs.** Their payload (including
//!   the full [`HostBuffer`] for `FromHost`) lives in the variant, which
//!   is what makes captured programs self-contained.
//! - **Every variant needs a static signature.** The graph verifier's
//!   signature table ([`super::graph::signature::infer`]) matches
//!   exhaustively over `Op` with no wildcard arm, exactly like
//!   [`execute`]: adding a variant without declaring its arity, input
//!   constraints, and output shape/dtype rule is a compile error.

use super::backend::{Conv2dParams, Pool2dParams, TensorBackend};
use super::dtype::DType;
use super::host::HostBuffer;
use super::shape::Shape;
use super::Tensor;
use crate::util::error::{Error, Result};

/// A reified backend primitive (see module docs). Variant payloads are the
/// non-tensor arguments of the corresponding [`TensorBackend`] method;
/// tensor operands are passed separately to [`TensorBackend::dispatch`].
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    // ---- creation (zero tensor inputs) -----------------------------------
    /// `full(shape, value, dtype)`.
    Full {
        /// Output shape.
        shape: Shape,
        /// Fill value.
        value: f64,
        /// Output dtype.
        dtype: DType,
    },
    /// `arange(n, dtype)`.
    Arange {
        /// Element count.
        n: usize,
        /// Output dtype.
        dtype: DType,
    },
    /// `rand_uniform(shape, lo, hi, dtype)` — draws from the backend RNG,
    /// so two executions are *not* bit-identical.
    RandUniform {
        /// Output shape.
        shape: Shape,
        /// Inclusive lower bound.
        lo: f64,
        /// Exclusive upper bound.
        hi: f64,
        /// Output dtype.
        dtype: DType,
    },
    /// `rand_normal(shape, mean, std, dtype)` — draws from the backend RNG.
    RandNormal {
        /// Output shape.
        shape: Shape,
        /// Distribution mean.
        mean: f64,
        /// Distribution standard deviation.
        std: f64,
        /// Output dtype.
        dtype: DType,
    },
    /// `from_host(host, shape)` — carries the host data by value so a
    /// captured program is self-contained and replayable.
    FromHost {
        /// The host data.
        host: HostBuffer,
        /// Logical shape.
        shape: Shape,
    },

    // ---- unary (one tensor input) ----------------------------------------
    /// Element-wise negation.
    Neg,
    /// Element-wise absolute value.
    Abs,
    /// Element-wise sign.
    Sign,
    /// Element-wise `e^x`.
    Exp,
    /// Element-wise natural log.
    Log,
    /// Element-wise `ln(1+x)`.
    Log1p,
    /// Element-wise sine.
    Sin,
    /// Element-wise cosine.
    Cos,
    /// Element-wise tanh.
    Tanh,
    /// Element-wise square root.
    Sqrt,
    /// Element-wise `1/sqrt(x)`.
    Rsqrt,
    /// Element-wise `1/x`.
    Reciprocal,
    /// Element-wise floor.
    Floor,
    /// Element-wise ceil.
    Ceil,
    /// Element-wise round.
    Round,
    /// Element-wise Gauss error function.
    Erf,
    /// Element-wise logical not (Bool result).
    LogicalNot,
    /// Element-wise NaN test (Bool result).
    IsNan,
    /// Clamp into `[lo, hi]`.
    Clip {
        /// Lower bound.
        lo: f64,
        /// Upper bound.
        hi: f64,
    },

    // ---- binary (two tensor inputs, broadcasting) --------------------------
    /// Element-wise sum.
    Add,
    /// Element-wise difference.
    Sub,
    /// Element-wise product.
    Mul,
    /// Element-wise quotient.
    Div,
    /// Element-wise power.
    Pow,
    /// Element-wise minimum.
    Minimum,
    /// Element-wise maximum.
    Maximum,
    /// Element-wise remainder.
    Rem,

    // ---- comparison (two tensor inputs, Bool result) ------------------------
    /// Element-wise equality.
    Eq,
    /// Element-wise inequality.
    Neq,
    /// Element-wise `<`.
    Lt,
    /// Element-wise `<=`.
    Le,
    /// Element-wise `>`.
    Gt,
    /// Element-wise `>=`.
    Ge,
    /// Element-wise logical and.
    LogicalAnd,
    /// Element-wise logical or.
    LogicalOr,

    // ---- reductions (one tensor input) ---------------------------------------
    /// Sum over `axes`.
    Sum {
        /// Normalized, deduplicated axes.
        axes: Vec<usize>,
        /// Keep reduced dims as size 1.
        keepdims: bool,
    },
    /// Product over `axes`.
    Prod {
        /// Normalized, deduplicated axes.
        axes: Vec<usize>,
        /// Keep reduced dims as size 1.
        keepdims: bool,
    },
    /// Max over `axes`.
    MaxReduce {
        /// Normalized, deduplicated axes.
        axes: Vec<usize>,
        /// Keep reduced dims as size 1.
        keepdims: bool,
    },
    /// Min over `axes`.
    MinReduce {
        /// Normalized, deduplicated axes.
        axes: Vec<usize>,
        /// Keep reduced dims as size 1.
        keepdims: bool,
    },
    /// Index of the max along `axis` (dtype I64).
    Argmax {
        /// Reduction axis.
        axis: usize,
        /// Keep the reduced dim as size 1.
        keepdims: bool,
    },
    /// Index of the min along `axis` (dtype I64).
    Argmin {
        /// Reduction axis.
        axis: usize,
        /// Keep the reduced dim as size 1.
        keepdims: bool,
    },
    /// Logical any over `axes` (Bool result).
    Any {
        /// Normalized, deduplicated axes.
        axes: Vec<usize>,
        /// Keep reduced dims as size 1.
        keepdims: bool,
    },
    /// Logical all over `axes` (Bool result).
    All {
        /// Normalized, deduplicated axes.
        axes: Vec<usize>,
        /// Keep reduced dims as size 1.
        keepdims: bool,
    },
    /// Inclusive cumulative sum along `axis`.
    Cumsum {
        /// Scan axis.
        axis: usize,
    },

    // ---- linear algebra (two tensor inputs) ------------------------------------
    /// Matrix multiply (see [`TensorBackend::matmul`]).
    Matmul,

    // ---- neural-network primitives ------------------------------------------------
    /// 2-D convolution over `(x, w)`.
    Conv2d(Conv2dParams),
    /// Gradient of conv2d w.r.t. its input, over `(grad_y, w)`.
    Conv2dBwdInput {
        /// Shape of the original input `x`.
        x_shape: Shape,
        /// The forward conv hyper-parameters.
        params: Conv2dParams,
    },
    /// Gradient of conv2d w.r.t. the filter, over `(grad_y, x)`.
    Conv2dBwdFilter {
        /// Shape of the original filter `w`.
        w_shape: Shape,
        /// The forward conv hyper-parameters.
        params: Conv2dParams,
    },
    /// 2-D max/avg pooling over `x`.
    Pool2d(Pool2dParams),
    /// Gradient of pool2d, over `(grad_y, x)`.
    Pool2dBwd(Pool2dParams),

    // ---- data movement ------------------------------------------------------------
    /// Reshape to `shape` (same element count).
    Reshape {
        /// Pre-resolved target shape.
        shape: Shape,
    },
    /// Permute dimensions.
    Transpose {
        /// The permutation.
        perm: Vec<usize>,
    },
    /// Rectangular slice `[starts, ends)` per dimension.
    Slice {
        /// Inclusive start per dim.
        starts: Vec<usize>,
        /// Exclusive end per dim.
        ends: Vec<usize>,
    },
    /// Concatenate all inputs along `axis` (variadic: ≥ 1 input).
    Concat {
        /// Concatenation axis.
        axis: usize,
    },
    /// Constant-pad.
    Pad {
        /// `(before, after)` per dimension.
        pads: Vec<(usize, usize)>,
        /// Fill value.
        value: f64,
    },
    /// Repeat along each dimension.
    Tile {
        /// Repetitions per dim.
        reps: Vec<usize>,
    },
    /// Reverse along the given axes.
    Flip {
        /// Axes to reverse.
        axes: Vec<usize>,
    },
    /// Gather along `axis` by integer indices, over `(x, indices)`.
    IndexSelect {
        /// Gather axis.
        axis: usize,
    },
    /// `out = base; out[indices[i], ...] += src[i, ...]`, over
    /// `(base, indices, src)`.
    ScatterAdd,
    /// Element-wise select, over `(cond, a, b)`.
    WhereCond,
    /// Cast to `dtype`.
    Astype {
        /// Target dtype.
        dtype: DType,
    },
    /// Deep copy.
    Copy,

    // ---- extension point -------------------------------------------------------------
    /// A named fused operation (variadic inputs); backends without a
    /// matching kernel return [`Error::Unsupported`].
    CallExt {
        /// The extension-op name (e.g. `"linear_gelu"`).
        name: String,
    },
}

impl Op {
    /// Every op name, in declaration order. Kept in sync with the enum by
    /// review and enforced by the round-trip test in
    /// `rust/tests/op_dispatch.rs`, which exercises each listed name
    /// through [`TensorBackend::dispatch`]. ([`execute`]'s exhaustive
    /// match is the compile-time guarantee that no variant goes unrouted.)
    pub const ALL_NAMES: &'static [&'static str] = &[
        "full",
        "arange",
        "rand_uniform",
        "rand_normal",
        "from_host",
        "neg",
        "abs",
        "sign",
        "exp",
        "log",
        "log1p",
        "sin",
        "cos",
        "tanh",
        "sqrt",
        "rsqrt",
        "reciprocal",
        "floor",
        "ceil",
        "round",
        "erf",
        "logical_not",
        "isnan",
        "clip",
        "add",
        "sub",
        "mul",
        "div",
        "pow",
        "minimum",
        "maximum",
        "rem",
        "eq",
        "neq",
        "lt",
        "le",
        "gt",
        "ge",
        "logical_and",
        "logical_or",
        "sum",
        "prod",
        "max_reduce",
        "min_reduce",
        "argmax",
        "argmin",
        "any",
        "all",
        "cumsum",
        "matmul",
        "conv2d",
        "conv2d_bwd_input",
        "conv2d_bwd_filter",
        "pool2d",
        "pool2d_bwd",
        "reshape",
        "transpose",
        "slice",
        "concat",
        "pad",
        "tile",
        "flip",
        "index_select",
        "scatter_add",
        "where_cond",
        "astype",
        "copy",
        "call_ext",
    ];

    /// The op's name — identical to the [`TensorBackend`] method it routes
    /// to (profilers and error messages key on this).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Full { .. } => "full",
            Op::Arange { .. } => "arange",
            Op::RandUniform { .. } => "rand_uniform",
            Op::RandNormal { .. } => "rand_normal",
            Op::FromHost { .. } => "from_host",
            Op::Neg => "neg",
            Op::Abs => "abs",
            Op::Sign => "sign",
            Op::Exp => "exp",
            Op::Log => "log",
            Op::Log1p => "log1p",
            Op::Sin => "sin",
            Op::Cos => "cos",
            Op::Tanh => "tanh",
            Op::Sqrt => "sqrt",
            Op::Rsqrt => "rsqrt",
            Op::Reciprocal => "reciprocal",
            Op::Floor => "floor",
            Op::Ceil => "ceil",
            Op::Round => "round",
            Op::Erf => "erf",
            Op::LogicalNot => "logical_not",
            Op::IsNan => "isnan",
            Op::Clip { .. } => "clip",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::Div => "div",
            Op::Pow => "pow",
            Op::Minimum => "minimum",
            Op::Maximum => "maximum",
            Op::Rem => "rem",
            Op::Eq => "eq",
            Op::Neq => "neq",
            Op::Lt => "lt",
            Op::Le => "le",
            Op::Gt => "gt",
            Op::Ge => "ge",
            Op::LogicalAnd => "logical_and",
            Op::LogicalOr => "logical_or",
            Op::Sum { .. } => "sum",
            Op::Prod { .. } => "prod",
            Op::MaxReduce { .. } => "max_reduce",
            Op::MinReduce { .. } => "min_reduce",
            Op::Argmax { .. } => "argmax",
            Op::Argmin { .. } => "argmin",
            Op::Any { .. } => "any",
            Op::All { .. } => "all",
            Op::Cumsum { .. } => "cumsum",
            Op::Matmul => "matmul",
            Op::Conv2d(_) => "conv2d",
            Op::Conv2dBwdInput { .. } => "conv2d_bwd_input",
            Op::Conv2dBwdFilter { .. } => "conv2d_bwd_filter",
            Op::Pool2d(_) => "pool2d",
            Op::Pool2dBwd(_) => "pool2d_bwd",
            Op::Reshape { .. } => "reshape",
            Op::Transpose { .. } => "transpose",
            Op::Slice { .. } => "slice",
            Op::Concat { .. } => "concat",
            Op::Pad { .. } => "pad",
            Op::Tile { .. } => "tile",
            Op::Flip { .. } => "flip",
            Op::IndexSelect { .. } => "index_select",
            Op::ScatterAdd => "scatter_add",
            Op::WhereCond => "where_cond",
            Op::Astype { .. } => "astype",
            Op::Copy => "copy",
            Op::CallExt { .. } => "call_ext",
        }
    }

    /// Expected tensor-input count, or `None` for variadic ops
    /// (`Concat` needs ≥ 1 input, `CallExt` any number).
    pub fn arity(&self) -> Option<usize> {
        match self {
            Op::Full { .. }
            | Op::Arange { .. }
            | Op::RandUniform { .. }
            | Op::RandNormal { .. }
            | Op::FromHost { .. } => Some(0),
            Op::Neg
            | Op::Abs
            | Op::Sign
            | Op::Exp
            | Op::Log
            | Op::Log1p
            | Op::Sin
            | Op::Cos
            | Op::Tanh
            | Op::Sqrt
            | Op::Rsqrt
            | Op::Reciprocal
            | Op::Floor
            | Op::Ceil
            | Op::Round
            | Op::Erf
            | Op::LogicalNot
            | Op::IsNan
            | Op::Clip { .. }
            | Op::Sum { .. }
            | Op::Prod { .. }
            | Op::MaxReduce { .. }
            | Op::MinReduce { .. }
            | Op::Argmax { .. }
            | Op::Argmin { .. }
            | Op::Any { .. }
            | Op::All { .. }
            | Op::Cumsum { .. }
            | Op::Pool2d(_)
            | Op::Reshape { .. }
            | Op::Transpose { .. }
            | Op::Slice { .. }
            | Op::Pad { .. }
            | Op::Tile { .. }
            | Op::Flip { .. }
            | Op::Astype { .. }
            | Op::Copy => Some(1),
            Op::Add
            | Op::Sub
            | Op::Mul
            | Op::Div
            | Op::Pow
            | Op::Minimum
            | Op::Maximum
            | Op::Rem
            | Op::Eq
            | Op::Neq
            | Op::Lt
            | Op::Le
            | Op::Gt
            | Op::Ge
            | Op::LogicalAnd
            | Op::LogicalOr
            | Op::Matmul
            | Op::Conv2d(_)
            | Op::Conv2dBwdInput { .. }
            | Op::Conv2dBwdFilter { .. }
            | Op::Pool2dBwd(_)
            | Op::IndexSelect { .. } => Some(2),
            Op::ScatterAdd | Op::WhereCond => Some(3),
            Op::Concat { .. } | Op::CallExt { .. } => None,
        }
    }
}

/// Route a reified [`Op`] to the corresponding typed [`TensorBackend`]
/// method. This is the body of the default [`TensorBackend::dispatch`]:
/// one exhaustive match, so the compiler proves every variant reaches its
/// typed implementation.
pub fn execute<B: TensorBackend + ?Sized>(
    backend: &B,
    op: &Op,
    inputs: &[&Tensor],
) -> Result<Tensor> {
    if let Some(want) = op.arity() {
        if inputs.len() != want {
            return Err(Error::msg(format!(
                "op `{}` expects {want} tensor input(s), got {}",
                op.name(),
                inputs.len()
            )));
        }
    }
    let out = match op {
        Op::Full { shape, value, dtype } => backend.full(shape, *value, *dtype),
        Op::Arange { n, dtype } => backend.arange(*n, *dtype),
        Op::RandUniform { shape, lo, hi, dtype } => backend.rand_uniform(shape, *lo, *hi, *dtype),
        Op::RandNormal { shape, mean, std, dtype } => {
            backend.rand_normal(shape, *mean, *std, *dtype)
        }
        Op::FromHost { host, shape } => backend.from_host(host.clone(), shape.clone()),
        Op::Neg => backend.neg(inputs[0]),
        Op::Abs => backend.abs(inputs[0]),
        Op::Sign => backend.sign(inputs[0]),
        Op::Exp => backend.exp(inputs[0]),
        Op::Log => backend.log(inputs[0]),
        Op::Log1p => backend.log1p(inputs[0]),
        Op::Sin => backend.sin(inputs[0]),
        Op::Cos => backend.cos(inputs[0]),
        Op::Tanh => backend.tanh(inputs[0]),
        Op::Sqrt => backend.sqrt(inputs[0]),
        Op::Rsqrt => backend.rsqrt(inputs[0]),
        Op::Reciprocal => backend.reciprocal(inputs[0]),
        Op::Floor => backend.floor(inputs[0]),
        Op::Ceil => backend.ceil(inputs[0]),
        Op::Round => backend.round(inputs[0]),
        Op::Erf => backend.erf(inputs[0]),
        Op::LogicalNot => backend.logical_not(inputs[0]),
        Op::IsNan => backend.isnan(inputs[0]),
        Op::Clip { lo, hi } => backend.clip(inputs[0], *lo, *hi),
        Op::Add => backend.add(inputs[0], inputs[1]),
        Op::Sub => backend.sub(inputs[0], inputs[1]),
        Op::Mul => backend.mul(inputs[0], inputs[1]),
        Op::Div => backend.div(inputs[0], inputs[1]),
        Op::Pow => backend.pow(inputs[0], inputs[1]),
        Op::Minimum => backend.minimum(inputs[0], inputs[1]),
        Op::Maximum => backend.maximum(inputs[0], inputs[1]),
        Op::Rem => backend.rem(inputs[0], inputs[1]),
        Op::Eq => backend.eq(inputs[0], inputs[1]),
        Op::Neq => backend.neq(inputs[0], inputs[1]),
        Op::Lt => backend.lt(inputs[0], inputs[1]),
        Op::Le => backend.le(inputs[0], inputs[1]),
        Op::Gt => backend.gt(inputs[0], inputs[1]),
        Op::Ge => backend.ge(inputs[0], inputs[1]),
        Op::LogicalAnd => backend.logical_and(inputs[0], inputs[1]),
        Op::LogicalOr => backend.logical_or(inputs[0], inputs[1]),
        Op::Sum { axes, keepdims } => backend.sum(inputs[0], axes, *keepdims),
        Op::Prod { axes, keepdims } => backend.prod(inputs[0], axes, *keepdims),
        Op::MaxReduce { axes, keepdims } => backend.max_reduce(inputs[0], axes, *keepdims),
        Op::MinReduce { axes, keepdims } => backend.min_reduce(inputs[0], axes, *keepdims),
        Op::Argmax { axis, keepdims } => backend.argmax(inputs[0], *axis, *keepdims),
        Op::Argmin { axis, keepdims } => backend.argmin(inputs[0], *axis, *keepdims),
        Op::Any { axes, keepdims } => backend.any(inputs[0], axes, *keepdims),
        Op::All { axes, keepdims } => backend.all(inputs[0], axes, *keepdims),
        Op::Cumsum { axis } => backend.cumsum(inputs[0], *axis),
        Op::Matmul => backend.matmul(inputs[0], inputs[1]),
        Op::Conv2d(p) => backend.conv2d(inputs[0], inputs[1], *p),
        Op::Conv2dBwdInput { x_shape, params } => {
            backend.conv2d_bwd_input(inputs[0], inputs[1], x_shape, *params)
        }
        Op::Conv2dBwdFilter { w_shape, params } => {
            backend.conv2d_bwd_filter(inputs[0], inputs[1], w_shape, *params)
        }
        Op::Pool2d(p) => backend.pool2d(inputs[0], *p),
        Op::Pool2dBwd(p) => backend.pool2d_bwd(inputs[0], inputs[1], *p),
        Op::Reshape { shape } => backend.reshape(inputs[0], shape),
        Op::Transpose { perm } => backend.transpose(inputs[0], perm),
        Op::Slice { starts, ends } => backend.slice(inputs[0], starts, ends),
        Op::Concat { axis } => {
            if inputs.is_empty() {
                return Err(Error::msg("op `concat` expects at least one tensor input"));
            }
            backend.concat(inputs, *axis)
        }
        Op::Pad { pads, value } => backend.pad(inputs[0], pads, *value),
        Op::Tile { reps } => backend.tile(inputs[0], reps),
        Op::Flip { axes } => backend.flip(inputs[0], axes),
        Op::IndexSelect { axis } => backend.index_select(inputs[0], *axis, inputs[1]),
        Op::ScatterAdd => backend.scatter_add(inputs[0], inputs[1], inputs[2]),
        Op::WhereCond => backend.where_cond(inputs[0], inputs[1], inputs[2]),
        Op::Astype { dtype } => backend.astype(inputs[0], *dtype),
        Op::Copy => backend.copy(inputs[0]),
        Op::CallExt { name } => return backend.call_ext(name, inputs),
    };
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::cpu::CpuBackend;

    #[test]
    fn names_are_unique_and_canonical() {
        let mut seen = std::collections::HashSet::new();
        for n in Op::ALL_NAMES {
            assert!(seen.insert(*n), "duplicate op name `{n}`");
        }
        // spot-check that `name()` agrees with the canonical list
        assert!(Op::ALL_NAMES.contains(&Op::Add.name()));
        assert!(Op::ALL_NAMES.contains(&Op::Matmul.name()));
        assert!(Op::ALL_NAMES.contains(&Op::CallExt { name: "x".into() }.name()));
    }

    #[test]
    fn arity_is_enforced() {
        let be = CpuBackend::shared();
        let t = Tensor::from_slice(&[1.0f32, 2.0], [2]);
        // add wants 2 inputs
        let err = be.dispatch(&Op::Add, &[&t]).unwrap_err();
        assert!(err.to_string().contains("add"), "{err}");
        // concat wants >= 1
        assert!(be.dispatch(&Op::Concat { axis: 0 }, &[]).is_err());
        // creation ops want 0
        assert!(be
            .dispatch(&Op::Arange { n: 3, dtype: DType::I64 }, &[&t])
            .is_err());
    }

    #[test]
    fn dispatch_routes_to_typed_methods() {
        let be = CpuBackend::shared();
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0], [3]);
        let b = Tensor::from_slice(&[10.0f32, 20.0, 30.0], [3]);
        let y = be.dispatch(&Op::Add, &[&a, &b]).unwrap();
        assert_eq!(y.to_vec(), vec![11.0, 22.0, 33.0]);
        let s = be
            .dispatch(&Op::Sum { axes: vec![0], keepdims: false }, &[&y])
            .unwrap();
        assert_eq!(s.item(), 66.0);
        let z = be
            .dispatch(
                &Op::Full { shape: Shape::new(vec![2]), value: 7.0, dtype: DType::F32 },
                &[],
            )
            .unwrap();
        assert_eq!(z.to_vec(), vec![7.0, 7.0]);
    }

    #[test]
    fn call_ext_errors_surface_through_dispatch() {
        let be = CpuBackend::shared();
        let err = be
            .dispatch(&Op::CallExt { name: "no_such_kernel".into() }, &[])
            .unwrap_err();
        assert!(err.to_string().contains("no_such_kernel"), "{err}");
    }
}
