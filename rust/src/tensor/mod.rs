//! The Tensor foundation API (paper §4.1.1).
//!
//! [`Tensor`] is a cheap shared handle to a backend-owned
//! [`adapter::TensorAdapter`]. All operations dispatch through the small
//! [`backend::TensorBackend`] interface; everything beyond that interface
//! (activations, softmax, statistics, …) is derived by composition in this
//! module, so a custom backend retargets the whole framework.
//!
//! The backend surface itself has a single choke point: every primitive
//! is reified as an [`op::Op`] value and executed via
//! [`TensorBackend::dispatch`]. Wrapper backends implement the
//! one-function [`interpose::Interposer`] instead of sixty methods — see
//! [`profile::ProfilingBackend`], [`trace::TraceBackend`], [`lazy`], and
//! [`xla_backend`] for the reference interposers.

pub mod adapter;
pub mod backend;
pub mod cpu;
pub mod dtype;
pub mod graph;
pub mod host;
pub mod index;
pub mod interpose;
pub mod lazy;
pub mod op;
pub mod profile;
pub mod shape;
pub mod trace;
pub mod xla_backend;

use std::sync::Arc;

pub use adapter::TensorAdapter;
pub use backend::{
    default_backend, set_default_backend, BackendGuard, Conv2dParams, Pool2dParams, PoolKind,
    TensorBackend,
};
pub use dtype::{DType, Element};
pub use graph::{
    trace_and_compile, trace_and_compile_many, CompileOptions, CompileReport, CompiledFn,
    CompiledProgram, Diagnostic, DiagnosticKind, SourceSpec, ValueMeta, VerifiedMeta,
};
pub use host::HostBuffer;
pub use interpose::{InterposedBackend, Interposer};
pub use op::Op;
pub use profile::ProfilingBackend;
pub use shape::Shape;
pub use trace::{TraceBackend, TraceProgram, ValueRef};

use crate::util::error::{Error, Result};

/// A multidimensional array handle (paper §2: tensors as first-class
/// objects). Clones share the underlying adapter.
#[derive(Clone)]
pub struct Tensor(Arc<dyn TensorAdapter>);

impl Tensor {
    // ---- construction ---------------------------------------------------

    /// Wrap a backend adapter (backend-implementer API).
    pub fn from_adapter(a: Arc<dyn TensorAdapter>) -> Tensor {
        Tensor(a)
    }

    /// The adapter behind this handle (backend-implementer API).
    pub fn adapter(&self) -> &dyn TensorAdapter {
        self.0.as_ref()
    }

    /// Build from a slice of scalars on the default backend.
    pub fn from_slice<T: Element>(data: &[T], shape: impl Into<Shape>) -> Tensor {
        let shape = shape.into();
        assert_eq!(shape.numel(), data.len(), "shape {shape} != data len {}", data.len());
        let host = match T::DTYPE {
            DType::F32 => HostBuffer::F32(data.iter().map(|x| x.to_f64() as f32).collect()),
            DType::F64 => HostBuffer::F64(data.iter().map(|x| x.to_f64()).collect()),
            DType::I32 => HostBuffer::I32(data.iter().map(|x| x.to_f64() as i32).collect()),
            DType::I64 => HostBuffer::I64(data.iter().map(|x| x.to_f64() as i64).collect()),
            DType::U8 | DType::Bool => {
                HostBuffer::U8(data.iter().map(|x| x.to_f64() as u8).collect(), false)
            }
        };
        default_backend().from_host(host, shape)
    }

    /// Build from host data on the default backend.
    pub fn from_host(host: HostBuffer, shape: impl Into<Shape>) -> Tensor {
        default_backend().from_host(host, shape.into())
    }

    /// All-zeros f32 tensor.
    pub fn zeros(shape: impl Into<Shape>) -> Tensor {
        default_backend().full(&shape.into(), 0.0, DType::F32)
    }

    /// All-ones f32 tensor.
    pub fn ones(shape: impl Into<Shape>) -> Tensor {
        default_backend().full(&shape.into(), 1.0, DType::F32)
    }

    /// Constant-filled tensor.
    pub fn full(shape: impl Into<Shape>, value: f64, dtype: DType) -> Tensor {
        default_backend().full(&shape.into(), value, dtype)
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar_value(value: f64, dtype: DType) -> Tensor {
        default_backend().full(&Shape::scalar(), value, dtype)
    }

    /// `[0, 1, ..., n-1]`.
    pub fn arange(n: usize, dtype: DType) -> Tensor {
        default_backend().arange(n, dtype)
    }

    /// Uniform random in `[lo, hi)`.
    pub fn rand(shape: impl Into<Shape>, lo: f64, hi: f64) -> Tensor {
        default_backend().rand_uniform(&shape.into(), lo, hi, DType::F32)
    }

    /// Standard-normal random (scaled).
    pub fn randn(shape: impl Into<Shape>, mean: f64, std: f64) -> Tensor {
        default_backend().rand_normal(&shape.into(), mean, std, DType::F32)
    }

    /// Identity matrix.
    pub fn eye(n: usize, dtype: DType) -> Tensor {
        // derived by composition: iota == iota^T
        let i = Tensor::arange(n, DType::I64).reshape(&[n as isize, 1]);
        let j = Tensor::arange(n, DType::I64).reshape(&[1, n as isize]);
        i.eq(&j).astype(dtype)
    }

    // ---- metadata ---------------------------------------------------------

    /// Tensor shape.
    pub fn shape(&self) -> &Shape {
        self.0.shape()
    }

    /// Dimension sizes.
    pub fn dims(&self) -> &[usize] {
        self.0.shape().dims()
    }

    /// Size of dimension `axis` (negative wraps).
    pub fn dim(&self, axis: isize) -> usize {
        self.0.shape().dim(axis)
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.shape().rank()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.0.shape().numel()
    }

    /// Element type.
    pub fn dtype(&self) -> DType {
        self.0.dtype()
    }

    /// Owning backend.
    pub fn backend(&self) -> Arc<dyn TensorBackend> {
        self.0.backend()
    }

    // ---- materialization -----------------------------------------------------

    /// Materialize to host memory (forces deferred backends).
    pub fn to_host(&self) -> HostBuffer {
        self.0.to_host()
    }

    /// Materialize as `Vec<f32>`.
    pub fn to_vec(&self) -> Vec<f32> {
        self.to_host().to_f32_vec()
    }

    /// Materialize as `Vec<f64>`.
    pub fn to_vec_f64(&self) -> Vec<f64> {
        self.to_host().to_f64_vec()
    }

    /// Materialize as `Vec<i64>`.
    pub fn to_vec_i64(&self) -> Vec<i64> {
        self.to_host().to_i64_vec()
    }

    /// Extract the single element of a size-1 tensor as f64.
    pub fn item(&self) -> f64 {
        assert_eq!(self.numel(), 1, "item() requires exactly one element, shape {}", self.shape());
        self.to_host().get_f64(0)
    }

    // ---- primitive pass-throughs ------------------------------------------------

    /// Element-wise negation.
    pub fn neg(&self) -> Tensor {
        default_backend().neg(self)
    }
    /// Element-wise absolute value.
    pub fn abs(&self) -> Tensor {
        default_backend().abs(self)
    }
    /// Element-wise sign (−1, 0, +1).
    pub fn sign(&self) -> Tensor {
        default_backend().sign(self)
    }
    /// Element-wise `e^x`.
    pub fn exp(&self) -> Tensor {
        default_backend().exp(self)
    }
    /// Element-wise natural log.
    pub fn log(&self) -> Tensor {
        default_backend().log(self)
    }
    /// Element-wise `ln(1+x)`.
    pub fn log1p(&self) -> Tensor {
        default_backend().log1p(self)
    }
    /// Element-wise sine.
    pub fn sin(&self) -> Tensor {
        default_backend().sin(self)
    }
    /// Element-wise cosine.
    pub fn cos(&self) -> Tensor {
        default_backend().cos(self)
    }
    /// Element-wise tanh.
    pub fn tanh(&self) -> Tensor {
        default_backend().tanh(self)
    }
    /// Element-wise square root.
    pub fn sqrt(&self) -> Tensor {
        default_backend().sqrt(self)
    }
    /// Element-wise `1/sqrt(x)`.
    pub fn rsqrt(&self) -> Tensor {
        default_backend().rsqrt(self)
    }
    /// Element-wise `1/x`.
    pub fn reciprocal(&self) -> Tensor {
        default_backend().reciprocal(self)
    }
    /// Element-wise floor.
    pub fn floor(&self) -> Tensor {
        default_backend().floor(self)
    }
    /// Element-wise ceil.
    pub fn ceil(&self) -> Tensor {
        default_backend().ceil(self)
    }
    /// Element-wise round-half-away-from-zero.
    pub fn round(&self) -> Tensor {
        default_backend().round(self)
    }
    /// Element-wise Gauss error function.
    pub fn erf(&self) -> Tensor {
        default_backend().erf(self)
    }
    /// Element-wise logical not (Bool result).
    pub fn logical_not(&self) -> Tensor {
        default_backend().logical_not(self)
    }
    /// Element-wise NaN test (Bool result).
    pub fn isnan(&self) -> Tensor {
        default_backend().isnan(self)
    }
    /// Clamp values into `[lo, hi]`.
    pub fn clip(&self, lo: f64, hi: f64) -> Tensor {
        default_backend().clip(self, lo, hi)
    }

    /// Element-wise sum (broadcasting).
    pub fn add(&self, other: &Tensor) -> Tensor {
        default_backend().add(self, other)
    }
    /// Element-wise difference (broadcasting).
    pub fn sub(&self, other: &Tensor) -> Tensor {
        default_backend().sub(self, other)
    }
    /// Element-wise product (broadcasting).
    pub fn mul(&self, other: &Tensor) -> Tensor {
        default_backend().mul(self, other)
    }
    /// Element-wise quotient (broadcasting).
    pub fn div(&self, other: &Tensor) -> Tensor {
        default_backend().div(self, other)
    }
    /// Element-wise power (broadcasting).
    pub fn pow(&self, other: &Tensor) -> Tensor {
        default_backend().pow(self, other)
    }
    /// Element-wise minimum (broadcasting).
    pub fn minimum(&self, other: &Tensor) -> Tensor {
        default_backend().minimum(self, other)
    }
    /// Element-wise maximum (broadcasting).
    pub fn maximum(&self, other: &Tensor) -> Tensor {
        default_backend().maximum(self, other)
    }
    /// Element-wise remainder (broadcasting).
    pub fn rem(&self, other: &Tensor) -> Tensor {
        default_backend().rem(self, other)
    }

    /// Element-wise equality (Bool result).
    pub fn eq(&self, other: &Tensor) -> Tensor {
        default_backend().eq(self, other)
    }
    /// Element-wise inequality (Bool result).
    pub fn neq(&self, other: &Tensor) -> Tensor {
        default_backend().neq(self, other)
    }
    /// Element-wise `<` (Bool result).
    pub fn lt(&self, other: &Tensor) -> Tensor {
        default_backend().lt(self, other)
    }
    /// Element-wise `<=` (Bool result).
    pub fn le(&self, other: &Tensor) -> Tensor {
        default_backend().le(self, other)
    }
    /// Element-wise `>` (Bool result).
    pub fn gt(&self, other: &Tensor) -> Tensor {
        default_backend().gt(self, other)
    }
    /// Element-wise `>=` (Bool result).
    pub fn ge(&self, other: &Tensor) -> Tensor {
        default_backend().ge(self, other)
    }
    /// Element-wise logical and.
    pub fn logical_and(&self, other: &Tensor) -> Tensor {
        default_backend().logical_and(self, other)
    }
    /// Element-wise logical or.
    pub fn logical_or(&self, other: &Tensor) -> Tensor {
        default_backend().logical_or(self, other)
    }

    fn norm_axes(&self, axes: &[isize]) -> Vec<usize> {
        if axes.is_empty() {
            return (0..self.rank()).collect();
        }
        let mut v: Vec<usize> = axes.iter().map(|&a| self.shape().normalize_axis(a)).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Sum over `axes` (empty = all).
    pub fn sum(&self, axes: &[isize], keepdims: bool) -> Tensor {
        default_backend().sum(self, &self.norm_axes(axes), keepdims)
    }
    /// Product over `axes` (empty = all).
    pub fn prod(&self, axes: &[isize], keepdims: bool) -> Tensor {
        default_backend().prod(self, &self.norm_axes(axes), keepdims)
    }
    /// Max over `axes` (empty = all).
    pub fn max(&self, axes: &[isize], keepdims: bool) -> Tensor {
        default_backend().max_reduce(self, &self.norm_axes(axes), keepdims)
    }
    /// Min over `axes` (empty = all).
    pub fn min(&self, axes: &[isize], keepdims: bool) -> Tensor {
        default_backend().min_reduce(self, &self.norm_axes(axes), keepdims)
    }
    /// Argmax along `axis`.
    pub fn argmax(&self, axis: isize, keepdims: bool) -> Tensor {
        default_backend().argmax(self, self.shape().normalize_axis(axis), keepdims)
    }
    /// Argmin along `axis`.
    pub fn argmin(&self, axis: isize, keepdims: bool) -> Tensor {
        default_backend().argmin(self, self.shape().normalize_axis(axis), keepdims)
    }
    /// Logical any over `axes`.
    pub fn any(&self, axes: &[isize], keepdims: bool) -> Tensor {
        default_backend().any(self, &self.norm_axes(axes), keepdims)
    }
    /// Logical all over `axes`.
    pub fn all(&self, axes: &[isize], keepdims: bool) -> Tensor {
        default_backend().all(self, &self.norm_axes(axes), keepdims)
    }
    /// Inclusive cumulative sum along `axis`.
    pub fn cumsum(&self, axis: isize) -> Tensor {
        default_backend().cumsum(self, self.shape().normalize_axis(axis))
    }

    /// Matrix product (see [`TensorBackend::matmul`]).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        default_backend().matmul(self, other)
    }

    /// 2-D convolution.
    pub fn conv2d(&self, w: &Tensor, p: Conv2dParams) -> Tensor {
        default_backend().conv2d(self, w, p)
    }
    /// 2-D pooling.
    pub fn pool2d(&self, p: Pool2dParams) -> Tensor {
        default_backend().pool2d(self, p)
    }

    /// Reshape (supports one `-1` wildcard).
    pub fn reshape(&self, dims: &[isize]) -> Tensor {
        let target = self.shape().resolve_reshape(dims).expect("bad reshape");
        default_backend().reshape(self, &target)
    }
    /// Permute dimensions.
    pub fn transpose(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.rank(), "perm rank mismatch");
        default_backend().transpose(self, perm)
    }
    /// Swap the last two dimensions (matrix transpose).
    pub fn t(&self) -> Tensor {
        let r = self.rank();
        assert!(r >= 2, "t() requires rank >= 2");
        let mut perm: Vec<usize> = (0..r).collect();
        perm.swap(r - 2, r - 1);
        self.transpose(&perm)
    }
    /// Rectangular slice `[starts, ends)`.
    pub fn slice(&self, starts: &[usize], ends: &[usize]) -> Tensor {
        default_backend().slice(self, starts, ends)
    }
    /// Slice a single axis, keeping others whole.
    pub fn narrow(&self, axis: isize, start: usize, len: usize) -> Tensor {
        let a = self.shape().normalize_axis(axis);
        let mut starts = vec![0; self.rank()];
        let mut ends = self.dims().to_vec();
        starts[a] = start;
        ends[a] = start + len;
        self.slice(&starts, &ends)
    }
    /// Concatenate along `axis`.
    pub fn concat(xs: &[&Tensor], axis: isize) -> Tensor {
        assert!(!xs.is_empty(), "concat of zero tensors");
        let a = xs[0].shape().normalize_axis(axis);
        default_backend().concat(xs, a)
    }
    /// Stack along a new leading axis.
    pub fn stack(xs: &[&Tensor], axis: isize) -> Tensor {
        let expanded: Vec<Tensor> = xs
            .iter()
            .map(|x| {
                let mut d: Vec<isize> = x.dims().iter().map(|&v| v as isize).collect();
                let a = if axis < 0 { (x.rank() as isize + 1 + axis) as usize } else { axis as usize };
                d.insert(a, 1);
                x.reshape(&d)
            })
            .collect();
        let refs: Vec<&Tensor> = expanded.iter().collect();
        Tensor::concat(&refs, axis)
    }
    /// Constant-pad.
    pub fn pad(&self, pads: &[(usize, usize)], value: f64) -> Tensor {
        default_backend().pad(self, pads, value)
    }
    /// Tile along each dimension.
    pub fn tile(&self, reps: &[usize]) -> Tensor {
        default_backend().tile(self, reps)
    }
    /// Reverse along `axes`.
    pub fn flip(&self, axes: &[isize]) -> Tensor {
        default_backend().flip(self, &self.norm_axes(axes))
    }
    /// Gather along `axis` by 1-D integer `indices`.
    pub fn index_select(&self, axis: isize, indices: &Tensor) -> Tensor {
        default_backend().index_select(self, self.shape().normalize_axis(axis), indices)
    }
    /// `out = self; out[idx[i]] += src[i]` along axis 0.
    pub fn scatter_add(&self, indices: &Tensor, src: &Tensor) -> Tensor {
        default_backend().scatter_add(self, indices, src)
    }
    /// Element-wise select.
    pub fn where_cond(cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
        default_backend().where_cond(cond, a, b)
    }
    /// Cast dtype.
    pub fn astype(&self, dtype: DType) -> Tensor {
        default_backend().astype(self, dtype)
    }
    /// Deep copy.
    pub fn copy(&self) -> Tensor {
        default_backend().copy(self)
    }
    /// Broadcast to a target shape (derived: tile of size-1 dims).
    pub fn broadcast_to(&self, target: impl Into<Shape>) -> Tensor {
        let target = target.into();
        let bshape = self.shape().broadcast(&target).expect("broadcast_to failed");
        assert_eq!(bshape, target, "{} does not broadcast to {}", self.shape(), target);
        // add with zeros of target shape — backends fuse/optimize as they wish
        self.add(&default_backend().full(&target, 0.0, self.dtype()))
    }

    // ---- scalar conveniences -------------------------------------------------

    fn scalar_like(&self, v: f64) -> Tensor {
        default_backend().full(&Shape::scalar(), v, self.dtype())
    }
    /// Add a scalar.
    pub fn add_scalar(&self, v: f64) -> Tensor {
        self.add(&self.scalar_like(v))
    }
    /// Subtract a scalar.
    pub fn sub_scalar(&self, v: f64) -> Tensor {
        self.sub(&self.scalar_like(v))
    }
    /// Multiply by a scalar.
    pub fn mul_scalar(&self, v: f64) -> Tensor {
        self.mul(&self.scalar_like(v))
    }
    /// Divide by a scalar.
    pub fn div_scalar(&self, v: f64) -> Tensor {
        self.div(&self.scalar_like(v))
    }
    /// Raise to a scalar power.
    pub fn pow_scalar(&self, v: f64) -> Tensor {
        self.pow(&self.scalar_like(v))
    }

    // ---- derived operators (composition over the primitive API) ---------------

    /// Rectified linear unit — derived from `maximum` (paper §4.1.1's
    /// canonical composition example).
    pub fn relu(&self) -> Tensor {
        self.maximum(&self.scalar_like(0.0))
    }

    /// Logistic sigmoid.
    pub fn sigmoid(&self) -> Tensor {
        // 1 / (1 + e^-x)
        self.neg().exp().add_scalar(1.0).reciprocal()
    }

    /// Gaussian error linear unit (exact, via erf).
    pub fn gelu(&self) -> Tensor {
        // x * 0.5 * (1 + erf(x / sqrt(2)))
        let inner = self.mul_scalar(1.0 / std::f64::consts::SQRT_2).erf().add_scalar(1.0);
        self.mul(&inner).mul_scalar(0.5)
    }

    /// SiLU / swish.
    pub fn silu(&self) -> Tensor {
        self.mul(&self.sigmoid())
    }

    /// Mean over `axes` (empty = all).
    pub fn mean(&self, axes: &[isize], keepdims: bool) -> Tensor {
        let axes_n = self.norm_axes(axes);
        let count: usize = axes_n.iter().map(|&a| self.dims()[a]).product();
        self.sum(axes, keepdims).div_scalar(count as f64)
    }

    /// Population variance over `axes`.
    pub fn var(&self, axes: &[isize], keepdims: bool) -> Tensor {
        let mu = self.mean(axes, true);
        let centered = self.sub(&mu);
        centered.mul(&centered).mean(axes, keepdims)
    }

    /// Population standard deviation over `axes`.
    pub fn std(&self, axes: &[isize], keepdims: bool) -> Tensor {
        self.var(axes, keepdims).sqrt()
    }

    /// Numerically-stable softmax along `axis`.
    pub fn softmax(&self, axis: isize) -> Tensor {
        let m = self.max(&[axis], true);
        let e = self.sub(&m).exp();
        let s = e.sum(&[axis], true);
        e.div(&s)
    }

    /// Numerically-stable log-softmax along `axis`.
    pub fn log_softmax(&self, axis: isize) -> Tensor {
        let m = self.max(&[axis], true);
        let shifted = self.sub(&m);
        let lse = shifted.exp().sum(&[axis], true).log();
        shifted.sub(&lse)
    }

    /// One-hot encode an integer tensor into `classes` classes (appends a
    /// trailing class dimension; f32 result).
    pub fn one_hot(&self, classes: usize) -> Tensor {
        let mut dims: Vec<isize> = self.dims().iter().map(|&d| d as isize).collect();
        dims.push(1);
        let idx = self.astype(DType::I64).reshape(&dims);
        let mut cshape = vec![1isize; self.rank()];
        cshape.push(classes as isize);
        let cls = Tensor::arange(classes, DType::I64).reshape(&cshape);
        idx.eq(&cls).astype(DType::F32)
    }

    /// Lower-triangular (inclusive) mask of shape `[n, n]`, Bool.
    pub fn tril_mask(n: usize) -> Tensor {
        let i = Tensor::arange(n, DType::I64).reshape(&[n as isize, 1]);
        let j = Tensor::arange(n, DType::I64).reshape(&[1, n as isize]);
        j.le(&i)
    }

    /// Squared L2 norm of all elements (scalar tensor).
    pub fn norm_sq(&self) -> Tensor {
        self.mul(self).sum(&[], false)
    }

    /// Check element-wise closeness with another tensor.
    pub fn allclose(&self, other: &Tensor, atol: f64, rtol: f64) -> bool {
        if self.shape() != other.shape() {
            return false;
        }
        let a = self.to_vec_f64();
        let b = other.to_vec_f64();
        a.iter().zip(&b).all(|(&x, &y)| (x - y).abs() <= atol + rtol * y.abs().max(x.abs()))
    }

    /// Like [`Tensor::allclose`] but returns the worst absolute deviation
    /// for diagnostics.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64> {
        if self.shape() != other.shape() {
            return Err(Error::ShapeMismatch(format!(
                "{} vs {}",
                self.shape(),
                other.shape()
            )));
        }
        let a = self.to_vec_f64();
        let b = other.to_vec_f64();
        Ok(a.iter().zip(&b).map(|(&x, &y)| (x - y).abs()).fold(0.0, f64::max))
    }
}

macro_rules! impl_binop {
    ($trait:ident, $meth:ident) => {
        impl std::ops::$trait<&Tensor> for &Tensor {
            type Output = Tensor;
            fn $meth(self, rhs: &Tensor) -> Tensor {
                Tensor::$meth(self, rhs)
            }
        }
        impl std::ops::$trait<Tensor> for Tensor {
            type Output = Tensor;
            fn $meth(self, rhs: Tensor) -> Tensor {
                Tensor::$meth(&self, &rhs)
            }
        }
        impl std::ops::$trait<f64> for &Tensor {
            type Output = Tensor;
            fn $meth(self, rhs: f64) -> Tensor {
                Tensor::$meth(self, &self.scalar_like(rhs))
            }
        }
    };
}
impl_binop!(Add, add);
impl_binop!(Sub, sub);
impl_binop!(Mul, mul);
impl_binop!(Div, div);

impl std::ops::Neg for &Tensor {
    type Output = Tensor;
    fn neg(self) -> Tensor {
        Tensor::neg(self)
    }
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Tensor(shape={}, dtype={}, backend={})",
            self.shape(),
            self.dtype(),
            default_backend().name()
        )?;
        if self.numel() <= 16 {
            write!(f, " {:?}", self.to_vec_f64())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creation_and_metadata() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], [2, 3]);
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.to_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let z = Tensor::zeros([4]);
        assert_eq!(z.to_vec(), vec![0.0; 4]);
        let o = Tensor::full([2], 7.0, DType::I64);
        assert_eq!(o.to_vec_i64(), vec![7, 7]);
    }

    #[test]
    fn eye_and_arange_composition() {
        let e = Tensor::eye(3, DType::F32);
        assert_eq!(e.to_vec(), vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        let a = Tensor::arange(4, DType::I32);
        assert_eq!(a.to_vec_i64(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn relu_is_composed_from_maximum() {
        let t = Tensor::from_slice(&[-2.0f32, -0.5, 0.0, 3.0], [4]);
        assert_eq!(t.relu().to_vec(), vec![0.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::rand([3, 7], -4.0, 4.0);
        let s = t.softmax(-1);
        let sums = s.sum(&[-1], false).to_vec();
        for v in sums {
            assert!((v - 1.0).abs() < 1e-5, "row sum {v}");
        }
        // log_softmax == log(softmax)
        let ls = t.log_softmax(-1);
        assert!(ls.exp().allclose(&s, 1e-5, 1e-5));
    }

    #[test]
    fn mean_var_std() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [4]);
        assert!((t.mean(&[], false).item() - 2.5).abs() < 1e-6);
        assert!((t.var(&[], false).item() - 1.25).abs() < 1e-6);
        assert!((t.std(&[], false).item() - 1.25f64.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn one_hot_encodes() {
        let t = Tensor::from_slice(&[0i64, 2, 1], [3]);
        let oh = t.one_hot(3);
        assert_eq!(oh.dims(), &[3, 3]);
        assert_eq!(oh.to_vec(), vec![1., 0., 0., 0., 0., 1., 0., 1., 0.]);
    }

    #[test]
    fn tril_mask_shape() {
        let m = Tensor::tril_mask(3);
        assert_eq!(m.dtype(), DType::Bool);
        assert_eq!(m.to_vec(), vec![1., 0., 0., 1., 1., 0., 1., 1., 1.]);
    }

    #[test]
    fn operator_overloads() {
        let a = Tensor::from_slice(&[1.0f32, 2.0], [2]);
        let b = Tensor::from_slice(&[3.0f32, 5.0], [2]);
        assert_eq!((&a + &b).to_vec(), vec![4.0, 7.0]);
        assert_eq!((&b - &a).to_vec(), vec![2.0, 3.0]);
        assert_eq!((&a * &b).to_vec(), vec![3.0, 10.0]);
        assert_eq!((&b / &a).to_vec(), vec![3.0, 2.5]);
        assert_eq!((&a * 2.0).to_vec(), vec![2.0, 4.0]);
        assert_eq!((-&a).to_vec(), vec![-1.0, -2.0]);
    }

    #[test]
    fn narrow_and_stack() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], [2, 3]);
        let n = t.narrow(1, 1, 2);
        assert_eq!(n.dims(), &[2, 2]);
        assert_eq!(n.to_vec(), vec![2.0, 3.0, 5.0, 6.0]);
        let s = Tensor::stack(&[&t, &t], 0);
        assert_eq!(s.dims(), &[2, 2, 3]);
    }

    #[test]
    fn broadcast_to_expands() {
        let t = Tensor::from_slice(&[1.0f32, 2.0], [2, 1]);
        let b = t.broadcast_to([2, 3]);
        assert_eq!(b.to_vec(), vec![1., 1., 1., 2., 2., 2.]);
    }

    #[test]
    fn item_panics_on_non_scalar() {
        let t = Tensor::zeros([2]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.item()));
        assert!(r.is_err());
    }

    #[test]
    fn allclose_and_max_abs_diff() {
        let a = Tensor::from_slice(&[1.0f32, 2.0], [2]);
        let b = Tensor::from_slice(&[1.0f32, 2.0001], [2]);
        assert!(a.allclose(&b, 1e-3, 0.0));
        assert!(!a.allclose(&b, 1e-6, 0.0));
        assert!(a.max_abs_diff(&b).unwrap() < 2e-4);
        assert!(a.max_abs_diff(&Tensor::zeros([3])).is_err());
    }
}
