//! Element types supported by the tensor stack.

/// Tensor element types. `Bool` shares `u8` storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit float (the workhorse type).
    F32,
    /// 64-bit float.
    F64,
    /// 32-bit signed integer.
    I32,
    /// 64-bit signed integer (indices).
    I64,
    /// 8-bit unsigned integer (images).
    U8,
    /// Boolean (stored as u8 ∈ {0,1}).
    Bool,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 => 8,
            DType::U8 | DType::Bool => 1,
        }
    }

    /// Is this a floating-point type?
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F64)
    }

    /// Is this an integer type (incl. bool)?
    pub fn is_int(self) -> bool {
        !self.is_float()
    }

    /// Binary-op result type (NumPy-style promotion, floats dominate).
    pub fn promote(self, other: DType) -> DType {
        use DType::*;
        if self == other {
            return self;
        }
        fn rank(d: DType) -> u8 {
            match d {
                Bool => 0,
                U8 => 1,
                I32 => 2,
                I64 => 3,
                F32 => 4,
                F64 => 5,
            }
        }
        if rank(self) >= rank(other) {
            self
        } else {
            other
        }
    }

    /// Name as shown in debug output.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::U8 => "u8",
            DType::Bool => "bool",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Rust scalar types that map to a [`DType`].
pub trait Element: Copy + Default + Send + Sync + 'static {
    /// The corresponding dtype.
    const DTYPE: DType;
    /// Lossy conversion to f64 (for printing / scalar extraction).
    fn to_f64(self) -> f64;
    /// Lossy conversion from f64 (for fills).
    fn from_f64(v: f64) -> Self;
}

impl Element for f32 {
    const DTYPE: DType = DType::F32;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
}
impl Element for f64 {
    const DTYPE: DType = DType::F64;
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f64(v: f64) -> Self {
        v
    }
}
impl Element for i32 {
    const DTYPE: DType = DType::I32;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as i32
    }
}
impl Element for i64 {
    const DTYPE: DType = DType::I64;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as i64
    }
}
impl Element for u8 {
    const DTYPE: DType = DType::U8;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promotion_lattice() {
        assert_eq!(DType::F32.promote(DType::F64), DType::F64);
        assert_eq!(DType::I64.promote(DType::F32), DType::F32);
        assert_eq!(DType::Bool.promote(DType::U8), DType::U8);
        assert_eq!(DType::I32.promote(DType::I32), DType::I32);
    }

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_of(), 4);
        assert_eq!(DType::Bool.size_of(), 1);
        assert!(DType::F64.is_float());
        assert!(DType::I64.is_int());
    }
}
