//! Indexing helpers: a small `numpy`-like sugar layer over
//! `slice`/`index_select`, mirroring the original library's
//! `tensor(span, range(a, b), idx)` style.

use super::Tensor;

/// One indexing specifier per dimension.
#[derive(Debug, Clone)]
pub enum Index {
    /// The whole dimension (`span`).
    Span,
    /// Half-open range `[start, end)`.
    Range(usize, usize),
    /// A single position (the dimension is kept with size 1).
    At(usize),
}

/// `span` — take the whole dimension.
pub fn span() -> Index {
    Index::Span
}

/// `range(a, b)` — take `[a, b)`.
pub fn range(a: usize, b: usize) -> Index {
    Index::Range(a, b)
}

/// `at(i)` — take position `i` (size-1 dim retained).
pub fn at(i: usize) -> Index {
    Index::At(i)
}

impl Tensor {
    /// Multi-dimensional indexing: one [`Index`] per leading dimension
    /// (trailing dimensions default to `span`).
    pub fn index(&self, ix: &[Index]) -> Tensor {
        assert!(ix.len() <= self.rank(), "too many indices for rank {}", self.rank());
        let dims = self.dims();
        let mut starts = vec![0usize; self.rank()];
        let mut ends = dims.to_vec();
        for (d, spec) in ix.iter().enumerate() {
            match *spec {
                Index::Span => {}
                Index::Range(a, b) => {
                    starts[d] = a;
                    ends[d] = b;
                }
                Index::At(i) => {
                    starts[d] = i;
                    ends[d] = i + 1;
                }
            }
        }
        self.slice(&starts, &ends)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn index_mixes_specs() {
        let t = Tensor::arange(24, DType::F32).reshape(&[2, 3, 4]);
        let s = t.index(&[at(1), range(0, 2)]);
        assert_eq!(s.dims(), &[1, 2, 4]);
        assert_eq!(s.to_vec()[0], 12.0);
        let whole = t.index(&[span(), span(), span()]);
        assert_eq!(whole.to_vec(), t.to_vec());
    }

    #[test]
    fn mnist_style_holdout_split() {
        // the paper's MNIST listing: val = x(span, range(0, kVal))
        let x = Tensor::arange(20, DType::F32).reshape(&[4, 5]);
        let val = x.index(&[span(), range(0, 2)]);
        let train = x.index(&[span(), range(2, 5)]);
        assert_eq!(val.dims(), &[4, 2]);
        assert_eq!(train.dims(), &[4, 3]);
    }
}
