//! Element-wise fusion: collapse chains *and diamonds* of f32
//! element-wise nodes into single [`FusedKernel`] regions that evaluate
//! in one pass over the output with no intermediate buffers.
//!
//! This generalizes (and replaces) the old lazy backend's private
//! `eval_fused` tree walk, with two correctness upgrades:
//!
//! - **Shared subgraphs evaluate once.** A kernel is a step *DAG*, not an
//!   expression tree: a value consumed by two steps is one step, computed
//!   once per element. (The tree walk duplicated shared subtrees in its
//!   RPN program — exponential work on diamond-heavy graphs.)
//! - **No cross-region duplication.** A fusible node consumed by two
//!   different regions (or by a non-fusible op, or requested as a program
//!   output) materializes exactly once as its own region root and enters
//!   the consumers as a plain input.
//!
//! Bit-identity contract: both execution engines apply *exactly* the
//! scalar f32 semantics of the CPU kernels (`kernels::map1`/`map2` with
//! the same `std` float ops), and regions are gated on every participant
//! being provably `F32` via the static verifier's signature inference
//! ([`super::verify::infer_node_meta`] — the same engine that re-checks
//! fusion legality after the fact). The differential fuzzer holds this
//! to bit-for-bit equality.
//!
//! Execution itself lives in [`super::fuse_exec`]: kernels are lowered
//! once into a blockwise [`FusedPlan`] (input access classes + liveness-
//! reused block buffers) — at compile time here in [`fuse`], since the
//! verifier's inference knows every input shape statically — and run as
//! autovectorizable straight-line loops. The original per-element
//! interpretive walk is kept behind `FL_FUSE_INTERP=1` as the
//! differential baseline.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use super::super::cpu;
use super::super::host::HostBuffer;
use super::super::op::Op;
use super::super::shape::Shape;
use super::super::trace::ValueRef;
use super::super::{DType, Tensor, TensorBackend};
use super::fuse_exec::{self, FusedPlan};
use super::{CompileReport, CompiledInstr, Graph, PassReport};
use crate::util::error::{Error, Result};

/// Arity of an op the fused interpreter can evaluate with bit-identical
/// f32 semantics (`None`: not fusible). This is also the lazy backend's
/// deferral predicate — the fusion ISA is a subset of [`Op`].
pub fn fusible_arity(op: &Op) -> Option<usize> {
    match op {
        Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Minimum | Op::Maximum => Some(2),
        Op::Neg
        | Op::Abs
        | Op::Sign
        | Op::Exp
        | Op::Log
        | Op::Tanh
        | Op::Sqrt
        | Op::Clip { .. } => Some(1),
        _ => None,
    }
}

/// Scalar semantics of a fusible unary op — must mirror the CPU backend's
/// f32 kernels exactly (see `cpu/mod.rs`).
pub fn apply1(op: &Op, x: f32) -> f32 {
    match op {
        Op::Neg => -x,
        Op::Abs => x.abs(),
        Op::Sign => {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                0.0
            }
        }
        Op::Exp => x.exp(),
        Op::Log => x.ln(),
        Op::Tanh => x.tanh(),
        Op::Sqrt => x.sqrt(),
        Op::Clip { lo, hi } => x.clamp(*lo as f32, *hi as f32),
        _ => unreachable!("not a fusible unary op: {op:?}"),
    }
}

/// Scalar semantics of a fusible binary op — must mirror the CPU
/// backend's f32 kernels exactly.
pub fn apply2(op: &Op, a: f32, b: f32) -> f32 {
    match op {
        Op::Add => a + b,
        Op::Sub => a - b,
        Op::Mul => a * b,
        Op::Div => a / b,
        Op::Minimum => a.min(b),
        Op::Maximum => a.max(b),
        _ => unreachable!("not a fusible binary op: {op:?}"),
    }
}

/// Where a fused step's operand comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusedArg {
    /// One of the kernel's external inputs.
    Input(usize),
    /// The value of an earlier step (shared steps evaluate once).
    Step(usize),
}

/// One scalar operation inside a fused region.
#[derive(Debug, Clone)]
pub struct FusedStep {
    /// A fusible element-wise [`Op`].
    pub op: Op,
    /// Operand sources (length = `fusible_arity(op)`).
    pub args: Vec<FusedArg>,
}

/// A fused element-wise region: external inputs plus a topologically
/// ordered step DAG. The last step is the region's output.
///
/// Carries a cached blockwise [`FusedPlan`] (see [`super::fuse_exec`]),
/// lowered at compile time by the [`fuse`] pass and rebuilt lazily if the
/// kernel executes under different input shapes. Mutating the public
/// fields directly (as the verifier's mutation tests do) leaves any
/// cached plan stale — such a kernel must be re-verified, not executed.
pub struct FusedKernel {
    /// External operand sources (deduplicated, first-use order).
    pub inputs: Vec<ValueRef>,
    /// The step DAG in evaluation order.
    pub steps: Vec<FusedStep>,
    /// Cached execution plan for the most recent input shapes.
    plan: Mutex<Option<Arc<FusedPlan>>>,
}

/// Lock the plan cache, shrugging off poisoning (the cache holds no
/// invariant a panicked writer could have broken halfway: it is a single
/// `Option` swap).
fn plan_lock(m: &Mutex<Option<Arc<FusedPlan>>>) -> MutexGuard<'_, Option<Arc<FusedPlan>>> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Clone for FusedKernel {
    fn clone(&self) -> Self {
        FusedKernel {
            inputs: self.inputs.clone(),
            steps: self.steps.clone(),
            plan: Mutex::new(plan_lock(&self.plan).clone()),
        }
    }
}

impl std::fmt::Debug for FusedKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FusedKernel")
            .field("inputs", &self.inputs)
            .field("steps", &self.steps)
            .finish()
    }
}

impl FusedKernel {
    /// Build a kernel with an empty plan cache (lowered on [`prepare`] or
    /// first execution).
    ///
    /// [`prepare`]: FusedKernel::prepare
    pub fn new(inputs: Vec<ValueRef>, steps: Vec<FusedStep>) -> FusedKernel {
        FusedKernel { inputs, steps, plan: Mutex::new(None) }
    }

    /// Lower and cache the blockwise plan for the given input shapes (one
    /// per entry of `self.inputs`). Called by the [`fuse`] pass at compile
    /// time; execution under different shapes re-lowers transparently.
    pub fn prepare(&self, in_shapes: &[Shape]) -> Result<()> {
        if in_shapes.len() != self.inputs.len() {
            return Err(Error::msg(format!(
                "fused kernel expects {} inputs, got {} shapes",
                self.inputs.len(),
                in_shapes.len()
            )));
        }
        let mut s = crate::obs::span("fuse.lower");
        s.attr_i64("steps", self.steps.len() as i64);
        let plan = Arc::new(FusedPlan::build(&self.steps, in_shapes)?);
        *plan_lock(&self.plan) = Some(plan);
        Ok(())
    }

    /// The cached plan if it matches these shapes, else a fresh lowering
    /// (cached for the next call).
    fn plan_for(&self, in_shapes: &[Shape]) -> Result<Arc<FusedPlan>> {
        if let Some(p) = plan_lock(&self.plan).as_ref() {
            if p.matches(in_shapes, self.steps.len()) {
                return Ok(p.clone());
            }
        }
        // a cache miss at execution time is a re-lowering worth seeing
        let mut s = crate::obs::span("fuse.lower");
        s.attr_i64("steps", self.steps.len() as i64);
        s.attr_str("when", "execute");
        let plan = Arc::new(FusedPlan::build(&self.steps, in_shapes)?);
        *plan_lock(&self.plan) = Some(plan.clone());
        Ok(plan)
    }

    /// Evaluate the region in a single pass. Inputs must broadcast to a
    /// common shape; per output element, every step is computed exactly
    /// once, in f32, with the CPU backend's scalar semantics. The result
    /// materializes through `backend.from_host`.
    ///
    /// Runs the blockwise engine by default; `FL_FUSE_INTERP=1` forces
    /// the per-element interpreted walk (bit-identical by contract — see
    /// [`super::fuse_exec`]).
    pub fn execute(&self, backend: &dyn TensorBackend, inputs: &[&Tensor]) -> Result<Tensor> {
        if fuse_exec::interpreter_forced() {
            self.execute_interpreted(backend, inputs)
        } else {
            self.execute_blockwise(backend, inputs)
        }
    }

    /// Evaluate with the blockwise engine (the default path).
    pub fn execute_blockwise(
        &self,
        backend: &dyn TensorBackend,
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        self.execute_with(backend, inputs, fuse_exec::run_blockwise)
    }

    /// Evaluate with the per-element interpreted walk (differential
    /// baseline).
    pub fn execute_interpreted(
        &self,
        backend: &dyn TensorBackend,
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        self.execute_with(backend, inputs, fuse_exec::run_interpreted)
    }

    fn execute_with(
        &self,
        backend: &dyn TensorBackend,
        inputs: &[&Tensor],
        run: fn(&[FusedStep], &FusedPlan, &[&[f32]], &mut [f32]),
    ) -> Result<Tensor> {
        if inputs.len() != self.inputs.len() {
            return Err(Error::msg(format!(
                "fused kernel expects {} inputs, got {}",
                self.inputs.len(),
                inputs.len()
            )));
        }
        for t in inputs {
            if t.dtype() != DType::F32 {
                return Err(Error::msg(format!(
                    "fused kernel input must be f32, got {}",
                    t.dtype().name()
                )));
            }
        }
        let in_shapes: Vec<Shape> = inputs.iter().map(|t| t.shape().clone()).collect();
        let plan = self.plan_for(&in_shapes)?;
        let out_shape = plan.out_shape().clone();
        let n = out_shape.numel();
        if n == 0 {
            return Ok(backend.from_host(HostBuffer::F32(Vec::new()), out_shape));
        }
        // borrow input storage in place — zero-copy when the tensors are
        // already CPU-resident (foreign backends convert through host)
        let cpus: Vec<cpu::CpuTensor> = inputs.iter().map(|t| cpu::cpu(t)).collect();
        let mut bufs: Vec<&[f32]> = Vec::with_capacity(cpus.len());
        for c in &cpus {
            match &*c.storage {
                cpu::Storage::F32(v) => bufs.push(v.as_slice()),
                _ => return Err(Error::msg("fused kernel input storage is not f32")),
            }
        }
        let mut out = vec![0f32; n];
        run(&self.steps, &plan, &bufs, &mut out);
        Ok(backend.from_host(HostBuffer::F32(out), out_shape))
    }
}

/// Fusion pass: cluster fusible nodes into single-output regions and
/// lower the graph to [`CompiledInstr`]s. Regions of a single node stay
/// plain ops (a one-op kernel is pure overhead). Returns the instruction
/// list and the remapped output references.
pub(crate) fn fuse(g: &Graph, report: &mut CompileReport) -> (Vec<CompiledInstr>, Vec<ValueRef>) {
    let n = g.nodes.len();
    // one inference engine: the verifier's per-op signature table (a node
    // only fuses when it is *provably* f32, inputs included)
    let metas = super::verify::infer_node_meta(g);
    let meta_f32 = |i: usize| metas[i].as_ref().is_some_and(|m| m.dtype == DType::F32);
    let is_f32 = |r: &ValueRef| match r {
        ValueRef::Const(c) => g.consts[*c].dtype() == DType::F32,
        ValueRef::Out(i) => meta_f32(*i),
    };
    let fusible: Vec<bool> = g
        .nodes
        .iter()
        .enumerate()
        .map(|(i, node)| {
            fusible_arity(&node.op) == Some(node.inputs.len())
                && meta_f32(i)
                && node.inputs.iter().all(is_f32)
        })
        .collect();
    let consumers = g.consumers();
    let is_out = g.output_mask();

    // cluster in reverse topological order: a fusible node is absorbed
    // into a region iff it is not an output and *all* its consumers sit
    // in that one region; otherwise it roots a region of its own
    let mut region_of: Vec<Option<usize>> = vec![None; n];
    let mut region_members: Vec<Vec<usize>> = Vec::new();
    for i in (0..n).rev() {
        if !fusible[i] {
            continue;
        }
        let all_same_region = (!is_out[i] && !consumers[i].is_empty())
            .then(|| {
                let r0 = region_of[consumers[i][0]]?;
                consumers[i].iter().all(|&c| region_of[c] == Some(r0)).then_some(r0)
            })
            .flatten();
        match all_same_region {
            Some(r) => {
                region_of[i] = Some(r);
                region_members[r].push(i);
            }
            None => {
                region_of[i] = Some(region_members.len());
                region_members.push(vec![i]);
            }
        }
    }
    // single-node regions revert to plain dispatch
    for members in &region_members {
        if members.len() == 1 {
            region_of[members[0]] = None;
        }
    }

    // lower: members collapse into their root's position; everything else
    // keeps its relative order. old node index -> new instr index
    let root_of = |r: usize| region_members[r][0]; // reverse order: first pushed = root (max index)
    let mut new_index: Vec<Option<usize>> = vec![None; n];
    let mut old_of_new: Vec<usize> = Vec::new(); // new instr index -> old node index
    let mut instrs: Vec<CompiledInstr> = Vec::new();
    let mut fused_ops = 0usize;
    for i in 0..n {
        let interior = region_of[i].is_some_and(|r| root_of(r) != i);
        if interior {
            continue;
        }
        let remap = |r: &ValueRef, new_index: &[Option<usize>]| match r {
            ValueRef::Out(j) => ValueRef::Out(new_index[*j].expect("fuse: ref to interior node")),
            c => *c,
        };
        match region_of[i] {
            Some(region) => {
                // build the kernel from members in topological order
                let mut members = region_members[region].clone();
                members.sort_unstable();
                let step_of: HashMap<usize, usize> =
                    members.iter().enumerate().map(|(s, &m)| (m, s)).collect();
                let mut inputs: Vec<ValueRef> = Vec::new();
                let mut steps: Vec<FusedStep> = Vec::new();
                for &m in &members {
                    let args: Vec<FusedArg> = g.nodes[m]
                        .inputs
                        .iter()
                        .map(|r| {
                            if let ValueRef::Out(j) = r {
                                if let Some(&s) = step_of.get(j) {
                                    return FusedArg::Step(s);
                                }
                            }
                            let ext = remap(r, &new_index);
                            let pos = match inputs.iter().position(|x| *x == ext) {
                                Some(p) => p,
                                None => {
                                    inputs.push(ext);
                                    inputs.len() - 1
                                }
                            };
                            FusedArg::Input(pos)
                        })
                        .collect();
                    steps.push(FusedStep { op: g.nodes[m].op.clone(), args });
                }
                fused_ops += steps.len();
                let kernel = FusedKernel::new(inputs, steps);
                // lower the blockwise plan now, at compile time: the
                // verifier's inference knows every input's shape
                // statically (consts carry theirs). A missing meta or a
                // lowering error just defers to first-execute, where any
                // genuine shape error resurfaces as a typed Error.
                let in_shapes: Option<Vec<Shape>> = kernel
                    .inputs
                    .iter()
                    .map(|r| match r {
                        ValueRef::Const(c) => Some(g.consts[*c].shape().clone()),
                        ValueRef::Out(j) => {
                            metas[old_of_new[*j]].as_ref().map(|m| m.shape.clone())
                        }
                    })
                    .collect();
                if let Some(shapes) = in_shapes {
                    kernel.prepare(&shapes).ok();
                }
                new_index[i] = Some(instrs.len());
                old_of_new.push(i);
                instrs.push(CompiledInstr::Fused(kernel));
            }
            None => {
                let inputs: Vec<ValueRef> =
                    g.nodes[i].inputs.iter().map(|r| remap(r, &new_index)).collect();
                new_index[i] = Some(instrs.len());
                old_of_new.push(i);
                instrs.push(CompiledInstr::Op { op: g.nodes[i].op.clone(), inputs });
            }
        }
    }
    let outputs: Vec<ValueRef> = g
        .outputs
        .iter()
        .map(|r| match r {
            ValueRef::Out(j) => ValueRef::Out(new_index[*j].expect("fuse: output was fused away")),
            c => *c,
        })
        .collect();
    report.passes.push(PassReport {
        pass: "fuse",
        ops_before: n,
        ops_after: instrs.len(),
        changed: fused_ops.saturating_sub(region_members.iter().filter(|m| m.len() > 1).count()),
    });
    (instrs, outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::cpu::CpuBackend;

    #[test]
    fn kernel_evaluates_diamond_once_per_element() {
        // e = exp(x); out = (e + y) * (e - y): e is one shared step
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0), ValueRef::Const(1)],
            vec![
                FusedStep { op: Op::Exp, args: vec![FusedArg::Input(0)] },
                FusedStep { op: Op::Add, args: vec![FusedArg::Step(0), FusedArg::Input(1)] },
                FusedStep { op: Op::Sub, args: vec![FusedArg::Step(0), FusedArg::Input(1)] },
                FusedStep { op: Op::Mul, args: vec![FusedArg::Step(1), FusedArg::Step(2)] },
            ],
        );
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[0.0f32, 1.0], [2]);
        let y = Tensor::from_slice(&[0.5f32, 2.0], [2]);
        let out = kernel.execute(cpu.as_ref(), &[&x, &y]).unwrap();
        let expect: Vec<f32> = [(0.0f32, 0.5f32), (1.0, 2.0)]
            .iter()
            .map(|&(x, y)| (x.exp() + y) * (x.exp() - y))
            .collect();
        assert_eq!(out.to_vec(), expect);
    }

    #[test]
    fn kernel_broadcasts_like_the_eager_backend() {
        // [2,1] + [1,3] inside the region -> [2,3]
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0), ValueRef::Const(1)],
            vec![FusedStep { op: Op::Add, args: vec![FusedArg::Input(0), FusedArg::Input(1)] }],
        );
        let cpu = CpuBackend::shared();
        let a = Tensor::from_slice(&[1.0f32, 2.0], [2, 1]);
        let b = Tensor::from_slice(&[10.0f32, 20.0, 30.0], [1, 3]);
        let fused = kernel.execute(cpu.as_ref(), &[&a, &b]).unwrap();
        let eager = cpu.add(&a, &b);
        assert_eq!(fused.dims(), eager.dims());
        assert_eq!(fused.to_vec(), eager.to_vec());
    }

    #[test]
    fn non_f32_inputs_are_rejected() {
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0)],
            vec![FusedStep { op: Op::Neg, args: vec![FusedArg::Input(0)] }],
        );
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[1i64, 2], [2]);
        assert!(kernel.execute(cpu.as_ref(), &[&x]).is_err());
    }

    #[test]
    fn mismatched_input_count_is_a_typed_error() {
        // release builds used to misindex here: the arity check was a
        // debug_assert that compiled away
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0), ValueRef::Const(1)],
            vec![FusedStep { op: Op::Add, args: vec![FusedArg::Input(0), FusedArg::Input(1)] }],
        );
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[1.0f32], [1]);
        let err = kernel.execute(cpu.as_ref(), &[&x]).unwrap_err();
        assert!(err.to_string().contains("expects 2 inputs, got 1"), "{err}");
    }

    #[test]
    fn empty_kernel_is_a_typed_error_not_a_panic() {
        let kernel = FusedKernel::new(vec![ValueRef::Const(0)], vec![]);
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[1.0f32], [1]);
        let err = kernel.execute(cpu.as_ref(), &[&x]).unwrap_err();
        assert!(err.to_string().contains("no steps"), "{err}");
    }

    #[test]
    fn both_engines_agree_bitwise_and_replan_on_shape_change() {
        // diamond with a broadcast input, run blockwise and interpreted,
        // then again under different shapes (the cached plan must rebuild)
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0), ValueRef::Const(1)],
            vec![
                FusedStep { op: Op::Exp, args: vec![FusedArg::Input(0)] },
                FusedStep { op: Op::Add, args: vec![FusedArg::Step(0), FusedArg::Input(1)] },
                FusedStep { op: Op::Mul, args: vec![FusedArg::Step(1), FusedArg::Step(0)] },
            ],
        );
        let cpu = CpuBackend::shared();
        for dims in [vec![2usize, 3], vec![4, 1, 5]] {
            let n: usize = dims.iter().product();
            let data: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37 - 1.0).collect();
            let x = Tensor::from_slice(&data, &dims[..]);
            let y = Tensor::from_slice(&[0.25f32], [1]);
            let blk = kernel.execute_blockwise(cpu.as_ref(), &[&x, &y]).unwrap();
            let interp = kernel.execute_interpreted(cpu.as_ref(), &[&x, &y]).unwrap();
            assert_eq!(blk.dims(), interp.dims());
            let (bb, ib) = (blk.to_vec(), interp.to_vec());
            for i in 0..bb.len() {
                assert_eq!(bb[i].to_bits(), ib[i].to_bits(), "elem {i} under {dims:?}");
            }
        }
    }

    #[test]
    fn cloned_kernels_share_the_lowered_plan() {
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0)],
            vec![FusedStep { op: Op::Neg, args: vec![FusedArg::Input(0)] }],
        );
        kernel.prepare(&[Shape::new(vec![3])]).unwrap();
        let clone = kernel.clone();
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[1.0f32, -2.0, 3.0], [3]);
        let out = clone.execute(cpu.as_ref(), &[&x]).unwrap();
        assert_eq!(out.to_vec(), vec![-1.0, 2.0, -3.0]);
    }
}
