//! Element-wise fusion: collapse chains *and diamonds* of f32
//! element-wise nodes into single [`FusedKernel`] regions that evaluate
//! in one pass over the output with no intermediate buffers.
//!
//! This generalizes (and replaces) the old lazy backend's private
//! `eval_fused` tree walk, with two correctness upgrades:
//!
//! - **Shared subgraphs evaluate once.** A kernel is a step *DAG*, not an
//!   expression tree: a value consumed by two steps is one step, computed
//!   once per element. (The tree walk duplicated shared subtrees in its
//!   RPN program — exponential work on diamond-heavy graphs.)
//! - **No cross-region duplication.** A fusible node consumed by two
//!   different regions (or by a non-fusible op, or requested as a program
//!   output) materializes exactly once as its own region root and enters
//!   the consumers as a plain input.
//!
//! Bit-identity contract: the fused interpreter applies *exactly* the
//! scalar f32 semantics of the CPU kernels (`kernels::map1`/`map2` with
//! the same `std` float ops), and regions are gated on every participant
//! being provably `F32` via the static verifier's signature inference
//! ([`super::verify::infer_node_meta`] — the same engine that re-checks
//! fusion legality after the fact). The differential fuzzer holds this
//! to bit-for-bit equality.

use std::collections::HashMap;

use super::super::host::HostBuffer;
use super::super::op::Op;
use super::super::shape::Shape;
use super::super::trace::ValueRef;
use super::super::{DType, Tensor, TensorBackend};
use super::{CompileReport, CompiledInstr, Graph, PassReport};
use crate::util::error::{Error, Result};

/// Arity of an op the fused interpreter can evaluate with bit-identical
/// f32 semantics (`None`: not fusible). This is also the lazy backend's
/// deferral predicate — the fusion ISA is a subset of [`Op`].
pub fn fusible_arity(op: &Op) -> Option<usize> {
    match op {
        Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Minimum | Op::Maximum => Some(2),
        Op::Neg
        | Op::Abs
        | Op::Sign
        | Op::Exp
        | Op::Log
        | Op::Tanh
        | Op::Sqrt
        | Op::Clip { .. } => Some(1),
        _ => None,
    }
}

/// Scalar semantics of a fusible unary op — must mirror the CPU backend's
/// f32 kernels exactly (see `cpu/mod.rs`).
pub fn apply1(op: &Op, x: f32) -> f32 {
    match op {
        Op::Neg => -x,
        Op::Abs => x.abs(),
        Op::Sign => {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                0.0
            }
        }
        Op::Exp => x.exp(),
        Op::Log => x.ln(),
        Op::Tanh => x.tanh(),
        Op::Sqrt => x.sqrt(),
        Op::Clip { lo, hi } => x.clamp(*lo as f32, *hi as f32),
        _ => unreachable!("not a fusible unary op: {op:?}"),
    }
}

/// Scalar semantics of a fusible binary op — must mirror the CPU
/// backend's f32 kernels exactly.
pub fn apply2(op: &Op, a: f32, b: f32) -> f32 {
    match op {
        Op::Add => a + b,
        Op::Sub => a - b,
        Op::Mul => a * b,
        Op::Div => a / b,
        Op::Minimum => a.min(b),
        Op::Maximum => a.max(b),
        _ => unreachable!("not a fusible binary op: {op:?}"),
    }
}

/// Where a fused step's operand comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusedArg {
    /// One of the kernel's external inputs.
    Input(usize),
    /// The value of an earlier step (shared steps evaluate once).
    Step(usize),
}

/// One scalar operation inside a fused region.
#[derive(Debug, Clone)]
pub struct FusedStep {
    /// A fusible element-wise [`Op`].
    pub op: Op,
    /// Operand sources (length = `fusible_arity(op)`).
    pub args: Vec<FusedArg>,
}

/// A fused element-wise region: external inputs plus a topologically
/// ordered step DAG. The last step is the region's output.
#[derive(Debug, Clone)]
pub struct FusedKernel {
    /// External operand sources (deduplicated, first-use order).
    pub inputs: Vec<ValueRef>,
    /// The step DAG in evaluation order.
    pub steps: Vec<FusedStep>,
}

impl FusedKernel {
    /// Evaluate the region in a single pass. Inputs must broadcast to a
    /// common shape; per output element, every step is computed exactly
    /// once, in f32, with the CPU backend's scalar semantics. The result
    /// materializes through `backend.from_host`.
    pub fn execute(&self, backend: &dyn TensorBackend, inputs: &[&Tensor]) -> Result<Tensor> {
        debug_assert_eq!(inputs.len(), self.inputs.len());
        for t in inputs {
            if t.dtype() != DType::F32 {
                return Err(Error::msg(format!(
                    "fused kernel input must be f32, got {}",
                    t.dtype().name()
                )));
            }
        }
        let bufs: Vec<Vec<f32>> = inputs.iter().map(|t| t.to_vec()).collect();
        let in_shapes: Vec<Shape> = inputs.iter().map(|t| t.shape().clone()).collect();
        // resolve step shapes by the same broadcast rules the eager
        // backend applies, so the kernel's output shape matches exactly
        let mut step_shapes: Vec<Shape> = Vec::with_capacity(self.steps.len());
        for step in &self.steps {
            let shape_of = |a: &FusedArg| match a {
                FusedArg::Input(i) => in_shapes[*i].clone(),
                FusedArg::Step(s) => step_shapes[*s].clone(),
            };
            let mut shape = shape_of(&step.args[0]);
            for a in &step.args[1..] {
                shape = shape.broadcast(&shape_of(a))?;
            }
            step_shapes.push(shape);
        }
        let out_shape = step_shapes.last().expect("empty fused kernel").clone();
        let n = out_shape.numel();
        let strides: Vec<Vec<usize>> = in_shapes
            .iter()
            .map(|s| s.broadcast_strides(&out_shape))
            .collect::<Result<_>>()?;
        if n == 0 {
            return Ok(backend.from_host(HostBuffer::F32(Vec::new()), out_shape));
        }
        let dims = out_shape.dims().to_vec();
        let rank = dims.len();
        let row_strides = out_shape.strides();
        let mut out = vec![0f32; n];
        // one fused pass, parallelized like the eager kernels; each chunk
        // seeds its odometer from its base linear index (parallel split
        // cannot change any value: every element is independent)
        crate::util::parallel::parallel_fill(
            &mut out,
            crate::util::parallel::PAR_THRESHOLD,
            |base, chunk| {
                let mut idx = vec![0usize; rank];
                let mut rem = base;
                for d in 0..rank {
                    idx[d] = rem / row_strides[d];
                    rem %= row_strides[d];
                }
                let mut offs: Vec<usize> = strides
                    .iter()
                    .map(|st| st.iter().zip(&idx).map(|(s, i)| s * i).sum())
                    .collect();
                let mut vals = vec![0f32; self.steps.len()];
                for slot in chunk.iter_mut() {
                    for (s, step) in self.steps.iter().enumerate() {
                        let read = |a: &FusedArg, vals: &[f32]| match a {
                            FusedArg::Input(i) => bufs[*i][offs[*i]],
                            FusedArg::Step(j) => vals[*j],
                        };
                        vals[s] = if step.args.len() == 1 {
                            apply1(&step.op, read(&step.args[0], &vals))
                        } else {
                            apply2(
                                &step.op,
                                read(&step.args[0], &vals),
                                read(&step.args[1], &vals),
                            )
                        };
                    }
                    *slot = *vals.last().unwrap();
                    // odometer: advance every input offset in lockstep
                    for d in (0..rank).rev() {
                        idx[d] += 1;
                        for (k, st) in strides.iter().enumerate() {
                            offs[k] += st[d];
                        }
                        if idx[d] < dims[d] {
                            break;
                        }
                        idx[d] = 0;
                        for (k, st) in strides.iter().enumerate() {
                            offs[k] -= st[d] * dims[d];
                        }
                    }
                }
            },
        );
        Ok(backend.from_host(HostBuffer::F32(out), out_shape))
    }
}

/// Fusion pass: cluster fusible nodes into single-output regions and
/// lower the graph to [`CompiledInstr`]s. Regions of a single node stay
/// plain ops (a one-op kernel is pure overhead). Returns the instruction
/// list and the remapped output references.
pub(crate) fn fuse(g: &Graph, report: &mut CompileReport) -> (Vec<CompiledInstr>, Vec<ValueRef>) {
    let n = g.nodes.len();
    // one inference engine: the verifier's per-op signature table (a node
    // only fuses when it is *provably* f32, inputs included)
    let metas = super::verify::infer_node_meta(g);
    let meta_f32 = |i: usize| metas[i].as_ref().is_some_and(|m| m.dtype == DType::F32);
    let is_f32 = |r: &ValueRef| match r {
        ValueRef::Const(c) => g.consts[*c].dtype() == DType::F32,
        ValueRef::Out(i) => meta_f32(*i),
    };
    let fusible: Vec<bool> = g
        .nodes
        .iter()
        .enumerate()
        .map(|(i, node)| {
            fusible_arity(&node.op) == Some(node.inputs.len())
                && meta_f32(i)
                && node.inputs.iter().all(is_f32)
        })
        .collect();
    let consumers = g.consumers();
    let is_out = g.output_mask();

    // cluster in reverse topological order: a fusible node is absorbed
    // into a region iff it is not an output and *all* its consumers sit
    // in that one region; otherwise it roots a region of its own
    let mut region_of: Vec<Option<usize>> = vec![None; n];
    let mut region_members: Vec<Vec<usize>> = Vec::new();
    for i in (0..n).rev() {
        if !fusible[i] {
            continue;
        }
        let all_same_region = (!is_out[i] && !consumers[i].is_empty())
            .then(|| {
                let r0 = region_of[consumers[i][0]]?;
                consumers[i].iter().all(|&c| region_of[c] == Some(r0)).then_some(r0)
            })
            .flatten();
        match all_same_region {
            Some(r) => {
                region_of[i] = Some(r);
                region_members[r].push(i);
            }
            None => {
                region_of[i] = Some(region_members.len());
                region_members.push(vec![i]);
            }
        }
    }
    // single-node regions revert to plain dispatch
    for members in &region_members {
        if members.len() == 1 {
            region_of[members[0]] = None;
        }
    }

    // lower: members collapse into their root's position; everything else
    // keeps its relative order. old node index -> new instr index
    let root_of = |r: usize| region_members[r][0]; // reverse order: first pushed = root (max index)
    let mut new_index: Vec<Option<usize>> = vec![None; n];
    let mut instrs: Vec<CompiledInstr> = Vec::new();
    let mut fused_ops = 0usize;
    for i in 0..n {
        let interior = region_of[i].is_some_and(|r| root_of(r) != i);
        if interior {
            continue;
        }
        let remap = |r: &ValueRef, new_index: &[Option<usize>]| match r {
            ValueRef::Out(j) => ValueRef::Out(new_index[*j].expect("fuse: ref to interior node")),
            c => *c,
        };
        match region_of[i] {
            Some(region) => {
                // build the kernel from members in topological order
                let mut members = region_members[region].clone();
                members.sort_unstable();
                let step_of: HashMap<usize, usize> =
                    members.iter().enumerate().map(|(s, &m)| (m, s)).collect();
                let mut inputs: Vec<ValueRef> = Vec::new();
                let mut steps: Vec<FusedStep> = Vec::new();
                for &m in &members {
                    let args: Vec<FusedArg> = g.nodes[m]
                        .inputs
                        .iter()
                        .map(|r| {
                            if let ValueRef::Out(j) = r {
                                if let Some(&s) = step_of.get(j) {
                                    return FusedArg::Step(s);
                                }
                            }
                            let ext = remap(r, &new_index);
                            let pos = match inputs.iter().position(|x| *x == ext) {
                                Some(p) => p,
                                None => {
                                    inputs.push(ext);
                                    inputs.len() - 1
                                }
                            };
                            FusedArg::Input(pos)
                        })
                        .collect();
                    steps.push(FusedStep { op: g.nodes[m].op.clone(), args });
                }
                fused_ops += steps.len();
                new_index[i] = Some(instrs.len());
                instrs.push(CompiledInstr::Fused(FusedKernel { inputs, steps }));
            }
            None => {
                let inputs: Vec<ValueRef> =
                    g.nodes[i].inputs.iter().map(|r| remap(r, &new_index)).collect();
                new_index[i] = Some(instrs.len());
                instrs.push(CompiledInstr::Op { op: g.nodes[i].op.clone(), inputs });
            }
        }
    }
    let outputs: Vec<ValueRef> = g
        .outputs
        .iter()
        .map(|r| match r {
            ValueRef::Out(j) => ValueRef::Out(new_index[*j].expect("fuse: output was fused away")),
            c => *c,
        })
        .collect();
    report.passes.push(PassReport {
        pass: "fuse",
        ops_before: n,
        ops_after: instrs.len(),
        changed: fused_ops.saturating_sub(region_members.iter().filter(|m| m.len() > 1).count()),
    });
    (instrs, outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::cpu::CpuBackend;

    #[test]
    fn kernel_evaluates_diamond_once_per_element() {
        // e = exp(x); out = (e + y) * (e - y): e is one shared step
        let kernel = FusedKernel {
            inputs: vec![ValueRef::Const(0), ValueRef::Const(1)],
            steps: vec![
                FusedStep { op: Op::Exp, args: vec![FusedArg::Input(0)] },
                FusedStep {
                    op: Op::Add,
                    args: vec![FusedArg::Step(0), FusedArg::Input(1)],
                },
                FusedStep {
                    op: Op::Sub,
                    args: vec![FusedArg::Step(0), FusedArg::Input(1)],
                },
                FusedStep {
                    op: Op::Mul,
                    args: vec![FusedArg::Step(1), FusedArg::Step(2)],
                },
            ],
        };
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[0.0f32, 1.0], [2]);
        let y = Tensor::from_slice(&[0.5f32, 2.0], [2]);
        let out = kernel.execute(cpu.as_ref(), &[&x, &y]).unwrap();
        let expect: Vec<f32> = [(0.0f32, 0.5f32), (1.0, 2.0)]
            .iter()
            .map(|&(x, y)| (x.exp() + y) * (x.exp() - y))
            .collect();
        assert_eq!(out.to_vec(), expect);
    }

    #[test]
    fn kernel_broadcasts_like_the_eager_backend() {
        // [2,1] + [1,3] inside the region -> [2,3]
        let kernel = FusedKernel {
            inputs: vec![ValueRef::Const(0), ValueRef::Const(1)],
            steps: vec![FusedStep {
                op: Op::Add,
                args: vec![FusedArg::Input(0), FusedArg::Input(1)],
            }],
        };
        let cpu = CpuBackend::shared();
        let a = Tensor::from_slice(&[1.0f32, 2.0], [2, 1]);
        let b = Tensor::from_slice(&[10.0f32, 20.0, 30.0], [1, 3]);
        let fused = kernel.execute(cpu.as_ref(), &[&a, &b]).unwrap();
        let eager = cpu.add(&a, &b);
        assert_eq!(fused.dims(), eager.dims());
        assert_eq!(fused.to_vec(), eager.to_vec());
    }

    #[test]
    fn non_f32_inputs_are_rejected() {
        let kernel = FusedKernel {
            inputs: vec![ValueRef::Const(0)],
            steps: vec![FusedStep { op: Op::Neg, args: vec![FusedArg::Input(0)] }],
        };
        let cpu = CpuBackend::shared();
        let x = Tensor::from_slice(&[1i64, 2], [2]);
        assert!(kernel.execute(cpu.as_ref(), &[&x]).is_err());
    }
}
