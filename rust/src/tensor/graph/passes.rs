//! The optimization passes: dead-code elimination, constant folding, and
//! common-subexpression elimination. Each pass rewrites the [`Graph`] in
//! place and appends a [`PassReport`].
//!
//! Shared semantics rules:
//!
//! - **Effectful ops are barriers.** `rand_uniform`/`rand_normal` advance
//!   the backend RNG stream and `call_ext` has backend-defined semantics,
//!   so DCE keeps them even when dead, folding never evaluates them at
//!   compile time, and CSE never merges them.
//! - **Folding uses the reference CPU backend.** A folded value is the
//!   byte-for-byte CPU result; on CPU execution this is indistinguishable
//!   from running the op at execution time, which is what the
//!   differential fuzzer checks.
//! - **Every pass must preserve the static invariants.** Under
//!   `FL_VERIFY=1` the [`super::verify`] pass re-checks SSA form, full
//!   shape/dtype inference, and the effectful-op sequence after each pass
//!   and attributes any violation to the pass that introduced it.

use std::collections::HashMap;

use super::super::cpu::CpuBackend;
use super::super::op::Op;
use super::super::trace::ValueRef;
use super::super::{Tensor, TensorBackend};
use super::{CompileOptions, CompileReport, Graph, PassReport};

/// Ops with observable effects beyond their value (kept by DCE, skipped
/// by folding and CSE).
pub(crate) fn effectful(op: &Op) -> bool {
    matches!(op, Op::RandUniform { .. } | Op::RandNormal { .. } | Op::CallExt { .. })
}

/// Dead-code elimination: drop every node not transitively reachable from
/// the requested outputs or from an effectful op.
pub fn dce(g: &mut Graph, report: &mut CompileReport) {
    let before = g.nodes.len();
    let mut live = vec![false; g.nodes.len()];
    let mut work: Vec<usize> = Vec::new();
    for r in &g.outputs {
        if let ValueRef::Out(i) = r {
            work.push(*i);
        }
    }
    for (i, n) in g.nodes.iter().enumerate() {
        if effectful(&n.op) {
            work.push(i);
        }
    }
    while let Some(i) = work.pop() {
        if live[i] {
            continue;
        }
        live[i] = true;
        for r in &g.nodes[i].inputs {
            if let ValueRef::Out(j) = r {
                work.push(*j);
            }
        }
    }
    g.retain(&live);
    report.passes.push(PassReport {
        pass: "dce",
        ops_before: before,
        ops_after: g.nodes.len(),
        changed: before - g.nodes.len(),
    });
}

/// Safe upper bound on the output element count of `op`, used to keep
/// compile-time folding from materializing huge values.
fn fold_size_bound(op: &Op, arg_numels: &[usize]) -> usize {
    match op {
        Op::Full { shape, .. } | Op::FromHost { shape, .. } => shape.numel(),
        Op::Arange { n, .. } => *n,
        Op::Tile { reps } => {
            arg_numels.first().copied().unwrap_or(1).saturating_mul(reps.iter().product())
        }
        Op::Pad { pads, .. } => {
            // numel(padded) <= numel * prod(1 + before + after)
            let grow: usize = pads.iter().map(|(a, b)| 1 + a + b).product();
            arg_numels.first().copied().unwrap_or(1).saturating_mul(grow)
        }
        // broadcast / matmul outputs are bounded by the operand-size product
        _ => arg_numels.iter().copied().fold(1usize, |a, b| a.saturating_mul(b.max(1))),
    }
}

/// Constant folding: evaluate deterministic nodes whose operands are all
/// compile-time constants (and none of them frozen parameters) on the
/// reference CPU backend, promoting the results into the constant pool.
/// Runs in topological order so folds cascade through chains in one pass.
pub fn fold(g: &mut Graph, opts: &CompileOptions, report: &mut CompileReport) {
    let before = g.nodes.len();
    let cpu = CpuBackend::shared();
    // per old node: its replacement const, if folded
    let mut folded: Vec<Option<ValueRef>> = vec![None; g.nodes.len()];
    for i in 0..g.nodes.len() {
        // rewrite inputs through earlier folds first so chains cascade
        let inputs: Vec<ValueRef> = g.nodes[i]
            .inputs
            .iter()
            .map(|r| match r {
                ValueRef::Out(j) => folded[*j].unwrap_or(*r),
                c => *c,
            })
            .collect();
        g.nodes[i].inputs = inputs.clone();
        if effectful(&g.nodes[i].op) {
            continue;
        }
        let const_ids: Vec<usize> = inputs
            .iter()
            .filter_map(|r| match r {
                ValueRef::Const(c) => Some(*c),
                ValueRef::Out(_) => None,
            })
            .collect();
        if const_ids.len() != inputs.len() {
            continue; // some operand is still computed at run time
        }
        if const_ids.iter().any(|c| opts.frozen_consts.contains(c)) {
            continue; // depends on a substitutable parameter
        }
        let arg_numels: Vec<usize> = const_ids.iter().map(|&c| g.consts[c].numel()).collect();
        if fold_size_bound(&g.nodes[i].op, &arg_numels) > opts.fold_numel_cap {
            continue;
        }
        let args: Vec<&Tensor> = const_ids.iter().map(|&c| &g.consts[c]).collect();
        match cpu.dispatch(&g.nodes[i].op, &args) {
            Ok(value) => {
                let c = g.consts.len();
                g.consts.push(value);
                folded[i] = Some(ValueRef::Const(c));
            }
            // a failing op is left in place: the executor will surface
            // the same error at run time (folding must not mask it)
            Err(_) => continue,
        }
    }
    // rewrite remaining uses and outputs, then drop the folded defs
    for n in g.nodes.iter_mut() {
        for r in n.inputs.iter_mut() {
            if let ValueRef::Out(j) = r {
                if let Some(c) = folded[*j] {
                    *r = c;
                }
            }
        }
    }
    for r in g.outputs.iter_mut() {
        if let ValueRef::Out(j) = r {
            if let Some(c) = folded[*j] {
                *r = c;
            }
        }
    }
    let keep: Vec<bool> = folded.iter().map(|f| f.is_none()).collect();
    g.retain(&keep);
    report.passes.push(PassReport {
        pass: "fold",
        ops_before: before,
        ops_after: g.nodes.len(),
        changed: before - g.nodes.len(),
    });
}

/// Common-subexpression elimination: redirect uses of syntactically
/// identical deterministic nodes (same op payload, same canonical
/// operands) to the first occurrence. Orphaned duplicates are left for
/// the follow-up DCE sweep.
pub fn cse(g: &mut Graph, report: &mut CompileReport) {
    let before = g.nodes.len();
    let mut seen: HashMap<String, usize> = HashMap::new();
    // canonical value for each node (identity unless merged away)
    let mut canon: Vec<usize> = (0..g.nodes.len()).collect();
    let mut merged = 0usize;
    for i in 0..g.nodes.len() {
        let inputs: Vec<ValueRef> = g.nodes[i]
            .inputs
            .iter()
            .map(|r| match r {
                ValueRef::Out(j) => ValueRef::Out(canon[*j]),
                c => *c,
            })
            .collect();
        g.nodes[i].inputs = inputs.clone();
        // effectful ops never merge; `from_host` is excluded because its
        // Debug key would serialize the whole host buffer (folding already
        // collapses constant data where it matters)
        if effectful(&g.nodes[i].op) || matches!(g.nodes[i].op, Op::FromHost { .. }) {
            continue;
        }
        // `Op` carries no interior mutability, so its Debug form is a
        // faithful syntactic key (payload floats included)
        let key = format!("{:?}|{:?}", g.nodes[i].op, inputs);
        match seen.get(&key) {
            Some(&first) => {
                canon[i] = first;
                merged += 1;
            }
            None => {
                seen.insert(key, i);
            }
        }
    }
    for n in g.nodes.iter_mut() {
        for r in n.inputs.iter_mut() {
            if let ValueRef::Out(j) = r {
                *j = canon[*j];
            }
        }
    }
    for r in g.outputs.iter_mut() {
        if let ValueRef::Out(j) = r {
            *j = canon[*j];
        }
    }
    report.passes.push(PassReport {
        pass: "cse",
        ops_before: before,
        ops_after: g.nodes.len(),
        changed: merged,
    });
}
