//! The static graph verifier: whole-program well-formedness checking
//! over the [`Op`] IR, run between every compiler pass.
//!
//! [`verify`] checks a [`Graph`] (and [`verify_program`] a compiled
//! program) for:
//!
//! - **SSA well-formedness** — every reference resolves to an earlier
//!   definition or a real constant (def-before-use doubles as an
//!   acyclicity proof, since nodes are kept in topological order);
//! - **signature validity** — full forward shape/dtype inference via the
//!   per-op [`signature`] table: every node's operands must satisfy its
//!   op's arity, dtype, and shape rules, and the inferred metadata flows
//!   forward as the next node's input facts;
//! - **effect preservation** — the ordered sequence of effectful ops
//!   (`rand_*`, `call_ext`; see [`passes::effectful`]) must survive every
//!   pass exactly, compared against a [`SourceSpec`] snapshot of the
//!   pre-optimization trace;
//! - **output stability** — each requested output's shape/dtype must
//!   match what the source trace produced;
//! - **fusion legality** — every [`FusedKernel`] step DAG re-checked:
//!   steps drawn from the fusible ISA with the right arities, interior
//!   references topological, inputs *provably* f32, interior shapes
//!   broadcast-compatible;
//! - **memory-plan soundness** — no two concurrently-live values share a
//!   slot, nothing is freed before its last reader, outputs are never
//!   freed, and donation frontiers never retire a constant that is still
//!   read (or is itself a requested output).
//!
//! Failures come back as [`Diagnostic`]s carrying a typed
//! [`DiagnosticKind`], the offending instruction index and op name, and
//! the name of the pass after which the invariant first broke — so a
//! miscompile reads as "`[after cse] ShapeMismatch at instr 3 `add`: …`"
//! instead of a shape panic deep in the executor.
//!
//! Wiring: [`super::compile`] *always* validates the source trace
//! (fail-closed boundary — a malformed trace is a typed
//! [`Error::Verify`], not a downstream panic), and re-verifies after
//! every pass when [`verify_enabled`] (`FL_VERIFY=1`; the fuzz CI jobs
//! set it unconditionally). The verifier is itself mutation-tested:
//! `rust/tests/graph_verify.rs` injects seeded miscompiles of every
//! class above and requires a 100% kill rate with zero false positives
//! on clean fuzz programs.

use super::super::op::Op;
use super::super::trace::ValueRef;
use super::super::{DType, Shape, Tensor};
use super::fuse::{fusible_arity, FusedArg, FusedKernel};
use super::signature::{self, SignatureErrorKind, ValueMeta};
use super::{passes, CompiledInstr, CompiledProgram, Graph};
use crate::util::error::Error;

/// What kind of invariant a [`Diagnostic`] reports broken.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiagnosticKind {
    /// A reference to a nonexistent constant or a not-yet-defined value
    /// (forward/self reference — an SSA or acyclicity violation).
    DanglingRef,
    /// Wrong tensor-input count for an op.
    Arity,
    /// An input dtype the consuming op (or fused region) cannot accept.
    DTypeMismatch,
    /// Shapes violating an op's shape rule (broadcast, rank, bounds…).
    ShapeMismatch,
    /// The ordered effectful-op sequence diverged from the source trace.
    EffectMismatch,
    /// A fused region that the fused interpreter cannot soundly evaluate.
    FusionIllegal,
    /// Two concurrently-live values assigned the same buffer slot.
    MemPlanAlias,
    /// A value freed before its last reader executes.
    MemPlanUseAfterFree,
    /// A requested output freed (or not pinned) by the plan.
    OutputFreed,
    /// A donation frontier that retires a constant still in use, or one
    /// that is itself a requested output.
    DonationUnsafe,
    /// A requested output whose shape/dtype diverged from the source
    /// trace's.
    OutputMismatch,
    /// A memory plan whose structure doesn't match the program
    /// (wrong vector lengths, out-of-range or duplicate entries).
    MemPlanMalformed,
}

/// One verification failure, with provenance.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// The broken invariant.
    pub kind: DiagnosticKind,
    /// Offending instruction/node index, when the failure is localized.
    pub instr: Option<usize>,
    /// Display name of the offending op (`"fused"`, `"plan"`,
    /// `"output"` for non-op failures).
    pub op: &'static str,
    /// The pass after which the invariant first failed (`"trace"` for
    /// the source program itself).
    pub pass: &'static str,
    /// Human-readable description.
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[after {}] {:?}", self.pass, self.kind)?;
        match self.instr {
            Some(i) => write!(f, " at instr {i} `{}`", self.op)?,
            None => write!(f, " ({})", self.op)?,
        }
        write!(f, ": {}", self.message)
    }
}

/// The verifier's result on success: per-value and per-output static
/// metadata (`None` = unknowable, e.g. downstream of `call_ext`).
#[derive(Debug, Clone)]
pub struct VerifiedMeta {
    /// Inferred metadata per node/instruction, in definition order.
    pub values: Vec<Option<ValueMeta>>,
    /// Inferred metadata per requested output, in request order.
    pub outputs: Vec<Option<ValueMeta>>,
}

/// What the source trace promised: the invariants every later pass must
/// preserve. Snapshotted by [`source_spec`] before optimization.
#[derive(Debug, Clone)]
pub struct SourceSpec {
    /// Effectful ops ([`passes::effectful`]) in trace order.
    pub effects: Vec<Op>,
    /// Shape/dtype of each requested output (`None` = unknown).
    pub output_meta: Vec<Option<ValueMeta>>,
}

/// Whether per-pass verification is switched on (`FL_VERIFY=1`/`true`),
/// read fresh on every call so tests can toggle it.
pub fn verify_enabled() -> bool {
    matches!(std::env::var("FL_VERIFY").ok().as_deref(), Some("1") | Some("true"))
}

/// Collapse a diagnostic list into the typed [`Error::Verify`] the
/// compile entry points surface.
pub fn to_error(diags: &[Diagnostic]) -> Error {
    Error::Verify(diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; "))
}

fn const_metas(consts: &[Tensor]) -> Vec<ValueMeta> {
    consts.iter().map(|t| ValueMeta::new(t.shape().clone(), t.dtype())).collect()
}

fn kind_of(k: SignatureErrorKind) -> DiagnosticKind {
    match k {
        SignatureErrorKind::Arity => DiagnosticKind::Arity,
        SignatureErrorKind::DType => DiagnosticKind::DTypeMismatch,
        SignatureErrorKind::Shape => DiagnosticKind::ShapeMismatch,
    }
}

/// `Some(why)` if `r` does not resolve under `num_consts` constants and
/// `limit` already-defined values.
fn bad_ref(r: &ValueRef, num_consts: usize, limit: usize) -> Option<String> {
    match r {
        ValueRef::Const(c) if *c >= num_consts => {
            Some(format!("const ref {c} out of range ({num_consts} const(s))"))
        }
        ValueRef::Out(j) if *j >= limit => {
            Some(format!("forward/dangling ref to value {j} ({limit} defined so far)"))
        }
        _ => None,
    }
}

/// Snapshot the invariants of a source trace — validating it in full
/// first (the fail-closed boundary check: a trace that fails signature
/// validation never enters the pass pipeline).
pub fn source_spec(g: &Graph) -> Result<SourceSpec, Vec<Diagnostic>> {
    let meta = verify(g, None, "trace")?;
    Ok(SourceSpec {
        effects: g
            .nodes
            .iter()
            .filter(|n| passes::effectful(&n.op))
            .map(|n| n.op.clone())
            .collect(),
        output_meta: meta.outputs,
    })
}

/// Verify a [`Graph`] against the static invariants (and, when `spec` is
/// given, against the source trace's promises). `pass` names the pass
/// whose output this graph is, for diagnostic provenance.
pub fn verify(
    g: &Graph,
    spec: Option<&SourceSpec>,
    pass: &'static str,
) -> Result<VerifiedMeta, Vec<Diagnostic>> {
    let const_meta = const_metas(&g.consts);
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut values: Vec<Option<ValueMeta>> = Vec::with_capacity(g.nodes.len());
    for (i, node) in g.nodes.iter().enumerate() {
        let name = node.op.name();
        let mut refs_ok = true;
        for r in &node.inputs {
            if let Some(why) = bad_ref(r, g.consts.len(), i) {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::DanglingRef,
                    instr: Some(i),
                    op: name,
                    pass,
                    message: why,
                });
                refs_ok = false;
            }
        }
        if !refs_ok {
            values.push(None);
            continue;
        }
        let meta = {
            let inputs: Vec<Option<&ValueMeta>> = node
                .inputs
                .iter()
                .map(|r| match r {
                    ValueRef::Const(c) => Some(&const_meta[*c]),
                    ValueRef::Out(j) => values[*j].as_ref(),
                })
                .collect();
            match signature::infer(&node.op, &inputs) {
                Ok(m) => m,
                Err(e) => {
                    diags.push(Diagnostic {
                        kind: kind_of(e.kind),
                        instr: Some(i),
                        op: name,
                        pass,
                        message: e.message,
                    });
                    None
                }
            }
        };
        values.push(meta);
    }
    let outputs = check_output_refs(&g.outputs, g.consts.len(), &const_meta, &values, pass, &mut diags);
    if let Some(spec) = spec {
        let effects: Vec<(usize, &Op)> = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| passes::effectful(&n.op))
            .map(|(i, n)| (i, &n.op))
            .collect();
        check_effects(&effects, spec, pass, &mut diags);
        check_output_meta(&outputs, spec, pass, &mut diags);
    }
    if diags.is_empty() {
        Ok(VerifiedMeta { values, outputs })
    } else {
        Err(diags)
    }
}

/// Verify a [`CompiledProgram`]: everything [`verify`] checks, plus
/// fusion legality for every [`FusedKernel`] and soundness of the
/// attached memory plan.
pub fn verify_program(
    p: &CompiledProgram,
    spec: Option<&SourceSpec>,
    pass: &'static str,
) -> Result<VerifiedMeta, Vec<Diagnostic>> {
    let const_meta = const_metas(&p.consts);
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut values: Vec<Option<ValueMeta>> = Vec::with_capacity(p.instrs.len());
    for (j, instr) in p.instrs.iter().enumerate() {
        let name = instr.name();
        let mut refs_ok = true;
        for r in instr.inputs() {
            if let Some(why) = bad_ref(r, p.consts.len(), j) {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::DanglingRef,
                    instr: Some(j),
                    op: name,
                    pass,
                    message: why,
                });
                refs_ok = false;
            }
        }
        if !refs_ok {
            values.push(None);
            continue;
        }
        let meta = match instr {
            CompiledInstr::Op { op, inputs } => {
                let im: Vec<Option<&ValueMeta>> = inputs
                    .iter()
                    .map(|r| match r {
                        ValueRef::Const(c) => Some(&const_meta[*c]),
                        ValueRef::Out(i) => values[*i].as_ref(),
                    })
                    .collect();
                match signature::infer(op, &im) {
                    Ok(m) => m,
                    Err(e) => {
                        diags.push(Diagnostic {
                            kind: kind_of(e.kind),
                            instr: Some(j),
                            op: name,
                            pass,
                            message: e.message,
                        });
                        None
                    }
                }
            }
            CompiledInstr::Fused(k) => check_fused(k, j, &const_meta, &values, pass, &mut diags),
        };
        values.push(meta);
    }
    let outputs = check_output_refs(&p.outputs, p.consts.len(), &const_meta, &values, pass, &mut diags);
    if let Some(spec) = spec {
        let effects: Vec<(usize, &Op)> = p
            .instrs
            .iter()
            .enumerate()
            .filter_map(|(j, instr)| match instr {
                CompiledInstr::Op { op, .. } if passes::effectful(op) => Some((j, op)),
                _ => None,
            })
            .collect();
        check_effects(&effects, spec, pass, &mut diags);
        check_output_meta(&outputs, spec, pass, &mut diags);
    }
    check_plan(p, pass, &mut diags);
    if diags.is_empty() {
        Ok(VerifiedMeta { values, outputs })
    } else {
        Err(diags)
    }
}

/// Lenient per-node inference for optimization heuristics (the fusion
/// pass's provable-f32 gate): invalid nodes infer as unknown instead of
/// failing — verification proper, not this, reports them.
pub fn infer_node_meta(g: &Graph) -> Vec<Option<ValueMeta>> {
    let const_meta = const_metas(&g.consts);
    let mut values: Vec<Option<ValueMeta>> = Vec::with_capacity(g.nodes.len());
    for (i, node) in g.nodes.iter().enumerate() {
        let ok = node
            .inputs
            .iter()
            .all(|r| bad_ref(r, g.consts.len(), i).is_none());
        let meta = if ok {
            let inputs: Vec<Option<&ValueMeta>> = node
                .inputs
                .iter()
                .map(|r| match r {
                    ValueRef::Const(c) => Some(&const_meta[*c]),
                    ValueRef::Out(j) => values[*j].as_ref(),
                })
                .collect();
            signature::infer(&node.op, &inputs).ok().flatten()
        } else {
            None
        };
        values.push(meta);
    }
    values
}

/// Resolve output references (flagging dangling ones) into output metas.
fn check_output_refs(
    outputs: &[ValueRef],
    num_consts: usize,
    const_meta: &[ValueMeta],
    values: &[Option<ValueMeta>],
    pass: &'static str,
    diags: &mut Vec<Diagnostic>,
) -> Vec<Option<ValueMeta>> {
    outputs
        .iter()
        .enumerate()
        .map(|(k, r)| match bad_ref(r, num_consts, values.len()) {
            Some(why) => {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::DanglingRef,
                    instr: None,
                    op: "output",
                    pass,
                    message: format!("output {k}: {why}"),
                });
                None
            }
            None => match r {
                ValueRef::Const(c) => Some(const_meta[*c].clone()),
                ValueRef::Out(i) => values[*i].clone(),
            },
        })
        .collect()
}

/// The effectful-op sequence must match the source trace's exactly —
/// same ops (payloads included), same order. Compared syntactically via
/// the `Debug` form, like CSE's node keys.
fn check_effects(
    found: &[(usize, &Op)],
    spec: &SourceSpec,
    pass: &'static str,
    diags: &mut Vec<Diagnostic>,
) {
    let want: Vec<String> = spec.effects.iter().map(|o| format!("{o:?}")).collect();
    let got: Vec<String> = found.iter().map(|(_, o)| format!("{o:?}")).collect();
    if want == got {
        return;
    }
    let k = want.iter().zip(&got).take_while(|(a, b)| a == b).count();
    let (instr, op, message) = if k < want.len() && k < got.len() {
        (
            Some(found[k].0),
            found[k].1.name(),
            format!("effect {k} is `{}`, source trace has `{}`", got[k], want[k]),
        )
    } else if k < want.len() {
        (
            None,
            "effect",
            format!(
                "effect {k} `{}` from the source trace was dropped ({} of {} survive)",
                want[k],
                got.len(),
                want.len()
            ),
        )
    } else {
        (
            Some(found[k].0),
            found[k].1.name(),
            format!("extra effect {k} `{}` not present in the source trace", got[k]),
        )
    };
    diags.push(Diagnostic { kind: DiagnosticKind::EffectMismatch, instr, op, pass, message });
}

/// Requested outputs must keep the shape/dtype the source trace produced
/// (checked wherever both sides are statically known).
fn check_output_meta(
    outputs: &[Option<ValueMeta>],
    spec: &SourceSpec,
    pass: &'static str,
    diags: &mut Vec<Diagnostic>,
) {
    if outputs.len() != spec.output_meta.len() {
        diags.push(Diagnostic {
            kind: DiagnosticKind::OutputMismatch,
            instr: None,
            op: "output",
            pass,
            message: format!(
                "{} output(s), source trace promised {}",
                outputs.len(),
                spec.output_meta.len()
            ),
        });
        return;
    }
    for (k, (got, want)) in outputs.iter().zip(&spec.output_meta).enumerate() {
        if let (Some(got), Some(want)) = (got, want) {
            if got != want {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::OutputMismatch,
                    instr: None,
                    op: "output",
                    pass,
                    message: format!("output {k} is {got}, source trace promised {want}"),
                });
            }
        }
    }
}

/// Fusion legality: the step DAG must be evaluable by the fused
/// interpreter with semantics identical to the unfused ops. Returns the
/// kernel's output metadata when sound.
fn check_fused(
    k: &FusedKernel,
    j: usize,
    const_meta: &[ValueMeta],
    values: &[Option<ValueMeta>],
    pass: &'static str,
    diags: &mut Vec<Diagnostic>,
) -> Option<ValueMeta> {
    let mut push = |kind: DiagnosticKind, message: String, diags: &mut Vec<Diagnostic>| {
        diags.push(Diagnostic { kind, instr: Some(j), op: "fused", pass, message });
    };
    if k.steps.is_empty() {
        push(DiagnosticKind::FusionIllegal, "kernel has no steps".to_string(), diags);
        return None;
    }
    // inputs must be *provably* f32 — the fused interpreter evaluates in
    // f32 unconditionally (caller already bounds-checked the refs)
    let in_meta: Vec<Option<&ValueMeta>> = k
        .inputs
        .iter()
        .map(|r| match r {
            ValueRef::Const(c) => Some(&const_meta[*c]),
            ValueRef::Out(i) => values[*i].as_ref(),
        })
        .collect();
    let mut sound = true;
    for (i, m) in in_meta.iter().enumerate() {
        match m {
            Some(m) if m.dtype != DType::F32 => {
                push(
                    DiagnosticKind::DTypeMismatch,
                    format!(
                        "kernel input {i} is {}, fused regions are f32-only",
                        m.dtype.name()
                    ),
                    diags,
                );
                sound = false;
            }
            None => {
                push(
                    DiagnosticKind::FusionIllegal,
                    format!("kernel input {i} is not provably f32 (metadata unknown)"),
                    diags,
                );
                sound = false;
            }
            _ => {}
        }
    }
    // step DAG: fusible ops only, right arities, topological references,
    // broadcast-compatible interior shapes
    let mut step_shapes: Vec<Option<Shape>> = Vec::with_capacity(k.steps.len());
    for (s, step) in k.steps.iter().enumerate() {
        match fusible_arity(&step.op) {
            Some(a) if a == step.args.len() => {}
            Some(a) => {
                push(
                    DiagnosticKind::FusionIllegal,
                    format!(
                        "step {s} `{}` has {} arg(s), needs {a}",
                        step.op.name(),
                        step.args.len()
                    ),
                    diags,
                );
                sound = false;
                step_shapes.push(None);
                continue;
            }
            None => {
                push(
                    DiagnosticKind::FusionIllegal,
                    format!("step {s} `{}` is not a fusible element-wise op", step.op.name()),
                    diags,
                );
                sound = false;
                step_shapes.push(None);
                continue;
            }
        }
        let mut shape: Option<Shape> = None;
        let mut step_ok = true;
        for a in &step.args {
            let arg_shape: Option<Shape> = match a {
                FusedArg::Input(i) if *i < k.inputs.len() => {
                    in_meta[*i].map(|m| m.shape.clone())
                }
                FusedArg::Input(i) => {
                    push(
                        DiagnosticKind::FusionIllegal,
                        format!(
                            "step {s}: input arg {i} out of range ({} input(s))",
                            k.inputs.len()
                        ),
                        diags,
                    );
                    step_ok = false;
                    None
                }
                FusedArg::Step(t) if *t < s => step_shapes[*t].clone(),
                FusedArg::Step(t) => {
                    push(
                        DiagnosticKind::FusionIllegal,
                        format!("step {s}: forward/self step ref {t}"),
                        diags,
                    );
                    step_ok = false;
                    None
                }
            };
            shape = match (shape, arg_shape) {
                (None, s2) => s2,
                (s1, None) => s1,
                (Some(s1), Some(s2)) => match s1.broadcast(&s2) {
                    Ok(b) => Some(b),
                    Err(_) => {
                        push(
                            DiagnosticKind::FusionIllegal,
                            format!(
                                "step {s} `{}`: cannot broadcast {s1} with {s2}",
                                step.op.name()
                            ),
                            diags,
                        );
                        step_ok = false;
                        None
                    }
                },
            };
        }
        if !step_ok {
            sound = false;
        }
        step_shapes.push(if step_ok { shape } else { None });
    }
    if !sound {
        return None;
    }
    step_shapes
        .last()
        .cloned()
        .flatten()
        .map(|shape| ValueMeta::new(shape, DType::F32))
}

/// Memory-plan soundness: replay the plan's free/donate decisions against
/// the program's actual read positions.
fn check_plan(p: &CompiledProgram, pass: &'static str, diags: &mut Vec<Diagnostic>) {
    let plan = &p.plan;
    let n = p.instrs.len();
    let nc = p.consts.len();
    if plan.slot.len() != n
        || plan.last_use.len() != n
        || plan.dies_after.len() != n
        || plan.is_output.len() != n
        || plan.const_last_use.len() != nc
    {
        diags.push(Diagnostic {
            kind: DiagnosticKind::MemPlanMalformed,
            instr: None,
            op: "plan",
            pass,
            message: format!(
                "plan sized for {} instr(s) / {} const(s), program has {n} / {nc}",
                plan.slot.len(),
                plan.const_last_use.len()
            ),
        });
        return; // indexing below would be unsafe
    }
    // actual last-read positions, from the instruction stream itself
    let mut last_read: Vec<usize> = (0..n).collect();
    let mut const_last_read: Vec<Option<usize>> = vec![None; nc];
    for (j, instr) in p.instrs.iter().enumerate() {
        for r in instr.inputs() {
            match r {
                ValueRef::Out(i) if *i < j => last_read[*i] = last_read[*i].max(j),
                ValueRef::Const(c) if *c < nc => const_last_read[*c] = Some(j),
                _ => {} // dangling refs already diagnosed
            }
        }
    }
    // where the plan frees each value
    let mut freed_at: Vec<Option<usize>> = vec![None; n];
    for (j, dead) in plan.dies_after.iter().enumerate() {
        for &d in dead {
            if d >= n {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::MemPlanMalformed,
                    instr: None,
                    op: "plan",
                    pass,
                    message: format!("dies_after[{j}] frees unknown value {d}"),
                });
                continue;
            }
            if let Some(prev) = freed_at[d] {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::MemPlanMalformed,
                    instr: Some(d),
                    op: p.instrs[d].name(),
                    pass,
                    message: format!("value {d} freed twice (after instr {prev} and {j})"),
                });
                continue;
            }
            freed_at[d] = Some(j);
        }
    }
    // use-after-free: a freed value must have no later reader
    for i in 0..n {
        if let Some(j) = freed_at[i] {
            if j < last_read[i] {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::MemPlanUseAfterFree,
                    instr: Some(i),
                    op: p.instrs[i].name(),
                    pass,
                    message: format!(
                        "value {i} freed after instr {j} but read by instr {}",
                        last_read[i]
                    ),
                });
            }
        }
    }
    // outputs stay live to the end of the program
    for (k, r) in p.outputs.iter().enumerate() {
        if let ValueRef::Out(i) = r {
            if *i >= n {
                continue; // dangling, already diagnosed
            }
            if let Some(j) = freed_at[*i] {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::OutputFreed,
                    instr: Some(*i),
                    op: p.instrs[*i].name(),
                    pass,
                    message: format!("output {k} (value {i}) is freed after instr {j}"),
                });
            } else if !plan.is_output[*i] {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::OutputFreed,
                    instr: Some(*i),
                    op: p.instrs[*i].name(),
                    pass,
                    message: format!("output {k} (value {i}) is not pinned in the plan"),
                });
            }
        }
    }
    // static interference: two values sharing a slot must not be live at
    // once; a value is live from its definition until the plan frees it
    // (to the end, if never freed)
    let free_point = |i: usize| freed_at[i].unwrap_or(n);
    for a in 0..n {
        for b in (a + 1)..n {
            if plan.slot[a] == plan.slot[b] && b <= free_point(a) {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::MemPlanAlias,
                    instr: Some(b),
                    op: p.instrs[b].name(),
                    pass,
                    message: format!(
                        "slot {} still holds value {a} (live through {}) when value {b} is \
                         defined",
                        plan.slot[a],
                        free_point(a)
                    ),
                });
            }
        }
    }
    // donation frontiers: never retire a constant that is still read, or
    // one the caller asked back as an output (the executor would return
    // the stale baked-in tensor instead of the substituted one)
    for c in 0..nc {
        let Some(j) = plan.const_last_use[c] else { continue };
        if j >= n {
            diags.push(Diagnostic {
                kind: DiagnosticKind::MemPlanMalformed,
                instr: None,
                op: "plan",
                pass,
                message: format!("const {c}: donation point {j} out of range ({n} instr(s))"),
            });
            continue;
        }
        if let Some(last) = const_last_read[c] {
            if j < last {
                diags.push(Diagnostic {
                    kind: DiagnosticKind::DonationUnsafe,
                    instr: None,
                    op: "plan",
                    pass,
                    message: format!(
                        "const {c} may be donated after instr {j} but is read by instr {last}"
                    ),
                });
            }
        }
        if p.outputs.iter().any(|r| matches!(r, ValueRef::Const(i) if *i == c)) {
            diags.push(Diagnostic {
                kind: DiagnosticKind::DonationUnsafe,
                instr: None,
                op: "plan",
                pass,
                message: format!(
                    "const {c} is a requested output but has a donation point (instr {j})"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::host::HostBuffer;
    use super::super::super::trace::{TraceInstr, TraceProgram};
    use super::*;

    fn fh(data: &[f32], shape: &[usize]) -> Op {
        Op::FromHost { host: HostBuffer::F32(data.to_vec()), shape: Shape::new(shape.to_vec()) }
    }

    fn graph(instrs: Vec<(Op, Vec<ValueRef>)>, outputs: &[ValueRef]) -> Graph {
        let p = TraceProgram {
            consts: Vec::new(),
            instrs: instrs.into_iter().map(|(op, inputs)| TraceInstr { op, inputs }).collect(),
        };
        Graph {
            consts: p.consts.clone(),
            nodes: p
                .instrs
                .iter()
                .map(|i| super::super::Node { op: i.op.clone(), inputs: i.inputs.clone() })
                .collect(),
            outputs: outputs.to_vec(),
        }
    }

    #[test]
    fn clean_graph_verifies_and_infers() {
        let g = graph(
            vec![
                (fh(&[1.0, 2.0], &[2, 1]), vec![]),
                (fh(&[1.0, 2.0, 3.0], &[1, 3]), vec![]),
                (Op::Add, vec![ValueRef::Out(0), ValueRef::Out(1)]),
            ],
            &[ValueRef::Out(2)],
        );
        let meta = verify(&g, None, "trace").unwrap();
        assert_eq!(
            meta.outputs[0],
            Some(ValueMeta::new(vec![2, 3], DType::F32))
        );
    }

    #[test]
    fn broken_broadcast_is_flagged_with_provenance() {
        let g = graph(
            vec![
                (fh(&[1.0, 2.0], &[2]), vec![]),
                (fh(&[1.0, 2.0, 3.0], &[3]), vec![]),
                (Op::Add, vec![ValueRef::Out(0), ValueRef::Out(1)]),
            ],
            &[ValueRef::Out(2)],
        );
        let diags = verify(&g, None, "cse").unwrap_err();
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].kind, DiagnosticKind::ShapeMismatch);
        assert_eq!(diags[0].instr, Some(2));
        assert_eq!(diags[0].pass, "cse");
        assert!(diags[0].to_string().contains("[after cse]"), "{}", diags[0]);
    }

    #[test]
    fn dropped_effect_is_flagged() {
        let rand = Op::RandUniform {
            shape: Shape::new(vec![2]),
            lo: 0.0,
            hi: 1.0,
            dtype: DType::F32,
        };
        let src = graph(
            vec![(rand.clone(), vec![]), (fh(&[1.0], &[1]), vec![])],
            &[ValueRef::Out(1)],
        );
        let spec = source_spec(&src).unwrap();
        assert_eq!(spec.effects.len(), 1);
        let mutated = graph(vec![(fh(&[1.0], &[1]), vec![])], &[ValueRef::Out(0)]);
        let diags = verify(&mutated, Some(&spec), "dce").unwrap_err();
        assert!(diags.iter().any(|d| d.kind == DiagnosticKind::EffectMismatch), "{diags:?}");
    }
}
