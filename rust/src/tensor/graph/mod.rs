//! An optimizing graph compiler over the [`Op`] IR (paper §4.1.1's
//! "deferred, on-the-fly kernel generation", grown into a real pass
//! pipeline).
//!
//! [`TraceProgram`]s captured by [`super::trace::TraceBackend`] are linear
//! instruction lists. This module lifts them into an SSA-style dataflow
//! [`Graph`] (every value defined exactly once, referenced by
//! [`ValueRef`]), runs an optimization pipeline —
//!
//! 1. **dead-code elimination** ([`passes::dce`]): drop everything not
//!    reachable from the requested outputs (RNG ops and `call_ext` are
//!    treated as effectful and kept),
//! 2. **constant folding** ([`passes::fold`]): evaluate nodes whose
//!    operands are all compile-time constants on the reference CPU
//!    backend,
//! 3. **common-subexpression elimination** ([`passes::cse`]): merge
//!    syntactically identical deterministic nodes,
//! 4. **element-wise fusion** ([`fuse`]): collapse chains *and diamonds*
//!    of f32 element-wise ops into single [`FusedKernel`] regions that
//!    evaluate in one pass with no intermediate buffers (shared interior
//!    values are computed once per element — the failure mode of the old
//!    lazy backend's tree walk),
//!
//! — then lays out a liveness-based [`MemoryPlan`] (buffers are dropped
//! back to the installed [`crate::memory::MemoryManagerAdapter`] at their
//! last use, and the slot assignment bounds concurrent live buffers) and
//! packages everything as an executable [`CompiledProgram`] that runs on
//! *any* [`TensorBackend`].
//!
//! The pipeline is guarded by the static [`verify`] pass built on the
//! per-op [`signature`] table: every source trace is validated before
//! optimization (fail-closed, typed [`Error::Verify`]), and under
//! `FL_VERIFY=1` the full invariant set — SSA form, shape/dtype
//! inference, effect preservation, fusion legality, memory-plan
//! soundness — is re-checked after *every* pass with per-pass
//! provenance. See `docs/ARCHITECTURE.md` ("Static verification").
//!
//! Correctness contract: on the reference CPU backend, an optimized
//! program is **bit-identical** to replaying the unoptimized trace — the
//! differential fuzzer in `rust/tests/graph_fuzz.rs` enforces this over
//! hundreds of random programs, `rust/tests/graph_passes.rs` pins down
//! each pass individually, and `rust/tests/graph_verify.rs` mutation-
//! tests the verifier itself (seeded miscompile classes must all be
//! caught; clean fuzz programs must verify with zero diagnostics).

pub mod fuse;
pub mod fuse_exec;
pub mod memplan;
pub mod passes;
pub mod signature;
pub mod verify;

use std::sync::Arc;

use super::cpu::CpuBackend;
use super::op::Op;
use super::trace::{TraceBackend, TraceProgram, ValueRef};
use super::{BackendGuard, DType, Shape, Tensor, TensorBackend};
use crate::memory::telemetry::AllocEvent;
use crate::util::error::{Error, Result};

pub use fuse::{FusedArg, FusedKernel, FusedStep};
pub use fuse_exec::FusedPlan;
pub use memplan::MemoryPlan;
pub use signature::{SignatureError, SignatureErrorKind, ValueMeta};
pub use verify::{verify_enabled, Diagnostic, DiagnosticKind, SourceSpec, VerifiedMeta};

/// Process-wide capture serialization. [`BackendGuard::install`] swaps
/// the *global* default backend, so two concurrent captures would record
/// each other's operations (and mis-restore on drop). Every capture site
/// — [`trace_and_compile`], [`crate::coordinator::compile_step`], the
/// serving session's bucket compiles — holds this lock for the duration
/// of its capture. Callers running other threads that do tensor work must
/// still quiesce them around compilation.
static TRACE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Acquire the process-wide trace lock (poison-tolerant: a panicked
/// capture must not wedge every later compilation).
pub fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// One dataflow node: an [`Op`] plus where its operands come from. Values
/// are SSA — defined once by their node, never mutated.
#[derive(Debug, Clone)]
pub struct Node {
    /// The reified operation.
    pub op: Op,
    /// Operand sources, in argument order.
    pub inputs: Vec<ValueRef>,
}

/// A dataflow graph lifted from a linear [`TraceProgram`], with an
/// explicit set of requested outputs (everything else is optimization
/// fodder). Nodes are kept in topological (trace) order throughout.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// The constant pool (external operands of the trace).
    pub consts: Vec<Tensor>,
    /// Nodes in topological order: `ValueRef::Out(i)` is node `i`'s value.
    pub nodes: Vec<Node>,
    /// The values the caller wants back, in order.
    pub outputs: Vec<ValueRef>,
}

impl Graph {
    /// Lift a captured program, requesting `outputs`. Fails on dangling
    /// references (forward edges, out-of-range constants).
    pub fn from_program(program: &TraceProgram, outputs: &[ValueRef]) -> Result<Graph> {
        let check = |r: &ValueRef, limit: usize| -> Result<()> {
            match r {
                ValueRef::Const(i) if *i >= program.consts.len() => {
                    Err(Error::msg(format!("graph: const ref {i} out of range")))
                }
                ValueRef::Out(i) if *i >= limit => {
                    Err(Error::msg(format!("graph: forward/dangling ref to instr {i}")))
                }
                _ => Ok(()),
            }
        };
        for (j, instr) in program.instrs.iter().enumerate() {
            for r in &instr.inputs {
                check(r, j)?;
            }
        }
        for r in outputs {
            check(r, program.instrs.len())?;
        }
        Ok(Graph {
            consts: program.consts.clone(),
            nodes: program
                .instrs
                .iter()
                .map(|i| Node { op: i.op.clone(), inputs: i.inputs.clone() })
                .collect(),
            outputs: outputs.to_vec(),
        })
    }

    /// Drop every node whose `keep` flag is false, remapping all
    /// `Out` references. Callers guarantee no kept node (or output)
    /// references a dropped one.
    pub(crate) fn retain(&mut self, keep: &[bool]) {
        let mut remap = vec![usize::MAX; self.nodes.len()];
        let mut next = 0usize;
        for (i, &k) in keep.iter().enumerate() {
            if k {
                remap[i] = next;
                next += 1;
            }
        }
        let fix = |r: &mut ValueRef| {
            if let ValueRef::Out(i) = r {
                debug_assert_ne!(remap[*i], usize::MAX, "reference to dropped node {i}");
                *i = remap[*i];
            }
        };
        let mut nodes = Vec::with_capacity(next);
        for (i, mut n) in std::mem::take(&mut self.nodes).into_iter().enumerate() {
            if keep[i] {
                n.inputs.iter_mut().for_each(fix);
                nodes.push(n);
            }
        }
        self.nodes = nodes;
        self.outputs.iter_mut().for_each(fix);
    }

    /// Per-node consumer lists (node indices, may repeat per use).
    pub(crate) fn consumers(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (j, n) in self.nodes.iter().enumerate() {
            for r in &n.inputs {
                if let ValueRef::Out(i) = r {
                    out[*i].push(j);
                }
            }
        }
        out
    }

    /// Which nodes are requested program outputs.
    pub(crate) fn output_mask(&self) -> Vec<bool> {
        let mut m = vec![false; self.nodes.len()];
        for r in &self.outputs {
            if let ValueRef::Out(i) = r {
                m[*i] = true;
            }
        }
        m
    }

}

/// Which passes run, and their knobs.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Dead-code elimination.
    pub dce: bool,
    /// Constant folding (on the reference CPU backend).
    pub fold: bool,
    /// Common-subexpression elimination.
    pub cse: bool,
    /// Element-wise fusion.
    pub fuse: bool,
    /// Upper bound (elements) on values materialized by constant folding.
    pub fold_numel_cap: usize,
    /// Constant-pool indices that must *not* be folded into (the
    /// parameters of a [`CompiledFn`], substituted at call time).
    pub frozen_consts: Vec<usize>,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            dce: true,
            fold: true,
            cse: true,
            fuse: true,
            fold_numel_cap: 1 << 16,
            frozen_consts: Vec::new(),
        }
    }
}

impl CompileOptions {
    /// All passes disabled — compile becomes a structure-preserving
    /// lowering (useful as a differential baseline and in pass tests).
    pub fn none() -> Self {
        CompileOptions { dce: false, fold: false, cse: false, fuse: false, ..Default::default() }
    }

    /// Exactly one pass enabled (pass-level tests).
    pub fn only(pass: &str) -> Self {
        let mut o = Self::none();
        match pass {
            "dce" => o.dce = true,
            "fold" => o.fold = true,
            "cse" => o.cse = true,
            "fuse" => o.fuse = true,
            other => panic!("unknown pass `{other}`"),
        }
        o
    }
}

/// What one pass did, for reports and tests.
#[derive(Debug, Clone)]
pub struct PassReport {
    /// Pass name (`dce`, `fold`, `cse`, `fuse`).
    pub pass: &'static str,
    /// Node count entering the pass.
    pub ops_before: usize,
    /// Node count leaving the pass.
    pub ops_after: usize,
    /// Nodes removed / folded / merged / fused by the pass.
    pub changed: usize,
}

/// Per-pass accounting for a whole compilation.
#[derive(Debug, Clone, Default)]
pub struct CompileReport {
    /// One entry per executed pass, in pipeline order.
    pub passes: Vec<PassReport>,
}

impl CompileReport {
    /// Tally for a named pass (sums repeated runs, e.g. the cleanup DCE).
    pub fn changed_by(&self, pass: &str) -> usize {
        self.passes.iter().filter(|p| p.pass == pass).map(|p| p.changed).sum()
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        self.passes
            .iter()
            .map(|p| format!("{}: {}→{} (-{})", p.pass, p.ops_before, p.ops_after, p.changed))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// One executable instruction of a compiled program.
#[derive(Debug, Clone)]
pub enum CompiledInstr {
    /// A plain op, dispatched through the backend choke point.
    Op {
        /// The reified operation.
        op: Op,
        /// Operand sources.
        inputs: Vec<ValueRef>,
    },
    /// A fused element-wise region, evaluated in one pass.
    Fused(FusedKernel),
}

impl CompiledInstr {
    /// Display / telemetry name (`'static` so allocation events can carry it).
    pub fn name(&self) -> &'static str {
        match self {
            CompiledInstr::Op { op, .. } => op.name(),
            CompiledInstr::Fused(_) => "fused",
        }
    }

    /// Operand sources of this instruction.
    pub fn inputs(&self) -> &[ValueRef] {
        match self {
            CompiledInstr::Op { inputs, .. } => inputs,
            CompiledInstr::Fused(k) => &k.inputs,
        }
    }
}

/// An optimized, executable program: the output of [`compile`].
#[derive(Clone)]
pub struct CompiledProgram {
    /// The constant pool (indices match the source program's).
    pub consts: Vec<Tensor>,
    /// Instructions in execution order.
    pub instrs: Vec<CompiledInstr>,
    /// Requested outputs, resolved against `instrs`/`consts`.
    pub outputs: Vec<ValueRef>,
    /// The liveness-based buffer plan.
    pub plan: MemoryPlan,
    /// What each pass did.
    pub report: CompileReport,
}

/// Execution statistics: op/buffer counts and a replayable allocation
/// trace (feed it to [`crate::memory::telemetry::replay`] to evaluate the
/// plan against any memory manager).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Instructions executed (fused regions count once).
    pub executed_instrs: usize,
    /// Primitive ops represented (fused regions count their members).
    pub executed_ops: usize,
    /// Peak bytes live under the plan (buffers freed at last use).
    pub planned_peak_bytes: usize,
    /// Peak bytes had every intermediate been kept to the end.
    pub naive_peak_bytes: usize,
    /// Distinct buffer slots the plan used.
    pub buffer_slots: usize,
    /// Bytes of caller-donated inputs whose *handles* were released back
    /// to the memory manager before the end of the run (see
    /// [`CompiledProgram::run_owned`]). Accounting is by handle: if the
    /// caller retains another handle to the same storage (e.g. the very
    /// first step of a compiled train loop, where the model's `Variable`s
    /// still hold the parameter tensors), the bytes count as donated here
    /// but the storage is not actually freed until that alias drops; from
    /// the second step on, loop-owned inputs donate for real.
    pub donated_bytes: usize,
    /// Alloc/free events in execution order, replayable via
    /// [`crate::memory::telemetry::replay`].
    pub events: Vec<AllocEvent>,
}

impl CompiledProgram {
    /// Instruction count.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// Whether the program has no instructions (fully folded).
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Instruction names in execution order (fused regions show as
    /// `"fused"`); diagnostics and pass tests.
    pub fn op_names(&self) -> Vec<&'static str> {
        self.instrs.iter().map(|i| i.name()).collect()
    }

    /// Total primitive ops including the members of fused regions.
    pub fn primitive_op_count(&self) -> usize {
        self.instrs
            .iter()
            .map(|i| match i {
                CompiledInstr::Op { .. } => 1,
                CompiledInstr::Fused(k) => k.steps.len(),
            })
            .sum()
    }

    /// Execute on `backend`, returning the requested outputs in order.
    /// Skips the allocation-event telemetry of [`Self::run_detailed`]
    /// (this is the hot path for lazy materialization and
    /// [`CompiledFn::call`]).
    pub fn run(&self, backend: &dyn TensorBackend) -> Result<Vec<Tensor>> {
        self.exec(backend, &[], false).map(|(outs, _)| outs)
    }

    /// Execute with constant-pool substitutions (`(const index, tensor)`)
    /// and full statistics. Values are dropped back to the installed
    /// memory manager at their last use, per the [`MemoryPlan`].
    pub fn run_detailed(
        &self,
        backend: &dyn TensorBackend,
        overrides: &[(usize, &Tensor)],
    ) -> Result<(Vec<Tensor>, ExecStats)> {
        self.exec(backend, overrides, true)
    }

    fn exec(
        &self,
        backend: &dyn TensorBackend,
        overrides: &[(usize, &Tensor)],
        instrument: bool,
    ) -> Result<(Vec<Tensor>, ExecStats)> {
        let owned: Vec<(usize, Tensor)> =
            overrides.iter().map(|(i, t)| (*i, (*t).clone())).collect();
        self.exec_impl(backend, owned, &[], instrument)
    }

    /// Execute with *owned* constant-pool substitutions and input
    /// donation: every slot listed in `donate` is dropped back to the
    /// installed memory manager right after its last consuming
    /// instruction (per [`MemoryPlan::const_last_use`]), instead of
    /// staying live for the whole run. With a caching manager this lets
    /// an output reuse the storage of the input it replaces — the
    /// `params' ← params` round-trip of a compiled train step then runs
    /// at a steady footprint rather than two copies of the model.
    ///
    /// Only pass slots whose tensors the caller truly relinquishes
    /// (other live handles to the same storage defeat the donation, and
    /// slots pinned as program outputs are never dropped).
    pub fn run_owned(
        &self,
        backend: &dyn TensorBackend,
        overrides: Vec<(usize, Tensor)>,
        donate: &[usize],
        instrument: bool,
    ) -> Result<(Vec<Tensor>, ExecStats)> {
        self.exec_impl(backend, overrides, donate, instrument)
    }

    fn exec_impl(
        &self,
        backend: &dyn TensorBackend,
        overrides: Vec<(usize, Tensor)>,
        donate: &[usize],
        instrument: bool,
    ) -> Result<(Vec<Tensor>, ExecStats)> {
        // per-instruction timing is sampled (every Nth run process-wide)
        // so instruction-level visibility doesn't tax every execution
        let sample = crate::obs::exec_should_sample();
        let _run_span = if sample {
            let mut s = crate::obs::span("exec.run");
            s.attr_i64("instrs", self.instrs.len() as i64);
            s.attr_i64("ops", self.primitive_op_count() as i64);
            Some(s)
        } else {
            None
        };
        let nc = self.consts.len();
        let mut ovr: Vec<Option<Tensor>> = vec![None; nc];
        let mut ovr_bytes: Vec<usize> = vec![0; nc];
        let mut stats = ExecStats {
            executed_instrs: self.instrs.len(),
            executed_ops: self.primitive_op_count(),
            buffer_slots: self.plan.num_slots,
            ..Default::default()
        };
        let mut live = crate::meter::PeakValueMeter::new();
        let mut naive_bytes = 0usize;
        for (i, t) in overrides {
            let bytes = t.numel() * t.dtype().size_of();
            ovr_bytes[i] = bytes;
            ovr[i] = Some(t);
            // substituted inputs are live at entry; the naive plan keeps
            // them to the end, donation retires them at last use
            live.add(bytes);
            naive_bytes += bytes;
        }
        // donation frontier: override slots to release after instruction j
        let mut donate_after: Vec<Vec<usize>> = vec![Vec::new(); self.instrs.len()];
        for &ci in donate {
            if ci < nc && ovr[ci].is_some() {
                if let Some(j) = self.plan.const_last_use[ci] {
                    donate_after[j].push(ci);
                }
            }
        }
        let mut vals: Vec<Option<Tensor>> = vec![None; self.instrs.len()];
        let mut def_bytes: Vec<usize> = vec![0; self.instrs.len()];
        for (j, instr) in self.instrs.iter().enumerate() {
            // sampled per-instruction spans, attributed via the PR 7
            // provenance the executor already carries (index + op name)
            let mut instr_span = if sample { Some(crate::obs::span(instr.name())) } else { None };
            let out = {
                // executor failures carry provenance: instruction index,
                // op name, and the pass pipeline that produced the
                // program, instead of a bare panic deep in a kernel
                let resolve = |r: &ValueRef| -> Result<&Tensor> {
                    match r {
                        ValueRef::Const(i) => Ok(match &ovr[*i] {
                            Some(t) => t,
                            None => &self.consts[*i],
                        }),
                        ValueRef::Out(i) => vals[*i].as_ref().ok_or_else(|| {
                            Error::Verify(format!(
                                "executor: instr {j} `{}` reads value {i} after the plan \
                                 freed it (pipeline: {})",
                                instr.name(),
                                self.report.summary()
                            ))
                        }),
                    }
                };
                let provenance = |e: Error| {
                    Error::msg(format!(
                        "instr {j} `{}`: {e} (pipeline: {})",
                        instr.name(),
                        self.report.summary()
                    ))
                };
                match instr {
                    CompiledInstr::Op { op, inputs } => {
                        let args: Vec<&Tensor> =
                            inputs.iter().map(resolve).collect::<Result<_>>()?;
                        backend.dispatch(op, &args).map_err(provenance)?
                    }
                    CompiledInstr::Fused(k) => {
                        let args: Vec<&Tensor> =
                            k.inputs.iter().map(resolve).collect::<Result<_>>()?;
                        k.execute(backend, &args).map_err(provenance)?
                    }
                }
            };
            if let Some(mut s) = instr_span.take() {
                s.attr_i64("instr", j as i64);
                s.attr_i64("out_bytes", (out.numel() * out.dtype().size_of()) as i64);
            }
            let bytes = out.numel() * out.dtype().size_of();
            def_bytes[j] = bytes;
            live.add(bytes);
            naive_bytes += bytes;
            if instrument {
                stats.events.push(AllocEvent {
                    kind: crate::memory::EventKind::Alloc,
                    bytes,
                    id: j as u64,
                    op: instr.name(),
                });
            }
            vals[j] = Some(out);
            for &dead in &self.plan.dies_after[j] {
                if let Some(t) = vals[dead].take() {
                    drop(t); // returns the buffer to the installed manager
                    live.sub(def_bytes[dead]);
                    if instrument {
                        stats.events.push(AllocEvent {
                            kind: crate::memory::EventKind::Free,
                            bytes: 0,
                            id: dead as u64,
                            op: instr.name(),
                        });
                    }
                }
            }
            for &ci in &donate_after[j] {
                if let Some(t) = ovr[ci].take() {
                    drop(t); // donated input returns to the manager early
                    live.sub(ovr_bytes[ci]);
                    stats.donated_bytes += ovr_bytes[ci];
                }
            }
        }
        stats.planned_peak_bytes = live.peak();
        stats.naive_peak_bytes = naive_bytes;
        if crate::obs::enabled() {
            crate::obs::record_exec(
                stats.executed_instrs as u64,
                stats.executed_ops as u64,
                stats.donated_bytes as u64,
            );
        }
        let outs: Vec<Tensor> = self
            .outputs
            .iter()
            .enumerate()
            .map(|(k, r)| match r {
                ValueRef::Const(i) => Ok(match &ovr[*i] {
                    Some(t) => t.clone(),
                    None => self.consts[*i].clone(),
                }),
                ValueRef::Out(i) => vals[*i].clone().ok_or_else(|| {
                    Error::Verify(format!(
                        "executor: output {k} (value {i}, `{}`) was freed during execution \
                         (pipeline: {})",
                        self.instrs[*i].name(),
                        self.report.summary()
                    ))
                }),
            })
            .collect::<Result<_>>()?;
        Ok((outs, stats))
    }
}

/// Compile a captured program into an optimized [`CompiledProgram`]
/// producing `outputs`.
///
/// The source trace is *always* validated against the static signature
/// table first (fail-closed: a malformed trace is a typed
/// [`Error::Verify`], never a downstream panic). Under `FL_VERIFY=1`
/// ([`verify::verify_enabled`]) the graph is additionally re-verified
/// after every pass, attributing any broken invariant to the pass that
/// broke it.
pub fn compile(
    program: &TraceProgram,
    outputs: &[ValueRef],
    opts: &CompileOptions,
) -> Result<CompiledProgram> {
    let mut outer = crate::obs::span("compile");
    let mut g = Graph::from_program(program, outputs)?;
    outer.attr_i64("nodes", g.nodes.len() as i64);
    // fail-closed trace boundary: snapshot the invariants every pass must
    // preserve, rejecting source programs that fail signature validation
    let spec = verify::source_spec(&g).map_err(|d| verify::to_error(&d))?;
    let paranoid = verify::verify_enabled();
    let check = |g: &Graph, pass: &'static str| -> Result<()> {
        let mut s = crate::obs::span("compile.verify");
        s.attr_str("pass", pass);
        verify::verify(g, Some(&spec), pass).map(|_| ()).map_err(|d| verify::to_error(&d))
    };
    let mut report = CompileReport::default();
    if opts.dce {
        {
            let _s = crate::obs::span("compile.pass.dce");
            passes::dce(&mut g, &mut report);
        }
        if paranoid {
            check(&g, "dce")?;
        }
    }
    if opts.fold {
        {
            let _s = crate::obs::span("compile.pass.fold");
            passes::fold(&mut g, opts, &mut report);
        }
        if paranoid {
            check(&g, "fold")?;
        }
    }
    if opts.cse {
        {
            let _s = crate::obs::span("compile.pass.cse");
            passes::cse(&mut g, &mut report);
        }
        if paranoid {
            check(&g, "cse")?;
        }
    }
    if opts.dce && (opts.fold || opts.cse) {
        // fold/cse leave orphaned defs behind; sweep them
        {
            let _s = crate::obs::span("compile.pass.dce");
            passes::dce(&mut g, &mut report);
        }
        if paranoid {
            check(&g, "dce(cleanup)")?;
        }
    }
    let (instrs, outputs) = if opts.fuse {
        let mut s = crate::obs::span("compile.pass.fuse");
        let fused = fuse::fuse(&g, &mut report);
        s.attr_i64("instrs", fused.0.len() as i64);
        fused
    } else {
        (
            g.nodes
                .iter()
                .map(|n| CompiledInstr::Op { op: n.op.clone(), inputs: n.inputs.clone() })
                .collect(),
            g.outputs.clone(),
        )
    };
    let plan = {
        let mut s = crate::obs::span("compile.memplan");
        s.attr_i64("instrs", instrs.len() as i64);
        MemoryPlan::build(&instrs, &outputs, g.consts.len())
    };
    let compiled = CompiledProgram { consts: g.consts, instrs, outputs, plan, report };
    if paranoid {
        let pass = if opts.fuse { "fuse+memplan" } else { "lower+memplan" };
        let mut s = crate::obs::span("compile.verify");
        s.attr_str("pass", pass);
        verify::verify_program(&compiled, Some(&spec), pass)
            .map_err(|d| verify::to_error(&d))?;
    }
    Ok(compiled)
}

/// A traced-and-compiled function: the `Tensor::compile`-style entry
/// point. Capture once with example inputs, then [`CompiledFn::call`]
/// with fresh tensors of the same shapes/dtypes.
pub struct CompiledFn {
    program: CompiledProgram,
    /// Per example argument: its constant-pool slot (`None` if the traced
    /// function never used that argument).
    params: Vec<Option<usize>>,
    arg_shapes: Vec<Shape>,
    arg_dtypes: Vec<DType>,
    /// How many tensors the traced function returned (1 for
    /// [`trace_and_compile`], the closure's `Vec` length for
    /// [`trace_and_compile_many`]).
    n_outputs: usize,
}

/// Trace `f` over the example inputs and compile the captured program
/// with default options. The examples' *values* are not baked in: each
/// one becomes a substitutable parameter of the returned [`CompiledFn`]
/// (constant folding is fenced off from them). Shapes and dtypes *are*
/// specialized.
///
/// Caveats: the capture installs the trace backend as the
/// *process-global* default for the duration of `f` (the same
/// [`BackendGuard`] mechanism every backend swap in this codebase uses),
/// so tensor work running concurrently on other threads gets captured
/// too — trace on a quiescent process. Example arguments must be
/// distinct tensors: two handles to the same storage would share one
/// constant slot and could not be substituted independently at call
/// time, so that case is rejected here.
pub fn trace_and_compile(
    examples: &[Tensor],
    f: impl FnOnce(&[Tensor]) -> Tensor,
) -> Result<CompiledFn> {
    trace_and_compile_many(examples, |args| vec![f(args)])
}

/// Multi-output form of [`trace_and_compile`]: `f` returns a `Vec` of
/// result tensors and the compiled program produces all of them in one
/// execution (shared subexpressions are computed once). Call through
/// [`CompiledFn::call_many`] / [`CompiledFn::call_owned_many`]. A result
/// tensor that *is* one of the examples (the function passed an argument
/// through untouched) compiles to a direct parameter reference rather
/// than an error. Same caveats as [`trace_and_compile`] otherwise.
pub fn trace_and_compile_many(
    examples: &[Tensor],
    f: impl FnOnce(&[Tensor]) -> Vec<Tensor>,
) -> Result<CompiledFn> {
    let _lock = trace_lock();
    let be = TraceBackend::over_cpu_default();
    let (roots, params, program) = {
        let _guard = BackendGuard::install(be.clone());
        let outs = f(examples);
        if outs.is_empty() {
            return Err(Error::msg("trace_and_compile_many: the function returned no outputs"));
        }
        let tracer = be.interposer();
        let mut roots = Vec::with_capacity(outs.len());
        for (i, out) in outs.iter().enumerate() {
            let root = tracer
                .value_ref_of(out)
                .or_else(|| tracer.const_index_of(out).map(ValueRef::Const))
                .ok_or_else(|| {
                    Error::msg(format!(
                        "trace_and_compile_many: output {i} was not produced by the trace"
                    ))
                })?;
            roots.push(root);
        }
        let params: Vec<Option<usize>> =
            examples.iter().map(|e| tracer.const_index_of(e)).collect();
        (roots, params, tracer.program())
    };
    for (i, p) in params.iter().enumerate() {
        if p.is_some() && params[..i].contains(p) {
            return Err(Error::msg(format!(
                "trace_and_compile: example arguments {i} and an earlier one alias the same \
                 tensor; parameters must be distinct to be substituted independently"
            )));
        }
    }
    let opts = CompileOptions {
        frozen_consts: params.iter().flatten().copied().collect(),
        ..Default::default()
    };
    let n_outputs = roots.len();
    let program = compile(&program, &roots, &opts)?;
    Ok(CompiledFn {
        program,
        params,
        arg_shapes: examples.iter().map(|e| e.shape().clone()).collect(),
        arg_dtypes: examples.iter().map(|e| e.dtype()).collect(),
        n_outputs,
    })
}

impl CompiledFn {
    /// Validate one call-time argument against the traced signature.
    fn check_arg(&self, i: usize, a: &Tensor) -> Result<()> {
        if *a.shape() != self.arg_shapes[i] || a.dtype() != self.arg_dtypes[i] {
            return Err(Error::msg(format!(
                "compiled fn arg {i}: expected {} {}, got {} {}",
                self.arg_shapes[i],
                self.arg_dtypes[i].name(),
                a.shape(),
                a.dtype().name()
            )));
        }
        Ok(())
    }

    fn check_arity(&self, n: usize) -> Result<()> {
        if n != self.params.len() {
            return Err(Error::msg(format!(
                "compiled fn expects {} argument(s), got {}",
                self.params.len(),
                n
            )));
        }
        Ok(())
    }

    fn check_single(&self) -> Result<()> {
        if self.n_outputs != 1 {
            return Err(Error::msg(format!(
                "compiled fn has {} outputs; use call_many/call_owned_many",
                self.n_outputs
            )));
        }
        Ok(())
    }

    /// Run the compiled program on `backend` with fresh arguments
    /// (shapes/dtypes must match the trace-time examples).
    pub fn call(&self, backend: &dyn TensorBackend, args: &[&Tensor]) -> Result<Tensor> {
        self.check_single()?;
        self.call_many(backend, args).map(|mut outs| outs.remove(0))
    }

    /// Run the compiled program and return *all* traced outputs, in the
    /// order the traced function returned them. This is the call path for
    /// [`trace_and_compile_many`] functions (single-output fns work too —
    /// the vec has one element).
    pub fn call_many(&self, backend: &dyn TensorBackend, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.check_arity(args.len())?;
        for (i, a) in args.iter().enumerate() {
            self.check_arg(i, a)?;
        }
        let overrides: Vec<(usize, &Tensor)> = self
            .params
            .iter()
            .zip(args)
            .filter_map(|(p, a)| p.map(|i| (i, *a)))
            .collect();
        let (outs, _) = self.program.exec(backend, &overrides, false)?;
        Ok(outs)
    }

    /// Like [`CompiledFn::call`], but the arguments are passed by value
    /// and (optionally) *donated*: each one is released back to the
    /// installed memory manager right after its last consuming
    /// instruction, per [`CompiledProgram::run_owned`]. This is the
    /// steady-state serving path — a padded request batch is consumed by
    /// the program instead of staying live for the whole run, so with a
    /// caching manager the first activation reuses its storage. Returns
    /// the result plus the executor's memory/op statistics.
    pub fn call_owned(
        &self,
        backend: &dyn TensorBackend,
        args: Vec<Tensor>,
        donate: bool,
    ) -> Result<(Tensor, ExecStats)> {
        self.check_single()?;
        self.call_owned_many(backend, args, donate).map(|(mut outs, stats)| (outs.remove(0), stats))
    }

    /// Multi-output form of [`CompiledFn::call_owned`]: arguments are
    /// passed by value (and optionally donated), all traced outputs are
    /// returned.
    pub fn call_owned_many(
        &self,
        backend: &dyn TensorBackend,
        args: Vec<Tensor>,
        donate: bool,
    ) -> Result<(Vec<Tensor>, ExecStats)> {
        self.check_arity(args.len())?;
        for (i, a) in args.iter().enumerate() {
            self.check_arg(i, a)?;
        }
        let mut overrides: Vec<(usize, Tensor)> = Vec::with_capacity(args.len());
        let mut don: Vec<usize> = Vec::new();
        for (p, a) in self.params.iter().zip(args) {
            if let Some(slot) = p {
                overrides.push((*slot, a));
                if donate {
                    don.push(*slot);
                }
            }
        }
        self.program.run_owned(backend, overrides, &don, false)
    }

    /// How many outputs the traced function returned.
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    /// Convenience: run on the reference CPU backend.
    pub fn call_cpu(&self, args: &[&Tensor]) -> Result<Tensor> {
        let cpu: Arc<dyn TensorBackend> = CpuBackend::shared();
        self.call(cpu.as_ref(), args)
    }

    /// Rebind example argument `arg` to a new tensor *without re-tracing*:
    /// the value is written into the compiled program's constant pool, so
    /// it becomes the default for direct [`CompiledProgram::run`]
    /// executions (per-[`CompiledFn::call`] arguments still override it).
    /// This is the per-step input swap of a long-running compiled loop —
    /// shape and dtype are pinned by the trace, only the data changes.
    pub fn rebind(&mut self, arg: usize, value: &Tensor) -> Result<()> {
        if arg >= self.params.len() {
            return Err(Error::msg(format!(
                "rebind: argument {arg} out of range ({} traced)",
                self.params.len()
            )));
        }
        if *value.shape() != self.arg_shapes[arg] || value.dtype() != self.arg_dtypes[arg] {
            return Err(Error::msg(format!(
                "rebind arg {arg}: expected {} {}, got {} {}",
                self.arg_shapes[arg],
                self.arg_dtypes[arg].name(),
                value.shape(),
                value.dtype().name()
            )));
        }
        match self.params[arg] {
            Some(slot) => {
                self.program.consts[slot] = value.clone();
                Ok(())
            }
            // the traced function never read this argument: nothing to bind
            None => Ok(()),
        }
    }

    /// The optimized program.
    pub fn program(&self) -> &CompiledProgram {
        &self.program
    }

    /// What each pass did during compilation.
    pub fn report(&self) -> &CompileReport {
        &self.program.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::HostBuffer;

    fn fh(data: &[f32], shape: &[usize]) -> Op {
        Op::FromHost { host: HostBuffer::F32(data.to_vec()), shape: Shape::new(shape.to_vec()) }
    }

    fn prog(instrs: Vec<(Op, Vec<ValueRef>)>) -> TraceProgram {
        TraceProgram {
            consts: Vec::new(),
            instrs: instrs
                .into_iter()
                .map(|(op, inputs)| crate::tensor::trace::TraceInstr { op, inputs })
                .collect(),
        }
    }

    #[test]
    fn lowering_without_passes_matches_replay() {
        let p = prog(vec![
            (fh(&[1.0, 2.0, 3.0], &[3]), vec![]),
            (fh(&[4.0, 5.0, 6.0], &[3]), vec![]),
            (Op::Add, vec![ValueRef::Out(0), ValueRef::Out(1)]),
            (Op::Tanh, vec![ValueRef::Out(2)]),
        ]);
        let cpu = CpuBackend::shared();
        let reference = p.replay_on(cpu.as_ref()).unwrap();
        let compiled = compile(&p, &[ValueRef::Out(3)], &CompileOptions::none()).unwrap();
        let outs = compiled.run(cpu.as_ref()).unwrap();
        assert_eq!(outs[0].to_vec(), reference[3].to_vec());
        assert_eq!(compiled.op_names(), vec!["from_host", "from_host", "add", "tanh"]);
    }

    #[test]
    fn default_pipeline_folds_fuses_and_matches() {
        let p = prog(vec![
            (fh(&[1.0, -2.0, 3.0, -4.0], &[4]), vec![]),
            (fh(&[0.5, 0.5, 0.5, 0.5], &[4]), vec![]),
            (Op::Mul, vec![ValueRef::Out(0), ValueRef::Out(1)]),
            (Op::Abs, vec![ValueRef::Out(2)]),
            (Op::Sqrt, vec![ValueRef::Out(3)]),
        ]);
        let cpu = CpuBackend::shared();
        let reference = p.replay_on(cpu.as_ref()).unwrap();
        let compiled = compile(&p, &[ValueRef::Out(4)], &CompileOptions::default()).unwrap();
        // everything is constant: the whole program folds away
        assert!(compiled.is_empty(), "ops left: {:?}", compiled.op_names());
        let outs = compiled.run(cpu.as_ref()).unwrap();
        assert_eq!(outs[0].to_vec(), reference[4].to_vec());
    }

    #[test]
    fn dangling_refs_are_rejected() {
        let p = prog(vec![(Op::Neg, vec![ValueRef::Out(5)])]);
        assert!(Graph::from_program(&p, &[ValueRef::Out(0)]).is_err());
        let p2 = prog(vec![(fh(&[1.0], &[1]), vec![])]);
        assert!(Graph::from_program(&p2, &[ValueRef::Out(9)]).is_err());
    }

    #[test]
    fn rebind_swaps_inputs_without_retracing() {
        let ex = [Tensor::from_slice(&[1.0f32, 2.0], [2])];
        let mut cf = trace_and_compile(&ex, |args| args[0].mul(&args[0])).unwrap();
        let outs = cf.program().run(CpuBackend::shared().as_ref()).unwrap();
        assert_eq!(outs[0].to_vec(), vec![1.0, 4.0]);
        cf.rebind(0, &Tensor::from_slice(&[3.0f32, 4.0], [2])).unwrap();
        let outs = cf.program().run(CpuBackend::shared().as_ref()).unwrap();
        assert_eq!(outs[0].to_vec(), vec![9.0, 16.0]);
        // shape mismatch is rejected, index out of range too
        assert!(cf.rebind(0, &Tensor::zeros([3])).is_err());
        assert!(cf.rebind(5, &Tensor::zeros([2])).is_err());
    }

    #[test]
    fn donation_retires_inputs_early_and_lowers_peak() {
        // two-instruction chain: p and g are dead after the first op
        let be = TraceBackend::over_cpu_default();
        let p = Tensor::from_slice(&vec![1.0f32; 1000], [1000]);
        let g = Tensor::from_slice(&vec![0.5f32; 1000], [1000]);
        let y = be.sub(&p, &g);
        let z = be.tanh(&y);
        let tracer = be.interposer();
        let root = tracer.value_ref_of(&z).unwrap();
        let pslot = tracer.const_index_of(&p).unwrap();
        let gslot = tracer.const_index_of(&g).unwrap();
        let opts =
            CompileOptions { frozen_consts: vec![pslot, gslot], ..CompileOptions::none() };
        let prog = compile(&tracer.program(), &[root], &opts).unwrap();
        let cpu = CpuBackend::shared();
        let fresh = || {
            vec![
                (pslot, Tensor::from_slice(&vec![2.0f32; 1000], [1000])),
                (gslot, Tensor::from_slice(&vec![1.0f32; 1000], [1000])),
            ]
        };
        let (outs_keep, keep) = prog.run_owned(cpu.as_ref(), fresh(), &[], false).unwrap();
        let (outs_don, don) =
            prog.run_owned(cpu.as_ref(), fresh(), &[pslot, gslot], false).unwrap();
        assert_eq!(outs_keep[0].to_vec(), outs_don[0].to_vec());
        assert_eq!(don.donated_bytes, 2 * 1000 * 4);
        assert_eq!(keep.donated_bytes, 0);
        assert!(
            don.planned_peak_bytes < keep.planned_peak_bytes,
            "donation did not lower the peak: {} vs {}",
            don.planned_peak_bytes,
            keep.planned_peak_bytes
        );
    }

    #[test]
    fn call_owned_matches_call_and_donates() {
        let ex = [Tensor::from_slice(&vec![1.5f32; 512], [512])];
        let cf = trace_and_compile(&ex, |args| args[0].mul(&args[0]).tanh()).unwrap();
        let fresh = || Tensor::from_slice(&vec![0.75f32; 512], [512]);
        let borrowed = cf.call_cpu(&[&fresh()]).unwrap();
        let cpu = CpuBackend::shared();
        let (kept, ks) = cf.call_owned(cpu.as_ref(), vec![fresh()], false).unwrap();
        let (donated, ds) = cf.call_owned(cpu.as_ref(), vec![fresh()], true).unwrap();
        assert_eq!(borrowed.to_vec(), kept.to_vec());
        assert_eq!(borrowed.to_vec(), donated.to_vec());
        assert_eq!(ks.donated_bytes, 0);
        assert_eq!(ds.donated_bytes, 512 * 4, "the argument must be retired at last use");
        // arity / signature checks still apply
        assert!(cf.call_owned(cpu.as_ref(), vec![], false).is_err());
        assert!(cf.call_owned(cpu.as_ref(), vec![Tensor::zeros([3])], true).is_err());
    }

    #[test]
    fn compiled_fn_substitutes_parameters() {
        let ex = [
            Tensor::from_slice(&[1.0f32, 2.0], [2]),
            Tensor::from_slice(&[10.0f32, 20.0], [2]),
        ];
        let cf = trace_and_compile(&ex, |args| args[0].add(&args[1]).mul(&args[0])).unwrap();
        // called with the example values
        let y = cf.call_cpu(&[&ex[0], &ex[1]]).unwrap();
        assert_eq!(y.to_vec(), vec![11.0, 44.0]);
        // called with *fresh* values: parameters must not be baked in
        let a = Tensor::from_slice(&[2.0f32, 3.0], [2]);
        let b = Tensor::from_slice(&[1.0f32, 1.0], [2]);
        let y = cf.call_cpu(&[&a, &b]).unwrap();
        assert_eq!(y.to_vec(), vec![6.0, 12.0]);
        // shape mismatch is rejected
        assert!(cf.call_cpu(&[&a, &Tensor::zeros([3])]).is_err());
    }
}
