//! Blockwise execution engine for [`FusedKernel`] step DAGs.
//!
//! The fusion pass produces step DAGs; this module decides how fast they
//! run. The original executor walked the DAG *per element* — a closure
//! dispatch per step per element, with every input addressed through a
//! broadcast-strided odometer even when it was plain contiguous data.
//! That interpretive overhead is exactly the "fewer ops vs fast ops" gap
//! the Flashlight paper closes with JIT kernel generation.
//!
//! The blockwise engine lowers each kernel **once** into a [`FusedPlan`]
//! (at compile time when shapes are statically known, lazily on first
//! call otherwise) and then evaluates in fixed-size lane blocks of
//! [`BLOCK`] f32s:
//!
//! - every external input is classified by access pattern against the
//!   kernel's output shape — the same taxonomy as the CPU backend's
//!   `map2` fast paths (`cpu/kernels.rs`): [`Gather::Contig`] (read the
//!   block straight out of the source buffer), [`Gather::Splat`] (scalar,
//!   one broadcast block built per call), [`Gather::Suffix`] (trailing-
//!   dims broadcast, a wrapping `memcpy` with period = the input's
//!   length), and [`Gather::Strided`] (general broadcast, the only case
//!   that still walks an odometer — and only to gather, once per block,
//!   not once per step);
//! - each step then runs as a straight-line `for` loop over plain
//!   `&[f32]` slices with the `match` on the op hoisted *outside* the
//!   loop ([`run1`]/[`run2`]), which rustc autovectorizes;
//! - step outputs land in per-step block buffers whose slots are reused
//!   via step liveness (a chain of 40 ops needs 2 slots, not 40);
//! - the block loop threads over [`crate::util::parallel`] chunks like
//!   the eager kernels, each chunk seeding its gathers from its absolute
//!   base index, so the parallel split cannot change any value.
//!
//! **Bit-identity holds by construction**: every output element is
//! independent, and the per-op loop bodies use the exact `std` float
//! operations of [`apply1`]/[`apply2`] (the CPU backend's scalar
//! semantics) — the loops only hoist the op dispatch, never change the
//! arithmetic. `tests` below pin the two engines and the eager CPU ops
//! to `to_bits` equality, and the `graph_fuzz` differential fuzzer holds
//! the default path to the same contract at scale. The interpreted
//! engine is kept behind `FL_FUSE_INTERP=1` for differential testing.

use std::sync::OnceLock;

use super::super::op::Op;
use super::super::shape::Shape;
use super::fuse::{apply1, apply2, FusedArg, FusedStep};
use crate::util::error::{Error, Result};
use crate::util::parallel;

/// Lane-block size in f32 elements. Big enough that per-block plan
/// overhead amortizes to nothing, small enough that one input block plus
/// all live step buffers stay in L1.
pub const BLOCK: usize = 256;

/// How one external input is read against the kernel's output shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Gather {
    /// Same element count as the output: the block is a direct slice of
    /// the source buffer (no copy at all).
    Contig,
    /// Single element broadcast everywhere: one splat block per call.
    Splat,
    /// Trailing-dims broadcast (`[n,d] op [d]`): element `i` reads source
    /// `i % period`, gathered as a wrapping segment copy.
    Suffix {
        /// The input's element count (= product of the covered trailing
        /// output dims).
        period: usize,
    },
    /// General broadcast: odometer walk over the input's broadcast
    /// strides, once per block.
    Strided,
}

/// A fused kernel lowered for blockwise execution: input access classes,
/// gather scratch assignment, and liveness-reused step buffer slots.
/// Built once per (kernel, input shapes) by [`FusedPlan::build`].
#[derive(Debug, Clone)]
pub struct FusedPlan {
    pub(crate) in_shapes: Vec<Shape>,
    out_shape: Shape,
    /// Output dims / row-major strides (odometer seeding).
    dims: Vec<usize>,
    rstrides: Vec<usize>,
    /// Per input: broadcast strides against the output shape (used by the
    /// strided gather and the interpreted engine).
    strides: Vec<Vec<usize>>,
    pub(crate) gathers: Vec<Gather>,
    /// Per input: gather scratch-block index (`Suffix`/`Strided` only).
    scratch_slot: Vec<Option<usize>>,
    num_scratch: usize,
    /// Per step: block-buffer slot, liveness-reused. The last step has no
    /// slot — it writes the output chunk directly.
    pub(crate) step_slot: Vec<Option<usize>>,
    pub(crate) num_slots: usize,
}

impl FusedPlan {
    /// Lower a step DAG for the given input shapes. Validates what
    /// execution relies on — at least one step, in-range argument
    /// references (topological for steps), arity matching the fusible
    /// ISA, and every input broadcastable to the output shape — so the
    /// engines themselves are straight-line code.
    pub fn build(steps: &[FusedStep], in_shapes: &[Shape]) -> Result<FusedPlan> {
        if steps.is_empty() {
            return Err(Error::msg("fused kernel has no steps"));
        }
        for (s, step) in steps.iter().enumerate() {
            if super::fuse::fusible_arity(&step.op) != Some(step.args.len()) {
                return Err(Error::msg(format!(
                    "fused step {s}: op {:?} with {} args is outside the fusible ISA",
                    step.op,
                    step.args.len()
                )));
            }
            for a in &step.args {
                match a {
                    FusedArg::Input(i) if *i >= in_shapes.len() => {
                        return Err(Error::msg(format!(
                            "fused step {s}: input ref {i} out of range ({} inputs)",
                            in_shapes.len()
                        )))
                    }
                    FusedArg::Step(t) if *t >= s => {
                        return Err(Error::msg(format!(
                            "fused step {s}: non-topological step ref {t}"
                        )))
                    }
                    _ => {}
                }
            }
        }
        // output shape: the same broadcast fold the eager backend applies
        let mut step_shapes: Vec<Shape> = Vec::with_capacity(steps.len());
        for step in steps {
            let shape_of = |a: &FusedArg| match a {
                FusedArg::Input(i) => in_shapes[*i].clone(),
                FusedArg::Step(t) => step_shapes[*t].clone(),
            };
            let mut shape = shape_of(&step.args[0]);
            for a in &step.args[1..] {
                shape = shape.broadcast(&shape_of(a))?;
            }
            step_shapes.push(shape);
        }
        let out_shape = step_shapes.last().unwrap().clone();
        let dims = out_shape.dims().to_vec();
        let rstrides = out_shape.strides();
        let out_numel = out_shape.numel();

        // classify every input against the output shape (map2's taxonomy)
        let mut strides = Vec::with_capacity(in_shapes.len());
        let mut gathers = Vec::with_capacity(in_shapes.len());
        for sh in in_shapes {
            let bs = sh.broadcast_strides(&out_shape)?;
            gathers.push(classify(&bs, &rstrides, &dims, sh.numel(), out_numel));
            strides.push(bs);
        }
        let mut scratch_slot = vec![None; in_shapes.len()];
        let mut num_scratch = 0usize;
        for (i, g) in gathers.iter().enumerate() {
            if matches!(g, Gather::Suffix { .. } | Gather::Strided) {
                scratch_slot[i] = Some(num_scratch);
                num_scratch += 1;
            }
        }

        // step liveness -> block-buffer slots. A step's slot is allocated
        // *before* the slots of values dying at that step are freed, so a
        // destination never aliases one of its own arguments.
        let nsteps = steps.len();
        let mut last_use: Vec<usize> = (0..nsteps).collect();
        for (s, step) in steps.iter().enumerate() {
            for a in &step.args {
                if let FusedArg::Step(t) = a {
                    last_use[*t] = s; // s > t: checked topological above
                }
            }
        }
        let mut step_slot: Vec<Option<usize>> = vec![None; nsteps];
        let mut free: Vec<usize> = Vec::new();
        let mut num_slots = 0usize;
        for s in 0..nsteps {
            if s + 1 < nsteps {
                step_slot[s] = Some(free.pop().unwrap_or_else(|| {
                    num_slots += 1;
                    num_slots - 1
                }));
            }
            for t in 0..=s {
                if last_use[t] == s {
                    if let Some(k) = step_slot[t] {
                        free.push(k);
                    }
                }
            }
        }

        Ok(FusedPlan {
            in_shapes: in_shapes.to_vec(),
            out_shape,
            dims,
            rstrides,
            strides,
            gathers,
            scratch_slot,
            num_scratch,
            step_slot,
            num_slots,
        })
    }

    /// The kernel's output shape under this plan's input shapes.
    pub fn out_shape(&self) -> &Shape {
        &self.out_shape
    }

    /// Does this plan apply to a call with these shapes and step count?
    pub(crate) fn matches(&self, in_shapes: &[Shape], nsteps: usize) -> bool {
        self.step_slot.len() == nsteps && self.in_shapes == in_shapes
    }
}

/// Pick the gather class from an input's broadcast strides `bs` against
/// the output's row-major strides `rs` / dims. Mirrors the `map2` fast
/// paths: equal numel ⇒ identical dims ⇒ contiguous; a zero-stride prefix
/// followed by the output's own trailing strides ⇒ pure suffix broadcast.
fn classify(
    bs: &[usize],
    rs: &[usize],
    dims: &[usize],
    in_numel: usize,
    out_numel: usize,
) -> Gather {
    if in_numel == 1 {
        return Gather::Splat;
    }
    if in_numel == out_numel {
        return Gather::Contig;
    }
    let k = bs.iter().position(|&s| s != 0).unwrap_or(bs.len());
    // size-1 dims carry stride 0 but contribute nothing to the offset
    if bs[k..].iter().zip(&rs[k..]).zip(&dims[k..]).all(|((&b, &r), &d)| b == r || d == 1) {
        let period: usize = dims[k..].iter().product();
        if period == in_numel {
            return Gather::Suffix { period };
        }
    }
    Gather::Strided
}

/// Is the interpreted engine forced via `FL_FUSE_INTERP=1`? (Kept for
/// differential testing; the blockwise engine is the default path.)
pub fn interpreter_forced() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| {
        std::env::var("FL_FUSE_INTERP").is_ok_and(|v| !v.is_empty() && v != "0")
    })
}

/// Wrapping segment copy for a suffix-broadcast input: output element
/// `base + i` reads `buf[(base + i) % period]`.
fn gather_suffix(buf: &[f32], period: usize, base: usize, out: &mut [f32]) {
    let mut src = base % period;
    let mut filled = 0usize;
    while filled < out.len() {
        let take = (period - src).min(out.len() - filled);
        out[filled..filled + take].copy_from_slice(&buf[src..src + take]);
        filled += take;
        src += take;
        if src == period {
            src = 0;
        }
    }
}

/// Odometer gather for a general strided input, seeded from the absolute
/// base index by decomposing against the output's row-major strides.
/// `idx` is caller-provided scratch of length `dims.len()`.
fn gather_strided(
    buf: &[f32],
    strides: &[usize],
    dims: &[usize],
    rstrides: &[usize],
    base: usize,
    idx: &mut [usize],
    out: &mut [f32],
) {
    let rank = dims.len();
    let mut off = 0usize;
    let mut rem = base;
    for d in 0..rank {
        idx[d] = rem / rstrides[d];
        rem %= rstrides[d];
        off += idx[d] * strides[d];
    }
    for slot in out.iter_mut() {
        *slot = buf[off];
        for d in (0..rank).rev() {
            idx[d] += 1;
            off += strides[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            off -= strides[d] * dims[d];
        }
    }
}

/// Straight-line unary loop, op dispatch hoisted out. The loop bodies are
/// the exact `std` float operations of [`apply1`] — only the `match`
/// moves, never the arithmetic (the bit-identity contract; pinned to
/// `apply1` on edge values by `tests::block_loops_mirror_scalar_semantics`).
fn run1(op: &Op, a: &[f32], out: &mut [f32]) {
    match op {
        Op::Neg => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = -x;
            }
        }
        Op::Abs => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.abs();
            }
        }
        Op::Sign => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = if x > 0.0 {
                    1.0
                } else if x < 0.0 {
                    -1.0
                } else {
                    0.0
                };
            }
        }
        Op::Exp => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.exp();
            }
        }
        Op::Log => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.ln();
            }
        }
        Op::Tanh => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.tanh();
            }
        }
        Op::Sqrt => {
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.sqrt();
            }
        }
        Op::Clip { lo, hi } => {
            let (lo, hi) = (*lo as f32, *hi as f32);
            for (o, &x) in out.iter_mut().zip(a) {
                *o = x.clamp(lo, hi);
            }
        }
        _ => unreachable!("not a fusible unary op: {op:?}"),
    }
}

/// Straight-line binary loop, op dispatch hoisted out (see [`run1`]).
fn run2(op: &Op, a: &[f32], b: &[f32], out: &mut [f32]) {
    match op {
        Op::Add => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x + y;
            }
        }
        Op::Sub => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x - y;
            }
        }
        Op::Mul => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x * y;
            }
        }
        Op::Div => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x / y;
            }
        }
        Op::Minimum => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x.min(y);
            }
        }
        Op::Maximum => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x.max(y);
            }
        }
        _ => unreachable!("not a fusible binary op: {op:?}"),
    }
}

/// The blockwise engine: gather each input's block once, run each step as
/// one loop, threaded over chunk boundaries aligned to [`BLOCK`].
pub(crate) fn run_blockwise(
    steps: &[FusedStep],
    plan: &FusedPlan,
    bufs: &[&[f32]],
    out: &mut [f32],
) {
    // scalar splat blocks are shared read-only across threads
    let splats: Vec<Option<Vec<f32>>> = plan
        .gathers
        .iter()
        .enumerate()
        .map(|(i, g)| matches!(g, Gather::Splat).then(|| vec![bufs[i][0]; BLOCK]))
        .collect();
    let rank = plan.dims.len();
    parallel::parallel_fill_aligned(out, parallel::PAR_THRESHOLD, BLOCK, |chunk_base, chunk| {
        let mut scratch: Vec<Vec<f32>> = vec![vec![0f32; BLOCK]; plan.num_scratch];
        let mut slots: Vec<Vec<f32>> = vec![vec![0f32; BLOCK]; plan.num_slots];
        let mut odo = vec![0usize; rank];
        let mut pos = 0usize;
        while pos < chunk.len() {
            let len = BLOCK.min(chunk.len() - pos);
            let base = chunk_base + pos;
            for (i, g) in plan.gathers.iter().enumerate() {
                match g {
                    Gather::Contig | Gather::Splat => {}
                    Gather::Suffix { period } => {
                        let blk = &mut scratch[plan.scratch_slot[i].unwrap()];
                        gather_suffix(bufs[i], *period, base, &mut blk[..len]);
                    }
                    Gather::Strided => {
                        let blk = &mut scratch[plan.scratch_slot[i].unwrap()];
                        gather_strided(
                            bufs[i],
                            &plan.strides[i],
                            &plan.dims,
                            &plan.rstrides,
                            base,
                            &mut odo,
                            &mut blk[..len],
                        );
                    }
                }
            }
            for (s, step) in steps.iter().enumerate() {
                // take the destination out of `slots` so the argument
                // resolver can borrow the rest immutably; the plan
                // guarantees the destination never aliases an argument
                let mut taken: Option<Vec<f32>> =
                    plan.step_slot[s].map(|k| std::mem::take(&mut slots[k]));
                {
                    let arg = |a: &FusedArg| -> &[f32] {
                        match a {
                            FusedArg::Input(i) => match &plan.gathers[*i] {
                                Gather::Contig => &bufs[*i][base..base + len],
                                Gather::Splat => &splats[*i].as_ref().unwrap()[..len],
                                _ => &scratch[plan.scratch_slot[*i].unwrap()][..len],
                            },
                            FusedArg::Step(t) => &slots[plan.step_slot[*t].unwrap()][..len],
                        }
                    };
                    let dst: &mut [f32] = match &mut taken {
                        Some(v) => &mut v[..len],
                        None => &mut chunk[pos..pos + len],
                    };
                    if step.args.len() == 1 {
                        run1(&step.op, arg(&step.args[0]), dst);
                    } else {
                        run2(&step.op, arg(&step.args[0]), arg(&step.args[1]), dst);
                    }
                }
                if let (Some(k), Some(v)) = (plan.step_slot[s], taken) {
                    slots[k] = v;
                }
            }
            pos += len;
        }
    });
}

/// The original per-element interpretive walk (differential baseline,
/// forced via `FL_FUSE_INTERP=1`): every step dispatched through
/// [`apply1`]/[`apply2`] per element, every input addressed through its
/// broadcast-strided odometer.
pub(crate) fn run_interpreted(
    steps: &[FusedStep],
    plan: &FusedPlan,
    bufs: &[&[f32]],
    out: &mut [f32],
) {
    let rank = plan.dims.len();
    parallel::parallel_fill(out, parallel::PAR_THRESHOLD, |base, chunk| {
        let mut idx = vec![0usize; rank];
        let mut rem = base;
        for d in 0..rank {
            idx[d] = rem / plan.rstrides[d];
            rem %= plan.rstrides[d];
        }
        let mut offs: Vec<usize> = plan
            .strides
            .iter()
            .map(|st| st.iter().zip(&idx).map(|(s, i)| s * i).sum())
            .collect();
        let mut vals = vec![0f32; steps.len()];
        for slot in chunk.iter_mut() {
            for (s, step) in steps.iter().enumerate() {
                let read = |a: &FusedArg, vals: &[f32]| match a {
                    FusedArg::Input(i) => bufs[*i][offs[*i]],
                    FusedArg::Step(j) => vals[*j],
                };
                vals[s] = if step.args.len() == 1 {
                    apply1(&step.op, read(&step.args[0], &vals))
                } else {
                    apply2(&step.op, read(&step.args[0], &vals), read(&step.args[1], &vals))
                };
            }
            *slot = *vals.last().unwrap();
            for d in (0..rank).rev() {
                idx[d] += 1;
                for (k, st) in plan.strides.iter().enumerate() {
                    offs[k] += st[d];
                }
                if idx[d] < plan.dims[d] {
                    break;
                }
                idx[d] = 0;
                for (k, st) in plan.strides.iter().enumerate() {
                    offs[k] -= st[d] * plan.dims[d];
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::super::fuse::FusedKernel;
    use super::*;
    use crate::tensor::cpu::CpuBackend;
    use crate::tensor::trace::ValueRef;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn shape(dims: &[usize]) -> Shape {
        Shape::new(dims.to_vec())
    }

    fn plan_of(steps: Vec<FusedStep>, in_shapes: &[Shape]) -> FusedPlan {
        FusedPlan::build(&steps, in_shapes).unwrap()
    }

    fn one_step_add(nin: usize) -> Vec<FusedStep> {
        assert_eq!(nin, 2);
        vec![FusedStep { op: Op::Add, args: vec![FusedArg::Input(0), FusedArg::Input(1)] }]
    }

    #[test]
    fn classification_matches_the_map2_taxonomy() {
        // contiguous same-shape
        let p = plan_of(one_step_add(2), &[shape(&[4, 3]), shape(&[4, 3])]);
        assert_eq!(p.gathers, vec![Gather::Contig, Gather::Contig]);
        // scalar splat
        let p = plan_of(one_step_add(2), &[shape(&[4, 3]), shape(&[1])]);
        assert_eq!(p.gathers[1], Gather::Splat);
        // suffix broadcast (bias-add), including a leading explicit 1-dim
        let p = plan_of(one_step_add(2), &[shape(&[4, 3]), shape(&[3])]);
        assert_eq!(p.gathers[1], Gather::Suffix { period: 3 });
        let p = plan_of(one_step_add(2), &[shape(&[5, 2, 3]), shape(&[1, 2, 3])]);
        assert_eq!(p.gathers[1], Gather::Suffix { period: 6 });
        // interior 1-dim inside the suffix block is still a pure modulo
        let p = plan_of(one_step_add(2), &[shape(&[5, 4, 1, 3]), shape(&[4, 1, 3])]);
        assert_eq!(p.gathers[1], Gather::Suffix { period: 12 });
        // middle-axis broadcast: genuinely strided
        let p = plan_of(one_step_add(2), &[shape(&[2, 4, 3]), shape(&[2, 1, 3])]);
        assert_eq!(p.gathers[1], Gather::Strided);
    }

    #[test]
    fn plan_rejects_malformed_kernels() {
        // no steps
        assert!(FusedPlan::build(&[], &[shape(&[2])]).is_err());
        // out-of-range input ref
        let bad = vec![FusedStep { op: Op::Neg, args: vec![FusedArg::Input(3)] }];
        assert!(FusedPlan::build(&bad, &[shape(&[2])]).is_err());
        // non-topological step ref
        let bad = vec![FusedStep { op: Op::Neg, args: vec![FusedArg::Step(0)] }];
        assert!(FusedPlan::build(&bad, &[shape(&[2])]).is_err());
        // wrong arity for the op
        let bad = vec![FusedStep { op: Op::Add, args: vec![FusedArg::Input(0)] }];
        assert!(FusedPlan::build(&bad, &[shape(&[2])]).is_err());
        // op outside the fusible ISA
        let bad = vec![FusedStep { op: Op::Matmul, args: vec![FusedArg::Input(0)] }];
        assert!(FusedPlan::build(&bad, &[shape(&[2])]).is_err());
    }

    #[test]
    fn chains_reuse_two_slots() {
        // a pure chain: each value dies at the next step, so however long
        // the chain, two block buffers alternate (last step writes out)
        let mut steps = vec![FusedStep { op: Op::Abs, args: vec![FusedArg::Input(0)] }];
        for s in 1..8 {
            steps.push(FusedStep { op: Op::Sqrt, args: vec![FusedArg::Step(s - 1)] });
        }
        let p = plan_of(steps, &[shape(&[10])]);
        assert_eq!(p.num_slots, 2);
        assert_eq!(p.step_slot[7], None, "last step writes the output directly");
    }

    #[test]
    fn destination_slot_never_aliases_an_argument_slot() {
        let mut rng = Rng::new(0xA11A5);
        for _ in 0..200 {
            let nsteps = 2 + rng.below(8);
            let mut steps = vec![FusedStep { op: Op::Abs, args: vec![FusedArg::Input(0)] }];
            for s in 1..nsteps {
                let a0 = FusedArg::Step(rng.below(s));
                let args = if rng.below(2) == 0 {
                    vec![a0]
                } else {
                    vec![a0, FusedArg::Step(rng.below(s))]
                };
                let op = if args.len() == 1 { Op::Sqrt } else { Op::Add };
                steps.push(FusedStep { op, args });
            }
            let p = FusedPlan::build(&steps, &[shape(&[4])]).unwrap();
            for (s, step) in steps.iter().enumerate() {
                for a in &step.args {
                    if let FusedArg::Step(t) = a {
                        assert!(p.step_slot[*t].is_some(), "consumed step {t} must hold a slot");
                        if let (Some(d), Some(src)) = (p.step_slot[s], p.step_slot[*t]) {
                            assert_ne!(d, src, "step {s} dest aliases arg {t}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn suffix_gather_wraps_across_block_boundaries() {
        let buf: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let mut out = vec![0f32; 300];
        gather_suffix(&buf, 7, 250, &mut out);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, ((250 + i) % 7) as f32);
        }
    }

    #[test]
    fn strided_gather_matches_division_indexing() {
        // [2,1,3] read against out [2,4,3]
        let ash = shape(&[2, 1, 3]);
        let osh = shape(&[2, 4, 3]);
        let buf: Vec<f32> = (0..6).map(|i| i as f32 * 1.5).collect();
        let bs = ash.broadcast_strides(&osh).unwrap();
        let rs = osh.strides();
        let dims = osh.dims().to_vec();
        for base in [0usize, 5, 17, 23] {
            let len = (osh.numel() - base).min(9);
            let mut out = vec![0f32; len];
            let mut idx = vec![0usize; 3];
            gather_strided(&buf, &bs, &dims, &rs, base, &mut idx, &mut out);
            for (i, v) in out.iter().enumerate() {
                let lin = base + i;
                let mut off = 0;
                let mut rem = lin;
                for d in 0..3 {
                    off += (rem / rs[d]) * bs[d];
                    rem %= rs[d];
                }
                assert_eq!(v.to_bits(), buf[off].to_bits());
            }
        }
    }

    #[test]
    fn block_loops_mirror_scalar_semantics_on_edge_values() {
        let edge = [
            f32::NAN,
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1.5,
            -1.5,
            f32::MIN_POSITIVE,
            -2.0,
            0.25,
        ];
        let unary = [
            Op::Neg,
            Op::Abs,
            Op::Sign,
            Op::Exp,
            Op::Log,
            Op::Tanh,
            Op::Sqrt,
            Op::Clip { lo: -1.0, hi: 0.5 },
        ];
        for op in &unary {
            let mut out = vec![0f32; edge.len()];
            run1(op, &edge, &mut out);
            for (i, &x) in edge.iter().enumerate() {
                assert_eq!(out[i].to_bits(), apply1(op, x).to_bits(), "{op:?} on {x}");
            }
        }
        let binary = [Op::Add, Op::Sub, Op::Mul, Op::Div, Op::Minimum, Op::Maximum];
        for op in &binary {
            for &y in &edge {
                let b = vec![y; edge.len()];
                let mut out = vec![0f32; edge.len()];
                run2(op, &edge, &b, &mut out);
                for (i, &x) in edge.iter().enumerate() {
                    assert_eq!(
                        out[i].to_bits(),
                        apply2(op, x, y).to_bits(),
                        "{op:?} on ({x}, {y})"
                    );
                }
            }
        }
    }

    /// Evaluate the step DAG with one eager CPU dispatch per step — the
    /// strongest oracle: the engines must match what the unfused program
    /// would have computed, bit for bit.
    fn eager_reference(kernel: &FusedKernel, inputs: &[&Tensor]) -> Tensor {
        let cpu = CpuBackend::shared();
        let mut vals: Vec<Tensor> = Vec::new();
        for step in &kernel.steps {
            let t = {
                let args: Vec<&Tensor> = step
                    .args
                    .iter()
                    .map(|a| match a {
                        FusedArg::Input(i) => inputs[*i],
                        FusedArg::Step(s) => &vals[*s],
                    })
                    .collect();
                cpu.dispatch(&step.op, &args).unwrap()
            };
            vals.push(t);
        }
        vals.pop().unwrap()
    }

    fn random_kernel(rng: &mut Rng, nin: usize) -> FusedKernel {
        let unary = [
            Op::Neg,
            Op::Abs,
            Op::Sign,
            Op::Exp,
            Op::Log,
            Op::Tanh,
            Op::Sqrt,
            Op::Clip { lo: -0.75, hi: 1.25 },
        ];
        let binary = [Op::Add, Op::Sub, Op::Mul, Op::Div, Op::Minimum, Op::Maximum];
        let nsteps = 1 + rng.below(7);
        let mut steps: Vec<FusedStep> = Vec::new();
        for s in 0..nsteps {
            // chain arg0 through the previous step so every step (and
            // input 0's full shape) reaches the root; extra args pick
            // random earlier steps or inputs, creating diamonds
            let a0 = if s == 0 {
                FusedArg::Input(0)
            } else {
                FusedArg::Step(s - 1)
            };
            if rng.below(3) == 0 {
                let op = unary[rng.below(unary.len())].clone();
                steps.push(FusedStep { op, args: vec![a0] });
            } else {
                let op = binary[rng.below(binary.len())].clone();
                let a1 = if s > 0 && rng.below(3) == 0 {
                    FusedArg::Step(rng.below(s))
                } else {
                    FusedArg::Input(rng.below(nin))
                };
                steps.push(FusedStep { op, args: vec![a0, a1] });
            }
        }
        let inputs = (0..nin).map(ValueRef::Const).collect();
        FusedKernel::new(inputs, steps)
    }

    #[test]
    fn blockwise_matches_interpreted_and_eager_on_random_dags() {
        let cpu = CpuBackend::shared();
        let mut rng = Rng::new(0xB10C_F00D);
        for case in 0..150 {
            let base = crate::testutil::prop::random_shape(&mut rng, 4, 5);
            let nin = 1 + rng.below(3);
            let mut shapes: Vec<Vec<usize>> = vec![base.clone()];
            for _ in 1..nin {
                shapes.push(crate::testutil::prop::broadcastable_shape(&mut rng, &base));
            }
            let tensors: Vec<Tensor> = shapes
                .iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    let data = crate::testutil::prop::random_vec(&mut rng, n, 2.0);
                    Tensor::from_slice(&data, &s[..])
                })
                .collect();
            let inputs: Vec<&Tensor> = tensors.iter().collect();
            let kernel = random_kernel(&mut rng, nin);
            let blk = kernel.execute_blockwise(cpu.as_ref(), &inputs).unwrap();
            let interp = kernel.execute_interpreted(cpu.as_ref(), &inputs).unwrap();
            let eager = eager_reference(&kernel, &inputs);
            let (bb, ib, eb) = (blk.to_vec(), interp.to_vec(), eager.to_vec());
            assert_eq!(blk.dims(), eager.dims(), "case {case}: shape");
            for i in 0..bb.len() {
                assert_eq!(
                    bb[i].to_bits(),
                    ib[i].to_bits(),
                    "case {case}, elem {i}: blockwise vs interpreted"
                );
                assert_eq!(
                    bb[i].to_bits(),
                    eb[i].to_bits(),
                    "case {case}, elem {i}: blockwise vs eager"
                );
            }
        }
    }

    #[test]
    fn large_outputs_cross_the_parallel_threshold_bit_identically() {
        // [33, 1024] output (33792 > PAR_THRESHOLD) with one contiguous,
        // one suffix, one scalar and one strided input
        let cpu = CpuBackend::shared();
        let mut rng = Rng::new(0x51AB);
        let mk = |dims: &[usize], rng: &mut Rng| {
            let n: usize = dims.iter().product();
            let data = crate::testutil::prop::random_vec(rng, n, 2.0);
            Tensor::from_slice(&data, dims)
        };
        let a = mk(&[33, 1024], &mut rng);
        let b = mk(&[1024], &mut rng);
        let c = mk(&[1], &mut rng);
        let d = mk(&[33, 1], &mut rng);
        let kernel = FusedKernel::new(
            (0..4).map(ValueRef::Const).collect(),
            vec![
                FusedStep { op: Op::Mul, args: vec![FusedArg::Input(0), FusedArg::Input(1)] },
                FusedStep { op: Op::Add, args: vec![FusedArg::Step(0), FusedArg::Input(2)] },
                FusedStep { op: Op::Maximum, args: vec![FusedArg::Step(1), FusedArg::Input(3)] },
                FusedStep { op: Op::Tanh, args: vec![FusedArg::Step(2)] },
            ],
        );
        let inputs = [&a, &b, &c, &d];
        let blk = kernel.execute_blockwise(cpu.as_ref(), &inputs).unwrap();
        let interp = kernel.execute_interpreted(cpu.as_ref(), &inputs).unwrap();
        let eager = eager_reference(&kernel, &inputs);
        assert_eq!(blk.dims(), &[33, 1024]);
        let (bb, ib, eb) = (blk.to_vec(), interp.to_vec(), eager.to_vec());
        for i in 0..bb.len() {
            assert_eq!(bb[i].to_bits(), ib[i].to_bits(), "elem {i} vs interpreted");
            assert_eq!(bb[i].to_bits(), eb[i].to_bits(), "elem {i} vs eager");
        }
    }

    #[test]
    fn rank0_and_zero_sized_outputs_work() {
        let cpu = CpuBackend::shared();
        let kernel = FusedKernel::new(
            vec![ValueRef::Const(0), ValueRef::Const(1)],
            one_step_add(2),
        );
        // rank-0 scalars
        let x = Tensor::from_slice(&[2.0f32], shape(&[]));
        let y = Tensor::from_slice(&[3.0f32], shape(&[]));
        let out = kernel.execute_blockwise(cpu.as_ref(), &[&x, &y]).unwrap();
        assert_eq!(out.to_vec(), vec![5.0]);
        assert_eq!(out.dims(), &[] as &[usize]);
        // zero-sized
        let x = Tensor::zeros([0, 3]);
        let y = Tensor::zeros([0, 3]);
        let out = kernel.execute_blockwise(cpu.as_ref(), &[&x, &y]).unwrap();
        assert_eq!(out.dims(), &[0, 3]);
        assert!(out.to_vec().is_empty());
    }
}
