//! Liveness-based buffer planning for compiled programs.
//!
//! Every instruction defines one value. The plan computes each value's
//! last use, assigns values to a small set of reused *slots* (greedy
//! linear scan over the topological order), and tells the executor when a
//! value can be dropped back to the installed
//! [`crate::memory::MemoryManagerAdapter`]. Program outputs are pinned
//! for the whole run.
//!
//! The invariant — two values whose lifetimes overlap never share a slot
//! — is checked by [`MemoryPlan::check_no_aliasing`] and exercised under
//! instrumented execution in `rust/tests/graph_passes.rs`. The static
//! verifier ([`super::verify`]) re-derives liveness independently from
//! the instruction stream and cross-checks the whole plan — slot
//! interference, free points vs last readers, output pinning, donation
//! frontiers — flagging any divergence as a typed diagnostic.

use super::super::trace::ValueRef;
use super::CompiledInstr;

/// The buffer plan for one [`super::CompiledProgram`].
#[derive(Debug, Clone, Default)]
pub struct MemoryPlan {
    /// Per instruction: the slot its output value occupies.
    pub slot: Vec<usize>,
    /// Per instruction: index of the last instruction that reads its
    /// value (its own index if never read).
    pub last_use: Vec<usize>,
    /// Per instruction `j`: the values that die once `j` has executed
    /// (the executor drops them there).
    pub dies_after: Vec<Vec<usize>>,
    /// Values pinned to the end of the program (requested outputs).
    pub is_output: Vec<bool>,
    /// Total distinct slots — the planned peak buffer count. The naive
    /// plan (keep everything) would use one slot per instruction.
    pub num_slots: usize,
    /// Per constant-pool slot: the index of the last instruction that
    /// reads it, or `None` if the constant is unused or is itself a
    /// requested output (and therefore must survive the whole run). This
    /// is the donation frontier: a caller-owned input substituted into a
    /// droppable slot can be released back to the memory manager as soon
    /// as that instruction retires, letting `params'` reuse the storage
    /// `params` occupied instead of growing the footprint every step.
    pub const_last_use: Vec<Option<usize>>,
}

impl MemoryPlan {
    /// Build the plan from the instruction stream, requested outputs, and
    /// the size of the constant pool the instructions index into.
    pub fn build(
        instrs: &[CompiledInstr],
        outputs: &[ValueRef],
        num_consts: usize,
    ) -> MemoryPlan {
        let n = instrs.len();
        let mut const_last_use: Vec<Option<usize>> = vec![None; num_consts];
        for (j, instr) in instrs.iter().enumerate() {
            for r in instr.inputs() {
                if let ValueRef::Const(i) = r {
                    const_last_use[*i] = Some(j);
                }
            }
        }
        // constants that are requested outputs are pinned (never donated)
        for r in outputs {
            if let ValueRef::Const(i) = r {
                const_last_use[*i] = None;
            }
        }
        let mut last_use: Vec<usize> = (0..n).collect();
        for (j, instr) in instrs.iter().enumerate() {
            for r in instr.inputs() {
                if let ValueRef::Out(i) = r {
                    last_use[*i] = (*i).max(j).max(last_use[*i]);
                }
            }
        }
        let mut is_output = vec![false; n];
        for r in outputs {
            if let ValueRef::Out(i) = r {
                is_output[*i] = true;
            }
        }
        let mut dies_after: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            if !is_output[i] {
                dies_after[last_use[i]].push(i);
            }
        }
        // greedy slot reuse over the topological order
        let mut slot = vec![usize::MAX; n];
        let mut free: Vec<usize> = Vec::new();
        let mut num_slots = 0usize;
        for j in 0..n {
            slot[j] = free.pop().unwrap_or_else(|| {
                num_slots += 1;
                num_slots - 1
            });
            for &dead in &dies_after[j] {
                free.push(slot[dead]);
            }
        }
        MemoryPlan { slot, last_use, dies_after, is_output, num_slots, const_last_use }
    }

    /// Verify that no two values with overlapping lifetimes share a slot.
    /// A value lives from its defining instruction until after its last
    /// use (or to the end of the program, for outputs).
    pub fn check_no_aliasing(&self) -> Result<(), String> {
        let n = self.slot.len();
        let end = |i: usize| if self.is_output[i] { n } else { self.last_use[i] };
        for a in 0..n {
            for b in (a + 1)..n {
                // b defined at b; a dies after end(a): overlap iff b <= end(a)
                if self.slot[a] == self.slot[b] && b <= end(a) {
                    return Err(format!(
                        "slot {} aliased: value {a} (live through {}) and value {b}",
                        self.slot[a],
                        end(a)
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::op::Op;
    use super::*;

    fn op(op: Op, inputs: Vec<ValueRef>) -> CompiledInstr {
        CompiledInstr::Op { op, inputs }
    }

    #[test]
    fn chain_reuses_two_slots() {
        // v0 -> v1 -> v2 -> v3, only v3 requested: at any time one value
        // is being read and one written, so two slots suffice
        let instrs = vec![
            op(
                Op::Full { shape: vec![4].into(), value: 1.0, dtype: crate::tensor::DType::F32 },
                vec![],
            ),
            op(Op::Neg, vec![ValueRef::Out(0)]),
            op(Op::Abs, vec![ValueRef::Out(1)]),
            op(Op::Exp, vec![ValueRef::Out(2)]),
        ];
        let plan = MemoryPlan::build(&instrs, &[ValueRef::Out(3)], 0);
        assert_eq!(plan.num_slots, 2);
        plan.check_no_aliasing().unwrap();
    }

    #[test]
    fn outputs_are_pinned() {
        let instrs = vec![
            op(
                Op::Full { shape: vec![1].into(), value: 1.0, dtype: crate::tensor::DType::F32 },
                vec![],
            ),
            op(Op::Neg, vec![ValueRef::Out(0)]),
            op(Op::Abs, vec![ValueRef::Out(1)]),
        ];
        // both v0 and v2 requested: v0 must not be freed at its last use
        let plan = MemoryPlan::build(&instrs, &[ValueRef::Out(0), ValueRef::Out(2)], 0);
        assert!(plan.is_output[0] && plan.is_output[2]);
        assert!(plan.dies_after.iter().all(|d| !d.contains(&0)));
        plan.check_no_aliasing().unwrap();
    }

    #[test]
    fn const_last_use_tracks_donation_frontier() {
        let instrs = vec![
            op(Op::Neg, vec![ValueRef::Const(0)]),
            op(Op::Add, vec![ValueRef::Out(0), ValueRef::Const(0)]),
            op(Op::Abs, vec![ValueRef::Out(1)]),
        ];
        let plan = MemoryPlan::build(&instrs, &[ValueRef::Out(2), ValueRef::Const(2)], 3);
        assert_eq!(plan.const_last_use[0], Some(1)); // last read at instr 1
        assert_eq!(plan.const_last_use[1], None); // never read
        assert_eq!(plan.const_last_use[2], None); // requested output: pinned
    }

    #[test]
    fn dead_value_dies_immediately() {
        let instrs = vec![
            op(
                Op::Full { shape: vec![1].into(), value: 1.0, dtype: crate::tensor::DType::F32 },
                vec![],
            ),
            op(
                Op::Full { shape: vec![1].into(), value: 2.0, dtype: crate::tensor::DType::F32 },
                vec![],
            ),
        ];
        let plan = MemoryPlan::build(&instrs, &[ValueRef::Out(1)], 0);
        // v0 is never read: it dies right after its own definition and
        // its slot is recycled for v1
        assert_eq!(plan.last_use[0], 0);
        assert!(plan.dies_after[0].contains(&0));
        assert_eq!(plan.num_slots, 1);
        plan.check_no_aliasing().unwrap();
    }
}
