//! The per-[`Op`] static signature table: arity, input constraints, and
//! the output shape + dtype of every primitive as a *total function* of
//! the input metadata.
//!
//! [`infer`] is the one inference engine of the compiler. It mirrors the
//! reference CPU backend's semantics *exactly* — every rule below cites
//! the kernel it transcribes — so a value the verifier types as
//! `[2, 3] f32` is precisely what `cpu::*` will materialize at run time.
//! The match over [`Op`] is exhaustive **with no wildcard arm**: adding a
//! variant without a signature is a compile error, the same guarantee
//! [`crate::tensor::op::execute`] gives for dispatch routing.
//!
//! Leniency contract: [`infer`] rejects exactly what the backend rejects
//! (or panics on), and accepts everything it accepts. The backend is
//! deliberately coercive about dtypes — integer operands promote, index
//! tensors are cast via `to_vec_i64`, conv/pool inputs are forced to f32
//! — so most constraints here are *shape* constraints; dtype constraints
//! proper only appear at the fusion layer (see [`super::verify`]). Two
//! deliberate asymmetries:
//!
//! - Reduction `axes` out of range are *ignored* by `cpu/reduce.rs`
//!   (`axes.contains(&d)` over real dims), so they are accepted here too.
//!   Single-axis ops (`argmax`/`argmin`/`cumsum`) index `dims[axis]`
//!   directly and do get a range check.
//! - `call_ext` is opaque by design (backend-defined semantics); its
//!   output is unknowable statically and infers as `None`.

use super::super::backend::{Conv2dParams, Pool2dParams};
use super::super::dtype::DType;
use super::super::op::Op;
use super::super::shape::Shape;

/// Statically known metadata of one SSA value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValueMeta {
    /// The value's shape.
    pub shape: Shape,
    /// The value's dtype.
    pub dtype: DType,
}

impl ValueMeta {
    /// Convenience constructor.
    pub fn new(shape: impl Into<Shape>, dtype: DType) -> ValueMeta {
        ValueMeta { shape: shape.into(), dtype }
    }
}

impl std::fmt::Display for ValueMeta {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.shape, self.dtype.name())
    }
}

/// Which class of constraint a signature violation falls into. Mapped
/// 1:1 onto the corresponding [`super::verify::DiagnosticKind`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignatureErrorKind {
    /// Wrong tensor-input count for the op.
    Arity,
    /// An input dtype the op cannot accept.
    DType,
    /// Shapes that fail the op's shape rule (broadcast, rank, bounds…).
    Shape,
}

/// A violated signature constraint.
#[derive(Debug, Clone)]
pub struct SignatureError {
    /// Constraint class.
    pub kind: SignatureErrorKind,
    /// Human-readable description (op name included by the caller).
    pub message: String,
}

impl SignatureError {
    fn shape(message: impl Into<String>) -> SignatureError {
        SignatureError { kind: SignatureErrorKind::Shape, message: message.into() }
    }

    fn arity(message: impl Into<String>) -> SignatureError {
        SignatureError { kind: SignatureErrorKind::Arity, message: message.into() }
    }
}

/// `cpu/mod.rs` float-unary rule: floats pass through, everything else
/// promotes to f32.
fn float_or_f32(d: DType) -> DType {
    if d.is_float() {
        d
    } else {
        DType::F32
    }
}

/// NumPy broadcast of two metas' shapes, as `Shape::broadcast` (which the
/// CPU binop kernels `expect` on).
fn broadcast(op: &Op, a: &Shape, b: &Shape) -> Result<Shape, SignatureError> {
    a.broadcast(b).map_err(|_| {
        SignatureError::shape(format!("`{}`: cannot broadcast {a} with {b}", op.name()))
    })
}

/// `cpu/conv.rs::out_dim`, with the usize-underflow panic and the
/// zero-stride division surfaced as typed errors.
fn conv_out_dim(
    what: &str,
    input: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
) -> Result<usize, SignatureError> {
    if stride == 0 {
        return Err(SignatureError::shape(format!("{what}: stride must be positive")));
    }
    let padded = input + 2 * pad;
    if padded < kernel {
        return Err(SignatureError::shape(format!(
            "{what}: window {kernel} exceeds padded extent {padded}"
        )));
    }
    Ok((padded - kernel) / stride + 1)
}

/// Forward conv2d output shape for `x [N,Cin,H,W]` ⋆ `w [Cout,Cin,Kh,Kw]`
/// (mirrors `cpu/conv.rs::conv2d`).
fn conv2d_out(
    x: &Shape,
    w: &Shape,
    p: &Conv2dParams,
) -> Result<Shape, SignatureError> {
    let (xd, wd) = (x.dims(), w.dims());
    if xd.len() != 4 {
        return Err(SignatureError::shape(format!("conv2d input must be NCHW, got {x}")));
    }
    if wd.len() != 4 {
        return Err(SignatureError::shape(format!("conv2d weight must be OIHW, got {w}")));
    }
    if xd[1] != wd[1] {
        return Err(SignatureError::shape(format!(
            "conv2d channel mismatch: input {x} vs weight {w}"
        )));
    }
    let oh = conv_out_dim("conv2d", xd[2], wd[2], p.stride.0, p.padding.0)?;
    let ow = conv_out_dim("conv2d", xd[3], wd[3], p.stride.1, p.padding.1)?;
    Ok(Shape::new(vec![xd[0], wd[0], oh, ow]))
}

/// Pool2d output shape for NCHW `x` (mirrors `cpu/pool.rs::pool2d`,
/// which pools with zero padding).
fn pool2d_out(x: &Shape, p: &Pool2dParams) -> Result<Shape, SignatureError> {
    let xd = x.dims();
    if xd.len() != 4 {
        return Err(SignatureError::shape(format!("pool2d input must be NCHW, got {x}")));
    }
    let oh = conv_out_dim("pool2d", xd[2], p.kernel.0, p.stride.0, 0)?;
    let ow = conv_out_dim("pool2d", xd[3], p.kernel.1, p.stride.1, 0)?;
    Ok(Shape::new(vec![xd[0], xd[1], oh, ow]))
}

/// Matmul output metadata, transcribing `cpu/matmul.rs::plan` exactly:
/// 1-D operands promote NumPy-style (`[k]` → `[1,k]` / `[k,1]`, the
/// synthetic dim squeezed from the output), inner dims must agree, batch
/// extents must match or broadcast from ≤ 1, and the output batch dims
/// come from the higher-batch-rank operand (ties → lhs). Operands float
/// before promoting, so the result dtype is always a float.
fn matmul_out(a: &ValueMeta, b: &ValueMeta) -> Result<ValueMeta, SignatureError> {
    let (ad, bd) = (a.shape.dims(), b.shape.dims());
    if ad.is_empty() || bd.is_empty() {
        return Err(SignatureError::shape(format!(
            "matmul on scalar: {} x {}",
            a.shape, b.shape
        )));
    }
    let (ad2, squeeze_m): (Vec<usize>, bool) =
        if ad.len() == 1 { (vec![1, ad[0]], true) } else { (ad.to_vec(), false) };
    let (bd2, squeeze_n): (Vec<usize>, bool) =
        if bd.len() == 1 { (vec![bd[0], 1], true) } else { (bd.to_vec(), false) };
    let (m, ka) = (ad2[ad2.len() - 2], ad2[ad2.len() - 1]);
    let (kb, n) = (bd2[bd2.len() - 2], bd2[bd2.len() - 1]);
    if ka != kb {
        return Err(SignatureError::shape(format!(
            "matmul inner dims: {} x {}",
            a.shape, b.shape
        )));
    }
    let a_batch: usize = ad2[..ad2.len() - 2].iter().product();
    let b_batch: usize = bd2[..bd2.len() - 2].iter().product();
    if !(a_batch == b_batch || a_batch <= 1 || b_batch <= 1) {
        return Err(SignatureError::shape(format!(
            "matmul batch mismatch: {} x {}",
            a.shape, b.shape
        )));
    }
    let mut out_dims: Vec<usize> = if ad2.len() - 2 >= bd2.len() - 2 {
        ad2[..ad2.len() - 2].to_vec()
    } else {
        bd2[..bd2.len() - 2].to_vec()
    };
    if !squeeze_m {
        out_dims.push(m);
    }
    if !squeeze_n {
        out_dims.push(n);
    }
    let dtype = float_or_f32(a.dtype).promote(float_or_f32(b.dtype));
    Ok(ValueMeta::new(out_dims, dtype))
}

/// Infer the output metadata of `op` applied to inputs with metadata
/// `inputs` (`None` = statically unknown, e.g. downstream of `call_ext`).
///
/// Returns:
///
/// - `Ok(Some(meta))` — inputs satisfy the signature; `meta` is exactly
///   what the reference backend will produce.
/// - `Ok(None)` — arity is valid but some needed input is opaque (or the
///   op is `call_ext`): nothing can be proven either way.
/// - `Err(e)` — the op *will* fail (or panic) at run time; `e` says how.
///
/// Arity is validated before any metadata is consulted, so a wrong input
/// count is reported even on fully opaque operands.
pub fn infer(
    op: &Op,
    inputs: &[Option<&ValueMeta>],
) -> Result<Option<ValueMeta>, SignatureError> {
    // arity first, mirroring `op::execute`'s run-time gate
    match op.arity() {
        Some(want) if inputs.len() != want => {
            return Err(SignatureError::arity(format!(
                "op `{}` expects {want} tensor input(s), got {}",
                op.name(),
                inputs.len()
            )));
        }
        None if matches!(op, Op::Concat { .. }) && inputs.is_empty() => {
            return Err(SignatureError::arity(
                "op `concat` expects at least one tensor input".to_string(),
            ));
        }
        _ => {}
    }
    // any opaque operand ⇒ the output is opaque too (arity already held)
    let Some(m) = inputs.iter().copied().collect::<Option<Vec<&ValueMeta>>>() else {
        return Ok(None);
    };
    // NOTE: exhaustive over every `Op` variant, deliberately without a
    // wildcard arm — adding an op without a signature must not compile.
    let out = match op {
        // ---- creation: the payload is the signature -----------------------
        Op::Full { shape, dtype, .. } => ValueMeta::new(shape.clone(), *dtype),
        Op::Arange { n, dtype } => ValueMeta::new(vec![*n], *dtype),
        Op::RandUniform { shape, dtype, .. } | Op::RandNormal { shape, dtype, .. } => {
            ValueMeta::new(shape.clone(), *dtype)
        }
        Op::FromHost { host, shape } => {
            if host.len() != shape.numel() {
                return Err(SignatureError::shape(format!(
                    "from_host: {} host element(s) for shape {shape}",
                    host.len()
                )));
            }
            ValueMeta::new(shape.clone(), host.dtype())
        }

        // ---- dtype-preserving unaries -------------------------------------
        Op::Neg | Op::Abs | Op::Sign | Op::Clip { .. } => m[0].clone(),

        // ---- float unaries: integers promote to f32 (`cpu/mod.rs`) --------
        Op::Exp
        | Op::Log
        | Op::Log1p
        | Op::Sin
        | Op::Cos
        | Op::Tanh
        | Op::Sqrt
        | Op::Rsqrt
        | Op::Reciprocal
        | Op::Floor
        | Op::Ceil
        | Op::Round
        | Op::Erf => ValueMeta::new(m[0].shape.clone(), float_or_f32(m[0].dtype)),

        // ---- predicate unaries --------------------------------------------
        Op::LogicalNot | Op::IsNan => ValueMeta::new(m[0].shape.clone(), DType::Bool),

        // ---- binary arithmetic: broadcast + NumPy promotion ---------------
        Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Pow | Op::Minimum | Op::Maximum | Op::Rem => {
            ValueMeta {
                shape: broadcast(op, &m[0].shape, &m[1].shape)?,
                dtype: m[0].dtype.promote(m[1].dtype),
            }
        }

        // ---- comparisons / logicals: broadcast, Bool result ---------------
        Op::Eq
        | Op::Neq
        | Op::Lt
        | Op::Le
        | Op::Gt
        | Op::Ge
        | Op::LogicalAnd
        | Op::LogicalOr => {
            ValueMeta { shape: broadcast(op, &m[0].shape, &m[1].shape)?, dtype: DType::Bool }
        }

        // ---- multi-axis reductions (`cpu/reduce.rs` ignores out-of-range
        // axes, so no range check here — see module docs) -------------------
        Op::Sum { axes, keepdims }
        | Op::Prod { axes, keepdims }
        | Op::MaxReduce { axes, keepdims }
        | Op::MinReduce { axes, keepdims } => {
            ValueMeta::new(m[0].shape.reduce(axes, *keepdims), m[0].dtype)
        }
        Op::Any { axes, keepdims } | Op::All { axes, keepdims } => {
            ValueMeta::new(m[0].shape.reduce(axes, *keepdims), DType::Bool)
        }

        // ---- single-axis reductions: the kernel indexes `dims[axis]` ------
        Op::Argmax { axis, keepdims } | Op::Argmin { axis, keepdims } => {
            if *axis >= m[0].shape.rank() {
                return Err(SignatureError::shape(format!(
                    "`{}`: axis {axis} out of range for {}",
                    op.name(),
                    m[0].shape
                )));
            }
            ValueMeta::new(m[0].shape.reduce(&[*axis], *keepdims), DType::I64)
        }
        Op::Cumsum { axis } => {
            if *axis >= m[0].shape.rank() {
                return Err(SignatureError::shape(format!(
                    "`cumsum`: axis {axis} out of range for {}",
                    m[0].shape
                )));
            }
            m[0].clone()
        }

        // ---- linear algebra -----------------------------------------------
        Op::Matmul => matmul_out(m[0], m[1])?,

        // ---- conv / pool: NCHW, always f32 out (`cpu/{conv,pool}.rs`) -----
        Op::Conv2d(p) => ValueMeta::new(conv2d_out(&m[0].shape, &m[1].shape, p)?, DType::F32),
        Op::Conv2dBwdInput { x_shape, params } => {
            // inputs are (grad_y, w); grad_y must be the forward output
            // shape the kernel slices by
            let expect = conv2d_out(x_shape, &m[1].shape, params)?;
            if m[0].shape != expect {
                return Err(SignatureError::shape(format!(
                    "conv2d_bwd_input: grad shape {} does not match forward output {expect}",
                    m[0].shape
                )));
            }
            ValueMeta::new(x_shape.clone(), DType::F32)
        }
        Op::Conv2dBwdFilter { w_shape, params } => {
            // inputs are (grad_y, x)
            let expect = conv2d_out(&m[1].shape, w_shape, params)?;
            if m[0].shape != expect {
                return Err(SignatureError::shape(format!(
                    "conv2d_bwd_filter: grad shape {} does not match forward output {expect}",
                    m[0].shape
                )));
            }
            ValueMeta::new(w_shape.clone(), DType::F32)
        }
        Op::Pool2d(p) => ValueMeta::new(pool2d_out(&m[0].shape, p)?, DType::F32),
        Op::Pool2dBwd(p) => {
            // inputs are (grad_y, x)
            let expect = pool2d_out(&m[1].shape, p)?;
            if m[0].shape != expect {
                return Err(SignatureError::shape(format!(
                    "pool2d_bwd: grad shape {} does not match forward output {expect}",
                    m[0].shape
                )));
            }
            ValueMeta::new(m[1].shape.clone(), DType::F32)
        }

        // ---- data movement ------------------------------------------------
        Op::Reshape { shape } => {
            if shape.numel() != m[0].shape.numel() {
                return Err(SignatureError::shape(format!(
                    "reshape {} ({} elements) -> {shape} ({} elements)",
                    m[0].shape,
                    m[0].shape.numel(),
                    shape.numel()
                )));
            }
            ValueMeta::new(shape.clone(), m[0].dtype)
        }
        Op::Transpose { perm } => {
            let dims = m[0].shape.dims();
            let mut sorted = perm.clone();
            sorted.sort_unstable();
            if perm.len() != dims.len() || sorted.iter().enumerate().any(|(i, &p)| p != i) {
                return Err(SignatureError::shape(format!(
                    "transpose: {perm:?} is not a permutation of rank {}",
                    dims.len()
                )));
            }
            let out: Vec<usize> = perm.iter().map(|&p| dims[p]).collect();
            ValueMeta::new(out, m[0].dtype)
        }
        Op::Slice { starts, ends } => {
            let dims = m[0].shape.dims();
            if starts.len() != dims.len() || ends.len() != dims.len() {
                return Err(SignatureError::shape(format!(
                    "slice: {} start(s) / {} end(s) for rank {}",
                    starts.len(),
                    ends.len(),
                    dims.len()
                )));
            }
            for d in 0..dims.len() {
                if starts[d] > ends[d] || ends[d] > dims[d] {
                    return Err(SignatureError::shape(format!(
                        "slice dim {d}: [{}, {}) out of bounds for extent {}",
                        starts[d], ends[d], dims[d]
                    )));
                }
            }
            let out: Vec<usize> = ends.iter().zip(starts).map(|(e, s)| e - s).collect();
            ValueMeta::new(out, m[0].dtype)
        }
        Op::Concat { axis } => {
            let first = m[0].shape.dims();
            if *axis >= first.len() {
                return Err(SignatureError::shape(format!(
                    "concat: axis {axis} out of range for {}",
                    m[0].shape
                )));
            }
            let mut along = 0usize;
            for (k, v) in m.iter().enumerate() {
                let dims = v.shape.dims();
                if dims.len() != first.len()
                    || dims
                        .iter()
                        .enumerate()
                        .any(|(d, &x)| d != *axis && x != first[d])
                {
                    return Err(SignatureError::shape(format!(
                        "concat input {k}: {} incompatible with {} along axis {axis}",
                        v.shape, m[0].shape
                    )));
                }
                along += dims[*axis];
            }
            let mut out = first.to_vec();
            out[*axis] = along;
            let dtype = m.iter().fold(m[0].dtype, |d, v| d.promote(v.dtype));
            ValueMeta::new(out, dtype)
        }
        Op::Pad { pads, .. } => {
            let dims = m[0].shape.dims();
            if pads.len() != dims.len() {
                return Err(SignatureError::shape(format!(
                    "pad: {} pad pair(s) for rank {}",
                    pads.len(),
                    dims.len()
                )));
            }
            let out: Vec<usize> =
                dims.iter().zip(pads).map(|(&d, &(b, a))| d + b + a).collect();
            ValueMeta::new(out, m[0].dtype)
        }
        Op::Tile { reps } => {
            let dims = m[0].shape.dims();
            if reps.len() != dims.len() {
                return Err(SignatureError::shape(format!(
                    "tile: {} rep(s) for rank {}",
                    reps.len(),
                    dims.len()
                )));
            }
            let out: Vec<usize> = dims.iter().zip(reps).map(|(&d, &r)| d * r).collect();
            ValueMeta::new(out, m[0].dtype)
        }
        Op::Flip { axes } => {
            let rank = m[0].shape.rank();
            if let Some(&bad) = axes.iter().find(|&&a| a >= rank) {
                return Err(SignatureError::shape(format!(
                    "flip: axis {bad} out of range for {}",
                    m[0].shape
                )));
            }
            m[0].clone()
        }
        Op::IndexSelect { axis } => {
            // inputs are (x, indices); index *values* are runtime-only and
            // indices of any dtype/shape are accepted (cast + flattened)
            let dims = m[0].shape.dims();
            if *axis >= dims.len() {
                return Err(SignatureError::shape(format!(
                    "index_select: axis {axis} out of range for {}",
                    m[0].shape
                )));
            }
            let mut out = dims.to_vec();
            out[*axis] = m[1].shape.numel();
            ValueMeta::new(out, m[0].dtype)
        }
        Op::ScatterAdd => {
            // inputs are (base, indices, src): src rows follow the index
            // count, trailing extents must agree element-for-element
            let (base, idx, src) = (m[0], m[1], m[2]);
            let bd = base.shape.dims();
            let sd = src.shape.dims();
            if bd.is_empty() || sd.is_empty() {
                return Err(SignatureError::shape(format!(
                    "scatter_add: base {} and src {} must have rank >= 1",
                    base.shape, src.shape
                )));
            }
            if sd[0] != idx.shape.numel() {
                return Err(SignatureError::shape(format!(
                    "scatter_add: {} src row(s) for {} index(es)",
                    sd[0],
                    idx.shape.numel()
                )));
            }
            if sd[1..].iter().product::<usize>() != bd[1..].iter().product::<usize>() {
                return Err(SignatureError::shape(format!(
                    "scatter_add: trailing dims mismatch ({} vs {})",
                    src.shape, base.shape
                )));
            }
            ValueMeta::new(base.shape.clone(), base.dtype.promote(src.dtype))
        }
        Op::WhereCond => {
            // (cond, a, b): a⊙b broadcast first, then cond against that
            let ab = broadcast(op, &m[1].shape, &m[2].shape)?;
            ValueMeta {
                shape: broadcast(op, &m[0].shape, &ab)?,
                dtype: m[1].dtype.promote(m[2].dtype),
            }
        }
        Op::Astype { dtype } => ValueMeta::new(m[0].shape.clone(), *dtype),
        Op::Copy => m[0].clone(),

        // ---- extension point: opaque by contract --------------------------
        Op::CallExt { .. } => return Ok(None),
    };
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(dims: &[usize], dtype: DType) -> ValueMeta {
        ValueMeta::new(dims.to_vec(), dtype)
    }

    fn infer1(op: &Op, a: &ValueMeta) -> Result<Option<ValueMeta>, SignatureError> {
        infer(op, &[Some(a)])
    }

    #[test]
    fn binary_broadcasts_and_promotes() {
        let a = meta(&[2, 1], DType::F32);
        let b = meta(&[1, 3], DType::I64);
        let out = infer(&Op::Add, &[Some(&a), Some(&b)]).unwrap().unwrap();
        assert_eq!(out, meta(&[2, 3], DType::F32));
        let bad = meta(&[4], DType::F32);
        let err = infer(&Op::Add, &[Some(&a), Some(&bad)]).unwrap_err();
        assert_eq!(err.kind, SignatureErrorKind::Shape);
    }

    #[test]
    fn arity_checked_before_metadata() {
        let err = infer(&Op::Add, &[None]).unwrap_err();
        assert_eq!(err.kind, SignatureErrorKind::Arity);
        // opaque operands with the right count: unknown, not an error
        assert!(infer(&Op::Add, &[None, None]).unwrap().is_none());
    }

    #[test]
    fn matmul_mirrors_the_kernel_plan() {
        // [2,3] @ [3,4] -> [2,4]
        let out = infer(
            &Op::Matmul,
            &[Some(&meta(&[2, 3], DType::F32)), Some(&meta(&[3, 4], DType::F32))],
        )
        .unwrap()
        .unwrap();
        assert_eq!(out, meta(&[2, 4], DType::F32));
        // 1-D promotion squeezes: [3] @ [3,4] -> [4]
        let out = infer(
            &Op::Matmul,
            &[Some(&meta(&[3], DType::I32)), Some(&meta(&[3, 4], DType::I64))],
        )
        .unwrap()
        .unwrap();
        assert_eq!(out, meta(&[4], DType::F32)); // ints float to f32
        // inner-dim mismatch
        let err = infer(
            &Op::Matmul,
            &[Some(&meta(&[2, 3], DType::F32)), Some(&meta(&[5, 4], DType::F32))],
        )
        .unwrap_err();
        assert_eq!(err.kind, SignatureErrorKind::Shape);
    }

    #[test]
    fn reductions_follow_reduce_rules() {
        let x = meta(&[2, 3, 4], DType::I64);
        let out =
            infer1(&Op::Sum { axes: vec![1], keepdims: true }, &x).unwrap().unwrap();
        assert_eq!(out, meta(&[2, 1, 4], DType::I64));
        let out = infer1(&Op::Any { axes: vec![0, 2], keepdims: false }, &x)
            .unwrap()
            .unwrap();
        assert_eq!(out, meta(&[3], DType::Bool));
        // single-axis ops do range-check
        let err = infer1(&Op::Argmax { axis: 3, keepdims: false }, &x).unwrap_err();
        assert_eq!(err.kind, SignatureErrorKind::Shape);
        assert!(infer1(&Op::Cumsum { axis: 2 }, &x).unwrap().is_some());
    }

    #[test]
    fn data_movement_bounds_are_enforced() {
        let x = meta(&[2, 3], DType::F32);
        assert!(infer1(&Op::Transpose { perm: vec![1, 0] }, &x).is_ok());
        assert!(infer1(&Op::Transpose { perm: vec![0, 0] }, &x).is_err());
        assert!(infer1(&Op::Slice { starts: vec![0, 1], ends: vec![2, 3] }, &x).is_ok());
        assert!(infer1(&Op::Slice { starts: vec![0, 1], ends: vec![2, 4] }, &x).is_err());
        assert!(infer1(&Op::Reshape { shape: vec![6].into() }, &x).is_ok());
        assert!(infer1(&Op::Reshape { shape: vec![7].into() }, &x).is_err());
        let out = infer1(&Op::Pad { pads: vec![(1, 0), (0, 2)], value: 0.0 }, &x)
            .unwrap()
            .unwrap();
        assert_eq!(out.shape.dims(), &[3, 5]);
    }

    #[test]
    fn conv_pool_require_nchw() {
        let p = Conv2dParams { stride: (1, 1), padding: (0, 0) };
        let x = meta(&[1, 2, 5, 5], DType::F32);
        let w = meta(&[3, 2, 3, 3], DType::F32);
        let out = infer(&Op::Conv2d(p), &[Some(&x), Some(&w)]).unwrap().unwrap();
        assert_eq!(out, meta(&[1, 3, 3, 3], DType::F32));
        let bad_w = meta(&[3, 9, 3, 3], DType::F32); // channel mismatch
        assert!(infer(&Op::Conv2d(p), &[Some(&x), Some(&bad_w)]).is_err());
        let pp = Pool2dParams {
            kind: crate::tensor::backend::PoolKind::Max,
            kernel: (2, 2),
            stride: (2, 2),
        };
        let out = infer1(&Op::Pool2d(pp), &x).unwrap().unwrap();
        assert_eq!(out, meta(&[1, 2, 2, 2], DType::F32));
        assert!(infer1(&Op::Pool2d(pp), &meta(&[5, 5], DType::F32)).is_err());
    }

    #[test]
    fn gather_scatter_where() {
        let x = meta(&[4, 3], DType::F32);
        let idx = meta(&[2, 3], DType::I64); // any shape: flattened
        let out =
            infer(&Op::IndexSelect { axis: 0 }, &[Some(&x), Some(&idx)]).unwrap().unwrap();
        assert_eq!(out, meta(&[6, 3], DType::F32));
        let src = meta(&[6, 3], DType::F64);
        let out = infer(&Op::ScatterAdd, &[Some(&x), Some(&idx), Some(&src)])
            .unwrap()
            .unwrap();
        assert_eq!(out, meta(&[4, 3], DType::F64));
        let bad_src = meta(&[6, 2], DType::F32);
        assert!(infer(&Op::ScatterAdd, &[Some(&x), Some(&idx), Some(&bad_src)]).is_err());
        let cond = meta(&[4, 3], DType::Bool);
        let out = infer(&Op::WhereCond, &[Some(&cond), Some(&x), Some(&src)])
            .unwrap()
            .unwrap();
        assert_eq!(out, meta(&[4, 3], DType::F64));
    }

    #[test]
    fn call_ext_is_opaque() {
        assert!(infer(&Op::CallExt { name: "x".into() }, &[]).unwrap().is_none());
        let x = meta(&[2], DType::F32);
        assert!(infer(&Op::CallExt { name: "x".into() }, &[Some(&x)]).unwrap().is_none());
    }
}
