//! Backend subclassing support (paper §5.2.4).
//!
//! Implement [`DelegateBackend`] with an `inner()` backend and override
//! only the methods you care about — every other operation forwards to the
//! inner backend, and one [`impl_delegate_backend!`](macro@crate::impl_delegate_backend)
//! invocation makes the wrapper a full drop-in [`TensorBackend`]. (A
//! blanket `impl<T: DelegateBackend> TensorBackend for T` is ruled out by
//! Rust's coherence rules — it would conflict with the concrete backend
//! impls — so the forwarding impl is generated per-type by the macro.)
//! This is the Rust rendition of the paper's "simply subclass or swap out
//! the existing implementation of the add function ... all add operations
//! in Flashlight dispatch to that operator, so existing baselines and
//! operations will run with the new implementation without any additional
//! code changes."
//!
//! ```ignore
//! struct MyBackend { inner: Arc<dyn TensorBackend> }
//! impl DelegateBackend for MyBackend { /* override what you need */ }
//! flashlight::impl_delegate_backend!(MyBackend);
//! ```

use std::sync::Arc;

use super::backend::{Conv2dParams, Pool2dParams, TensorBackend};
use super::dtype::DType;
use super::host::HostBuffer;
use super::shape::Shape;
use super::Tensor;
use crate::util::error::Result;

/// A backend defined as a set of overrides over an inner backend. Every
/// method defaults to delegation.
#[allow(missing_docs)] // mirrors TensorBackend, documented there
pub trait DelegateBackend: Send + Sync {
    /// The backend receiving non-overridden calls.
    fn inner(&self) -> Arc<dyn TensorBackend>;

    /// Wrapper name.
    fn wrapper_name(&self) -> &str;

    fn full(&self, shape: &Shape, value: f64, dtype: DType) -> Tensor {
        self.inner().full(shape, value, dtype)
    }
    fn arange(&self, n: usize, dtype: DType) -> Tensor {
        self.inner().arange(n, dtype)
    }
    fn rand_uniform(&self, shape: &Shape, lo: f64, hi: f64, dtype: DType) -> Tensor {
        self.inner().rand_uniform(shape, lo, hi, dtype)
    }
    fn rand_normal(&self, shape: &Shape, mean: f64, std: f64, dtype: DType) -> Tensor {
        self.inner().rand_normal(shape, mean, std, dtype)
    }
    fn from_host(&self, host: HostBuffer, shape: Shape) -> Tensor {
        self.inner().from_host(host, shape)
    }
    fn neg(&self, x: &Tensor) -> Tensor {
        self.inner().neg(x)
    }
    fn abs(&self, x: &Tensor) -> Tensor {
        self.inner().abs(x)
    }
    fn sign(&self, x: &Tensor) -> Tensor {
        self.inner().sign(x)
    }
    fn exp(&self, x: &Tensor) -> Tensor {
        self.inner().exp(x)
    }
    fn log(&self, x: &Tensor) -> Tensor {
        self.inner().log(x)
    }
    fn log1p(&self, x: &Tensor) -> Tensor {
        self.inner().log1p(x)
    }
    fn sin(&self, x: &Tensor) -> Tensor {
        self.inner().sin(x)
    }
    fn cos(&self, x: &Tensor) -> Tensor {
        self.inner().cos(x)
    }
    fn tanh(&self, x: &Tensor) -> Tensor {
        self.inner().tanh(x)
    }
    fn sqrt(&self, x: &Tensor) -> Tensor {
        self.inner().sqrt(x)
    }
    fn rsqrt(&self, x: &Tensor) -> Tensor {
        self.inner().rsqrt(x)
    }
    fn reciprocal(&self, x: &Tensor) -> Tensor {
        self.inner().reciprocal(x)
    }
    fn floor(&self, x: &Tensor) -> Tensor {
        self.inner().floor(x)
    }
    fn ceil(&self, x: &Tensor) -> Tensor {
        self.inner().ceil(x)
    }
    fn round(&self, x: &Tensor) -> Tensor {
        self.inner().round(x)
    }
    fn erf(&self, x: &Tensor) -> Tensor {
        self.inner().erf(x)
    }
    fn logical_not(&self, x: &Tensor) -> Tensor {
        self.inner().logical_not(x)
    }
    fn isnan(&self, x: &Tensor) -> Tensor {
        self.inner().isnan(x)
    }
    fn clip(&self, x: &Tensor, lo: f64, hi: f64) -> Tensor {
        self.inner().clip(x, lo, hi)
    }
    fn add(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().add(a, b)
    }
    fn sub(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().sub(a, b)
    }
    fn mul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().mul(a, b)
    }
    fn div(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().div(a, b)
    }
    fn pow(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().pow(a, b)
    }
    fn minimum(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().minimum(a, b)
    }
    fn maximum(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().maximum(a, b)
    }
    fn rem(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().rem(a, b)
    }
    fn eq(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().eq(a, b)
    }
    fn neq(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().neq(a, b)
    }
    fn lt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().lt(a, b)
    }
    fn le(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().le(a, b)
    }
    fn gt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().gt(a, b)
    }
    fn ge(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().ge(a, b)
    }
    fn logical_and(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().logical_and(a, b)
    }
    fn logical_or(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().logical_or(a, b)
    }
    fn sum(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.inner().sum(x, axes, keepdims)
    }
    fn prod(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.inner().prod(x, axes, keepdims)
    }
    fn max_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.inner().max_reduce(x, axes, keepdims)
    }
    fn min_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.inner().min_reduce(x, axes, keepdims)
    }
    fn argmax(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor {
        self.inner().argmax(x, axis, keepdims)
    }
    fn argmin(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor {
        self.inner().argmin(x, axis, keepdims)
    }
    fn any(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.inner().any(x, axes, keepdims)
    }
    fn all(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.inner().all(x, axes, keepdims)
    }
    fn cumsum(&self, x: &Tensor, axis: usize) -> Tensor {
        self.inner().cumsum(x, axis)
    }
    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().matmul(a, b)
    }
    fn conv2d(&self, x: &Tensor, w: &Tensor, p: Conv2dParams) -> Tensor {
        self.inner().conv2d(x, w, p)
    }
    fn conv2d_bwd_input(&self, gy: &Tensor, w: &Tensor, xs: &Shape, p: Conv2dParams) -> Tensor {
        self.inner().conv2d_bwd_input(gy, w, xs, p)
    }
    fn conv2d_bwd_filter(&self, gy: &Tensor, x: &Tensor, ws: &Shape, p: Conv2dParams) -> Tensor {
        self.inner().conv2d_bwd_filter(gy, x, ws, p)
    }
    fn pool2d(&self, x: &Tensor, p: Pool2dParams) -> Tensor {
        self.inner().pool2d(x, p)
    }
    fn pool2d_bwd(&self, gy: &Tensor, x: &Tensor, p: Pool2dParams) -> Tensor {
        self.inner().pool2d_bwd(gy, x, p)
    }
    fn reshape(&self, x: &Tensor, shape: &Shape) -> Tensor {
        self.inner().reshape(x, shape)
    }
    fn transpose(&self, x: &Tensor, perm: &[usize]) -> Tensor {
        self.inner().transpose(x, perm)
    }
    fn slice(&self, x: &Tensor, starts: &[usize], ends: &[usize]) -> Tensor {
        self.inner().slice(x, starts, ends)
    }
    fn concat(&self, xs: &[&Tensor], axis: usize) -> Tensor {
        self.inner().concat(xs, axis)
    }
    fn pad(&self, x: &Tensor, pads: &[(usize, usize)], value: f64) -> Tensor {
        self.inner().pad(x, pads, value)
    }
    fn tile(&self, x: &Tensor, reps: &[usize]) -> Tensor {
        self.inner().tile(x, reps)
    }
    fn flip(&self, x: &Tensor, axes: &[usize]) -> Tensor {
        self.inner().flip(x, axes)
    }
    fn index_select(&self, x: &Tensor, axis: usize, indices: &Tensor) -> Tensor {
        self.inner().index_select(x, axis, indices)
    }
    fn scatter_add(&self, base: &Tensor, indices: &Tensor, src: &Tensor) -> Tensor {
        self.inner().scatter_add(base, indices, src)
    }
    fn where_cond(&self, cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
        self.inner().where_cond(cond, a, b)
    }
    fn astype(&self, x: &Tensor, dtype: DType) -> Tensor {
        self.inner().astype(x, dtype)
    }
    fn copy(&self, x: &Tensor) -> Tensor {
        self.inner().copy(x)
    }
    fn call_ext(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.inner().call_ext(name, inputs)
    }
}

/// Generate the full forwarding `impl TensorBackend` for a type that
/// implements [`DelegateBackend`]. Invoke once per wrapper type:
///
/// ```ignore
/// flashlight::impl_delegate_backend!(MyBackend);
/// ```
#[macro_export]
macro_rules! impl_delegate_backend {
    ($ty:ty) => {
        impl $crate::tensor::TensorBackend for $ty {
            fn name(&self) -> &str {
                $crate::tensor::delegate::DelegateBackend::wrapper_name(self)
            }
            fn full(&self, shape: &$crate::tensor::Shape, value: f64, dtype: $crate::tensor::DType) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::full(self, shape, value, dtype) }
            fn arange(&self, n: usize, dtype: $crate::tensor::DType) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::arange(self, n, dtype) }
            fn rand_uniform(&self, shape: &$crate::tensor::Shape, lo: f64, hi: f64, dtype: $crate::tensor::DType) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::rand_uniform(self, shape, lo, hi, dtype) }
            fn rand_normal(&self, shape: &$crate::tensor::Shape, mean: f64, std: f64, dtype: $crate::tensor::DType) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::rand_normal(self, shape, mean, std, dtype) }
            fn from_host(&self, host: $crate::tensor::HostBuffer, shape: $crate::tensor::Shape) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::from_host(self, host, shape) }
            fn neg(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::neg(self, x) }
            fn abs(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::abs(self, x) }
            fn sign(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::sign(self, x) }
            fn exp(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::exp(self, x) }
            fn log(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::log(self, x) }
            fn log1p(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::log1p(self, x) }
            fn sin(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::sin(self, x) }
            fn cos(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::cos(self, x) }
            fn tanh(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::tanh(self, x) }
            fn sqrt(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::sqrt(self, x) }
            fn rsqrt(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::rsqrt(self, x) }
            fn reciprocal(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::reciprocal(self, x) }
            fn floor(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::floor(self, x) }
            fn ceil(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::ceil(self, x) }
            fn round(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::round(self, x) }
            fn erf(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::erf(self, x) }
            fn logical_not(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::logical_not(self, x) }
            fn isnan(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::isnan(self, x) }
            fn clip(&self, x: &$crate::tensor::Tensor, lo: f64, hi: f64) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::clip(self, x, lo, hi) }
            fn add(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::add(self, a, b) }
            fn sub(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::sub(self, a, b) }
            fn mul(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::mul(self, a, b) }
            fn div(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::div(self, a, b) }
            fn pow(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::pow(self, a, b) }
            fn minimum(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::minimum(self, a, b) }
            fn maximum(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::maximum(self, a, b) }
            fn rem(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::rem(self, a, b) }
            fn eq(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::eq(self, a, b) }
            fn neq(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::neq(self, a, b) }
            fn lt(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::lt(self, a, b) }
            fn le(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::le(self, a, b) }
            fn gt(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::gt(self, a, b) }
            fn ge(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::ge(self, a, b) }
            fn logical_and(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::logical_and(self, a, b) }
            fn logical_or(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::logical_or(self, a, b) }
            fn sum(&self, x: &$crate::tensor::Tensor, axes: &[usize], keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::sum(self, x, axes, keepdims) }
            fn prod(&self, x: &$crate::tensor::Tensor, axes: &[usize], keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::prod(self, x, axes, keepdims) }
            fn max_reduce(&self, x: &$crate::tensor::Tensor, axes: &[usize], keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::max_reduce(self, x, axes, keepdims) }
            fn min_reduce(&self, x: &$crate::tensor::Tensor, axes: &[usize], keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::min_reduce(self, x, axes, keepdims) }
            fn argmax(&self, x: &$crate::tensor::Tensor, axis: usize, keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::argmax(self, x, axis, keepdims) }
            fn argmin(&self, x: &$crate::tensor::Tensor, axis: usize, keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::argmin(self, x, axis, keepdims) }
            fn any(&self, x: &$crate::tensor::Tensor, axes: &[usize], keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::any(self, x, axes, keepdims) }
            fn all(&self, x: &$crate::tensor::Tensor, axes: &[usize], keepdims: bool) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::all(self, x, axes, keepdims) }
            fn cumsum(&self, x: &$crate::tensor::Tensor, axis: usize) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::cumsum(self, x, axis) }
            fn matmul(&self, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::matmul(self, a, b) }
            fn conv2d(&self, x: &$crate::tensor::Tensor, w: &$crate::tensor::Tensor, p: $crate::tensor::Conv2dParams) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::conv2d(self, x, w, p) }
            fn conv2d_bwd_input(&self, gy: &$crate::tensor::Tensor, w: &$crate::tensor::Tensor, xs: &$crate::tensor::Shape, p: $crate::tensor::Conv2dParams) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::conv2d_bwd_input(self, gy, w, xs, p) }
            fn conv2d_bwd_filter(&self, gy: &$crate::tensor::Tensor, x: &$crate::tensor::Tensor, ws: &$crate::tensor::Shape, p: $crate::tensor::Conv2dParams) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::conv2d_bwd_filter(self, gy, x, ws, p) }
            fn pool2d(&self, x: &$crate::tensor::Tensor, p: $crate::tensor::Pool2dParams) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::pool2d(self, x, p) }
            fn pool2d_bwd(&self, gy: &$crate::tensor::Tensor, x: &$crate::tensor::Tensor, p: $crate::tensor::Pool2dParams) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::pool2d_bwd(self, gy, x, p) }
            fn reshape(&self, x: &$crate::tensor::Tensor, shape: &$crate::tensor::Shape) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::reshape(self, x, shape) }
            fn transpose(&self, x: &$crate::tensor::Tensor, perm: &[usize]) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::transpose(self, x, perm) }
            fn slice(&self, x: &$crate::tensor::Tensor, starts: &[usize], ends: &[usize]) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::slice(self, x, starts, ends) }
            fn concat(&self, xs: &[&$crate::tensor::Tensor], axis: usize) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::concat(self, xs, axis) }
            fn pad(&self, x: &$crate::tensor::Tensor, pads: &[(usize, usize)], value: f64) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::pad(self, x, pads, value) }
            fn tile(&self, x: &$crate::tensor::Tensor, reps: &[usize]) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::tile(self, x, reps) }
            fn flip(&self, x: &$crate::tensor::Tensor, axes: &[usize]) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::flip(self, x, axes) }
            fn index_select(&self, x: &$crate::tensor::Tensor, axis: usize, indices: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::index_select(self, x, axis, indices) }
            fn scatter_add(&self, base: &$crate::tensor::Tensor, indices: &$crate::tensor::Tensor, src: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::scatter_add(self, base, indices, src) }
            fn where_cond(&self, cond: &$crate::tensor::Tensor, a: &$crate::tensor::Tensor, b: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::where_cond(self, cond, a, b) }
            fn astype(&self, x: &$crate::tensor::Tensor, dtype: $crate::tensor::DType) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::astype(self, x, dtype) }
            fn copy(&self, x: &$crate::tensor::Tensor) -> $crate::tensor::Tensor { $crate::tensor::delegate::DelegateBackend::copy(self, x) }
            fn call_ext(&self, name: &str, inputs: &[&$crate::tensor::Tensor]) -> $crate::util::error::Result<$crate::tensor::Tensor> { $crate::tensor::delegate::DelegateBackend::call_ext(self, name, inputs) }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::cpu::CpuBackend;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// The paper's §5.2.4 example: a backend that swaps the source of
    /// truth for `add` (here: counts dispatches and delegates).
    struct CountingAdd {
        inner: Arc<dyn TensorBackend>,
        adds: AtomicU64,
    }

    impl DelegateBackend for CountingAdd {
        fn inner(&self) -> Arc<dyn TensorBackend> {
            self.inner.clone()
        }
        fn wrapper_name(&self) -> &str {
            "counting-add"
        }
        fn add(&self, a: &Tensor, b: &Tensor) -> Tensor {
            self.adds.fetch_add(1, Ordering::Relaxed);
            self.inner.add(a, b)
        }
    }

    crate::impl_delegate_backend!(CountingAdd);

    #[test]
    fn override_one_method_delegate_rest() {
        let be = Arc::new(CountingAdd { inner: CpuBackend::shared(), adds: AtomicU64::new(0) });
        let x = TensorBackend::full(be.as_ref(), &Shape::new(vec![3]), 2.0, DType::F32);
        let y = TensorBackend::add(be.as_ref(), &x, &x);
        assert_eq!(y.to_vec(), vec![4.0; 3]);
        // mul (not overridden) delegates without counting
        let _ = TensorBackend::mul(be.as_ref(), &x, &x);
        assert_eq!(be.adds.load(Ordering::Relaxed), 1);
        assert_eq!(TensorBackend::name(be.as_ref()), "counting-add");
    }

    #[test]
    fn composed_ops_route_through_override() {
        // relu = maximum; mean = sum + div... pick gelu which uses add:
        // installed as default backend, *derived* ops pick up the override
        // with zero call-site changes (paper §5.2.4's whole point).
        let be = Arc::new(CountingAdd { inner: CpuBackend::shared(), adds: AtomicU64::new(0) });
        let _guard = crate::tensor::BackendGuard::install(be.clone());
        let t = Tensor::rand([4, 4], -1.0, 1.0);
        let _ = t.gelu(); // gelu composition includes add_scalar -> add
        assert!(be.adds.load(Ordering::Relaxed) >= 1, "derived op did not hit override");
    }
}
