//! Data-movement operations for the CPU backend. All outputs are fresh
//! contiguous buffers (the reference backend trades views for simplicity).

use crate::memory::TypedBuf;
use crate::tensor::shape::Shape;
use crate::tensor::{DType, Tensor};

use super::kernels::map3;
use super::{cast, cpu, dispatch_same, promote_pair, wrap, CpuTensor, Storage};

/// Gather-copy: walk `out_shape` linearly; element i comes from
/// `base + Σ idx[d]·strides[d]` of the input (strides may be negative for
/// flips).
fn strided_gather<T: Copy + Default + Send + Sync>(
    input: &[T],
    out_shape: &Shape,
    strides: &[isize],
    base: isize,
) -> TypedBuf<T> {
    let n = out_shape.numel();
    let mut out = TypedBuf::<T>::zeroed(n);
    let dims = out_shape.dims();
    let rank = dims.len();
    let mut idx = vec![0usize; rank];
    let mut off = base;
    for slot in out.as_mut_slice().iter_mut() {
        *slot = input[off as usize];
        for d in (0..rank).rev() {
            idx[d] += 1;
            off += strides[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            off -= strides[d] * dims[d] as isize;
        }
    }
    out
}

/// Permute dimensions.
pub fn transpose(x: &CpuTensor, perm: &[usize]) -> Tensor {
    let in_strides = x.shape.strides();
    let out_dims: Vec<usize> = perm.iter().map(|&p| x.shape.dims()[p]).collect();
    let out_shape = Shape::new(out_dims);
    let strides: Vec<isize> = perm.iter().map(|&p| in_strides[p] as isize).collect();
    let storage =
        dispatch_same!(&*x.storage, v => strided_gather(v, &out_shape, &strides, 0));
    wrap(storage, out_shape, x.dtype)
}

/// Rectangular slice `[starts, ends)`.
pub fn slice(x: &CpuTensor, starts: &[usize], ends: &[usize]) -> Tensor {
    assert_eq!(starts.len(), x.shape.rank(), "slice starts rank");
    assert_eq!(ends.len(), x.shape.rank(), "slice ends rank");
    let dims = x.shape.dims();
    for d in 0..dims.len() {
        assert!(
            starts[d] <= ends[d] && ends[d] <= dims[d],
            "slice bounds [{}, {}) out of range for dim {} (size {})",
            starts[d],
            ends[d],
            d,
            dims[d]
        );
    }
    let in_strides = x.shape.strides();
    let out_shape = Shape::new(
        starts.iter().zip(ends).map(|(&s, &e)| e - s).collect::<Vec<_>>(),
    );
    let base: isize = starts.iter().zip(&in_strides).map(|(&s, &st)| (s * st) as isize).sum();
    let strides: Vec<isize> = in_strides.iter().map(|&s| s as isize).collect();
    let storage =
        dispatch_same!(&*x.storage, v => strided_gather(v, &out_shape, &strides, base));
    wrap(storage, out_shape, x.dtype)
}

/// Concatenate along `axis`.
pub fn concat(xs: &[&Tensor], axis: usize) -> Tensor {
    assert!(!xs.is_empty());
    let first = cpu(xs[0]);
    let dtype = xs.iter().fold(first.dtype, |d, t| d.promote(t.dtype()));
    let cs: Vec<CpuTensor> = xs.iter().map(|t| cast(&cpu(t), dtype)).collect();
    let rank = first.shape.rank();
    let mut out_dims = first.shape.dims().to_vec();
    out_dims[axis] = cs.iter().map(|c| c.shape.dims()[axis]).sum();
    for c in &cs {
        for d in 0..rank {
            if d != axis {
                assert_eq!(
                    c.shape.dims()[d],
                    out_dims[d],
                    "concat shape mismatch off-axis"
                );
            }
        }
    }
    let out_shape = Shape::new(out_dims.clone());
    let outer: usize = out_dims[..axis].iter().product();
    let inner: usize = out_dims[axis + 1..].iter().product();

    macro_rules! do_concat {
        ($variant:ident, $t:ty) => {{
            let mut out = TypedBuf::<$t>::zeroed(out_shape.numel());
            let o = out.as_mut_slice();
            let mut axis_off = 0usize;
            for c in &cs {
                let len = c.shape.dims()[axis];
                let src = match &*c.storage {
                    Storage::$variant(v) => v.as_slice(),
                    _ => unreachable!(),
                };
                for ob in 0..outer {
                    let dst_start = (ob * out_dims[axis] + axis_off) * inner;
                    let src_start = ob * len * inner;
                    o[dst_start..dst_start + len * inner]
                        .copy_from_slice(&src[src_start..src_start + len * inner]);
                }
                axis_off += len;
            }
            Storage::$variant(out)
        }};
    }
    let storage = match dtype {
        DType::F32 => do_concat!(F32, f32),
        DType::F64 => do_concat!(F64, f64),
        DType::I32 => do_concat!(I32, i32),
        DType::I64 => do_concat!(I64, i64),
        DType::U8 | DType::Bool => do_concat!(U8, u8),
    };
    wrap(storage, out_shape, dtype)
}

/// Constant-pad by `(before, after)` per dimension.
pub fn pad(x: &CpuTensor, pads: &[(usize, usize)], value: f64) -> Tensor {
    assert_eq!(pads.len(), x.shape.rank(), "pad rank mismatch");
    let in_dims = x.shape.dims();
    let out_dims: Vec<usize> =
        in_dims.iter().zip(pads).map(|(&d, &(b, a))| d + b + a).collect();
    let out_shape = Shape::new(out_dims);
    let out_strides = out_shape.strides();
    let base: usize = pads.iter().zip(&out_strides).map(|(&(b, _), &s)| b * s).sum();
    let in_strides_o: Vec<usize> = out_strides.clone();

    macro_rules! do_pad {
        ($v:ident, $t:ty, $conv:expr) => {{
            let src = $v.as_slice();
            let mut out = TypedBuf::<$t>::from_fn(out_shape.numel(), |_| $conv);
            let o = out.as_mut_slice();
            // scatter input into the interior
            let rank = in_dims.len();
            let mut idx = vec![0usize; rank];
            let mut off = base;
            for &val in src {
                o[off] = val;
                for d in (0..rank).rev() {
                    idx[d] += 1;
                    off += in_strides_o[d];
                    if idx[d] < in_dims[d] {
                        break;
                    }
                    idx[d] = 0;
                    off -= in_strides_o[d] * in_dims[d];
                }
            }
            out
        }};
    }
    let storage = match &*x.storage {
        Storage::F32(v) => Storage::F32(do_pad!(v, f32, value as f32)),
        Storage::F64(v) => Storage::F64(do_pad!(v, f64, value)),
        Storage::I32(v) => Storage::I32(do_pad!(v, i32, value as i32)),
        Storage::I64(v) => Storage::I64(do_pad!(v, i64, value as i64)),
        Storage::U8(v) => Storage::U8(do_pad!(v, u8, value as u8)),
    };
    wrap(storage, out_shape, x.dtype)
}

/// Repeat `reps[d]` times along each dimension.
pub fn tile(x: &CpuTensor, reps: &[usize]) -> Tensor {
    assert_eq!(reps.len(), x.shape.rank(), "tile rank mismatch");
    let in_dims = x.shape.dims();
    let out_dims: Vec<usize> = in_dims.iter().zip(reps).map(|(&d, &r)| d * r).collect();
    let out_shape = Shape::new(out_dims.clone());
    let in_strides = x.shape.strides();
    let rank = in_dims.len();
    let storage = dispatch_same!(&*x.storage, v => {
        let src = v.as_slice();
        TypedBuf::from_fn(out_shape.numel(), |flat| {
            // decompose flat out index, wrap each dim into the input
            let mut rem = flat;
            let mut off = 0usize;
            for d in 0..rank {
                let stride_out: usize = out_dims[d + 1..].iter().product();
                let od = rem / stride_out;
                rem %= stride_out;
                off += (od % in_dims[d]) * in_strides[d];
            }
            src[off]
        })
    });
    wrap(storage, out_shape, x.dtype)
}

/// Reverse along `axes`.
pub fn flip(x: &CpuTensor, axes: &[usize]) -> Tensor {
    let in_strides = x.shape.strides();
    let dims = x.shape.dims();
    let mut strides: Vec<isize> = in_strides.iter().map(|&s| s as isize).collect();
    let mut base: isize = 0;
    for &a in axes {
        base += ((dims[a] - 1) * in_strides[a]) as isize;
        strides[a] = -(in_strides[a] as isize);
    }
    let storage =
        dispatch_same!(&*x.storage, v => strided_gather(v, &x.shape, &strides, base));
    wrap(storage, x.shape.clone(), x.dtype)
}

/// Gather along `axis` with 1-D integer indices.
pub fn index_select(x: &CpuTensor, axis: usize, indices: &Tensor) -> Tensor {
    let idx = indices.to_vec_i64();
    let dims = x.shape.dims();
    let len = dims[axis];
    let outer: usize = dims[..axis].iter().product();
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out_dims = dims.to_vec();
    out_dims[axis] = idx.len();
    let out_shape = Shape::new(out_dims);
    for &i in &idx {
        assert!((0..len as i64).contains(&i), "index_select index {i} out of range (len {len})");
    }
    let storage = dispatch_same!(&*x.storage, v => {
        let src = v.as_slice();
        let mut out = TypedBuf::zeroed(out_shape.numel());
        {
            let o = out.as_mut_slice();
            for ob in 0..outer {
                for (pos, &i) in idx.iter().enumerate() {
                    let dst = (ob * idx.len() + pos) * inner;
                    let s = (ob * len + i as usize) * inner;
                    o[dst..dst + inner].copy_from_slice(&src[s..s + inner]);
                }
            }
        }
        out
    });
    wrap(storage, out_shape, x.dtype)
}

/// `out = base; out[idx[i], ...] += src[i, ...]` along axis 0.
pub fn scatter_add(base: &Tensor, indices: &Tensor, src: &Tensor) -> Tensor {
    let (cb, cs, d) = promote_pair(base, src);
    let idx = indices.to_vec_i64();
    let rows = cb.shape.dims()[0];
    let inner: usize = cb.shape.dims()[1..].iter().product();
    assert_eq!(cs.shape.dims()[0], idx.len(), "scatter_add: src rows != indices");
    assert_eq!(
        cs.shape.dims()[1..].iter().product::<usize>(),
        inner,
        "scatter_add: trailing dims mismatch"
    );

    macro_rules! do_scatter {
        ($variant:ident) => {{
            let (bv, sv) = match (&*cb.storage, &*cs.storage) {
                (Storage::$variant(b), Storage::$variant(s)) => (b, s),
                _ => unreachable!(),
            };
            let mut out = bv.clone();
            {
                let o = out.as_mut_slice();
                let s = sv.as_slice();
                for (i, &row) in idx.iter().enumerate() {
                    assert!((0..rows as i64).contains(&row), "scatter_add row {row} out of range");
                    let dst = row as usize * inner;
                    for j in 0..inner {
                        o[dst + j] = o[dst + j] + s[i * inner + j];
                    }
                }
            }
            Storage::$variant(out)
        }};
    }
    let storage = match d {
        DType::F32 => do_scatter!(F32),
        DType::F64 => do_scatter!(F64),
        DType::I32 => do_scatter!(I32),
        DType::I64 => do_scatter!(I64),
        DType::U8 | DType::Bool => do_scatter!(U8),
    };
    wrap(storage, cb.shape.clone(), d)
}

/// Broadcasting element-wise select.
pub fn where_cond(cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
    let cc = cast(&cpu(cond), DType::Bool);
    let (ca, cb, d) = promote_pair(a, b);
    let ab_shape = ca.shape.broadcast(&cb.shape).expect("where operands");
    let out_shape = cc.shape.broadcast(&ab_shape).expect("where cond");
    let cv = match &*cc.storage {
        Storage::U8(v) => v,
        _ => unreachable!(),
    };
    macro_rules! do_where {
        ($variant:ident) => {{
            let (av, bv) = match (&*ca.storage, &*cb.storage) {
                (Storage::$variant(x), Storage::$variant(y)) => (x, y),
                _ => unreachable!(),
            };
            Storage::$variant(map3(
                cv,
                &cc.shape,
                av,
                &ca.shape,
                bv,
                &cb.shape,
                &out_shape,
                |c, x, y| if c != 0 { x } else { y },
            ))
        }};
    }
    let storage = match d {
        DType::F32 => do_where!(F32),
        DType::F64 => do_where!(F64),
        DType::I32 => do_where!(I32),
        DType::I64 => do_where!(I64),
        DType::U8 | DType::Bool => do_where!(U8),
    };
    wrap(storage, out_shape, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_2d() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], [2, 3]);
        let tt = t.t();
        assert_eq!(tt.dims(), &[3, 2]);
        assert_eq!(tt.to_vec(), vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        // double transpose is identity
        assert_eq!(tt.t().to_vec(), t.to_vec());
    }

    #[test]
    fn transpose_3d_perm() {
        let t = Tensor::arange(24, DType::F32).reshape(&[2, 3, 4]);
        let p = t.transpose(&[2, 0, 1]);
        assert_eq!(p.dims(), &[4, 2, 3]);
        // element (i,j,k) of p == element (j,k,i) of t
        let tv = t.to_vec();
        let pv = p.to_vec();
        for i in 0..4 {
            for j in 0..2 {
                for k in 0..3 {
                    assert_eq!(pv[(i * 2 + j) * 3 + k], tv[(j * 3 + k) * 4 + i]);
                }
            }
        }
    }

    #[test]
    fn slice_and_bounds() {
        let t = Tensor::arange(12, DType::F32).reshape(&[3, 4]);
        let s = t.slice(&[1, 1], &[3, 3]);
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.to_vec(), vec![5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn concat_axis0_and_1() {
        let a = Tensor::from_slice(&[1.0f32, 2.0], [1, 2]);
        let b = Tensor::from_slice(&[3.0f32, 4.0], [1, 2]);
        let c0 = Tensor::concat(&[&a, &b], 0);
        assert_eq!(c0.dims(), &[2, 2]);
        assert_eq!(c0.to_vec(), vec![1.0, 2.0, 3.0, 4.0]);
        let c1 = Tensor::concat(&[&a, &b], 1);
        assert_eq!(c1.dims(), &[1, 4]);
        assert_eq!(c1.to_vec(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn concat_promotes_dtype() {
        let a = Tensor::from_slice(&[1i32, 2], [2]);
        let b = Tensor::from_slice(&[0.5f32, 1.5], [2]);
        let c = Tensor::concat(&[&a, &b], 0);
        assert_eq!(c.dtype(), DType::F32);
        assert_eq!(c.to_vec(), vec![1.0, 2.0, 0.5, 1.5]);
    }

    #[test]
    fn pad_constant() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [2, 2]);
        let p = t.pad(&[(1, 0), (0, 1)], 9.0);
        assert_eq!(p.dims(), &[3, 3]);
        assert_eq!(p.to_vec(), vec![9., 9., 9., 1., 2., 9., 3., 4., 9.]);
    }

    #[test]
    fn tile_repeats() {
        let t = Tensor::from_slice(&[1.0f32, 2.0], [1, 2]);
        let r = t.tile(&[2, 2]);
        assert_eq!(r.dims(), &[2, 4]);
        assert_eq!(r.to_vec(), vec![1., 2., 1., 2., 1., 2., 1., 2.]);
    }

    #[test]
    fn flip_axes() {
        let t = Tensor::arange(6, DType::F32).reshape(&[2, 3]);
        assert_eq!(t.flip(&[1]).to_vec(), vec![2., 1., 0., 5., 4., 3.]);
        assert_eq!(t.flip(&[0]).to_vec(), vec![3., 4., 5., 0., 1., 2.]);
        assert_eq!(t.flip(&[0, 1]).to_vec(), vec![5., 4., 3., 2., 1., 0.]);
    }

    #[test]
    fn index_select_rows_and_cols() {
        let t = Tensor::arange(6, DType::F32).reshape(&[3, 2]);
        let idx = Tensor::from_slice(&[2i64, 0], [2]);
        let rows = t.index_select(0, &idx);
        assert_eq!(rows.to_vec(), vec![4., 5., 0., 1.]);
        let cols = t.index_select(1, &Tensor::from_slice(&[1i64], [1]));
        assert_eq!(cols.dims(), &[3, 1]);
        assert_eq!(cols.to_vec(), vec![1., 3., 5.]);
    }

    #[test]
    fn scatter_add_accumulates_duplicates() {
        let base = Tensor::zeros([3, 2]);
        let idx = Tensor::from_slice(&[1i64, 1, 0], [3]);
        let src = Tensor::from_slice(&[1.0f32, 1.0, 2.0, 2.0, 5.0, 5.0], [3, 2]);
        let out = base.scatter_add(&idx, &src);
        assert_eq!(out.to_vec(), vec![5., 5., 3., 3., 0., 0.]);
    }

    #[test]
    fn where_broadcasts() {
        let cond = Tensor::from_slice(&[1u8, 0], [2]).astype(DType::Bool);
        let a = Tensor::full([2, 2], 1.0, DType::F32);
        let b = Tensor::full([2, 2], -1.0, DType::F32);
        let out = Tensor::where_cond(&cond, &a, &b);
        assert_eq!(out.to_vec(), vec![1., -1., 1., -1.]);
    }
}
