//! The reference eager CPU backend (paper §4.1.1: "deliberately-compact
//! default implementations").
//!
//! Storage is always contiguous row-major; structural ops copy rather than
//! view (compactness over cleverness — the paper "deliberately abstains
//! from adding small efficiency improvements if they conflict with keeping
//! the codebase simple"). Hot loops (GEMM, conv, large maps) are
//! parallelized over native threads; buffers come from the installed
//! [`crate::memory::MemoryManagerAdapter`].

pub mod conv;
pub mod kernels;
pub mod matmul;
pub mod pool;
pub mod reduce;
pub mod shape_ops;

use std::sync::{Arc, OnceLock};

use super::adapter::TensorAdapter;
use super::backend::{Conv2dParams, Pool2dParams, TensorBackend};
use super::dtype::DType;
use super::host::HostBuffer;
use super::shape::Shape;
use super::Tensor;
use crate::memory::telemetry::OpScope;
use crate::memory::TypedBuf;
use crate::util::error::Result;
use crate::util::rng::with_thread_rng;

/// Dtype-dispatched storage (Bool shares the `U8` variant; the tensor's
/// `dtype` field disambiguates).
pub enum Storage {
    /// f32 elements.
    F32(TypedBuf<f32>),
    /// f64 elements.
    F64(TypedBuf<f64>),
    /// i32 elements.
    I32(TypedBuf<i32>),
    /// i64 elements.
    I64(TypedBuf<i64>),
    /// u8 / bool elements.
    U8(TypedBuf<u8>),
}

impl Storage {
    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I64(v) => v.len(),
            Storage::U8(v) => v.len(),
        }
    }

    /// Whether there are zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Variant's natural dtype (`U8` for bool storage).
    pub fn natural_dtype(&self) -> DType {
        match self {
            Storage::F32(_) => DType::F32,
            Storage::F64(_) => DType::F64,
            Storage::I32(_) => DType::I32,
            Storage::I64(_) => DType::I64,
            Storage::U8(_) => DType::U8,
        }
    }
}

/// Expand `$body` with `$buf` bound to the typed buffer of each variant.
macro_rules! dispatch {
    ($s:expr, $buf:ident => $body:expr) => {
        match $s {
            Storage::F32($buf) => $body,
            Storage::F64($buf) => $body,
            Storage::I32($buf) => $body,
            Storage::I64($buf) => $body,
            Storage::U8($buf) => $body,
        }
    };
}

/// Like `dispatch!` but rebuilds the same variant from the expression.
macro_rules! dispatch_same {
    ($s:expr, $buf:ident => $body:expr) => {
        match $s {
            Storage::F32($buf) => Storage::F32($body),
            Storage::F64($buf) => Storage::F64($body),
            Storage::I32($buf) => Storage::I32($body),
            Storage::I64($buf) => Storage::I64($body),
            Storage::U8($buf) => Storage::U8($body),
        }
    };
}

pub(crate) use {dispatch, dispatch_same};

/// The CPU backend's per-tensor adapter (paper Listing 1): contiguous
/// storage + shape/type metadata.
pub struct CpuTensor {
    /// Shared contiguous storage (reshape is zero-copy).
    pub storage: Arc<Storage>,
    /// Logical shape.
    pub shape: Shape,
    /// Logical dtype (distinguishes Bool from U8).
    pub dtype: DType,
}

impl Clone for CpuTensor {
    fn clone(&self) -> Self {
        CpuTensor { storage: self.storage.clone(), shape: self.shape.clone(), dtype: self.dtype }
    }
}

impl TensorAdapter for CpuTensor {
    fn shape(&self) -> &Shape {
        &self.shape
    }
    fn dtype(&self) -> DType {
        self.dtype
    }
    fn backend(&self) -> Arc<dyn TensorBackend> {
        CpuBackend::shared()
    }
    fn to_host(&self) -> HostBuffer {
        match &*self.storage {
            Storage::F32(v) => HostBuffer::F32(v.as_slice().to_vec()),
            Storage::F64(v) => HostBuffer::F64(v.as_slice().to_vec()),
            Storage::I32(v) => HostBuffer::I32(v.as_slice().to_vec()),
            Storage::I64(v) => HostBuffer::I64(v.as_slice().to_vec()),
            Storage::U8(v) => HostBuffer::U8(v.as_slice().to_vec(), self.dtype == DType::Bool),
        }
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Wrap storage into a public tensor handle.
pub fn wrap(storage: Storage, shape: Shape, dtype: DType) -> Tensor {
    debug_assert_eq!(storage.len(), shape.numel(), "storage/shape mismatch");
    Tensor::from_adapter(Arc::new(CpuTensor { storage: Arc::new(storage), shape, dtype }))
}

/// View a public tensor as a `CpuTensor`, converting through host memory
/// when it belongs to a different backend (cross-backend interop).
pub fn cpu(t: &Tensor) -> CpuTensor {
    if let Some(c) = t.adapter().as_any().downcast_ref::<CpuTensor>() {
        return c.clone();
    }
    let host = t.to_host();
    from_host_storage(host, t.shape().clone())
}

fn from_host_storage(host: HostBuffer, shape: Shape) -> CpuTensor {
    let dtype = host.dtype();
    let storage = match host {
        HostBuffer::F32(v) => Storage::F32(TypedBuf::from_slice(&v)),
        HostBuffer::F64(v) => Storage::F64(TypedBuf::from_slice(&v)),
        HostBuffer::I32(v) => Storage::I32(TypedBuf::from_slice(&v)),
        HostBuffer::I64(v) => Storage::I64(TypedBuf::from_slice(&v)),
        HostBuffer::U8(v, _) => Storage::U8(TypedBuf::from_slice(&v)),
    };
    CpuTensor { storage: Arc::new(storage), shape, dtype }
}

/// Cast a `CpuTensor`'s storage to `to` (identity when already there).
pub fn cast(x: &CpuTensor, to: DType) -> CpuTensor {
    if x.dtype == to {
        return x.clone();
    }
    let storage = match to {
        DType::F32 => {
            Storage::F32(dispatch!(&*x.storage, v => kernels::map1(v, |e| e as f32)))
        }
        DType::F64 => {
            Storage::F64(dispatch!(&*x.storage, v => kernels::map1(v, |e| e as f64)))
        }
        DType::I32 => {
            Storage::I32(dispatch!(&*x.storage, v => kernels::map1(v, |e| e as i32)))
        }
        DType::I64 => {
            Storage::I64(dispatch!(&*x.storage, v => kernels::map1(v, |e| e as i64)))
        }
        DType::U8 => Storage::U8(dispatch!(&*x.storage, v => kernels::map1(v, |e| e as u8))),
        DType::Bool => Storage::U8(
            dispatch!(&*x.storage, v => kernels::map1(v, |e| ((e as f64) != 0.0) as u8)),
        ),
    };
    CpuTensor { storage: Arc::new(storage), shape: x.shape.clone(), dtype: to }
}

/// Promote both operands to their common dtype.
pub fn promote_pair(a: &Tensor, b: &Tensor) -> (CpuTensor, CpuTensor, DType) {
    let (ca, cb) = (cpu(a), cpu(b));
    let d = ca.dtype.promote(cb.dtype);
    (cast(&ca, d), cast(&cb, d), d)
}

/// Promote a tensor to floating point (f32 unless already f64).
pub fn to_float(x: CpuTensor) -> CpuTensor {
    match x.dtype {
        DType::F32 | DType::F64 => x,
        _ => cast(&x, DType::F32),
    }
}

/// f32-native erf (same A&S 7.1.26 polynomial; |err| < ~3e-7 in f32) —
/// the f32 hot path avoids the f64 `exp` that dominated the composed
/// gelu's cost (EXPERIMENTS.md §Perf L3.1).
#[inline]
pub fn erf_f32(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Abramowitz & Stegun 7.1.26 rational approximation of erf (|err| < 1.5e-7).
pub fn erf_scalar(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Same-variant broadcasting arithmetic: `$ff` runs on float variants,
/// `$fi` on integer variants (after dtype promotion both operands share a
/// variant).
macro_rules! binop {
    ($name:literal, $a:expr, $b:expr, $ff:expr, $fi:expr) => {{
        let _g = OpScope::enter($name);
        let (ca, cb, d) = promote_pair($a, $b);
        let out_shape = ca.shape.broadcast(&cb.shape).expect("binop broadcast");
        let storage = match (&*ca.storage, &*cb.storage) {
            (Storage::F32(x), Storage::F32(y)) => {
                Storage::F32(kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, $ff))
            }
            (Storage::F64(x), Storage::F64(y)) => {
                Storage::F64(kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, $ff))
            }
            (Storage::I32(x), Storage::I32(y)) => {
                Storage::I32(kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, $fi))
            }
            (Storage::I64(x), Storage::I64(y)) => {
                Storage::I64(kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, $fi))
            }
            (Storage::U8(x), Storage::U8(y)) => {
                Storage::U8(kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, $fi))
            }
            _ => unreachable!("promote_pair produced mismatched variants"),
        };
        wrap(storage, out_shape, d)
    }};
}

/// Broadcasting comparison: closure returns bool, result dtype Bool.
macro_rules! cmpop {
    ($name:literal, $a:expr, $b:expr, $f:expr) => {{
        let _g = OpScope::enter($name);
        let (ca, cb, _) = promote_pair($a, $b);
        let out_shape = ca.shape.broadcast(&cb.shape).expect("cmp broadcast");
        let f = $f;
        let buf = match (&*ca.storage, &*cb.storage) {
            (Storage::F32(x), Storage::F32(y)) => {
                kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, |a, b| f(a as f64, b as f64) as u8)
            }
            (Storage::F64(x), Storage::F64(y)) => {
                kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, |a, b| f(a, b) as u8)
            }
            (Storage::I32(x), Storage::I32(y)) => {
                kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, |a, b| f(a as f64, b as f64) as u8)
            }
            (Storage::I64(x), Storage::I64(y)) => {
                kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, |a, b| f(a as f64, b as f64) as u8)
            }
            (Storage::U8(x), Storage::U8(y)) => {
                kernels::map2(x, &ca.shape, y, &cb.shape, &out_shape, |a, b| f(a as f64, b as f64) as u8)
            }
            _ => unreachable!(),
        };
        wrap(Storage::U8(buf), out_shape, DType::Bool)
    }};
}

/// Float unary op (integer inputs promote to f32).
macro_rules! unary_float {
    ($name:literal, $x:expr, $f:expr) => {{
        let _g = OpScope::enter($name);
        let cx = to_float(cpu($x));
        let storage = match &*cx.storage {
            Storage::F32(v) => Storage::F32(kernels::map1(v, $f)),
            Storage::F64(v) => Storage::F64(kernels::map1(v, $f)),
            _ => unreachable!("to_float returned non-float"),
        };
        wrap(storage, cx.shape.clone(), cx.dtype)
    }};
}

/// The reference eager backend (stateless; all instances share storage
/// semantics, `shared()` returns the canonical Arc).
pub struct CpuBackend;

impl CpuBackend {
    /// Create an instance (stateless).
    pub fn new() -> Self {
        CpuBackend
    }

    /// The canonical shared instance used by adapters.
    pub fn shared() -> Arc<dyn TensorBackend> {
        static INST: OnceLock<Arc<CpuBackend>> = OnceLock::new();
        INST.get_or_init(|| Arc::new(CpuBackend)).clone() as Arc<dyn TensorBackend>
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl TensorBackend for CpuBackend {
    fn name(&self) -> &str {
        "cpu"
    }

    // ---- creation -------------------------------------------------------

    fn full(&self, shape: &Shape, value: f64, dtype: DType) -> Tensor {
        let n = shape.numel();
        let storage = match dtype {
            DType::F32 => Storage::F32(TypedBuf::from_fn(n, |_| value as f32)),
            DType::F64 => Storage::F64(TypedBuf::from_fn(n, |_| value)),
            DType::I32 => Storage::I32(TypedBuf::from_fn(n, |_| value as i32)),
            DType::I64 => Storage::I64(TypedBuf::from_fn(n, |_| value as i64)),
            DType::U8 => Storage::U8(TypedBuf::from_fn(n, |_| value as u8)),
            DType::Bool => Storage::U8(TypedBuf::from_fn(n, |_| (value != 0.0) as u8)),
        };
        wrap(storage, shape.clone(), dtype)
    }

    fn arange(&self, n: usize, dtype: DType) -> Tensor {
        let storage = match dtype {
            DType::F32 => Storage::F32(TypedBuf::from_fn(n, |i| i as f32)),
            DType::F64 => Storage::F64(TypedBuf::from_fn(n, |i| i as f64)),
            DType::I32 => Storage::I32(TypedBuf::from_fn(n, |i| i as i32)),
            DType::I64 => Storage::I64(TypedBuf::from_fn(n, |i| i as i64)),
            DType::U8 => Storage::U8(TypedBuf::from_fn(n, |i| i as u8)),
            DType::Bool => Storage::U8(TypedBuf::from_fn(n, |i| (i != 0) as u8)),
        };
        wrap(storage, Shape::new(vec![n]), dtype)
    }

    fn rand_uniform(&self, shape: &Shape, lo: f64, hi: f64, dtype: DType) -> Tensor {
        let n = shape.numel();
        let vals: Vec<f64> = with_thread_rng(|r| (0..n).map(|_| r.uniform_range(lo, hi)).collect());
        let host = HostBuffer::F64(vals).cast(dtype);
        self.from_host(host, shape.clone())
    }

    fn rand_normal(&self, shape: &Shape, mean: f64, std: f64, dtype: DType) -> Tensor {
        let n = shape.numel();
        let vals: Vec<f64> = with_thread_rng(|r| (0..n).map(|_| mean + std * r.normal()).collect());
        let host = HostBuffer::F64(vals).cast(dtype);
        self.from_host(host, shape.clone())
    }

    fn from_host(&self, host: HostBuffer, shape: Shape) -> Tensor {
        assert_eq!(host.len(), shape.numel(), "host data length != shape numel");
        let c = from_host_storage(host, shape);
        Tensor::from_adapter(Arc::new(c))
    }

    // ---- unary ----------------------------------------------------------

    fn neg(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("neg");
        let cx = cpu(x);
        let storage = match &*cx.storage {
            Storage::F32(v) => Storage::F32(kernels::map1(v, |e| -e)),
            Storage::F64(v) => Storage::F64(kernels::map1(v, |e| -e)),
            Storage::I32(v) => Storage::I32(kernels::map1(v, |e| e.wrapping_neg())),
            Storage::I64(v) => Storage::I64(kernels::map1(v, |e| e.wrapping_neg())),
            Storage::U8(v) => Storage::U8(kernels::map1(v, |e| e.wrapping_neg())),
        };
        wrap(storage, cx.shape.clone(), cx.dtype)
    }

    fn abs(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("abs");
        let cx = cpu(x);
        let storage = match &*cx.storage {
            Storage::F32(v) => Storage::F32(kernels::map1(v, |e| e.abs())),
            Storage::F64(v) => Storage::F64(kernels::map1(v, |e| e.abs())),
            Storage::I32(v) => Storage::I32(kernels::map1(v, |e| e.wrapping_abs())),
            Storage::I64(v) => Storage::I64(kernels::map1(v, |e| e.wrapping_abs())),
            Storage::U8(v) => Storage::U8(kernels::map1(v, |e| e)),
        };
        wrap(storage, cx.shape.clone(), cx.dtype)
    }

    fn sign(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("sign");
        let cx = cpu(x);
        let storage = match &*cx.storage {
            Storage::F32(v) => {
                Storage::F32(kernels::map1(v, |e| if e > 0.0 { 1.0 } else if e < 0.0 { -1.0 } else { 0.0 }))
            }
            Storage::F64(v) => {
                Storage::F64(kernels::map1(v, |e| if e > 0.0 { 1.0 } else if e < 0.0 { -1.0 } else { 0.0 }))
            }
            Storage::I32(v) => Storage::I32(kernels::map1(v, |e| e.signum())),
            Storage::I64(v) => Storage::I64(kernels::map1(v, |e| e.signum())),
            Storage::U8(v) => Storage::U8(kernels::map1(v, |e| (e != 0) as u8)),
        };
        wrap(storage, cx.shape.clone(), cx.dtype)
    }

    fn exp(&self, x: &Tensor) -> Tensor {
        unary_float!("exp", x, |e| e.exp())
    }
    fn log(&self, x: &Tensor) -> Tensor {
        unary_float!("log", x, |e| e.ln())
    }
    fn log1p(&self, x: &Tensor) -> Tensor {
        unary_float!("log1p", x, |e| e.ln_1p())
    }
    fn sin(&self, x: &Tensor) -> Tensor {
        unary_float!("sin", x, |e| e.sin())
    }
    fn cos(&self, x: &Tensor) -> Tensor {
        unary_float!("cos", x, |e| e.cos())
    }
    fn tanh(&self, x: &Tensor) -> Tensor {
        unary_float!("tanh", x, |e| e.tanh())
    }
    fn sqrt(&self, x: &Tensor) -> Tensor {
        unary_float!("sqrt", x, |e| e.sqrt())
    }
    fn rsqrt(&self, x: &Tensor) -> Tensor {
        unary_float!("rsqrt", x, |e| e.sqrt().recip())
    }
    fn reciprocal(&self, x: &Tensor) -> Tensor {
        unary_float!("reciprocal", x, |e| e.recip())
    }
    fn floor(&self, x: &Tensor) -> Tensor {
        unary_float!("floor", x, |e| e.floor())
    }
    fn ceil(&self, x: &Tensor) -> Tensor {
        unary_float!("ceil", x, |e| e.ceil())
    }
    fn round(&self, x: &Tensor) -> Tensor {
        unary_float!("round", x, |e| e.round())
    }

    fn erf(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("erf");
        let cx = to_float(cpu(x));
        let storage = match &*cx.storage {
            Storage::F32(v) => Storage::F32(kernels::map1(v, erf_f32)),
            Storage::F64(v) => Storage::F64(kernels::map1(v, erf_scalar)),
            _ => unreachable!(),
        };
        wrap(storage, cx.shape.clone(), cx.dtype)
    }

    fn logical_not(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("logical_not");
        let cx = cpu(x);
        let buf = dispatch!(&*cx.storage, v => kernels::map1(v, |e| ((e as f64) == 0.0) as u8));
        wrap(Storage::U8(buf), cx.shape.clone(), DType::Bool)
    }

    fn isnan(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("isnan");
        let cx = cpu(x);
        let buf = match &*cx.storage {
            Storage::F32(v) => kernels::map1(v, |e| e.is_nan() as u8),
            Storage::F64(v) => kernels::map1(v, |e| e.is_nan() as u8),
            s => dispatch!(s, v => kernels::map1(v, |_e| 0u8)),
        };
        wrap(Storage::U8(buf), cx.shape.clone(), DType::Bool)
    }

    fn clip(&self, x: &Tensor, lo: f64, hi: f64) -> Tensor {
        let _g = OpScope::enter("clip");
        let cx = cpu(x);
        let storage = match &*cx.storage {
            Storage::F32(v) => {
                Storage::F32(kernels::map1(v, |e| e.clamp(lo as f32, hi as f32)))
            }
            Storage::F64(v) => Storage::F64(kernels::map1(v, |e| e.clamp(lo, hi))),
            Storage::I32(v) => {
                Storage::I32(kernels::map1(v, |e| e.clamp(lo as i32, hi as i32)))
            }
            Storage::I64(v) => {
                Storage::I64(kernels::map1(v, |e| e.clamp(lo as i64, hi as i64)))
            }
            Storage::U8(v) => {
                Storage::U8(kernels::map1(v, |e| e.clamp(lo.max(0.0) as u8, hi.min(255.0) as u8)))
            }
        };
        wrap(storage, cx.shape.clone(), cx.dtype)
    }

    // ---- binary ----------------------------------------------------------

    fn add(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("add", a, b, |x, y| x + y, |x, y| x.wrapping_add(y))
    }
    fn sub(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("sub", a, b, |x, y| x - y, |x, y| x.wrapping_sub(y))
    }
    fn mul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("mul", a, b, |x, y| x * y, |x, y| x.wrapping_mul(y))
    }
    fn div(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("div", a, b, |x, y| x / y, |x, y| if y == 0 { 0 } else { x.wrapping_div(y) })
    }
    fn pow(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!(
            "pow",
            a,
            b,
            |x, y| x.powf(y),
            |x, y| ((x as f64).powf(y as f64)) as _
        )
    }
    fn minimum(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("minimum", a, b, |x, y| x.min(y), |x, y| x.min(y))
    }
    fn maximum(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("maximum", a, b, |x, y| x.max(y), |x, y| x.max(y))
    }
    fn rem(&self, a: &Tensor, b: &Tensor) -> Tensor {
        binop!("rem", a, b, |x, y| x % y, |x, y| if y == 0 { 0 } else { x % y })
    }

    // ---- comparison --------------------------------------------------------

    fn eq(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("eq", a, b, |x: f64, y: f64| x == y)
    }
    fn neq(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("neq", a, b, |x: f64, y: f64| x != y)
    }
    fn lt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("lt", a, b, |x: f64, y: f64| x < y)
    }
    fn le(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("le", a, b, |x: f64, y: f64| x <= y)
    }
    fn gt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("gt", a, b, |x: f64, y: f64| x > y)
    }
    fn ge(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("ge", a, b, |x: f64, y: f64| x >= y)
    }
    fn logical_and(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("logical_and", a, b, |x: f64, y: f64| x != 0.0 && y != 0.0)
    }
    fn logical_or(&self, a: &Tensor, b: &Tensor) -> Tensor {
        cmpop!("logical_or", a, b, |x: f64, y: f64| x != 0.0 || y != 0.0)
    }

    // ---- reductions -----------------------------------------------------------

    fn sum(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        let _g = OpScope::enter("sum");
        reduce::sum(&cpu(x), axes, keepdims)
    }
    fn prod(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        let _g = OpScope::enter("prod");
        reduce::prod(&cpu(x), axes, keepdims)
    }
    fn max_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        let _g = OpScope::enter("max_reduce");
        reduce::max(&cpu(x), axes, keepdims)
    }
    fn min_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        let _g = OpScope::enter("min_reduce");
        reduce::min(&cpu(x), axes, keepdims)
    }
    fn argmax(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor {
        let _g = OpScope::enter("argmax");
        reduce::argminmax(&cpu(x), axis, keepdims, true)
    }
    fn argmin(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor {
        let _g = OpScope::enter("argmin");
        reduce::argminmax(&cpu(x), axis, keepdims, false)
    }
    fn any(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        let _g = OpScope::enter("any");
        reduce::any_all(&cpu(x), axes, keepdims, false)
    }
    fn all(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        let _g = OpScope::enter("all");
        reduce::any_all(&cpu(x), axes, keepdims, true)
    }
    fn cumsum(&self, x: &Tensor, axis: usize) -> Tensor {
        let _g = OpScope::enter("cumsum");
        reduce::cumsum(&cpu(x), axis)
    }

    // ---- linear algebra -----------------------------------------------------------

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let _g = OpScope::enter("matmul");
        matmul::matmul(a, b)
    }

    // ---- nn primitives -----------------------------------------------------------

    fn conv2d(&self, x: &Tensor, w: &Tensor, p: Conv2dParams) -> Tensor {
        let _g = OpScope::enter("conv2d");
        conv::conv2d(x, w, p)
    }
    fn conv2d_bwd_input(
        &self,
        grad_y: &Tensor,
        w: &Tensor,
        x_shape: &Shape,
        p: Conv2dParams,
    ) -> Tensor {
        let _g = OpScope::enter("conv2d_bwd_input");
        conv::conv2d_bwd_input(grad_y, w, x_shape, p)
    }
    fn conv2d_bwd_filter(
        &self,
        grad_y: &Tensor,
        x: &Tensor,
        w_shape: &Shape,
        p: Conv2dParams,
    ) -> Tensor {
        let _g = OpScope::enter("conv2d_bwd_filter");
        conv::conv2d_bwd_filter(grad_y, x, w_shape, p)
    }
    fn pool2d(&self, x: &Tensor, p: Pool2dParams) -> Tensor {
        let _g = OpScope::enter("pool2d");
        pool::pool2d(x, p)
    }
    fn pool2d_bwd(&self, grad_y: &Tensor, x: &Tensor, p: Pool2dParams) -> Tensor {
        let _g = OpScope::enter("pool2d_bwd");
        pool::pool2d_bwd(grad_y, x, p)
    }

    // ---- data movement -----------------------------------------------------------

    fn reshape(&self, x: &Tensor, shape: &Shape) -> Tensor {
        let cx = cpu(x);
        assert_eq!(cx.shape.numel(), shape.numel(), "reshape numel mismatch");
        // zero-copy: share storage under the new shape
        Tensor::from_adapter(Arc::new(CpuTensor {
            storage: cx.storage.clone(),
            shape: shape.clone(),
            dtype: cx.dtype,
        }))
    }
    fn transpose(&self, x: &Tensor, perm: &[usize]) -> Tensor {
        let _g = OpScope::enter("transpose");
        shape_ops::transpose(&cpu(x), perm)
    }
    fn slice(&self, x: &Tensor, starts: &[usize], ends: &[usize]) -> Tensor {
        let _g = OpScope::enter("slice");
        shape_ops::slice(&cpu(x), starts, ends)
    }
    fn concat(&self, xs: &[&Tensor], axis: usize) -> Tensor {
        let _g = OpScope::enter("concat");
        shape_ops::concat(xs, axis)
    }
    fn pad(&self, x: &Tensor, pads: &[(usize, usize)], value: f64) -> Tensor {
        let _g = OpScope::enter("pad");
        shape_ops::pad(&cpu(x), pads, value)
    }
    fn tile(&self, x: &Tensor, reps: &[usize]) -> Tensor {
        let _g = OpScope::enter("tile");
        shape_ops::tile(&cpu(x), reps)
    }
    fn flip(&self, x: &Tensor, axes: &[usize]) -> Tensor {
        let _g = OpScope::enter("flip");
        shape_ops::flip(&cpu(x), axes)
    }
    fn index_select(&self, x: &Tensor, axis: usize, indices: &Tensor) -> Tensor {
        let _g = OpScope::enter("index_select");
        shape_ops::index_select(&cpu(x), axis, indices)
    }
    fn scatter_add(&self, base: &Tensor, indices: &Tensor, src: &Tensor) -> Tensor {
        let _g = OpScope::enter("scatter_add");
        shape_ops::scatter_add(base, indices, src)
    }
    fn where_cond(&self, cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
        let _g = OpScope::enter("where_cond");
        shape_ops::where_cond(cond, a, b)
    }
    fn astype(&self, x: &Tensor, dtype: DType) -> Tensor {
        let cx = cpu(x);
        let out = cast(&cx, dtype);
        Tensor::from_adapter(Arc::new(out))
    }
    fn copy(&self, x: &Tensor) -> Tensor {
        let _g = OpScope::enter("copy");
        let cx = cpu(x);
        let storage = dispatch_same!(&*cx.storage, v => v.clone());
        wrap(storage, cx.shape.clone(), cx.dtype)
    }

    fn call_ext(&self, name: &str, _inputs: &[&Tensor]) -> Result<Tensor> {
        Err(crate::util::error::Error::Unsupported {
            backend: "cpu".into(),
            op: format!("ext:{name}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_promotion_in_binops() {
        let a = Tensor::from_slice(&[1i32, 2], [2]);
        let b = Tensor::from_slice(&[0.5f32, 0.5], [2]);
        let c = a.add(&b);
        assert_eq!(c.dtype(), DType::F32);
        assert_eq!(c.to_vec(), vec![1.5, 2.5]);
    }

    #[test]
    fn unary_int_promotes_to_float() {
        let a = Tensor::from_slice(&[1i64, 2], [2]);
        let e = a.exp();
        assert_eq!(e.dtype(), DType::F32);
        assert!((e.to_vec()[1] - std::f64::consts::E.powi(2) as f32).abs() < 1e-4);
    }

    #[test]
    fn erf_accuracy() {
        // reference values from scipy
        for (x, want) in [(0.0, 0.0), (0.5, 0.5204998778), (1.0, 0.8427007929), (-2.0, -0.9953222650)]
        {
            assert!((erf_scalar(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn comparisons_yield_bool() {
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0], [3]);
        let b = Tensor::from_slice(&[2.0f32, 2.0, 2.0], [3]);
        let lt = a.lt(&b);
        assert_eq!(lt.dtype(), DType::Bool);
        assert_eq!(lt.to_vec(), vec![1.0, 0.0, 0.0]);
        assert_eq!(a.ge(&b).to_vec(), vec![0.0, 1.0, 1.0]);
        assert_eq!(a.eq(&b).to_vec(), vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn div_by_zero_int_is_zero() {
        let a = Tensor::from_slice(&[4i32, 9], [2]);
        let b = Tensor::from_slice(&[0i32, 3], [2]);
        assert_eq!(a.div(&b).to_vec_i64(), vec![0, 3]);
    }

    #[test]
    fn clip_clamps() {
        let a = Tensor::from_slice(&[-5.0f32, 0.5, 5.0], [3]);
        assert_eq!(a.clip(-1.0, 1.0).to_vec(), vec![-1.0, 0.5, 1.0]);
    }

    #[test]
    fn reshape_is_zero_copy() {
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [2, 2]);
        let b = a.reshape(&[4]);
        assert_eq!(b.to_vec(), vec![1.0, 2.0, 3.0, 4.0]);
        // both handles alive and consistent
        assert_eq!(a.dims(), &[2, 2]);
        assert_eq!(b.dims(), &[4]);
    }

    #[test]
    fn rand_respects_bounds_and_dtype() {
        crate::util::rng::seed(1234);
        let u = Tensor::rand([1000], -2.0, 3.0);
        let v = u.to_vec();
        assert!(v.iter().all(|&x| (-2.0..3.0).contains(&x)));
        let n = Tensor::randn([1000], 1.0, 0.5);
        let mean = n.mean(&[], false).item();
        assert!((mean - 1.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn isnan_detects() {
        let a = Tensor::from_slice(&[1.0f32, f32::NAN], [2]);
        assert_eq!(a.isnan().to_vec(), vec![0.0, 1.0]);
        let i = Tensor::from_slice(&[1i32, 2], [2]);
        assert_eq!(i.isnan().to_vec(), vec![0.0, 0.0]);
    }

    #[test]
    fn pow_int_and_float() {
        let a = Tensor::from_slice(&[2.0f32, 3.0], [2]);
        let b = Tensor::from_slice(&[3.0f32, 2.0], [2]);
        assert_eq!(a.pow(&b).to_vec(), vec![8.0, 9.0]);
        let ai = Tensor::from_slice(&[2i64, 3], [2]);
        let bi = Tensor::from_slice(&[3i64, 2], [2]);
        assert_eq!(ai.pow(&bi).to_vec_i64(), vec![8, 9]);
    }
}
