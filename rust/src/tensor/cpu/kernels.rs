//! Element-wise kernel machinery for the CPU backend: broadcast-aware
//! map/zip loops with contiguous fast paths.
//!
//! Layout invariant: every CPU tensor is contiguous row-major, so the only
//! non-trivial indexing is broadcasting. Four cases, fastest first:
//! same-shape zip (parallelized), scalar operand, suffix broadcast (e.g.
//! bias add `[n,d]+[d]`, reduced to a modulo), and a general strided
//! odometer walk.

use crate::memory::TypedBuf;
use crate::tensor::shape::Shape;
use crate::util::parallel::{parallel_fill, PAR_THRESHOLD};

/// Unary map over a contiguous buffer.
pub fn map1<T, U>(x: &[T], f: impl Fn(T) -> U + Sync) -> TypedBuf<U>
where
    T: Copy + Send + Sync,
    U: Copy + Default + Send + Sync,
{
    let mut out = TypedBuf::<U>::zeroed(x.len());
    parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            *slot = f(x[base + i]);
        }
    });
    out
}

/// Is `small` a suffix of `big` (exact trailing dims)?
fn is_suffix(small: &Shape, big: &Shape) -> bool {
    let (s, b) = (small.dims(), big.dims());
    s.len() <= b.len() && b[b.len() - s.len()..] == *s && small.numel() > 0
}

/// Broadcast binary zip producing `out_shape` (precomputed by the caller
/// via `Shape::broadcast`).
pub fn map2<T, U>(
    a: &[T],
    ash: &Shape,
    b: &[T],
    bsh: &Shape,
    out_shape: &Shape,
    f: impl Fn(T, T) -> U + Sync,
) -> TypedBuf<U>
where
    T: Copy + Send + Sync,
    U: Copy + Default + Send + Sync,
{
    let n = out_shape.numel();
    let mut out = TypedBuf::<U>::zeroed(n);

    // fast path 1: identical shapes
    if ash == bsh {
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                *slot = f(a[base + i], b[base + i]);
            }
        });
        return out;
    }
    // fast path 2: scalar operands
    if bsh.numel() == 1 && *ash == *out_shape {
        let bv = b[0];
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                *slot = f(a[base + i], bv);
            }
        });
        return out;
    }
    if ash.numel() == 1 && *bsh == *out_shape {
        let av = a[0];
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                *slot = f(av, b[base + i]);
            }
        });
        return out;
    }
    // fast path 3: suffix broadcast (bias-add pattern)
    if *ash == *out_shape && is_suffix(bsh, out_shape) {
        let bl = b.len();
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let idx = base + i;
                *slot = f(a[idx], b[idx % bl]);
            }
        });
        return out;
    }
    if *bsh == *out_shape && is_suffix(ash, out_shape) {
        let al = a.len();
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let idx = base + i;
                *slot = f(a[idx % al], b[idx]);
            }
        });
        return out;
    }

    // general case: strided odometer walk (serial; rare in practice)
    let sa = ash.broadcast_strides(out_shape).expect("map2 lhs not broadcastable");
    let sb = bsh.broadcast_strides(out_shape).expect("map2 rhs not broadcastable");
    let dims = out_shape.dims();
    let rank = dims.len();
    let mut idx = vec![0usize; rank];
    let (mut oa, mut ob) = (0usize, 0usize);
    for slot in out.as_mut_slice().iter_mut() {
        *slot = f(a[oa], b[ob]);
        for d in (0..rank).rev() {
            idx[d] += 1;
            oa += sa[d];
            ob += sb[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            oa -= sa[d] * dims[d];
            ob -= sb[d] * dims[d];
        }
    }
    out
}

/// Three-way broadcast zip (for `where_cond`).
pub fn map3<C, T>(
    c: &[C],
    csh: &Shape,
    a: &[T],
    ash: &Shape,
    b: &[T],
    bsh: &Shape,
    out_shape: &Shape,
    f: impl Fn(C, T, T) -> T + Sync,
) -> TypedBuf<T>
where
    C: Copy + Send + Sync,
    T: Copy + Default + Send + Sync,
{
    let n = out_shape.numel();
    let mut out = TypedBuf::<T>::zeroed(n);
    if csh == out_shape && ash == out_shape && bsh == out_shape {
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let idx = base + i;
                *slot = f(c[idx], a[idx], b[idx]);
            }
        });
        return out;
    }
    let sc = csh.broadcast_strides(out_shape).expect("map3 cond");
    let sa = ash.broadcast_strides(out_shape).expect("map3 lhs");
    let sb = bsh.broadcast_strides(out_shape).expect("map3 rhs");
    let dims = out_shape.dims();
    let rank = dims.len();
    let mut idx = vec![0usize; rank];
    let (mut oc, mut oa, mut ob) = (0usize, 0usize, 0usize);
    for slot in out.as_mut_slice().iter_mut() {
        *slot = f(c[oc], a[oa], b[ob]);
        for d in (0..rank).rev() {
            idx[d] += 1;
            oc += sc[d];
            oa += sa[d];
            ob += sb[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            oc -= sc[d] * dims[d];
            oa -= sa[d] * dims[d];
            ob -= sb[d] * dims[d];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map1_applies() {
        let out = map1(&[1.0f32, -2.0, 3.0], |x| x * 2.0);
        assert_eq!(out.as_slice(), &[2.0, -4.0, 6.0]);
    }

    #[test]
    fn map2_same_shape() {
        let s = Shape::new(vec![3]);
        let out = map2(&[1.0f32, 2.0, 3.0], &s, &[10.0, 20.0, 30.0], &s, &s, |a, b| a + b);
        assert_eq!(out.as_slice(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn map2_scalar_rhs() {
        let s = Shape::new(vec![2, 2]);
        let sc = Shape::scalar();
        let out = map2(&[1.0f32, 2.0, 3.0, 4.0], &s, &[10.0], &sc, &s, |a, b| a * b);
        assert_eq!(out.as_slice(), &[10.0, 20.0, 30.0, 40.0]);
    }

    #[test]
    fn map2_suffix_bias() {
        let s = Shape::new(vec![2, 3]);
        let bs = Shape::new(vec![3]);
        let out =
            map2(&[0.0f32; 6], &s, &[1.0, 2.0, 3.0], &bs, &s, |a, b| a + b);
        assert_eq!(out.as_slice(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn map2_general_broadcast() {
        // [2,1] * [1,3] -> [2,3]
        let a = Shape::new(vec![2, 1]);
        let b = Shape::new(vec![1, 3]);
        let o = a.broadcast(&b).unwrap();
        let out = map2(&[2.0f32, 3.0], &a, &[1.0, 10.0, 100.0], &b, &o, |x, y| x * y);
        assert_eq!(out.as_slice(), &[2., 20., 200., 3., 30., 300.]);
    }

    #[test]
    fn map3_select() {
        let s = Shape::new(vec![3]);
        let out = map3(
            &[1u8, 0, 1],
            &s,
            &[1.0f32, 2.0, 3.0],
            &s,
            &[9.0, 9.0, 9.0],
            &s,
            &s,
            |c, a, b| if c != 0 { a } else { b },
        );
        assert_eq!(out.as_slice(), &[1.0, 9.0, 3.0]);
    }
}
