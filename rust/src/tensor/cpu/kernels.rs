//! Element-wise kernel machinery for the CPU backend: broadcast-aware
//! map/zip loops with contiguous fast paths.
//!
//! Layout invariant: every CPU tensor is contiguous row-major, so the only
//! non-trivial indexing is broadcasting. Four cases, fastest first:
//! same-shape zip (parallelized), scalar operand, suffix broadcast (e.g.
//! bias add `[n,d]+[d]`, reduced to a modulo), and a general strided
//! odometer walk.

use crate::memory::TypedBuf;
use crate::tensor::shape::Shape;
use crate::util::parallel::{parallel_fill, PAR_THRESHOLD};

/// Unary map over a contiguous buffer.
pub fn map1<T, U>(x: &[T], f: impl Fn(T) -> U + Sync) -> TypedBuf<U>
where
    T: Copy + Send + Sync,
    U: Copy + Default + Send + Sync,
{
    let mut out = TypedBuf::<U>::zeroed(x.len());
    parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            *slot = f(x[base + i]);
        }
    });
    out
}

/// Is `small` a suffix of `big` (exact trailing dims)?
fn is_suffix(small: &Shape, big: &Shape) -> bool {
    let (s, b) = (small.dims(), big.dims());
    s.len() <= b.len() && b[b.len() - s.len()..] == *s && small.numel() > 0
}

/// Broadcast binary zip producing `out_shape` (precomputed by the caller
/// via `Shape::broadcast`).
pub fn map2<T, U>(
    a: &[T],
    ash: &Shape,
    b: &[T],
    bsh: &Shape,
    out_shape: &Shape,
    f: impl Fn(T, T) -> U + Sync,
) -> TypedBuf<U>
where
    T: Copy + Send + Sync,
    U: Copy + Default + Send + Sync,
{
    let n = out_shape.numel();
    let mut out = TypedBuf::<U>::zeroed(n);

    // fast path 1: identical shapes
    if ash == bsh {
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                *slot = f(a[base + i], b[base + i]);
            }
        });
        return out;
    }
    // fast path 2: scalar operands
    if bsh.numel() == 1 && *ash == *out_shape {
        let bv = b[0];
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                *slot = f(a[base + i], bv);
            }
        });
        return out;
    }
    if ash.numel() == 1 && *bsh == *out_shape {
        let av = a[0];
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                *slot = f(av, b[base + i]);
            }
        });
        return out;
    }
    // fast path 3: suffix broadcast (bias-add pattern)
    if *ash == *out_shape && is_suffix(bsh, out_shape) {
        let bl = b.len();
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let idx = base + i;
                *slot = f(a[idx], b[idx % bl]);
            }
        });
        return out;
    }
    if *bsh == *out_shape && is_suffix(ash, out_shape) {
        let al = a.len();
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let idx = base + i;
                *slot = f(a[idx % al], b[idx]);
            }
        });
        return out;
    }

    // general case: strided odometer walk, parallelized by seeding each
    // chunk's odometer from its base linear index (the same base-seeded
    // scheme as the blockwise fused-kernel engine in `graph/fuse_exec`);
    // every element is independent, so the split cannot change any value
    if n == 0 {
        // a zero dim makes some row-major strides 0; the base-index
        // decomposition below would divide by them
        return out;
    }
    let sa = ash.broadcast_strides(out_shape).expect("map2 lhs not broadcastable");
    let sb = bsh.broadcast_strides(out_shape).expect("map2 rhs not broadcastable");
    let dims = out_shape.dims().to_vec();
    let rank = dims.len();
    let rs = out_shape.strides();
    parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
        let mut idx = vec![0usize; rank];
        let (mut oa, mut ob) = (0usize, 0usize);
        let mut rem = base;
        for d in 0..rank {
            idx[d] = rem / rs[d];
            rem %= rs[d];
            oa += idx[d] * sa[d];
            ob += idx[d] * sb[d];
        }
        for slot in chunk.iter_mut() {
            *slot = f(a[oa], b[ob]);
            for d in (0..rank).rev() {
                idx[d] += 1;
                oa += sa[d];
                ob += sb[d];
                if idx[d] < dims[d] {
                    break;
                }
                idx[d] = 0;
                oa -= sa[d] * dims[d];
                ob -= sb[d] * dims[d];
            }
        }
    });
    out
}

/// Three-way broadcast zip (for `where_cond`).
pub fn map3<C, T>(
    c: &[C],
    csh: &Shape,
    a: &[T],
    ash: &Shape,
    b: &[T],
    bsh: &Shape,
    out_shape: &Shape,
    f: impl Fn(C, T, T) -> T + Sync,
) -> TypedBuf<T>
where
    C: Copy + Send + Sync,
    T: Copy + Default + Send + Sync,
{
    let n = out_shape.numel();
    let mut out = TypedBuf::<T>::zeroed(n);
    if csh == out_shape && ash == out_shape && bsh == out_shape {
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD, |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let idx = base + i;
                *slot = f(c[idx], a[idx], b[idx]);
            }
        });
        return out;
    }
    let sc = csh.broadcast_strides(out_shape).expect("map3 cond");
    let sa = ash.broadcast_strides(out_shape).expect("map3 lhs");
    let sb = bsh.broadcast_strides(out_shape).expect("map3 rhs");
    let dims = out_shape.dims();
    let rank = dims.len();
    let mut idx = vec![0usize; rank];
    let (mut oc, mut oa, mut ob) = (0usize, 0usize, 0usize);
    for slot in out.as_mut_slice().iter_mut() {
        *slot = f(c[oc], a[oa], b[ob]);
        for d in (0..rank).rev() {
            idx[d] += 1;
            oc += sc[d];
            oa += sa[d];
            ob += sb[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            oc -= sc[d] * dims[d];
            oa -= sa[d] * dims[d];
            ob -= sb[d] * dims[d];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map1_applies() {
        let out = map1(&[1.0f32, -2.0, 3.0], |x| x * 2.0);
        assert_eq!(out.as_slice(), &[2.0, -4.0, 6.0]);
    }

    #[test]
    fn map2_same_shape() {
        let s = Shape::new(vec![3]);
        let out = map2(&[1.0f32, 2.0, 3.0], &s, &[10.0, 20.0, 30.0], &s, &s, |a, b| a + b);
        assert_eq!(out.as_slice(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn map2_scalar_rhs() {
        let s = Shape::new(vec![2, 2]);
        let sc = Shape::scalar();
        let out = map2(&[1.0f32, 2.0, 3.0, 4.0], &s, &[10.0], &sc, &s, |a, b| a * b);
        assert_eq!(out.as_slice(), &[10.0, 20.0, 30.0, 40.0]);
    }

    #[test]
    fn map2_suffix_bias() {
        let s = Shape::new(vec![2, 3]);
        let bs = Shape::new(vec![3]);
        let out =
            map2(&[0.0f32; 6], &s, &[1.0, 2.0, 3.0], &bs, &s, |a, b| a + b);
        assert_eq!(out.as_slice(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn map2_general_broadcast() {
        // [2,1] * [1,3] -> [2,3]
        let a = Shape::new(vec![2, 1]);
        let b = Shape::new(vec![1, 3]);
        let o = a.broadcast(&b).unwrap();
        let out = map2(&[2.0f32, 3.0], &a, &[1.0, 10.0, 100.0], &b, &o, |x, y| x * y);
        assert_eq!(out.as_slice(), &[2., 20., 200., 3., 30., 300.]);
    }

    /// Division-based reference for the broadcast zip: compute each
    /// output element's input offsets independently from its linear index
    /// (no odometer), so it shares no code path with `map2`'s walk.
    fn naive_map2<T: Copy, U>(
        a: &[T],
        ash: &Shape,
        b: &[T],
        bsh: &Shape,
        out_shape: &Shape,
        f: impl Fn(T, T) -> U,
    ) -> Vec<U> {
        let sa = ash.broadcast_strides(out_shape).unwrap();
        let sb = bsh.broadcast_strides(out_shape).unwrap();
        let rs = out_shape.strides();
        (0..out_shape.numel())
            .map(|lin| {
                let (mut oa, mut ob) = (0usize, 0usize);
                let mut rem = lin;
                for d in 0..out_shape.rank() {
                    let i = rem / rs[d];
                    rem %= rs[d];
                    oa += i * sa[d];
                    ob += i * sb[d];
                }
                f(a[oa], b[ob])
            })
            .collect()
    }

    #[test]
    fn map2_middle_axis_broadcast_matches_naive_bitwise() {
        // [2,1,3] op [2,4,3]: neither side equals the output and the lhs
        // is not a suffix -> the general strided path
        let ash = Shape::new(vec![2, 1, 3]);
        let bsh = Shape::new(vec![2, 4, 3]);
        let o = ash.broadcast(&bsh).unwrap();
        assert_eq!(o.dims(), &[2, 4, 3]);
        let a: Vec<f32> = (0..6).map(|i| (i as f32) * 0.31 - 0.9).collect();
        let b: Vec<f32> = (0..24).map(|i| (i as f32) * -0.17 + 1.1).collect();
        let f = |x: f32, y: f32| x * y + y;
        let got = map2(&a, &ash, &b, &bsh, &o, f);
        let want = naive_map2(&a, &ash, &b, &bsh, &o, f);
        for (g, w) in got.as_slice().iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn map2_general_broadcast_i64() {
        // same path, integer dtype: [3,1] op [1,4]
        let ash = Shape::new(vec![3, 1]);
        let bsh = Shape::new(vec![1, 4]);
        let o = ash.broadcast(&bsh).unwrap();
        let a = vec![10i64, 20, 30];
        let b = vec![1i64, 2, 3, 4];
        let got = map2(&a, &ash, &b, &bsh, &o, |x, y| x + y);
        let want = naive_map2(&a, &ash, &b, &bsh, &o, |x, y| x + y);
        assert_eq!(got.as_slice(), &want[..]);
    }

    #[test]
    fn map2_general_broadcast_rank4_crosses_parallel_threshold() {
        // [2,1,8,64] op [2,33,8,64] -> 33792 elements (> PAR_THRESHOLD):
        // the parallel split with base-seeded odometers must be
        // bit-identical to the serial division-based reference
        let ash = Shape::new(vec![2, 1, 8, 64]);
        let bsh = Shape::new(vec![2, 33, 8, 64]);
        let o = ash.broadcast(&bsh).unwrap();
        assert!(o.numel() > PAR_THRESHOLD);
        let a: Vec<f32> = (0..ash.numel()).map(|i| ((i * 37) % 101) as f32 * 0.13 - 2.0).collect();
        let b: Vec<f32> = (0..bsh.numel()).map(|i| ((i * 53) % 97) as f32 * 0.07 - 1.0).collect();
        let f = |x: f32, y: f32| (x - y) * 0.5 + x * y;
        let got = map2(&a, &ash, &b, &bsh, &o, f);
        let want = naive_map2(&a, &ash, &b, &bsh, &o, f);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.as_slice().iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "elem {i}");
        }
    }

    #[test]
    fn map3_select() {
        let s = Shape::new(vec![3]);
        let out = map3(
            &[1u8, 0, 1],
            &s,
            &[1.0f32, 2.0, 3.0],
            &s,
            &[9.0, 9.0, 9.0],
            &s,
            &s,
            |c, a, b| if c != 0 { a } else { b },
        );
        assert_eq!(out.as_slice(), &[1.0, 9.0, 3.0]);
    }
}
