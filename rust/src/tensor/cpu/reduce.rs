//! Reductions for the CPU backend.
//!
//! Floating-point reductions accumulate in f64 (precision over speed for
//! the reference implementation); integer reductions accumulate in i64.
//! The common case — reducing over trailing axes, e.g. softmax's row sums —
//! takes a parallel contiguous-segment fast path.

use crate::memory::TypedBuf;
use crate::tensor::dtype::DType;
use crate::tensor::shape::Shape;
use crate::tensor::Tensor;
use crate::util::parallel::{parallel_fill, PAR_THRESHOLD};

use super::{cpu, wrap, CpuTensor, Storage};

/// Are `axes` exactly the trailing dims of a rank-`rank` shape?
fn is_trailing(axes: &[usize], rank: usize) -> bool {
    !axes.is_empty() && axes.iter().rev().enumerate().all(|(i, &a)| a == rank - 1 - i)
}

/// Generic reduction core. `load` lifts an element into the accumulator
/// domain, `fold` combines, `store` lowers the result.
fn reduce_generic<T, A>(
    x: &[T],
    shape: &Shape,
    axes: &[usize],
    keepdims: bool,
    init: A,
    load: impl Fn(T) -> A + Sync,
    fold: impl Fn(A, A) -> A + Sync,
    store: impl Fn(A) -> T + Sync,
) -> (TypedBuf<T>, Shape)
where
    T: Copy + Default + Send + Sync,
    A: Copy + Send + Sync,
{
    let out_shape_flat = shape.reduce(axes, false);
    let out_shape = shape.reduce(axes, keepdims);
    let out_n = out_shape_flat.numel().max(1);
    let mut out = TypedBuf::<T>::zeroed(out_n);

    if is_trailing(axes, shape.rank()) || axes.len() == shape.rank() {
        // contiguous segments: out[i] = fold(x[i*seg .. (i+1)*seg])
        let seg = if out_n == 0 { 0 } else { x.len() / out_n };
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD / seg.max(1), |base, chunk| {
            for (i, slot) in chunk.iter_mut().enumerate() {
                let row = &x[(base + i) * seg..(base + i + 1) * seg];
                let mut acc = init;
                for &v in row {
                    acc = fold(acc, load(v));
                }
                *slot = store(acc);
            }
        });
        return (out, out_shape);
    }

    // general case: accumulate with an input odometer mapped to out offsets
    let out_strides_flat = out_shape_flat.strides();
    let mut ostride = vec![0usize; shape.rank()];
    let mut oi = 0usize;
    for d in 0..shape.rank() {
        if axes.contains(&d) {
            ostride[d] = 0;
        } else {
            ostride[d] = out_strides_flat[oi];
            oi += 1;
        }
    }
    let mut acc = vec![init; out_n];
    let dims = shape.dims();
    let rank = dims.len();
    let mut idx = vec![0usize; rank];
    let mut off = 0usize;
    for &v in x {
        acc[off] = fold(acc[off], load(v));
        for d in (0..rank).rev() {
            idx[d] += 1;
            off += ostride[d];
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
            off -= ostride[d] * dims[d];
        }
    }
    for (slot, a) in out.as_mut_slice().iter_mut().zip(acc) {
        *slot = store(a);
    }
    (out, out_shape)
}

macro_rules! reduce_dispatch {
    ($x:expr, $axes:expr, $keep:expr, $initf:expr, $ff:expr, $initi:expr, $fi:expr) => {{
        let x = $x;
        let (storage, shape) = match &*x.storage {
            Storage::F32(v) => {
                let (b, s) = reduce_generic(v, &x.shape, $axes, $keep, $initf, |e| e as f64, $ff, |a| a as f32);
                (Storage::F32(b), s)
            }
            Storage::F64(v) => {
                let (b, s) = reduce_generic(v, &x.shape, $axes, $keep, $initf, |e| e, $ff, |a| a);
                (Storage::F64(b), s)
            }
            Storage::I32(v) => {
                let (b, s) = reduce_generic(v, &x.shape, $axes, $keep, $initi, |e| e as i64, $fi, |a| a as i32);
                (Storage::I32(b), s)
            }
            Storage::I64(v) => {
                let (b, s) = reduce_generic(v, &x.shape, $axes, $keep, $initi, |e| e, $fi, |a| a);
                (Storage::I64(b), s)
            }
            Storage::U8(v) => {
                let (b, s) = reduce_generic(v, &x.shape, $axes, $keep, $initi, |e| e as i64, $fi, |a| a as u8);
                (Storage::U8(b), s)
            }
        };
        wrap(storage, shape, x.dtype)
    }};
}

/// Sum over `axes`.
pub fn sum(x: &CpuTensor, axes: &[usize], keepdims: bool) -> Tensor {
    reduce_dispatch!(x, axes, keepdims, 0.0f64, |a, b| a + b, 0i64, |a: i64, b: i64| a.wrapping_add(b))
}

/// Product over `axes`.
pub fn prod(x: &CpuTensor, axes: &[usize], keepdims: bool) -> Tensor {
    reduce_dispatch!(x, axes, keepdims, 1.0f64, |a, b| a * b, 1i64, |a: i64, b: i64| a.wrapping_mul(b))
}

/// Max over `axes`.
pub fn max(x: &CpuTensor, axes: &[usize], keepdims: bool) -> Tensor {
    reduce_dispatch!(x, axes, keepdims, f64::NEG_INFINITY, |a: f64, b: f64| a.max(b), i64::MIN, |a: i64, b: i64| a.max(b))
}

/// Min over `axes`.
pub fn min(x: &CpuTensor, axes: &[usize], keepdims: bool) -> Tensor {
    reduce_dispatch!(x, axes, keepdims, f64::INFINITY, |a: f64, b: f64| a.min(b), i64::MAX, |a: i64, b: i64| a.min(b))
}

/// Logical any (`and=false`) / all (`and=true`) over `axes` (Bool result).
pub fn any_all(x: &CpuTensor, axes: &[usize], keepdims: bool, and: bool) -> Tensor {
    let as_bool = super::cast(x, DType::Bool);
    let t = if and {
        reduce_dispatch!(&as_bool, axes, keepdims, 1.0f64, |a: f64, b: f64| if a != 0.0 && b != 0.0 { 1.0 } else { 0.0 }, 1i64, |a: i64, b: i64| (a != 0 && b != 0) as i64)
    } else {
        reduce_dispatch!(&as_bool, axes, keepdims, 0.0f64, |a: f64, b: f64| if a != 0.0 || b != 0.0 { 1.0 } else { 0.0 }, 0i64, |a: i64, b: i64| (a != 0 || b != 0) as i64)
    };
    t
}

/// Argmax/argmin along one axis (I64 result). First match wins.
pub fn argminmax(x: &CpuTensor, axis: usize, keepdims: bool, want_max: bool) -> Tensor {
    let dims = x.shape.dims();
    let outer: usize = dims[..axis].iter().product();
    let len = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out = TypedBuf::<i64>::zeroed(outer * inner);

    super::dispatch!(&*x.storage, v => {
        let data = v.as_slice();
        parallel_fill(out.as_mut_slice(), PAR_THRESHOLD / len.max(1), |base, chunk| {
            for (ci, slot) in chunk.iter_mut().enumerate() {
                let flat = base + ci;
                let (o, i) = (flat / inner, flat % inner);
                let mut best_k = 0usize;
                let mut best_v = data[(o * len) * inner + i] as f64;
                for k in 1..len {
                    let val = data[(o * len + k) * inner + i] as f64;
                    let better = if want_max { val > best_v } else { val < best_v };
                    if better {
                        best_v = val;
                        best_k = k;
                    }
                }
                *slot = best_k as i64;
            }
        });
    });
    let shape = x.shape.reduce(&[axis], keepdims);
    wrap(Storage::I64(out), shape, DType::I64)
}

/// Inclusive cumulative sum along `axis` (same dtype).
pub fn cumsum(x: &CpuTensor, axis: usize) -> Tensor {
    let dims = x.shape.dims();
    let len = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let outer: usize = dims[..axis].iter().product();
    let storage = super::dispatch_same!(&*x.storage, v => {
        let data = v.as_slice();
        let mut out = TypedBuf::from_slice(data);
        {
            let o = out.as_mut_slice();
            for ob in 0..outer {
                for i in 0..inner {
                    for k in 1..len {
                        let cur = (ob * len + k) * inner + i;
                        let prev = (ob * len + k - 1) * inner + i;
                        o[cur] = o[cur] + o[prev];
                    }
                }
            }
        }
        out
    });
    wrap(storage, x.shape.clone(), x.dtype)
}

/// Convenience: sum everything to a scalar f64.
pub fn sum_all_f64(t: &Tensor) -> f64 {
    let c = cpu(t);
    sum(&c, &(0..c.shape.rank()).collect::<Vec<_>>(), false).item()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_trailing_axis() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], [2, 3]);
        assert_eq!(t.sum(&[1], false).to_vec(), vec![6.0, 15.0]);
        assert_eq!(t.sum(&[1], true).dims(), &[2, 1]);
    }

    #[test]
    fn sum_leading_axis_general_path() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0], [2, 3]);
        assert_eq!(t.sum(&[0], false).to_vec(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn sum_all_and_multiple_axes() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], [2, 2, 2]);
        assert_eq!(t.sum(&[], false).item(), 36.0);
        assert_eq!(t.sum(&[0, 2], false).to_vec(), vec![14.0, 22.0]);
    }

    #[test]
    fn prod_max_min() {
        let t = Tensor::from_slice(&[2.0f32, 3.0, -1.0, 4.0], [2, 2]);
        assert_eq!(t.prod(&[], false).item(), -24.0);
        assert_eq!(t.max(&[1], false).to_vec(), vec![3.0, 4.0]);
        assert_eq!(t.min(&[0], false).to_vec(), vec![-1.0, 3.0]);
    }

    #[test]
    fn int_reductions_stay_int() {
        let t = Tensor::from_slice(&[1i64, 2, 3, 4], [4]);
        let s = t.sum(&[], false);
        assert_eq!(s.dtype(), DType::I64);
        assert_eq!(s.to_vec_i64(), vec![10]);
    }

    #[test]
    fn argmax_argmin() {
        let t = Tensor::from_slice(&[1.0f32, 9.0, 3.0, 7.0, 2.0, 5.0], [2, 3]);
        assert_eq!(t.argmax(1, false).to_vec_i64(), vec![1, 0]);
        assert_eq!(t.argmin(1, false).to_vec_i64(), vec![0, 1]);
        assert_eq!(t.argmax(0, false).to_vec_i64(), vec![1, 0, 1]);
        assert_eq!(t.argmax(1, true).dims(), &[2, 1]);
    }

    #[test]
    fn any_all_bool() {
        let t = Tensor::from_slice(&[0.0f32, 1.0, 0.0, 0.0], [2, 2]);
        assert_eq!(t.any(&[1], false).to_vec(), vec![1.0, 0.0]);
        assert_eq!(t.all(&[1], false).to_vec(), vec![0.0, 0.0]);
        assert_eq!(t.any(&[], false).to_vec(), vec![1.0]);
        assert_eq!(t.any(&[], false).dtype(), DType::Bool);
    }

    #[test]
    fn cumsum_axes() {
        let t = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [2, 2]);
        assert_eq!(t.cumsum(1).to_vec(), vec![1.0, 3.0, 3.0, 7.0]);
        assert_eq!(t.cumsum(0).to_vec(), vec![1.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn large_sum_precision() {
        // f64 accumulation keeps 1M small f32 sums exact enough
        let t = Tensor::full([1_000_000], 0.1, DType::F32);
        let s = t.sum(&[], false).item();
        assert!((s - 100_000.0).abs() / 100_000.0 < 1e-4, "{s}");
    }
}
