//! Batched GEMM for the CPU backend.
//!
//! The kernel is a cache-friendly `i-l-j` loop (rows outer, contraction
//! middle, contiguous output columns inner) so the innermost loop is an
//! axpy the compiler auto-vectorizes. Rows are parallelized across native
//! threads via `chunks_mut`. Integer inputs promote to f32.

use crate::memory::TypedBuf;
use crate::tensor::shape::Shape;
use crate::tensor::Tensor;
use crate::util::parallel::num_threads;

use super::{cast, cpu, to_float, wrap, CpuTensor, Storage};

/// `C += A @ B` where A is `[m,k]`, B is `[k,n]`, C is `[m,n]`, all
/// contiguous row-major. Generic over f32/f64.
pub fn gemm<T>(a: &[T], b: &[T], c: &mut [T], m: usize, k: usize, n: usize)
where
    T: Copy + Default + Send + Sync + std::ops::Mul<Output = T> + std::ops::AddAssign + PartialEq,
{
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let work = m * k * n;
    let threads = if work < 64 * 1024 { 1 } else { num_threads() };
    let rows_per = m.div_ceil(threads).max(1);
    let zero = T::default();
    std::thread::scope(|s| {
        for (ti, c_chunk) in c.chunks_mut(rows_per * n).enumerate() {
            let row0 = ti * rows_per;
            s.spawn(move || {
                // 4-row micro-kernel: each streamed B row is reused across
                // four output rows, quartering B bandwidth (§Perf L3.2)
                let mut rows = c_chunk.chunks_mut(n);
                let mut i = row0;
                loop {
                    let (Some(c0), r1, r2, r3) =
                        (rows.next(), rows.next(), rows.next(), rows.next())
                    else {
                        break;
                    };
                    match (r1, r2, r3) {
                        (Some(c1), Some(c2), Some(c3)) => {
                            let (a0, a1, a2, a3) = (
                                &a[i * k..(i + 1) * k],
                                &a[(i + 1) * k..(i + 2) * k],
                                &a[(i + 2) * k..(i + 3) * k],
                                &a[(i + 3) * k..(i + 4) * k],
                            );
                            for l in 0..k {
                                let b_row = &b[l * n..(l + 1) * n];
                                let (v0, v1, v2, v3) = (a0[l], a1[l], a2[l], a3[l]);
                                for j in 0..n {
                                    let bv = b_row[j];
                                    c0[j] += v0 * bv;
                                    c1[j] += v1 * bv;
                                    c2[j] += v2 * bv;
                                    c3[j] += v3 * bv;
                                }
                            }
                            i += 4;
                        }
                        (r1, r2, _) => {
                            // 1–3 leftover rows: simple row kernel
                            for (ri, c_row) in
                                [Some(c0), r1, r2].into_iter().flatten().enumerate()
                            {
                                let a_row = &a[(i + ri) * k..(i + ri + 1) * k];
                                for (l, &av) in a_row.iter().enumerate() {
                                    if av == zero {
                                        continue;
                                    }
                                    let b_row = &b[l * n..(l + 1) * n];
                                    for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                                        *cv += av * bv;
                                    }
                                }
                            }
                            break;
                        }
                    }
                }
            });
        }
    });
}

/// `C += A @ Bᵀ` where A is `[m,k]`, Bt is `[n,k]` (i.e. B transposed),
/// C is `[m,n]`. Dot-product kernel used by conv backward-filter.
pub fn gemm_nt<T>(a: &[T], bt: &[T], c: &mut [T], m: usize, k: usize, n: usize)
where
    T: Copy + Default + Send + Sync + std::ops::Mul<Output = T> + std::ops::AddAssign,
{
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let work = m * k * n;
    let threads = if work < 64 * 1024 { 1 } else { num_threads() };
    let rows_per = m.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        for (ti, c_chunk) in c.chunks_mut(rows_per * n).enumerate() {
            let row0 = ti * rows_per;
            s.spawn(move || {
                for (ri, c_row) in c_chunk.chunks_mut(n).enumerate() {
                    let i = row0 + ri;
                    let a_row = &a[i * k..(i + 1) * k];
                    for (j, cv) in c_row.iter_mut().enumerate() {
                        let b_row = &bt[j * k..(j + 1) * k];
                        let mut acc = T::default();
                        for (&av, &bv) in a_row.iter().zip(b_row) {
                            acc += av * bv;
                        }
                        *cv += acc;
                    }
                }
            });
        }
    });
}

struct MatmulPlan {
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_batch_stride: usize, // 0 when a is broadcast across the batch
    b_batch_stride: usize,
    out_shape: Shape,
}

fn plan(a_shape: &Shape, b_shape: &Shape) -> MatmulPlan {
    let (ad, bd) = (a_shape.dims(), b_shape.dims());
    assert!(!ad.is_empty() && !bd.is_empty(), "matmul on scalar");
    // promote 1-D operands numpy-style
    let (ad2, squeeze_m): (Vec<usize>, bool) =
        if ad.len() == 1 { (vec![1, ad[0]], true) } else { (ad.to_vec(), false) };
    let (bd2, squeeze_n): (Vec<usize>, bool) =
        if bd.len() == 1 { (vec![bd[0], 1], true) } else { (bd.to_vec(), false) };
    let (m, ka) = (ad2[ad2.len() - 2], ad2[ad2.len() - 1]);
    let (kb, n) = (bd2[bd2.len() - 2], bd2[bd2.len() - 1]);
    assert_eq!(ka, kb, "matmul inner dims: {a_shape} x {b_shape}");
    let a_batch: usize = ad2[..ad2.len() - 2].iter().product();
    let b_batch: usize = bd2[..bd2.len() - 2].iter().product();
    let batch = a_batch.max(b_batch).max(1);
    assert!(
        a_batch == b_batch || a_batch <= 1 || b_batch <= 1,
        "matmul batch mismatch: {a_shape} x {b_shape}"
    );
    // output shape: broadcast batch dims ++ [m, n] (minus squeezed dims)
    let batch_dims: Vec<usize> = if ad2.len() - 2 >= bd2.len() - 2 {
        ad2[..ad2.len() - 2].to_vec()
    } else {
        bd2[..bd2.len() - 2].to_vec()
    };
    let mut out_dims = batch_dims;
    if !squeeze_m {
        out_dims.push(m);
    }
    if !squeeze_n {
        out_dims.push(n);
    }
    MatmulPlan {
        batch,
        m,
        k: ka,
        n,
        a_batch_stride: if a_batch <= 1 { 0 } else { m * ka },
        b_batch_stride: if b_batch <= 1 { 0 } else { kb * n },
        out_shape: Shape::new(out_dims),
    }
}

fn matmul_typed<T>(a: &[T], b: &[T], p: &MatmulPlan) -> TypedBuf<T>
where
    T: Copy + Default + Send + Sync + std::ops::Mul<Output = T> + std::ops::AddAssign + PartialEq,
{
    let mut out = TypedBuf::<T>::zeroed(p.batch * p.m * p.n);
    let o = out.as_mut_slice();
    for bi in 0..p.batch {
        let av = &a[bi * p.a_batch_stride..bi * p.a_batch_stride + p.m * p.k];
        let bv = &b[bi * p.b_batch_stride..bi * p.b_batch_stride + p.k * p.n];
        let cv = &mut o[bi * p.m * p.n..(bi + 1) * p.m * p.n];
        gemm(av, bv, cv, p.m, p.k, p.n);
    }
    out
}

/// Public matmul entry (dtype promotion, batching, 1-D promotion).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (ca, cb) = (to_float(cpu(a)), to_float(cpu(b)));
    // unify float width
    let d = ca.dtype.promote(cb.dtype);
    let (ca, cb): (CpuTensor, CpuTensor) = (cast(&ca, d), cast(&cb, d));
    let p = plan(&ca.shape, &cb.shape);
    match (&*ca.storage, &*cb.storage) {
        (Storage::F32(x), Storage::F32(y)) => {
            wrap(Storage::F32(matmul_typed(x, y, &p)), p.out_shape.clone(), d)
        }
        (Storage::F64(x), Storage::F64(y)) => {
            wrap(Storage::F64(matmul_typed(x, y, &p)), p.out_shape.clone(), d)
        }
        _ => unreachable!("matmul operands not float after promotion"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn gemm_small_exact() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gemm_nt_matches_gemm() {
        let m = 5;
        let k = 7;
        let n = 3;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32) * 0.1).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32) * 0.01 - 0.1).collect();
        // bt[j*k + l] = b[l*n + j]
        let mut bt = vec![0.0f32; n * k];
        for l in 0..k {
            for j in 0..n {
                bt[j * k + l] = b[l * n + j];
            }
        }
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm(&a, &b, &mut c1, m, k, n);
        gemm_nt(&a, &bt, &mut c2, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_2d() {
        let a = Tensor::from_slice(&[1.0f32, 2.0, 3.0, 4.0], [2, 2]);
        let b = Tensor::from_slice(&[1.0f32, 0.0, 0.0, 1.0], [2, 2]);
        assert_eq!(a.matmul(&b).to_vec(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn matmul_batched_and_broadcast() {
        // batch 2: a [2,2,3] x b [2,3,2]
        let a = Tensor::arange(12, DType::F32).reshape(&[2, 2, 3]);
        let b = Tensor::ones([2, 3, 2]);
        let c = a.matmul(&b);
        assert_eq!(c.dims(), &[2, 2, 2]);
        assert_eq!(c.to_vec()[..4], [3.0, 3.0, 12.0, 12.0]);
        // broadcast: a [2,2,3] x b [3,2]
        let b2 = Tensor::ones([3, 2]);
        let c2 = a.matmul(&b2);
        assert_eq!(c2.dims(), &[2, 2, 2]);
        assert_eq!(c.to_vec(), c2.to_vec());
    }

    #[test]
    fn matmul_1d_promotion() {
        let v = Tensor::from_slice(&[1.0f32, 2.0, 3.0], [3]);
        let m = Tensor::eye(3, DType::F32);
        let out = v.matmul(&m);
        assert_eq!(out.dims(), &[3]);
        assert_eq!(out.to_vec(), vec![1.0, 2.0, 3.0]);
        let dot = v.matmul(&v);
        assert_eq!(dot.dims(), &[] as &[usize]);
        assert_eq!(dot.item(), 14.0);
    }

    #[test]
    fn matmul_int_promotes_to_float() {
        let a = Tensor::from_slice(&[1i64, 2, 3, 4], [2, 2]);
        let c = a.matmul(&a);
        assert_eq!(c.dtype(), DType::F32);
        assert_eq!(c.to_vec(), vec![7.0, 10.0, 15.0, 22.0]);
    }

    #[test]
    fn matmul_large_against_naive() {
        crate::util::rng::seed(7);
        let (m, k, n) = (33, 47, 29);
        let a = Tensor::rand([m, k], -1.0, 1.0);
        let b = Tensor::rand([k, n], -1.0, 1.0);
        let c = a.matmul(&b).to_vec();
        let (av, bv) = (a.to_vec(), b.to_vec());
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..k {
                    acc += av[i * k + l] as f64 * bv[l * n + j] as f64;
                }
                assert!((c[i * n + j] as f64 - acc).abs() < 1e-3, "({i},{j})");
            }
        }
    }
}
