//! 2-D convolution via im2col + GEMM (NCHW layout).
//!
//! This mirrors how the original library's reference backend offloads
//! convolutions to a GEMM-shaped vendor kernel: patches are lowered to a
//! column matrix and the filter bank becomes a `[Cout, Cin*Kh*Kw]` matrix.
//! Backward passes reuse the same lowering (col2im scatter for the input
//! gradient, `A·Bᵀ` for the filter gradient).

use crate::memory::TypedBuf;
use crate::tensor::backend::Conv2dParams;
use crate::tensor::shape::Shape;
use crate::tensor::{DType, Tensor};

use super::matmul::{gemm, gemm_nt};
use super::{cast, cpu, to_float, wrap, Storage};

/// Output spatial size for one dimension.
pub fn out_dim(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (input + 2 * pad - kernel) / stride + 1
}

fn f32_data(t: &Tensor) -> (Vec<usize>, std::sync::Arc<Storage>) {
    let c = cast(&to_float(cpu(t)), DType::F32);
    (c.shape.dims().to_vec(), c.storage)
}

fn as_f32(s: &Storage) -> &[f32] {
    match s {
        Storage::F32(v) => v.as_slice(),
        _ => unreachable!("expected f32 storage"),
    }
}

/// Lower input patches of one image `[C,H,W]` into columns
/// `[C*Kh*Kw, OH*OW]`.
#[allow(clippy::too_many_arguments)]
fn im2col(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: (usize, usize),
    pad: (usize, usize),
    col: &mut [f32],
) {
    let oh = out_dim(h, kh, stride.0, pad.0);
    let ow = out_dim(w, kw, stride.1, pad.1);
    let ospatial = oh * ow;
    debug_assert_eq!(col.len(), c * kh * kw * ospatial);
    for ci in 0..c {
        for ki in 0..kh {
            for kj in 0..kw {
                let row = (ci * kh + ki) * kw + kj;
                let dst = &mut col[row * ospatial..(row + 1) * ospatial];
                for oy in 0..oh {
                    let iy = (oy * stride.0 + ki) as isize - pad.0 as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[oy * ow..(oy + 1) * ow].fill(0.0);
                        continue;
                    }
                    let src_row = &x[(ci * h + iy as usize) * w..(ci * h + iy as usize + 1) * w];
                    for ox in 0..ow {
                        let ix = (ox * stride.1 + kj) as isize - pad.1 as isize;
                        dst[oy * ow + ox] =
                            if ix < 0 || ix >= w as isize { 0.0 } else { src_row[ix as usize] };
                    }
                }
            }
        }
    }
}

/// Scatter-add columns back into an image (inverse of `im2col`).
#[allow(clippy::too_many_arguments)]
fn col2im(
    col: &[f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: (usize, usize),
    pad: (usize, usize),
    x: &mut [f32],
) {
    let oh = out_dim(h, kh, stride.0, pad.0);
    let ow = out_dim(w, kw, stride.1, pad.1);
    let ospatial = oh * ow;
    for ci in 0..c {
        for ki in 0..kh {
            for kj in 0..kw {
                let row = (ci * kh + ki) * kw + kj;
                let src = &col[row * ospatial..(row + 1) * ospatial];
                for oy in 0..oh {
                    let iy = (oy * stride.0 + ki) as isize - pad.0 as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = (ox * stride.1 + kj) as isize - pad.1 as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        x[(ci * h + iy as usize) * w + ix as usize] += src[oy * ow + ox];
                    }
                }
            }
        }
    }
}

/// Forward convolution: `x [N,Cin,H,W] ⋆ w [Cout,Cin,Kh,Kw]`.
pub fn conv2d(x: &Tensor, w: &Tensor, p: Conv2dParams) -> Tensor {
    let (xd, xs) = f32_data(x);
    let (wd, ws) = f32_data(w);
    assert_eq!(xd.len(), 4, "conv2d input must be NCHW, got {:?}", xd);
    assert_eq!(wd.len(), 4, "conv2d weight must be OIHW, got {:?}", wd);
    let (n, cin, h, wid) = (xd[0], xd[1], xd[2], xd[3]);
    let (cout, cin_w, kh, kw) = (wd[0], wd[1], wd[2], wd[3]);
    assert_eq!(cin, cin_w, "conv2d channel mismatch");
    let oh = out_dim(h, kh, p.stride.0, p.padding.0);
    let ow = out_dim(wid, kw, p.stride.1, p.padding.1);
    let (xv, wv) = (as_f32(&xs), as_f32(&ws));
    let ckk = cin * kh * kw;
    let ospatial = oh * ow;
    let mut out = TypedBuf::<f32>::zeroed(n * cout * ospatial);
    let mut col = vec![0.0f32; ckk * ospatial];
    for ni in 0..n {
        im2col(&xv[ni * cin * h * wid..], cin, h, wid, kh, kw, p.stride, p.padding, &mut col);
        let dst = &mut out.as_mut_slice()[ni * cout * ospatial..(ni + 1) * cout * ospatial];
        gemm(wv, &col, dst, cout, ckk, ospatial);
    }
    wrap(Storage::F32(out), Shape::new(vec![n, cout, oh, ow]), DType::F32)
}

/// Input gradient: `col_grad = wᵀ · gy`, then col2im.
pub fn conv2d_bwd_input(grad_y: &Tensor, w: &Tensor, x_shape: &Shape, p: Conv2dParams) -> Tensor {
    let (gd, gs) = f32_data(grad_y);
    let (wd, ws) = f32_data(w);
    let xd = x_shape.dims();
    let (n, cin, h, wid) = (xd[0], xd[1], xd[2], xd[3]);
    let (cout, _, kh, kw) = (wd[0], wd[1], wd[2], wd[3]);
    let (oh, ow) = (gd[2], gd[3]);
    let ospatial = oh * ow;
    let ckk = cin * kh * kw;
    let (gv, wv) = (as_f32(&gs), as_f32(&ws));
    // wt [ckk, cout]: wt[r, o] = w[o, r]
    let mut wt = vec![0.0f32; ckk * cout];
    for o in 0..cout {
        for r in 0..ckk {
            wt[r * cout + o] = wv[o * ckk + r];
        }
    }
    let mut dx = TypedBuf::<f32>::zeroed(n * cin * h * wid);
    let mut colg = vec![0.0f32; ckk * ospatial];
    for ni in 0..n {
        colg.fill(0.0);
        let gy = &gv[ni * cout * ospatial..(ni + 1) * cout * ospatial];
        gemm(&wt, gy, &mut colg, ckk, cout, ospatial);
        col2im(
            &colg,
            cin,
            h,
            wid,
            kh,
            kw,
            p.stride,
            p.padding,
            &mut dx.as_mut_slice()[ni * cin * h * wid..(ni + 1) * cin * h * wid],
        );
    }
    wrap(Storage::F32(dx), x_shape.clone(), DType::F32)
}

/// Filter gradient: `gw += gy · colᵀ`, accumulated over the batch.
pub fn conv2d_bwd_filter(grad_y: &Tensor, x: &Tensor, w_shape: &Shape, p: Conv2dParams) -> Tensor {
    let (gd, gs) = f32_data(grad_y);
    let (xd, xs) = f32_data(x);
    let wd = w_shape.dims();
    let (n, cin, h, wid) = (xd[0], xd[1], xd[2], xd[3]);
    let (cout, _, kh, kw) = (wd[0], wd[1], wd[2], wd[3]);
    let (oh, ow) = (gd[2], gd[3]);
    let ospatial = oh * ow;
    let ckk = cin * kh * kw;
    let (gv, xv) = (as_f32(&gs), as_f32(&xs));
    let mut gw = TypedBuf::<f32>::zeroed(cout * ckk);
    let mut col = vec![0.0f32; ckk * ospatial];
    for ni in 0..n {
        im2col(&xv[ni * cin * h * wid..], cin, h, wid, kh, kw, p.stride, p.padding, &mut col);
        let gy = &gv[ni * cout * ospatial..(ni + 1) * cout * ospatial];
        // gw [cout, ckk] += gy [cout, ospatial] @ col[ckk, ospatial]^T
        gemm_nt(gy, &col, gw.as_mut_slice(), cout, ospatial, ckk);
    }
    wrap(Storage::F32(gw), w_shape.clone(), DType::F32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_conv(
        x: &[f32],
        w: &[f32],
        n: usize,
        cin: usize,
        h: usize,
        wid: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: (usize, usize),
        pad: (usize, usize),
    ) -> Vec<f32> {
        let oh = out_dim(h, kh, stride.0, pad.0);
        let ow = out_dim(wid, kw, stride.1, pad.1);
        let mut out = vec![0.0f32; n * cout * oh * ow];
        for ni in 0..n {
            for co in 0..cout {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0;
                        for ci in 0..cin {
                            for ki in 0..kh {
                                for kj in 0..kw {
                                    let iy = (oy * stride.0 + ki) as isize - pad.0 as isize;
                                    let ix = (ox * stride.1 + kj) as isize - pad.1 as isize;
                                    if iy < 0 || iy >= h as isize || ix < 0 || ix >= wid as isize {
                                        continue;
                                    }
                                    let xi = ((ni * cin + ci) * h + iy as usize) * wid + ix as usize;
                                    let wi = ((co * cin + ci) * kh + ki) * kw + kj;
                                    acc += x[xi] * w[wi];
                                }
                            }
                        }
                        out[((ni * cout + co) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive() {
        crate::util::rng::seed(42);
        for (stride, pad) in [((1, 1), (0, 0)), ((2, 2), (1, 1)), ((1, 2), (2, 0))] {
            let (n, cin, h, w, cout, kh, kw) = (2, 3, 7, 8, 4, 3, 3);
            let x = Tensor::rand([n, cin, h, w], -1.0, 1.0);
            let wt = Tensor::rand([cout, cin, kh, kw], -1.0, 1.0);
            let p = Conv2dParams { stride, padding: pad };
            let got = conv2d(&x, &wt, p).to_vec();
            let want = naive_conv(&x.to_vec(), &wt.to_vec(), n, cin, h, w, cout, kh, kw, stride, pad);
            for (g, wv) in got.iter().zip(&want) {
                assert!((g - wv).abs() < 1e-4, "stride {stride:?} pad {pad:?}");
            }
        }
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel of ones on a single channel = identity
        let x = Tensor::arange(9, DType::F32).reshape(&[1, 1, 3, 3]);
        let w = Tensor::ones([1, 1, 1, 1]);
        let y = conv2d(&x, &w, Conv2dParams::default());
        assert_eq!(y.to_vec(), x.to_vec());
    }

    #[test]
    fn bwd_input_gradient_numerically() {
        crate::util::rng::seed(3);
        let (n, cin, h, w, cout, kh, kw) = (1, 2, 5, 5, 3, 3, 3);
        let p = Conv2dParams { stride: (1, 1), padding: (1, 1) };
        let x = Tensor::rand([n, cin, h, w], -1.0, 1.0);
        let wt = Tensor::rand([cout, cin, kh, kw], -1.0, 1.0);
        // loss = sum(conv(x, w)); dL/dx via analytic path
        let gy = Tensor::ones([n, cout, h, w]);
        let dx = conv2d_bwd_input(&gy, &wt, x.shape(), p).to_vec();
        // numeric check a few entries
        let eps = 1e-3f32;
        let base: f32 = conv2d(&x, &wt, p).to_vec().iter().sum();
        let xv = x.to_vec();
        for &probe in &[0usize, 7, 24, 49] {
            let mut xp = xv.clone();
            xp[probe] += eps;
            let xt = Tensor::from_slice(&xp, [n, cin, h, w]);
            let plus: f32 = conv2d(&xt, &wt, p).to_vec().iter().sum();
            let num = (plus - base) / eps;
            assert!((num - dx[probe]).abs() < 2e-2, "probe {probe}: num {num} vs {}", dx[probe]);
        }
    }

    #[test]
    fn bwd_filter_gradient_numerically() {
        crate::util::rng::seed(4);
        let (n, cin, h, w, cout, kh, kw) = (2, 2, 5, 5, 2, 3, 3);
        let p = Conv2dParams { stride: (2, 2), padding: (1, 1) };
        let x = Tensor::rand([n, cin, h, w], -1.0, 1.0);
        let wt = Tensor::rand([cout, cin, kh, kw], -1.0, 1.0);
        let y = conv2d(&x, &wt, p);
        let gy = Tensor::ones(y.dims().to_vec());
        let dw = conv2d_bwd_filter(&gy, &x, wt.shape(), p).to_vec();
        let eps = 1e-3f32;
        let base: f32 = y.to_vec().iter().sum();
        let wv = wt.to_vec();
        for &probe in &[0usize, 5, 17, 35] {
            let mut wp = wv.clone();
            wp[probe] += eps;
            let wtp = Tensor::from_slice(&wp, [cout, cin, kh, kw]);
            let plus: f32 = conv2d(&x, &wtp, p).to_vec().iter().sum();
            let num = (plus - base) / eps;
            assert!((num - dw[probe]).abs() < 2e-2, "probe {probe}: num {num} vs {}", dw[probe]);
        }
    }
}
