//! 2-D max/average pooling (NCHW), forward and backward.

use crate::memory::TypedBuf;
use crate::tensor::backend::{Pool2dParams, PoolKind};
use crate::tensor::shape::Shape;
use crate::tensor::{DType, Tensor};
use crate::util::parallel::parallel_chunks;

use super::conv::out_dim;
use super::{cast, cpu, to_float, wrap, Storage};

fn f32_view(t: &Tensor) -> (Vec<usize>, std::sync::Arc<Storage>) {
    let c = cast(&to_float(cpu(t)), DType::F32);
    (c.shape.dims().to_vec(), c.storage)
}

fn data(s: &Storage) -> &[f32] {
    match s {
        Storage::F32(v) => v.as_slice(),
        _ => unreachable!(),
    }
}

/// Forward pooling over `x [N,C,H,W]` (no padding; windows must fit with
/// the given stride, trailing elements are dropped as in other frameworks).
pub fn pool2d(x: &Tensor, p: Pool2dParams) -> Tensor {
    let (xd, xs) = f32_view(x);
    assert_eq!(xd.len(), 4, "pool2d input must be NCHW");
    let (n, c, h, w) = (xd[0], xd[1], xd[2], xd[3]);
    let (kh, kw) = p.kernel;
    let (sh, sw) = p.stride;
    let oh = out_dim(h, kh, sh, 0);
    let ow = out_dim(w, kw, sw, 0);
    let xv = data(&xs);
    let mut out = TypedBuf::<f32>::zeroed(n * c * oh * ow);
    let ov = out.as_mut_slice();
    let ov_ptr = SendPtr(ov.as_mut_ptr());
    parallel_chunks(n * c, 4, |lo, hi| {
        let ov = ov_ptr;
        for plane in lo..hi {
            let src = &xv[plane * h * w..(plane + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = if matches!(p.kind, PoolKind::Max) { f32::NEG_INFINITY } else { 0.0 };
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let v = src[(oy * sh + ky) * w + (ox * sw + kx)];
                            match p.kind {
                                PoolKind::Max => acc = acc.max(v),
                                PoolKind::Avg => acc += v,
                            }
                        }
                    }
                    if matches!(p.kind, PoolKind::Avg) {
                        acc /= (kh * kw) as f32;
                    }
                    unsafe { *ov.0.add(plane * oh * ow + oy * ow + ox) = acc };
                }
            }
        }
    });
    wrap(Storage::F32(out), Shape::new(vec![n, c, oh, ow]), DType::F32)
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Backward pooling: max routes the gradient to the (first) argmax element
/// of each window (re-derived from `x`); avg spreads it uniformly.
pub fn pool2d_bwd(grad_y: &Tensor, x: &Tensor, p: Pool2dParams) -> Tensor {
    let (xd, xs) = f32_view(x);
    let (gd, gs) = f32_view(grad_y);
    let (n, c, h, w) = (xd[0], xd[1], xd[2], xd[3]);
    let (oh, ow) = (gd[2], gd[3]);
    let (kh, kw) = p.kernel;
    let (sh, sw) = p.stride;
    let xv = data(&xs);
    let gv = data(&gs);
    let mut dx = TypedBuf::<f32>::zeroed(n * c * h * w);
    let dptr = SendPtr(dx.as_mut_slice().as_mut_ptr());
    parallel_chunks(n * c, 4, |lo, hi| {
        let d = dptr;
        for plane in lo..hi {
            let src = &xv[plane * h * w..(plane + 1) * h * w];
            let g = &gv[plane * oh * ow..(plane + 1) * oh * ow];
            for oy in 0..oh {
                for ox in 0..ow {
                    let go = g[oy * ow + ox];
                    match p.kind {
                        PoolKind::Max => {
                            let (mut by, mut bx, mut bv) = (0usize, 0usize, f32::NEG_INFINITY);
                            for ky in 0..kh {
                                for kx in 0..kw {
                                    let v = src[(oy * sh + ky) * w + (ox * sw + kx)];
                                    if v > bv {
                                        bv = v;
                                        by = ky;
                                        bx = kx;
                                    }
                                }
                            }
                            let idx = plane * h * w + (oy * sh + by) * w + (ox * sw + bx);
                            unsafe { *d.0.add(idx) += go };
                        }
                        PoolKind::Avg => {
                            let share = go / (kh * kw) as f32;
                            for ky in 0..kh {
                                for kx in 0..kw {
                                    let idx = plane * h * w + (oy * sh + ky) * w + (ox * sw + kx);
                                    unsafe { *d.0.add(idx) += share };
                                }
                            }
                        }
                    }
                }
            }
        }
    });
    wrap(Storage::F32(dx), Shape::new(vec![n, c, h, w]), DType::F32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_2x2() {
        let x = Tensor::from_slice(
            &[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0, 16.0],
            [1, 1, 4, 4],
        );
        let p = Pool2dParams { kind: PoolKind::Max, kernel: (2, 2), stride: (2, 2) };
        let y = pool2d(&x, p);
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        assert_eq!(y.to_vec(), vec![6.0, 8.0, 14.0, 16.0]);
    }

    #[test]
    fn avgpool_2x2() {
        let x = Tensor::from_slice(&[1.0f32, 3.0, 5.0, 7.0], [1, 1, 2, 2]);
        let p = Pool2dParams { kind: PoolKind::Avg, kernel: (2, 2), stride: (2, 2) };
        assert_eq!(pool2d(&x, p).to_vec(), vec![4.0]);
    }

    #[test]
    fn maxpool_bwd_routes_to_argmax() {
        let x = Tensor::from_slice(&[1.0f32, 9.0, 2.0, 3.0], [1, 1, 2, 2]);
        let p = Pool2dParams { kind: PoolKind::Max, kernel: (2, 2), stride: (2, 2) };
        let gy = Tensor::from_slice(&[5.0f32], [1, 1, 1, 1]);
        let dx = pool2d_bwd(&gy, &x, p);
        assert_eq!(dx.to_vec(), vec![0.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn avgpool_bwd_spreads() {
        let x = Tensor::ones([1, 1, 2, 2]);
        let p = Pool2dParams { kind: PoolKind::Avg, kernel: (2, 2), stride: (2, 2) };
        let gy = Tensor::from_slice(&[8.0f32], [1, 1, 1, 1]);
        let dx = pool2d_bwd(&gy, &x, p);
        assert_eq!(dx.to_vec(), vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn strided_pool_drops_tail() {
        // 5x5 with 2x2 kernel stride 2 -> 2x2 output (last row/col dropped)
        let x = Tensor::arange(25, DType::F32).reshape(&[1, 1, 5, 5]);
        let p = Pool2dParams { kind: PoolKind::Max, kernel: (2, 2), stride: (2, 2) };
        let y = pool2d(&x, p);
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        assert_eq!(y.to_vec(), vec![6.0, 8.0, 16.0, 18.0]);
    }
}
