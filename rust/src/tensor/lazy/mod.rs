//! Deferred-execution tensor backend (paper Figure 2, §4.1.1: "tensor
//! values need only be materialized upon user request").
//!
//! Element-wise operations and `matmul` build an expression graph instead
//! of executing; materialization (`to_host`) walks the graph and evaluates
//! **fused**: a chain of element-wise ops becomes a single pass over the
//! output with no intermediate buffers — the same JIT-fusion idea as the
//! original library's ArrayFire backend ("deferred, on-the-fly code
//! generation ... to increase kernel arithmetic intensity").
//!
//! The backend is a single [`Interposer`] over the shared [`Op`] IR: the
//! graph nodes store `Op` values directly (no private opcode enum), the
//! fusion pass is a `match` over `Op`, and everything non-fusible falls
//! through `inner.dispatch` to the eager CPU backend — lazy tensors
//! materialize on the way in, so the backend is always complete.

use std::sync::{Arc, Mutex, OnceLock};

use super::adapter::TensorAdapter;
use super::cpu::CpuBackend;
use super::interpose::{InterposedBackend, Interposer};
use super::op::Op;
use super::{DType, HostBuffer, Shape, Tensor, TensorBackend};
use crate::util::error::Result;

/// Arity of a *fusible* element-wise op (`None`: not deferred). This is
/// the deferral predicate — the fusion ISA is just a subset of [`Op`].
fn ew_arity(op: &Op) -> Option<usize> {
    match op {
        Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Maximum | Op::Minimum => Some(2),
        Op::Neg | Op::Exp | Op::Log | Op::Tanh | Op::Sqrt | Op::Abs => Some(1),
        _ => None,
    }
}

fn apply1(op: &Op, x: f32) -> f32 {
    match op {
        Op::Neg => -x,
        Op::Exp => x.exp(),
        Op::Log => x.ln(),
        Op::Tanh => x.tanh(),
        Op::Sqrt => x.sqrt(),
        Op::Abs => x.abs(),
        _ => unreachable!("not a fusible unary op: {op:?}"),
    }
}

fn apply2(op: &Op, a: f32, b: f32) -> f32 {
    match op {
        Op::Add => a + b,
        Op::Sub => a - b,
        Op::Mul => a * b,
        Op::Div => a / b,
        Op::Maximum => a.max(b),
        Op::Minimum => a.min(b),
        _ => unreachable!("not a fusible binary op: {op:?}"),
    }
}

enum Node {
    /// A materialized operand.
    Leaf(Tensor),
    /// Deferred element-wise [`Op`] over lazy operands.
    Ew(Op, Vec<Arc<LazyTensor>>),
    /// Deferred matmul.
    Matmul(Arc<LazyTensor>, Arc<LazyTensor>),
}

/// Adapter for deferred tensors: shape/dtype are known immediately, the
/// value only on request.
pub struct LazyTensor {
    node: Node,
    shape: Shape,
    dtype: DType,
    cache: Mutex<Option<Tensor>>,
}

impl LazyTensor {
    fn leaf(t: Tensor) -> Arc<LazyTensor> {
        Arc::new(LazyTensor {
            shape: t.shape().clone(),
            dtype: t.dtype(),
            node: Node::Leaf(t),
            cache: Mutex::new(None),
        })
    }

    /// View any public tensor as a lazy node (wrapping eagerly-computed
    /// tensors as leaves).
    fn of(t: &Tensor) -> Arc<LazyTensor> {
        if let Some(l) = t.adapter().as_any().downcast_ref::<Handle>() {
            return l.0.clone();
        }
        Self::leaf(t.clone())
    }

    /// Graph depth statistics (pending, unmaterialized ops).
    pub fn pending_ops(&self) -> usize {
        if self.cache.lock().unwrap().is_some() {
            return 0;
        }
        match &self.node {
            Node::Leaf(_) => 0,
            Node::Ew(_, ins) => 1 + ins.iter().map(|i| i.pending_ops()).sum::<usize>(),
            Node::Matmul(a, b) => 1 + a.pending_ops() + b.pending_ops(),
        }
    }

    /// Force evaluation (memoized).
    pub fn force(&self) -> Tensor {
        if let Some(t) = self.cache.lock().unwrap().clone() {
            return t;
        }
        let out = match &self.node {
            Node::Leaf(t) => t.clone(),
            Node::Matmul(a, b) => CpuBackend::shared().matmul(&a.force(), &b.force()),
            Node::Ew(..) => self.eval_fused(),
        };
        *self.cache.lock().unwrap() = Some(out.clone());
        out
    }

    /// Fused evaluation of an element-wise subtree: one pass, no
    /// intermediates. Operands that broadcast are pre-materialized to the
    /// output shape; deeper non-elementwise nodes are forced first and
    /// enter as leaves.
    fn eval_fused(&self) -> Tensor {
        // compile: post-order RPN program over the ew subtree
        let mut leaves: Vec<Vec<f32>> = Vec::new();
        let mut rpn: Vec<Rpn> = Vec::new();
        self.compile(&mut rpn, &mut leaves);
        let n = self.shape.numel();
        let mut out = vec![0.0f32; n];
        let mut stack = vec![0.0f32; rpn.len()];
        for (i, o) in out.iter_mut().enumerate() {
            let mut sp = 0usize;
            for step in &rpn {
                match step {
                    Rpn::Leaf(li) => {
                        let buf = &leaves[*li];
                        stack[sp] = if buf.len() == 1 { buf[0] } else { buf[i] };
                        sp += 1;
                    }
                    Rpn::Op(op) => {
                        if ew_arity(op) == Some(1) {
                            stack[sp - 1] = apply1(op, stack[sp - 1]);
                        } else {
                            stack[sp - 2] = apply2(op, stack[sp - 2], stack[sp - 1]);
                            sp -= 1;
                        }
                    }
                }
            }
            *o = stack[0];
        }
        Tensor::from_slice(&out, self.shape.clone())
    }

    fn compile(&self, rpn: &mut Vec<Rpn>, leaves: &mut Vec<Vec<f32>>) {
        match &self.node {
            Node::Ew(op, ins) if self.cache.lock().unwrap().is_none() => {
                for i in ins {
                    // operands must align element-wise with the output;
                    // scalars stay scalar, everything else materializes to
                    // the broadcast shape
                    if i.shape == self.shape || i.shape.numel() == 1 {
                        i.compile(rpn, leaves);
                    } else {
                        // expand through the eager CPU backend explicitly —
                        // going through the default (lazy) backend here
                        // would re-enter this evaluator
                        let cpu = CpuBackend::shared();
                        let zeros = cpu.full(&self.shape, 0.0, DType::F32);
                        let forced = cpu.add(&i.force(), &zeros);
                        rpn.push(Rpn::Leaf(leaves.len()));
                        leaves.push(forced.to_vec());
                    }
                }
                rpn.push(Rpn::Op(op.clone()));
            }
            _ => {
                let forced = self.force();
                rpn.push(Rpn::Leaf(leaves.len()));
                leaves.push(forced.to_vec());
            }
        }
    }
}

enum Rpn {
    Leaf(usize),
    Op(Op),
}

/// Public adapter handle for lazy tensors.
struct Handle(Arc<LazyTensor>);

impl TensorAdapter for Handle {
    fn shape(&self) -> &Shape {
        &self.0.shape
    }
    fn dtype(&self) -> DType {
        self.0.dtype
    }
    fn backend(&self) -> Arc<dyn TensorBackend> {
        LazyBackend::shared()
    }
    fn to_host(&self) -> HostBuffer {
        self.0.force().to_host()
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Count pending (deferred, unevaluated) ops behind a tensor handle; 0 for
/// eager tensors. Used by tests and the Figure-2 bench.
pub fn pending_ops(t: &Tensor) -> usize {
    t.adapter().as_any().downcast_ref::<Handle>().map(|h| h.0.pending_ops()).unwrap_or(0)
}

/// The deferral policy, as a one-function [`Interposer`]: fusible f32
/// element-wise ops and 2-D f32 matmuls queue as graph nodes; everything
/// else falls through `dispatch` to the eager inner backend (lazy
/// operands materialize on the way in via `to_host`).
pub struct LazyInterposer;

impl LazyInterposer {
    fn defer_ew(&self, op: &Op, inputs: &[&Tensor]) -> Option<Tensor> {
        if inputs.len() != ew_arity(op)? {
            return None;
        }
        if inputs.iter().any(|t| t.dtype() != DType::F32) {
            return None; // defer only the f32 hot path
        }
        let mut shape = inputs[0].shape().clone();
        for t in &inputs[1..] {
            shape = shape.broadcast(t.shape()).ok()?;
        }
        let ins: Vec<Arc<LazyTensor>> = inputs.iter().map(|t| LazyTensor::of(t)).collect();
        let lt = Arc::new(LazyTensor {
            node: Node::Ew(op.clone(), ins),
            shape,
            dtype: DType::F32,
            cache: Mutex::new(None),
        });
        Some(Tensor::from_adapter(Arc::new(Handle(lt))))
    }

    fn defer_matmul(&self, inputs: &[&Tensor]) -> Option<Tensor> {
        let [a, b] = inputs else { return None };
        if a.dtype() != DType::F32 || b.dtype() != DType::F32 || a.rank() != 2 || b.rank() != 2 {
            return None;
        }
        let (la, lb) = (LazyTensor::of(a), LazyTensor::of(b));
        let shape = Shape::new(vec![a.dims()[0], b.dims()[1]]);
        let lt = Arc::new(LazyTensor {
            node: Node::Matmul(la, lb),
            shape,
            dtype: DType::F32,
            cache: Mutex::new(None),
        });
        Some(Tensor::from_adapter(Arc::new(Handle(lt))))
    }
}

impl Interposer for LazyInterposer {
    fn name(&self) -> &str {
        "lazy"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        if ew_arity(op).is_some() {
            if let Some(t) = self.defer_ew(op, inputs) {
                return Ok(t);
            }
        } else if matches!(op, Op::Matmul) {
            if let Some(t) = self.defer_matmul(inputs) {
                return Ok(t);
            }
        }
        inner.dispatch(op, inputs)
    }
}

/// The deferred backend: [`LazyInterposer`] over the eager CPU backend.
pub type LazyBackend = InterposedBackend<LazyInterposer>;

impl LazyBackend {
    /// The canonical shared instance.
    pub fn shared() -> Arc<dyn TensorBackend> {
        static INST: OnceLock<Arc<LazyBackend>> = OnceLock::new();
        let be: Arc<LazyBackend> = INST
            .get_or_init(|| InterposedBackend::new(LazyInterposer, CpuBackend::shared()))
            .clone();
        be
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::BackendGuard;

    #[test]
    fn defers_until_materialization() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[1.0f32, 2.0], [2]);
        let b = Tensor::from_slice(&[3.0f32, 4.0], [2]);
        let c = a.add(&b).mul(&b).exp().log(); // 4 deferred ops
        assert_eq!(pending_ops(&c), 4);
        let v = c.to_vec(); // (1+3)*3 = 12, (2+4)*4 = 24, through exp/log
        assert!((v[0] - 12.0).abs() < 1e-4 && (v[1] - 24.0).abs() < 1e-3, "{v:?}");
        // memoized after forcing
        assert_eq!(pending_ops(&c), 0);
    }

    #[test]
    fn lazy_matches_eager_on_composed_expressions() {
        crate::util::rng::seed(21);
        let av = Tensor::rand([16, 16], 0.1, 2.0).to_vec();
        let bv = Tensor::rand([16, 16], 0.1, 2.0).to_vec();
        let eager = {
            let a = Tensor::from_slice(&av, [16, 16]);
            let b = Tensor::from_slice(&bv, [16, 16]);
            a.matmul(&b).add(&b).tanh().mul(&a).to_vec()
        };
        let lazy = {
            let _g = BackendGuard::install(LazyBackend::shared());
            let a = Tensor::from_slice(&av, [16, 16]);
            let b = Tensor::from_slice(&bv, [16, 16]);
            a.matmul(&b).add(&b).tanh().mul(&a).to_vec()
        };
        for (e, l) in eager.iter().zip(&lazy) {
            assert!((e - l).abs() < 1e-4, "{e} vs {l}");
        }
    }

    #[test]
    fn scalars_and_broadcast_fuse() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[1.0f32, -2.0, 3.0], [3]);
        let r = a.relu(); // maximum(a, scalar 0)
        assert_eq!(r.to_vec(), vec![1.0, 0.0, 3.0]);
        let row = Tensor::from_slice(&[1.0f32, 2.0, 3.0], [3]);
        let m = Tensor::ones([2, 3]);
        let s = m.add(&row); // broadcast operand
        assert_eq!(s.to_vec(), vec![2., 3., 4., 2., 3., 4.]);
    }

    #[test]
    fn non_deferred_ops_fall_back_and_force() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[4.0f32, 1.0], [2]);
        let c = a.add_scalar(1.0); // deferred
        let s = c.sum(&[], false); // reduction: eager fallback, forces c
        assert_eq!(s.item(), 7.0);
    }

    #[test]
    fn diamond_sharing_evaluates_once() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[2.0f32], [1]);
        let shared = a.exp(); // used twice
        let out = shared.add(&shared);
        assert!((out.to_vec()[0] - 2.0 * 2.0f32.exp()).abs() < 1e-5);
    }

    #[test]
    fn graph_nodes_are_shared_ops() {
        // the deferral predicate and the dispatch surface speak the same
        // IR: a deferred tensor dispatched through the public choke point
        // materializes identically to the typed path
        let lazy = LazyBackend::shared();
        let a = Tensor::from_slice(&[1.0f32, 4.0, 9.0], [3]);
        let deferred = lazy.dispatch(&Op::Sqrt, &[&a]).unwrap();
        assert_eq!(pending_ops(&deferred), 1);
        assert_eq!(deferred.to_vec(), vec![1.0, 2.0, 3.0]);
    }
}
