//! Deferred-execution tensor backend (paper Figure 2, §4.1.1: "tensor
//! values need only be materialized upon user request").
//!
//! Element-wise operations and `matmul` build an expression graph instead
//! of executing; materialization (`to_host`) lowers the pending subgraph
//! into a [`TraceProgram`] and hands it to the optimizing graph compiler
//! ([`super::graph`]): CSE deduplicates shared subexpressions, fusion
//! collapses element-wise chains *and diamonds* into single
//! [`super::graph::FusedKernel`] passes with no intermediate buffers —
//! the same JIT-fusion idea as the original library's ArrayFire backend
//! ("deferred, on-the-fly code generation ... to increase kernel
//! arithmetic intensity"), but shared with every other consumer of the
//! IR instead of living in a private tree walker.
//!
//! The backend is a single [`Interposer`] over the shared [`Op`] IR: the
//! graph nodes store `Op` values directly, the deferral predicate is the
//! compiler's fusion ISA ([`graph::fuse::fusible_arity`]), and everything
//! non-fusible falls through `inner.dispatch` to the eager CPU backend —
//! lazy tensors materialize on the way in, so the backend is always
//! complete.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::adapter::TensorAdapter;
use super::cpu::CpuBackend;
use super::graph::{self, fuse::fusible_arity, CompileOptions};
use super::interpose::{InterposedBackend, Interposer};
use super::op::Op;
use super::trace::{TraceInstr, TraceProgram, ValueRef};
use super::{DType, HostBuffer, Shape, Tensor, TensorBackend};
use crate::util::error::Result;

enum Node {
    /// A materialized operand.
    Leaf(Tensor),
    /// Deferred element-wise [`Op`] over lazy operands.
    Ew(Op, Vec<Arc<LazyTensor>>),
    /// Deferred matmul.
    Matmul(Arc<LazyTensor>, Arc<LazyTensor>),
}

/// Adapter for deferred tensors: shape/dtype are known immediately, the
/// value only on request.
pub struct LazyTensor {
    node: Node,
    shape: Shape,
    dtype: DType,
    cache: Mutex<Option<Tensor>>,
}

/// The pass configuration for lazy materialization: folding is pointless
/// (every leaf is a constant, so it would just evaluate the graph op by
/// op at "compile" time and bypass fusion), the rest earn their keep.
fn lazy_opts() -> CompileOptions {
    CompileOptions { fold: false, ..CompileOptions::default() }
}

impl LazyTensor {
    fn leaf(t: Tensor) -> Arc<LazyTensor> {
        Arc::new(LazyTensor {
            shape: t.shape().clone(),
            dtype: t.dtype(),
            node: Node::Leaf(t),
            cache: Mutex::new(None),
        })
    }

    /// View any public tensor as a lazy node (wrapping eagerly-computed
    /// tensors as leaves).
    fn of(t: &Tensor) -> Arc<LazyTensor> {
        if let Some(l) = t.adapter().as_any().downcast_ref::<Handle>() {
            return l.0.clone();
        }
        Self::leaf(t.clone())
    }

    fn ptr_key(&self) -> usize {
        self as *const LazyTensor as usize
    }

    /// Number of *distinct* pending (deferred, unevaluated) ops behind
    /// this tensor. Shared subgraphs are counted once: the walk keeps a
    /// visited set keyed by node pointer, so diamond-heavy graphs stay
    /// linear instead of going exponential.
    pub fn pending_ops(&self) -> usize {
        let mut seen = std::collections::HashSet::new();
        self.count_pending(&mut seen);
        seen.len()
    }

    fn count_pending(&self, seen: &mut std::collections::HashSet<usize>) {
        if self.cache.lock().unwrap().is_some() {
            return;
        }
        match &self.node {
            Node::Leaf(_) => {}
            Node::Ew(_, ins) => {
                if seen.insert(self.ptr_key()) {
                    for i in ins {
                        i.count_pending(seen);
                    }
                }
            }
            Node::Matmul(a, b) => {
                if seen.insert(self.ptr_key()) {
                    a.count_pending(seen);
                    b.count_pending(seen);
                }
            }
        }
    }

    /// Force evaluation (memoized): lower the pending subgraph to a
    /// [`TraceProgram`] and run it through the optimizing pipeline.
    /// Interior matmul values are requested as extra program outputs and
    /// written back into their nodes' caches, so an expensive subgraph
    /// shared by several separately-materialized roots executes once.
    pub fn force(&self) -> Tensor {
        if let Some(t) = self.cache.lock().unwrap().clone() {
            return t;
        }
        let out = match &self.node {
            Node::Leaf(t) => t.clone(),
            _ => {
                let mut b = ProgramBuilder {
                    program: TraceProgram::default(),
                    seen: HashMap::new(),
                    matmuls: Vec::new(),
                };
                let root = b.lower(self);
                // fast path: a single pending op gains nothing from the
                // pass pipeline — dispatch it directly
                if b.program.instrs.len() == 1 {
                    let outs = b
                        .program
                        .replay_on(CpuBackend::shared().as_ref())
                        .expect("lazy: single-op dispatch failed");
                    let out = outs.into_iter().next().expect("lazy: no value");
                    *self.cache.lock().unwrap() = Some(out.clone());
                    return out;
                }
                let mut outputs = vec![root];
                let mut memoize: Vec<&LazyTensor> = Vec::new();
                for &(node, id) in &b.matmuls {
                    if ValueRef::Out(id) != root {
                        outputs.push(ValueRef::Out(id));
                        memoize.push(node);
                    }
                }
                let compiled = graph::compile(&b.program, &outputs, &lazy_opts())
                    .expect("lazy: pending graph failed to compile");
                let mut outs = compiled
                    .run(CpuBackend::shared().as_ref())
                    .expect("lazy: compiled program failed to execute")
                    .into_iter();
                let result = outs.next().expect("lazy: compiled program had no output");
                for (node, value) in memoize.iter().zip(outs) {
                    *node.cache.lock().unwrap() = Some(value);
                }
                result
            }
        };
        *self.cache.lock().unwrap() = Some(out.clone());
        out
    }
}

/// Lowers a pending lazy subgraph into a linear [`TraceProgram`]. The
/// visited map (keyed by node pointer) wires each shared subgraph to a
/// single instruction, which is what lets the compiler's CSE/fusion see
/// diamonds as diamonds. Matmul nodes are recorded so [`LazyTensor::force`]
/// can memoize their values after execution.
struct ProgramBuilder<'a> {
    program: TraceProgram,
    seen: HashMap<usize, ValueRef>,
    matmuls: Vec<(&'a LazyTensor, usize)>,
}

impl<'a> ProgramBuilder<'a> {
    fn lower(&mut self, t: &'a LazyTensor) -> ValueRef {
        if let Some(r) = self.seen.get(&t.ptr_key()) {
            return *r;
        }
        // materialized values (leaves and already-forced nodes) enter the
        // program as constants
        let materialized: Option<Tensor> = match &t.node {
            Node::Leaf(v) => Some(v.clone()),
            _ => t.cache.lock().unwrap().clone(),
        };
        let r = match materialized {
            Some(v) => {
                let c = ValueRef::Const(self.program.consts.len());
                self.program.consts.push(v);
                c
            }
            None => match &t.node {
                Node::Leaf(_) => unreachable!("leaf handled above"),
                Node::Ew(op, ins) => {
                    let inputs: Vec<ValueRef> = ins.iter().map(|i| self.lower(i)).collect();
                    let id = self.program.instrs.len();
                    self.program.instrs.push(TraceInstr { op: op.clone(), inputs });
                    ValueRef::Out(id)
                }
                Node::Matmul(a, b) => {
                    let inputs = vec![self.lower(a), self.lower(b)];
                    let id = self.program.instrs.len();
                    self.program.instrs.push(TraceInstr { op: Op::Matmul, inputs });
                    self.matmuls.push((t, id));
                    ValueRef::Out(id)
                }
            },
        };
        self.seen.insert(t.ptr_key(), r);
        r
    }
}

/// Public adapter handle for lazy tensors.
struct Handle(Arc<LazyTensor>);

impl TensorAdapter for Handle {
    fn shape(&self) -> &Shape {
        &self.0.shape
    }
    fn dtype(&self) -> DType {
        self.0.dtype
    }
    fn backend(&self) -> Arc<dyn TensorBackend> {
        LazyBackend::shared()
    }
    fn to_host(&self) -> HostBuffer {
        self.0.force().to_host()
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Count distinct pending (deferred, unevaluated) ops behind a tensor
/// handle; 0 for eager tensors. Used by tests and the Figure-2 bench.
pub fn pending_ops(t: &Tensor) -> usize {
    t.adapter().as_any().downcast_ref::<Handle>().map(|h| h.0.pending_ops()).unwrap_or(0)
}

/// The deferral policy, as a one-function [`Interposer`]: f32 ops in the
/// compiler's fusion ISA and 2-D f32 matmuls queue as graph nodes;
/// everything else falls through `dispatch` to the eager inner backend
/// (lazy operands materialize on the way in via `to_host`).
pub struct LazyInterposer;

impl LazyInterposer {
    fn defer_ew(&self, op: &Op, inputs: &[&Tensor]) -> Option<Tensor> {
        if inputs.len() != fusible_arity(op)? {
            return None;
        }
        if inputs.iter().any(|t| t.dtype() != DType::F32) {
            return None; // defer only the f32 hot path
        }
        let mut shape = inputs[0].shape().clone();
        for t in &inputs[1..] {
            shape = shape.broadcast(t.shape()).ok()?;
        }
        let ins: Vec<Arc<LazyTensor>> = inputs.iter().map(|t| LazyTensor::of(t)).collect();
        let lt = Arc::new(LazyTensor {
            node: Node::Ew(op.clone(), ins),
            shape,
            dtype: DType::F32,
            cache: Mutex::new(None),
        });
        Some(Tensor::from_adapter(Arc::new(Handle(lt))))
    }

    fn defer_matmul(&self, inputs: &[&Tensor]) -> Option<Tensor> {
        let [a, b] = inputs else { return None };
        if a.dtype() != DType::F32 || b.dtype() != DType::F32 || a.rank() != 2 || b.rank() != 2 {
            return None;
        }
        let (la, lb) = (LazyTensor::of(a), LazyTensor::of(b));
        let shape = Shape::new(vec![a.dims()[0], b.dims()[1]]);
        let lt = Arc::new(LazyTensor {
            node: Node::Matmul(la, lb),
            shape,
            dtype: DType::F32,
            cache: Mutex::new(None),
        });
        Some(Tensor::from_adapter(Arc::new(Handle(lt))))
    }
}

impl Interposer for LazyInterposer {
    fn name(&self) -> &str {
        "lazy"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        if fusible_arity(op).is_some() {
            if let Some(t) = self.defer_ew(op, inputs) {
                return Ok(t);
            }
        } else if matches!(op, Op::Matmul) {
            if let Some(t) = self.defer_matmul(inputs) {
                return Ok(t);
            }
        }
        inner.dispatch(op, inputs)
    }
}

/// The deferred backend: [`LazyInterposer`] over the eager CPU backend.
pub type LazyBackend = InterposedBackend<LazyInterposer>;

impl LazyBackend {
    /// The canonical shared instance.
    pub fn shared() -> Arc<dyn TensorBackend> {
        static INST: OnceLock<Arc<LazyBackend>> = OnceLock::new();
        let be: Arc<LazyBackend> = INST
            .get_or_init(|| InterposedBackend::new(LazyInterposer, CpuBackend::shared()))
            .clone();
        be
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::BackendGuard;

    #[test]
    fn defers_until_materialization() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[1.0f32, 2.0], [2]);
        let b = Tensor::from_slice(&[3.0f32, 4.0], [2]);
        let c = a.add(&b).mul(&b).exp().log(); // 4 deferred ops
        assert_eq!(pending_ops(&c), 4);
        let v = c.to_vec(); // (1+3)*3 = 12, (2+4)*4 = 24, through exp/log
        assert!((v[0] - 12.0).abs() < 1e-4 && (v[1] - 24.0).abs() < 1e-3, "{v:?}");
        // memoized after forcing
        assert_eq!(pending_ops(&c), 0);
    }

    #[test]
    fn lazy_matches_eager_on_composed_expressions() {
        crate::util::rng::seed(21);
        let av = Tensor::rand([16, 16], 0.1, 2.0).to_vec();
        let bv = Tensor::rand([16, 16], 0.1, 2.0).to_vec();
        let eager = {
            let a = Tensor::from_slice(&av, [16, 16]);
            let b = Tensor::from_slice(&bv, [16, 16]);
            a.matmul(&b).add(&b).tanh().mul(&a).to_vec()
        };
        let lazy = {
            let _g = BackendGuard::install(LazyBackend::shared());
            let a = Tensor::from_slice(&av, [16, 16]);
            let b = Tensor::from_slice(&bv, [16, 16]);
            a.matmul(&b).add(&b).tanh().mul(&a).to_vec()
        };
        for (e, l) in eager.iter().zip(&lazy) {
            assert!((e - l).abs() < 1e-4, "{e} vs {l}");
        }
    }

    #[test]
    fn scalars_and_broadcast_fuse() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[1.0f32, -2.0, 3.0], [3]);
        let r = a.relu(); // maximum(a, scalar 0)
        assert_eq!(r.to_vec(), vec![1.0, 0.0, 3.0]);
        let row = Tensor::from_slice(&[1.0f32, 2.0, 3.0], [3]);
        let m = Tensor::ones([2, 3]);
        let s = m.add(&row); // broadcast operand
        assert_eq!(s.to_vec(), vec![2., 3., 4., 2., 3., 4.]);
    }

    #[test]
    fn non_deferred_ops_fall_back_and_force() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[4.0f32, 1.0], [2]);
        let c = a.add_scalar(1.0); // deferred
        let s = c.sum(&[], false); // reduction: eager fallback, forces c
        assert_eq!(s.item(), 7.0);
    }

    #[test]
    fn diamond_sharing_evaluates_once() {
        let _g = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::from_slice(&[2.0f32], [1]);
        let shared = a.exp(); // used twice
        let out = shared.add(&shared);
        assert!((out.to_vec()[0] - 2.0 * 2.0f32.exp()).abs() < 1e-5);
    }

    #[test]
    fn pending_ops_stays_linear_on_diamond_heavy_graphs() {
        // regression: the old recursive count revisited shared subgraphs,
        // doubling per layer — 2^40 walks on this graph. The visited-set
        // walk counts each distinct op once and returns immediately.
        // (explicit dispatch on the lazy backend, so concurrent tests
        // swapping the process-global default cannot perturb the counts)
        let be = LazyBackend::shared();
        let mut x = be.from_host(HostBuffer::F32(vec![1.0, 2.0]), [2].into());
        let depth = 40;
        for _ in 0..depth {
            x = be.add(&x, &x); // both operands share one node: a diamond per layer
        }
        assert_eq!(pending_ops(&x), depth);
        // the fused evaluator shares subgraphs too: each lane is
        // value * 2^40 exactly (f32 scaling by a power of two is exact)
        let v = x.to_vec();
        let expect = (2f32).powi(depth as i32);
        assert_eq!(v, vec![expect, 2.0 * expect]);
        assert_eq!(pending_ops(&x), 0);
    }

    #[test]
    fn graph_nodes_are_shared_ops() {
        // the deferral predicate and the dispatch surface speak the same
        // IR: a deferred tensor dispatched through the public choke point
        // materializes identically to the typed path
        let lazy = LazyBackend::shared();
        let a = Tensor::from_slice(&[1.0f32, 4.0, 9.0], [3]);
        let deferred = lazy.dispatch(&Op::Sqrt, &[&a]).unwrap();
        assert_eq!(pending_ops(&deferred), 1);
        assert_eq!(deferred.to_vec(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn shared_matmul_memoizes_across_materializations() {
        // m feeds two separately-materialized roots: the first
        // materialization must write m's cache so the second reuses it
        let be = LazyBackend::shared();
        let a = be.from_host(HostBuffer::F32(vec![1.0, 2.0, 3.0, 4.0]), [2, 2].into());
        let m = be.matmul(&a, &a);
        let y1 = be.tanh(&m);
        let y2 = be.neg(&m);
        assert_eq!(pending_ops(&m), 1);
        let _ = y1.to_vec();
        assert_eq!(pending_ops(&m), 0, "sibling materialization must memoize the shared matmul");
        assert_eq!(y2.to_vec(), vec![-7.0, -10.0, -15.0, -22.0]);
    }

    #[test]
    fn materialization_goes_through_the_compiler() {
        // a diamond of ew ops over a matmul: the compiled program must
        // agree with the eager CPU result, bit for bit
        let av: Vec<f32> = (0..16).map(|i| 0.2 * i as f32 - 1.5).collect();
        let got = {
            let be = LazyBackend::shared();
            let a = be.from_host(HostBuffer::F32(av.clone()), [4, 4].into());
            let m = be.matmul(&a, &a); // deferred
            let e = be.tanh(&m); // shared
            be.add(&be.mul(&e, &e), &m).to_vec()
        };
        let eager = {
            let cpu = CpuBackend::shared();
            let a = cpu.from_host(HostBuffer::F32(av.clone()), [4, 4].into());
            let m = cpu.matmul(&a, &a);
            let e = cpu.tanh(&m);
            cpu.add(&cpu.mul(&e, &e), &m).to_vec()
        };
        assert_eq!(got, eager, "lazy pipeline must be bit-identical to eager CPU");
    }
}
