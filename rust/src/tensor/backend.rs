//! The `TensorBackend` interface (paper Listing 2): the *complete*
//! implementation surface for a tensor backend.
//!
//! This is deliberately small — roughly sixty primitive operations. Every
//! other operator in the library (activations, losses, softmax, norms,
//! whole models) is **derived by composition** from these primitives, so
//! swapping a backend (or overriding a single primitive — see
//! `examples/custom_backend.rs` and paper §5.2.4) retargets the entire
//! framework with zero call-site changes.
//!
//! Backends are free to implement any computation mode (paper Figure 2):
//! the reference [`super::cpu::CpuBackend`] is eager, [`super::lazy`] is
//! deferred with fusion, and [`super::xla_backend`] dispatches to
//! AOT-compiled (static) XLA executables.

use std::sync::{Arc, RwLock};

use super::dtype::DType;
use super::host::HostBuffer;
use super::op::Op;
use super::shape::Shape;
use super::Tensor;
use crate::util::error::{Error, Result};

/// Convolution hyper-parameters (stride / zero-padding per spatial dim).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dParams {
    /// Stride (height, width).
    pub stride: (usize, usize),
    /// Zero padding (height, width), applied symmetrically.
    pub padding: (usize, usize),
}

impl Default for Conv2dParams {
    fn default() -> Self {
        Conv2dParams { stride: (1, 1), padding: (0, 0) }
    }
}

/// Pooling variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    /// Max pooling.
    Max,
    /// Average pooling.
    Avg,
}

/// Pooling hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pool2dParams {
    /// Max or average.
    pub kind: PoolKind,
    /// Window (height, width).
    pub kernel: (usize, usize),
    /// Stride (height, width).
    pub stride: (usize, usize),
}

/// The open backend interface. All tensor arguments are materialization-
/// agnostic handles; backends may defer evaluation arbitrarily as long as
/// `TensorAdapter::to_host` forces a correct value.
#[allow(missing_docs)] // op names are self-describing; contracts documented per group
pub trait TensorBackend: Send + Sync {
    /// Backend name (shows up in errors, telemetry and benches).
    fn name(&self) -> &str;

    // ---- single-point dispatch ------------------------------------------
    /// Execute a reified [`Op`] — the single choke point of the backend
    /// surface. The default implementation routes every variant to the
    /// corresponding typed method below ([`super::op::execute`]), so a
    /// backend that implements the typed surface is automatically complete
    /// here. Wrapper backends (see [`super::interpose::Interposer`])
    /// override the behavior of *this one method* to observe, redirect, or
    /// replace every operation in the framework — the paper's §5.2.4
    /// "subclass the add function" claim with a one-function surface.
    fn dispatch(&self, op: &Op, inputs: &[&Tensor]) -> Result<Tensor> {
        crate::tensor::op::execute(self, op, inputs)
    }

    // ---- creation -------------------------------------------------------
    /// Constant-filled tensor.
    fn full(&self, shape: &Shape, value: f64, dtype: DType) -> Tensor;
    /// `[0, 1, ..., n-1]`.
    fn arange(&self, n: usize, dtype: DType) -> Tensor;
    /// Uniform samples in `[lo, hi)`.
    fn rand_uniform(&self, shape: &Shape, lo: f64, hi: f64, dtype: DType) -> Tensor;
    /// Normal samples.
    fn rand_normal(&self, shape: &Shape, mean: f64, std: f64, dtype: DType) -> Tensor;
    /// Wrap host data.
    fn from_host(&self, host: HostBuffer, shape: Shape) -> Tensor;

    // ---- unary (element-wise; float ops promote int inputs to f32) ------
    fn neg(&self, x: &Tensor) -> Tensor;
    fn abs(&self, x: &Tensor) -> Tensor;
    fn sign(&self, x: &Tensor) -> Tensor;
    fn exp(&self, x: &Tensor) -> Tensor;
    fn log(&self, x: &Tensor) -> Tensor;
    fn log1p(&self, x: &Tensor) -> Tensor;
    fn sin(&self, x: &Tensor) -> Tensor;
    fn cos(&self, x: &Tensor) -> Tensor;
    fn tanh(&self, x: &Tensor) -> Tensor;
    fn sqrt(&self, x: &Tensor) -> Tensor;
    fn rsqrt(&self, x: &Tensor) -> Tensor;
    fn reciprocal(&self, x: &Tensor) -> Tensor;
    fn floor(&self, x: &Tensor) -> Tensor;
    fn ceil(&self, x: &Tensor) -> Tensor;
    fn round(&self, x: &Tensor) -> Tensor;
    fn erf(&self, x: &Tensor) -> Tensor;
    fn logical_not(&self, x: &Tensor) -> Tensor;
    fn isnan(&self, x: &Tensor) -> Tensor;
    /// Clamp into `[lo, hi]`.
    fn clip(&self, x: &Tensor, lo: f64, hi: f64) -> Tensor;

    // ---- binary (element-wise, broadcasting, dtype promotion) ------------
    fn add(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn sub(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn mul(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn div(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn pow(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn minimum(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn maximum(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn rem(&self, a: &Tensor, b: &Tensor) -> Tensor;

    // ---- comparison (broadcasting; result dtype Bool) ---------------------
    fn eq(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn neq(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn lt(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn le(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn gt(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn ge(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn logical_and(&self, a: &Tensor, b: &Tensor) -> Tensor;
    fn logical_or(&self, a: &Tensor, b: &Tensor) -> Tensor;

    // ---- reductions -------------------------------------------------------
    /// Sum over `axes` (normalized, deduplicated by the `Tensor` wrapper).
    fn sum(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor;
    fn prod(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor;
    fn max_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor;
    fn min_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor;
    /// Index of the max along `axis` (dtype I64).
    fn argmax(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor;
    fn argmin(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor;
    /// Logical any/all over `axes` (result Bool).
    fn any(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor;
    fn all(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor;
    /// Inclusive cumulative sum along `axis`.
    fn cumsum(&self, x: &Tensor, axis: usize) -> Tensor;

    // ---- linear algebra ----------------------------------------------------
    /// Matrix multiply. Accepts 2-D × 2-D, or batched 3-D with broadcastable
    /// leading batch dimension; 1-D operands are promoted NumPy-style.
    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor;

    // ---- neural-network primitives (NCHW) -----------------------------------
    /// 2-D convolution: `x [N,Cin,H,W]`, `w [Cout,Cin,Kh,Kw]`.
    fn conv2d(&self, x: &Tensor, w: &Tensor, p: Conv2dParams) -> Tensor;
    /// Gradient of conv2d w.r.t. its input.
    fn conv2d_bwd_input(&self, grad_y: &Tensor, w: &Tensor, x_shape: &Shape, p: Conv2dParams) -> Tensor;
    /// Gradient of conv2d w.r.t. the filter.
    fn conv2d_bwd_filter(&self, grad_y: &Tensor, x: &Tensor, w_shape: &Shape, p: Conv2dParams) -> Tensor;
    /// 2-D max/avg pooling over `x [N,C,H,W]`.
    fn pool2d(&self, x: &Tensor, p: Pool2dParams) -> Tensor;
    /// Gradient of pool2d (max pooling re-derives the argmax from `x`).
    fn pool2d_bwd(&self, grad_y: &Tensor, x: &Tensor, p: Pool2dParams) -> Tensor;

    // ---- data movement -------------------------------------------------------
    /// Reshape (same element count; target pre-resolved by the wrapper).
    fn reshape(&self, x: &Tensor, shape: &Shape) -> Tensor;
    /// Permute dimensions.
    fn transpose(&self, x: &Tensor, perm: &[usize]) -> Tensor;
    /// Rectangular slice `[starts, ends)` per dimension.
    fn slice(&self, x: &Tensor, starts: &[usize], ends: &[usize]) -> Tensor;
    /// Concatenate along `axis`.
    fn concat(&self, xs: &[&Tensor], axis: usize) -> Tensor;
    /// Constant-pad: `pads[d] = (before, after)`.
    fn pad(&self, x: &Tensor, pads: &[(usize, usize)], value: f64) -> Tensor;
    /// Repeat the tensor `reps[d]` times along each dimension.
    fn tile(&self, x: &Tensor, reps: &[usize]) -> Tensor;
    /// Reverse along the given axes.
    fn flip(&self, x: &Tensor, axes: &[usize]) -> Tensor;
    /// Gather slices along `axis` by integer `indices` (1-D).
    fn index_select(&self, x: &Tensor, axis: usize, indices: &Tensor) -> Tensor;
    /// `out = base; out[indices[i], ...] += src[i, ...]` along axis 0
    /// (the embedding-gradient primitive).
    fn scatter_add(&self, base: &Tensor, indices: &Tensor, src: &Tensor) -> Tensor;
    /// Element-wise select: `cond ? a : b` (broadcasting).
    fn where_cond(&self, cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor;
    /// Cast to another dtype.
    fn astype(&self, x: &Tensor, dtype: DType) -> Tensor;
    /// Deep copy (used to detach storage).
    fn copy(&self, x: &Tensor) -> Tensor;

    // ---- extension point -------------------------------------------------------
    /// Optional named fused operations (e.g. AOT-compiled "linear_gelu" on
    /// the XLA backend). Composed operators probe this and fall back to
    /// primitive composition when unsupported.
    fn call_ext(&self, name: &str, _inputs: &[&Tensor]) -> Result<Tensor> {
        Err(Error::Unsupported { backend: self.name().to_string(), op: format!("ext:{name}") })
    }
}

static DEFAULT_BACKEND: RwLock<Option<Arc<dyn TensorBackend>>> = RwLock::new(None);

/// The process-wide default backend used by creation routines
/// (`Tensor::zeros` etc.). Initialized to the reference CPU backend.
pub fn default_backend() -> Arc<dyn TensorBackend> {
    if let Some(b) = DEFAULT_BACKEND.read().unwrap().as_ref() {
        return b.clone();
    }
    let mut w = DEFAULT_BACKEND.write().unwrap();
    if let Some(b) = w.as_ref() {
        return b.clone();
    }
    let b: Arc<dyn TensorBackend> = Arc::new(super::cpu::CpuBackend::new());
    *w = Some(b.clone());
    b
}

/// Install a new default backend; returns the previous one. This is the
/// paper's §5.2.4 swap: *all* creation routines — and therefore every model,
/// baseline and bench in the repo — pick up the new backend with no
/// call-site changes.
pub fn set_default_backend(b: Arc<dyn TensorBackend>) -> Option<Arc<dyn TensorBackend>> {
    DEFAULT_BACKEND.write().unwrap().replace(b)
}

/// RAII guard that restores the previous default backend on drop.
pub struct BackendGuard {
    prev: Option<Arc<dyn TensorBackend>>,
}

impl BackendGuard {
    /// Swap in `b` until the guard drops.
    pub fn install(b: Arc<dyn TensorBackend>) -> Self {
        BackendGuard { prev: set_default_backend(b) }
    }
}

impl Drop for BackendGuard {
    fn drop(&mut self) {
        // Restore the exact previous state, including "unset": if no
        // default had been installed before this guard, clear the slot so
        // `default_backend()` lazily re-resolves to the reference CPU
        // backend instead of leaking the guard's backend process-wide.
        *DEFAULT_BACKEND.write().unwrap() = self.prev.take();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::interpose::{InterposedBackend, Interposer};

    /// A pass-through wrapper whose only job is a recognizable name.
    struct Named(&'static str);
    impl Interposer for Named {
        fn name(&self) -> &str {
            self.0
        }
    }
    fn sentinel(name: &'static str) -> Arc<dyn TensorBackend> {
        InterposedBackend::new(Named(name), super::super::cpu::CpuBackend::shared())
    }

    // NOTE: the default backend is process-global and unit tests run
    // concurrently, so these tests snapshot the slot, run the guard
    // machinery with no tensor ops inside the critical section (keeping
    // the window microscopic), restore the snapshot, and only then
    // assert — on values they read directly, never on what a concurrent
    // test may have installed. They serialize against each other.
    static GUARD_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn guard_restores_unset_state() {
        let _l = GUARD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let snapshot = DEFAULT_BACKEND.write().unwrap().take(); // force "unset"
        let guard = BackendGuard::install(sentinel("guard-sentinel-unset"));
        drop(guard);
        let after = DEFAULT_BACKEND.write().unwrap().clone();
        *DEFAULT_BACKEND.write().unwrap() = snapshot; // undo our meddling
        // the buggy drop left the sentinel installed when prev was None;
        // a concurrent default_backend() may have refilled the slot with
        // the CPU backend, so assert "not our sentinel" rather than None
        assert!(
            after.is_none() || after.unwrap().name() != "guard-sentinel-unset",
            "guard must not leave its backend installed after drop"
        );
    }

    #[test]
    fn nested_guards_unwind() {
        let _l = GUARD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let snapshot = DEFAULT_BACKEND.write().unwrap().clone();
        let a = BackendGuard::install(sentinel("guard-sentinel-a"));
        let b = BackendGuard::install(sentinel("guard-sentinel-b"));
        drop(b);
        let mid = DEFAULT_BACKEND.read().unwrap().clone();
        drop(a);
        let after = DEFAULT_BACKEND.write().unwrap().clone();
        *DEFAULT_BACKEND.write().unwrap() = snapshot;
        assert_eq!(
            mid.map(|be| be.name().to_string()).as_deref(),
            Some("guard-sentinel-a"),
            "inner guard must restore the outer backend"
        );
        assert!(
            after.map(|be| be.name().to_string()).as_deref() != Some("guard-sentinel-a"),
            "outer guard must restore the pre-install state"
        );
    }
}
