//! Dtype-erased host-side data, the materialization format every backend
//! produces on request ("tensor values need only be materialized upon user
//! request", paper §4.1.1).

use super::dtype::DType;

/// A host buffer of one of the supported element types.
#[derive(Debug, Clone, PartialEq)]
pub enum HostBuffer {
    /// f32 data.
    F32(Vec<f32>),
    /// f64 data.
    F64(Vec<f64>),
    /// i32 data.
    I32(Vec<i32>),
    /// i64 data.
    I64(Vec<i64>),
    /// u8 data (also backs Bool; `bool_tag` distinguishes).
    U8(Vec<u8>, /* is_bool */ bool),
}

impl HostBuffer {
    /// The dtype of the contained data.
    pub fn dtype(&self) -> DType {
        match self {
            HostBuffer::F32(_) => DType::F32,
            HostBuffer::F64(_) => DType::F64,
            HostBuffer::I32(_) => DType::I32,
            HostBuffer::I64(_) => DType::I64,
            HostBuffer::U8(_, false) => DType::U8,
            HostBuffer::U8(_, true) => DType::Bool,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            HostBuffer::F32(v) => v.len(),
            HostBuffer::F64(v) => v.len(),
            HostBuffer::I32(v) => v.len(),
            HostBuffer::I64(v) => v.len(),
            HostBuffer::U8(v, _) => v.len(),
        }
    }

    /// Is the buffer empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element `i` as f64.
    pub fn get_f64(&self, i: usize) -> f64 {
        match self {
            HostBuffer::F32(v) => v[i] as f64,
            HostBuffer::F64(v) => v[i],
            HostBuffer::I32(v) => v[i] as f64,
            HostBuffer::I64(v) => v[i] as f64,
            HostBuffer::U8(v, _) => v[i] as f64,
        }
    }

    /// Convert to a `Vec<f32>` (lossy for f64/i64).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match self {
            HostBuffer::F32(v) => v.clone(),
            HostBuffer::F64(v) => v.iter().map(|&x| x as f32).collect(),
            HostBuffer::I32(v) => v.iter().map(|&x| x as f32).collect(),
            HostBuffer::I64(v) => v.iter().map(|&x| x as f32).collect(),
            HostBuffer::U8(v, _) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    /// Convert to a `Vec<f64>`.
    pub fn to_f64_vec(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.get_f64(i)).collect()
    }

    /// Convert to a `Vec<i64>` (floats truncate).
    pub fn to_i64_vec(&self) -> Vec<i64> {
        match self {
            HostBuffer::F32(v) => v.iter().map(|&x| x as i64).collect(),
            HostBuffer::F64(v) => v.iter().map(|&x| x as i64).collect(),
            HostBuffer::I32(v) => v.iter().map(|&x| x as i64).collect(),
            HostBuffer::I64(v) => v.clone(),
            HostBuffer::U8(v, _) => v.iter().map(|&x| x as i64).collect(),
        }
    }

    /// Borrow as f32 slice if the dtype matches.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostBuffer::F32(v) => Some(v),
            _ => None,
        }
    }

    /// Cast to a different dtype (creates a new buffer).
    pub fn cast(&self, to: DType) -> HostBuffer {
        if self.dtype() == to {
            return self.clone();
        }
        match to {
            DType::F32 => HostBuffer::F32(self.to_f32_vec()),
            DType::F64 => HostBuffer::F64(self.to_f64_vec()),
            DType::I32 => HostBuffer::I32(self.to_i64_vec().iter().map(|&x| x as i32).collect()),
            DType::I64 => HostBuffer::I64(self.to_i64_vec()),
            DType::U8 => {
                HostBuffer::U8(self.to_i64_vec().iter().map(|&x| x as u8).collect(), false)
            }
            DType::Bool => HostBuffer::U8(
                (0..self.len()).map(|i| (self.get_f64(i) != 0.0) as u8).collect(),
                true,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn casts_roundtrip() {
        let h = HostBuffer::F32(vec![1.5, -2.0, 0.0]);
        assert_eq!(h.cast(DType::I64), HostBuffer::I64(vec![1, -2, 0]));
        assert_eq!(h.cast(DType::Bool), HostBuffer::U8(vec![1, 1, 0], true));
        assert_eq!(h.cast(DType::F64).dtype(), DType::F64);
        assert_eq!(h.cast(DType::F32), h);
    }

    #[test]
    fn get_and_len() {
        let h = HostBuffer::I32(vec![7, 8]);
        assert_eq!(h.len(), 2);
        assert_eq!(h.get_f64(1), 8.0);
        assert!(!h.is_empty());
    }

    #[test]
    fn bool_tag_distinguishes_dtype() {
        assert_eq!(HostBuffer::U8(vec![1], true).dtype(), DType::Bool);
        assert_eq!(HostBuffer::U8(vec![1], false).dtype(), DType::U8);
    }
}
