//! Program capture as a one-function [`Interposer`] (proof of power for
//! the [`Op`] IR): records every dispatched operation into a linear
//! [`TraceProgram`] — a `Vec<Op>` plus operand wiring — that can be
//! replayed on *any* backend via [`TensorBackend::dispatch`].
//!
//! Capture executes eagerly through the inner backend (trace-through), so
//! the traced run produces normal results; the side effect is a
//! self-contained program: external operands are snapshotted into a
//! constant pool, and `FromHost` ops carry their data by value. Replay of
//! a deterministic program on the capturing backend is bit-identical to
//! the eager run (random ops re-draw from the RNG by design).
//!
//! This is the enabling layer for graph serialization, autotuned fusion,
//! and multi-backend sharding: a cross-cutting concern that previously
//! required ~60 overrides is ~20 lines over the IR.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::interpose::{InterposedBackend, Interposer};
use super::op::Op;
use super::{Tensor, TensorBackend};
use crate::util::error::Result;

/// Where an instruction operand comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueRef {
    /// The constant pool (an external operand snapshotted at capture).
    Const(usize),
    /// The output of an earlier instruction.
    Out(usize),
}

/// One captured operation with its operand wiring.
#[derive(Debug, Clone)]
pub struct TraceInstr {
    /// The reified operation.
    pub op: Op,
    /// Operand sources, in argument order.
    pub inputs: Vec<ValueRef>,
}

/// A linear, self-contained, backend-portable program.
#[derive(Clone, Default)]
pub struct TraceProgram {
    /// External operands captured as constants.
    pub consts: Vec<Tensor>,
    /// The instruction sequence, in dispatch order.
    pub instrs: Vec<TraceInstr>,
}

impl TraceProgram {
    /// Number of captured instructions.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// Whether anything was captured.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Re-execute the program on `backend`, returning every instruction's
    /// output (the last entry is the program's final result). Works on any
    /// [`TensorBackend`] — replay goes through `dispatch`, so it can
    /// itself be profiled, re-traced, or deferred.
    pub fn replay_on(&self, backend: &dyn TensorBackend) -> Result<Vec<Tensor>> {
        let mut outs: Vec<Tensor> = Vec::with_capacity(self.instrs.len());
        for instr in &self.instrs {
            let out = {
                let args: Vec<&Tensor> = instr
                    .inputs
                    .iter()
                    .map(|r| match r {
                        ValueRef::Const(i) => &self.consts[*i],
                        ValueRef::Out(i) => &outs[*i],
                    })
                    .collect();
                backend.dispatch(&instr.op, &args)?
            };
            outs.push(out);
        }
        Ok(outs)
    }

    /// Op names in capture order (diagnostics / tests).
    pub fn op_names(&self) -> Vec<&'static str> {
        self.instrs.iter().map(|i| i.op.name()).collect()
    }
}

#[derive(Default)]
struct TraceState {
    program: TraceProgram,
    /// Adapter-pointer identity -> where that tensor lives in the program.
    regs: HashMap<usize, ValueRef>,
    /// Keeps every captured output's adapter alive for the duration of the
    /// capture, so the pointer keys in `regs` can never be reused by a
    /// freed-and-reallocated adapter.
    outputs: Vec<Tensor>,
}

/// Tensor identity for wiring: the adapter allocation behind the handle.
fn key(t: &Tensor) -> usize {
    t.adapter() as *const dyn super::adapter::TensorAdapter as *const () as usize
}

/// The capturing interposer. Thread-safe; concurrent captures interleave
/// in dispatch order.
#[derive(Default)]
pub struct Tracer {
    state: Mutex<TraceState>,
}

impl Tracer {
    /// Fresh tracer with an empty program.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot the captured program.
    pub fn program(&self) -> TraceProgram {
        self.state.lock().unwrap().program.clone()
    }

    /// Number of instructions captured so far.
    pub fn captured_ops(&self) -> usize {
        self.state.lock().unwrap().program.instrs.len()
    }

    /// Discard the captured program and start over.
    pub fn clear(&self) {
        let mut st = self.state.lock().unwrap();
        st.program.consts.clear();
        st.program.instrs.clear();
        st.regs.clear();
        st.outputs.clear();
    }

    /// Where `t` lives in the captured program — `Out` for a traced
    /// result, `Const` for an external operand the tracer snapshotted —
    /// or `None` if the tracer never saw it. Used by
    /// [`super::graph::trace_and_compile`] to locate roots and parameters.
    pub fn value_ref_of(&self, t: &Tensor) -> Option<ValueRef> {
        self.state.lock().unwrap().regs.get(&key(t)).copied()
    }

    /// The constant-pool slot `t` was snapshotted into, if it entered the
    /// trace as an external operand.
    pub fn const_index_of(&self, t: &Tensor) -> Option<usize> {
        match self.value_ref_of(t) {
            Some(ValueRef::Const(i)) => Some(i),
            _ => None,
        }
    }
}

impl Interposer for Tracer {
    fn name(&self) -> &str {
        "trace"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        // trace-through: execute first so capture never changes results
        let out = inner.dispatch(op, inputs)?;
        let mut st = self.state.lock().unwrap();
        let mut refs = Vec::with_capacity(inputs.len());
        for t in inputs {
            let k = key(t);
            let r = match st.regs.get(&k) {
                Some(r) => *r,
                None => {
                    // external operand: snapshot into the constant pool
                    let r = ValueRef::Const(st.program.consts.len());
                    st.program.consts.push((*t).clone());
                    st.regs.insert(k, r);
                    r
                }
            };
            refs.push(r);
        }
        let id = st.program.instrs.len();
        st.program.instrs.push(TraceInstr { op: op.clone(), inputs: refs });
        st.regs.insert(key(&out), ValueRef::Out(id));
        st.outputs.push(out.clone());
        Ok(out)
    }
}

/// A capturing wrapper over any backend: run code as usual, get back a
/// replayable [`TraceProgram`].
pub type TraceBackend = InterposedBackend<Tracer>;

impl TraceBackend {
    /// Capture over the reference CPU backend.
    pub fn over_cpu_default() -> Arc<TraceBackend> {
        InterposedBackend::over_cpu(Tracer::new())
    }

    /// Capture over an arbitrary inner backend.
    pub fn over(inner: Arc<dyn TensorBackend>) -> Arc<TraceBackend> {
        InterposedBackend::new(Tracer::new(), inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::cpu::CpuBackend;
    use crate::tensor::BackendGuard;

    #[test]
    fn captured_program_replays_bit_identically_on_cpu() {
        // eager reference: explicit typed calls on the CPU backend, so the
        // reference is immune to whatever default backend other tests have
        // installed concurrently
        let av: Vec<f32> = (0..16).map(|i| 0.25 * i as f32 - 2.0).collect();
        let bv: Vec<f32> = (0..16).map(|i| 1.0 - 0.125 * i as f32).collect();
        let cpu = CpuBackend::shared();
        let eager = {
            let a = cpu.from_host(crate::tensor::HostBuffer::F32(av.clone()), [4, 4].into());
            let b = cpu.from_host(crate::tensor::HostBuffer::F32(bv.clone()), [4, 4].into());
            let y = cpu.tanh(&cpu.add(&cpu.matmul(&a, &b), &b));
            cpu.sum(&y, &[1], false).to_vec()
        };

        // the same computation under the trace backend, via the public API
        let be = TraceBackend::over_cpu_default();
        let traced = {
            let _guard = BackendGuard::install(be.clone());
            let a = crate::tensor::Tensor::from_slice(&av, [4, 4]);
            let b = crate::tensor::Tensor::from_slice(&bv, [4, 4]);
            a.matmul(&b).add(&b).tanh().sum(&[-1], false).to_vec()
        };
        assert_eq!(eager, traced, "capture must be trace-through");

        // replay the captured program on the plain CPU backend
        let program = be.interposer().program();
        // 2 from_host + matmul + add + tanh + sum
        assert!(program.len() >= 6, "ops: {:?}", program.op_names());
        assert!(program.op_names().contains(&"matmul"));
        let outs = program.replay_on(cpu.as_ref()).unwrap();
        let replayed = outs.last().unwrap().to_vec();
        assert_eq!(eager, replayed, "replay must be bit-identical to eager execution");
    }

    #[test]
    fn external_operands_are_snapshotted_as_constants() {
        let be = TraceBackend::over_cpu_default();
        // operands created *outside* the traced backend
        let a = crate::tensor::Tensor::from_slice(&[1.0f32, 2.0], [2]);
        let b = crate::tensor::Tensor::from_slice(&[3.0f32, 4.0], [2]);
        let _ = be.add(&a, &b);
        let p = be.interposer().program();
        assert_eq!(p.len(), 1);
        assert_eq!(p.consts.len(), 2);
        assert_eq!(p.instrs[0].inputs, vec![ValueRef::Const(0), ValueRef::Const(1)]);
        // the program is self-contained: replay without the originals
        drop((a, b));
        let outs = p.replay_on(CpuBackend::shared().as_ref()).unwrap();
        assert_eq!(outs[0].to_vec(), vec![4.0, 6.0]);
    }

    #[test]
    fn dataflow_wires_outputs_to_later_inputs() {
        let be = TraceBackend::over_cpu_default();
        let a = crate::tensor::Tensor::from_slice(&[2.0f32, 3.0], [2]);
        let y = be.mul(&a, &a); // instr 0
        let _ = be.add(&y, &a); // instr 1: inputs (Out(0), Const(0))
        let p = be.interposer().program();
        assert_eq!(p.instrs[1].inputs[0], ValueRef::Out(0));
        assert_eq!(p.instrs[1].inputs[1], ValueRef::Const(0));
        let outs = p.replay_on(CpuBackend::shared().as_ref()).unwrap();
        assert_eq!(outs[1].to_vec(), vec![6.0, 12.0]);
    }

    #[test]
    fn clear_resets_capture() {
        let be = TraceBackend::over_cpu_default();
        let a = crate::tensor::Tensor::from_slice(&[1.0f32], [1]);
        let _ = be.neg(&a);
        assert_eq!(be.interposer().captured_ops(), 1);
        be.interposer().clear();
        assert!(be.interposer().program().is_empty());
    }
}
