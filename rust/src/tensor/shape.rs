//! Shapes, strides, and broadcasting.
//!
//! Tensors are row-major ("C order"): the last dimension is contiguous.
//! Broadcasting follows NumPy rules — shapes are aligned at the trailing
//! dimensions and size-1 dimensions stretch.

use crate::util::error::{Error, Result};

/// A tensor shape (dimension sizes). Rank-0 (`Shape::scalar()`) denotes a
/// scalar with one element.
#[derive(Clone, PartialEq, Eq, Hash, Default)]
pub struct Shape(Vec<usize>);

impl Shape {
    /// Build from dimension sizes.
    pub fn new(dims: impl Into<Vec<usize>>) -> Self {
        Shape(dims.into())
    }

    /// The rank-0 scalar shape.
    pub fn scalar() -> Self {
        Shape(Vec::new())
    }

    /// Dimension sizes.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Size of dimension `axis` (negative axes wrap).
    pub fn dim(&self, axis: isize) -> usize {
        self.0[self.normalize_axis(axis)]
    }

    /// Map a possibly-negative axis to `0..rank`. Panics when out of range
    /// (an internal invariant; public APIs validate first).
    pub fn normalize_axis(&self, axis: isize) -> usize {
        let rank = self.rank() as isize;
        let a = if axis < 0 { axis + rank } else { axis };
        assert!(
            (0..rank.max(1)).contains(&a),
            "axis {axis} out of range for rank {rank}"
        );
        a as usize
    }

    /// Validate and normalize an axis, returning an error instead of
    /// panicking.
    pub fn checked_axis(&self, axis: isize) -> Result<usize> {
        let rank = self.rank() as isize;
        let a = if axis < 0 { axis + rank } else { axis };
        if (0..rank.max(1)).contains(&a) {
            Ok(a as usize)
        } else {
            Err(Error::Index(format!("axis {axis} out of range for rank {rank}")))
        }
    }

    /// Row-major strides (in elements).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Broadcast two shapes (NumPy rules).
    pub fn broadcast(&self, other: &Shape) -> Result<Shape> {
        let rank = self.rank().max(other.rank());
        let mut out = vec![0usize; rank];
        for i in 0..rank {
            let a = if i < rank - self.rank() { 1 } else { self.0[i - (rank - self.rank())] };
            let b = if i < rank - other.rank() { 1 } else { other.0[i - (rank - other.rank())] };
            out[i] = if a == b {
                a
            } else if a == 1 {
                b
            } else if b == 1 {
                a
            } else {
                return Err(Error::ShapeMismatch(format!(
                    "cannot broadcast {self} with {other}"
                )));
            };
        }
        Ok(Shape(out))
    }

    /// Strides for iterating `self` as if broadcast to `target`:
    /// broadcast dimensions get stride 0. `self` must be broadcastable to
    /// `target`.
    pub fn broadcast_strides(&self, target: &Shape) -> Result<Vec<usize>> {
        if self.broadcast(target)? != *target {
            return Err(Error::ShapeMismatch(format!(
                "{self} does not broadcast to {target}"
            )));
        }
        let own = self.strides();
        let offset = target.rank() - self.rank();
        let mut out = vec![0usize; target.rank()];
        for i in 0..self.rank() {
            out[offset + i] = if self.0[i] == 1 { 0 } else { own[i] };
        }
        Ok(out)
    }

    /// Shape with `axes` removed (for reductions with `keepdims=false`) or
    /// set to 1 (`keepdims=true`). `axes` must be normalized and sorted.
    pub fn reduce(&self, axes: &[usize], keepdims: bool) -> Shape {
        let mut out = Vec::new();
        for (i, &d) in self.0.iter().enumerate() {
            if axes.contains(&i) {
                if keepdims {
                    out.push(1);
                }
            } else {
                out.push(d);
            }
        }
        Shape(out)
    }

    /// Resolve a reshape target that may contain a single `-1` wildcard.
    pub fn resolve_reshape(&self, target: &[isize]) -> Result<Shape> {
        let numel = self.numel();
        let mut wild = None;
        let mut known = 1usize;
        for (i, &d) in target.iter().enumerate() {
            if d == -1 {
                if wild.is_some() {
                    return Err(Error::ShapeMismatch("multiple -1 in reshape".into()));
                }
                wild = Some(i);
            } else if d < 0 {
                return Err(Error::ShapeMismatch(format!("bad dim {d} in reshape")));
            } else {
                known *= d as usize;
            }
        }
        let mut dims: Vec<usize> =
            target.iter().map(|&d| if d < 0 { 0 } else { d as usize }).collect();
        if let Some(i) = wild {
            if known == 0 || numel % known != 0 {
                return Err(Error::ShapeMismatch(format!(
                    "cannot infer -1 reshaping {numel} elements into {target:?}"
                )));
            }
            dims[i] = numel / known;
        }
        let out = Shape(dims);
        if out.numel() != numel {
            return Err(Error::ShapeMismatch(format!(
                "reshape {self} ({numel} elements) -> {out} ({} elements)",
                out.numel()
            )));
        }
        Ok(out)
    }
}

impl std::fmt::Debug for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self}")
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Self {
        Shape(v.to_vec())
    }
}

impl<const N: usize> From<[usize; N]> for Shape {
    fn from(v: [usize; N]) -> Self {
        Shape(v.to_vec())
    }
}

/// Iterate multi-dimensional indices of `shape`, yielding flat offsets for
/// each of the provided stride vectors. The workhorse of broadcast loops.
pub struct StridedIter<'a> {
    shape: &'a [usize],
    idx: Vec<usize>,
    offsets: Vec<usize>,
    strides: Vec<&'a [usize]>,
    remaining: usize,
}

impl<'a> StridedIter<'a> {
    /// Iterate `shape`, tracking an offset per stride vector.
    pub fn new(shape: &'a Shape, strides: Vec<&'a [usize]>) -> Self {
        StridedIter {
            shape: shape.dims(),
            idx: vec![0; shape.rank()],
            offsets: vec![0; strides.len()],
            strides,
            remaining: shape.numel(),
        }
    }
}

impl<'a> Iterator for StridedIter<'a> {
    type Item = Vec<usize>; // offsets snapshot

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.remaining == 0 {
            return None;
        }
        let out = self.offsets.clone();
        self.remaining -= 1;
        // increment odometer
        for d in (0..self.shape.len()).rev() {
            self.idx[d] += 1;
            for (o, s) in self.offsets.iter_mut().zip(&self.strides) {
                *o += s[d];
            }
            if self.idx[d] < self.shape[d] {
                break;
            }
            for (o, s) in self.offsets.iter_mut().zip(&self.strides) {
                *o -= s[d] * self.shape[d];
            }
            self.idx[d] = 0;
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(Shape::new(vec![2, 3, 4]).strides(), vec![12, 4, 1]);
        assert_eq!(Shape::scalar().strides(), Vec::<usize>::new());
    }

    #[test]
    fn broadcast_rules() {
        let a = Shape::new(vec![3, 1, 5]);
        let b = Shape::new(vec![4, 5]);
        assert_eq!(a.broadcast(&b).unwrap().dims(), &[3, 4, 5]);
        let s = Shape::scalar();
        assert_eq!(s.broadcast(&a).unwrap(), a);
        assert!(Shape::new(vec![2]).broadcast(&Shape::new(vec![3])).is_err());
    }

    #[test]
    fn broadcast_strides_zero_on_stretched() {
        let a = Shape::new(vec![3, 1]);
        let t = Shape::new(vec![2, 3, 4]);
        assert_eq!(a.broadcast_strides(&t).unwrap(), vec![0, 1, 0]);
        assert!(Shape::new(vec![5]).broadcast_strides(&t).is_err());
    }

    #[test]
    fn reduce_shapes() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.reduce(&[1], false).dims(), &[2, 4]);
        assert_eq!(s.reduce(&[1], true).dims(), &[2, 1, 4]);
        assert_eq!(s.reduce(&[0, 1, 2], false).dims(), &[] as &[usize]);
    }

    #[test]
    fn resolve_reshape_wildcard() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.resolve_reshape(&[6, -1]).unwrap().dims(), &[6, 4]);
        assert_eq!(s.resolve_reshape(&[-1]).unwrap().dims(), &[24]);
        assert!(s.resolve_reshape(&[-1, -1]).is_err());
        assert!(s.resolve_reshape(&[5, -1]).is_err());
        assert!(s.resolve_reshape(&[7, 7]).is_err());
    }

    #[test]
    fn negative_axes() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.normalize_axis(-1), 2);
        assert_eq!(s.dim(-2), 3);
        assert!(s.checked_axis(3).is_err());
        assert!(s.checked_axis(-4).is_err());
    }

    #[test]
    fn strided_iter_broadcast_walk() {
        // walk [2,3] with a [3]-shaped operand broadcast across rows
        let target = Shape::new(vec![2, 3]);
        let a = Shape::new(vec![3]);
        let sa = a.broadcast_strides(&target).unwrap();
        let st = target.strides();
        let offs: Vec<Vec<usize>> = StridedIter::new(&target, vec![&st, &sa]).collect();
        assert_eq!(offs.len(), 6);
        assert_eq!(offs[0], vec![0, 0]);
        assert_eq!(offs[4], vec![4, 1]);
        assert_eq!(offs[5], vec![5, 2]);
    }
}
