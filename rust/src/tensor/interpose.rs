//! Backend interposition (paper §5.2.4) over the [`Op`] IR.
//!
//! The old plug-in story required mirroring the ~60-method backend
//! surface (a `DelegateBackend` trait plus a 300-line forwarding macro).
//! With operations reified as [`Op`] data, a wrapper backend is now *one
//! function*: implement [`Interposer::intercept`], wrap it in
//! [`InterposedBackend`], and every operation in the framework — every
//! bias add, every autograd accumulation, every composed `gelu` — flows
//! through your function before (or instead of) reaching the inner
//! backend.
//!
//! ```ignore
//! struct CountAdds { adds: AtomicU64 }
//!
//! impl Interposer for CountAdds {
//!     fn name(&self) -> &str { "count-adds" }
//!     fn intercept(&self, op: &Op, inputs: &[&Tensor], inner: &dyn TensorBackend)
//!         -> Result<Tensor>
//!     {
//!         if matches!(op, Op::Add) { self.adds.fetch_add(1, Ordering::Relaxed); }
//!         inner.dispatch(op, inputs)
//!     }
//! }
//!
//! let be = InterposedBackend::over_cpu(CountAdds { adds: AtomicU64::new(0) });
//! let _guard = BackendGuard::install(be.clone());
//! ```
//!
//! This module is the Rust rendition of the paper's "simply subclass or
//! swap out the existing implementation of the add function ... all add
//! operations in Flashlight dispatch to that operator" — except the
//! subclass surface is a single choke point instead of sixty methods.
//! The deferred ([`super::lazy`]), AOT/XLA ([`super::xla_backend`]),
//! profiling ([`super::profile`]), tracing ([`super::trace`]) and
//! bloat-baseline ([`crate::baseline`]) backends are all built this way.

use std::sync::Arc;

use super::backend::{Conv2dParams, Pool2dParams, TensorBackend};
use super::dtype::DType;
use super::host::HostBuffer;
use super::op::Op;
use super::shape::Shape;
use super::Tensor;
use crate::util::error::Result;

/// A backend defined by a single interception function over the [`Op`]
/// IR. The default implementation is a transparent pass-through.
pub trait Interposer: Send + Sync {
    /// Name reported by the wrapping backend (errors, telemetry, benches).
    fn name(&self) -> &str;

    /// The single choke point: observe, modify, redirect, or replace the
    /// operation. Forward to `inner.dispatch(op, inputs)` for everything
    /// you do not handle; `inner` is the wrapped backend, so recursion is
    /// impossible unless you re-enter the public `Tensor` API.
    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        inner.dispatch(op, inputs)
    }
}

/// A full [`TensorBackend`] generated from one [`Interposer`]: every
/// typed method reifies its arguments into an [`Op`] and funnels through
/// [`Interposer::intercept`]. This single generic type replaces the old
/// per-wrapper `impl_delegate_backend!` expansion.
pub struct InterposedBackend<I: Interposer> {
    interposer: I,
    inner: Arc<dyn TensorBackend>,
}

impl<I: Interposer> InterposedBackend<I> {
    /// Wrap `inner` with `interposer`.
    pub fn new(interposer: I, inner: Arc<dyn TensorBackend>) -> Arc<Self> {
        Arc::new(InterposedBackend { interposer, inner })
    }

    /// Wrap the reference CPU backend (the common case).
    pub fn over_cpu(interposer: I) -> Arc<Self> {
        Self::new(interposer, super::cpu::CpuBackend::shared())
    }

    /// The interposer (wrapper-specific state: counters, traces, …).
    pub fn interposer(&self) -> &I {
        &self.interposer
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<dyn TensorBackend> {
        &self.inner
    }

    /// Funnel for the infallible typed methods: the `TensorBackend`
    /// surface returns `Tensor` (panicking on internal errors), so a
    /// failed interception surfaces as a panic carrying op + backend.
    fn run(&self, op: Op, inputs: &[&Tensor]) -> Tensor {
        match self.interposer.intercept(&op, inputs, self.inner.as_ref()) {
            Ok(t) => t,
            Err(e) => panic!("backend `{}`: op `{}` failed: {e}", self.interposer.name(), op.name()),
        }
    }
}

macro_rules! funnel_unary {
    ($($meth:ident => $variant:ident),* $(,)?) => {
        $(fn $meth(&self, x: &Tensor) -> Tensor {
            self.run(Op::$variant, &[x])
        })*
    };
}

macro_rules! funnel_binary {
    ($($meth:ident => $variant:ident),* $(,)?) => {
        $(fn $meth(&self, a: &Tensor, b: &Tensor) -> Tensor {
            self.run(Op::$variant, &[a, b])
        })*
    };
}

macro_rules! funnel_reduce {
    ($($meth:ident => $variant:ident),* $(,)?) => {
        $(fn $meth(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
            self.run(Op::$variant { axes: axes.to_vec(), keepdims }, &[x])
        })*
    };
}

impl<I: Interposer> TensorBackend for InterposedBackend<I> {
    fn name(&self) -> &str {
        self.interposer.name()
    }

    /// `dispatch` itself routes through the interposer, so callers that
    /// speak the IR directly (trace replay, tests, other wrappers) see
    /// the same single choke point as the typed surface.
    fn dispatch(&self, op: &Op, inputs: &[&Tensor]) -> Result<Tensor> {
        self.interposer.intercept(op, inputs, self.inner.as_ref())
    }

    fn full(&self, shape: &Shape, value: f64, dtype: DType) -> Tensor {
        self.run(Op::Full { shape: shape.clone(), value, dtype }, &[])
    }
    fn arange(&self, n: usize, dtype: DType) -> Tensor {
        self.run(Op::Arange { n, dtype }, &[])
    }
    fn rand_uniform(&self, shape: &Shape, lo: f64, hi: f64, dtype: DType) -> Tensor {
        self.run(Op::RandUniform { shape: shape.clone(), lo, hi, dtype }, &[])
    }
    fn rand_normal(&self, shape: &Shape, mean: f64, std: f64, dtype: DType) -> Tensor {
        self.run(Op::RandNormal { shape: shape.clone(), mean, std, dtype }, &[])
    }
    fn from_host(&self, host: HostBuffer, shape: Shape) -> Tensor {
        self.run(Op::FromHost { host, shape }, &[])
    }

    funnel_unary! {
        neg => Neg, abs => Abs, sign => Sign, exp => Exp, log => Log, log1p => Log1p,
        sin => Sin, cos => Cos, tanh => Tanh, sqrt => Sqrt, rsqrt => Rsqrt,
        reciprocal => Reciprocal, floor => Floor, ceil => Ceil, round => Round,
        erf => Erf, logical_not => LogicalNot, isnan => IsNan,
    }

    fn clip(&self, x: &Tensor, lo: f64, hi: f64) -> Tensor {
        self.run(Op::Clip { lo, hi }, &[x])
    }

    funnel_binary! {
        add => Add, sub => Sub, mul => Mul, div => Div, pow => Pow,
        minimum => Minimum, maximum => Maximum, rem => Rem,
        eq => Eq, neq => Neq, lt => Lt, le => Le, gt => Gt, ge => Ge,
        logical_and => LogicalAnd, logical_or => LogicalOr,
        matmul => Matmul,
    }

    funnel_reduce! {
        sum => Sum, prod => Prod, max_reduce => MaxReduce, min_reduce => MinReduce,
        any => Any, all => All,
    }

    fn argmax(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor {
        self.run(Op::Argmax { axis, keepdims }, &[x])
    }
    fn argmin(&self, x: &Tensor, axis: usize, keepdims: bool) -> Tensor {
        self.run(Op::Argmin { axis, keepdims }, &[x])
    }
    fn cumsum(&self, x: &Tensor, axis: usize) -> Tensor {
        self.run(Op::Cumsum { axis }, &[x])
    }

    fn conv2d(&self, x: &Tensor, w: &Tensor, p: Conv2dParams) -> Tensor {
        self.run(Op::Conv2d(p), &[x, w])
    }
    fn conv2d_bwd_input(&self, gy: &Tensor, w: &Tensor, xs: &Shape, p: Conv2dParams) -> Tensor {
        self.run(Op::Conv2dBwdInput { x_shape: xs.clone(), params: p }, &[gy, w])
    }
    fn conv2d_bwd_filter(&self, gy: &Tensor, x: &Tensor, ws: &Shape, p: Conv2dParams) -> Tensor {
        self.run(Op::Conv2dBwdFilter { w_shape: ws.clone(), params: p }, &[gy, x])
    }
    fn pool2d(&self, x: &Tensor, p: Pool2dParams) -> Tensor {
        self.run(Op::Pool2d(p), &[x])
    }
    fn pool2d_bwd(&self, gy: &Tensor, x: &Tensor, p: Pool2dParams) -> Tensor {
        self.run(Op::Pool2dBwd(p), &[gy, x])
    }

    fn reshape(&self, x: &Tensor, shape: &Shape) -> Tensor {
        self.run(Op::Reshape { shape: shape.clone() }, &[x])
    }
    fn transpose(&self, x: &Tensor, perm: &[usize]) -> Tensor {
        self.run(Op::Transpose { perm: perm.to_vec() }, &[x])
    }
    fn slice(&self, x: &Tensor, starts: &[usize], ends: &[usize]) -> Tensor {
        self.run(Op::Slice { starts: starts.to_vec(), ends: ends.to_vec() }, &[x])
    }
    fn concat(&self, xs: &[&Tensor], axis: usize) -> Tensor {
        self.run(Op::Concat { axis }, xs)
    }
    fn pad(&self, x: &Tensor, pads: &[(usize, usize)], value: f64) -> Tensor {
        self.run(Op::Pad { pads: pads.to_vec(), value }, &[x])
    }
    fn tile(&self, x: &Tensor, reps: &[usize]) -> Tensor {
        self.run(Op::Tile { reps: reps.to_vec() }, &[x])
    }
    fn flip(&self, x: &Tensor, axes: &[usize]) -> Tensor {
        self.run(Op::Flip { axes: axes.to_vec() }, &[x])
    }
    fn index_select(&self, x: &Tensor, axis: usize, indices: &Tensor) -> Tensor {
        self.run(Op::IndexSelect { axis }, &[x, indices])
    }
    fn scatter_add(&self, base: &Tensor, indices: &Tensor, src: &Tensor) -> Tensor {
        self.run(Op::ScatterAdd, &[base, indices, src])
    }
    fn where_cond(&self, cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
        self.run(Op::WhereCond, &[cond, a, b])
    }
    fn astype(&self, x: &Tensor, dtype: DType) -> Tensor {
        self.run(Op::Astype { dtype }, &[x])
    }
    fn copy(&self, x: &Tensor) -> Tensor {
        self.run(Op::Copy, &[x])
    }

    fn call_ext(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.interposer.intercept(
            &Op::CallExt { name: name.to_string() },
            inputs,
            self.inner.as_ref(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{BackendGuard, Shape};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// The paper's §5.2.4 example, one-function edition: swap the source
    /// of truth for `add` (here: count dispatches, then forward).
    struct CountingAdd {
        adds: AtomicU64,
        total: AtomicU64,
    }

    impl Interposer for CountingAdd {
        fn name(&self) -> &str {
            "counting-add"
        }
        fn intercept(
            &self,
            op: &Op,
            inputs: &[&Tensor],
            inner: &dyn TensorBackend,
        ) -> Result<Tensor> {
            self.total.fetch_add(1, Ordering::Relaxed);
            if matches!(op, Op::Add) {
                self.adds.fetch_add(1, Ordering::Relaxed);
            }
            inner.dispatch(op, inputs)
        }
    }

    fn counting() -> Arc<InterposedBackend<CountingAdd>> {
        InterposedBackend::over_cpu(CountingAdd {
            adds: AtomicU64::new(0),
            total: AtomicU64::new(0),
        })
    }

    #[test]
    fn one_function_sees_every_op() {
        let be = counting();
        let x = be.full(&Shape::new(vec![3]), 2.0, crate::tensor::DType::F32);
        let y = be.add(&x, &x);
        assert_eq!(y.to_vec(), vec![4.0; 3]);
        let _ = be.mul(&x, &x);
        assert_eq!(be.interposer().adds.load(Ordering::Relaxed), 1);
        // full + add + mul all crossed the choke point
        assert!(be.interposer().total.load(Ordering::Relaxed) >= 3);
        assert_eq!(be.name(), "counting-add");
    }

    #[test]
    fn composed_ops_route_through_interception() {
        // installed as default backend, *derived* ops pick up the
        // interposer with zero call-site changes (paper §5.2.4's point)
        let be = counting();
        let _guard = BackendGuard::install(be.clone());
        let t = Tensor::rand([4, 4], -1.0, 1.0);
        let _ = t.gelu(); // gelu composition includes add_scalar -> add
        assert!(
            be.interposer().adds.load(Ordering::Relaxed) >= 1,
            "derived op did not hit the interposer"
        );
    }

    #[test]
    fn dispatch_and_typed_surface_share_the_choke_point() {
        let be = counting();
        let a = be.from_host(crate::tensor::HostBuffer::F32(vec![1.0, 2.0]), Shape::new(vec![2]));
        let before = be.interposer().adds.load(Ordering::Relaxed);
        let via_ir = be.dispatch(&Op::Add, &[&a, &a]).unwrap();
        let via_typed = be.add(&a, &a);
        assert_eq!(via_ir.to_vec(), via_typed.to_vec());
        assert_eq!(be.interposer().adds.load(Ordering::Relaxed), before + 2);
    }

    #[test]
    fn errors_propagate_through_call_ext() {
        let be = counting();
        assert!(be.call_ext("definitely_missing", &[]).is_err());
    }
}
