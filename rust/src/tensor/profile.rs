//! Per-op profiling as a one-function [`Interposer`] (proof of power for
//! the [`Op`] IR): counts and wall-clock nanoseconds for every primitive
//! that crosses the dispatch choke point, aggregated into the
//! [`crate::meter`] machinery.
//!
//! ```ignore
//! let be = ProfilingBackend::over_cpu_default();
//! let _guard = BackendGuard::install(be.clone());
//! // ... run any model, unchanged ...
//! println!("{}", be.interposer().report());
//! ```

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::interpose::{InterposedBackend, Interposer};
use super::op::Op;
use super::{Tensor, TensorBackend};
use crate::meter::AverageValueMeter;
use crate::util::error::Result;

/// Aggregate for one op kind, as returned by [`Profiler::snapshot`].
#[derive(Debug, Clone)]
pub struct OpStat {
    /// Op name (see [`Op::name`]).
    pub op: &'static str,
    /// Number of dispatches observed.
    pub calls: u64,
    /// Mean nanoseconds per dispatch.
    pub mean_ns: f64,
    /// Total nanoseconds across all dispatches.
    pub total_ns: f64,
}

/// The profiling interposer: one [`AverageValueMeter`] per op name.
#[derive(Default)]
pub struct Profiler {
    meters: Mutex<HashMap<&'static str, AverageValueMeter>>,
}

impl Profiler {
    /// Fresh profiler with no recorded ops.
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-op aggregates, heaviest (by total time) first.
    pub fn snapshot(&self) -> Vec<OpStat> {
        let meters = self.meters.lock().unwrap();
        let mut stats: Vec<OpStat> = meters
            .iter()
            .map(|(op, m)| OpStat {
                op,
                calls: m.count(),
                mean_ns: m.value(),
                total_ns: m.value() * m.count() as f64,
            })
            .collect();
        stats.sort_by(|a, b| b.total_ns.partial_cmp(&a.total_ns).unwrap());
        stats
    }

    /// Total dispatches across all ops.
    pub fn total_calls(&self) -> u64 {
        self.meters.lock().unwrap().values().map(|m| m.count()).sum()
    }

    /// Drop all recorded data.
    pub fn reset(&self) {
        self.meters.lock().unwrap().clear();
    }

    /// A human-readable table (op, calls, mean µs, total ms).
    pub fn report(&self) -> String {
        let mut out = format!("{:<18} {:>8} {:>12} {:>12}\n", "OP", "CALLS", "mean (µs)", "total (ms)");
        for s in self.snapshot() {
            out.push_str(&format!(
                "{:<18} {:>8} {:>12.2} {:>12.3}\n",
                s.op,
                s.calls,
                s.mean_ns / 1e3,
                s.total_ns / 1e6
            ));
        }
        out
    }
}

impl Interposer for Profiler {
    fn name(&self) -> &str {
        "profiling"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        let t0 = Instant::now();
        let out = inner.dispatch(op, inputs);
        let ns = t0.elapsed().as_nanos() as f64;
        self.meters.lock().unwrap().entry(op.name()).or_default().add(ns);
        out
    }
}

/// A profiling wrapper over any backend: per-op counts and nanoseconds
/// for the *entire* primitive surface, from one function.
pub type ProfilingBackend = InterposedBackend<Profiler>;

impl ProfilingBackend {
    /// Profile the reference CPU backend.
    pub fn over_cpu_default() -> Arc<ProfilingBackend> {
        InterposedBackend::over_cpu(Profiler::new())
    }

    /// Profile an arbitrary inner backend.
    pub fn over(inner: Arc<dyn TensorBackend>) -> Arc<ProfilingBackend> {
        InterposedBackend::new(Profiler::new(), inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::BackendGuard;

    #[test]
    fn profiles_every_op_without_overrides() {
        let be = ProfilingBackend::over_cpu_default();
        let _guard = BackendGuard::install(be.clone());
        let a = Tensor::rand([8, 8], -1.0, 1.0);
        let b = Tensor::rand([8, 8], -1.0, 1.0);
        let _ = a.matmul(&b).gelu().sum(&[], false).item();
        let stats = be.interposer().snapshot();
        let names: Vec<&str> = stats.iter().map(|s| s.op).collect();
        // primitives hit directly
        assert!(names.contains(&"matmul"), "{names:?}");
        assert!(names.contains(&"sum"), "{names:?}");
        // primitives reached only through composition (gelu -> erf, mul)
        assert!(names.contains(&"erf"), "{names:?}");
        assert!(names.contains(&"mul"), "{names:?}");
        for s in &stats {
            assert!(s.calls >= 1);
            assert!(s.total_ns >= 0.0);
        }
        assert!(be.interposer().total_calls() >= 6);
    }

    #[test]
    fn numerics_are_untouched() {
        crate::util::rng::seed(31);
        let av = Tensor::rand([6, 6], -1.0, 1.0).to_vec();
        let plain = {
            let a = Tensor::from_slice(&av, [6, 6]);
            a.matmul(&a).gelu().to_vec()
        };
        let profiled = {
            let be = ProfilingBackend::over_cpu_default();
            let _guard = BackendGuard::install(be);
            let a = Tensor::from_slice(&av, [6, 6]);
            a.matmul(&a).gelu().to_vec()
        };
        assert_eq!(plain, profiled, "profiling must be observation-only");
    }

    #[test]
    fn reset_and_report() {
        let be = ProfilingBackend::over_cpu_default();
        let x = be.full(&crate::tensor::Shape::new(vec![4]), 1.0, crate::tensor::DType::F32);
        let _ = be.add(&x, &x);
        assert!(be.interposer().report().contains("add"));
        be.interposer().reset();
        assert_eq!(be.interposer().total_calls(), 0);
    }
}
