//! Per-op profiling as a one-function [`Interposer`] (proof of power for
//! the [`Op`] IR): counts and wall-clock nanoseconds for every primitive
//! that crosses the dispatch choke point, aggregated into the
//! [`crate::meter`] machinery.
//!
//! ```ignore
//! let be = ProfilingBackend::over_cpu_default();
//! let _guard = BackendGuard::install(be.clone());
//! // ... run any model, unchanged ...
//! println!("{}", be.interposer().report());
//! ```

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::interpose::{InterposedBackend, Interposer};
use super::op::Op;
use super::{Tensor, TensorBackend};
use crate::meter::AverageValueMeter;
use crate::util::error::Result;

/// Aggregate for one op kind, as returned by [`Profiler::snapshot`].
#[derive(Debug, Clone)]
pub struct OpStat {
    /// Op name (see [`Op::name`]).
    pub op: &'static str,
    /// Number of dispatches observed.
    pub calls: u64,
    /// Mean nanoseconds per dispatch.
    pub mean_ns: f64,
    /// Total nanoseconds across all dispatches.
    pub total_ns: f64,
}

/// The profiling interposer: one [`AverageValueMeter`] per op name.
#[derive(Default)]
pub struct Profiler {
    meters: Mutex<HashMap<&'static str, AverageValueMeter>>,
}

impl Profiler {
    /// Fresh profiler with no recorded ops.
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-op aggregates, heaviest (by total time) first; ties (and NaN
    /// totals, which sort last) break on op name so the ordering — and
    /// every report built from it — is deterministic. Each snapshot also
    /// publishes per-op call counts and total time into the process-wide
    /// metrics registry (`profile.op.<name>.calls` /
    /// `profile.op.<name>.total_ns`).
    pub fn snapshot(&self) -> Vec<OpStat> {
        let meters = self.meters.lock().unwrap();
        let mut stats: Vec<OpStat> = meters
            .iter()
            .map(|(op, m)| OpStat {
                op,
                calls: m.count(),
                mean_ns: m.value(),
                total_ns: m.value() * m.count() as f64,
            })
            .collect();
        stats.sort_by(|a, b| {
            let key = |s: &OpStat| {
                // NaN (never produced by the meter, but cheap to rule
                // out) orders after every finite total
                if s.total_ns.is_nan() { f64::NEG_INFINITY } else { s.total_ns }
            };
            key(b)
                .partial_cmp(&key(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.op.cmp(b.op))
        });
        for s in &stats {
            crate::obs::counter(&format!("profile.op.{}.calls", s.op)).set(s.calls);
            crate::obs::gauge(&format!("profile.op.{}.total_ns", s.op)).set(s.total_ns);
        }
        stats
    }

    /// Total dispatches across all ops.
    pub fn total_calls(&self) -> u64 {
        self.meters.lock().unwrap().values().map(|m| m.count()).sum()
    }

    /// Drop all recorded data.
    pub fn reset(&self) {
        self.meters.lock().unwrap().clear();
    }

    /// A human-readable table (op, calls, mean µs, total ms).
    pub fn report(&self) -> String {
        let mut out = format!("{:<18} {:>8} {:>12} {:>12}\n", "OP", "CALLS", "mean (µs)", "total (ms)");
        for s in self.snapshot() {
            out.push_str(&format!(
                "{:<18} {:>8} {:>12.2} {:>12.3}\n",
                s.op,
                s.calls,
                s.mean_ns / 1e3,
                s.total_ns / 1e6
            ));
        }
        out
    }
}

impl Interposer for Profiler {
    fn name(&self) -> &str {
        "profiling"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        let t0 = Instant::now();
        let out = inner.dispatch(op, inputs);
        let ns = t0.elapsed().as_nanos() as f64;
        self.meters.lock().unwrap().entry(op.name()).or_default().add(ns);
        out
    }
}

/// A profiling wrapper over any backend: per-op counts and nanoseconds
/// for the *entire* primitive surface, from one function.
pub type ProfilingBackend = InterposedBackend<Profiler>;

impl ProfilingBackend {
    /// Profile the reference CPU backend.
    pub fn over_cpu_default() -> Arc<ProfilingBackend> {
        InterposedBackend::over_cpu(Profiler::new())
    }

    /// Profile an arbitrary inner backend.
    pub fn over(inner: Arc<dyn TensorBackend>) -> Arc<ProfilingBackend> {
        InterposedBackend::new(Profiler::new(), inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::BackendGuard;

    #[test]
    fn profiles_every_op_without_overrides() {
        let be = ProfilingBackend::over_cpu_default();
        let _guard = BackendGuard::install(be.clone());
        let a = Tensor::rand([8, 8], -1.0, 1.0);
        let b = Tensor::rand([8, 8], -1.0, 1.0);
        let _ = a.matmul(&b).gelu().sum(&[], false).item();
        let stats = be.interposer().snapshot();
        let names: Vec<&str> = stats.iter().map(|s| s.op).collect();
        // primitives hit directly
        assert!(names.contains(&"matmul"), "{names:?}");
        assert!(names.contains(&"sum"), "{names:?}");
        // primitives reached only through composition (gelu -> erf, mul)
        assert!(names.contains(&"erf"), "{names:?}");
        assert!(names.contains(&"mul"), "{names:?}");
        for s in &stats {
            assert!(s.calls >= 1);
            assert!(s.total_ns >= 0.0);
        }
        assert!(be.interposer().total_calls() >= 6);
    }

    #[test]
    fn numerics_are_untouched() {
        crate::util::rng::seed(31);
        let av = Tensor::rand([6, 6], -1.0, 1.0).to_vec();
        let plain = {
            let a = Tensor::from_slice(&av, [6, 6]);
            a.matmul(&a).gelu().to_vec()
        };
        let profiled = {
            let be = ProfilingBackend::over_cpu_default();
            let _guard = BackendGuard::install(be);
            let a = Tensor::from_slice(&av, [6, 6]);
            a.matmul(&a).gelu().to_vec()
        };
        assert_eq!(plain, profiled, "profiling must be observation-only");
    }

    #[test]
    fn snapshot_ordering_is_deterministic() {
        let p = Profiler::new();
        // three ops with equal totals (one call of 100ns each) plus one
        // clear winner: ties must break on name, every time. Synthetic op
        // names keep the registry assertions isolated from other tests'
        // profiler runs (metric names are process-global).
        {
            let mut meters = p.meters.lock().unwrap();
            for op in ["ztie_mul", "ztie_add", "ztie_sub"] {
                meters.entry(op).or_default().add(100.0);
            }
            meters.entry("ztie_matmul").or_default().add(5000.0);
        }
        let order: Vec<&str> = p.snapshot().iter().map(|s| s.op).collect();
        assert_eq!(
            order,
            vec!["ztie_matmul", "ztie_add", "ztie_mul", "ztie_sub"],
            "total desc, then name asc"
        );
        for _ in 0..10 {
            let again: Vec<&str> = p.snapshot().iter().map(|s| s.op).collect();
            assert_eq!(again, order, "snapshot ordering must be stable across calls");
        }
        // the snapshot published per-op counts into the metrics registry
        assert_eq!(crate::obs::counter("profile.op.ztie_matmul.calls").get(), 1);
        assert_eq!(crate::obs::gauge("profile.op.ztie_matmul.total_ns").get(), 5000.0);
    }

    #[test]
    fn reset_and_report() {
        let be = ProfilingBackend::over_cpu_default();
        let x = be.full(&crate::tensor::Shape::new(vec![4]), 1.0, crate::tensor::DType::F32);
        let _ = be.add(&x, &x);
        assert!(be.interposer().report().contains("add"));
        be.interposer().reset();
        assert_eq!(be.interposer().total_calls(), 0);
    }
}
