//! Differentiable operators over [`Variable`]s.
//!
//! Each operator calls the underlying [`Tensor`] primitive and records a
//! `gradFunc` on the tape — exactly the pattern of paper Listing 4 (whose
//! cosine example is reproduced verbatim as [`cos`]). Broadcasting ops
//! reduce their gradients back to the operand shapes via
//! [`reduce_grad_to`].

use crate::tensor::{Conv2dParams, DType, Pool2dParams, Shape, Tensor};

use super::Variable;

/// Sum `grad` over broadcast dimensions so it matches `target`.
pub fn reduce_grad_to(grad: &Tensor, target: &Shape) -> Tensor {
    if grad.shape() == target {
        return grad.clone();
    }
    let gdims = grad.dims().to_vec();
    let tdims = target.dims();
    let extra = gdims.len() - tdims.len();
    let mut axes: Vec<isize> = (0..extra as isize).collect();
    for (i, &td) in tdims.iter().enumerate() {
        if td == 1 && gdims[extra + i] != 1 {
            axes.push((extra + i) as isize);
        }
    }
    let mut out = grad.sum(&axes, false);
    if out.shape() != target {
        let dims: Vec<isize> = tdims.iter().map(|&d| d as isize).collect();
        out = out.reshape(&dims);
    }
    out
}

// ---- arithmetic ---------------------------------------------------------

/// `a + b` (broadcasting).
pub fn add(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().add(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "add", |ins, g| {
        vec![
            Some(reduce_grad_to(g, &ins[0].shape())),
            Some(reduce_grad_to(g, &ins[1].shape())),
        ]
    })
}

/// `a - b` (broadcasting).
pub fn sub(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().sub(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "sub", |ins, g| {
        vec![
            Some(reduce_grad_to(g, &ins[0].shape())),
            Some(reduce_grad_to(&g.neg(), &ins[1].shape())),
        ]
    })
}

/// `a * b` (broadcasting).
pub fn mul(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().mul(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "mul", |ins, g| {
        vec![
            Some(reduce_grad_to(&g.mul(&ins[1].tensor()), &ins[0].shape())),
            Some(reduce_grad_to(&g.mul(&ins[0].tensor()), &ins[1].shape())),
        ]
    })
}

/// `a / b` (broadcasting).
pub fn div(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().div(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "div", |ins, g| {
        let bt = ins[1].tensor();
        let ga = g.div(&bt);
        let gb = g.mul(&ins[0].tensor()).div(&bt.mul(&bt)).neg();
        vec![
            Some(reduce_grad_to(&ga, &ins[0].shape())),
            Some(reduce_grad_to(&gb, &ins[1].shape())),
        ]
    })
}

/// `-a`.
pub fn neg(a: &Variable) -> Variable {
    Variable::from_op(a.tensor().neg(), vec![a.clone()], "neg", |_, g| vec![Some(g.neg())])
}

/// `a + s` for a scalar.
pub fn add_scalar(a: &Variable, s: f64) -> Variable {
    Variable::from_op(a.tensor().add_scalar(s), vec![a.clone()], "add_scalar", |_, g| {
        vec![Some(g.clone())]
    })
}

/// `a * s` for a scalar.
pub fn mul_scalar(a: &Variable, s: f64) -> Variable {
    Variable::from_op(a.tensor().mul_scalar(s), vec![a.clone()], "mul_scalar", move |_, g| {
        vec![Some(g.mul_scalar(s))]
    })
}

/// `a^p` for a scalar exponent.
pub fn pow_scalar(a: &Variable, p: f64) -> Variable {
    let out = a.tensor().pow_scalar(p);
    Variable::from_op(out, vec![a.clone()], "pow_scalar", move |ins, g| {
        let x = ins[0].tensor();
        vec![Some(g.mul(&x.pow_scalar(p - 1.0).mul_scalar(p)))]
    })
}

// ---- transcendental ------------------------------------------------------

/// `e^a` (gradient reuses the forward output).
pub fn exp(a: &Variable) -> Variable {
    let out = a.tensor().exp();
    let saved = out.clone();
    Variable::from_op(out, vec![a.clone()], "exp", move |_, g| vec![Some(g.mul(&saved))])
}

/// `ln a`.
pub fn log(a: &Variable) -> Variable {
    Variable::from_op(a.tensor().log(), vec![a.clone()], "log", |ins, g| {
        vec![Some(g.div(&ins[0].tensor()))]
    })
}

/// Paper Listing 4, verbatim: cosine with `gradFunc` pushing
/// `-sin(x) * grad_output`.
pub fn cos(a: &Variable) -> Variable {
    let result = a.tensor().cos();
    Variable::from_op(result, vec![a.clone()], "cos", |inputs, grad_output| {
        vec![Some(inputs[0].tensor().sin().neg().mul(grad_output))]
    })
}

/// Sine.
pub fn sin(a: &Variable) -> Variable {
    Variable::from_op(a.tensor().sin(), vec![a.clone()], "sin", |ins, g| {
        vec![Some(ins[0].tensor().cos().mul(g))]
    })
}

/// Hyperbolic tangent.
pub fn tanh(a: &Variable) -> Variable {
    let out = a.tensor().tanh();
    let saved = out.clone();
    Variable::from_op(out, vec![a.clone()], "tanh", move |_, g| {
        // g * (1 - y^2)
        vec![Some(g.mul(&saved.mul(&saved).neg().add_scalar(1.0)))]
    })
}

/// Square root.
pub fn sqrt(a: &Variable) -> Variable {
    let out = a.tensor().sqrt();
    let saved = out.clone();
    Variable::from_op(out, vec![a.clone()], "sqrt", move |_, g| {
        vec![Some(g.div(&saved.mul_scalar(2.0)))]
    })
}

/// Absolute value (subgradient 0 at 0 via sign).
pub fn abs(a: &Variable) -> Variable {
    Variable::from_op(a.tensor().abs(), vec![a.clone()], "abs", |ins, g| {
        vec![Some(g.mul(&ins[0].tensor().sign()))]
    })
}

// ---- activations ------------------------------------------------------------

/// ReLU (derived from `maximum` in the tensor API; custom gradient mask).
pub fn relu(a: &Variable) -> Variable {
    let out = a.tensor().relu();
    Variable::from_op(out, vec![a.clone()], "relu", |ins, g| {
        let x = ins[0].tensor();
        let mask = x.gt(&Tensor::zeros(x.dims().to_vec())).astype(DType::F32);
        vec![Some(g.mul(&mask))]
    })
}

/// Logistic sigmoid.
pub fn sigmoid(a: &Variable) -> Variable {
    let out = a.tensor().sigmoid();
    let saved = out.clone();
    Variable::from_op(out, vec![a.clone()], "sigmoid", move |_, g| {
        vec![Some(g.mul(&saved).mul(&saved.neg().add_scalar(1.0)))]
    })
}

/// Exact GELU.
pub fn gelu(a: &Variable) -> Variable {
    let out = a.tensor().gelu();
    Variable::from_op(out, vec![a.clone()], "gelu", |ins, g| {
        let x = ins[0].tensor();
        // d/dx [x Φ(x)] = Φ(x) + x φ(x)
        let phi = x.mul_scalar(1.0 / std::f64::consts::SQRT_2).erf().add_scalar(1.0).mul_scalar(0.5);
        let pdf = x
            .mul(&x)
            .mul_scalar(-0.5)
            .exp()
            .mul_scalar(1.0 / (2.0 * std::f64::consts::PI).sqrt());
        vec![Some(g.mul(&phi.add(&x.mul(&pdf))))]
    })
}

/// Element-wise max with gradient routed to the winner (ties to `a`).
pub fn maximum(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().maximum(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "maximum", |ins, g| {
        let (at, bt) = (ins[0].tensor(), ins[1].tensor());
        let amask = at.ge(&bt).astype(DType::F32);
        let bmask = amask.neg().add_scalar(1.0);
        vec![
            Some(reduce_grad_to(&g.mul(&amask), &ins[0].shape())),
            Some(reduce_grad_to(&g.mul(&bmask), &ins[1].shape())),
        ]
    })
}

/// Element-wise min with routed gradient (ties to `a`).
pub fn minimum(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().minimum(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "minimum", |ins, g| {
        let (at, bt) = (ins[0].tensor(), ins[1].tensor());
        let amask = at.le(&bt).astype(DType::F32);
        let bmask = amask.neg().add_scalar(1.0);
        vec![
            Some(reduce_grad_to(&g.mul(&amask), &ins[0].shape())),
            Some(reduce_grad_to(&g.mul(&bmask), &ins[1].shape())),
        ]
    })
}

// ---- reductions ---------------------------------------------------------------

fn keepdims_shape(x: &Shape, axes: &[isize]) -> Vec<isize> {
    let naxes: Vec<usize> = axes.iter().map(|&a| x.normalize_axis(a)).collect();
    x.dims()
        .iter()
        .enumerate()
        .map(|(i, &d)| if naxes.contains(&i) || axes.is_empty() { 1 } else { d as isize })
        .collect()
}

/// Sum over `axes` (empty = all).
pub fn sum(a: &Variable, axes: &[isize], keepdims: bool) -> Variable {
    let out = a.tensor().sum(axes, keepdims);
    let axes_v = axes.to_vec();
    Variable::from_op(out, vec![a.clone()], "sum", move |ins, g| {
        let xshape = ins[0].shape();
        let gk = if keepdims { g.clone() } else { g.reshape(&keepdims_shape(&xshape, &axes_v)) };
        vec![Some(gk.broadcast_to(xshape.clone()))]
    })
}

/// Mean over `axes` (empty = all).
pub fn mean(a: &Variable, axes: &[isize], keepdims: bool) -> Variable {
    let x = a.tensor();
    let naxes: Vec<usize> = if axes.is_empty() {
        (0..x.rank()).collect()
    } else {
        axes.iter().map(|&ax| x.shape().normalize_axis(ax)).collect()
    };
    let count: usize = naxes.iter().map(|&ax| x.dims()[ax]).product();
    mul_scalar(&sum(a, axes, keepdims), 1.0 / count as f64)
}

/// Max over `axes`; gradient flows to arg-max positions (split on ties).
pub fn max(a: &Variable, axes: &[isize], keepdims: bool) -> Variable {
    let out = a.tensor().max(axes, keepdims);
    let axes_v = axes.to_vec();
    Variable::from_op(out, vec![a.clone()], "max", move |ins, g| {
        let x = ins[0].tensor();
        let mk = x.max(&axes_v, true);
        let mask = x.eq(&mk).astype(DType::F32);
        let norm = mask.sum(&axes_v, true);
        let gk = if keepdims {
            g.clone()
        } else {
            g.reshape(&keepdims_shape(&ins[0].shape(), &axes_v))
        };
        vec![Some(mask.div(&norm).mul(&gk))]
    })
}

// ---- shape -----------------------------------------------------------------------

/// Reshape.
pub fn reshape(a: &Variable, dims: &[isize]) -> Variable {
    let out = a.tensor().reshape(dims);
    Variable::from_op(out, vec![a.clone()], "reshape", |ins, g| {
        let target: Vec<isize> = ins[0].dims().iter().map(|&d| d as isize).collect();
        vec![Some(g.reshape(&target))]
    })
}

/// Permute dimensions.
pub fn transpose(a: &Variable, perm: &[usize]) -> Variable {
    let out = a.tensor().transpose(perm);
    let perm_v = perm.to_vec();
    Variable::from_op(out, vec![a.clone()], "transpose", move |_, g| {
        let mut inv = vec![0usize; perm_v.len()];
        for (i, &p) in perm_v.iter().enumerate() {
            inv[p] = i;
        }
        vec![Some(g.transpose(&inv))]
    })
}

/// Swap the last two dims.
pub fn t(a: &Variable) -> Variable {
    let r = a.tensor().rank();
    let mut perm: Vec<usize> = (0..r).collect();
    perm.swap(r - 2, r - 1);
    transpose(a, &perm)
}

/// Rectangular slice; gradient zero-pads back.
pub fn slice(a: &Variable, starts: &[usize], ends: &[usize]) -> Variable {
    let out = a.tensor().slice(starts, ends);
    let (s, e) = (starts.to_vec(), ends.to_vec());
    Variable::from_op(out, vec![a.clone()], "slice", move |ins, g| {
        let dims = ins[0].dims();
        let pads: Vec<(usize, usize)> =
            (0..dims.len()).map(|d| (s[d], dims[d] - e[d])).collect();
        vec![Some(g.pad(&pads, 0.0))]
    })
}

/// Concatenate along `axis`; gradient slices back per input.
pub fn concat(xs: &[&Variable], axis: isize) -> Variable {
    let tensors: Vec<Tensor> = xs.iter().map(|v| v.tensor()).collect();
    let refs: Vec<&Tensor> = tensors.iter().collect();
    let out = Tensor::concat(&refs, axis);
    let a = out.shape().normalize_axis(axis);
    let owned: Vec<Variable> = xs.iter().map(|&v| v.clone()).collect();
    Variable::from_op(out, owned, "concat", move |ins, g| {
        let mut grads = Vec::with_capacity(ins.len());
        let mut off = 0usize;
        for v in ins {
            let len = v.dims()[a];
            grads.push(Some(g.narrow(a as isize, off, len)));
            off += len;
        }
        grads
    })
}

/// Gather rows along axis 0 (embedding lookup); gradient scatter-adds.
pub fn index_select0(a: &Variable, indices: &Tensor) -> Variable {
    let out = a.tensor().index_select(0, indices);
    let idx = indices.clone();
    Variable::from_op(out, vec![a.clone()], "index_select0", move |ins, g| {
        let zeros = Tensor::zeros(ins[0].dims());
        // flatten gathered grad rows to [n, rest]
        let n = idx.numel();
        let rest: usize = ins[0].dims()[1..].iter().product();
        let gflat = g.reshape(&[n as isize, rest as isize]);
        let flat_idx = idx.reshape(&[n as isize]);
        let base_rest: Vec<isize> = ins[0].dims().iter().map(|&d| d as isize).collect();
        let acc = zeros
            .reshape(&[base_rest[0], rest as isize])
            .scatter_add(&flat_idx, &gflat)
            .reshape(&base_rest);
        vec![Some(acc)]
    })
}

// ---- linear algebra / nn ------------------------------------------------------------

/// Matrix multiply (2-D or batched 3-D; batch broadcast allowed on either
/// side — the gradient reduces over broadcast batch dims).
pub fn matmul(a: &Variable, b: &Variable) -> Variable {
    let out = a.tensor().matmul(&b.tensor());
    Variable::from_op(out, vec![a.clone(), b.clone()], "matmul", |ins, g| {
        let (at, bt) = (ins[0].tensor(), ins[1].tensor());
        let ga = g.matmul(&bt.t());
        let gb = at.t().matmul(g);
        vec![
            Some(reduce_grad_to(&ga, &ins[0].shape())),
            Some(reduce_grad_to(&gb, &ins[1].shape())),
        ]
    })
}

/// 2-D convolution (NCHW x OIHW).
pub fn conv2d(x: &Variable, w: &Variable, p: Conv2dParams) -> Variable {
    let out = x.tensor().conv2d(&w.tensor(), p);
    Variable::from_op(out, vec![x.clone(), w.clone()], "conv2d", move |ins, g| {
        let xt = ins[0].tensor();
        let wt = ins[1].tensor();
        let be = crate::tensor::default_backend();
        let gx = be.conv2d_bwd_input(g, &wt, xt.shape(), p);
        let gw = be.conv2d_bwd_filter(g, &xt, wt.shape(), p);
        vec![Some(gx), Some(gw)]
    })
}

/// 2-D pooling.
pub fn pool2d(x: &Variable, p: Pool2dParams) -> Variable {
    let out = x.tensor().pool2d(p);
    Variable::from_op(out, vec![x.clone()], "pool2d", move |ins, g| {
        let xt = ins[0].tensor();
        vec![Some(crate::tensor::default_backend().pool2d_bwd(g, &xt, p))]
    })
}

// ---- softmax family -------------------------------------------------------------------

/// Numerically-stable softmax along `axis` with the fused gradient
/// `y ⊙ (g − Σ g⊙y)`.
pub fn softmax(a: &Variable, axis: isize) -> Variable {
    let out = a.tensor().softmax(axis);
    let saved = out.clone();
    Variable::from_op(out, vec![a.clone()], "softmax", move |_, g| {
        let dot = g.mul(&saved).sum(&[axis], true);
        vec![Some(saved.mul(&g.sub(&dot)))]
    })
}

/// Numerically-stable log-softmax with gradient `g − e^y · Σ g`.
pub fn log_softmax(a: &Variable, axis: isize) -> Variable {
    let out = a.tensor().log_softmax(axis);
    let saved = out.clone();
    Variable::from_op(out, vec![a.clone()], "log_softmax", move |_, g| {
        let gsum = g.sum(&[axis], true);
        vec![Some(g.sub(&saved.exp().mul(&gsum)))]
    })
}

// ---- convenience composite -----------------------------------------------------------

/// Mean of `(a-b)^2` over everything.
pub fn mse(a: &Variable, b: &Variable) -> Variable {
    let d = sub(a, b);
    mean(&mul(&d, &d), &[], false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::gradcheck::check_grad;

    #[test]
    fn listing4_cosine() {
        let x = Variable::param(Tensor::from_slice(&[0.5f32, 1.0], [2]));
        let y = cos(&x);
        y.backward_seeded(Tensor::ones([2]), &Default::default());
        let g = x.grad().unwrap().to_vec();
        assert!((g[0] - (-0.5f32.sin())).abs() < 1e-5);
        assert!((g[1] - (-1.0f32.sin())).abs() < 1e-5);
    }

    #[test]
    fn broadcast_grad_reduces() {
        // [2,3] + [3] — grad of bias is summed over rows
        let a = Variable::param(Tensor::ones([2, 3]));
        let b = Variable::param(Tensor::ones([3]));
        let y = sum(&add(&a, &b), &[], false);
        y.backward();
        assert_eq!(b.grad().unwrap().dims(), &[3]);
        assert_eq!(b.grad().unwrap().to_vec(), vec![2.0; 3]);
        assert_eq!(a.grad().unwrap().to_vec(), vec![1.0; 6]);
    }

    #[test]
    fn matmul_grads_match_numeric() {
        let w = Variable::constant(Tensor::rand([3, 2], -1.0, 1.0));
        check_grad("matmul-a", &[4, 3], move |x| sum(&matmul(x, &w), &[], false));
        let x = Variable::constant(Tensor::rand([4, 3], -1.0, 1.0));
        check_grad("matmul-b", &[3, 2], move |w| sum(&matmul(&x, w), &[], false));
    }

    #[test]
    fn unary_grads_match_numeric() {
        check_grad("exp", &[5], |x| sum(&exp(x), &[], false));
        check_grad("tanh", &[5], |x| sum(&tanh(x), &[], false));
        check_grad("sigmoid", &[5], |x| sum(&sigmoid(x), &[], false));
        check_grad("gelu", &[5], |x| sum(&gelu(x), &[], false));
        check_grad("sin", &[5], |x| sum(&sin(x), &[], false));
    }

    #[test]
    fn softmax_grads_match_numeric() {
        let w = Variable::constant(Tensor::rand([3, 4], 0.0, 1.0));
        let w2 = w.clone();
        check_grad("softmax", &[3, 4], move |x| sum(&mul(&softmax(x, -1), &w), &[], false));
        check_grad("log_softmax", &[3, 4], move |x| {
            sum(&mul(&log_softmax(x, -1), &w2), &[], false)
        });
    }

    #[test]
    fn reduction_grads_match_numeric() {
        check_grad("mean-axis", &[3, 4], |x| sum(&mean(x, &[1], false), &[], false));
        let w = Variable::constant(Tensor::rand([2, 1], 0.5, 1.5));
        check_grad("sum-keep", &[2, 3], move |x| {
            sum(&mul(&sum(x, &[1], true), &w), &[], false)
        });
    }

    #[test]
    fn shape_op_grads() {
        let w = Variable::constant(Tensor::rand([3, 4], -1.0, 1.0));
        check_grad("reshape", &[2, 6], move |x| {
            sum(&mul(&reshape(x, &[3, 4]), &w), &[], false)
        });
        let w = Variable::constant(Tensor::rand([3, 2], -1.0, 1.0));
        check_grad("transpose", &[2, 3], move |x| sum(&mul(&t(x), &w), &[], false));
        check_grad("slice", &[4, 4], |x| sum(&slice(x, &[1, 0], &[3, 2]), &[], false));
    }

    #[test]
    fn concat_grads_split() {
        let a = Variable::param(Tensor::ones([2, 2]));
        let b = Variable::param(Tensor::ones([2, 3]));
        let c = concat(&[&a, &b], 1);
        let w = Variable::constant(Tensor::arange(10, DType::F32).reshape(&[2, 5]));
        sum(&mul(&c, &w), &[], false).backward();
        assert_eq!(a.grad().unwrap().to_vec(), vec![0., 1., 5., 6.]);
        assert_eq!(b.grad().unwrap().to_vec(), vec![2., 3., 4., 7., 8., 9.]);
    }

    #[test]
    fn index_select_scatter_grad() {
        let emb = Variable::param(Tensor::arange(8, DType::F32).reshape(&[4, 2]));
        let idx = Tensor::from_slice(&[1i64, 1, 3], [3]);
        let picked = index_select0(&emb, &idx);
        sum(&picked, &[], false).backward();
        let g = emb.grad().unwrap().to_vec();
        assert_eq!(g, vec![0., 0., 2., 2., 0., 0., 1., 1.]);
    }

    #[test]
    fn conv_pool_grads_match_numeric() {
        let w = Variable::constant(Tensor::rand([3, 2, 3, 3], -0.5, 0.5));
        check_grad("conv2d-x", &[1, 2, 5, 5], move |x| {
            sum(&conv2d(x, &w, Conv2dParams { stride: (1, 1), padding: (1, 1) }), &[], false)
        });
        let x = Variable::constant(Tensor::rand([1, 2, 5, 5], -0.5, 0.5));
        check_grad("conv2d-w", &[2, 2, 3, 3], move |w| {
            sum(&conv2d(&x, w, Conv2dParams { stride: (2, 2), padding: (0, 0) }), &[], false)
        });
        check_grad("avgpool", &[1, 1, 4, 4], |x| {
            use crate::tensor::PoolKind;
            sum(
                &pool2d(x, Pool2dParams { kind: PoolKind::Avg, kernel: (2, 2), stride: (2, 2) }),
                &[],
                false,
            )
        });
    }

    #[test]
    fn max_reduction_grad_routes() {
        let x = Variable::param(Tensor::from_slice(&[1.0f32, 5.0, 3.0, 2.0], [2, 2]));
        let m = max(&x, &[1], false);
        sum(&m, &[], false).backward();
        assert_eq!(x.grad().unwrap().to_vec(), vec![0., 1., 1., 0.]);
    }

    #[test]
    fn mse_value_and_grad() {
        let a = Variable::param(Tensor::from_slice(&[1.0f32, 2.0], [2]));
        let b = Variable::constant(Tensor::from_slice(&[0.0f32, 0.0], [2]));
        let l = mse(&a, &b);
        assert!((l.tensor().item() - 2.5).abs() < 1e-6);
        l.backward();
        assert_eq!(a.grad().unwrap().to_vec(), vec![1.0, 2.0]);
    }
}
