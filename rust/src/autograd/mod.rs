//! Tape-based automatic differentiation (paper §4.2, Listing 4).
//!
//! A [`Variable`] wraps a [`Tensor`]; operators on Variables call the
//! underlying tensor ops and record a node on a dynamic tape. The design
//! deliberately separates `Tensor` from `Variable` so non-gradient
//! algorithms pay no autograd overhead, and keeps the tape open for
//! customization — the paper's §5.2.1 case study (differentiable beam
//! search over million-node graphs) is supported directly via
//! [`BackwardOpts`]: on-the-fly zero-gradient pruning and explicit node
//! lifetime control ([`Variable::release_graph`]).

pub mod ops;

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::tensor::{Shape, Tensor};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

type GradFn = Box<dyn Fn(&[Variable], &Tensor) -> Vec<Option<Tensor>> + Send + Sync>;

/// A recorded tape node: the inputs of an op and its gradient function
/// (mirrors the `gradFunc` lambda of paper Listing 4).
pub struct GraphNode {
    /// Operator inputs (kept alive while the node lives).
    pub inputs: Vec<Variable>,
    /// Maps (inputs, upstream grad) -> per-input gradients.
    pub grad_fn: GradFn,
    /// Operator name (debugging / telemetry).
    pub name: &'static str,
}

struct VarInner {
    id: u64,
    tensor: RwLock<Tensor>,
    grad: Mutex<Option<Tensor>>,
    requires_grad: bool,
    graph: Mutex<Option<GraphNode>>,
}

/// A differentiable tensor handle. Clones share state.
#[derive(Clone)]
pub struct Variable {
    inner: Arc<VarInner>,
}

thread_local! {
    static NO_GRAD_DEPTH: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// Run `f` with tape recording disabled (evaluation loops).
pub fn no_grad<T>(f: impl FnOnce() -> T) -> T {
    NO_GRAD_DEPTH.with(|d| d.set(d.get() + 1));
    let out = f();
    NO_GRAD_DEPTH.with(|d| d.set(d.get() - 1));
    out
}

/// Is tape recording currently disabled on this thread?
pub fn is_no_grad() -> bool {
    NO_GRAD_DEPTH.with(|d| d.get() > 0)
}

/// Options for [`Variable::backward_with`].
#[derive(Debug, Clone, Copy)]
pub struct BackwardOpts {
    /// Keep tape nodes alive after the pass (for repeated backward).
    /// Default false: nodes are released, mirroring the §5.2.1
    /// custom-lifetime optimization.
    pub retain_graph: bool,
    /// Skip propagating through nodes whose upstream gradient is exactly
    /// zero — the §5.2.1 "on-the-fly graph pruning" for sparse decoder
    /// lattices.
    pub prune_zero_grads: bool,
}

impl Default for BackwardOpts {
    fn default() -> Self {
        BackwardOpts { retain_graph: false, prune_zero_grads: false }
    }
}

/// Statistics from a backward pass (used by the §5.2.1 ablation bench).
#[derive(Debug, Clone, Copy, Default)]
pub struct BackwardStats {
    /// Tape nodes visited.
    pub nodes_visited: usize,
    /// Nodes skipped by zero-gradient pruning.
    pub nodes_pruned: usize,
    /// Gradient tensors materialized.
    pub grads_computed: usize,
}

impl Variable {
    fn make(tensor: Tensor, requires_grad: bool, graph: Option<GraphNode>) -> Variable {
        Variable {
            inner: Arc::new(VarInner {
                id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
                tensor: RwLock::new(tensor),
                grad: Mutex::new(None),
                requires_grad,
                graph: Mutex::new(graph),
            }),
        }
    }

    /// A trainable variable (gradient will be accumulated).
    pub fn param(tensor: Tensor) -> Variable {
        Variable::make(tensor, true, None)
    }

    /// A constant (the paper's `noGrad`).
    pub fn constant(tensor: Tensor) -> Variable {
        Variable::make(tensor, false, None)
    }

    /// Result of an op: requires grad iff any input does (and recording is
    /// enabled); `grad_fn` receives `(inputs, upstream)` (Listing 4).
    pub fn from_op(
        tensor: Tensor,
        inputs: Vec<Variable>,
        name: &'static str,
        grad_fn: impl Fn(&[Variable], &Tensor) -> Vec<Option<Tensor>> + Send + Sync + 'static,
    ) -> Variable {
        let needs = !is_no_grad() && inputs.iter().any(|v| v.requires_grad_path());
        if needs {
            Variable::make(
                tensor,
                true,
                Some(GraphNode { inputs, grad_fn: Box::new(grad_fn), name }),
            )
        } else {
            Variable::make(tensor, false, None)
        }
    }

    /// Stable identity of this variable.
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// The current value.
    pub fn tensor(&self) -> Tensor {
        self.inner.tensor.read().unwrap().clone()
    }

    /// Replace the value in place (optimizer updates). The tape node, if
    /// any, is untouched.
    pub fn set_tensor(&self, t: Tensor) {
        *self.inner.tensor.write().unwrap() = t;
    }

    /// Shape of the current value.
    pub fn shape(&self) -> Shape {
        self.tensor().shape().clone()
    }

    /// Dims of the current value.
    pub fn dims(&self) -> Vec<usize> {
        self.tensor().dims().to_vec()
    }

    /// Total elements of the current value.
    pub fn numel(&self) -> usize {
        self.tensor().numel()
    }

    /// Whether gradients flow into this variable.
    pub fn requires_grad(&self) -> bool {
        self.inner.requires_grad
    }

    /// Does this variable participate in the tape (itself or upstream)?
    fn requires_grad_path(&self) -> bool {
        self.inner.requires_grad
    }

    /// The accumulated gradient, if any.
    pub fn grad(&self) -> Option<Tensor> {
        self.inner.grad.lock().unwrap().clone()
    }

    /// Clear the accumulated gradient.
    pub fn zero_grad(&self) {
        *self.inner.grad.lock().unwrap() = None;
    }

    /// Accumulate `g` into the gradient buffer.
    pub fn add_grad(&self, g: &Tensor) {
        let mut slot = self.inner.grad.lock().unwrap();
        *slot = Some(match slot.take() {
            Some(prev) => prev.add(g),
            None => g.clone(),
        });
    }

    /// Overwrite the gradient buffer (distributed gradient averaging).
    pub fn set_grad(&self, g: Tensor) {
        *self.inner.grad.lock().unwrap() = Some(g);
    }

    /// Cut this variable loose from the tape (a constant view of the same
    /// value).
    pub fn detach(&self) -> Variable {
        Variable::constant(self.tensor())
    }

    /// Explicitly drop this variable's tape node — the §5.2.1 node-lifetime
    /// control (avoids keeping whole sub-graphs alive via refcounts).
    pub fn release_graph(&self) {
        *self.inner.graph.lock().unwrap() = None;
    }

    /// Name of the op that produced this variable (if on the tape).
    pub fn op_name(&self) -> Option<&'static str> {
        self.inner.graph.lock().unwrap().as_ref().map(|n| n.name)
    }

    /// Backward with default options, seeding d(self)/d(self) = 1.
    pub fn backward(&self) -> BackwardStats {
        self.backward_with(&BackwardOpts::default())
    }

    /// Backward pass from this variable (usually a scalar loss).
    pub fn backward_with(&self, opts: &BackwardOpts) -> BackwardStats {
        let seed = Tensor::ones(self.tensor().dims().to_vec());
        self.backward_seeded(seed, opts)
    }

    /// Backward with an explicit seed gradient, accumulating into each
    /// parameter's gradient slot (the classic mutating tape sweep).
    pub fn backward_seeded(&self, seed: Tensor, opts: &BackwardOpts) -> BackwardStats {
        self.backward_sink(seed, opts, &mut |v, g| v.add_grad(g))
    }

    /// Backward with the gradients returned as *values* instead of written
    /// into the `Mutex` slots: a pure map from variable id to gradient.
    ///
    /// This is the trace-transparent face of the tape: every gradient op
    /// still flows through the installed backend's `dispatch`, but the
    /// results are explicit outputs, so a capturing backend (or
    /// [`crate::coordinator::compile_step`]) can wire them into a compiled
    /// program rather than chasing side effects. The arithmetic is
    /// bit-identical to [`Variable::backward_seeded`] — both run the same
    /// sweep; only the destination of each finished gradient differs.
    pub fn backward_collect(
        &self,
        seed: Tensor,
        opts: &BackwardOpts,
    ) -> (HashMap<u64, Tensor>, BackwardStats) {
        let mut out: HashMap<u64, Tensor> = HashMap::new();
        let stats = self.backward_sink(seed, opts, &mut |v, g| {
            out.insert(v.id(), g.clone());
        });
        (out, stats)
    }

    /// The shared sweep behind [`Variable::backward_seeded`] and
    /// [`Variable::backward_collect`]: `sink` receives each
    /// requires-grad variable exactly once with its fully-accumulated
    /// gradient, in reverse-topological visit order.
    fn backward_sink(
        &self,
        seed: Tensor,
        opts: &BackwardOpts,
        sink: &mut dyn FnMut(&Variable, &Tensor),
    ) -> BackwardStats {
        let mut stats = BackwardStats::default();
        // iterative DFS topological order over tape nodes
        let order = self.topo_order();
        let mut grads: HashMap<u64, Tensor> = HashMap::new();
        grads.insert(self.id(), seed);

        for v in order.iter().rev() {
            let Some(g) = grads.remove(&v.id()) else { continue };
            if v.inner.requires_grad {
                sink(v, &g);
            }
            let node_guard = v.inner.graph.lock().unwrap();
            let Some(node) = node_guard.as_ref() else { continue };
            stats.nodes_visited += 1;
            if opts.prune_zero_grads && is_all_zero(&g) {
                stats.nodes_pruned += 1;
                continue;
            }
            let input_grads = (node.grad_fn)(&node.inputs, &g);
            debug_assert_eq!(input_grads.len(), node.inputs.len(), "grad_fn arity ({})", node.name);
            for (inp, ig) in node.inputs.iter().zip(input_grads) {
                if let Some(ig) = ig {
                    if inp.requires_grad_path() {
                        stats.grads_computed += 1;
                        match grads.get_mut(&inp.id()) {
                            Some(acc) => *acc = acc.add(&ig),
                            None => {
                                grads.insert(inp.id(), ig);
                            }
                        }
                    }
                }
            }
        }
        if !opts.retain_graph {
            for v in &order {
                v.release_graph();
            }
        }
        stats
    }

    fn topo_order(&self) -> Vec<Variable> {
        let mut order = Vec::new();
        let mut visited: HashSet<u64> = HashSet::new();
        // iterative post-order DFS (recursion would overflow on the
        // million-node lattices of §5.2.1)
        let mut stack: Vec<(Variable, usize)> = vec![(self.clone(), 0)];
        visited.insert(self.id());
        while let Some((v, child)) = stack.pop() {
            let next_child = {
                let guard = v.inner.graph.lock().unwrap();
                guard.as_ref().and_then(|n| n.inputs.get(child).cloned())
            };
            match next_child {
                Some(c) => {
                    stack.push((v, child + 1));
                    if visited.insert(c.id()) {
                        stack.push((c, 0));
                    }
                }
                None => order.push(v),
            }
        }
        order
    }
}

fn is_all_zero(t: &Tensor) -> bool {
    // cheap for the scalar nodes of decoder lattices; linear scan otherwise
    t.abs().max(&[], false).item() == 0.0
}

impl std::fmt::Debug for Variable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Variable(id={}, shape={}, requires_grad={}, op={:?})",
            self.id(),
            self.tensor().shape(),
            self.requires_grad(),
            self.op_name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_records_nothing() {
        let c = Variable::constant(Tensor::ones([2]));
        let d = ops::add(&c, &c);
        assert!(!d.requires_grad());
        assert!(d.op_name().is_none());
    }

    #[test]
    fn simple_chain_backward() {
        // y = (x * 3) + 2; dy/dx = 3
        let x = Variable::param(Tensor::from_slice(&[5.0f32], [1]));
        let y = ops::add_scalar(&ops::mul_scalar(&x, 3.0), 2.0);
        assert_eq!(y.tensor().item(), 17.0);
        y.backward();
        assert_eq!(x.grad().unwrap().item(), 3.0);
    }

    #[test]
    fn grad_accumulates_across_uses() {
        // y = x + x => dy/dx = 2
        let x = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        let y = ops::add(&x, &x);
        y.backward();
        assert_eq!(x.grad().unwrap().item(), 2.0);
    }

    #[test]
    fn zero_grad_and_second_pass() {
        let x = Variable::param(Tensor::from_slice(&[2.0f32], [1]));
        let y = ops::mul(&x, &x);
        y.backward();
        assert_eq!(x.grad().unwrap().item(), 4.0);
        x.zero_grad();
        let y2 = ops::mul(&x, &x);
        y2.backward();
        assert_eq!(x.grad().unwrap().item(), 4.0);
    }

    #[test]
    fn no_grad_scope_disables_tape() {
        let x = Variable::param(Tensor::ones([2]));
        let y = no_grad(|| ops::mul(&x, &x));
        assert!(!y.requires_grad());
        assert!(y.op_name().is_none());
    }

    #[test]
    fn detach_cuts_graph() {
        let x = Variable::param(Tensor::from_slice(&[3.0f32], [1]));
        let y = ops::mul(&x, &x).detach();
        let z = ops::mul_scalar(&y, 2.0);
        z.backward();
        assert!(x.grad().is_none());
    }

    #[test]
    fn pruning_skips_zero_branches() {
        // inner's node receives an exactly-zero upstream gradient
        // (killed by the *0 constant), so pruning skips it entirely
        let a = Variable::param(Tensor::ones([4]));
        let b = Variable::param(Tensor::ones([4]));
        let zero = Variable::constant(Tensor::zeros([4]));
        let inner = ops::mul(&a, &a);
        let dead = ops::mul(&inner, &zero);
        let alive = ops::mul_scalar(&b, 2.0);
        let z = ops::sum(&ops::add(&dead, &alive), &[], false);
        let stats = z.backward_with(&BackwardOpts { prune_zero_grads: true, ..Default::default() });
        assert!(stats.nodes_pruned >= 1, "stats: {stats:?}");
        assert_eq!(b.grad().unwrap().to_vec(), vec![2.0; 4]);
    }

    #[test]
    fn deep_chain_no_stack_overflow() {
        // 50k-node chain exercises the iterative DFS
        let x = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        let mut y = x.clone();
        for _ in 0..50_000 {
            y = ops::add_scalar(&y, 1.0);
        }
        y.backward();
        assert_eq!(x.grad().unwrap().item(), 1.0);
    }

    #[test]
    fn backward_collect_is_pure_and_matches_seeded() {
        let x = Variable::param(Tensor::from_slice(&[2.0f32], [1]));
        let y = ops::mul(&x, &x);
        let opts = BackwardOpts { retain_graph: true, ..Default::default() };
        let (grads, stats) = y.backward_collect(Tensor::ones([1]), &opts);
        // pure: the gradient arrives as a value, the slot stays empty
        assert!(x.grad().is_none());
        assert_eq!(grads[&x.id()].item(), 4.0);
        assert!(stats.grads_computed >= 1);
        // the mutating sweep over the retained graph agrees
        y.backward_with(&BackwardOpts::default());
        assert_eq!(x.grad().unwrap().item(), 4.0);
    }

    #[test]
    fn retain_graph_allows_second_backward() {
        let x = Variable::param(Tensor::from_slice(&[3.0f32], [1]));
        let y = ops::mul(&x, &x);
        y.backward_with(&BackwardOpts { retain_graph: true, ..Default::default() });
        y.backward_with(&BackwardOpts::default());
        // two passes accumulate: 6 + 6
        assert_eq!(x.grad().unwrap().item(), 12.0);
    }
}
