//! Metric meters (paper Listings 9–10: `AverageValueMeter`,
//! `FrameErrorMeter`, plus the speech package's edit-distance meter and
//! the serving engine's streaming percentile meter).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Running mean/variance of scalar observations.
#[derive(Debug, Clone, Default)]
pub struct AverageValueMeter {
    n: u64,
    mean: f64,
    m2: f64,
}

impl AverageValueMeter {
    /// Fresh meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation (Welford update).
    pub fn add(&mut self, v: f64) {
        self.n += 1;
        let delta = v - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (v - self.mean);
    }

    /// Current mean (0 when empty).
    pub fn value(&self) -> f64 {
        self.mean
    }

    /// Sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Reset to empty.
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// Running level + high-water mark of an additive quantity (live bytes,
/// queue depth, pending ops). Used by the graph executor to report
/// planned-vs-naive peak memory.
#[derive(Debug, Clone, Copy, Default)]
pub struct PeakValueMeter {
    current: usize,
    peak: usize,
}

impl PeakValueMeter {
    /// Fresh meter at level 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Raise the current level by `v`.
    pub fn add(&mut self, v: usize) {
        self.current += v;
        self.peak = self.peak.max(self.current);
    }

    /// Lower the current level by `v` (saturating).
    pub fn sub(&mut self, v: usize) {
        self.current = self.current.saturating_sub(v);
    }

    /// Current level.
    pub fn current(&self) -> usize {
        self.current
    }

    /// High-water mark.
    pub fn peak(&self) -> usize {
        self.peak
    }
}

/// Streaming quantiles (p50/p95/p99) over a bounded reservoir.
///
/// Observations are kept in a fixed-capacity reservoir (Vitter's
/// Algorithm R with a deterministic in-tree RNG, so a meter fed the same
/// stream always reports the same quantiles): the first `capacity`
/// observations are stored verbatim, after which each new observation
/// replaces a uniformly-random slot with probability `capacity / n`.
/// Memory is O(capacity) no matter how long the stream runs — this is the
/// serving engine's per-request latency meter, where the stream is
/// unbounded by design.
#[derive(Debug, Clone)]
pub struct PercentileMeter {
    reservoir: Vec<f64>,
    capacity: usize,
    n: u64,
    rng: Rng,
}

impl PercentileMeter {
    /// Default reservoir of 1024 observations.
    pub fn new() -> Self {
        Self::with_capacity(1024)
    }

    /// Reservoir bounded at `capacity` observations (must be > 0).
    pub fn with_capacity(capacity: usize) -> Self {
        assert!(capacity > 0, "PercentileMeter needs a non-empty reservoir");
        PercentileMeter {
            reservoir: Vec::with_capacity(capacity.min(4096)),
            capacity,
            n: 0,
            // fixed seed: quantiles are reproducible for a given stream
            rng: Rng::new(0x9E3779B97F4A7C15),
        }
    }

    /// Record one observation.
    pub fn add(&mut self, v: f64) {
        self.n += 1;
        if self.reservoir.len() < self.capacity {
            self.reservoir.push(v);
        } else {
            // Algorithm R: keep each of the n observations with equal
            // probability capacity/n
            let j = (self.rng.next_u64() % self.n) as usize;
            if j < self.capacity {
                self.reservoir[j] = v;
            }
        }
    }

    /// Total observations seen (not the reservoir size).
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Nearest-rank quantile `q` in `[0, 1]` over the reservoir
    /// (0 when empty). Exact while the stream fits the reservoir,
    /// a uniform-sample estimate beyond it.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut sorted = self.reservoir.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.clamp(1, sorted.len()) - 1]
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Reset to empty (the RNG restarts too, keeping resets reproducible).
    pub fn reset(&mut self) {
        let cap = self.capacity;
        *self = Self::with_capacity(cap);
    }
}

impl Default for PercentileMeter {
    fn default() -> Self {
        Self::new()
    }
}

/// Classification frame-error meter: compares predicted ids with targets
/// and reports error percentage (paper Listing 10).
#[derive(Debug, Clone, Default)]
pub struct FrameErrorMeter {
    errors: u64,
    total: u64,
}

impl FrameErrorMeter {
    /// Fresh meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a batch of integer predictions vs targets.
    pub fn add(&mut self, pred: &Tensor, target: &Tensor) {
        let p = pred.to_vec_i64();
        let t = target.to_vec_i64();
        assert_eq!(p.len(), t.len(), "prediction/target length");
        self.total += p.len() as u64;
        self.errors += p.iter().zip(&t).filter(|(a, b)| a != b).count() as u64;
    }

    /// Error rate in percent.
    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            100.0 * self.errors as f64 / self.total as f64
        }
    }

    /// Reset.
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// Top-k accuracy meter (vision benchmarks).
#[derive(Debug, Clone)]
pub struct TopKMeter {
    k: usize,
    hits: u64,
    total: u64,
}

impl TopKMeter {
    /// Track top-`k` accuracy.
    pub fn new(k: usize) -> Self {
        TopKMeter { k, hits: 0, total: 0 }
    }

    /// Record `[N, C]` scores against `[N]` integer targets.
    pub fn add(&mut self, scores: &Tensor, target: &Tensor) {
        let dims = scores.dims().to_vec();
        let (n, c) = (dims[0], dims[1]);
        let s = scores.to_vec();
        let t = target.to_vec_i64();
        for i in 0..n {
            let row = &s[i * c..(i + 1) * c];
            let target_score = row[t[i] as usize];
            let better = row.iter().filter(|&&v| v > target_score).count();
            if better < self.k {
                self.hits += 1;
            }
            self.total += 1;
        }
    }

    /// Accuracy in percent.
    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            100.0 * self.hits as f64 / self.total as f64
        }
    }
}

/// Edit-distance (Levenshtein) meter for sequence tasks (WER/CER in the
/// speech package).
#[derive(Debug, Clone, Default)]
pub struct EditDistanceMeter {
    edits: u64,
    ref_len: u64,
}

impl EditDistanceMeter {
    /// Fresh meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one (hypothesis, reference) token pair.
    pub fn add<T: PartialEq>(&mut self, hyp: &[T], reference: &[T]) {
        self.edits += levenshtein(hyp, reference) as u64;
        self.ref_len += reference.len() as u64;
    }

    /// Error rate in percent (edits / reference length).
    pub fn value(&self) -> f64 {
        if self.ref_len == 0 {
            0.0
        } else {
            100.0 * self.edits as f64 / self.ref_len as f64
        }
    }
}

/// Levenshtein distance between two sequences.
pub fn levenshtein<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ai) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, bj) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ai != bj);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Wall-clock + items/sec throughput meter for training loops.
#[derive(Debug)]
pub struct TimeMeter {
    start: std::time::Instant,
    items: u64,
}

impl TimeMeter {
    /// Start timing.
    pub fn start() -> Self {
        TimeMeter { start: std::time::Instant::now(), items: 0 }
    }

    /// Record processed items.
    pub fn add_items(&mut self, n: u64) {
        self.items += n;
    }

    /// Items per second since start.
    pub fn items_per_sec(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.items as f64 / secs
        }
    }

    /// Elapsed seconds.
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time-weighted mean of a piecewise-constant level — e.g. decode-slot
/// occupancy in the continuous batcher, where "mean active sequences"
/// must weight each batch size by how long it was in effect, not by how
/// many times it was observed.
#[derive(Debug, Default)]
pub struct TimeWeightedMeter {
    level: f64,
    weighted: f64, // ∫ level dt over closed segments
    elapsed: f64,  // total closed-segment seconds
    peak: f64,
    last: Option<std::time::Instant>,
}

impl TimeWeightedMeter {
    /// Empty meter; the clock starts at the first [`Self::set`].
    pub fn new() -> Self {
        Self::default()
    }

    /// The level changed to `level` now: close the previous segment at
    /// the old level and start a new one.
    pub fn set(&mut self, level: f64) {
        let now = std::time::Instant::now();
        if let Some(last) = self.last {
            self.observe(self.level, now.duration_since(last).as_secs_f64());
        }
        self.level = level;
        self.peak = self.peak.max(level);
        self.last = Some(now);
    }

    /// Deterministic low-level entry (and the testable core of
    /// [`Self::set`]): account `level` having held for `secs` seconds.
    pub fn observe(&mut self, level: f64, secs: f64) {
        self.weighted += level * secs;
        self.elapsed += secs;
        self.peak = self.peak.max(level);
    }

    /// Time-weighted mean level over every closed segment (0 before any).
    pub fn mean(&self) -> f64 {
        if self.elapsed == 0.0 {
            0.0
        } else {
            self.weighted / self.elapsed
        }
    }

    /// Highest level seen.
    pub fn peak(&self) -> f64 {
        self.peak
    }

    /// Total accounted seconds.
    pub fn seconds(&self) -> f64 {
        self.elapsed
    }

    /// Forget everything.
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_meter_welford() {
        let mut m = AverageValueMeter::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.add(v);
        }
        assert!((m.value() - 2.5).abs() < 1e-12);
        assert!((m.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.count(), 4);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn percentile_meter_exact_within_reservoir() {
        let mut m = PercentileMeter::with_capacity(256);
        // 1..=100 in shuffled order: nearest-rank quantiles are exact
        let mut vals: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let mut r = Rng::new(5);
        r.shuffle(&mut vals);
        for v in vals {
            m.add(v);
        }
        assert_eq!(m.count(), 100);
        assert_eq!(m.p50(), 50.0);
        assert_eq!(m.p95(), 95.0);
        assert_eq!(m.p99(), 99.0);
        assert_eq!(m.quantile(0.0), 1.0);
        assert_eq!(m.quantile(1.0), 100.0);
        m.reset();
        assert_eq!(m.count(), 0);
        assert_eq!(m.p50(), 0.0);
    }

    #[test]
    fn percentile_meter_reservoir_stays_bounded() {
        let mut m = PercentileMeter::with_capacity(64);
        for i in 0..10_000 {
            m.add(i as f64);
        }
        assert_eq!(m.count(), 10_000);
        assert!(m.reservoir.len() <= 64);
        // estimates stay inside the observed range and keep order
        let (p50, p95, p99) = (m.p50(), m.p95(), m.p99());
        assert!((0.0..10_000.0).contains(&p50));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // uniform stream: the median estimate lands near the middle
        assert!((2_000.0..8_000.0).contains(&p50), "p50={p50}");
    }

    #[test]
    fn percentile_meter_is_deterministic() {
        let run = || {
            let mut m = PercentileMeter::with_capacity(32);
            for i in 0..5_000 {
                m.add((i * 7 % 1000) as f64);
            }
            (m.p50(), m.p95(), m.p99())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn percentile_meter_reset_restores_the_seed() {
        // reset() must restart the reservoir RNG, not just clear the
        // samples: the same stream replayed after a reset has to report
        // bit-identical quantiles, or latency dashboards drift per window
        let stream = |m: &mut PercentileMeter| {
            for i in 0..5_000 {
                m.add((i * 13 % 997) as f64);
            }
            (m.p50(), m.p95(), m.p99())
        };
        let mut m = PercentileMeter::with_capacity(32);
        let first = stream(&mut m);
        m.reset();
        assert_eq!(m.count(), 0, "reset empties the reservoir");
        let replayed = stream(&mut m);
        assert_eq!(first, replayed, "replayed stream after reset must match bit-for-bit");
    }

    #[test]
    fn peak_meter_sub_saturates() {
        let mut m = PeakValueMeter::new();
        m.add(10);
        m.sub(25); // over-release must clamp at zero, not wrap
        assert_eq!(m.current(), 0);
        assert_eq!(m.peak(), 10, "peak survives the over-release");
        m.add(3);
        assert_eq!(m.current(), 3, "the meter keeps working after saturating");
        assert_eq!(m.peak(), 10);
    }

    #[test]
    fn time_weighted_meter_zero_duration_stream() {
        // a stream of only zero-length segments closes no time: the mean
        // must stay at its empty-meter value, never divide by zero
        let mut m = TimeWeightedMeter::new();
        for level in [5.0, 2.0, 9.0] {
            m.observe(level, 0.0);
        }
        assert_eq!(m.seconds(), 0.0);
        assert_eq!(m.mean(), 0.0, "no closed time, no mean");
        assert_eq!(m.peak(), 9.0, "peak still tracks instantaneous levels");
    }

    #[test]
    fn frame_error_counts() {
        let mut m = FrameErrorMeter::new();
        m.add(
            &Tensor::from_slice(&[1i64, 2, 3, 4], [4]),
            &Tensor::from_slice(&[1i64, 0, 3, 0], [4]),
        );
        assert!((m.value() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn topk_meter() {
        let scores = Tensor::from_slice(&[0.1f32, 0.9, 0.0, 0.4, 0.5, 0.6], [2, 3]);
        let targets = Tensor::from_slice(&[1i64, 0], [2]);
        let mut top1 = TopKMeter::new(1);
        top1.add(&scores, &targets);
        assert!((top1.value() - 50.0).abs() < 1e-12);
        let mut top3 = TopKMeter::new(3);
        top3.add(&scores, &targets);
        assert!((top3.value() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn time_weighted_meter_weights_by_duration() {
        let mut m = TimeWeightedMeter::new();
        assert_eq!(m.mean(), 0.0);
        // level 4 for 1s, level 1 for 3s: mean = (4 + 3) / 4 = 1.75
        m.observe(4.0, 1.0);
        m.observe(1.0, 3.0);
        assert!((m.mean() - 1.75).abs() < 1e-12);
        assert_eq!(m.peak(), 4.0);
        assert!((m.seconds() - 4.0).abs() < 1e-12);
        // an instantaneous observation adds no weight
        m.observe(100.0, 0.0);
        assert!((m.mean() - 1.75).abs() < 1e-12);
        assert_eq!(m.peak(), 100.0);
        m.reset();
        assert_eq!(m.mean(), 0.0);
        assert_eq!(m.peak(), 0.0);
    }

    #[test]
    fn time_weighted_meter_set_tracks_wall_clock() {
        let mut m = TimeWeightedMeter::new();
        m.set(3.0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.set(0.0);
        assert!(m.seconds() > 0.0, "a closed segment must account time");
        assert!((m.mean() - 3.0).abs() < 1e-9, "only level-3 time is closed");
        assert_eq!(m.peak(), 3.0);
    }

    #[test]
    fn edit_distance() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein::<u8>(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"same", b"same"), 0);
        let mut m = EditDistanceMeter::new();
        m.add(&["the", "cat"], &["the", "cat", "sat"]);
        assert!((m.value() - 100.0 / 3.0).abs() < 1e-9);
    }
}
