//! Raw aligned memory blocks handed out by memory managers.

use std::alloc::{alloc, dealloc, Layout};

/// Alignment of every block (cache-line / SIMD friendly).
pub const BLOCK_ALIGN: usize = 64;

/// A contiguous region a manager handed to a user.
///
/// Blocks may be sub-ranges of a larger native *segment* owned by the
/// manager (`segment != NATIVE`), or standalone native allocations that the
/// receiver of the block is responsible for returning (never freeing
/// directly — always via [`super::MemoryManagerAdapter::unlock`]).
pub struct Block {
    ptr: *mut u8,
    /// Usable size in bytes (possibly rounded up from the request).
    pub size: usize,
    /// Manager-private segment id (`usize::MAX` = standalone native block).
    pub segment: usize,
    /// Offset within the segment.
    pub offset: usize,
}

// Safety: a Block is an exclusive handle to its region.
unsafe impl Send for Block {}
unsafe impl Sync for Block {}

impl Block {
    /// Standalone-native sentinel for `segment`.
    pub const NATIVE: usize = usize::MAX;

    /// Construct a block view (manager-internal use).
    pub fn new(ptr: *mut u8, size: usize, segment: usize, offset: usize) -> Self {
        Block { ptr, size, segment, offset }
    }

    /// Base pointer.
    pub fn ptr(&self) -> *mut u8 {
        self.ptr
    }
}

impl std::fmt::Debug for Block {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Block(size={}, segment={}, offset={})", self.size, self.segment, self.offset)
    }
}

/// An owned native allocation (a manager-held segment or a standalone
/// passthrough block). Freed on drop.
pub struct NativeAlloc {
    ptr: *mut u8,
    layout: Layout,
}

unsafe impl Send for NativeAlloc {}
unsafe impl Sync for NativeAlloc {}

impl NativeAlloc {
    /// Allocate `size` bytes, 64-byte aligned. Zero-size requests get a
    /// minimal 64-byte allocation so pointers stay valid and unique.
    pub fn new(size: usize) -> Self {
        let size = size.max(BLOCK_ALIGN);
        let layout = Layout::from_size_align(size, BLOCK_ALIGN).expect("bad layout");
        let ptr = unsafe { alloc(layout) };
        assert!(!ptr.is_null(), "native allocation of {size} bytes failed");
        NativeAlloc { ptr, layout }
    }

    /// Base pointer.
    pub fn ptr(&self) -> *mut u8 {
        self.ptr
    }

    /// Allocated size.
    pub fn size(&self) -> usize {
        self.layout.size()
    }
}

impl Drop for NativeAlloc {
    fn drop(&mut self) {
        unsafe { dealloc(self.ptr, self.layout) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_alloc_alignment() {
        for size in [1usize, 63, 64, 65, 4096, 1 << 20] {
            let a = NativeAlloc::new(size);
            assert_eq!(a.ptr() as usize % BLOCK_ALIGN, 0);
            assert!(a.size() >= size);
            // write across the whole region
            unsafe { std::ptr::write_bytes(a.ptr(), 0xAB, a.size()) };
        }
    }

    #[test]
    fn block_debug() {
        let a = NativeAlloc::new(128);
        let b = Block::new(a.ptr(), 128, Block::NATIVE, 0);
        assert!(format!("{b:?}").contains("size=128"));
    }
}
