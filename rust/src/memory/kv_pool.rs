//! Paged KV-cache pool: fixed-size pages leased from a shared, capped
//! reservoir, built on the block machinery every other buffer uses.
//!
//! Continuous (iteration-level) batching admits and retires generation
//! requests every token, so per-request KV memory must come and go just
//! as fast. Instead of one contiguous `[B*H, len, hd]` tensor per request
//! that grows by concat-append, each request's cache owns a set of
//! fixed-size **pages** leased from a process-wide [`KvPagePool`]; a page
//! table (in [`crate::nn::PagedKvCache`]) maps logical KV positions to
//! pool pages. Retirement drops the lease handles, which return their
//! backing [`TypedBuf`] blocks to the originating memory manager — the
//! pool is a *policy* layer (capacity + accounting) over the existing
//! `memory/caching.rs` allocator, not a second allocator.
//!
//! Exhaustion is a first-class, typed outcome ([`PoolExhausted`]), not a
//! panic: the serving scheduler treats it as backpressure and holds the
//! queue head until a retirement frees pages.

use std::sync::{Arc, Mutex};

use crate::util::error::Error;

use super::{manager, MemoryManagerAdapter, MemStats, TypedBuf};

/// Geometry of one pool: every page stores `page_tokens` KV positions for
/// *all* layers and heads of one request, so a request's page count is
/// just `ceil(positions / page_tokens)` regardless of model depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Transformer layers the cache covers.
    pub layers: usize,
    /// Attention heads per layer.
    pub heads: usize,
    /// Per-head feature width.
    pub head_dim: usize,
    /// KV positions stored per page.
    pub page_tokens: usize,
    /// Hard cap on simultaneously leased pages (the backpressure knob).
    pub max_pages: usize,
}

impl KvPoolConfig {
    /// f32 elements in one page: `[layers][k|v][heads][page_tokens][head_dim]`.
    pub fn floats_per_page(&self) -> usize {
        self.layers * 2 * self.heads * self.page_tokens * self.head_dim
    }

    /// Bytes in one page.
    pub fn page_bytes(&self) -> usize {
        self.floats_per_page() * std::mem::size_of::<f32>()
    }

    /// Pages needed to hold `positions` KV positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_tokens)
    }

    /// Most KV positions one request could hold if it leased every page.
    pub fn max_positions(&self) -> usize {
        self.max_pages * self.page_tokens
    }

    /// Physical offset (in f32 elements, within one page) of the
    /// `head_dim`-long run holding position-slot `slot` of head `head`,
    /// key (`kv == 0`) or value (`kv == 1`), layer `layer`. This is the
    /// page table's address math; `kv_pool` unit tests pin it against a
    /// naive enumeration and `nn/attention.rs` pins the end-to-end
    /// gather against the contiguous concat-append reference.
    pub fn run_offset(&self, layer: usize, kv: usize, head: usize, slot: usize) -> usize {
        debug_assert!(layer < self.layers && kv < 2 && head < self.heads);
        debug_assert!(slot < self.page_tokens);
        (((layer * 2 + kv) * self.heads + head) * self.page_tokens + slot) * self.head_dim
    }
}

/// Typed backpressure error: the pool cannot lease `wanted` more pages
/// right now. Callers decide whether to wait for retirements (`wanted <=
/// capacity`) or reject the request outright (`wanted > capacity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// Pages the lease asked for.
    pub wanted: usize,
    /// Pages currently free.
    pub free: usize,
    /// Total pool capacity in pages.
    pub capacity: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv page pool exhausted: wanted {} pages, {} free of {} total",
            self.wanted, self.free, self.capacity
        )
    }
}

impl std::error::Error for PoolExhausted {}

impl From<PoolExhausted> for Error {
    fn from(e: PoolExhausted) -> Self {
        Error::Memory(e.to_string())
    }
}

/// A point-in-time snapshot of the pool's accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Pages currently leased out.
    pub leased_pages: usize,
    /// Pages currently available.
    pub free_pages: usize,
    /// Total capacity in pages.
    pub capacity_pages: usize,
    /// High-water mark of `leased_pages`.
    pub peak_leased_pages: usize,
    /// Pages handed out over the pool's lifetime.
    pub total_leases: u64,
    /// Pages returned over the pool's lifetime.
    pub total_releases: u64,
    /// Lease calls rejected with [`PoolExhausted`].
    pub exhausted_count: u64,
}

#[derive(Default)]
struct PoolState {
    leased: usize,
    peak_leased: usize,
    total_leases: u64,
    total_releases: u64,
    exhausted: u64,
}

/// The shared page reservoir. Cheap to clone via `Arc`; every leased
/// [`KvPage`] holds one back-reference for release accounting.
pub struct KvPagePool {
    cfg: KvPoolConfig,
    mgr: Arc<dyn MemoryManagerAdapter>,
    state: Mutex<PoolState>,
}

impl KvPagePool {
    /// A pool allocating pages through the globally installed memory
    /// manager (see [`crate::memory::manager`]).
    pub fn new(cfg: KvPoolConfig) -> Arc<Self> {
        Self::with_manager(cfg, manager())
    }

    /// A pool allocating pages through a specific manager (tests pin this
    /// to a telemetry-wrapped caching manager to audit for leaks).
    pub fn with_manager(cfg: KvPoolConfig, mgr: Arc<dyn MemoryManagerAdapter>) -> Arc<Self> {
        assert!(cfg.layers > 0 && cfg.heads > 0 && cfg.head_dim > 0, "degenerate pool geometry");
        assert!(cfg.page_tokens > 0, "pages must hold at least one position");
        assert!(cfg.max_pages > 0, "a zero-capacity pool can serve nothing");
        Arc::new(KvPagePool { cfg, mgr, state: Mutex::new(PoolState::default()) })
    }

    /// The pool's geometry.
    pub fn config(&self) -> &KvPoolConfig {
        &self.cfg
    }

    /// Lease `pages` pages atomically: either all are granted or none
    /// are, so a multi-page reservation can never deadlock half-held.
    /// Pages come back zero-filled (recycled blocks never leak stale KV
    /// bits across requests).
    pub fn lease(self: &Arc<Self>, pages: usize) -> Result<Vec<KvPage>, PoolExhausted> {
        if pages == 0 {
            return Ok(Vec::new());
        }
        {
            let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
            let free = self.cfg.max_pages - st.leased;
            if pages > free {
                st.exhausted += 1;
                return Err(PoolExhausted { wanted: pages, free, capacity: self.cfg.max_pages });
            }
            st.leased += pages;
            st.peak_leased = st.peak_leased.max(st.leased);
            st.total_leases += pages as u64;
        }
        // allocate outside the lock: the counters already reserve the
        // capacity, and allocation may be slow under a caching miss
        let n = self.cfg.floats_per_page();
        Ok((0..pages)
            .map(|_| KvPage {
                buf: TypedBuf::zeroed_in(n, self.mgr.clone()),
                pool: Arc::clone(self),
            })
            .collect())
    }

    fn release_one(&self) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        debug_assert!(st.leased > 0, "release without a matching lease");
        st.leased = st.leased.saturating_sub(1);
        st.total_releases += 1;
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> KvPoolStats {
        let st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        KvPoolStats {
            leased_pages: st.leased,
            free_pages: self.cfg.max_pages - st.leased,
            capacity_pages: self.cfg.max_pages,
            peak_leased_pages: st.peak_leased,
            total_leases: st.total_leases,
            total_releases: st.total_releases,
            exhausted_count: st.exhausted,
        }
    }

    /// The underlying memory manager's statistics (pages show up here as
    /// ordinary allocations — the no-leak tests assert both ledgers).
    pub fn manager_stats(&self) -> MemStats {
        self.mgr.stats()
    }
}

impl std::fmt::Debug for KvPagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "KvPagePool({} leased / {} pages of {} tokens, mgr={})",
            s.leased_pages,
            s.capacity_pages,
            self.cfg.page_tokens,
            self.mgr.name()
        )
    }
}

/// One leased page. Dropping it returns the backing block to the memory
/// manager *and* the capacity to the pool (RAII — retirement cannot leak).
pub struct KvPage {
    buf: TypedBuf<f32>,
    pool: Arc<KvPagePool>,
}

impl KvPage {
    /// The page's f32 storage.
    pub fn data(&self) -> &[f32] {
        self.buf.as_slice()
    }

    /// Mutable f32 storage.
    pub fn data_mut(&mut self) -> &mut [f32] {
        self.buf.as_mut_slice()
    }
}

impl Drop for KvPage {
    fn drop(&mut self) {
        self.pool.release_one();
    }
}

impl std::fmt::Debug for KvPage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KvPage({} floats)", self.buf.len())
    }
}

#[cfg(test)]
mod tests {
    use super::super::caching::CachingMemoryManager;
    use super::super::telemetry::TelemetryMemoryManager;
    use super::*;
    use crate::util::rng::Rng;

    fn small_cfg(max_pages: usize) -> KvPoolConfig {
        KvPoolConfig { layers: 2, heads: 2, head_dim: 4, page_tokens: 3, max_pages }
    }

    #[test]
    fn geometry_math() {
        let cfg = small_cfg(8);
        assert_eq!(cfg.floats_per_page(), 2 * 2 * 2 * 3 * 4);
        assert_eq!(cfg.page_bytes(), cfg.floats_per_page() * 4);
        assert_eq!(cfg.pages_for(0), 0);
        assert_eq!(cfg.pages_for(1), 1);
        assert_eq!(cfg.pages_for(3), 1);
        assert_eq!(cfg.pages_for(4), 2);
        assert_eq!(cfg.max_positions(), 24);
    }

    #[test]
    fn run_offsets_tile_the_page_exactly() {
        // the address math must be a bijection from (layer, kv, head,
        // slot) onto disjoint head_dim-long runs covering the whole page —
        // checked against a naive enumeration in storage order
        let cfg = small_cfg(1);
        let mut expected = 0usize;
        for layer in 0..cfg.layers {
            for kv in 0..2 {
                for head in 0..cfg.heads {
                    for slot in 0..cfg.page_tokens {
                        assert_eq!(cfg.run_offset(layer, kv, head, slot), expected);
                        expected += cfg.head_dim;
                    }
                }
            }
        }
        assert_eq!(expected, cfg.floats_per_page());
    }

    #[test]
    fn lease_release_churn_never_leaks() {
        // audit both ledgers under random churn: the pool's page counters
        // and the real allocator bytes seen through the telemetry wrapper
        let mgr = Arc::new(TelemetryMemoryManager::new(Arc::new(
            CachingMemoryManager::unrestricted(),
        )));
        let pool = KvPagePool::with_manager(small_cfg(16), mgr.clone());
        // the caching allocator rounds block sizes to its quantum, so
        // measure one page's real footprint instead of assuming page_bytes
        let bytes_per_page = {
            let probe = pool.lease(1).unwrap();
            let b = mgr.stats().allocated_bytes;
            assert!(b >= pool.config().page_bytes());
            drop(probe);
            b
        };
        assert_eq!(mgr.stats().allocated_bytes, 0);
        let mut rng = Rng::new(0x9A6E);
        let mut held: Vec<KvPage> = Vec::new();
        for _ in 0..200 {
            if !held.is_empty() && rng.uniform() < 0.5 {
                let i = rng.below(held.len());
                held.swap_remove(i);
            } else {
                let want = 1 + rng.below(4);
                match pool.lease(want) {
                    Ok(pages) => held.extend(pages),
                    Err(e) => assert!(e.wanted > e.free, "spurious exhaustion: {e}"),
                }
            }
            let s = pool.stats();
            assert_eq!(s.leased_pages, held.len());
            assert_eq!(s.leased_pages + s.free_pages, s.capacity_pages);
            assert_eq!(
                mgr.stats().allocated_bytes,
                held.len() * bytes_per_page,
                "allocator bytes disagree with the page ledger"
            );
        }
        held.clear();
        let s = pool.stats();
        assert_eq!(s.leased_pages, 0, "pages leaked after the churn");
        assert_eq!(s.total_leases, s.total_releases);
        assert_eq!(mgr.stats().allocated_bytes, 0, "allocator bytes leaked after the churn");
        assert!(s.peak_leased_pages <= s.capacity_pages);
    }

    #[test]
    fn exhaustion_is_a_typed_error_not_a_panic() {
        let pool = KvPagePool::new(small_cfg(4));
        let held = pool.lease(3).unwrap();
        let err = pool.lease(2).unwrap_err();
        assert_eq!(err, PoolExhausted { wanted: 2, free: 1, capacity: 4 });
        assert!(err.to_string().contains("exhausted"));
        // the failed lease must not consume capacity
        assert_eq!(pool.stats().leased_pages, 3);
        assert_eq!(pool.stats().exhausted_count, 1);
        drop(held);
        // freed capacity serves the retry
        let again = pool.lease(4).unwrap();
        assert_eq!(again.len(), 4);
        // conversion into the library error keeps the context
        let lib: Error = PoolExhausted { wanted: 9, free: 0, capacity: 4 }.into();
        assert!(matches!(lib, Error::Memory(ref m) if m.contains("wanted 9")));
    }

    #[test]
    fn leases_are_all_or_nothing_and_zeroed() {
        let pool = KvPagePool::new(small_cfg(2));
        assert!(pool.lease(3).is_err(), "over-capacity lease must fail atomically");
        assert_eq!(pool.stats().leased_pages, 0);
        let mut pages = pool.lease(2).unwrap();
        assert!(pages.iter().all(|p| p.data().iter().all(|&x| x == 0.0)));
        // dirty a page, return it, lease again: still zeroed
        pages[0].data_mut()[0] = 7.0;
        drop(pages);
        let pages = pool.lease(1).unwrap();
        assert!(pages[0].data().iter().all(|&x| x == 0.0), "recycled page leaked stale bits");
    }
}
