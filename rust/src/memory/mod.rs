//! Open memory-management interface (paper §4.1.2, Listing 3).
//!
//! Every buffer the reference tensor backend materializes is allocated
//! through the globally *installed* [`MemoryManagerAdapter`]. Managers are
//! swappable at runtime — the paper's fragmentation case study (§5.2.2) is
//! reproduced by swapping [`caching::CachingMemoryManager`] configurations
//! (unrestricted vs. split-restricted) under an identical allocation trace.
//!
//! Buffers are handed out as raw [`block::Block`]s and typed via
//! [`TypedBuf`], which returns its block to the *originating* manager on
//! drop (managers may be swapped mid-run without leaking).

pub mod block;
pub mod caching;
pub mod default;
pub mod kv_pool;
pub mod telemetry;

use std::sync::{Arc, RwLock};

pub use block::Block;
pub use caching::{CachingConfig, CachingMemoryManager};
pub use default::DefaultMemoryManager;
pub use kv_pool::{KvPage, KvPagePool, KvPoolConfig, KvPoolStats, PoolExhausted};
pub use telemetry::{AllocEvent, EventKind, TelemetryMemoryManager};

use crate::util::error::Result;

/// Live statistics reported by a memory manager.
///
/// `fragmentation()` follows the PyTorch/paper convention: the fraction of
/// reserved (native) bytes not currently backing a live user allocation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemStats {
    /// Bytes currently locked by users (live allocations, rounded sizes).
    pub allocated_bytes: usize,
    /// Bytes currently reserved from the system allocator (live + cached).
    pub reserved_bytes: usize,
    /// High-water mark of `allocated_bytes`.
    pub peak_allocated_bytes: usize,
    /// High-water mark of `reserved_bytes`.
    pub peak_reserved_bytes: usize,
    /// Total user `alloc` calls served.
    pub alloc_count: u64,
    /// Allocations that had to hit the system allocator.
    pub native_alloc_count: u64,
    /// Allocations served from a cache / free list.
    pub cache_hit_count: u64,
    /// Number of block splits performed.
    pub split_count: u64,
    /// Number of adjacent-block coalesces performed on free.
    pub coalesce_count: u64,
}

impl MemStats {
    /// Fraction of reserved memory that is *not* backing a live allocation
    /// (external + internal fragmentation of the pool). 0.0 when nothing
    /// is reserved.
    pub fn fragmentation(&self) -> f64 {
        if self.reserved_bytes == 0 {
            0.0
        } else {
            1.0 - self.allocated_bytes as f64 / self.reserved_bytes as f64
        }
    }

    /// Peak-based fragmentation (peak reserved vs peak allocated).
    pub fn peak_fragmentation(&self) -> f64 {
        if self.peak_reserved_bytes == 0 {
            0.0
        } else {
            1.0 - self.peak_allocated_bytes as f64 / self.peak_reserved_bytes as f64
        }
    }
}

/// The open memory-manager interface (paper Listing 3).
///
/// Implementations must be thread-safe; the reference tensor backend calls
/// `alloc`/`unlock` from parallel kernels and data-loader threads.
pub trait MemoryManagerAdapter: Send + Sync {
    /// Human-readable manager name (shown in telemetry and benches).
    fn name(&self) -> &str;
    /// Allocate at least `bytes` bytes (64-byte aligned).
    fn alloc(&self, bytes: usize) -> Result<Block>;
    /// Return a block previously obtained from `alloc` ("unlock" in the
    /// paper's API; the manager may cache or release it).
    fn unlock(&self, block: Block);
    /// Current statistics snapshot.
    fn stats(&self) -> MemStats;
    /// Drop all cached (non-live) memory back to the system.
    fn clear_cache(&self);
}

static INSTALLED: RwLock<Option<Arc<dyn MemoryManagerAdapter>>> = RwLock::new(None);

/// The currently installed manager (a lock-free passthrough
/// [`DefaultMemoryManager`] until one is installed).
pub fn manager() -> Arc<dyn MemoryManagerAdapter> {
    if let Some(m) = INSTALLED.read().unwrap().as_ref() {
        return m.clone();
    }
    // install the default lazily
    let mut w = INSTALLED.write().unwrap();
    if let Some(m) = w.as_ref() {
        return m.clone();
    }
    let m: Arc<dyn MemoryManagerAdapter> = Arc::new(DefaultMemoryManager::new());
    *w = Some(m.clone());
    m
}

/// Install a new global memory manager (the `MemoryManagerInstaller` of the
/// paper). Returns the previously installed manager, if any. Live buffers
/// keep a handle to their originating manager, so swapping is safe.
pub fn install(m: Arc<dyn MemoryManagerAdapter>) -> Option<Arc<dyn MemoryManagerAdapter>> {
    INSTALLED.write().unwrap().replace(m)
}

/// A typed, manager-owned buffer. The backbone of CPU tensor storage.
pub struct TypedBuf<T> {
    block: Option<Block>,
    mgr: Arc<dyn MemoryManagerAdapter>,
    len: usize,
    _marker: std::marker::PhantomData<T>,
}

// Safety: TypedBuf uniquely owns its block's memory region; T is plain data.
unsafe impl<T: Send> Send for TypedBuf<T> {}
unsafe impl<T: Sync> Sync for TypedBuf<T> {}

impl<T: Copy + Default> TypedBuf<T> {
    /// Allocate a zero-initialized buffer of `len` elements through the
    /// installed manager.
    pub fn zeroed(len: usize) -> Self {
        let mgr = manager();
        Self::zeroed_in(len, mgr)
    }

    /// Allocate through a specific manager.
    pub fn zeroed_in(len: usize, mgr: Arc<dyn MemoryManagerAdapter>) -> Self {
        let bytes = len * std::mem::size_of::<T>();
        let block = mgr.alloc(bytes).expect("memory manager allocation failed");
        // zero-fill: managers may hand back recycled blocks
        unsafe { std::ptr::write_bytes(block.ptr(), 0, bytes) };
        TypedBuf { block: Some(block), mgr, len, _marker: std::marker::PhantomData }
    }

    /// Build from a slice (copies).
    pub fn from_slice(xs: &[T]) -> Self {
        let mut b = Self::zeroed(xs.len());
        b.as_mut_slice().copy_from_slice(xs);
        b
    }

    /// Build by evaluating `f(i)` for each index.
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> T) -> Self {
        let mut b = Self::zeroed(len);
        for (i, slot) in b.as_mut_slice().iter_mut().enumerate() {
            *slot = f(i);
        }
        b
    }
}

impl<T> TypedBuf<T> {
    /// Number of elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Immutable element view.
    pub fn as_slice(&self) -> &[T] {
        let ptr = self.block.as_ref().unwrap().ptr() as *const T;
        unsafe { std::slice::from_raw_parts(ptr, self.len) }
    }

    /// Mutable element view.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        let ptr = self.block.as_ref().unwrap().ptr() as *mut T;
        unsafe { std::slice::from_raw_parts_mut(ptr, self.len) }
    }
}

impl<T> Drop for TypedBuf<T> {
    fn drop(&mut self) {
        if let Some(b) = self.block.take() {
            self.mgr.unlock(b);
        }
    }
}

impl<T: Copy + Default> Clone for TypedBuf<T> {
    fn clone(&self) -> Self {
        let mut out = Self::zeroed_in(self.len, self.mgr.clone());
        out.as_mut_slice().copy_from_slice(self.as_slice());
        out
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for TypedBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TypedBuf(len={}, mgr={})", self.len, self.mgr.name())
    }
}

impl<T> std::ops::Deref for TypedBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T> std::ops::DerefMut for TypedBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typedbuf_roundtrip() {
        let b = TypedBuf::from_slice(&[1.0f32, 2.0, 3.0]);
        assert_eq!(b.as_slice(), &[1.0, 2.0, 3.0]);
        let c = b.clone();
        drop(b);
        assert_eq!(c.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn typedbuf_zeroed_and_from_fn() {
        let z = TypedBuf::<f64>::zeroed(17);
        assert!(z.as_slice().iter().all(|&x| x == 0.0));
        let f = TypedBuf::from_fn(5, |i| i as i64 * 2);
        assert_eq!(f.as_slice(), &[0, 2, 4, 6, 8]);
    }

    #[test]
    fn install_swaps_manager_safely() {
        let before = manager();
        let held = TypedBuf::from_slice(&[9u8; 100]); // allocated on `before`
        let caching = Arc::new(CachingMemoryManager::unrestricted());
        install(caching.clone());
        let after = TypedBuf::from_slice(&[1u8; 100]);
        assert_eq!(held.as_slice()[0], 9);
        assert_eq!(after.as_slice()[0], 1);
        drop(held); // returns to `before`, not `caching`
        drop(after);
        install(before);
        assert!(caching.stats().allocated_bytes == 0);
    }

    #[test]
    fn fragmentation_math() {
        let s = MemStats { allocated_bytes: 60, reserved_bytes: 100, ..Default::default() };
        assert!((s.fragmentation() - 0.4).abs() < 1e-12);
        assert_eq!(MemStats::default().fragmentation(), 0.0);
    }
}
