//! Passthrough memory manager: every `alloc` hits the system allocator.
//!
//! This is the installed default — on CPU, malloc is already a caching
//! allocator, and a lock-free passthrough keeps parallel kernels from
//! contending on a pool mutex. It still maintains full [`MemStats`] so
//! telemetry and benches can compare it against the caching managers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::block::{Block, NativeAlloc};
use super::{MemStats, MemoryManagerAdapter};
use crate::util::error::Result;

/// See module docs.
pub struct DefaultMemoryManager {
    live: Mutex<HashMap<usize, NativeAlloc>>, // ptr -> owner
    allocated: AtomicUsize,
    peak_allocated: AtomicUsize,
    allocs: AtomicU64,
}

impl DefaultMemoryManager {
    /// Create a fresh passthrough manager.
    pub fn new() -> Self {
        DefaultMemoryManager {
            live: Mutex::new(HashMap::new()),
            allocated: AtomicUsize::new(0),
            peak_allocated: AtomicUsize::new(0),
            allocs: AtomicU64::new(0),
        }
    }
}

impl Default for DefaultMemoryManager {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryManagerAdapter for DefaultMemoryManager {
    fn name(&self) -> &str {
        "default"
    }

    fn alloc(&self, bytes: usize) -> Result<Block> {
        let native = NativeAlloc::new(bytes);
        let size = native.size();
        let block = Block::new(native.ptr(), size, Block::NATIVE, 0);
        self.live.lock().unwrap().insert(native.ptr() as usize, native);
        let now = self.allocated.fetch_add(size, Ordering::Relaxed) + size;
        self.peak_allocated.fetch_max(now, Ordering::Relaxed);
        self.allocs.fetch_add(1, Ordering::Relaxed);
        Ok(block)
    }

    fn unlock(&self, block: Block) {
        let owner = self.live.lock().unwrap().remove(&(block.ptr() as usize));
        if let Some(native) = owner {
            self.allocated.fetch_sub(native.size(), Ordering::Relaxed);
        }
        // native drops here, freeing the memory
    }

    fn stats(&self) -> MemStats {
        let allocated = self.allocated.load(Ordering::Relaxed);
        let peak = self.peak_allocated.load(Ordering::Relaxed);
        MemStats {
            allocated_bytes: allocated,
            reserved_bytes: allocated, // passthrough never caches
            peak_allocated_bytes: peak,
            peak_reserved_bytes: peak,
            alloc_count: self.allocs.load(Ordering::Relaxed),
            native_alloc_count: self.allocs.load(Ordering::Relaxed),
            ..Default::default()
        }
    }

    fn clear_cache(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_unlock_balance() {
        let m = DefaultMemoryManager::new();
        let b1 = m.alloc(1000).unwrap();
        let b2 = m.alloc(2000).unwrap();
        assert!(m.stats().allocated_bytes >= 3000);
        assert_eq!(m.stats().fragmentation(), 0.0);
        m.unlock(b1);
        m.unlock(b2);
        assert_eq!(m.stats().allocated_bytes, 0);
        assert_eq!(m.stats().alloc_count, 2);
    }

    #[test]
    fn peak_tracking() {
        let m = DefaultMemoryManager::new();
        let b = m.alloc(1 << 20).unwrap();
        m.unlock(b);
        let _small = m.alloc(64).unwrap();
        assert!(m.stats().peak_allocated_bytes >= 1 << 20);
    }
}
