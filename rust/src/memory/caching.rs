//! Caching memory manager with block splitting — the substrate of the
//! paper's fragmentation case study (§5.2.2).
//!
//! Design follows the caching allocators used across deep-learning
//! frameworks: requests are rounded to 512-byte quanta; small requests are
//! carved out of pooled 2 MiB segments, large requests get dedicated
//! segments; freed blocks go to a size-indexed free list and are coalesced
//! with free neighbors.
//!
//! The case-study knob is [`CachingConfig::max_split_size`]: the paper's
//! researchers found that **restricting splitting of large cache blocks**
//! reduced fragmentation by over 20% on most models. With splitting
//! unrestricted, a large free block can be chipped into many odd-sized
//! residues that never fit later requests (external fragmentation); with a
//! threshold, oversized blocks are only handed out whole, keeping the pool
//! reusable. `benches/case_memory.rs` replays identical traces through both
//! configurations and reports the fragmentation delta.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::block::{Block, NativeAlloc};
use super::{MemStats, MemoryManagerAdapter};
use crate::util::error::Result;

/// Allocation-size quantum (all requests round up to a multiple of this).
pub const QUANTUM: usize = 512;
/// Requests at or below this size share pooled segments.
pub const SMALL_LIMIT: usize = 1 << 20; // 1 MiB
/// Size of pooled segments for small requests.
pub const SMALL_SEGMENT: usize = 2 << 20; // 2 MiB
/// Minimum leftover for a split to happen (smaller residues stay attached
/// as internal fragmentation).
pub const MIN_SPLIT_REMAINDER: usize = QUANTUM;

/// Tuning knobs for [`CachingMemoryManager`].
#[derive(Debug, Clone, Copy)]
pub struct CachingConfig {
    /// Free blocks larger than this are never split: they are handed out
    /// whole (if the request is large) or bypassed. `usize::MAX` disables
    /// the restriction (classic caching-allocator behavior).
    pub max_split_size: usize,
    /// Round requests up to the next power-of-two multiple of `QUANTUM`
    /// when below `SMALL_LIMIT` (bucketing); otherwise round to `QUANTUM`.
    pub pow2_buckets: bool,
}

impl Default for CachingConfig {
    fn default() -> Self {
        CachingConfig { max_split_size: usize::MAX, pow2_buckets: false }
    }
}

struct Segment {
    native: NativeAlloc,
    /// offset -> (size, free?) for every block carved from this segment.
    blocks: BTreeMap<usize, (usize, bool)>,
}

#[derive(Default)]
struct Pool {
    segments: Vec<Option<Segment>>,
    /// (size, segment, offset) ordered index over free blocks.
    free_index: std::collections::BTreeSet<(usize, usize, usize)>,
    stats: MemStats,
}

/// See module docs.
pub struct CachingMemoryManager {
    cfg: CachingConfig,
    pool: Mutex<Pool>,
    name: String,
}

impl CachingMemoryManager {
    /// Build with an explicit config.
    pub fn new(cfg: CachingConfig) -> Self {
        let name = if cfg.max_split_size == usize::MAX {
            "caching".to_string()
        } else {
            format!("caching(max_split={})", cfg.max_split_size)
        };
        CachingMemoryManager { cfg, pool: Mutex::new(Pool::default()), name }
    }

    /// Classic caching allocator: unlimited splitting.
    pub fn unrestricted() -> Self {
        Self::new(CachingConfig::default())
    }

    /// The case-study variant: blocks above `max_split_size` bytes are
    /// never split.
    pub fn split_restricted(max_split_size: usize) -> Self {
        Self::new(CachingConfig { max_split_size, ..Default::default() })
    }

    fn round(&self, bytes: usize) -> usize {
        let bytes = bytes.max(1);
        if self.cfg.pow2_buckets && bytes <= SMALL_LIMIT {
            let quanta = bytes.div_ceil(QUANTUM);
            (quanta.next_power_of_two()) * QUANTUM
        } else {
            bytes.div_ceil(QUANTUM) * QUANTUM
        }
    }
}

impl Pool {
    fn bump_peaks(&mut self) {
        self.stats.peak_allocated_bytes =
            self.stats.peak_allocated_bytes.max(self.stats.allocated_bytes);
        self.stats.peak_reserved_bytes =
            self.stats.peak_reserved_bytes.max(self.stats.reserved_bytes);
    }

    /// Take the best-fit free block of size >= `want`, if any.
    fn take_free(&mut self, want: usize) -> Option<(usize, usize, usize)> {
        let key = self
            .free_index
            .range((want, 0, 0)..)
            .next()
            .copied()?;
        self.free_index.remove(&key);
        Some(key)
    }

    fn new_segment(&mut self, size: usize) -> usize {
        let native = NativeAlloc::new(size);
        self.stats.reserved_bytes += native.size();
        self.stats.native_alloc_count += 1;
        let seg = Segment { native, blocks: BTreeMap::new() };
        // reuse a vacated slot if available
        if let Some(idx) = self.segments.iter().position(|s| s.is_none()) {
            self.segments[idx] = Some(seg);
            idx
        } else {
            self.segments.push(Some(seg));
            self.segments.len() - 1
        }
    }
}

impl MemoryManagerAdapter for CachingMemoryManager {
    fn name(&self) -> &str {
        &self.name
    }

    fn alloc(&self, bytes: usize) -> Result<Block> {
        let want = self.round(bytes);
        let mut pool = self.pool.lock().unwrap();
        pool.stats.alloc_count += 1;

        // 1) try the free list
        if let Some((size, seg_id, offset)) = pool.take_free(want) {
            // the split restriction governs the *large* pool only; blocks
            // within the pooled small-segment size always split (PyTorch's
            // max_split_size semantics)
            let splittable = size <= self.cfg.max_split_size || size <= SMALL_SEGMENT;
            let remainder = size - want;
            let (give, split) = if splittable && remainder >= MIN_SPLIT_REMAINDER {
                (want, true)
            } else if !splittable && remainder >= MIN_SPLIT_REMAINDER && size > want * 4 {
                // Restricted mode: a grossly oversized unsplittable block is
                // a bad fit — put it back and fall through to a fresh
                // segment instead of wasting it.
                pool.free_index.insert((size, seg_id, offset));
                return self.alloc_fresh(&mut pool, want);
            } else {
                (size, false)
            };
            pool.stats.cache_hit_count += 1;
            let seg = pool.segments[seg_id].as_mut().unwrap();
            if split {
                seg.blocks.insert(offset, (give, false));
                seg.blocks.insert(offset + give, (size - give, true));
                let base = seg.native.ptr();
                pool.free_index.insert((size - give, seg_id, offset + give));
                pool.stats.split_count += 1;
                pool.stats.allocated_bytes += give;
                pool.bump_peaks();
                return Ok(Block::new(unsafe { base.add(offset) }, give, seg_id, offset));
            }
            seg.blocks.insert(offset, (give, false));
            let base = seg.native.ptr();
            pool.stats.allocated_bytes += give;
            pool.bump_peaks();
            return Ok(Block::new(unsafe { base.add(offset) }, give, seg_id, offset));
        }

        self.alloc_fresh(&mut pool, want)
    }

    fn unlock(&self, block: Block) {
        let mut pool = self.pool.lock().unwrap();
        let seg_id = block.segment;
        let (mut offset, mut size) = (block.offset, block.size);
        pool.stats.allocated_bytes = pool.stats.allocated_bytes.saturating_sub(size);
        let seg = pool.segments[seg_id].as_mut().expect("unlock into vacated segment");
        seg.blocks.remove(&offset);

        // coalesce with the free block immediately after
        let mut coalesced = 0u64;
        if let Some((&next_off, &(next_size, next_free))) =
            seg.blocks.range(offset + size..).next()
        {
            if next_free && next_off == offset + size {
                seg.blocks.remove(&next_off);
                pool.free_index.remove(&(next_size, seg_id, next_off));
                size += next_size;
                coalesced += 1;
            }
        }
        // re-borrow (free_index removal above required pool access)
        let seg = pool.segments[seg_id].as_mut().unwrap();
        // coalesce with the free block immediately before
        if let Some((&prev_off, &(prev_size, prev_free))) = seg.blocks.range(..offset).next_back()
        {
            if prev_free && prev_off + prev_size == offset {
                seg.blocks.remove(&prev_off);
                pool.free_index.remove(&(prev_size, seg_id, prev_off));
                offset = prev_off;
                size += prev_size;
                coalesced += 1;
            }
        }
        let seg = pool.segments[seg_id].as_mut().unwrap();
        seg.blocks.insert(offset, (size, true));
        pool.free_index.insert((size, seg_id, offset));
        pool.stats.coalesce_count += coalesced;
    }

    fn stats(&self) -> MemStats {
        self.pool.lock().unwrap().stats
    }

    fn clear_cache(&self) {
        let mut pool = self.pool.lock().unwrap();
        let mut freed = Vec::new();
        for (seg_id, slot) in pool.segments.iter_mut().enumerate() {
            let fully_free = match slot {
                Some(seg) => seg.blocks.values().all(|&(_, free)| free),
                None => false,
            };
            if fully_free {
                let seg = slot.take().unwrap();
                freed.push((seg_id, seg));
            }
        }
        for (seg_id, seg) in freed {
            for (&off, &(sz, free)) in &seg.blocks {
                if free {
                    pool.free_index.remove(&(sz, seg_id, off));
                }
            }
            pool.stats.reserved_bytes -= seg.native.size();
            // seg drops -> native memory returned
        }
    }
}

impl CachingMemoryManager {
    fn alloc_fresh(&self, pool: &mut Pool, want: usize) -> Result<Block> {
        // 2) new segment: pooled for small requests, dedicated for large
        let seg_size = if want <= SMALL_LIMIT { SMALL_SEGMENT } else { want };
        let seg_id = pool.new_segment(seg_size);
        let seg = pool.segments[seg_id].as_mut().unwrap();
        let total = seg.native.size();
        let base = seg.native.ptr();
        let remainder = total - want;
        let splittable = total <= self.cfg.max_split_size || total == SMALL_SEGMENT;
        if splittable && remainder >= MIN_SPLIT_REMAINDER {
            seg.blocks.insert(0, (want, false));
            seg.blocks.insert(want, (remainder, true));
            pool.free_index.insert((remainder, seg_id, want));
            pool.stats.split_count += 1;
            pool.stats.allocated_bytes += want;
            pool.bump_peaks();
            Ok(Block::new(base, want, seg_id, 0))
        } else {
            seg.blocks.insert(0, (total, false));
            pool.stats.allocated_bytes += total;
            pool.bump_peaks();
            Ok(Block::new(base, total, seg_id, 0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_no_overlap(m: &CachingMemoryManager) {
        let pool = m.pool.lock().unwrap();
        for slot in pool.segments.iter().flatten() {
            let mut prev_end = 0usize;
            for (&off, &(size, _)) in &slot.blocks {
                assert!(off >= prev_end, "overlapping blocks");
                prev_end = off + size;
            }
            assert!(prev_end <= slot.native.size());
        }
    }

    #[test]
    fn reuse_after_free() {
        let m = CachingMemoryManager::unrestricted();
        let b = m.alloc(10_000).unwrap();
        let p = b.ptr() as usize;
        m.unlock(b);
        let b2 = m.alloc(10_000).unwrap();
        assert_eq!(b2.ptr() as usize, p, "expected cache hit to reuse block");
        assert_eq!(m.stats().cache_hit_count, 1);
        m.unlock(b2);
        check_no_overlap(&m);
    }

    #[test]
    fn splitting_and_coalescing() {
        let m = CachingMemoryManager::unrestricted();
        // Small allocs carve a shared 2MiB segment
        let a = m.alloc(1024).unwrap();
        let b = m.alloc(1024).unwrap();
        assert_eq!(m.stats().native_alloc_count, 1, "both should share one segment");
        assert_eq!(a.segment, b.segment);
        m.unlock(a);
        m.unlock(b);
        let s = m.stats();
        assert!(s.coalesce_count >= 2, "frees should coalesce, got {}", s.coalesce_count);
        // after coalescing the whole segment is one free block again
        let big = m.alloc(SMALL_SEGMENT / 2).unwrap();
        assert_eq!(m.stats().native_alloc_count, 1, "should reuse coalesced segment");
        m.unlock(big);
        check_no_overlap(&m);
    }

    #[test]
    fn split_restriction_blocks_large_splits() {
        let max_split = 4 << 20;
        let m = CachingMemoryManager::split_restricted(max_split);
        // allocate and free a large (unsplittable) block
        let b = m.alloc(8 << 20).unwrap();
        m.unlock(b);
        // a small-ish large request must NOT carve the 8MiB block
        let c = m.alloc(2 << 20).unwrap();
        assert_eq!(m.stats().split_count, 0, "restricted manager must not split large blocks");
        m.unlock(c);
        check_no_overlap(&m);

        // unrestricted manager happily splits the same sequence
        let u = CachingMemoryManager::unrestricted();
        let b = u.alloc(8 << 20).unwrap();
        u.unlock(b);
        let c = u.alloc(2 << 20).unwrap();
        assert!(u.stats().split_count >= 1);
        u.unlock(c);
    }

    #[test]
    fn clear_cache_releases_reserved() {
        let m = CachingMemoryManager::unrestricted();
        let b = m.alloc(3 << 20).unwrap();
        m.unlock(b);
        assert!(m.stats().reserved_bytes >= 3 << 20);
        m.clear_cache();
        assert_eq!(m.stats().reserved_bytes, 0);
        // allocating again works after a clear
        let b = m.alloc(1024).unwrap();
        m.unlock(b);
    }

    #[test]
    fn stats_allocated_matches_live() {
        let m = CachingMemoryManager::unrestricted();
        let blocks: Vec<_> = (0..10).map(|i| m.alloc(1000 * (i + 1)).unwrap()).collect();
        let live: usize = blocks.iter().map(|b| b.size).sum();
        assert_eq!(m.stats().allocated_bytes, live);
        for b in blocks {
            m.unlock(b);
        }
        assert_eq!(m.stats().allocated_bytes, 0);
        assert!(m.stats().fragmentation() >= 0.999); // all reserved, none live
    }

    #[test]
    fn pow2_bucketing_rounds_up() {
        let m = CachingMemoryManager::new(CachingConfig { pow2_buckets: true, ..Default::default() });
        let b = m.alloc(QUANTUM + 1).unwrap();
        assert_eq!(b.size, 2 * QUANTUM);
        m.unlock(b);
    }

    #[test]
    fn many_random_allocs_no_overlap() {
        use crate::util::rng::Rng;
        let m = CachingMemoryManager::unrestricted();
        let mut rng = Rng::new(123);
        let mut live: Vec<Block> = Vec::new();
        for _ in 0..2000 {
            if !live.is_empty() && rng.uniform() < 0.45 {
                let i = rng.below(live.len());
                let b = live.swap_remove(i);
                // verify the block's memory is still exclusively ours
                unsafe { std::ptr::write_bytes(b.ptr(), 0xCD, b.size) };
                m.unlock(b);
            } else {
                let sz = 1 + rng.below(300_000);
                let b = m.alloc(sz).unwrap();
                unsafe { std::ptr::write_bytes(b.ptr(), 0xAB, b.size) };
                live.push(b);
            }
        }
        check_no_overlap(&m);
        for b in live {
            m.unlock(b);
        }
        assert_eq!(m.stats().allocated_bytes, 0);
    }
}
