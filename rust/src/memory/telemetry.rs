//! Allocation telemetry tying tensor operations to specific allocations —
//! the instrumentation the paper's §5.2.2 researchers built ("specialized
//! telemetry that tied individual tensor operations to specific
//! allocations") to study fragmentation.
//!
//! [`TelemetryMemoryManager`] wraps any inner manager, recording every
//! alloc/free event together with the *operation label* active on the
//! calling thread (pushed by the tensor backend around each op). Recorded
//! traces can be replayed against other managers via [`replay`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::block::Block;
use super::{MemStats, MemoryManagerAdapter};
use crate::util::error::Result;

thread_local! {
    static OP_STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
}

/// RAII guard labelling allocations made on this thread with an op name.
pub struct OpScope;

impl OpScope {
    /// Push `op` onto the thread's label stack.
    pub fn enter(op: &'static str) -> OpScope {
        OP_STACK.with(|s| s.borrow_mut().push(op));
        OpScope
    }
}

impl Drop for OpScope {
    fn drop(&mut self) {
        OP_STACK.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// Innermost active op label on this thread.
pub fn current_op() -> &'static str {
    OP_STACK.with(|s| s.borrow().last().copied().unwrap_or("<unattributed>"))
}

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// An allocation of `bytes`.
    Alloc,
    /// A free of the allocation with matching `id`.
    Free,
}

/// One recorded allocator event.
#[derive(Debug, Clone)]
pub struct AllocEvent {
    /// Alloc/Free.
    pub kind: EventKind,
    /// Requested size in bytes (0 for frees).
    pub bytes: usize,
    /// Trace-local allocation id (frees reference the alloc's id).
    pub id: u64,
    /// Tensor-op label active at the time.
    pub op: &'static str,
}

/// Wraps an inner manager and records an event trace.
pub struct TelemetryMemoryManager {
    inner: Arc<dyn MemoryManagerAdapter>,
    trace: Mutex<Vec<AllocEvent>>,
    /// ptr -> alloc id, to pair frees with allocs.
    live: Mutex<std::collections::HashMap<usize, u64>>,
    next_id: Mutex<u64>,
    enabled: AtomicBool,
    name: String,
}

impl TelemetryMemoryManager {
    /// Wrap `inner`.
    pub fn new(inner: Arc<dyn MemoryManagerAdapter>) -> Self {
        let name = format!("telemetry({})", inner.name());
        TelemetryMemoryManager {
            inner,
            trace: Mutex::new(Vec::new()),
            live: Mutex::new(std::collections::HashMap::new()),
            next_id: Mutex::new(0),
            enabled: AtomicBool::new(true),
            name,
        }
    }

    /// Pause/resume recording (the trace survives).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    /// Snapshot the recorded trace.
    pub fn trace(&self) -> Vec<AllocEvent> {
        self.trace.lock().unwrap().clone()
    }

    /// Clear the recorded trace.
    pub fn reset(&self) {
        self.trace.lock().unwrap().clear();
    }

    /// Per-op aggregate: (op, alloc count, total bytes), largest first.
    pub fn by_op(&self) -> Vec<(&'static str, usize, usize)> {
        let mut agg: std::collections::HashMap<&'static str, (usize, usize)> = Default::default();
        for ev in self.trace.lock().unwrap().iter() {
            if ev.kind == EventKind::Alloc {
                let e = agg.entry(ev.op).or_default();
                e.0 += 1;
                e.1 += ev.bytes;
            }
        }
        let mut v: Vec<_> = agg.into_iter().map(|(op, (n, b))| (op, n, b)).collect();
        v.sort_by_key(|&(_, _, b)| std::cmp::Reverse(b));
        v
    }
}

impl MemoryManagerAdapter for TelemetryMemoryManager {
    fn name(&self) -> &str {
        &self.name
    }

    fn alloc(&self, bytes: usize) -> Result<Block> {
        let block = self.inner.alloc(bytes)?;
        if self.enabled.load(Ordering::SeqCst) {
            let mut idg = self.next_id.lock().unwrap();
            let id = *idg;
            *idg += 1;
            drop(idg);
            self.live.lock().unwrap().insert(block.ptr() as usize, id);
            self.trace.lock().unwrap().push(AllocEvent {
                kind: EventKind::Alloc,
                bytes,
                id,
                op: current_op(),
            });
            // bridge allocator events onto the unified trace timeline
            crate::obs::instant(
                "mem.alloc",
                &[
                    ("bytes", crate::obs::AttrValue::I64(bytes as i64)),
                    ("op", crate::obs::AttrValue::Str(current_op())),
                ],
            );
        }
        Ok(block)
    }

    fn unlock(&self, block: Block) {
        if self.enabled.load(Ordering::SeqCst) {
            if let Some(id) = self.live.lock().unwrap().remove(&(block.ptr() as usize)) {
                self.trace.lock().unwrap().push(AllocEvent {
                    kind: EventKind::Free,
                    bytes: 0,
                    id,
                    op: current_op(),
                });
                crate::obs::instant(
                    "mem.free",
                    &[
                        ("id", crate::obs::AttrValue::I64(id as i64)),
                        ("op", crate::obs::AttrValue::Str(current_op())),
                    ],
                );
            }
        }
        self.inner.unlock(block);
    }

    fn stats(&self) -> MemStats {
        self.inner.stats()
    }

    fn clear_cache(&self) {
        self.inner.clear_cache()
    }
}

/// Replay a recorded trace against `mgr`, returning the stats afterwards
/// and the high-water fragmentation: `1 - peak_allocated/peak_reserved`.
/// Peak allocated bytes are workload-determined (identical across
/// managers), so lower peak reserved = less fragmentation — the metric the
/// paper's §5.2.2 case study optimizes.
pub fn replay(trace: &[AllocEvent], mgr: &dyn MemoryManagerAdapter) -> (MemStats, f64) {
    let mut live: std::collections::HashMap<u64, Block> = Default::default();
    for ev in trace {
        match ev.kind {
            EventKind::Alloc => {
                let b = mgr.alloc(ev.bytes).expect("replay alloc failed");
                live.insert(ev.id, b);
            }
            EventKind::Free => {
                if let Some(b) = live.remove(&ev.id) {
                    mgr.unlock(b);
                }
            }
        }
    }
    for (_, b) in live.drain() {
        mgr.unlock(b);
    }
    let stats = mgr.stats();
    (stats, stats.peak_fragmentation())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::caching::CachingMemoryManager;
    use crate::memory::default::DefaultMemoryManager;

    #[test]
    fn records_and_pairs_events() {
        let t = TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new()));
        let b = {
            let _g = OpScope::enter("matmul");
            t.alloc(4096).unwrap()
        };
        t.unlock(b);
        let tr = t.trace();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0].kind, EventKind::Alloc);
        assert_eq!(tr[0].op, "matmul");
        assert_eq!(tr[1].kind, EventKind::Free);
        assert_eq!(tr[0].id, tr[1].id);
    }

    #[test]
    fn op_scope_nests() {
        let _a = OpScope::enter("outer");
        assert_eq!(current_op(), "outer");
        {
            let _b = OpScope::enter("inner");
            assert_eq!(current_op(), "inner");
        }
        assert_eq!(current_op(), "outer");
    }

    #[test]
    fn by_op_aggregates() {
        let t = TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new()));
        let b1 = {
            let _g = OpScope::enter("conv2d");
            t.alloc(1000).unwrap()
        };
        let b2 = {
            let _g = OpScope::enter("conv2d");
            t.alloc(2000).unwrap()
        };
        let agg = t.by_op();
        assert_eq!(agg[0].0, "conv2d");
        assert_eq!(agg[0].1, 2);
        assert_eq!(agg[0].2, 3000);
        t.unlock(b1);
        t.unlock(b2);
    }

    #[test]
    fn replay_reproduces_liveness() {
        let t = TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new()));
        let a = t.alloc(10_000).unwrap();
        let b = t.alloc(20_000).unwrap();
        t.unlock(a);
        let c = t.alloc(5_000).unwrap();
        t.unlock(b);
        t.unlock(c);
        let trace = t.trace();
        let target = CachingMemoryManager::unrestricted();
        let (stats, worst) = replay(&trace, &target);
        assert_eq!(stats.allocated_bytes, 0, "replay must free everything");
        assert_eq!(stats.alloc_count, 3);
        assert!((0.0..=1.0).contains(&worst));
    }
}
