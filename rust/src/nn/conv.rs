//! Convolution / pooling / reshape modules (paper Listing 8 building
//! blocks: `Conv2D`, `Pool2D`, `View`).

use crate::autograd::{ops, Variable};
use crate::tensor::{Conv2dParams, Pool2dParams, PoolKind, Tensor};

use super::init::kaiming_normal;
use super::Module;

/// Padding specification (paper Listing 8's `PaddingMode::SAME`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// No padding.
    Valid,
    /// Pad so stride-1 output matches input size (`(k-1)/2` per side).
    Same,
    /// Explicit symmetric padding.
    Explicit(usize, usize),
}

/// 2-D convolution layer (NCHW), weight `[out_c, in_c, kh, kw]`.
pub struct Conv2D {
    /// Filter bank.
    pub weight: Variable,
    /// Optional per-output-channel bias.
    pub bias: Option<Variable>,
    stride: (usize, usize),
    padding: (usize, usize),
    desc: String,
}

impl Conv2D {
    /// Construct with the paper's Listing 8 argument order.
    pub fn new(
        in_channels: usize,
        out_channels: usize,
        kw: usize,
        kh: usize,
        sx: usize,
        sy: usize,
        px: Padding,
        py: Padding,
    ) -> Self {
        let resolve = |p: Padding, k: usize| match p {
            Padding::Valid => 0,
            Padding::Same => (k - 1) / 2,
            Padding::Explicit(a, _) => a,
        };
        let padding = (resolve(py, kh), resolve(px, kw));
        let fan_in = in_channels * kh * kw;
        Conv2D {
            weight: Variable::param(kaiming_normal(
                fan_in,
                &[out_channels, in_channels, kh, kw],
            )),
            bias: Some(Variable::param(Tensor::zeros([out_channels]))),
            stride: (sy, sx),
            padding,
            desc: format!("Conv2D({in_channels}, {out_channels}, {kw}x{kh})"),
        }
    }

    /// Square-kernel convenience.
    pub fn square(in_c: usize, out_c: usize, k: usize, stride: usize, pad: Padding) -> Self {
        Self::new(in_c, out_c, k, k, stride, stride, pad, pad)
    }
}

impl Module for Conv2D {
    fn forward(&self, input: &Variable) -> Variable {
        let p = Conv2dParams { stride: self.stride, padding: self.padding };
        let mut y = ops::conv2d(input, &self.weight, p);
        if let Some(b) = &self.bias {
            // bias [C] -> broadcast over [N, C, H, W]
            let c = b.dims()[0];
            let b4 = ops::reshape(b, &[1, c as isize, 1, 1]);
            y = ops::add(&y, &b4);
        }
        y
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            p.push(b.clone());
        }
        p
    }

    fn name(&self) -> String {
        self.desc.clone()
    }
}

/// 2-D pooling layer.
pub struct Pool2D {
    params: Pool2dParams,
}

impl Pool2D {
    /// Max pooling (paper Listing 8 argument order: kw, kh, sx, sy).
    pub fn max(kw: usize, kh: usize, sx: usize, sy: usize) -> Self {
        Pool2D { params: Pool2dParams { kind: PoolKind::Max, kernel: (kh, kw), stride: (sy, sx) } }
    }

    /// Average pooling.
    pub fn avg(kw: usize, kh: usize, sx: usize, sy: usize) -> Self {
        Pool2D { params: Pool2dParams { kind: PoolKind::Avg, kernel: (kh, kw), stride: (sy, sx) } }
    }
}

impl Module for Pool2D {
    fn forward(&self, input: &Variable) -> Variable {
        ops::pool2d(input, self.params)
    }
    fn params(&self) -> Vec<Variable> {
        Vec::new()
    }
    fn name(&self) -> String {
        format!("Pool2D({:?})", self.params.kind)
    }
}

/// Reshape module (paper Listing 8's `View`), `-1` wildcard allowed.
pub struct View {
    dims: Vec<isize>,
}

impl View {
    /// Target dims, one `-1` allowed.
    pub fn new(dims: &[isize]) -> Self {
        View { dims: dims.to_vec() }
    }
}

impl Module for View {
    fn forward(&self, input: &Variable) -> Variable {
        ops::reshape(input, &self.dims)
    }
    fn params(&self) -> Vec<Variable> {
        Vec::new()
    }
    fn name(&self) -> String {
        format!("View({:?})", self.dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ops as aops;

    #[test]
    fn conv_same_preserves_spatial() {
        let c = Conv2D::square(3, 8, 3, 1, Padding::Same);
        let x = Variable::constant(Tensor::rand([2, 3, 8, 8], -1.0, 1.0));
        let y = c.forward(&x);
        assert_eq!(y.dims(), vec![2, 8, 8, 8]);
    }

    #[test]
    fn conv_valid_shrinks() {
        let c = Conv2D::square(1, 4, 5, 1, Padding::Valid);
        let x = Variable::constant(Tensor::rand([1, 1, 10, 10], -1.0, 1.0));
        assert_eq!(c.forward(&x).dims(), vec![1, 4, 6, 6]);
    }

    #[test]
    fn conv_bias_broadcasts_and_gets_grad() {
        let c = Conv2D::square(1, 2, 3, 1, Padding::Same);
        let x = Variable::constant(Tensor::rand([1, 1, 4, 4], -1.0, 1.0));
        let y = aops::sum(&c.forward(&x), &[], false);
        y.backward();
        let bg = c.bias.as_ref().unwrap().grad().unwrap();
        assert_eq!(bg.dims(), &[2]);
        assert_eq!(bg.to_vec(), vec![16.0, 16.0]); // 4x4 spatial each
    }

    #[test]
    fn conv_layer_gradcheck() {
        use crate::testutil::gradcheck::check_grad_tol;
        // fixed module outside the closure (random kaiming weights must
        // not be re-drawn between numeric probes); checks grads through
        // conv2d + broadcast bias add
        let c = Conv2D::square(2, 3, 3, 1, Padding::Same);
        check_grad_tol("conv2d-layer", &[1, 2, 5, 5], 1e-4, 1e-2, |x| {
            aops::sum(&c.forward(x), &[], false)
        });
    }

    #[test]
    fn pool_and_view_chain() {
        let p = Pool2D::max(2, 2, 2, 2);
        let v = View::new(&[-1, 4]);
        let x = Variable::constant(Tensor::rand([1, 1, 4, 4], 0.0, 1.0));
        let y = v.forward(&p.forward(&x));
        assert_eq!(y.dims(), vec![1, 4]);
    }
}
