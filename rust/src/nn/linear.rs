//! Fully-connected layer.

use crate::autograd::{ops, Variable};
use crate::tensor::Tensor;

use super::init::glorot_uniform;
use super::Module;

/// `y = x Wᵀ + b` over the last dimension (leading dims are batch).
pub struct Linear {
    /// Weight `[out, in]`.
    pub weight: Variable,
    /// Optional bias `[out]`.
    pub bias: Option<Variable>,
    in_features: usize,
    out_features: usize,
}

impl Linear {
    /// Glorot-initialized layer with bias.
    pub fn new(in_features: usize, out_features: usize) -> Self {
        Linear {
            weight: Variable::param(glorot_uniform(
                in_features,
                out_features,
                &[out_features, in_features],
            )),
            bias: Some(Variable::param(Tensor::zeros([out_features]))),
            in_features,
            out_features,
        }
    }

    /// Without bias.
    pub fn new_no_bias(in_features: usize, out_features: usize) -> Self {
        let mut l = Self::new(in_features, out_features);
        l.bias = None;
        l
    }

    /// Input width.
    pub fn in_features(&self) -> usize {
        self.in_features
    }

    /// Output width.
    pub fn out_features(&self) -> usize {
        self.out_features
    }
}

impl Module for Linear {
    fn forward(&self, input: &Variable) -> Variable {
        // flatten leading dims into a batch for the 2-D matmul, then restore
        let in_dims = input.dims();
        let rank = in_dims.len();
        assert!(rank >= 1, "Linear needs rank >= 1");
        assert_eq!(in_dims[rank - 1], self.in_features, "Linear input width");
        let flat = if rank == 2 {
            input.clone()
        } else {
            ops::reshape(input, &[-1, self.in_features as isize])
        };
        let mut y = ops::matmul(&flat, &ops::t(&self.weight));
        if let Some(b) = &self.bias {
            y = ops::add(&y, b);
        }
        if rank != 2 {
            let mut out_dims: Vec<isize> =
                in_dims[..rank - 1].iter().map(|&d| d as isize).collect();
            out_dims.push(self.out_features as isize);
            y = ops::reshape(&y, &out_dims);
        }
        y
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            p.push(b.clone());
        }
        p
    }

    fn name(&self) -> String {
        format!("Linear({}, {})", self.in_features, self.out_features)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let l = Linear::new(4, 3);
        let x = Variable::constant(Tensor::rand([5, 4], -1.0, 1.0));
        assert_eq!(l.forward(&x).dims(), vec![5, 3]);
        // rank-3 input
        let x3 = Variable::constant(Tensor::rand([2, 5, 4], -1.0, 1.0));
        assert_eq!(l.forward(&x3).dims(), vec![2, 5, 3]);
    }

    #[test]
    fn known_values() {
        let l = Linear::new(2, 1);
        l.weight.set_tensor(Tensor::from_slice(&[2.0f32, 3.0], [1, 2]));
        l.bias.as_ref().unwrap().set_tensor(Tensor::from_slice(&[1.0f32], [1]));
        let x = Variable::constant(Tensor::from_slice(&[1.0f32, 1.0], [1, 2]));
        assert_eq!(l.forward(&x).tensor().to_vec(), vec![6.0]);
    }

    #[test]
    fn gradients_flow_to_both_params() {
        let l = Linear::new(3, 2);
        let x = Variable::constant(Tensor::rand([4, 3], -1.0, 1.0));
        let y = ops::sum(&l.forward(&x), &[], false);
        y.backward();
        assert_eq!(l.weight.grad().unwrap().dims(), &[2, 3]);
        // bias grad = batch size per output
        assert_eq!(l.bias.as_ref().unwrap().grad().unwrap().to_vec(), vec![4.0, 4.0]);
    }

    #[test]
    fn no_bias_variant() {
        let l = Linear::new_no_bias(2, 2);
        assert_eq!(l.params().len(), 1);
    }
}
