//! Normalization layers.

use std::sync::Mutex;

use crate::autograd::{ops, Variable};
use crate::tensor::Tensor;

use super::Module;

/// Layer normalization over the last dimension, with learnable gain/bias.
pub struct LayerNorm {
    /// Gain `γ` `[dim]`.
    pub gamma: Variable,
    /// Bias `β` `[dim]`.
    pub beta: Variable,
    dim: usize,
    eps: f64,
}

impl LayerNorm {
    /// Normalize the trailing `dim` features.
    pub fn new(dim: usize) -> Self {
        LayerNorm {
            gamma: Variable::param(Tensor::ones([dim])),
            beta: Variable::param(Tensor::zeros([dim])),
            dim,
            eps: 1e-5,
        }
    }
}

impl Module for LayerNorm {
    fn forward(&self, input: &Variable) -> Variable {
        assert_eq!(*input.dims().last().unwrap(), self.dim, "LayerNorm dim");
        let mu = ops::mean(input, &[-1], true);
        let centered = ops::sub(input, &mu);
        let var = ops::mean(&ops::mul(&centered, &centered), &[-1], true);
        let inv = ops::pow_scalar(&ops::add_scalar(&var, self.eps), -0.5);
        let normed = ops::mul(&centered, &inv);
        ops::add(&ops::mul(&normed, &self.gamma), &self.beta)
    }

    fn params(&self) -> Vec<Variable> {
        vec![self.gamma.clone(), self.beta.clone()]
    }

    fn name(&self) -> String {
        format!("LayerNorm({})", self.dim)
    }
}

/// Batch normalization over NCHW feature maps with running statistics.
pub struct BatchNorm2d {
    /// Gain per channel.
    pub gamma: Variable,
    /// Bias per channel.
    pub beta: Variable,
    running_mean: Variable,
    running_var: Variable,
    momentum: f64,
    eps: f64,
    channels: usize,
    train: bool,
    // updates to running stats happen during forward; guard for Sync
    update_lock: Mutex<()>,
}

impl BatchNorm2d {
    /// Batch-norm over `channels` feature maps.
    pub fn new(channels: usize) -> Self {
        BatchNorm2d {
            gamma: Variable::param(Tensor::ones([channels])),
            beta: Variable::param(Tensor::zeros([channels])),
            running_mean: Variable::constant(Tensor::zeros([channels])),
            running_var: Variable::constant(Tensor::ones([channels])),
            momentum: 0.1,
            eps: 1e-5,
            channels,
            train: true,
            update_lock: Mutex::new(()),
        }
    }
}

impl Module for BatchNorm2d {
    fn forward(&self, input: &Variable) -> Variable {
        let dims = input.dims();
        assert_eq!(dims.len(), 4, "BatchNorm2d wants NCHW");
        assert_eq!(dims[1], self.channels, "BatchNorm2d channels");
        let c = self.channels as isize;
        let reshape4 = |v: &Variable| ops::reshape(v, &[1, c, 1, 1]);

        let (mu, var) = if self.train {
            let mu = ops::mean(input, &[0, 2, 3], true);
            let centered = ops::sub(input, &mu);
            let var = ops::mean(&ops::mul(&centered, &centered), &[0, 2, 3], true);
            // update running stats (detached)
            {
                let _g = self.update_lock.lock().unwrap();
                let m = self.momentum;
                let mu_flat = mu.tensor().reshape(&[c]);
                let var_flat = var.tensor().reshape(&[c]);
                self.running_mean.set_tensor(
                    self.running_mean.tensor().mul_scalar(1.0 - m).add(&mu_flat.mul_scalar(m)),
                );
                self.running_var.set_tensor(
                    self.running_var.tensor().mul_scalar(1.0 - m).add(&var_flat.mul_scalar(m)),
                );
            }
            (mu, var)
        } else {
            (
                reshape4(&Variable::constant(self.running_mean.tensor())),
                reshape4(&Variable::constant(self.running_var.tensor())),
            )
        };
        let inv = ops::pow_scalar(&ops::add_scalar(&var, self.eps), -0.5);
        let normed = ops::mul(&ops::sub(input, &mu), &inv);
        ops::add(&ops::mul(&normed, &reshape4(&self.gamma)), &reshape4(&self.beta))
    }

    fn params(&self) -> Vec<Variable> {
        vec![self.gamma.clone(), self.beta.clone()]
    }

    fn buffers(&self) -> Vec<Variable> {
        vec![self.running_mean.clone(), self.running_var.clone()]
    }

    fn set_train(&mut self, train: bool) {
        self.train = train;
    }

    fn name(&self) -> String {
        format!("BatchNorm2d({})", self.channels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let ln = LayerNorm::new(8);
        let x = Variable::constant(Tensor::rand([4, 8], -3.0, 7.0));
        let y = ln.forward(&x).tensor();
        let mu = y.mean(&[-1], false).to_vec();
        let sd = y.std(&[-1], false).to_vec();
        for m in mu {
            assert!(m.abs() < 1e-4, "mean {m}");
        }
        for s in sd {
            assert!((s - 1.0).abs() < 1e-2, "std {s}");
        }
    }

    #[test]
    fn layernorm_gradcheck() {
        use crate::testutil::gradcheck::check_grad;
        check_grad("layernorm", &[2, 6], |x| {
            let ln = LayerNorm::new(6);
            ops::sum(&ops::mul(&ln.forward(x), x), &[], false)
        });
    }

    #[test]
    fn layernorm_param_gradcheck() {
        use crate::tensor::DType;
        use crate::testutil::gradcheck::check_grad;
        let input = Variable::constant(Tensor::rand([3, 6], -1.0, 1.0).astype(DType::F64));
        let input2 = Variable::constant(input.tensor());
        check_grad("layernorm-gamma", &[6], move |g| {
            let mut ln = LayerNorm::new(6);
            ln.gamma = g.clone();
            ops::sum(&ln.forward(&input), &[], false)
        });
        check_grad("layernorm-beta", &[6], move |b| {
            let mut ln = LayerNorm::new(6);
            ln.beta = b.clone();
            ops::sum(&ops::mul(&ln.forward(&input2), &input2), &[], false)
        });
    }

    #[test]
    fn batchnorm_gradcheck() {
        use crate::testutil::gradcheck::check_grad_tol;
        let bn = BatchNorm2d::new(2);
        // multiply by x so the target is nonlinear in the input (a plain
        // sum of a batch-normalized tensor has near-zero gradient)
        check_grad_tol("batchnorm", &[2, 2, 3, 3], 1e-4, 1e-2, |x| {
            ops::sum(&ops::mul(&bn.forward(x), x), &[], false)
        });
    }

    #[test]
    fn batchnorm_train_normalizes_batch() {
        let bn = BatchNorm2d::new(3);
        let x = Variable::constant(Tensor::rand([4, 3, 5, 5], 2.0, 6.0));
        let y = bn.forward(&x).tensor();
        let mu = y.mean(&[0, 2, 3], false).to_vec();
        for m in mu {
            assert!(m.abs() < 1e-4);
        }
    }

    #[test]
    fn batchnorm_eval_uses_running_stats() {
        let mut bn = BatchNorm2d::new(2);
        // feed a few training batches to build running stats
        for _ in 0..20 {
            let x = Variable::constant(Tensor::randn([8, 2, 4, 4], 3.0, 2.0));
            bn.forward(&x);
        }
        bn.set_train(false);
        let x = Variable::constant(Tensor::randn([8, 2, 4, 4], 3.0, 2.0));
        let y = bn.forward(&x).tensor();
        // eval output should be roughly standardized given matched stats
        let m = y.mean(&[], false).item();
        assert!(m.abs() < 0.5, "eval mean {m}");
        assert_eq!(bn.buffers().len(), 2);
    }
}
