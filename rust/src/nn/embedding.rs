//! Token embedding lookup.

use crate::autograd::{ops, Variable};
use crate::tensor::{DType, Tensor};

use super::init::normal;
use super::Module;

/// Trainable embedding table `[vocab, dim]`; forward maps integer token
/// tensors `[...]` to `[..., dim]` via `index_select`, with a
/// `scatter_add` gradient.
pub struct Embedding {
    /// The table.
    pub weight: Variable,
    vocab: usize,
    dim: usize,
}

impl Embedding {
    /// N(0, 0.02)-initialized table (transformer convention).
    pub fn new(vocab: usize, dim: usize) -> Self {
        Embedding { weight: Variable::param(normal(0.02, &[vocab, dim])), vocab, dim }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Embedding width.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Look up integer ids (any shape); returns `[..ids, dim]`.
    pub fn lookup(&self, ids: &Tensor) -> Variable {
        let id_dims = ids.dims().to_vec();
        let n = ids.numel();
        let flat = ids.astype(DType::I64).reshape(&[n as isize]);
        let rows = ops::index_select0(&self.weight, &flat);
        let mut out_dims: Vec<isize> = id_dims.iter().map(|&d| d as isize).collect();
        out_dims.push(self.dim as isize);
        ops::reshape(&rows, &out_dims)
    }
}

impl Module for Embedding {
    fn forward(&self, input: &Variable) -> Variable {
        self.lookup(&input.tensor())
    }

    fn params(&self) -> Vec<Variable> {
        vec![self.weight.clone()]
    }

    fn name(&self) -> String {
        format!("Embedding({}, {})", self.vocab, self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_shapes_and_values() {
        let e = Embedding::new(10, 4);
        e.weight.set_tensor(Tensor::arange(40, DType::F32).reshape(&[10, 4]));
        let ids = Tensor::from_slice(&[2i64, 0, 2], [3]);
        let out = e.lookup(&ids).tensor();
        assert_eq!(out.dims(), &[3, 4]);
        assert_eq!(out.to_vec()[..4], [8.0, 9.0, 10.0, 11.0]);
        // batched ids
        let ids2 = Tensor::from_slice(&[1i64, 2, 3, 4], [2, 2]);
        assert_eq!(e.lookup(&ids2).dims(), vec![2, 2, 4]);
    }

    #[test]
    fn duplicate_ids_accumulate_grads() {
        let e = Embedding::new(5, 2);
        let ids = Tensor::from_slice(&[3i64, 3, 1], [3]);
        let out = e.lookup(&ids);
        ops::sum(&out, &[], false).backward();
        let g = e.weight.grad().unwrap().to_vec();
        assert_eq!(g[6..8], [2.0, 2.0]); // row 3 hit twice
        assert_eq!(g[2..4], [1.0, 1.0]); // row 1 hit once
        assert_eq!(g[0..2], [0.0, 0.0]);
    }
}
