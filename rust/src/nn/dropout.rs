//! Dropout (paper Listing 6, including train/eval gating).

use crate::autograd::{ops, Variable};
use crate::tensor::{DType, Tensor};

use super::Module;

/// Inverted dropout: at train time, zero each element with probability
/// `ratio` and scale survivors by `1/(1-ratio)`; identity in eval mode.
pub struct Dropout {
    ratio: f64,
    train: bool,
}

impl Dropout {
    /// Listing 6's constructor (default ratio 0.5).
    pub fn new(drop_ratio: f64) -> Self {
        assert!((0.0..1.0).contains(&drop_ratio), "dropout ratio must be in [0,1)");
        Dropout { ratio: drop_ratio, train: true }
    }

    /// The configured drop probability.
    pub fn ratio(&self) -> f64 {
        self.ratio
    }
}

impl Default for Dropout {
    fn default() -> Self {
        Self::new(0.5)
    }
}

impl Module for Dropout {
    fn forward(&self, input: &Variable) -> Variable {
        if !self.train || self.ratio == 0.0 {
            return input.clone();
        }
        let shape = input.dims();
        let keep = Tensor::rand(shape, 0.0, 1.0)
            .ge(&Tensor::full([], self.ratio, DType::F32))
            .astype(DType::F32)
            .mul_scalar(1.0 / (1.0 - self.ratio));
        ops::mul(input, &Variable::constant(keep))
    }

    fn params(&self) -> Vec<Variable> {
        Vec::new()
    }

    fn set_train(&mut self, train: bool) {
        self.train = train;
    }

    fn name(&self) -> String {
        format!("Dropout({})", self.ratio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_mode_is_identity() {
        let mut d = Dropout::new(0.5);
        d.set_train(false);
        let x = Variable::constant(Tensor::rand([100], -1.0, 1.0));
        assert_eq!(d.forward(&x).tensor().to_vec(), x.tensor().to_vec());
    }

    #[test]
    fn train_mode_zeroes_and_rescales() {
        crate::util::rng::seed(11);
        let d = Dropout::new(0.5);
        let x = Variable::constant(Tensor::ones([10_000]));
        let y = d.forward(&x).tensor().to_vec();
        let zeros = y.iter().filter(|&&v| v == 0.0).count();
        let frac = zeros as f64 / y.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "drop fraction {frac}");
        // survivors are scaled to preserve the expectation
        for &v in &y {
            assert!(v == 0.0 || (v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_ratio_is_noop() {
        let d = Dropout::new(0.0);
        let x = Variable::constant(Tensor::ones([4]));
        assert_eq!(d.forward(&x).tensor().to_vec(), vec![1.0; 4]);
    }

    #[test]
    fn gradient_masks_match_forward() {
        crate::util::rng::seed(3);
        let d = Dropout::new(0.3);
        let x = Variable::param(Tensor::ones([1000]));
        let y = d.forward(&x);
        let yv = y.tensor().to_vec();
        crate::autograd::ops::sum(&y, &[], false).backward();
        let g = x.grad().unwrap().to_vec();
        for (gi, yi) in g.iter().zip(&yv) {
            assert_eq!(*gi == 0.0, *yi == 0.0, "gradient mask mismatch");
        }
    }
}
