//! Loss functions, derived by composition from the primitive op set.

use crate::autograd::{ops, Variable};
use crate::tensor::{DType, Tensor};

/// Categorical cross-entropy between `logits [N, C]` (unnormalized) and
/// integer `targets [N]`; mean over the batch. (The paper's MNIST listing
/// feeds LogSoftmax outputs; this accepts raw logits and applies
/// log-softmax internally, which is equivalent since log-softmax is
/// idempotent up to an additive constant.)
pub fn categorical_cross_entropy(logits: &Variable, targets: &Tensor) -> Variable {
    let dims = logits.dims();
    assert_eq!(dims.len(), 2, "cross entropy wants [N, C] logits");
    let (n, c) = (dims[0], dims[1]);
    assert_eq!(targets.numel(), n, "targets length");
    let logp = ops::log_softmax(logits, -1);
    let onehot = Variable::constant(targets.astype(DType::I64).one_hot(c));
    let picked = ops::sum(&ops::mul(&logp, &onehot), &[], false);
    ops::mul_scalar(&picked, -1.0 / n as f64)
}

/// Mean squared error.
pub fn mse_loss(pred: &Variable, target: &Variable) -> Variable {
    ops::mse(pred, target)
}

/// Binary cross-entropy on probabilities in `(0,1)`.
pub fn binary_cross_entropy(prob: &Variable, target: &Variable) -> Variable {
    let eps = 1e-7;
    let p = ops::add_scalar(prob, eps);
    let q = ops::add_scalar(&ops::mul_scalar(prob, -1.0), 1.0 + eps);
    let pos = ops::mul(target, &ops::log(&p));
    let neg = ops::mul(&ops::add_scalar(&ops::mul_scalar(target, -1.0), 1.0), &ops::log(&q));
    ops::mul_scalar(&ops::mean(&ops::add(&pos, &neg), &[], false), -1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_entropy_perfect_prediction_near_zero() {
        // huge logit on the right class
        let logits = Variable::constant(Tensor::from_slice(
            &[20.0f32, 0.0, 0.0, 0.0, 20.0, 0.0],
            [2, 3],
        ));
        let targets = Tensor::from_slice(&[0i64, 1], [2]);
        let l = categorical_cross_entropy(&logits, &targets);
        assert!(l.tensor().item() < 1e-6);
    }

    #[test]
    fn cross_entropy_uniform_is_log_c() {
        let logits = Variable::constant(Tensor::zeros([4, 10]));
        let targets = Tensor::from_slice(&[0i64, 3, 5, 9], [4]);
        let l = categorical_cross_entropy(&logits, &targets).tensor().item();
        assert!((l - (10.0f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_gradcheck() {
        use crate::testutil::gradcheck::check_grad;
        let targets = Tensor::from_slice(&[1i64, 0, 2], [3]);
        check_grad("xent", &[3, 4], move |x| categorical_cross_entropy(x, &targets));
    }

    #[test]
    fn bce_symmetric_extremes() {
        let p = Variable::constant(Tensor::from_slice(&[0.9f32, 0.1], [2]));
        let t = Variable::constant(Tensor::from_slice(&[1.0f32, 0.0], [2]));
        let l = binary_cross_entropy(&p, &t).tensor().item();
        assert!((l - (-(0.9f64).ln())).abs() < 1e-4);
    }

    #[test]
    fn training_reduces_cross_entropy() {
        // one linear layer learns a trivial mapping
        use crate::nn::{Linear, Module};
        crate::util::rng::seed(1);
        let layer = Linear::new(4, 3);
        let x = Tensor::from_slice(
            &[1.0f32, 0., 0., 0., 0., 1., 0., 0., 0., 0., 1., 0.],
            [3, 4],
        );
        let y = Tensor::from_slice(&[0i64, 1, 2], [3]);
        let mut last = f64::INFINITY;
        for _ in 0..50 {
            let out = layer.forward(&Variable::constant(x.clone()));
            let loss = categorical_cross_entropy(&out, &y);
            let lv = loss.tensor().item();
            loss.backward();
            for p in layer.params() {
                let g = p.grad().unwrap();
                p.set_tensor(p.tensor().sub(&g.mul_scalar(0.5)));
                p.zero_grad();
            }
            last = lv;
        }
        assert!(last < 0.1, "loss did not converge: {last}");
    }
}
