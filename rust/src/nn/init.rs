//! Parameter initializers.

use crate::tensor::Tensor;

/// Glorot/Xavier uniform: `U(-a, a)`, `a = sqrt(6 / (fan_in + fan_out))`.
pub fn glorot_uniform(fan_in: usize, fan_out: usize, shape: &[usize]) -> Tensor {
    let a = (6.0 / (fan_in + fan_out) as f64).sqrt();
    Tensor::rand(shape.to_vec(), -a, a)
}

/// Kaiming/He normal: `N(0, sqrt(2 / fan_in))` (ReLU networks).
pub fn kaiming_normal(fan_in: usize, shape: &[usize]) -> Tensor {
    let std = (2.0 / fan_in as f64).sqrt();
    Tensor::randn(shape.to_vec(), 0.0, std)
}

/// Truncated-ish normal used for embeddings / transformers.
pub fn normal(std: f64, shape: &[usize]) -> Tensor {
    Tensor::randn(shape.to_vec(), 0.0, std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glorot_bounds() {
        let t = glorot_uniform(100, 100, &[100, 100]);
        let bound = (6.0 / 200.0_f64).sqrt() as f32;
        assert!(t.to_vec().iter().all(|&x| x.abs() <= bound));
    }

    #[test]
    fn kaiming_scale() {
        crate::util::rng::seed(5);
        let t = kaiming_normal(200, &[200, 50]);
        let std = t.std(&[], false).item();
        let want = (2.0 / 200.0_f64).sqrt();
        assert!((std - want).abs() / want < 0.1, "std {std} want {want}");
    }
}
