//! Neural-network building blocks (paper §4.2 "Neural Network
//! Primitives", §A.4.2).
//!
//! Everything derives from the [`Module`] interface, communicates by
//! exchanging [`Variable`]s, and composes functionally or imperatively
//! (e.g. [`Sequential`]). All layer math is written in terms of the small
//! tensor-backend primitive set via [`crate::autograd::ops`], so modules
//! run unchanged on any backend.

pub mod activations;
pub mod attention;
pub mod conv;
pub mod dropout;
pub mod embedding;
pub mod init;
pub mod linear;
pub mod loss;
pub mod norm;
pub mod transformer;

pub use activations::{LogSoftmax, ReLU, Sigmoid, Tanh, GELU};
pub use attention::{KvCache, MultiheadAttention, PagedKvCache};
pub use conv::{Conv2D, Pool2D, View};
pub use dropout::Dropout;
pub use embedding::Embedding;
pub use linear::Linear;
pub use loss::{binary_cross_entropy, categorical_cross_entropy, mse_loss};
pub use norm::{BatchNorm2d, LayerNorm};
pub use transformer::{PositionalEmbedding, TransformerEncoderLayer};

use crate::autograd::Variable;

/// The module interface (paper §4: blocks "derive from a MODULE interface,
/// communicate by exchanging Tensor data, and are composed functionally or
/// imperatively").
pub trait Module: Send {
    /// Apply the module.
    fn forward(&self, input: &Variable) -> Variable;

    /// Trainable parameters (used by optimizers, serialization, and the
    /// distributed gradient synchronizer).
    fn params(&self) -> Vec<Variable>;

    /// Non-trainable state (e.g. batch-norm running statistics).
    fn buffers(&self) -> Vec<Variable> {
        Vec::new()
    }

    /// Switch train/eval behavior (dropout, batch-norm).
    fn set_train(&mut self, _train: bool) {}

    /// Human-readable name.
    fn name(&self) -> String;
}

/// Total number of scalar parameters of a module.
pub fn num_params(m: &dyn Module) -> usize {
    m.params().iter().map(|p| p.tensor().numel()).sum()
}

/// A sequence of modules applied in order (paper Listing 8).
#[derive(Default)]
pub struct Sequential {
    layers: Vec<Box<dyn Module>>,
}

impl Sequential {
    /// Empty container.
    pub fn new() -> Self {
        Sequential { layers: Vec::new() }
    }

    /// Append a module (builder style: `seq.add(Linear::new(...))`).
    pub fn add(&mut self, m: impl Module + 'static) -> &mut Self {
        self.layers.push(Box::new(m));
        self
    }

    /// Append a boxed module.
    pub fn add_boxed(&mut self, m: Box<dyn Module>) -> &mut Self {
        self.layers.push(m);
        self
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Is the container empty?
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Access a layer.
    pub fn layer(&self, i: usize) -> &dyn Module {
        self.layers[i].as_ref()
    }
}

impl Module for Sequential {
    fn forward(&self, input: &Variable) -> Variable {
        let mut x = input.clone();
        for l in &self.layers {
            x = l.forward(&x);
        }
        x
    }

    fn params(&self) -> Vec<Variable> {
        self.layers.iter().flat_map(|l| l.params()).collect()
    }

    fn buffers(&self) -> Vec<Variable> {
        self.layers.iter().flat_map(|l| l.buffers()).collect()
    }

    fn set_train(&mut self, train: bool) {
        for l in &mut self.layers {
            l.set_train(train);
        }
    }

    fn name(&self) -> String {
        let inner: Vec<String> = self.layers.iter().map(|l| l.name()).collect();
        format!("Sequential({})", inner.join(" -> "))
    }
}

/// A module made from a plain function (functional composition).
pub struct Lambda<F: Fn(&Variable) -> Variable + Send> {
    f: F,
    label: &'static str,
}

impl<F: Fn(&Variable) -> Variable + Send> Lambda<F> {
    /// Wrap a closure as a module.
    pub fn new(label: &'static str, f: F) -> Self {
        Lambda { f, label }
    }
}

impl<F: Fn(&Variable) -> Variable + Send> Module for Lambda<F> {
    fn forward(&self, input: &Variable) -> Variable {
        (self.f)(input)
    }
    fn params(&self) -> Vec<Variable> {
        Vec::new()
    }
    fn name(&self) -> String {
        format!("Lambda({})", self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ops;
    use crate::tensor::Tensor;

    #[test]
    fn sequential_composes_and_collects_params() {
        let mut seq = Sequential::new();
        seq.add(Linear::new(4, 8));
        seq.add(ReLU);
        seq.add(Linear::new(8, 2));
        let x = Variable::constant(Tensor::rand([3, 4], -1.0, 1.0));
        let y = seq.forward(&x);
        assert_eq!(y.dims(), vec![3, 2]);
        assert_eq!(seq.params().len(), 4); // two weight+bias pairs
        assert!(num_params(&seq) > 0);
        assert!(seq.name().contains("Linear"));
    }

    #[test]
    fn lambda_module() {
        let m = Lambda::new("double", |x| ops::mul_scalar(x, 2.0));
        let y = m.forward(&Variable::constant(Tensor::ones([2])));
        assert_eq!(y.tensor().to_vec(), vec![2.0, 2.0]);
        assert!(m.params().is_empty());
    }

    #[test]
    fn sequential_gradient_flows_end_to_end() {
        let mut seq = Sequential::new();
        seq.add(Linear::new(3, 3));
        seq.add(Tanh);
        seq.add(Linear::new(3, 1));
        let x = Variable::constant(Tensor::rand([2, 3], -1.0, 1.0));
        let y = ops::sum(&seq.forward(&x), &[], false);
        y.backward();
        for p in seq.params() {
            assert!(p.grad().is_some(), "missing grad");
        }
    }
}
