//! Transformer encoder blocks and positional embeddings.

use crate::autograd::{ops, Variable};

use super::attention::{KvCache, MultiheadAttention};
use super::dropout::Dropout;
use super::linear::Linear;
use super::norm::LayerNorm;
use super::Module;

/// Learned absolute positional embedding added to `[B, L, D]` inputs.
pub struct PositionalEmbedding {
    /// Table `[max_len, dim]`.
    pub weight: Variable,
    max_len: usize,
}

impl PositionalEmbedding {
    /// Table for sequences up to `max_len`.
    pub fn new(max_len: usize, dim: usize) -> Self {
        PositionalEmbedding {
            weight: Variable::param(super::init::normal(0.02, &[max_len, dim])),
            max_len,
        }
    }

    /// Longest supported sequence.
    pub fn max_len(&self) -> usize {
        self.max_len
    }

    /// Add the embeddings of positions `offset .. offset + L` to a
    /// `[B, L, D]` input — the incremental-decode entry, where the new
    /// tokens sit `offset` positions into the sequence.
    pub fn forward_at(&self, input: &Variable, offset: usize) -> Variable {
        let dims = input.dims();
        let l = dims[1];
        assert!(
            offset + l <= self.max_len,
            "positions {}..{} exceed max_len {}",
            offset,
            offset + l,
            self.max_len
        );
        let pos = ops::slice(&self.weight, &[offset, 0], &[offset + l, dims[2]]);
        // [L, D] broadcasts over batch
        ops::add(input, &pos)
    }
}

impl Module for PositionalEmbedding {
    fn forward(&self, input: &Variable) -> Variable {
        self.forward_at(input, 0)
    }
    fn params(&self) -> Vec<Variable> {
        vec![self.weight.clone()]
    }
    fn name(&self) -> String {
        format!("PositionalEmbedding(max={})", self.max_len)
    }
}

/// Pre-norm transformer encoder layer:
/// `x + attn(ln1(x))`, then `x + mlp(ln2(x))` with GELU MLP.
pub struct TransformerEncoderLayer {
    /// Self-attention block.
    pub attn: MultiheadAttention,
    /// MLP up-projection.
    pub fc1: Linear,
    /// MLP down-projection.
    pub fc2: Linear,
    ln1: LayerNorm,
    ln2: LayerNorm,
    drop: Dropout,
    dim: usize,
}

impl TransformerEncoderLayer {
    /// Standard block: `mlp_dim` is usually `4*dim`.
    pub fn new(dim: usize, heads: usize, mlp_dim: usize, dropout: f64, causal: bool) -> Self {
        TransformerEncoderLayer {
            attn: MultiheadAttention::new(dim, heads, causal),
            fc1: Linear::new(dim, mlp_dim),
            fc2: Linear::new(mlp_dim, dim),
            ln1: LayerNorm::new(dim),
            ln2: LayerNorm::new(dim),
            drop: Dropout::new(dropout),
            dim,
        }
    }

    /// Forward new positions `[B, L_new, D]` against this layer's KV
    /// cache (see [`MultiheadAttention::forward_cached`]); everything
    /// outside attention is position-wise, so only the attention core
    /// needs the past. Run the layer in eval mode (dropout off) — a
    /// random mask over only the new positions would not match a full
    /// recompute.
    pub fn forward_cached(&self, input: &Variable, cache: &mut KvCache) -> Variable {
        let a = self.attn.forward_cached(&self.ln1.forward(input), cache);
        let x = ops::add(input, &self.drop.forward(&a));
        let h = self.fc2.forward(&ops::gelu(&self.fc1.forward(&self.ln2.forward(&x))));
        ops::add(&x, &self.drop.forward(&h))
    }
}

impl Module for TransformerEncoderLayer {
    fn forward(&self, input: &Variable) -> Variable {
        let a = self.attn.forward(&self.ln1.forward(input));
        let x = ops::add(input, &self.drop.forward(&a));
        let h = self.fc2.forward(&ops::gelu(&self.fc1.forward(&self.ln2.forward(&x))));
        ops::add(&x, &self.drop.forward(&h))
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.attn.params();
        p.extend(self.fc1.params());
        p.extend(self.fc2.params());
        p.extend(self.ln1.params());
        p.extend(self.ln2.params());
        p
    }

    fn set_train(&mut self, train: bool) {
        self.drop.set_train(train);
    }

    fn name(&self) -> String {
        format!("TransformerEncoderLayer(d={})", self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn block_preserves_shape() {
        let mut blk = TransformerEncoderLayer::new(16, 4, 32, 0.0, false);
        blk.set_train(false);
        let x = Variable::constant(Tensor::rand([2, 6, 16], -1.0, 1.0));
        assert_eq!(blk.forward(&x).dims(), vec![2, 6, 16]);
    }

    #[test]
    fn positional_embedding_adds() {
        let pe = PositionalEmbedding::new(8, 4);
        pe.weight.set_tensor(Tensor::ones([8, 4]));
        let x = Variable::constant(Tensor::zeros([2, 3, 4]));
        let y = pe.forward(&x).tensor();
        assert_eq!(y.to_vec(), vec![1.0; 24]);
    }

    #[test]
    fn full_block_gradients() {
        let blk = TransformerEncoderLayer::new(8, 2, 16, 0.0, true);
        let x = Variable::constant(Tensor::rand([1, 4, 8], -1.0, 1.0));
        ops::sum(&blk.forward(&x), &[], false).backward();
        let n_with_grad = blk.params().iter().filter(|p| p.grad().is_some()).count();
        assert_eq!(n_with_grad, blk.params().len());
    }
}
