//! Transformer encoder blocks and positional embeddings.

use crate::autograd::{ops, Variable};
use crate::tensor::Tensor;

use super::attention::{KvCache, MultiheadAttention, PagedKvCache};
use super::dropout::Dropout;
use super::linear::Linear;
use super::norm::LayerNorm;
use super::Module;

/// Learned absolute positional embedding added to `[B, L, D]` inputs.
pub struct PositionalEmbedding {
    /// Table `[max_len, dim]`.
    pub weight: Variable,
    max_len: usize,
}

impl PositionalEmbedding {
    /// Table for sequences up to `max_len`.
    pub fn new(max_len: usize, dim: usize) -> Self {
        PositionalEmbedding {
            weight: Variable::param(super::init::normal(0.02, &[max_len, dim])),
            max_len,
        }
    }

    /// Longest supported sequence.
    pub fn max_len(&self) -> usize {
        self.max_len
    }

    /// Add the embeddings of positions `offset .. offset + L` to a
    /// `[B, L, D]` input — the incremental-decode entry, where the new
    /// tokens sit `offset` positions into the sequence.
    pub fn forward_at(&self, input: &Variable, offset: usize) -> Variable {
        let dims = input.dims();
        let l = dims[1];
        assert!(
            offset + l <= self.max_len,
            "positions {}..{} exceed max_len {}",
            offset,
            offset + l,
            self.max_len
        );
        let pos = ops::slice(&self.weight, &[offset, 0], &[offset + l, dims[2]]);
        // [L, D] broadcasts over batch
        ops::add(input, &pos)
    }

    /// Add each row's *own* position embedding to a `[B, 1, D]` decode
    /// batch: row `i` sits at position `offsets[i]` of its sequence. The
    /// continuous batcher needs this because cohabiting requests are at
    /// different depths. Row `i` sees the same value pair additions as
    /// [`Self::forward_at`] with `offset = offsets[i]` would feed it, so
    /// the batched add is bit-identical per row.
    pub fn forward_at_each(&self, input: &Variable, offsets: &[usize]) -> Variable {
        for &o in offsets {
            assert!(o < self.max_len, "position {o} exceeds max_len {}", self.max_len);
        }
        let idx: Vec<i64> = offsets.iter().map(|&o| o as i64).collect();
        self.forward_at_positions(input, &Tensor::from_slice(&idx, [idx.len()]))
    }

    /// [`Self::forward_at_each`] with the positions already materialized
    /// as an `i64` `[B]` tensor. This is the traceable form: the position
    /// tensor is a substitutable parameter of a compiled decode step, so
    /// requests advancing through their sequences never change the traced
    /// program. Positions are *not* range-checked here (a trace sees only
    /// example values); the eager wrapper and the scheduler's admission
    /// bounds (`prompt + max_new <= max_len`) keep them in range.
    pub fn forward_at_positions(&self, input: &Variable, positions: &Tensor) -> Variable {
        let dims = input.dims();
        assert_eq!(dims.len(), 3, "positional embedding wants [B, L, D]");
        assert_eq!(dims[1], 1, "per-row offsets step one position per row");
        assert_eq!(positions.dims(), &[dims[0]][..], "one position per batch row");
        let rows = ops::index_select0(&self.weight, positions);
        let pos = ops::reshape(&rows, &[dims[0] as isize, 1, dims[2] as isize]);
        ops::add(input, &pos)
    }
}

impl Module for PositionalEmbedding {
    fn forward(&self, input: &Variable) -> Variable {
        self.forward_at(input, 0)
    }
    fn params(&self) -> Vec<Variable> {
        vec![self.weight.clone()]
    }
    fn name(&self) -> String {
        format!("PositionalEmbedding(max={})", self.max_len)
    }
}

/// Pre-norm transformer encoder layer:
/// `x + attn(ln1(x))`, then `x + mlp(ln2(x))` with GELU MLP.
pub struct TransformerEncoderLayer {
    /// Self-attention block.
    pub attn: MultiheadAttention,
    /// MLP up-projection.
    pub fc1: Linear,
    /// MLP down-projection.
    pub fc2: Linear,
    ln1: LayerNorm,
    ln2: LayerNorm,
    drop: Dropout,
    dim: usize,
}

impl TransformerEncoderLayer {
    /// Standard block: `mlp_dim` is usually `4*dim`.
    pub fn new(dim: usize, heads: usize, mlp_dim: usize, dropout: f64, causal: bool) -> Self {
        TransformerEncoderLayer {
            attn: MultiheadAttention::new(dim, heads, causal),
            fc1: Linear::new(dim, mlp_dim),
            fc2: Linear::new(mlp_dim, dim),
            ln1: LayerNorm::new(dim),
            ln2: LayerNorm::new(dim),
            drop: Dropout::new(dropout),
            dim,
        }
    }

    /// Forward new positions `[B, L_new, D]` against this layer's KV
    /// cache (see [`MultiheadAttention::forward_cached`]); everything
    /// outside attention is position-wise, so only the attention core
    /// needs the past. Run the layer in eval mode (dropout off) — a
    /// random mask over only the new positions would not match a full
    /// recompute.
    pub fn forward_cached(&self, input: &Variable, cache: &mut KvCache) -> Variable {
        let a = self.attn.forward_cached(&self.ln1.forward(input), cache);
        let x = ops::add(input, &self.drop.forward(&a));
        let h = self.fc2.forward(&ops::gelu(&self.fc1.forward(&self.ln2.forward(&x))));
        ops::add(&x, &self.drop.forward(&h))
    }

    /// [`Self::forward_cached`] against one request's paged cache (this
    /// block's keys/values live under index `layer` in the page layout).
    pub fn forward_paged(
        &self,
        input: &Variable,
        cache: &mut PagedKvCache,
        layer: usize,
    ) -> Variable {
        let a = self.attn.forward_paged(&self.ln1.forward(input), cache, layer);
        let x = ops::add(input, &self.drop.forward(&a));
        let h = self.fc2.forward(&ops::gelu(&self.fc1.forward(&self.ln2.forward(&x))));
        ops::add(&x, &self.drop.forward(&h))
    }

    /// One decode step for `B` different requests (see
    /// [`MultiheadAttention::forward_decode_batch`]): the position-wise
    /// pieces (norms, MLP, residuals) batch across rows bitwise; only the
    /// attention core runs per request.
    pub fn forward_decode_batch(
        &self,
        input: &Variable,
        caches: &mut [&mut PagedKvCache],
        layer: usize,
    ) -> Variable {
        let b = input.dims()[0];
        let (q, k, v) = self.decode_attn_in(input, b);
        let ctx = self.attn.decode_cores(&q.tensor(), &k.tensor(), &v.tensor(), caches, layer);
        self.decode_attn_out(input, &Variable::constant(ctx), b)
    }

    /// Row-independent prefix of this layer's decode step: pre-norm plus
    /// Q/K/V projection/split. Traced by `serve::CompiledDecodeStep` and
    /// run verbatim by the eager [`Self::forward_decode_batch`] — shared
    /// code is what makes compiled-vs-eager parity structural rather than
    /// coincidental.
    pub(crate) fn decode_attn_in(&self, x: &Variable, b: usize) -> (Variable, Variable, Variable) {
        self.attn.decode_qkv(&self.ln1.forward(x), b)
    }

    /// Row-independent suffix of this layer's decode step: output
    /// projection of the attention contexts, attention residual, MLP, MLP
    /// residual. Counterpart of [`Self::decode_attn_in`].
    pub(crate) fn decode_attn_out(&self, input: &Variable, ctx: &Variable, b: usize) -> Variable {
        let a = self.attn.decode_out(ctx, b);
        let x = ops::add(input, &self.drop.forward(&a));
        let h = self.fc2.forward(&ops::gelu(&self.fc1.forward(&self.ln2.forward(&x))));
        ops::add(&x, &self.drop.forward(&h))
    }
}

impl Module for TransformerEncoderLayer {
    fn forward(&self, input: &Variable) -> Variable {
        let a = self.attn.forward(&self.ln1.forward(input));
        let x = ops::add(input, &self.drop.forward(&a));
        let h = self.fc2.forward(&ops::gelu(&self.fc1.forward(&self.ln2.forward(&x))));
        ops::add(&x, &self.drop.forward(&h))
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.attn.params();
        p.extend(self.fc1.params());
        p.extend(self.fc2.params());
        p.extend(self.ln1.params());
        p.extend(self.ln2.params());
        p
    }

    fn set_train(&mut self, train: bool) {
        self.drop.set_train(train);
    }

    fn name(&self) -> String {
        format!("TransformerEncoderLayer(d={})", self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn block_preserves_shape() {
        let mut blk = TransformerEncoderLayer::new(16, 4, 32, 0.0, false);
        blk.set_train(false);
        let x = Variable::constant(Tensor::rand([2, 6, 16], -1.0, 1.0));
        assert_eq!(blk.forward(&x).dims(), vec![2, 6, 16]);
    }

    #[test]
    fn positional_embedding_adds() {
        let pe = PositionalEmbedding::new(8, 4);
        pe.weight.set_tensor(Tensor::ones([8, 4]));
        let x = Variable::constant(Tensor::zeros([2, 3, 4]));
        let y = pe.forward(&x).tensor();
        assert_eq!(y.to_vec(), vec![1.0; 24]);
    }

    #[test]
    fn forward_at_each_rows_match_forward_at_bitwise() {
        let pe = PositionalEmbedding::new(8, 4);
        let x = Tensor::rand([3, 1, 4], -1.0, 1.0);
        let offsets = [5usize, 0, 7];
        let batched = pe
            .forward_at_each(&Variable::constant(x.clone()), &offsets)
            .tensor()
            .to_vec();
        for (i, &o) in offsets.iter().enumerate() {
            let solo = pe
                .forward_at(&Variable::constant(x.narrow(0, i, 1)), o)
                .tensor()
                .to_vec();
            let row = &batched[i * 4..(i + 1) * 4];
            let same = row.iter().zip(&solo).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "row {i} at offset {o} diverged from forward_at");
        }
    }

    #[test]
    fn full_block_gradients() {
        let blk = TransformerEncoderLayer::new(8, 2, 16, 0.0, true);
        let x = Variable::constant(Tensor::rand([1, 4, 8], -1.0, 1.0));
        ops::sum(&blk.forward(&x), &[], false).backward();
        let n_with_grad = blk.params().iter().filter(|p| p.grad().is_some()).count();
        assert_eq!(n_with_grad, blk.params().len());
    }
}
