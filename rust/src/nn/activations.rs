//! Activation modules (thin wrappers over [`crate::autograd::ops`]; the
//! underlying tensor ops are themselves compositions of backend
//! primitives, e.g. ReLU = `maximum(x, 0)` per paper §4.1.1).

use crate::autograd::{ops, Variable};

use super::Module;

macro_rules! activation {
    ($(#[$doc:meta])* $name:ident, $op:expr) => {
        $(#[$doc])*
        pub struct $name;

        impl Module for $name {
            fn forward(&self, input: &Variable) -> Variable {
                $op(input)
            }
            fn params(&self) -> Vec<Variable> {
                Vec::new()
            }
            fn name(&self) -> String {
                stringify!($name).to_string()
            }
        }
    };
}

activation!(
    /// Rectified linear unit.
    ReLU,
    ops::relu
);
activation!(
    /// Exact GELU.
    GELU,
    ops::gelu
);
activation!(
    /// Hyperbolic tangent.
    Tanh,
    ops::tanh
);
activation!(
    /// Logistic sigmoid.
    Sigmoid,
    ops::sigmoid
);

/// Log-softmax over the last dimension (classifier heads, paper Listing 8).
pub struct LogSoftmax;

impl Module for LogSoftmax {
    fn forward(&self, input: &Variable) -> Variable {
        ops::log_softmax(input, -1)
    }
    fn params(&self) -> Vec<Variable> {
        Vec::new()
    }
    fn name(&self) -> String {
        "LogSoftmax".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn activations_apply() {
        let x = Variable::constant(Tensor::from_slice(&[-1.0f32, 0.0, 2.0], [3]));
        assert_eq!(ReLU.forward(&x).tensor().to_vec(), vec![0.0, 0.0, 2.0]);
        let s = Sigmoid.forward(&x).tensor().to_vec();
        assert!((s[1] - 0.5).abs() < 1e-6);
        let t = Tanh.forward(&x).tensor().to_vec();
        assert!((t[2] - 2.0f32.tanh()).abs() < 1e-6);
        let g = GELU.forward(&x).tensor().to_vec();
        assert!((g[2] - 1.9545977).abs() < 1e-4); // reference value
    }

    #[test]
    fn log_softmax_normalizes() {
        let x = Variable::constant(Tensor::rand([2, 5], -2.0, 2.0));
        let y = LogSoftmax.forward(&x).tensor();
        let sums = y.exp().sum(&[-1], false).to_vec();
        for s in sums {
            assert!((s - 1.0).abs() < 1e-5);
        }
    }
}
