//! Multi-head scaled-dot-product attention.
//!
//! The softmax(QKᵀ/√d)V core probes the backend's `call_ext("attention")`
//! extension first — on the AOT/XLA backend that dispatches to the
//! Pallas-authored fused kernel — and falls back to primitive composition
//! everywhere else (inference path; training always uses the composed
//! graph so the tape sees every op).
//!
//! For autoregressive serving, [`MultiheadAttention::forward_cached`]
//! threads a per-layer [`KvCache`]: each new token's query attends over
//! the cached keys/values of every earlier position instead of
//! recomputing the whole prefix, turning an O(L²)-per-token decode into
//! O(L). The cached path is **bit-identical** to the full recompute on
//! the reference CPU backend (`rust/tests/serve.rs` pins this down over
//! 64 generated tokens).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::autograd::{ops, Variable};
use crate::memory::{KvPage, KvPagePool, PoolExhausted};
use crate::tensor::Tensor;

use super::linear::Linear;
use super::Module;

/// Per-layer key/value cache for incremental decoding. Keys and values
/// are stored merged-head-major, `[B*H, len, head_dim]` — exactly the
/// layout [`MultiheadAttention::sdpa`] consumes, so appending is a single
/// `concat` along the position axis and no re-layout happens per step.
#[derive(Default)]
pub struct KvCache {
    k: Option<Tensor>,
    v: Option<Tensor>,
    len: usize,
}

impl KvCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Positions cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether any position is cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append `[B*H, l_new, head_dim]` keys/values and return the full
    /// (past + new) tensors. On an empty cache this is a handle clone, so
    /// prefill stores and reuses the very tensors the forward computed.
    pub fn append(&mut self, k_new: &Tensor, v_new: &Tensor) -> (Tensor, Tensor) {
        let (k_all, v_all) = match (&self.k, &self.v) {
            (Some(k), Some(v)) => {
                (Tensor::concat(&[k, k_new], 1), Tensor::concat(&[v, v_new], 1))
            }
            _ => (k_new.clone(), v_new.clone()),
        };
        self.len += k_new.dim(1);
        self.k = Some(k_all.clone());
        self.v = Some(v_all.clone());
        (k_all, v_all)
    }

    /// Drop all cached positions (start a fresh sequence).
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// Per-*request* KV cache backed by fixed-size pages leased from a shared
/// [`KvPagePool`] — the indirection layer that lets the continuous
/// batcher admit and retire sequences every token without moving anyone
/// else's memory.
///
/// Where [`KvCache`] stores one contiguous `[B*H, len, hd]` tensor per
/// layer that grows by concat-append, a `PagedKvCache` owns a page table:
/// logical KV position `p` lives in page `p / page_tokens` at slot
/// `p % page_tokens`, and one page holds that slot range for *every*
/// layer and head (see [`crate::memory::KvPoolConfig::run_offset`]).
/// Dropping the cache releases its lease, so retirement frees memory
/// immediately. The gathered per-layer tensors are bit-copies of what the
/// contiguous cache would hold — `rust/src/nn/attention.rs` tests pin the
/// two layouts against each other bitwise.
pub struct PagedKvCache {
    pool: Arc<KvPagePool>,
    pages: Vec<KvPage>,
    len: usize,
}

impl PagedKvCache {
    /// Empty cache leasing from `pool`.
    pub fn new(pool: Arc<KvPagePool>) -> Self {
        PagedKvCache { pool, pages: Vec::new(), len: 0 }
    }

    /// Positions written so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether any position has been written.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions the currently leased pages can hold.
    pub fn capacity(&self) -> usize {
        self.pages.len() * self.pool.config().page_tokens
    }

    /// Pages currently leased.
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// The pool this cache leases from.
    pub fn pool(&self) -> &Arc<KvPagePool> {
        &self.pool
    }

    /// Ensure capacity for `total_positions` logical positions, leasing
    /// additional pages as needed. All-or-nothing: on [`PoolExhausted`]
    /// nothing was leased and the cache is unchanged — the scheduler's
    /// backpressure signal. Reserving a request's worst case (prompt +
    /// max new tokens) at admission means decode can never die mid-flight
    /// from a failed page grab.
    pub fn reserve(&mut self, total_positions: usize) -> Result<(), PoolExhausted> {
        let need = self.pool.config().pages_for(total_positions);
        if need > self.pages.len() {
            let extra = self.pool.lease(need - self.pages.len())?;
            self.pages.extend(extra);
        }
        Ok(())
    }

    /// Write `[H, l_new, hd]` keys/values for `layer` at logical
    /// positions `base .. base + l_new`. Capacity must already be
    /// reserved. The per-layer write does *not* advance [`Self::len`] —
    /// every layer of one forward writes at the same base, and the model
    /// calls [`Self::advance`] once after the layer stack.
    pub fn write_layer(&mut self, layer: usize, base: usize, k_new: &Tensor, v_new: &Tensor) {
        let cfg = *self.pool.config();
        assert!(layer < cfg.layers, "layer {layer} out of range {}", cfg.layers);
        let dims = k_new.dims().to_vec();
        assert_eq!(dims.len(), 3, "paged write wants [H, l_new, hd]");
        assert_eq!(dims[0], cfg.heads, "head count mismatch");
        assert_eq!(dims[2], cfg.head_dim, "head width mismatch");
        assert_eq!(v_new.dims(), dims, "K and V must agree in shape");
        let (h, l_new, hd) = (dims[0], dims[1], dims[2]);
        assert!(
            base + l_new <= self.capacity(),
            "write beyond reserved capacity: {} + {} > {}",
            base,
            l_new,
            self.capacity()
        );
        for (which, data) in [k_new.to_vec(), v_new.to_vec()].iter().enumerate() {
            for head in 0..h {
                for t in 0..l_new {
                    let pos = base + t;
                    let (page, slot) = (pos / cfg.page_tokens, pos % cfg.page_tokens);
                    let off = cfg.run_offset(layer, which, head, slot);
                    let src = &data[(head * l_new + t) * hd..(head * l_new + t + 1) * hd];
                    self.pages[page].data_mut()[off..off + hd].copy_from_slice(src);
                }
            }
        }
    }

    /// Commit `l_new` freshly written positions (once per model forward,
    /// after every layer wrote at the old length).
    pub fn advance(&mut self, l_new: usize) {
        self.len += l_new;
        debug_assert!(self.len <= self.capacity(), "advance beyond reserved capacity");
    }

    /// Materialize `layer`'s keys/values over positions `0 .. len` as
    /// contiguous `[H, len, hd]` tensors — bit-copies of what the
    /// concat-append [`KvCache`] would hold, so attention downstream of a
    /// gather cannot tell the layouts apart.
    pub fn gather_layer(&self, layer: usize, len: usize) -> (Tensor, Tensor) {
        let cfg = *self.pool.config();
        assert!(len <= self.capacity(), "gather beyond reserved capacity");
        let (h, hd) = (cfg.heads, cfg.head_dim);
        let mut out = [vec![0.0f32; h * len * hd], vec![0.0f32; h * len * hd]];
        for (which, data) in out.iter_mut().enumerate() {
            for head in 0..h {
                for t in 0..len {
                    let (page, slot) = (t / cfg.page_tokens, t % cfg.page_tokens);
                    let off = cfg.run_offset(layer, which, head, slot);
                    let dst = &mut data[(head * len + t) * hd..(head * len + t + 1) * hd];
                    dst.copy_from_slice(&self.pages[page].data()[off..off + hd]);
                }
            }
        }
        let [k, v] = out;
        (Tensor::from_slice(&k, [h, len, hd]), Tensor::from_slice(&v, [h, len, hd]))
    }

    /// Release every page and forget all positions.
    pub fn reset(&mut self) {
        self.pages.clear();
        self.len = 0;
    }
}

impl std::fmt::Debug for PagedKvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PagedKvCache(len={}, pages={})", self.len, self.pages.len())
    }
}

/// Multi-head self-attention with optional causal masking.
pub struct MultiheadAttention {
    /// Q/K/V projections.
    pub wq: Linear,
    /// Key projection.
    pub wk: Linear,
    /// Value projection.
    pub wv: Linear,
    /// Output projection.
    pub wo: Linear,
    heads: usize,
    dim: usize,
    causal: bool,
    /// Additive causal bias tensors keyed by `(q_len, past_len)`, built
    /// once per shape instead of re-deriving the `-1e9` mask from
    /// `tril_mask` on every forward.
    bias_cache: Mutex<HashMap<(usize, usize), Tensor>>,
}

impl MultiheadAttention {
    /// `dim` must be divisible by `heads`.
    pub fn new(dim: usize, heads: usize, causal: bool) -> Self {
        assert_eq!(dim % heads, 0, "dim {dim} not divisible by heads {heads}");
        MultiheadAttention {
            wq: Linear::new(dim, dim),
            wk: Linear::new(dim, dim),
            wv: Linear::new(dim, dim),
            wo: Linear::new(dim, dim),
            heads,
            dim,
            causal,
            bias_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Whether this attention applies a causal mask.
    pub fn is_causal(&self) -> bool {
        self.causal
    }

    /// Number of attention heads.
    pub fn heads(&self) -> usize {
        self.heads
    }

    /// Per-head feature width.
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// The additive causal bias for `q_len` query rows whose global
    /// positions start at `past_len`: entry `(i, j)` is `-0.0` where query
    /// `past_len + i` may attend key `j` and `-1e9` where it may not
    /// (matching the bits of the historical `(1 - tril) * -1e9`
    /// construction, whose allowed entries were `0.0 * -1e9 = -0.0`).
    /// Built once per shape and cached.
    fn causal_bias(&self, q_len: usize, past_len: usize) -> Tensor {
        // Retained shapes per module. Training and bucketed serving see a
        // handful; only a server fed organically varied prompt lengths
        // would otherwise accumulate O(Σ L²) dense masks without bound.
        const BIAS_CACHE_CAP: usize = 64;
        let mut cache = self.bias_cache.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(t) = cache.get(&(q_len, past_len)) {
            return t.clone();
        }
        let kv_len = past_len + q_len;
        let mut data = vec![0.0f32; q_len * kv_len];
        for (i, row) in data.chunks_mut(kv_len).enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = if j <= past_len + i { -0.0 } else { -1e9 };
            }
        }
        let t = Tensor::from_slice(&data, [q_len, kv_len]);
        if cache.len() >= BIAS_CACHE_CAP {
            cache.clear();
        }
        cache.insert((q_len, past_len), t.clone());
        t
    }

    /// Split `[B, L, D]` into `[B*H, L, D/H]`.
    fn split_heads(&self, x: &Variable, b: usize, l: usize) -> Variable {
        let hd = self.dim / self.heads;
        let x = ops::reshape(x, &[b as isize, l as isize, self.heads as isize, hd as isize]);
        let x = ops::transpose(&x, &[0, 2, 1, 3]);
        ops::reshape(&x, &[(b * self.heads) as isize, l as isize, hd as isize])
    }

    /// Inverse of `split_heads`.
    fn merge_heads(&self, x: &Variable, b: usize, l: usize) -> Variable {
        let hd = self.dim / self.heads;
        let x = ops::reshape(x, &[b as isize, self.heads as isize, l as isize, hd as isize]);
        let x = ops::transpose(&x, &[0, 2, 1, 3]);
        ops::reshape(&x, &[b as isize, l as isize, self.dim as isize])
    }

    /// Scaled-dot-product core over `[B*H, L, hd]` tensors.
    pub fn sdpa(&self, q: &Variable, k: &Variable, v: &Variable, l: usize) -> Variable {
        self.sdpa_with_past(q, k, v, l, 0)
    }

    /// Scaled-dot-product with a key/value *past*: `q` holds the trailing
    /// `q_len` positions (`[B*H, q_len, hd]`) while `k`/`v` cover all
    /// `past_len + q_len` positions. With `past_len == 0` this is the
    /// classic full-sequence core; with a non-zero past it is the
    /// KV-cached incremental decode step, where each new query attends
    /// over cached keys instead of recomputing the prefix.
    pub fn sdpa_with_past(
        &self,
        q: &Variable,
        k: &Variable,
        v: &Variable,
        q_len: usize,
        past_len: usize,
    ) -> Variable {
        let hd = self.dim / self.heads;
        let scale = 1.0 / (hd as f64).sqrt();
        let scores = ops::mul_scalar(&ops::matmul(q, &ops::t(k)), scale);
        // a single trailing query row may attend every key, so its bias
        // row is all `-0.0` — an additive bitwise no-op we skip entirely
        // (this is what keeps cached decode bit-identical to recompute)
        let scores = if self.causal && q_len > 1 {
            let bias = self.causal_bias(q_len, past_len);
            ops::add(&scores, &Variable::constant(bias))
        } else {
            scores
        };
        let attn = ops::softmax(&scores, -1);
        ops::matmul(&attn, v)
    }

    /// Forward one or more *new* positions `[B, L_new, D]` against the
    /// cached past, appending this call's keys/values to `cache`. An empty
    /// cache makes this the prefill pass (identical to
    /// [`Module::forward`]); a one-token input is the steady-state decode
    /// step. Requires causal attention — with bidirectional attention
    /// earlier positions would need recomputing anyway.
    pub fn forward_cached(&self, input: &Variable, cache: &mut KvCache) -> Variable {
        assert!(self.causal, "KV-cached attention requires causal masking");
        let dims = input.dims();
        assert_eq!(dims.len(), 3, "attention wants [B, L, D]");
        let (b, l_new) = (dims[0], dims[1]);
        let past = cache.len();
        let q = self.split_heads(&self.wq.forward(input), b, l_new);
        let k = self.split_heads(&self.wk.forward(input), b, l_new);
        let v = self.split_heads(&self.wv.forward(input), b, l_new);
        let (k_all, v_all) = cache.append(&k.tensor(), &v.tensor());
        let ctx = self.sdpa_with_past(
            &q,
            &Variable::constant(k_all),
            &Variable::constant(v_all),
            l_new,
            past,
        );
        self.wo.forward(&self.merge_heads(&ctx, b, l_new))
    }

    /// [`Self::forward_cached`] against a paged cache: forward one
    /// request's new positions `[1, L_new, D]`, writing this call's
    /// keys/values into `cache`'s pages for `layer` and attending over a
    /// gather of the full past. Bit-identical to the contiguous cached
    /// path — the gather reproduces the concat-append layout exactly.
    /// Per-request by construction (`B == 1`): prefill lengths differ per
    /// request, so prefill never batches across requests.
    pub fn forward_paged(
        &self,
        input: &Variable,
        cache: &mut PagedKvCache,
        layer: usize,
    ) -> Variable {
        assert!(self.causal, "KV-cached attention requires causal masking");
        let dims = input.dims();
        assert_eq!(dims.len(), 3, "attention wants [B, L, D]");
        let (b, l_new) = (dims[0], dims[1]);
        assert_eq!(b, 1, "the paged prefill/decode path is per-request");
        let past = cache.len();
        let q = self.split_heads(&self.wq.forward(input), b, l_new);
        let k = self.split_heads(&self.wk.forward(input), b, l_new);
        let v = self.split_heads(&self.wv.forward(input), b, l_new);
        cache.write_layer(layer, past, &k.tensor(), &v.tensor());
        let (k_all, v_all) = cache.gather_layer(layer, past + l_new);
        let ctx = self.sdpa_with_past(
            &q,
            &Variable::constant(k_all),
            &Variable::constant(v_all),
            l_new,
            past,
        );
        self.wo.forward(&self.merge_heads(&ctx, b, l_new))
    }

    /// One decode step for `B` *different* requests at once — the
    /// continuous batcher's inner loop. `input` is `[B, 1, D]`, row `i`
    /// belonging to the request behind `caches[i]` (each at its own past
    /// length). The row-independent projections (Q/K/V, output) run
    /// batched; the attention core runs per request over that request's
    /// gathered pages, because the KV lengths differ. Row `i`'s output is
    /// bit-identical to running the request alone: the projections are
    /// row-independent bitwise (the batch-parity contract
    /// `rust/tests/serve.rs` pins for the whole stack) and the per-row
    /// attention sees exactly the solo operands.
    pub fn forward_decode_batch(
        &self,
        input: &Variable,
        caches: &mut [&mut PagedKvCache],
        layer: usize,
    ) -> Variable {
        assert!(self.causal, "KV-cached attention requires causal masking");
        let dims = input.dims();
        assert_eq!(dims.len(), 3, "attention wants [B, L, D]");
        let (b, l_new) = (dims[0], dims[1]);
        assert_eq!(l_new, 1, "iteration-level decode steps one token per sequence");
        assert_eq!(b, caches.len(), "one KV cache per batch row");
        let (q, k, v) = self.decode_qkv(input, b);
        let ctx = self.decode_cores(&q.tensor(), &k.tensor(), &v.tensor(), caches, layer);
        self.decode_out(&Variable::constant(ctx), b)
    }

    /// The row-independent half of a batched decode step that *precedes*
    /// attention: Q/K/V projections plus head split over `[B, 1, D]`,
    /// yielding `[B*H, 1, hd]` each. Pure tensor math — this is one of
    /// the pieces `serve::CompiledDecodeStep` traces per batch-size
    /// bucket, and the eager [`Self::forward_decode_batch`] runs the
    /// exact same ops through it, which is what keeps the compiled and
    /// eager decode paths bitwise identical by construction.
    pub(crate) fn decode_qkv(&self, input: &Variable, b: usize) -> (Variable, Variable, Variable) {
        let q = self.split_heads(&self.wq.forward(input), b, 1);
        let k = self.split_heads(&self.wk.forward(input), b, 1);
        let v = self.split_heads(&self.wv.forward(input), b, 1);
        (q, k, v)
    }

    /// The per-request attention cores of a batched decode step: for each
    /// row, append this step's K/V to that request's pages, gather its
    /// full past, and run the SDPA core at its own past length. KV
    /// lengths and page tables live only here — never inside a traced
    /// program — so varying them can never force a re-trace. Returns the
    /// concatenated contexts `[B*H, 1, hd]`.
    pub(crate) fn decode_cores(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        caches: &mut [&mut PagedKvCache],
        layer: usize,
    ) -> Tensor {
        assert!(self.causal, "KV-cached attention requires causal masking");
        let h = self.heads;
        // `>=`, not `==`: a compiled decode step padded up to its bucket
        // size has more Q rows than live caches; the pad rows never reach
        // an attention core.
        assert!(q.dims()[0] >= caches.len() * h, "decode cores: fewer Q rows than KV caches");
        let mut ctx_rows: Vec<Tensor> = Vec::with_capacity(caches.len());
        for (i, cache) in caches.iter_mut().enumerate() {
            let past = cache.len();
            let qi = q.narrow(0, i * h, h);
            let ki = k.narrow(0, i * h, h);
            let vi = v.narrow(0, i * h, h);
            cache.write_layer(layer, past, &ki, &vi);
            let (k_all, v_all) = cache.gather_layer(layer, past + 1);
            let ctx = self.sdpa_with_past(
                &Variable::constant(qi),
                &Variable::constant(k_all),
                &Variable::constant(v_all),
                1,
                past,
            );
            ctx_rows.push(ctx.tensor());
        }
        let refs: Vec<&Tensor> = ctx_rows.iter().collect();
        Tensor::concat(&refs, 0)
    }

    /// The row-independent half of a batched decode step that *follows*
    /// attention: head merge plus output projection over the concatenated
    /// contexts. Counterpart of [`Self::decode_qkv`]; also traced by
    /// `serve::CompiledDecodeStep`.
    pub(crate) fn decode_out(&self, ctx: &Variable, b: usize) -> Variable {
        self.wo.forward(&self.merge_heads(ctx, b, 1))
    }
}

impl Module for MultiheadAttention {
    fn forward(&self, input: &Variable) -> Variable {
        let dims = input.dims();
        assert_eq!(dims.len(), 3, "attention wants [B, L, D]");
        let (b, l) = (dims[0], dims[1]);
        let q = self.split_heads(&self.wq.forward(input), b, l);
        let k = self.split_heads(&self.wk.forward(input), b, l);
        let v = self.split_heads(&self.wv.forward(input), b, l);
        let ctx = self.sdpa(&q, &k, &v, l);
        self.wo.forward(&self.merge_heads(&ctx, b, l))
    }

    fn params(&self) -> Vec<Variable> {
        [&self.wq, &self.wk, &self.wv, &self.wo].iter().flat_map(|m| m.params()).collect()
    }

    fn name(&self) -> String {
        format!("MultiheadAttention(d={}, h={}, causal={})", self.dim, self.heads, self.causal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn shapes_roundtrip() {
        let m = MultiheadAttention::new(16, 4, false);
        let x = Variable::constant(Tensor::rand([2, 5, 16], -1.0, 1.0));
        let y = m.forward(&x);
        assert_eq!(y.dims(), vec![2, 5, 16]);
        assert_eq!(m.params().len(), 8);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // with causal masking, output at position 0 must not depend on
        // later positions
        let m = MultiheadAttention::new(8, 2, true);
        let base = Tensor::rand([1, 4, 8], -1.0, 1.0);
        let y1 = m.forward(&Variable::constant(base.clone())).tensor().to_vec();
        // perturb the last position only
        let mut v = base.to_vec();
        for x in v[24..32].iter_mut() {
            *x += 10.0;
        }
        let y2 = m
            .forward(&Variable::constant(Tensor::from_slice(&v, [1, 4, 8])))
            .tensor()
            .to_vec();
        for i in 0..8 {
            assert!((y1[i] - y2[i]).abs() < 1e-5, "position 0 leaked future info");
        }
        // but the last position must change
        let tail_moved = (0..8).any(|i| (y1[24 + i] - y2[24 + i]).abs() > 1e-4);
        assert!(tail_moved);
    }

    #[test]
    fn gradients_reach_all_projections() {
        let m = MultiheadAttention::new(8, 2, false);
        let x = Variable::constant(Tensor::rand([1, 3, 8], -1.0, 1.0));
        ops::sum(&m.forward(&x), &[], false).backward();
        for p in m.params() {
            assert!(p.grad().is_some());
        }
    }

    #[test]
    fn attention_gradcheck() {
        use crate::testutil::gradcheck::check_grad_tol;
        // module built once outside the closure: gradcheck re-evaluates f
        // for numeric differencing, so the (random-initialized) weights
        // must stay fixed across calls
        let m = MultiheadAttention::new(4, 2, true);
        check_grad_tol("attention", &[1, 3, 4], 1e-4, 1e-2, |x| {
            ops::sum(&m.forward(x), &[], false)
        });
    }

    #[test]
    fn sdpa_core_gradcheck() {
        use crate::autograd::ops::{matmul, sum};
        use crate::testutil::gradcheck::check_grad_tol;
        let m = MultiheadAttention::new(4, 1, false);
        // grad through softmax(QK^T/sqrt(d))V with Q=K=V derived from x
        check_grad_tol("sdpa", &[1, 3, 4], 1e-4, 1e-2, |x| {
            let w = Variable::constant(Tensor::eye(4, DType::F64));
            let q = matmul(x, &w);
            sum(&m.sdpa(&q, x, x, 3), &[], false)
        });
    }

    fn bits(v: &Variable) -> Vec<u32> {
        v.tensor().to_vec().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn kv_cached_forward_is_bit_identical_to_full() {
        let m = MultiheadAttention::new(8, 2, true);
        let x = Tensor::rand([1, 5, 8], -1.0, 1.0);
        let full = m.forward(&Variable::constant(x.clone()));

        // prefill: the whole sequence through the cached path at once
        let mut cache = KvCache::new();
        let prefill = m.forward_cached(&Variable::constant(x.clone()), &mut cache);
        assert_eq!(bits(&full), bits(&prefill), "prefill must equal the full forward");
        assert_eq!(cache.len(), 5);

        // incremental: one position at a time through a fresh cache
        let mut cache = KvCache::new();
        let full_bits = bits(&full);
        for t in 0..5 {
            let step = x.narrow(1, t, 1);
            let y = m.forward_cached(&Variable::constant(step), &mut cache);
            assert_eq!(
                bits(&y),
                full_bits[t * 8..(t + 1) * 8].to_vec(),
                "cached decode diverged at position {t}"
            );
        }
        assert_eq!(cache.len(), 5);
        cache.reset();
        assert!(cache.is_empty());
    }

    #[test]
    fn causal_bias_is_cached_per_shape() {
        let m = MultiheadAttention::new(8, 2, true);
        let x = Variable::constant(Tensor::rand([1, 4, 8], -1.0, 1.0));
        let _ = m.forward(&x);
        let _ = m.forward(&x);
        assert_eq!(m.bias_cache.lock().unwrap().len(), 1, "same shape must hit the cache");
        let y = Variable::constant(Tensor::rand([1, 6, 8], -1.0, 1.0));
        let _ = m.forward(&y);
        assert_eq!(m.bias_cache.lock().unwrap().len(), 2, "new shape adds one entry");
        // the cached bias matches the historical (1 - tril) * -1e9 bits
        let bias = m.causal_bias(4, 0);
        let legacy = Tensor::tril_mask(4)
            .astype(DType::F32)
            .neg()
            .add_scalar(1.0)
            .mul_scalar(-1e9);
        let (a, b) = (bias.to_vec(), legacy.to_vec());
        let eq = a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "cached bias bits drifted from the legacy construction");
    }

    fn test_pool(
        layers: usize,
        heads: usize,
        head_dim: usize,
        page_tokens: usize,
        max_pages: usize,
    ) -> Arc<KvPagePool> {
        KvPagePool::new(crate::memory::KvPoolConfig {
            layers,
            heads,
            head_dim,
            page_tokens,
            max_pages,
        })
    }

    #[test]
    fn paged_write_gather_matches_contiguous_reference() {
        // property-check the page-table address math against the naive
        // contiguous layout: random-sized appends through both, gather
        // must reproduce the concat bits exactly (page size 3 forces
        // writes and reads to straddle page boundaries)
        let pool = test_pool(2, 2, 4, 3, 8);
        let mut paged = PagedKvCache::new(Arc::clone(&pool));
        paged.reserve(11).unwrap();
        // [layer] -> appended K chunks (V in vref)
        let mut reference: Vec<Vec<Tensor>> = vec![Vec::new(), Vec::new()];
        let mut vref: Vec<Vec<Tensor>> = vec![Vec::new(), Vec::new()];
        let mut len = 0usize;
        for &l_new in &[1usize, 2, 5, 3] {
            for layer in 0..2 {
                let k = Tensor::rand([2, l_new, 4], -1.0, 1.0);
                let v = Tensor::rand([2, l_new, 4], -1.0, 1.0);
                paged.write_layer(layer, len, &k, &v);
                reference[layer].push(k);
                vref[layer].push(v);
            }
            paged.advance(l_new);
            len += l_new;
            for layer in 0..2 {
                let (kg, vg) = paged.gather_layer(layer, len);
                let kcat = Tensor::concat(&reference[layer].iter().collect::<Vec<_>>(), 1);
                let vcat = Tensor::concat(&vref[layer].iter().collect::<Vec<_>>(), 1);
                assert_eq!(kg.dims(), vec![2, len, 4]);
                let same = |a: &Tensor, b: &Tensor| {
                    a.to_vec().iter().zip(b.to_vec().iter()).all(|(x, y): (&f32, &f32)| {
                        x.to_bits() == y.to_bits()
                    })
                };
                assert!(same(&kg, &kcat), "K gather diverged at len {len} layer {layer}");
                assert!(same(&vg, &vcat), "V gather diverged at len {len} layer {layer}");
            }
        }
        assert_eq!(paged.len(), 11);
        assert_eq!(paged.pages_held(), 4);
        paged.reset();
        assert_eq!(pool.stats().leased_pages, 0);
    }

    #[test]
    fn paged_forward_is_bit_identical_to_contiguous_cached() {
        let m = MultiheadAttention::new(8, 2, true);
        let x = Tensor::rand([1, 7, 8], -1.0, 1.0);
        let pool = test_pool(1, 2, 4, 2, 8);

        // prefill-then-steps through the contiguous cache
        let mut cc = KvCache::new();
        let mut contiguous: Vec<Vec<u32>> = Vec::new();
        // prefill 4, then 3 single-token steps
        contiguous.push(bits(&m.forward_cached(&Variable::constant(x.narrow(1, 0, 4)), &mut cc)));
        for t in 4..7 {
            contiguous
                .push(bits(&m.forward_cached(&Variable::constant(x.narrow(1, t, 1)), &mut cc)));
        }

        // same schedule through the paged cache
        let mut pc = PagedKvCache::new(pool);
        pc.reserve(7).unwrap();
        let mut paged: Vec<Vec<u32>> = Vec::new();
        paged.push(bits(&m.forward_paged(&Variable::constant(x.narrow(1, 0, 4)), &mut pc, 0)));
        pc.advance(4);
        for t in 4..7 {
            paged.push(bits(&m.forward_paged(&Variable::constant(x.narrow(1, t, 1)), &mut pc, 0)));
            pc.advance(1);
        }
        assert_eq!(contiguous, paged, "paged attention diverged from the contiguous cache");
        assert_eq!(pc.len(), 7);
    }

    #[test]
    fn decode_batch_rows_are_bit_identical_to_solo_decode() {
        // two requests at different past lengths, stepped together through
        // forward_decode_batch, must match each one stepped alone
        let m = MultiheadAttention::new(8, 2, true);
        let pool = test_pool(1, 2, 4, 2, 16);
        let a = Tensor::rand([1, 5, 8], -1.0, 1.0); // request A: past 4, step 1
        let b = Tensor::rand([1, 3, 8], -1.0, 1.0); // request B: past 2, step 1

        let solo = |prompt: &Tensor| {
            let l = prompt.dim(1);
            let mut c = PagedKvCache::new(test_pool(1, 2, 4, 2, 16));
            c.reserve(l).unwrap();
            let _ = m.forward_paged(&Variable::constant(prompt.narrow(1, 0, l - 1)), &mut c, 0);
            c.advance(l - 1);
            let y = m.forward_paged(&Variable::constant(prompt.narrow(1, l - 1, 1)), &mut c, 0);
            bits(&y)
        };
        let solo_a = solo(&a);
        let solo_b = solo(&b);

        let mut ca = PagedKvCache::new(Arc::clone(&pool));
        let mut cb = PagedKvCache::new(Arc::clone(&pool));
        ca.reserve(5).unwrap();
        cb.reserve(3).unwrap();
        let _ = m.forward_paged(&Variable::constant(a.narrow(1, 0, 4)), &mut ca, 0);
        ca.advance(4);
        let _ = m.forward_paged(&Variable::constant(b.narrow(1, 0, 2)), &mut cb, 0);
        cb.advance(2);
        // batch the two final steps: rows [A_step; B_step]
        let step = Tensor::concat(&[&a.narrow(1, 4, 1), &b.narrow(1, 2, 1)], 0);
        let mut caches: Vec<&mut PagedKvCache> = vec![&mut ca, &mut cb];
        let y = m.forward_decode_batch(&Variable::constant(step), &mut caches, 0);
        ca.advance(1);
        cb.advance(1);
        let yb = bits(&y);
        assert_eq!(&yb[..8], &solo_a[..], "batched row A diverged from solo decode");
        assert_eq!(&yb[8..], &solo_b[..], "batched row B diverged from solo decode");
    }

    #[test]
    fn paged_reserve_propagates_pool_exhaustion() {
        let pool = test_pool(1, 2, 4, 2, 2);
        let mut c = PagedKvCache::new(Arc::clone(&pool));
        c.reserve(4).unwrap(); // both pages
        let mut d = PagedKvCache::new(Arc::clone(&pool));
        let err = d.reserve(1).unwrap_err();
        assert_eq!(err.free, 0);
        assert_eq!(d.pages_held(), 0, "failed reserve must not hold pages");
        c.reset();
        assert!(d.reserve(2).is_ok(), "released pages must serve the retry");
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // uniform V rows -> output equals that row regardless of scores
        let m = MultiheadAttention::new(4, 1, false);
        // make wv identity-ish, wo identity, wq/wk zero -> uniform attention
        m.wq.weight.set_tensor(Tensor::zeros([4, 4]));
        m.wk.weight.set_tensor(Tensor::zeros([4, 4]));
        m.wv.weight.set_tensor(Tensor::eye(4, DType::F32));
        m.wo.weight.set_tensor(Tensor::eye(4, DType::F32));
        let x = Variable::constant(Tensor::from_slice(
            &[1.0f32, 0., 0., 0., 0., 1., 0., 0.],
            [1, 2, 4],
        ));
        let y = m.forward(&x).tensor().to_vec();
        // uniform attention -> each row is the mean of V rows = [0.5, 0.5, 0, 0]
        assert!((y[0] - 0.5).abs() < 1e-5 && (y[1] - 0.5).abs() < 1e-5);
        assert!((y[4] - 0.5).abs() < 1e-5 && (y[5] - 0.5).abs() < 1e-5);
    }
}
