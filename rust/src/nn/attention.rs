//! Multi-head scaled-dot-product attention.
//!
//! The softmax(QKᵀ/√d)V core probes the backend's `call_ext("attention")`
//! extension first — on the AOT/XLA backend that dispatches to the
//! Pallas-authored fused kernel — and falls back to primitive composition
//! everywhere else (inference path; training always uses the composed
//! graph so the tape sees every op).

use crate::autograd::{ops, Variable};
use crate::tensor::{DType, Tensor};

use super::linear::Linear;
use super::Module;

/// Multi-head self-attention with optional causal masking.
pub struct MultiheadAttention {
    /// Q/K/V projections.
    pub wq: Linear,
    /// Key projection.
    pub wk: Linear,
    /// Value projection.
    pub wv: Linear,
    /// Output projection.
    pub wo: Linear,
    heads: usize,
    dim: usize,
    causal: bool,
}

impl MultiheadAttention {
    /// `dim` must be divisible by `heads`.
    pub fn new(dim: usize, heads: usize, causal: bool) -> Self {
        assert_eq!(dim % heads, 0, "dim {dim} not divisible by heads {heads}");
        MultiheadAttention {
            wq: Linear::new(dim, dim),
            wk: Linear::new(dim, dim),
            wv: Linear::new(dim, dim),
            wo: Linear::new(dim, dim),
            heads,
            dim,
            causal,
        }
    }

    /// Split `[B, L, D]` into `[B*H, L, D/H]`.
    fn split_heads(&self, x: &Variable, b: usize, l: usize) -> Variable {
        let hd = self.dim / self.heads;
        let x = ops::reshape(x, &[b as isize, l as isize, self.heads as isize, hd as isize]);
        let x = ops::transpose(&x, &[0, 2, 1, 3]);
        ops::reshape(&x, &[(b * self.heads) as isize, l as isize, hd as isize])
    }

    /// Inverse of `split_heads`.
    fn merge_heads(&self, x: &Variable, b: usize, l: usize) -> Variable {
        let hd = self.dim / self.heads;
        let x = ops::reshape(x, &[b as isize, self.heads as isize, l as isize, hd as isize]);
        let x = ops::transpose(&x, &[0, 2, 1, 3]);
        ops::reshape(&x, &[b as isize, l as isize, self.dim as isize])
    }

    /// Scaled-dot-product core over `[B*H, L, hd]` tensors.
    pub fn sdpa(&self, q: &Variable, k: &Variable, v: &Variable, l: usize) -> Variable {
        let hd = self.dim / self.heads;
        let scale = 1.0 / (hd as f64).sqrt();
        let scores = ops::mul_scalar(&ops::matmul(q, &ops::t(k)), scale);
        let scores = if self.causal {
            let mask = Tensor::tril_mask(l).astype(DType::F32);
            // additive -inf style mask: (1-mask) * -1e9
            let bias = mask.neg().add_scalar(1.0).mul_scalar(-1e9);
            ops::add(&scores, &Variable::constant(bias))
        } else {
            scores
        };
        let attn = ops::softmax(&scores, -1);
        ops::matmul(&attn, v)
    }
}

impl Module for MultiheadAttention {
    fn forward(&self, input: &Variable) -> Variable {
        let dims = input.dims();
        assert_eq!(dims.len(), 3, "attention wants [B, L, D]");
        let (b, l) = (dims[0], dims[1]);
        let q = self.split_heads(&self.wq.forward(input), b, l);
        let k = self.split_heads(&self.wk.forward(input), b, l);
        let v = self.split_heads(&self.wv.forward(input), b, l);
        let ctx = self.sdpa(&q, &k, &v, l);
        self.wo.forward(&self.merge_heads(&ctx, b, l))
    }

    fn params(&self) -> Vec<Variable> {
        [&self.wq, &self.wk, &self.wv, &self.wo].iter().flat_map(|m| m.params()).collect()
    }

    fn name(&self) -> String {
        format!("MultiheadAttention(d={}, h={}, causal={})", self.dim, self.heads, self.causal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_roundtrip() {
        let m = MultiheadAttention::new(16, 4, false);
        let x = Variable::constant(Tensor::rand([2, 5, 16], -1.0, 1.0));
        let y = m.forward(&x);
        assert_eq!(y.dims(), vec![2, 5, 16]);
        assert_eq!(m.params().len(), 8);
    }

    #[test]
    fn causal_mask_blocks_future() {
        // with causal masking, output at position 0 must not depend on
        // later positions
        let m = MultiheadAttention::new(8, 2, true);
        let base = Tensor::rand([1, 4, 8], -1.0, 1.0);
        let y1 = m.forward(&Variable::constant(base.clone())).tensor().to_vec();
        // perturb the last position only
        let mut v = base.to_vec();
        for x in v[24..32].iter_mut() {
            *x += 10.0;
        }
        let y2 = m
            .forward(&Variable::constant(Tensor::from_slice(&v, [1, 4, 8])))
            .tensor()
            .to_vec();
        for i in 0..8 {
            assert!((y1[i] - y2[i]).abs() < 1e-5, "position 0 leaked future info");
        }
        // but the last position must change
        let tail_moved = (0..8).any(|i| (y1[24 + i] - y2[24 + i]).abs() > 1e-4);
        assert!(tail_moved);
    }

    #[test]
    fn gradients_reach_all_projections() {
        let m = MultiheadAttention::new(8, 2, false);
        let x = Variable::constant(Tensor::rand([1, 3, 8], -1.0, 1.0));
        ops::sum(&m.forward(&x), &[], false).backward();
        for p in m.params() {
            assert!(p.grad().is_some());
        }
    }

    #[test]
    fn attention_gradcheck() {
        use crate::testutil::gradcheck::check_grad_tol;
        // module built once outside the closure: gradcheck re-evaluates f
        // for numeric differencing, so the (random-initialized) weights
        // must stay fixed across calls
        let m = MultiheadAttention::new(4, 2, true);
        check_grad_tol("attention", &[1, 3, 4], 1e-4, 1e-2, |x| {
            ops::sum(&m.forward(x), &[], false)
        });
    }

    #[test]
    fn sdpa_core_gradcheck() {
        use crate::autograd::ops::{matmul, sum};
        use crate::testutil::gradcheck::check_grad_tol;
        let m = MultiheadAttention::new(4, 1, false);
        // grad through softmax(QK^T/sqrt(d))V with Q=K=V derived from x
        check_grad_tol("sdpa", &[1, 3, 4], 1e-4, 1e-2, |x| {
            let w = Variable::constant(Tensor::eye(4, DType::F64));
            let q = matmul(x, &w);
            sum(&m.sdpa(&q, x, x, 3), &[], false)
        });
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // uniform V rows -> output equals that row regardless of scores
        let m = MultiheadAttention::new(4, 1, false);
        // make wv identity-ish, wo identity, wq/wk zero -> uniform attention
        m.wq.weight.set_tensor(Tensor::zeros([4, 4]));
        m.wk.weight.set_tensor(Tensor::zeros([4, 4]));
        m.wv.weight.set_tensor(Tensor::eye(4, DType::F32));
        m.wo.weight.set_tensor(Tensor::eye(4, DType::F32));
        let x = Variable::constant(Tensor::from_slice(
            &[1.0f32, 0., 0., 0., 0., 1., 0., 0.],
            [1, 2, 4],
        ));
        let y = m.forward(&x).tensor().to_vec();
        // uniform attention -> each row is the mean of V rows = [0.5, 0.5, 0, 0]
        assert!((y[0] - 0.5).abs() < 1e-5 && (y[1] - 0.5).abs() < 1e-5);
        assert!((y[4] - 0.5).abs() < 1e-5 && (y[5] - 0.5).abs() < 1e-5);
    }
}
