//! Large-framework overhead baseline (DESIGN.md substitution for the
//! paper's PyTorch/TensorFlow comparison rows).
//!
//! PyTorch cannot be built on this offline testbed, so Table 3's
//! "large framework" column is reproduced with a backend that models the
//! overhead dimensions the paper attributes to big frameworks (§5.1.2,
//! §5.2.4): deep dispatcher indirection (schema lookup through a
//! dispatch-key chain on *every* op), op-granular temporary materialization
//! (every result copied through an extra buffer, defeating fusion and
//! buffer reuse), and per-op bookkeeping (version counters / trace
//! records). Kernel math is identical — only framework overhead differs —
//! which is exactly the variable the paper isolates: overhead matters most
//! for low-arithmetic-intensity models (AlexNet) and least for GEMM-bound
//! ones (VGG).
//!
//! With the [`Op`] IR this is a single [`Interposer`] function: *every*
//! primitive pays the dispatcher tax, with no per-method overrides —
//! previously the model only taxed the dozen ops someone remembered to
//! override.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::tensor::cpu::CpuBackend;
use crate::tensor::interpose::{InterposedBackend, Interposer};
use crate::tensor::op::Op;
use crate::tensor::{Tensor, TensorBackend};
use crate::util::error::Result;

/// Number of simulated dispatch-key layers an op passes through
/// (autograd, autocast, tracing, batching, backend-select — the usual
/// tower in a large framework).
pub const DISPATCH_LAYERS: usize = 5;

/// The overhead model (see module docs), applied uniformly to the entire
/// primitive surface through one intercept function.
pub struct BloatInterposer {
    /// Simulated operator-schema registry (string-keyed, looked up per op).
    schema: Mutex<std::collections::HashMap<String, u64>>,
    /// Per-op version counter churn.
    version: AtomicU64,
    /// Total ops dispatched.
    pub dispatches: AtomicU64,
}

impl BloatInterposer {
    /// The per-op overhead: a dispatch-key walk where every layer
    /// re-resolves the op through a string-keyed registry (each hop
    /// allocates, like boxing through an interpreter / dispatcher tower),
    /// version-counter churn, and an output copy through a fresh
    /// temporary. Calibrated to ~1 µs/op — the order of the per-op
    /// dispatch cost eager large frameworks pay (interpreter + dispatcher
    /// + record-keeping), which is the variable the paper's Table 3
    /// isolates. The temporary copy runs on the *inner* backend directly:
    /// it models framework bookkeeping, not a user op, and must not
    /// re-enter the dispatcher.
    fn overhead(&self, op: &str, out: Tensor, inner: &dyn TensorBackend) -> Tensor {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        {
            let mut reg = self.schema.lock().unwrap();
            // dispatch-key chain: each layer boxes a fresh lookup key
            for layer in 0..DISPATCH_LAYERS {
                let key = format!("dispatch::{layer}::aten::{op}");
                *reg.entry(key).or_insert(0) += 1;
            }
            // schema/overload resolution pass
            for overload in ["Tensor", "Scalar", "out"] {
                let key = format!("aten::{op}.{overload}");
                std::hint::black_box(reg.get(&key));
            }
        }
        self.version.fetch_add(1, Ordering::SeqCst);
        // op-granular temporary: copy the output through a fresh buffer
        inner.copy(&out)
    }
}

impl Interposer for BloatInterposer {
    fn name(&self) -> &str {
        "bloat-baseline"
    }

    fn intercept(
        &self,
        op: &Op,
        inputs: &[&Tensor],
        inner: &dyn TensorBackend,
    ) -> Result<Tensor> {
        let out = inner.dispatch(op, inputs)?;
        Ok(self.overhead(op.name(), out, inner))
    }
}

/// See module docs.
pub type BloatBackend = InterposedBackend<BloatInterposer>;

impl BloatBackend {
    /// Build over the reference CPU backend. (Named distinctly from the
    /// generic `InterposedBackend::new` — an inherent `new` on the
    /// concrete instantiation would collide with it, E0592.)
    pub fn over_cpu_default() -> Arc<BloatBackend> {
        InterposedBackend::new(
            BloatInterposer {
                schema: Mutex::new(std::collections::HashMap::new()),
                version: AtomicU64::new(0),
                dispatches: AtomicU64::new(0),
            },
            CpuBackend::shared(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::BackendGuard;

    #[test]
    fn numerics_identical_to_reference() {
        crate::util::rng::seed(55);
        let av = Tensor::rand([16, 16], -1.0, 1.0).to_vec();
        let eager = {
            let a = Tensor::from_slice(&av, [16, 16]);
            a.matmul(&a).add(&a).gelu().sum(&[], false).item()
        };
        let bloat = {
            let _g = BackendGuard::install(BloatBackend::over_cpu_default());
            let a = Tensor::from_slice(&av, [16, 16]);
            a.matmul(&a).add(&a).gelu().sum(&[], false).item()
        };
        assert!((eager - bloat).abs() < 1e-6);
    }

    #[test]
    fn every_primitive_pays_the_tax() {
        let be = BloatBackend::over_cpu_default();
        let _g = BackendGuard::install(be.clone());
        let t = Tensor::rand([4, 4], -1.0, 1.0);
        let before = be.interposer().dispatches.load(Ordering::Relaxed);
        // ops the old hand-written override list never covered
        let _ = t.floor();
        let _ = t.cumsum(0);
        let _ = t.flip(&[0]);
        assert!(
            be.interposer().dispatches.load(Ordering::Relaxed) >= before + 3,
            "uniform overhead must cover the whole surface"
        );
    }

    #[test]
    fn overhead_is_measurably_slower_per_small_op() {
        use std::time::Instant;
        let n = 3000;
        let small = Tensor::rand([8], -1.0, 1.0);
        let t0 = Instant::now();
        for _ in 0..n {
            std::hint::black_box(small.add(&small));
        }
        let fast = t0.elapsed();
        let be = BloatBackend::over_cpu_default();
        let _g = BackendGuard::install(be.clone());
        let t1 = Instant::now();
        for _ in 0..n {
            std::hint::black_box(small.add(&small));
        }
        let slow = t1.elapsed();
        assert!(be.interposer().dispatches.load(Ordering::Relaxed) >= n as u64);
        assert!(
            slow > fast,
            "bloat backend should be slower on tiny ops: {slow:?} vs {fast:?}"
        );
    }
}
