//! Large-framework overhead baseline (DESIGN.md substitution for the
//! paper's PyTorch/TensorFlow comparison rows).
//!
//! PyTorch cannot be built on this offline testbed, so Table 3's
//! "large framework" column is reproduced with a backend that models the
//! overhead dimensions the paper attributes to big frameworks (§5.1.2,
//! §5.2.4): deep dispatcher indirection (schema lookup through a
//! dispatch-key chain on *every* op), op-granular temporary materialization
//! (every result copied through an extra buffer, defeating fusion and
//! buffer reuse), and per-op bookkeeping (version counters / trace
//! records). Kernel math is identical — only framework overhead differs —
//! which is exactly the variable the paper isolates: overhead matters most
//! for low-arithmetic-intensity models (AlexNet) and least for GEMM-bound
//! ones (VGG).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::tensor::cpu::CpuBackend;
use crate::tensor::delegate::DelegateBackend;
use crate::tensor::{Tensor, TensorBackend};

/// Number of simulated dispatch-key layers an op passes through
/// (autograd, autocast, tracing, batching, backend-select — the usual
/// tower in a large framework).
pub const DISPATCH_LAYERS: usize = 5;

/// See module docs.
pub struct BloatBackend {
    inner: Arc<dyn TensorBackend>,
    /// Simulated operator-schema registry (string-keyed, looked up per op).
    schema: Mutex<std::collections::HashMap<String, u64>>,
    /// Per-op version counter churn.
    version: AtomicU64,
    /// Total ops dispatched.
    pub dispatches: AtomicU64,
}

impl BloatBackend {
    /// Build over the reference CPU backend.
    pub fn new() -> Arc<BloatBackend> {
        Arc::new(BloatBackend {
            inner: CpuBackend::shared(),
            schema: Mutex::new(std::collections::HashMap::new()),
            version: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
        })
    }

    /// The per-op overhead: a dispatch-key walk where every layer
    /// re-resolves the op through a string-keyed registry (each hop
    /// allocates, like boxing through an interpreter / dispatcher tower),
    /// version-counter churn, and an output copy through a fresh
    /// temporary. Calibrated to ~1 µs/op — the order of the per-op
    /// dispatch cost eager large frameworks pay (interpreter + dispatcher
    /// + record-keeping), which is the variable the paper's Table 3
    /// isolates.
    fn overhead(&self, op: &str, out: Tensor) -> Tensor {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        {
            let mut reg = self.schema.lock().unwrap();
            // dispatch-key chain: each layer boxes a fresh lookup key
            for layer in 0..DISPATCH_LAYERS {
                let key = format!("dispatch::{layer}::aten::{op}");
                *reg.entry(key).or_insert(0) += 1;
            }
            // schema/overload resolution pass
            for overload in ["Tensor", "Scalar", "out"] {
                let key = format!("aten::{op}.{overload}");
                std::hint::black_box(reg.get(&key));
            }
        }
        self.version.fetch_add(1, Ordering::SeqCst);
        // op-granular temporary: copy the output through a fresh buffer
        out.copy()
    }
}

impl DelegateBackend for BloatBackend {
    fn inner(&self) -> Arc<dyn TensorBackend> {
        self.inner.clone()
    }
    fn wrapper_name(&self) -> &str {
        "bloat-baseline"
    }

    fn add(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.overhead("add", self.inner.add(a, b))
    }
    fn sub(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.overhead("sub", self.inner.sub(a, b))
    }
    fn mul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.overhead("mul", self.inner.mul(a, b))
    }
    fn div(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.overhead("div", self.inner.div(a, b))
    }
    fn maximum(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.overhead("maximum", self.inner.maximum(a, b))
    }
    fn exp(&self, x: &Tensor) -> Tensor {
        self.overhead("exp", self.inner.exp(x))
    }
    fn tanh(&self, x: &Tensor) -> Tensor {
        self.overhead("tanh", self.inner.tanh(x))
    }
    fn erf(&self, x: &Tensor) -> Tensor {
        self.overhead("erf", self.inner.erf(x))
    }
    fn sum(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.overhead("sum", self.inner.sum(x, axes, keepdims))
    }
    fn max_reduce(&self, x: &Tensor, axes: &[usize], keepdims: bool) -> Tensor {
        self.overhead("max", self.inner.max_reduce(x, axes, keepdims))
    }
    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.overhead("matmul", self.inner.matmul(a, b))
    }
    fn conv2d(&self, x: &Tensor, w: &Tensor, p: crate::tensor::Conv2dParams) -> Tensor {
        self.overhead("conv2d", self.inner.conv2d(x, w, p))
    }
    fn transpose(&self, x: &Tensor, perm: &[usize]) -> Tensor {
        self.overhead("transpose", self.inner.transpose(x, perm))
    }
    fn reshape(&self, x: &Tensor, shape: &crate::tensor::Shape) -> Tensor {
        // large frameworks still record a node for views
        self.overhead("reshape", self.inner.reshape(x, shape))
    }
}

crate::impl_delegate_backend!(BloatBackend);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::BackendGuard;

    #[test]
    fn numerics_identical_to_reference() {
        crate::util::rng::seed(55);
        let av = Tensor::rand([16, 16], -1.0, 1.0).to_vec();
        let eager = {
            let a = Tensor::from_slice(&av, [16, 16]);
            a.matmul(&a).add(&a).gelu().sum(&[], false).item()
        };
        let bloat = {
            let _g = BackendGuard::install(BloatBackend::new());
            let a = Tensor::from_slice(&av, [16, 16]);
            a.matmul(&a).add(&a).gelu().sum(&[], false).item()
        };
        assert!((eager - bloat).abs() < 1e-6);
    }

    #[test]
    fn overhead_is_measurably_slower_per_small_op() {
        use std::time::Instant;
        let n = 3000;
        let small = Tensor::rand([8], -1.0, 1.0);
        let t0 = Instant::now();
        for _ in 0..n {
            std::hint::black_box(small.add(&small));
        }
        let fast = t0.elapsed();
        let be = BloatBackend::new();
        let _g = BackendGuard::install(be.clone());
        let t1 = Instant::now();
        for _ in 0..n {
            std::hint::black_box(small.add(&small));
        }
        let slow = t1.elapsed();
        assert!(be.dispatches.load(Ordering::Relaxed) >= n as u64);
        assert!(
            slow > fast,
            "bloat backend should be slower on tiny ops: {slow:?} vs {fast:?}"
        );
    }
}
