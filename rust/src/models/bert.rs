//! BERT-like masked/causal language model (paper Table 3's "BERT-like",
//! scaled; also the backbone of the end-to-end training example).

use crate::autograd::{ops, Variable};
use crate::nn::{
    Embedding, KvCache, LayerNorm, Linear, Module, PositionalEmbedding, TransformerEncoderLayer,
};
use crate::tensor::Tensor;

/// Token embedding + positional embedding + N transformer layers + LM head.
pub struct BertLike {
    /// Token embedding.
    pub tok: Embedding,
    /// Positional embedding.
    pub pos: PositionalEmbedding,
    layers: Vec<TransformerEncoderLayer>,
    ln_f: LayerNorm,
    /// LM head projecting back to the vocabulary.
    pub head: Linear,
    dim: usize,
}

impl BertLike {
    /// `vocab` tokens, `dim` width, `heads`, `depth` layers, `max_len`.
    pub fn new(vocab: usize, dim: usize, heads: usize, depth: usize, max_len: usize) -> Self {
        BertLike {
            tok: Embedding::new(vocab, dim),
            pos: PositionalEmbedding::new(max_len, dim),
            layers: (0..depth)
                .map(|_| TransformerEncoderLayer::new(dim, heads, dim * 4, 0.0, true))
                .collect(),
            ln_f: LayerNorm::new(dim),
            head: Linear::new(dim, vocab),
            dim,
        }
    }

    /// Forward token ids `[B, L]` (i64 tensor) to logits `[B, L, V]`.
    pub fn logits(&self, ids: &Tensor) -> Variable {
        let mut h = self.pos.forward(&self.tok.lookup(ids));
        for l in &self.layers {
            h = l.forward(&h);
        }
        self.head.forward(&self.ln_f.forward(&h))
    }

    /// Forward *new* token ids `[B, L_new]` against per-layer KV caches
    /// (one [`KvCache`] per transformer layer, from
    /// [`BertLike::empty_cache`]): positions are offset by the cache
    /// length, each layer's attention consumes and extends its cache, and
    /// only the new positions' logits `[B, L_new, V]` come back. With an
    /// empty cache and the full sequence this is the prefill pass —
    /// bit-identical to [`BertLike::logits`]; with one token it is the
    /// O(L) incremental decode step [`crate::serve::generate()`] drives.
    pub fn logits_cached(&self, ids: &Tensor, caches: &mut [KvCache]) -> Variable {
        assert_eq!(caches.len(), self.layers.len(), "one KV cache per layer");
        let offset = caches.first().map_or(0, |c| c.len());
        let mut h = self.pos.forward_at(&self.tok.lookup(ids), offset);
        for (layer, cache) in self.layers.iter().zip(caches.iter_mut()) {
            h = layer.forward_cached(&h, cache);
        }
        self.head.forward(&self.ln_f.forward(&h))
    }

    /// Fresh per-layer KV caches for one generation stream.
    pub fn empty_cache(&self) -> Vec<KvCache> {
        (0..self.layers.len()).map(|_| KvCache::new()).collect()
    }

    /// Number of transformer layers.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Longest supported sequence (the positional table's size).
    pub fn max_len(&self) -> usize {
        self.pos.max_len()
    }

    /// Vocabulary size (the LM head's output width).
    pub fn vocab(&self) -> usize {
        self.tok.vocab()
    }

    /// Hidden width.
    pub fn dim(&self) -> usize {
        self.dim
    }
}

impl Module for BertLike {
    fn forward(&self, input: &Variable) -> Variable {
        self.logits(&input.tensor())
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.tok.params();
        p.extend(self.pos.params());
        for l in &self.layers {
            p.extend(l.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }

    fn set_train(&mut self, train: bool) {
        for l in &mut self.layers {
            l.set_train(train);
        }
    }

    fn name(&self) -> String {
        format!("BertLike(d={}, layers={})", self.dim, self.layers.len())
    }
}

/// Next-token cross-entropy for an autoregressive LM over `[B, L]` ids.
pub fn lm_loss(model: &BertLike, ids: &Tensor) -> Variable {
    let dims = ids.dims().to_vec();
    let (b, l) = (dims[0], dims[1]);
    let inputs = ids.narrow(1, 0, l - 1);
    let targets = ids.narrow(1, 1, l - 1);
    let logits = model.logits(&inputs); // [B, L-1, V]
    let v = logits.dims()[2];
    let flat = ops::reshape(&logits, &[(b * (l - 1)) as isize, v as isize]);
    let tflat = targets.reshape(&[(b * (l - 1)) as isize]);
    crate::nn::categorical_cross_entropy(&flat, &tflat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn logits_shape() {
        let m = BertLike::new(50, 32, 4, 2, 16);
        let ids = Tensor::rand([2, 10], 0.0, 50.0).astype(DType::I64);
        let y = m.logits(&ids);
        assert_eq!(y.dims(), vec![2, 10, 50]);
    }

    #[test]
    fn lm_loss_starts_near_uniform() {
        crate::util::rng::seed(8);
        let m = BertLike::new(64, 32, 2, 1, 16);
        let ids = Tensor::rand([4, 12], 0.0, 64.0).astype(DType::I64);
        let l = lm_loss(&m, &ids).tensor().item();
        let uniform = (64.0f64).ln();
        assert!((l - uniform).abs() < 1.0, "initial loss {l} far from ln(V)={uniform}");
    }

    #[test]
    fn few_steps_reduce_loss_on_fixed_batch() {
        crate::util::rng::seed(9);
        let m = BertLike::new(32, 32, 2, 1, 16);
        let ids = Tensor::rand([2, 12], 0.0, 32.0).astype(DType::I64);
        let params = m.params();
        let mut opt = crate::optim::AdamOptimizer::new(params, 5e-3);
        use crate::optim::Optimizer;
        let first = lm_loss(&m, &ids).tensor().item();
        for _ in 0..12 {
            let loss = lm_loss(&m, &ids);
            loss.backward();
            opt.step();
            opt.zero_grad();
        }
        let last = lm_loss(&m, &ids).tensor().item();
        assert!(last < first * 0.8, "no learning: {first} -> {last}");
    }
}
