//! BERT-like masked/causal language model (paper Table 3's "BERT-like",
//! scaled; also the backbone of the end-to-end training example).

use crate::autograd::{ops, Variable};
use crate::memory::KvPoolConfig;
use crate::nn::{
    Embedding, KvCache, LayerNorm, Linear, Module, PagedKvCache, PositionalEmbedding,
    TransformerEncoderLayer,
};
use crate::tensor::Tensor;

/// Token embedding + positional embedding + N transformer layers + LM head.
pub struct BertLike {
    /// Token embedding.
    pub tok: Embedding,
    /// Positional embedding.
    pub pos: PositionalEmbedding,
    layers: Vec<TransformerEncoderLayer>,
    ln_f: LayerNorm,
    /// LM head projecting back to the vocabulary.
    pub head: Linear,
    dim: usize,
}

impl BertLike {
    /// `vocab` tokens, `dim` width, `heads`, `depth` layers, `max_len`.
    pub fn new(vocab: usize, dim: usize, heads: usize, depth: usize, max_len: usize) -> Self {
        BertLike {
            tok: Embedding::new(vocab, dim),
            pos: PositionalEmbedding::new(max_len, dim),
            layers: (0..depth)
                .map(|_| TransformerEncoderLayer::new(dim, heads, dim * 4, 0.0, true))
                .collect(),
            ln_f: LayerNorm::new(dim),
            head: Linear::new(dim, vocab),
            dim,
        }
    }

    /// Forward token ids `[B, L]` (i64 tensor) to logits `[B, L, V]`.
    pub fn logits(&self, ids: &Tensor) -> Variable {
        let mut h = self.pos.forward(&self.tok.lookup(ids));
        for l in &self.layers {
            h = l.forward(&h);
        }
        self.head.forward(&self.ln_f.forward(&h))
    }

    /// Forward *new* token ids `[B, L_new]` against per-layer KV caches
    /// (one [`KvCache`] per transformer layer, from
    /// [`BertLike::empty_cache`]): positions are offset by the cache
    /// length, each layer's attention consumes and extends its cache, and
    /// only the new positions' logits `[B, L_new, V]` come back. With an
    /// empty cache and the full sequence this is the prefill pass —
    /// bit-identical to [`BertLike::logits`]; with one token it is the
    /// O(L) incremental decode step [`crate::serve::generate()`] drives.
    pub fn logits_cached(&self, ids: &Tensor, caches: &mut [KvCache]) -> Variable {
        assert_eq!(caches.len(), self.layers.len(), "one KV cache per layer");
        let offset = caches.first().map_or(0, |c| c.len());
        let mut h = self.pos.forward_at(&self.tok.lookup(ids), offset);
        for (layer, cache) in self.layers.iter().zip(caches.iter_mut()) {
            h = layer.forward_cached(&h, cache);
        }
        self.head.forward(&self.ln_f.forward(&h))
    }

    /// Fresh per-layer KV caches for one generation stream.
    pub fn empty_cache(&self) -> Vec<KvCache> {
        (0..self.layers.len()).map(|_| KvCache::new()).collect()
    }

    /// [`BertLike::logits_cached`] against one request's paged cache:
    /// forward new ids `[1, L_new]` at the cache's current length, write
    /// each layer's keys/values into the cache's pages, and commit the
    /// new positions once after the layer stack. Bit-identical to the
    /// contiguous cached path (`rust/tests/serve.rs` pins this).
    pub fn logits_paged(&self, ids: &Tensor, cache: &mut PagedKvCache) -> Variable {
        let dims = ids.dims().to_vec();
        assert_eq!(dims.len(), 2, "ids want [B, L]");
        assert_eq!(dims[0], 1, "the paged path is per-request");
        let l_new = dims[1];
        let offset = cache.len();
        let mut h = self.pos.forward_at(&self.tok.lookup(ids), offset);
        for (i, layer) in self.layers.iter().enumerate() {
            h = layer.forward_paged(&h, cache, i);
        }
        cache.advance(l_new);
        self.head.forward(&self.ln_f.forward(&h))
    }

    /// One continuous-batching decode iteration: step `B` *different*
    /// requests one token each. `ids` is `[B, 1]`, row `i` the latest
    /// token of the request behind `caches[i]`; every row sits at its own
    /// position (its cache length). Returns `[B, 1, V]` logits whose row
    /// `i` is bit-identical to stepping that request alone — the
    /// correctness contract of the continuous batcher, fuzzed in
    /// `rust/tests/serve_continuous_fuzz.rs`.
    /// The step is expressed over the same *segment* methods
    /// ([`Self::decode_seg_embed`] / [`Self::decode_seg_mid`] /
    /// [`Self::decode_seg_head`]) that [`crate::serve::CompiledDecodeStep`]
    /// traces per batch-size bucket, with the per-request attention cores
    /// ([`Self::decode_attention_core`]) running between segments in both
    /// paths — so the compiled and eager decode iterations execute the
    /// same op stream on the same values, and their bitwise parity is
    /// structural.
    pub fn logits_decode_batch(&self, ids: &Tensor, caches: &mut [&mut PagedKvCache]) -> Variable {
        let dims = ids.dims().to_vec();
        assert_eq!(dims.len(), 2, "ids want [B, L]");
        assert_eq!(dims[1], 1, "decode steps one token per request");
        assert_eq!(dims[0], caches.len(), "one paged cache per batch row");
        assert!(!self.layers.is_empty(), "decode needs at least one transformer layer");
        let offsets: Vec<i64> = caches.iter().map(|c| c.len() as i64).collect();
        let max_len = self.max_len();
        for &o in &offsets {
            assert!((o as usize) < max_len, "position {o} exceeds max_len {max_len}");
        }
        let positions = Tensor::from_slice(&offsets, [caches.len()]);
        let mut seg = self.decode_seg_embed(ids, &positions);
        let depth = self.layers.len();
        let mut logits = None;
        for layer in 0..depth {
            let ctx = self.decode_attention_core(layer, &seg[1], &seg[2], &seg[3], caches);
            if layer + 1 < depth {
                seg = self.decode_seg_mid(layer, &seg[0], &ctx);
            } else {
                logits = Some(self.decode_seg_head(layer, &seg[0], &ctx));
            }
        }
        for c in caches.iter_mut() {
            c.advance(1);
        }
        Variable::constant(logits.expect("at least one layer"))
    }

    /// First decode segment: token embedding, per-row positional add
    /// (`positions` is i64 `[B]`), and layer 0's pre-attention half.
    /// Returns `[hidden [B,1,D], q, k, v [B*H,1,hd]]` — the fixed
    /// four-tensor segment interface shared with
    /// [`Self::decode_seg_mid`]. Pure tensor math over `ids`/`positions`:
    /// this is what `serve::CompiledDecodeStep` traces as its entry
    /// program, with both arguments substitutable so neither token values
    /// nor sequence depths ever force a re-trace.
    pub fn decode_seg_embed(&self, ids: &Tensor, positions: &Tensor) -> Vec<Tensor> {
        let b = ids.dims()[0];
        let x = self.tok.lookup(ids);
        let h = self.pos.forward_at_positions(&x, positions);
        let (q, k, v) = self.layers[0].decode_attn_in(&h, b);
        vec![h.tensor(), q.tensor(), k.tensor(), v.tensor()]
    }

    /// Middle decode segment: layer `layer`'s post-attention half
    /// (output projection, residuals, MLP) over its attention contexts
    /// `ctx` `[B*H,1,hd]`, then layer `layer + 1`'s pre-attention half.
    /// Same four-tensor interface as [`Self::decode_seg_embed`].
    pub fn decode_seg_mid(&self, layer: usize, h: &Tensor, ctx: &Tensor) -> Vec<Tensor> {
        let b = h.dims()[0];
        let x = self.layers[layer].decode_attn_out(
            &Variable::constant(h.clone()),
            &Variable::constant(ctx.clone()),
            b,
        );
        let (q, k, v) = self.layers[layer + 1].decode_attn_in(&x, b);
        vec![x.tensor(), q.tensor(), k.tensor(), v.tensor()]
    }

    /// Final decode segment: the last layer's post-attention half, final
    /// layer norm, and the LM head — `[B,1,V]` logits.
    pub fn decode_seg_head(&self, layer: usize, h: &Tensor, ctx: &Tensor) -> Tensor {
        let b = h.dims()[0];
        let x = self.layers[layer].decode_attn_out(
            &Variable::constant(h.clone()),
            &Variable::constant(ctx.clone()),
            b,
        );
        self.head.forward(&self.ln_f.forward(&x)).tensor()
    }

    /// The per-request attention cores between two decode segments:
    /// page writes, past gathers, and SDPA at each request's own length
    /// (see [`crate::nn::MultiheadAttention`]'s `decode_cores`). Always
    /// eager — KV lengths and page tables never appear inside a traced
    /// segment.
    pub fn decode_attention_core(
        &self,
        layer: usize,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        caches: &mut [&mut PagedKvCache],
    ) -> Tensor {
        self.layers[layer].attn.decode_cores(q, k, v, caches, layer)
    }

    /// Pool geometry matching this model for a given page size and
    /// capacity — the glue between the model's shape and
    /// [`crate::memory::KvPagePool`].
    pub fn kv_pool_config(&self, page_tokens: usize, max_pages: usize) -> KvPoolConfig {
        KvPoolConfig {
            layers: self.depth(),
            heads: self.heads(),
            head_dim: self.head_dim(),
            page_tokens,
            max_pages,
        }
    }

    /// Attention heads per layer.
    pub fn heads(&self) -> usize {
        self.layers.first().map_or(1, |l| l.attn.heads())
    }

    /// Per-head feature width.
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads()
    }

    /// Number of transformer layers.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Longest supported sequence (the positional table's size).
    pub fn max_len(&self) -> usize {
        self.pos.max_len()
    }

    /// Vocabulary size (the LM head's output width).
    pub fn vocab(&self) -> usize {
        self.tok.vocab()
    }

    /// Hidden width.
    pub fn dim(&self) -> usize {
        self.dim
    }
}

impl Module for BertLike {
    fn forward(&self, input: &Variable) -> Variable {
        self.logits(&input.tensor())
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.tok.params();
        p.extend(self.pos.params());
        for l in &self.layers {
            p.extend(l.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }

    fn set_train(&mut self, train: bool) {
        for l in &mut self.layers {
            l.set_train(train);
        }
    }

    fn name(&self) -> String {
        format!("BertLike(d={}, layers={})", self.dim, self.layers.len())
    }
}

/// Next-token cross-entropy for an autoregressive LM over `[B, L]` ids.
pub fn lm_loss(model: &BertLike, ids: &Tensor) -> Variable {
    let dims = ids.dims().to_vec();
    let (b, l) = (dims[0], dims[1]);
    let inputs = ids.narrow(1, 0, l - 1);
    let targets = ids.narrow(1, 1, l - 1);
    let logits = model.logits(&inputs); // [B, L-1, V]
    let v = logits.dims()[2];
    let flat = ops::reshape(&logits, &[(b * (l - 1)) as isize, v as isize]);
    let tflat = targets.reshape(&[(b * (l - 1)) as isize]);
    crate::nn::categorical_cross_entropy(&flat, &tflat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn logits_shape() {
        let m = BertLike::new(50, 32, 4, 2, 16);
        let ids = Tensor::rand([2, 10], 0.0, 50.0).astype(DType::I64);
        let y = m.logits(&ids);
        assert_eq!(y.dims(), vec![2, 10, 50]);
    }

    #[test]
    fn lm_loss_starts_near_uniform() {
        crate::util::rng::seed(8);
        let m = BertLike::new(64, 32, 2, 1, 16);
        let ids = Tensor::rand([4, 12], 0.0, 64.0).astype(DType::I64);
        let l = lm_loss(&m, &ids).tensor().item();
        let uniform = (64.0f64).ln();
        assert!((l - uniform).abs() < 1.0, "initial loss {l} far from ln(V)={uniform}");
    }

    #[test]
    fn few_steps_reduce_loss_on_fixed_batch() {
        crate::util::rng::seed(9);
        let m = BertLike::new(32, 32, 2, 1, 16);
        let ids = Tensor::rand([2, 12], 0.0, 32.0).astype(DType::I64);
        let params = m.params();
        let mut opt = crate::optim::AdamOptimizer::new(params, 5e-3);
        use crate::optim::Optimizer;
        let first = lm_loss(&m, &ids).tensor().item();
        for _ in 0..12 {
            let loss = lm_loss(&m, &ids);
            loss.backward();
            opt.step();
            opt.zero_grad();
        }
        let last = lm_loss(&m, &ids).tensor().item();
        assert!(last < first * 0.8, "no learning: {first} -> {last}");
    }
}
