//! AlexNet-family CNN, scaled for 32×32 inputs on CPU (channels ≈ /8 of
//! the original; same 5-conv + 3-fc topology and low arithmetic intensity
//! that makes AlexNet the paper's best framework-overhead probe).

use crate::nn::conv::Padding;
use crate::nn::{Conv2D, Dropout, Linear, Pool2D, ReLU, Sequential, View};

/// Scaled AlexNet for `[N, 3, 32, 32]` inputs.
pub fn alexnet(classes: usize) -> Sequential {
    let mut m = Sequential::new();
    m.add(Conv2D::square(3, 8, 3, 1, Padding::Same)); // 32x32
    m.add(ReLU);
    m.add(Pool2D::max(2, 2, 2, 2)); // 16x16
    m.add(Conv2D::square(8, 24, 3, 1, Padding::Same));
    m.add(ReLU);
    m.add(Pool2D::max(2, 2, 2, 2)); // 8x8
    m.add(Conv2D::square(24, 48, 3, 1, Padding::Same));
    m.add(ReLU);
    m.add(Conv2D::square(48, 32, 3, 1, Padding::Same));
    m.add(ReLU);
    m.add(Conv2D::square(32, 32, 3, 1, Padding::Same));
    m.add(ReLU);
    m.add(Pool2D::max(2, 2, 2, 2)); // 4x4
    m.add(View::new(&[-1, 32 * 4 * 4]));
    m.add(Dropout::new(0.5));
    m.add(Linear::new(32 * 4 * 4, 256));
    m.add(ReLU);
    m.add(Dropout::new(0.5));
    m.add(Linear::new(256, 128));
    m.add(ReLU);
    m.add(Linear::new(128, classes));
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::Variable;
    use crate::nn::Module;
    use crate::tensor::Tensor;

    #[test]
    fn forward_shape() {
        let mut m = alexnet(10);
        m.set_train(false);
        let y = m.forward(&Variable::constant(Tensor::rand([2, 3, 32, 32], -1.0, 1.0)));
        assert_eq!(y.dims(), vec![2, 10]);
    }
}
