//! VGG16-family CNN, narrow variant for 32×32 CPU benchmarking (the
//! paper's highest-arithmetic-intensity conv model: time dominated by
//! vendor GEMMs, framework overhead smallest here).

use crate::nn::conv::Padding;
use crate::nn::{Conv2D, Linear, Pool2D, ReLU, Sequential, View};

/// Scaled VGG16 (13 conv + 3 fc) for `[N, 3, 32, 32]`.
pub fn vgg16(classes: usize) -> Sequential {
    let mut m = Sequential::new();
    let blocks: &[(usize, usize, usize)] = &[
        // (in, out, convs)
        (3, 16, 2),
        (16, 32, 2),
        (32, 64, 3),
        (64, 64, 3),
        (64, 64, 3),
    ];
    for &(cin, cout, convs) in blocks {
        let mut c = cin;
        for _ in 0..convs {
            m.add(Conv2D::square(c, cout, 3, 1, Padding::Same));
            m.add(ReLU);
            c = cout;
        }
        m.add(Pool2D::max(2, 2, 2, 2));
    }
    // 32 / 2^5 = 1 spatial
    m.add(View::new(&[-1, 64]));
    m.add(Linear::new(64, 128));
    m.add(ReLU);
    m.add(Linear::new(128, 128));
    m.add(ReLU);
    m.add(Linear::new(128, classes));
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::Variable;
    use crate::nn::Module;
    use crate::tensor::Tensor;

    #[test]
    fn forward_shape_and_depth() {
        let m = vgg16(10);
        assert!(m.len() > 25, "vgg should be deep, got {}", m.len());
        let y = m.forward(&Variable::constant(Tensor::rand([1, 3, 32, 32], -1.0, 1.0)));
        assert_eq!(y.dims(), vec![1, 10]);
    }
}
