//! Plain MLP builder (quickstart / tests / Figure-2 demo).

use crate::nn::{Linear, ReLU, Sequential};

/// `dims[0] -> dims[1] -> ... -> dims.last()` with ReLU between layers.
pub fn mlp(dims: &[usize]) -> Sequential {
    assert!(dims.len() >= 2);
    let mut seq = Sequential::new();
    for i in 0..dims.len() - 1 {
        seq.add(Linear::new(dims[i], dims[i + 1]));
        if i + 2 < dims.len() {
            seq.add(ReLU);
        }
    }
    seq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::Variable;
    use crate::nn::Module;
    use crate::tensor::Tensor;

    #[test]
    fn builds_and_runs() {
        let m = mlp(&[8, 16, 4]);
        let y = m.forward(&Variable::constant(Tensor::rand([3, 8], -1.0, 1.0)));
        assert_eq!(y.dims(), vec![3, 4]);
        assert_eq!(m.params().len(), 4);
    }
}
