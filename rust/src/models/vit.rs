//! Vision Transformer (ViT), tiny variant for 32×32 images.

use crate::autograd::{ops, Variable};
use crate::nn::{LayerNorm, Linear, Module, PositionalEmbedding, TransformerEncoderLayer};

/// Patchify + linear embed + transformer + mean-pool classifier head.
pub struct ViT {
    patch_embed: Linear,
    pos: PositionalEmbedding,
    layers: Vec<TransformerEncoderLayer>,
    ln_f: LayerNorm,
    head: Linear,
    image: usize,
    patch: usize,
    dim: usize,
}

impl ViT {
    /// `image`×`image` RGB inputs cut into `patch`×`patch` patches.
    pub fn new(image: usize, patch: usize, dim: usize, heads: usize, depth: usize, classes: usize) -> Self {
        assert_eq!(image % patch, 0);
        let n_patches = (image / patch) * (image / patch);
        ViT {
            patch_embed: Linear::new(3 * patch * patch, dim),
            pos: PositionalEmbedding::new(n_patches, dim),
            layers: (0..depth)
                .map(|_| TransformerEncoderLayer::new(dim, heads, dim * 4, 0.0, false))
                .collect(),
            ln_f: LayerNorm::new(dim),
            head: Linear::new(dim, classes),
            image,
            patch,
            dim,
        }
    }

    /// `[N, 3, H, W]` -> `[N, P, 3*patch*patch]` patch extraction via
    /// reshape/transpose composition (no custom op needed).
    fn patchify(&self, x: &Variable) -> Variable {
        let dims = x.dims();
        let (n, c) = (dims[0], dims[1]);
        let g = self.image / self.patch;
        let p = self.patch;
        // [N, C, g, p, g, p]
        let x = ops::reshape(
            x,
            &[n as isize, c as isize, g as isize, p as isize, g as isize, p as isize],
        );
        // -> [N, g, g, C, p, p]
        let x = ops::transpose(&x, &[0, 2, 4, 1, 3, 5]);
        ops::reshape(&x, &[n as isize, (g * g) as isize, (c * p * p) as isize])
    }
}

impl Module for ViT {
    fn forward(&self, input: &Variable) -> Variable {
        let patches = self.patchify(input);
        let mut h = self.pos.forward(&self.patch_embed.forward(&patches));
        for l in &self.layers {
            h = l.forward(&h);
        }
        let pooled = ops::mean(&self.ln_f.forward(&h), &[1], false);
        self.head.forward(&pooled)
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.patch_embed.params();
        p.extend(self.pos.params());
        for l in &self.layers {
            p.extend(l.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }

    fn set_train(&mut self, train: bool) {
        for l in &mut self.layers {
            l.set_train(train);
        }
    }

    fn name(&self) -> String {
        format!("ViT(img={}, patch={}, d={})", self.image, self.patch, self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn patchify_partitions_pixels() {
        let v = ViT::new(4, 2, 8, 2, 1, 3);
        let x = Variable::constant(Tensor::arange(48, crate::tensor::DType::F32).reshape(&[1, 3, 4, 4]));
        let p = v.patchify(&x);
        assert_eq!(p.dims(), vec![1, 4, 12]);
        // first patch = top-left 2x2 of every channel
        let pv = p.tensor().to_vec();
        assert_eq!(&pv[..12], &[0., 1., 4., 5., 16., 17., 20., 21., 32., 33., 36., 37.]);
    }

    #[test]
    fn forward_shape() {
        let v = ViT::new(32, 4, 48, 4, 1, 10);
        let y = v.forward(&Variable::constant(Tensor::rand([2, 3, 32, 32], -1.0, 1.0)));
        assert_eq!(y.dims(), vec![2, 10]);
    }
}
