//! ASR transformer (paper Table 3's "ASR TR."; also the speech package's
//! acoustic model): conv subsampling frontend over log-mel features +
//! transformer encoder + CTC head.

use crate::autograd::{ops, Variable};
use crate::nn::conv::Padding;
use crate::nn::{Conv2D, LayerNorm, Linear, Module, PositionalEmbedding, TransformerEncoderLayer};

/// See module docs. Input: `[N, 1, T, F]` feature maps (T frames, F mel
/// bins); output: `[N, T/4, classes]` frame logits for CTC.
pub struct AsrTransformer {
    conv1: Conv2D,
    conv2: Conv2D,
    proj: Linear,
    pos: PositionalEmbedding,
    layers: Vec<TransformerEncoderLayer>,
    ln_f: LayerNorm,
    head: Linear,
    feat: usize,
    dim: usize,
}

impl AsrTransformer {
    /// `feat` mel bins, `dim` width, `heads`, `depth`, `classes` output
    /// tokens (incl. CTC blank at index 0).
    pub fn new(feat: usize, dim: usize, heads: usize, depth: usize, classes: usize) -> Self {
        AsrTransformer {
            conv1: Conv2D::square(1, 8, 3, 2, Padding::Same), // T/2, F/2
            conv2: Conv2D::square(8, 8, 3, 2, Padding::Same), // T/4, F/4
            proj: Linear::new(8 * (feat / 4), dim),
            pos: PositionalEmbedding::new(512, dim),
            layers: (0..depth)
                .map(|_| TransformerEncoderLayer::new(dim, heads, dim * 4, 0.0, false))
                .collect(),
            ln_f: LayerNorm::new(dim),
            head: Linear::new(dim, classes),
            feat,
            dim,
        }
    }
}

impl Module for AsrTransformer {
    fn forward(&self, input: &Variable) -> Variable {
        let h = ops::relu(&self.conv1.forward(input));
        let h = ops::relu(&self.conv2.forward(&h));
        // [N, C, T', F'] -> [N, T', C*F']
        let d = h.dims();
        let (n, c, t, f) = (d[0], d[1], d[2], d[3]);
        let h = ops::transpose(&h, &[0, 2, 1, 3]);
        let h = ops::reshape(&h, &[n as isize, t as isize, (c * f) as isize]);
        let mut h = self.pos.forward(&self.proj.forward(&h));
        for l in &self.layers {
            h = l.forward(&h);
        }
        self.head.forward(&self.ln_f.forward(&h))
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.conv1.params();
        p.extend(self.conv2.params());
        p.extend(self.proj.params());
        p.extend(self.pos.params());
        for l in &self.layers {
            p.extend(l.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }

    fn set_train(&mut self, train: bool) {
        for l in &mut self.layers {
            l.set_train(train);
        }
    }

    fn name(&self) -> String {
        format!("AsrTransformer(feat={}, d={})", self.feat, self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn subsamples_time_by_four() {
        let m = AsrTransformer::new(80, 64, 4, 1, 30);
        let x = Variable::constant(Tensor::rand([1, 1, 64, 80], -1.0, 1.0));
        let y = m.forward(&x);
        assert_eq!(y.dims(), vec![1, 16, 30]);
    }
}
