//! ResNet (basic-block) family, narrow ResNet-18-style for 32×32 inputs.

use crate::autograd::{ops, Variable};
use crate::nn::conv::Padding;
use crate::nn::{BatchNorm2d, Conv2D, Linear, Module, Pool2D, ReLU, Sequential, View};

/// A residual basic block: two 3×3 convs with batch norm and an optional
/// 1×1 projection shortcut on stride/width changes.
pub struct BasicBlock {
    conv1: Conv2D,
    bn1: BatchNorm2d,
    conv2: Conv2D,
    bn2: BatchNorm2d,
    shortcut: Option<(Conv2D, BatchNorm2d)>,
}

impl BasicBlock {
    /// Build a block mapping `cin -> cout` with the given stride.
    pub fn new(cin: usize, cout: usize, stride: usize) -> Self {
        let shortcut = if stride != 1 || cin != cout {
            Some((
                Conv2D::square(cin, cout, 1, stride, Padding::Valid),
                BatchNorm2d::new(cout),
            ))
        } else {
            None
        };
        BasicBlock {
            conv1: Conv2D::square(cin, cout, 3, stride, Padding::Same),
            bn1: BatchNorm2d::new(cout),
            conv2: Conv2D::square(cout, cout, 3, 1, Padding::Same),
            bn2: BatchNorm2d::new(cout),
            shortcut,
        }
    }
}

impl Module for BasicBlock {
    fn forward(&self, x: &Variable) -> Variable {
        let h = ops::relu(&self.bn1.forward(&self.conv1.forward(x)));
        let h = self.bn2.forward(&self.conv2.forward(&h));
        let skip = match &self.shortcut {
            Some((c, b)) => b.forward(&c.forward(x)),
            None => x.clone(),
        };
        ops::relu(&ops::add(&h, &skip))
    }

    fn params(&self) -> Vec<Variable> {
        let mut p = self.conv1.params();
        p.extend(self.bn1.params());
        p.extend(self.conv2.params());
        p.extend(self.bn2.params());
        if let Some((c, b)) = &self.shortcut {
            p.extend(c.params());
            p.extend(b.params());
        }
        p
    }

    fn buffers(&self) -> Vec<Variable> {
        let mut b = self.bn1.buffers();
        b.extend(self.bn2.buffers());
        if let Some((_, bn)) = &self.shortcut {
            b.extend(bn.buffers());
        }
        b
    }

    fn set_train(&mut self, train: bool) {
        self.bn1.set_train(train);
        self.bn2.set_train(train);
        if let Some((_, b)) = &mut self.shortcut {
            b.set_train(train);
        }
    }

    fn name(&self) -> String {
        "BasicBlock".into()
    }
}

/// Narrow ResNet-18-style network for `[N, 3, 32, 32]`.
pub fn resnet(classes: usize) -> Sequential {
    let mut m = Sequential::new();
    m.add(Conv2D::square(3, 16, 3, 1, Padding::Same));
    m.add(BatchNorm2d::new(16));
    m.add(ReLU);
    m.add(BasicBlock::new(16, 16, 1));
    m.add(BasicBlock::new(16, 16, 1));
    m.add(BasicBlock::new(16, 32, 2)); // 16x16
    m.add(BasicBlock::new(32, 32, 1));
    m.add(BasicBlock::new(32, 64, 2)); // 8x8
    m.add(BasicBlock::new(64, 64, 1));
    m.add(Pool2D::avg(8, 8, 8, 8)); // global
    m.add(View::new(&[-1, 64]));
    m.add(Linear::new(64, classes));
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn residual_identity_path() {
        // zeroed conv weights -> block(x) == relu(x + bn-ish) shape check
        let mut blk = BasicBlock::new(4, 4, 1);
        blk.set_train(false);
        let x = Variable::constant(Tensor::rand([1, 4, 8, 8], 0.0, 1.0));
        let y = blk.forward(&x);
        assert_eq!(y.dims(), vec![1, 4, 8, 8]);
    }

    #[test]
    fn projection_shortcut_on_stride() {
        let blk = BasicBlock::new(4, 8, 2);
        let x = Variable::constant(Tensor::rand([1, 4, 8, 8], 0.0, 1.0));
        assert_eq!(blk.forward(&x).dims(), vec![1, 8, 4, 4]);
        assert!(blk.params().len() > 6);
    }

    #[test]
    fn full_network_shape() {
        let mut m = resnet(10);
        m.set_train(false);
        let y = m.forward(&Variable::constant(Tensor::rand([2, 3, 32, 32], -1.0, 1.0)));
        assert_eq!(y.dims(), vec![2, 10]);
    }
}
