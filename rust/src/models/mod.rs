//! Model zoo (paper §3: "state-of-the-art, research-ready models ...
//! across a variety of domains"; §5.1.2 Table 3 benchmarks these).
//!
//! Models are *scaled-down* versions of the paper's six benchmark
//! architectures (same topology family, same relative arithmetic-intensity
//! ordering; see DESIGN.md §5) so forward+backward runs on a CPU testbed.

pub mod alexnet;
pub mod asr;
pub mod bert;
pub mod mlp;
pub mod resnet;
pub mod vgg;
pub mod vit;

pub use alexnet::alexnet;
pub use asr::AsrTransformer;
pub use bert::BertLike;
pub use mlp::mlp;
pub use resnet::resnet;
pub use vgg::vgg16;
pub use vit::ViT;

use crate::nn::Module;

/// A named model plus its benchmark input specification.
pub struct ModelSpec {
    /// Paper Table 3 row label.
    pub name: &'static str,
    /// Batch size used in the bench.
    pub batch: usize,
    /// Whether inputs are images `[N,C,H,W]` (true) or token ids `[N,L]`.
    pub image_input: Option<(usize, usize, usize)>, // C, H, W
    /// Sequence length for token models.
    pub seq_len: usize,
    /// Vocabulary for token models.
    pub vocab: usize,
    /// Number of output classes.
    pub classes: usize,
}

/// Build one of the Table 3 models by row name. Returns the module and its
/// input spec.
pub fn by_name(name: &str) -> Option<(Box<dyn Module>, ModelSpec)> {
    match name {
        "alexnet" => Some((
            Box::new(alexnet(10)),
            ModelSpec { name: "alexnet", batch: 8, image_input: Some((3, 32, 32)), seq_len: 0, vocab: 0, classes: 10 },
        )),
        "vgg16" => Some((
            Box::new(vgg16(10)),
            ModelSpec { name: "vgg16", batch: 4, image_input: Some((3, 32, 32)), seq_len: 0, vocab: 0, classes: 10 },
        )),
        "resnet" => Some((
            Box::new(resnet(10)),
            ModelSpec { name: "resnet", batch: 8, image_input: Some((3, 32, 32)), seq_len: 0, vocab: 0, classes: 10 },
        )),
        "bert" => Some((
            Box::new(BertLike::new(1000, 128, 4, 2, 64)),
            ModelSpec { name: "bert", batch: 8, image_input: None, seq_len: 64, vocab: 1000, classes: 1000 },
        )),
        "asr" => Some((
            Box::new(AsrTransformer::new(80, 128, 4, 2, 32)),
            ModelSpec { name: "asr", batch: 4, image_input: Some((1, 128, 80)), seq_len: 0, vocab: 0, classes: 32 },
        )),
        "vit" => Some((
            Box::new(ViT::new(32, 4, 96, 4, 2, 10)),
            ModelSpec { name: "vit", batch: 8, image_input: Some((3, 32, 32)), seq_len: 0, vocab: 0, classes: 10 },
        )),
        _ => None,
    }
}

/// All Table 3 row names.
pub const TABLE3_MODELS: [&str; 6] = ["alexnet", "vgg16", "resnet", "bert", "asr", "vit"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::{ops, Variable};
    use crate::nn::num_params;
    use crate::tensor::{DType, Tensor};

    #[test]
    fn every_table3_model_builds_and_steps() {
        for name in TABLE3_MODELS {
            let (model, spec) = by_name(name).unwrap();
            let x = match spec.image_input {
                Some((c, h, w)) => {
                    Variable::constant(Tensor::rand([2, c, h, w], -1.0, 1.0))
                }
                None => Variable::constant(
                    Tensor::rand([2, spec.seq_len], 0.0, spec.vocab as f64).astype(DType::I64),
                ),
            };
            let y = model.forward(&x);
            assert_eq!(y.dims().last().copied().unwrap(), spec.classes, "{name} head width");
            // full backward reaches every parameter
            ops::sum(&y, &[], false).backward();
            let with_grad = model.params().iter().filter(|p| p.grad().is_some()).count();
            assert_eq!(with_grad, model.params().len(), "{name}: missing grads");
            assert!(num_params(model.as_ref()) > 10_000, "{name} suspiciously small");
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(by_name("gpt5").is_none());
    }
}
