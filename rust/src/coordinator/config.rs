//! Training configuration, parsed from TOML-subset files with CLI
//! `--set section.key=value` overrides.

use std::path::Path;

use crate::util::error::{Error, Result};
use crate::util::toml::{parse, Doc};

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model name (one of [`crate::models::TABLE3_MODELS`] or "mlp").
    pub model: String,
    /// Optimizer: "sgd" | "adam" | "adamw".
    pub optimizer: String,
    /// Peak learning rate.
    pub lr: f64,
    /// Steps to train.
    pub steps: usize,
    /// Batch size (per worker).
    pub batch_size: usize,
    /// Data-parallel worker count (threads).
    pub workers: usize,
    /// Gradient-clip max norm (0 disables).
    pub grad_clip: f64,
    /// Random seed.
    pub seed: u64,
    /// Log every N steps.
    pub log_every: usize,
    /// Checkpoint path ("" disables).
    pub checkpoint: String,
    /// Tensor backend: "cpu" | "lazy" | "xla".
    pub backend: String,
    /// Trace forward + backward + optimizer update into one compiled
    /// program and run training through it (see
    /// [`crate::coordinator::compile_step`]).
    pub compile_step: bool,
    /// Maximum number of batches the classifier eval pass visits.
    pub eval_batches: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "mlp".into(),
            optimizer: "adam".into(),
            lr: 1e-3,
            steps: 100,
            batch_size: 8,
            workers: 1,
            grad_clip: 0.0,
            seed: 42,
            log_every: 10,
            checkpoint: String::new(),
            backend: "cpu".into(),
            compile_step: false,
            eval_batches: 16,
        }
    }
}

impl TrainConfig {
    /// Build from a parsed document (missing keys keep defaults).
    pub fn from_doc(doc: &Doc) -> Result<TrainConfig> {
        let mut c = TrainConfig::default();
        let get_str = |sec: &str, key: &str| -> Option<String> {
            doc.get(sec, key).and_then(|v| v.as_str().map(|s| s.to_string()))
        };
        if let Some(v) = get_str("model", "name") {
            c.model = v;
        }
        if let Some(v) = get_str("train", "optimizer") {
            c.optimizer = v;
        }
        if let Some(v) = doc.get("train", "lr").and_then(|v| v.as_float()) {
            c.lr = v;
        }
        if let Some(v) = doc.get("train", "steps").and_then(|v| v.as_int()) {
            c.steps = v as usize;
        }
        if let Some(v) = doc.get("train", "batch_size").and_then(|v| v.as_int()) {
            c.batch_size = v as usize;
        }
        if let Some(v) = doc.get("train", "workers").and_then(|v| v.as_int()) {
            c.workers = (v as usize).max(1);
        }
        if let Some(v) = doc.get("train", "grad_clip").and_then(|v| v.as_float()) {
            c.grad_clip = v;
        }
        if let Some(v) = doc.get("train", "seed").and_then(|v| v.as_int()) {
            c.seed = v as u64;
        }
        if let Some(v) = doc.get("train", "log_every").and_then(|v| v.as_int()) {
            c.log_every = (v as usize).max(1);
        }
        if let Some(v) = get_str("train", "checkpoint") {
            c.checkpoint = v;
        }
        if let Some(v) = get_str("train", "backend") {
            c.backend = v;
        }
        if let Some(v) = doc.get("train", "compile_step").and_then(|v| v.as_bool()) {
            c.compile_step = v;
        }
        if let Some(v) = doc.get("train", "eval_batches").and_then(|v| v.as_int()) {
            c.eval_batches = (v as usize).max(1);
        }
        c.validate()?;
        Ok(c)
    }

    /// Parse a config file and apply `--set` overrides.
    pub fn load(path: &Path, overrides: &[String]) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("reading {path:?}: {e}")))?;
        let mut doc = parse(&text)?;
        for o in overrides {
            doc.apply_override(o)?;
        }
        Self::from_doc(&doc)
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<()> {
        if self.lr <= 0.0 {
            return Err(Error::Config(format!("lr must be positive, got {}", self.lr)));
        }
        if self.batch_size == 0 || self.steps == 0 {
            return Err(Error::Config("steps and batch_size must be nonzero".into()));
        }
        if !["sgd", "adam", "adamw"].contains(&self.optimizer.as_str()) {
            return Err(Error::Config(format!("unknown optimizer `{}`", self.optimizer)));
        }
        if !["cpu", "lazy", "xla"].contains(&self.backend.as_str()) {
            return Err(Error::Config(format!("unknown backend `{}`", self.backend)));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = parse(
            r#"
            [model]
            name = "bert"
            [train]
            optimizer = "adamw"
            lr = 0.01
            steps = 50
            batch_size = 4
            workers = 2
            backend = "lazy"
            compile_step = true
            eval_batches = 4
            "#,
        )
        .unwrap();
        let c = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(c.model, "bert");
        assert_eq!(c.optimizer, "adamw");
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.steps, 50);
        assert_eq!(c.workers, 2);
        assert_eq!(c.backend, "lazy");
        assert!(c.compile_step);
        assert_eq!(c.eval_batches, 4);
    }

    #[test]
    fn rejects_bad_values() {
        let mut doc = parse("[train]\nlr = 0.1").unwrap();
        doc.apply_override("train.optimizer=lion").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let mut doc2 = Doc::default();
        doc2.apply_override("train.lr=-1").unwrap();
        assert!(TrainConfig::from_doc(&doc2).is_err());
    }

    #[test]
    fn overrides_win() {
        let mut doc = parse("[train]\nlr = 0.1\nsteps = 10").unwrap();
        doc.apply_override("train.lr=0.5").unwrap();
        let c = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(c.lr, 0.5);
        assert_eq!(c.steps, 10);
    }
}
