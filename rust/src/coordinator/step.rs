//! Compiled training steps: forward + backward + gradient clipping +
//! optimizer update captured as **one** traceable Op program and run
//! through the graph compiler (the paper's JIT case study compiles whole
//! train iterations, not just inference graphs).
//!
//! [`compile_step`] traces a complete training step through the
//! [`crate::tensor::TraceBackend`]: the model forward pass, the loss, the
//! autograd sweep ([`crate::autograd::Variable::backward_collect`] exposes
//! gradients as values, so the tape is trace-transparent), branch-free
//! gradient clipping ([`crate::optim::clip_grads`]), and the pure
//! optimizer core ([`crate::optim::UpdateRule`]). The captured program is
//! compiled through the full pass pipeline (DCE / constant folding / CSE /
//! element-wise fusion / memory planning) into a [`CompiledTrainStep`]
//! mapping `(params, opt_state, batch) -> (params', opt_state', loss)`.
//!
//! Three programs come out of the trace:
//!
//! - **full** — the whole step; the single-process fast path.
//! - **backward** — same trace, outputs cut at the gradients (the update
//!   arithmetic is dead code and DCE removes it); used by data-parallel
//!   replicas so the [`crate::dist::GradientSynchronizer`] bucketed
//!   all-reduce can run *between* the traced backward and the traced
//!   update.
//! - **update** — a separate trace of the optimizer core alone, with
//!   gradients as substitutable inputs.
//!
//! Correctness contract: with the same RNG stream, a compiled step is
//! **bit-identical** to the eager loop — the eager optimizers and
//! `clip_grad_norm` now execute the very same tensor formulas, every
//! compiler pass is bit-preserving on the reference CPU backend (PR 3's
//! fuzzed contract), and `Op::RandUniform`/`Op::RandNormal` are effectful
//! ops the passes keep in order, so dropout masks replay identically.
//! `rust/tests/train_step_compiled.rs` pins this down over multi-step
//! parameter trajectories, single-process and world=2.
//!
//! What is *not* capturable: host-side control flow on tensor values
//! (early stopping on the loss), modules that mutate internal buffers
//! during forward (BatchNorm running statistics update eagerly at trace
//! time but are not re-traced per step), and shape-dependent behavior —
//! batch shapes are specialized at trace time, so every step must be fed
//! batches of the traced shape.

use crate::autograd::{BackwardOpts, Variable};
use crate::nn::{categorical_cross_entropy, Module};
use crate::optim::{clip_grads, UpdateRule};
use crate::tensor::graph::{compile, CompileOptions, CompileReport, CompiledProgram, ExecStats};
use crate::tensor::{
    default_backend, BackendGuard, DType, Shape, Tensor, TensorBackend, TraceBackend, ValueRef,
};
use crate::util::error::{Error, Result};

use super::config::TrainConfig;

/// Shapes and dtypes of the batch columns a compiled step consumes each
/// iteration (values are substituted per call; shapes are specialized at
/// trace time). Classifier steps use two columns `(input, target)`; LM
/// steps use one (the token window).
#[derive(Debug, Clone)]
pub struct BatchSpec {
    /// One `(dims, dtype)` entry per batch column.
    pub columns: Vec<(Vec<usize>, DType)>,
}

impl BatchSpec {
    /// The spec describing an example batch.
    pub fn like(batch: &[Tensor]) -> BatchSpec {
        BatchSpec {
            columns: batch.iter().map(|t| (t.dims().to_vec(), t.dtype())).collect(),
        }
    }

    /// Materialize zero-valued example tensors for tracing.
    fn examples(&self) -> Vec<Tensor> {
        self.columns.iter().map(|(dims, dt)| Tensor::full(dims.clone(), 0.0, *dt)).collect()
    }
}

/// The optimizer state a compiled step threads from one iteration to the
/// next, as plain tensors (no `Mutex` slots, no host-side counters).
#[derive(Clone)]
pub struct TrainStepState {
    /// Per-parameter state tensors ([`UpdateRule::state_slots`] each).
    pub per_param: Vec<Vec<Tensor>>,
    /// Scalar f32 step counter (Adam-family bias correction), if used.
    pub t: Option<Tensor>,
}

/// One executed compiled step: the next parameters and optimizer state,
/// the scalar loss, and the executor's memory/op statistics.
pub struct StepResult {
    /// Updated parameters, in registration order.
    pub params: Vec<Tensor>,
    /// Updated optimizer state.
    pub state: TrainStepState,
    /// The step's loss value.
    pub loss: f64,
    /// Op counts and planned/naive peak bytes for this execution.
    pub stats: ExecStats,
}

/// Where each runtime input of one compiled program lives in its constant
/// pool (`None`: the traced computation never read that input).
struct SlotMap {
    params: Vec<Option<usize>>,
    state: Vec<Vec<Option<usize>>>,
    t: Option<usize>,
    batch: Vec<Option<usize>>,
    grads: Vec<Option<usize>>,
}

/// A traced-and-compiled training step; see the module docs. Build with
/// [`compile_step`] (module + cross-entropy) or [`compile_step_fn`]
/// (arbitrary loss).
pub struct CompiledTrainStep {
    rule: UpdateRule,
    full: CompiledProgram,
    bwd: CompiledProgram,
    upd: CompiledProgram,
    full_slots: SlotMap,
    upd_slots: SlotMap,
    n_params: usize,
    param_meta: Vec<(Shape, DType)>,
    batch_meta: Vec<(Shape, DType)>,
}

/// Trace and compile one training step of `model` under `cfg`
/// (optimizer, learning rate, gradient clipping): cross-entropy loss of
/// `model.forward(input)` against integer targets, exactly the arithmetic
/// of [`super::trainer::train_classifier`]'s eager loop.
///
/// Tracing runs the model forward once (consuming one step's RNG draws
/// and any eager buffer updates); reseed afterwards if the subsequent run
/// must align with a reference stream.
pub fn compile_step(
    model: &dyn Module,
    cfg: &TrainConfig,
    spec: &BatchSpec,
) -> Result<CompiledTrainStep> {
    if spec.columns.len() != 2 {
        return Err(Error::Config(format!(
            "compile_step expects (input, target) batch columns, got {}",
            spec.columns.len()
        )));
    }
    let examples = spec.examples();
    let params = model.params();
    compile_step_fn(&params, cfg, &examples, |batch| {
        let out = model.forward(&Variable::constant(batch[0].clone()));
        categorical_cross_entropy(&out, &batch[1])
    })
}

/// Generalized entry: trace `loss_fn` (forward + loss over the batch
/// columns) plus backward, clipping, and the optimizer update for
/// `params` into a [`CompiledTrainStep`]. `batch_examples` fix the batch
/// shapes/dtypes; their values are not baked in.
pub fn compile_step_fn(
    params: &[Variable],
    cfg: &TrainConfig,
    batch_examples: &[Tensor],
    loss_fn: impl FnOnce(&[Tensor]) -> Variable,
) -> Result<CompiledTrainStep> {
    let rule = UpdateRule::from_config(&cfg.optimizer, cfg.lr)?;
    let n = params.len();
    if n == 0 {
        return Err(Error::Config("compile_step: model has no parameters".into()));
    }
    // one open capture at a time, process-wide (the trace lock shared
    // with `graph::trace_and_compile` and the serving session); taken
    // before the state/proto allocations so they cannot leak into another
    // thread's open capture either. The data-parallel trainer additionally
    // brackets compilation with ring barriers to quiesce its replicas.
    let _trace_lock = crate::tensor::graph::trace_lock();
    let mut step_span = crate::obs::span("compile_step");
    step_span.attr_i64("params", n as i64);

    // pre-trace allocations on the *untraced* backend: these enter the
    // trace as external constants, i.e. substitutable per-step inputs
    let param_tensors: Vec<Tensor> = params.iter().map(|p| p.tensor()).collect();
    let state0: Vec<Vec<Tensor>> = param_tensors.iter().map(|p| rule.init_state(p)).collect();
    let t0 = rule.uses_step_count().then(|| Tensor::full([], 0.0, DType::F32));
    let grad_protos: Vec<Tensor> = param_tensors
        .iter()
        .map(|p| Tensor::full(p.dims().to_vec(), 0.0, p.dtype()))
        .collect();

    // ---- trace 1: forward + backward + clip + update --------------------
    let tb = TraceBackend::over(default_backend());
    let (trace_prog, full_slots, full_outputs, bwd_outputs) = {
        let _guard = BackendGuard::install(tb.clone());
        let loss = loss_fn(batch_examples);
        // the same seeding backward_with() performs
        let seed = Tensor::ones(loss.tensor().dims().to_vec());
        let (gradmap, _) = loss.backward_collect(seed, &BackwardOpts::default());
        let raw_grads: Vec<Tensor> = params
            .iter()
            .map(|p| {
                gradmap.get(&p.id()).cloned().ok_or_else(|| {
                    Error::Config(
                        "compile_step: a parameter received no gradient; every parameter \
                         must participate in the loss (or be excluded from the step)"
                            .into(),
                    )
                })
            })
            .collect::<Result<_>>()?;
        let grads = if cfg.grad_clip > 0.0 {
            clip_grads(&raw_grads, cfg.grad_clip).0
        } else {
            raw_grads.clone()
        };
        let t1 = t0.as_ref().map(|t| t.add_scalar(1.0));
        let mut new_params = Vec::with_capacity(n);
        let mut new_state = Vec::with_capacity(n);
        for i in 0..n {
            let (p2, s2) = rule.apply(&param_tensors[i], &grads[i], &state0[i], t1.as_ref());
            new_params.push(p2);
            new_state.push(s2);
        }

        let tracer = tb.interposer();
        let out_ref = |t: &Tensor, what: &str| -> Result<ValueRef> {
            tracer.value_ref_of(t).ok_or_else(|| {
                Error::Config(format!("compile_step: {what} was not produced by the trace"))
            })
        };
        let loss_ref = out_ref(&loss.tensor(), "the loss")?;
        let mut full_outputs: Vec<ValueRef> = Vec::with_capacity(n * (1 + rule.state_slots()) + 2);
        for (i, p2) in new_params.iter().enumerate() {
            full_outputs.push(out_ref(p2, &format!("updated parameter {i}"))?);
        }
        for (i, s2) in new_state.iter().enumerate() {
            for s in s2 {
                full_outputs.push(out_ref(s, &format!("updated state of parameter {i}"))?);
            }
        }
        if let Some(t1) = &t1 {
            full_outputs.push(out_ref(t1, "the step counter")?);
        }
        full_outputs.push(loss_ref);
        let mut bwd_outputs: Vec<ValueRef> = Vec::with_capacity(n + 1);
        for (i, g) in raw_grads.iter().enumerate() {
            bwd_outputs.push(out_ref(g, &format!("gradient of parameter {i}"))?);
        }
        bwd_outputs.push(loss_ref);

        let slots = SlotMap {
            params: param_tensors.iter().map(|p| tracer.const_index_of(p)).collect(),
            state: state0
                .iter()
                .map(|sv| sv.iter().map(|s| tracer.const_index_of(s)).collect())
                .collect(),
            t: t0.as_ref().and_then(|t| tracer.const_index_of(t)),
            batch: batch_examples.iter().map(|b| tracer.const_index_of(b)).collect(),
            grads: Vec::new(),
        };
        (tracer.program(), slots, full_outputs, bwd_outputs)
    };

    let frozen = full_slots.frozen();
    let opts = CompileOptions { frozen_consts: frozen, ..Default::default() };
    // name the sub-program in any verifier/compile failure: one training
    // step compiles three programs from two traces
    let in_program = |which: &str| {
        let which = which.to_string();
        move |e: Error| Error::msg(format!("compile_step: {which} program: {e}"))
    };
    let full = {
        let _s = crate::obs::span("compile_step.forward_loss");
        compile(&trace_prog, &full_outputs, &opts).map_err(in_program("forward+loss"))?
    };
    let bwd = {
        let _s = crate::obs::span("compile_step.backward");
        compile(&trace_prog, &bwd_outputs, &opts).map_err(in_program("backward"))?
    };

    // ---- trace 2: the optimizer update alone (data-parallel split) ------
    let tb2 = TraceBackend::over(default_backend());
    let (upd_prog, upd_slots, upd_outputs) = {
        let _guard = BackendGuard::install(tb2.clone());
        let t1 = t0.as_ref().map(|t| t.add_scalar(1.0));
        let mut outs: Vec<Tensor> = Vec::new();
        let mut state_outs: Vec<Tensor> = Vec::new();
        for i in 0..n {
            let (p2, s2) = rule.apply(&param_tensors[i], &grad_protos[i], &state0[i], t1.as_ref());
            outs.push(p2);
            state_outs.extend(s2);
        }
        let tracer = tb2.interposer();
        let out_ref = |t: &Tensor, what: &str| -> Result<ValueRef> {
            tracer.value_ref_of(t).ok_or_else(|| {
                Error::Config(format!("compile_step: {what} was not produced by the trace"))
            })
        };
        let mut upd_outputs = Vec::with_capacity(outs.len() + state_outs.len() + 1);
        for (i, p2) in outs.iter().enumerate() {
            upd_outputs.push(out_ref(p2, &format!("updated parameter {i}"))?);
        }
        for s in &state_outs {
            upd_outputs.push(out_ref(s, "updated optimizer state")?);
        }
        if let Some(t1) = &t1 {
            upd_outputs.push(out_ref(t1, "the step counter")?);
        }
        let slots = SlotMap {
            params: param_tensors.iter().map(|p| tracer.const_index_of(p)).collect(),
            state: state0
                .iter()
                .map(|sv| sv.iter().map(|s| tracer.const_index_of(s)).collect())
                .collect(),
            t: t0.as_ref().and_then(|t| tracer.const_index_of(t)),
            batch: Vec::new(),
            grads: grad_protos.iter().map(|g| tracer.const_index_of(g)).collect(),
        };
        (tracer.program(), slots, upd_outputs)
    };
    let upd_opts = CompileOptions { frozen_consts: upd_slots.frozen(), ..Default::default() };
    let upd = {
        let _s = crate::obs::span("compile_step.update");
        compile(&upd_prog, &upd_outputs, &upd_opts).map_err(in_program("optimizer update"))?
    };

    Ok(CompiledTrainStep {
        rule,
        full,
        bwd,
        upd,
        full_slots,
        upd_slots,
        n_params: n,
        param_meta: param_tensors.iter().map(|p| (p.shape().clone(), p.dtype())).collect(),
        batch_meta: batch_examples.iter().map(|b| (b.shape().clone(), b.dtype())).collect(),
    })
}

impl SlotMap {
    /// Every substitutable constant slot: fenced off from constant folding.
    fn frozen(&self) -> Vec<usize> {
        let mut v: Vec<usize> = Vec::new();
        v.extend(self.params.iter().flatten());
        v.extend(self.state.iter().flatten().flatten());
        v.extend(self.t.iter());
        v.extend(self.batch.iter().flatten());
        v.extend(self.grads.iter().flatten());
        v
    }
}

impl CompiledTrainStep {
    /// The optimizer core the step was compiled against.
    pub fn rule(&self) -> &UpdateRule {
        &self.rule
    }

    /// Number of parameters the step updates.
    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// Fresh optimizer state (zeros) for `params`.
    pub fn init_state(&self, params: &[Tensor]) -> TrainStepState {
        TrainStepState {
            per_param: params.iter().map(|p| self.rule.init_state(p)).collect(),
            t: self.rule.uses_step_count().then(|| Tensor::full([], 0.0, DType::F32)),
        }
    }

    /// The fully-fused single-process program (`(params, state, batch) ->
    /// (params', state', loss)`).
    pub fn program(&self) -> &CompiledProgram {
        &self.full
    }

    /// The backward-only program (`(params, batch) -> (grads, loss)`).
    pub fn backward_program(&self) -> &CompiledProgram {
        &self.bwd
    }

    /// The update-only program (`(params, grads, state) -> (params',
    /// state')`).
    pub fn update_program(&self) -> &CompiledProgram {
        &self.upd
    }

    /// What each compiler pass did to the full step program.
    pub fn report(&self) -> &CompileReport {
        &self.full.report
    }

    fn check_params(&self, params: &[Tensor]) -> Result<()> {
        if params.len() != self.n_params {
            return Err(Error::Config(format!(
                "compiled step expects {} parameters, got {}",
                self.n_params,
                params.len()
            )));
        }
        for (i, (p, (shape, dt))) in params.iter().zip(&self.param_meta).enumerate() {
            if p.shape() != shape || p.dtype() != *dt {
                return Err(Error::Config(format!(
                    "compiled step parameter {i}: expected {} {}, got {} {}",
                    shape,
                    dt.name(),
                    p.shape(),
                    p.dtype().name()
                )));
            }
        }
        Ok(())
    }

    fn check_batch(&self, batch: &[Tensor]) -> Result<()> {
        if batch.len() != self.batch_meta.len() {
            return Err(Error::Config(format!(
                "compiled step expects {} batch column(s), got {}",
                self.batch_meta.len(),
                batch.len()
            )));
        }
        for (i, (b, (shape, dt))) in batch.iter().zip(&self.batch_meta).enumerate() {
            if b.shape() != shape || b.dtype() != *dt {
                return Err(Error::Config(format!(
                    "compiled step batch column {i}: expected {} {} (shapes are specialized \
                     at trace time; keep batches full-sized), got {} {}",
                    shape,
                    dt.name(),
                    b.shape(),
                    b.dtype().name()
                )));
            }
        }
        Ok(())
    }

    fn check_state(&self, state: &TrainStepState) -> Result<()> {
        let k = self.rule.state_slots();
        if state.per_param.len() != self.n_params
            || state.per_param.iter().any(|s| s.len() != k)
            || state.t.is_some() != self.rule.uses_step_count()
        {
            return Err(Error::Config("compiled step: optimizer state layout mismatch".into()));
        }
        Ok(())
    }

    /// Assemble `(slot, tensor)` overrides plus the donation list for a
    /// program run. Owned inputs (params / state / t / grads) are donated
    /// when `donate` is set; batch handles are shared with the caller and
    /// never donated.
    fn overrides(
        slots: &SlotMap,
        params: Vec<Tensor>,
        state: Option<TrainStepState>,
        grads: Option<Vec<Tensor>>,
        batch: &[Tensor],
        donate: bool,
    ) -> (Vec<(usize, Tensor)>, Vec<usize>) {
        let mut ovr: Vec<(usize, Tensor)> = Vec::new();
        let mut don: Vec<usize> = Vec::new();
        let mut push_owned = |slot: Option<usize>, t: Tensor, don: &mut Vec<usize>| {
            if let Some(s) = slot {
                ovr.push((s, t));
                if donate {
                    don.push(s);
                }
            }
        };
        for (slot, p) in slots.params.iter().zip(params) {
            push_owned(*slot, p, &mut don);
        }
        if let Some(st) = state {
            for (sv, tv) in slots.state.iter().zip(st.per_param) {
                for (slot, t) in sv.iter().zip(tv) {
                    push_owned(*slot, t, &mut don);
                }
            }
            if let (Some(slot), Some(t)) = (slots.t, st.t) {
                push_owned(Some(slot), t, &mut don);
            }
        }
        if let Some(gs) = grads {
            for (slot, g) in slots.grads.iter().zip(gs) {
                push_owned(*slot, g, &mut don);
            }
        }
        for (slot, b) in slots.batch.iter().zip(batch) {
            if let Some(s) = slot {
                ovr.push((s, b.clone()));
            }
        }
        (ovr, don)
    }

    /// Split a program's outputs back into `(params', state', loss)`.
    /// `outs` is consumed in the output order the compiler was given.
    fn unpack(
        &self,
        mut outs: Vec<Tensor>,
        with_loss: bool,
    ) -> (Vec<Tensor>, TrainStepState, f64) {
        let loss = if with_loss {
            let l = outs.pop().expect("compiled step: missing loss output");
            l.item()
        } else {
            f64::NAN
        };
        let t = self.rule.uses_step_count().then(|| {
            outs.pop().expect("compiled step: missing step counter output")
        });
        let k = self.rule.state_slots();
        let state_flat: Vec<Tensor> = outs.split_off(self.n_params);
        let per_param: Vec<Vec<Tensor>> = if k == 0 {
            vec![Vec::new(); self.n_params]
        } else {
            state_flat.chunks(k).map(|c| c.to_vec()).collect()
        };
        (outs, TrainStepState { per_param, t }, loss)
    }

    /// Run one full compiled step: `(params, state, batch) -> (params',
    /// state', loss)`. With `donate`, the incoming parameter and state
    /// buffers are released back to the memory manager at their last use,
    /// so the updated tensors can reuse their storage (pass ownership —
    /// keeping extra handles alive defeats the donation).
    pub fn run(
        &self,
        backend: &dyn TensorBackend,
        params: Vec<Tensor>,
        state: TrainStepState,
        batch: &[Tensor],
        donate: bool,
    ) -> Result<StepResult> {
        self.check_params(&params)?;
        self.check_state(&state)?;
        self.check_batch(batch)?;
        let (ovr, don) =
            Self::overrides(&self.full_slots, params, Some(state), None, batch, donate);
        let (outs, stats) = self.full.run_owned(backend, ovr, &don, false)?;
        let (params, state, loss) = self.unpack(outs, true);
        Ok(StepResult { params, state, loss, stats })
    }

    /// Run the backward half only: `(params, batch) -> (grads, loss)`.
    /// Parameters are borrowed — they are still needed by
    /// [`CompiledTrainStep::run_update`] after gradient synchronization.
    pub fn run_backward(
        &self,
        backend: &dyn TensorBackend,
        params: &[Tensor],
        batch: &[Tensor],
    ) -> Result<(Vec<Tensor>, f64)> {
        self.check_params(params)?;
        self.check_batch(batch)?;
        let (ovr, _) = Self::overrides(
            &self.full_slots,
            params.to_vec(),
            None,
            None,
            batch,
            false,
        );
        let (mut outs, _) = self.bwd.run_owned(backend, ovr, &[], false)?;
        let loss = outs.pop().expect("compiled step: missing loss output").item();
        Ok((outs, loss))
    }

    /// Run the update half only: `(params, grads, state) -> (params',
    /// state')`. Gradients typically arrive from
    /// [`crate::dist::GradientSynchronizer::average_tensors`].
    ///
    /// Note the data-parallel composition applies no gradient clipping,
    /// mirroring the eager `train_data_parallel` loop.
    pub fn run_update(
        &self,
        backend: &dyn TensorBackend,
        params: Vec<Tensor>,
        grads: Vec<Tensor>,
        state: TrainStepState,
        donate: bool,
    ) -> Result<(Vec<Tensor>, TrainStepState, ExecStats)> {
        self.check_params(&params)?;
        self.check_state(&state)?;
        if grads.len() != self.n_params {
            return Err(Error::Config(format!(
                "compiled step expects {} gradients, got {}",
                self.n_params,
                grads.len()
            )));
        }
        let (ovr, don) =
            Self::overrides(&self.upd_slots, params, Some(state), Some(grads), &[], donate);
        let (outs, stats) = self.upd.run_owned(backend, ovr, &don, false)?;
        let (params, state, _) = self.unpack(outs, false);
        Ok((params, state, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp;

    #[test]
    fn compile_step_validates_inputs() {
        let model = mlp(&[4, 3]);
        let cfg = TrainConfig::default(); // adam
        let batch =
            vec![Tensor::zeros([2, 4]), Tensor::from_slice(&[0i64, 1], [2])];
        let step = compile_step(&model, &cfg, &BatchSpec::like(&batch)).unwrap();
        let be = default_backend();
        let params: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
        let state = step.init_state(&params);
        // shapes are specialized: a different batch size is rejected
        let bad = vec![Tensor::zeros([3, 4]), Tensor::from_slice(&[0i64, 1, 0], [3])];
        assert!(step.run(be.as_ref(), params.clone(), state.clone(), &bad, false).is_err());
        // batch arity is checked
        assert!(step
            .run(be.as_ref(), params.clone(), state.clone(), &batch[..1], false)
            .is_err());
        // a well-formed step runs
        let ok = step.run(be.as_ref(), params, state, &batch, false).unwrap();
        assert!(ok.loss.is_finite());
        assert_eq!(ok.params.len(), step.n_params());
        assert!(ok.state.t.is_some(), "adam threads a step counter");
        // the cross-entropy entry point wants (input, target) columns
        let one_col = BatchSpec { columns: vec![(vec![2, 4], DType::F32)] };
        assert!(compile_step(&model, &cfg, &one_col).is_err());
    }

    #[test]
    fn unknown_optimizer_is_rejected_at_compile() {
        let model = mlp(&[4, 3]);
        let cfg = TrainConfig { optimizer: "lion".into(), ..Default::default() };
        let batch =
            vec![Tensor::zeros([2, 4]), Tensor::from_slice(&[0i64, 1], [2])];
        assert!(compile_step(&model, &cfg, &BatchSpec::like(&batch)).is_err());
    }
}
